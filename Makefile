# Repo-level driver targets. The tier-1 gate is `make verify`.

RUST_DIR := rust

.PHONY: verify build test fmt clippy artifacts bench bench-fleet bench-serve

# Everything CI runs: release build, tests, formatting, lints.
verify: build test fmt clippy

build:
	cd $(RUST_DIR) && cargo build --release

test:
	cd $(RUST_DIR) && cargo test -q

fmt:
	cd $(RUST_DIR) && cargo fmt --check

clippy:
	cd $(RUST_DIR) && cargo clippy -- -D warnings

# Regenerate the AOT HLO artifacts (needs the Python toolchain; see
# python/compile/aot.py).
artifacts:
	python3 python/compile/aot.py

# The perf trajectory: native-kernel + pool + campaign benches, recorded
# to BENCH_native.json at the repo root (methodology in EXPERIMENTS.md).
# Set PAOTA_BENCH_FAST=1 for a seconds-long smoke run (CI does).
bench:
	cd $(RUST_DIR) && PAOTA_BENCH_OUT=$(CURDIR)/BENCH_native.json \
		cargo bench --bench native_kernel

# Fleet scale-out trajectory: K ∈ {10², 10⁴, 10⁶} periodic-PAOTA runs
# (rounds/sec + peak RSS) and the indexed-vs-rebuild handover sweep,
# recorded to BENCH_fleet.json at the repo root. PAOTA_BENCH_FAST=1
# caps the fleet at K = 10⁴ for CI smoke runs.
bench-fleet:
	cd $(RUST_DIR) && PAOTA_BENCH_OUT=$(CURDIR)/BENCH_fleet.json \
		cargo bench --bench fleet_scale

# Wire-service trajectory: loopback serve + loadgen at increasing session
# concurrency (requests/sec, submit-latency percentiles, busy/reject
# counters), recorded to BENCH_serve.json at the repo root.
# PAOTA_BENCH_FAST=1 shrinks rounds/fleet/sweep for CI smoke runs.
bench-serve:
	cd $(RUST_DIR) && PAOTA_BENCH_OUT=$(CURDIR)/BENCH_serve.json \
		cargo bench --bench serve_load
