//! Mini benchmark harness (offline stand-in for criterion).
//!
//! `cargo bench` targets are `harness = false` binaries that call
//! [`Bench::new`] and register closures with [`Bench::iter`]. Each gets a
//! warmup phase, then timed batches until a minimum measurement window is
//! reached; mean, standard deviation, and throughput are reported in a
//! criterion-like format:
//!
//! ```text
//! aircomp/aggregate_k100      time: [1.234 ms ± 0.056 ms]  (812.3 MiB/s)
//! ```
//!
//! `PAOTA_BENCH_FAST=1` shrinks the measurement window for smoke runs.

use std::time::{Duration, Instant};

/// One benchmark group.
pub struct Bench {
    group: String,
    /// Minimum measurement window per benchmark.
    window: Duration,
    /// Warmup window.
    warmup: Duration,
}

/// A single measurement result.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub mean: Duration,
    pub std_dev: Duration,
    pub iters: u64,
}

impl Bench {
    pub fn new(group: &str) -> Self {
        let fast = std::env::var("PAOTA_BENCH_FAST").is_ok();
        Self {
            group: group.to_string(),
            window: if fast {
                Duration::from_millis(200)
            } else {
                Duration::from_secs(2)
            },
            warmup: if fast {
                Duration::from_millis(50)
            } else {
                Duration::from_millis(500)
            },
        }
    }

    /// Time `f` repeatedly; print and return the measurement.
    pub fn iter<F: FnMut()>(&self, name: &str, mut f: F) -> Measurement {
        // Warmup.
        let start = Instant::now();
        let mut warm_iters = 0u64;
        while start.elapsed() < self.warmup || warm_iters == 0 {
            f();
            warm_iters += 1;
        }
        // Pick a batch size aiming at ~10 batches per window.
        let per_iter = start.elapsed() / warm_iters.max(1) as u32;
        let batch = ((self.window.as_secs_f64() / 10.0 / per_iter.as_secs_f64().max(1e-9))
            .ceil() as u64)
            .max(1);

        let mut samples: Vec<f64> = Vec::new();
        let meas_start = Instant::now();
        let mut total_iters = 0u64;
        while meas_start.elapsed() < self.window || samples.len() < 3 {
            let t0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            samples.push(t0.elapsed().as_secs_f64() / batch as f64);
            total_iters += batch;
        }

        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let var = samples
            .iter()
            .map(|s| (s - mean) * (s - mean))
            .sum::<f64>()
            / samples.len() as f64;
        let m = Measurement {
            name: format!("{}/{name}", self.group),
            mean: Duration::from_secs_f64(mean),
            std_dev: Duration::from_secs_f64(var.sqrt()),
            iters: total_iters,
        };
        println!(
            "{:<44} time: [{} ± {}]  ({} iters)",
            m.name,
            crate::util::timer::fmt_duration(m.mean),
            crate::util::timer::fmt_duration(m.std_dev),
            m.iters
        );
        m
    }

    /// Like [`Bench::iter`] but also reports throughput for `bytes` moved
    /// per iteration.
    pub fn iter_bytes<F: FnMut()>(&self, name: &str, bytes: usize, f: F) -> Measurement {
        let m = self.iter(name, f);
        let gbps = bytes as f64 / m.mean.as_secs_f64() / 1e9;
        println!("{:<44}   throughput: {gbps:.2} GB/s", "");
        m
    }
}

/// Print a section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_sane() {
        std::env::set_var("PAOTA_BENCH_FAST", "1");
        let b = Bench::new("test");
        let mut x = 0u64;
        let m = b.iter("noop-ish", || {
            x = x.wrapping_add(1);
            std::hint::black_box(x);
        });
        assert!(m.mean < Duration::from_micros(100));
        assert!(m.iters > 0);
    }
}
