//! Metrics: curve extraction, the paper's time-to-accuracy table, and CSV
//! emission for every figure the harness regenerates.
//!
//! Everything here consumes the canonical [`RoundRecord`] stream emitted
//! by the coordinator's [`Telemetry`](crate::fl::Telemetry) recorder —
//! contiguous rounds, monotone `sim_time` — so curves from different
//! algorithms (and different round timings) overlay directly.

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use crate::fl::{RoundRecord, RunResult};

/// (round, sim_time, value) triples extracted from a run.
#[derive(Debug, Clone)]
pub struct Curve {
    pub name: String,
    pub points: Vec<(usize, f64, f64)>,
}

impl Curve {
    /// Test-accuracy curve (evaluated rounds only).
    pub fn accuracy(name: &str, run: &RunResult) -> Curve {
        Curve {
            name: name.to_string(),
            points: run
                .records
                .iter()
                .filter_map(|r| {
                    r.eval
                        .map(|e| (r.round, r.sim_time, e.accuracy as f64))
                })
                .collect(),
        }
    }

    /// Loss-gap curve `F(w^r) − F(w*)` from the probe loss.
    pub fn loss_gap(name: &str, run: &RunResult, f_star: f64) -> Curve {
        Curve {
            name: name.to_string(),
            points: run
                .records
                .iter()
                .filter_map(|r| {
                    r.probe_loss
                        .map(|l| (r.round, r.sim_time, (l as f64 - f_star).max(0.0)))
                })
                .collect(),
        }
    }

    /// Value at the last point.
    pub fn last(&self) -> Option<f64> {
        self.points.last().map(|p| p.2)
    }
}

/// One row of the paper's Table I: first round/time reaching an accuracy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimeToAccuracy {
    pub target: f64,
    /// 1-based round count, as the paper reports. None = never reached.
    pub rounds: Option<usize>,
    pub time_s: Option<f64>,
}

/// Compute time-to-accuracy rows for each target (fractions in [0,1]).
pub fn time_to_accuracy(records: &[RoundRecord], targets: &[f64]) -> Vec<TimeToAccuracy> {
    targets
        .iter()
        .map(|&target| {
            let hit = records.iter().find(|r| {
                r.eval
                    .map(|e| e.accuracy as f64 >= target)
                    .unwrap_or(false)
            });
            TimeToAccuracy {
                target,
                rounds: hit.map(|r| r.round + 1),
                time_s: hit.map(|r| r.sim_time),
            }
        })
        .collect()
}

/// Create `path` (and its parent directory) for CSV emission — the one
/// shared entry point for every CSV the harness writes, so directory
/// errors surface instead of being silently swallowed.
fn create_csv(path: &Path) -> Result<std::fs::File> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating output directory {}", dir.display()))?;
        }
    }
    std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))
}

/// Write a CSV as a `header` line plus preformatted `rows` (the shared
/// writer for table-shaped outputs like Table I).
pub fn write_csv_lines<I, S>(path: &Path, header: &str, rows: I) -> Result<()>
where
    I: IntoIterator<Item = S>,
    S: AsRef<str>,
{
    let mut f = create_csv(path)?;
    writeln!(f, "{header}")?;
    for row in rows {
        writeln!(f, "{}", row.as_ref())?;
    }
    Ok(())
}

/// Write curves as CSV: `name,round,time_s,value`.
pub fn write_curves_csv(path: &Path, curves: &[Curve]) -> Result<()> {
    let mut f = create_csv(path)?;
    writeln!(f, "series,round,time_s,value")?;
    for c in curves {
        for (round, t, v) in &c.points {
            writeln!(f, "{},{round},{t:.3},{v:.6}", c.name)?;
        }
    }
    Ok(())
}

/// Write per-round telemetry as CSV (one run).
pub fn write_records_csv(path: &Path, run: &RunResult) -> Result<()> {
    let mut f = create_csv(path)?;
    writeln!(
        f,
        "round,time_s,train_loss,probe_loss,test_loss,test_acc,participants,mean_staleness,mean_power"
    )?;
    for r in &run.records {
        writeln!(
            f,
            "{},{:.3},{:.6},{},{},{},{},{:.3},{:.4}",
            r.round,
            r.sim_time,
            r.train_loss,
            r.probe_loss.map_or(String::new(), |v| format!("{v:.6}")),
            r.eval.map_or(String::new(), |e| format!("{:.6}", e.loss)),
            r.eval.map_or(String::new(), |e| format!("{:.4}", e.accuracy)),
            r.participants,
            r.mean_staleness,
            r.mean_power,
        )?;
    }
    Ok(())
}

/// Render Table-I-style rows for several algorithms.
pub fn format_table1(rows: &[(String, Vec<TimeToAccuracy>)], targets: &[f64]) -> String {
    let mut out = String::new();
    out.push_str("| Target Accuracy |        |");
    for t in targets {
        out.push_str(&format!(" {:>6.0}% |", t * 100.0));
    }
    out.push('\n');
    for (name, ttas) in rows {
        out.push_str(&format!("| {name:<15} | round  |"));
        for t in ttas {
            match t.rounds {
                Some(r) => out.push_str(&format!(" {r:>7} |")),
                None => out.push_str("       – |"),
            }
        }
        out.push('\n');
        out.push_str(&format!("| {:<15} | time/s |", ""));
        for t in ttas {
            match t.time_s {
                Some(s) => out.push_str(&format!(" {s:>7.1} |")),
                None => out.push_str("       – |"),
            }
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Algorithm;
    use crate::runtime::EvalOut;

    fn rec(round: usize, t: f64, acc: f32, probe: f32) -> RoundRecord {
        RoundRecord {
            round,
            sim_time: t,
            train_loss: 1.0,
            probe_loss: Some(probe),
            eval: Some(EvalOut {
                loss: 1.0,
                accuracy: acc,
            }),
            participants: 3,
            mean_staleness: 0.0,
            mean_power: 1.0,
        }
    }

    fn fake_run() -> RunResult {
        RunResult {
            algorithm: Algorithm::default(),
            records: vec![
                rec(0, 8.0, 0.3, 2.0),
                rec(1, 16.0, 0.55, 1.5),
                rec(2, 24.0, 0.62, 1.2),
                rec(3, 32.0, 0.71, 1.0),
            ],
            final_weights: vec![],
        }
    }

    #[test]
    fn time_to_accuracy_finds_first_crossing() {
        let run = fake_run();
        let rows = time_to_accuracy(&run.records, &[0.5, 0.6, 0.7, 0.8]);
        assert_eq!(rows[0].rounds, Some(2));
        assert_eq!(rows[0].time_s, Some(16.0));
        assert_eq!(rows[1].rounds, Some(3));
        assert_eq!(rows[2].rounds, Some(4));
        assert_eq!(rows[3].rounds, None);
        assert_eq!(rows[3].time_s, None);
    }

    #[test]
    fn loss_gap_clamps_at_zero() {
        let run = fake_run();
        let c = Curve::loss_gap("paota", &run, 1.1);
        // Last probe 1.0 < f_star 1.1 → gap clamped to 0.
        assert_eq!(c.last(), Some(0.0));
        assert!(c.points[0].2 > 0.0);
    }

    #[test]
    fn accuracy_curve_extraction() {
        let run = fake_run();
        let c = Curve::accuracy("paota", &run);
        assert_eq!(c.points.len(), 4);
        assert!((c.last().unwrap() - 0.71).abs() < 1e-6); // f32→f64 cast slack
    }

    #[test]
    fn csv_roundtrip_files() {
        let dir = std::env::temp_dir().join("paota_metrics_test");
        let run = fake_run();
        let curves = vec![Curve::accuracy("a", &run)];
        let p1 = dir.join("curves.csv");
        write_curves_csv(&p1, &curves).unwrap();
        let text = std::fs::read_to_string(&p1).unwrap();
        assert!(text.starts_with("series,round,time_s,value"));
        assert_eq!(text.lines().count(), 5);

        let p2 = dir.join("records.csv");
        write_records_csv(&p2, &run).unwrap();
        let text = std::fs::read_to_string(&p2).unwrap();
        assert_eq!(text.lines().count(), 5);
    }

    #[test]
    fn write_csv_lines_creates_parent_dirs_and_reports_failures() {
        let dir = std::env::temp_dir()
            .join("paota_metrics_test")
            .join("nested")
            .join("deeper");
        let p = dir.join("t.csv");
        write_csv_lines(&p, "a,b", ["1,2".to_string(), "3,4".to_string()]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text, "a,b\n1,2\n3,4\n");
        // A path whose parent is an existing *file* must error loudly.
        let bad = p.join("impossible.csv");
        assert!(write_csv_lines(&bad, "x", ["y"]).is_err());
    }

    #[test]
    fn table_format_has_all_rows() {
        let run = fake_run();
        let rows = vec![(
            "PAOTA".to_string(),
            time_to_accuracy(&run.records, &[0.5, 0.8]),
        )];
        let s = format_table1(&rows, &[0.5, 0.8]);
        assert!(s.contains("PAOTA"));
        assert!(s.contains("round"));
        assert!(s.contains("time/s"));
        assert!(s.contains('–')); // unreached target marker
    }
}
