//! CA-PAOTA — channel/gradient-aware participant scheduling on top of
//! PAOTA (after arXiv 2212.00491, "Scheduling and Aggregation Design for
//! Asynchronous Federated Learning over Wireless Networks").
//!
//! PAOTA's rule is *take-all*: every client that finished inside the ΔT
//! slot uploads, however deep its fade and however little its update
//! moved. This policy keeps PAOTA's periodic AirComp timing, power
//! control and aggregation untouched and only overrides
//! [`select_participants`](super::AggregationPolicy::select_participants):
//! ready clients are ranked by the scheduling metric
//!
//! ```text
//!   score_k = |h_k| · ‖Δw_k‖̂
//! ```
//!
//! — the fading amplitude drawn at scheduling time multiplied by the
//! client's last observed update norm (an optimistic prior for clients
//! that never uploaded, so fresh clients are explored by channel quality
//! first). The top-`m` clients upload; the rest stay in the ready pool
//! and are re-offered next slot with correspondingly higher staleness —
//! exactly the scheduling/staleness trade-off the reference studies.
//!
//! `m` comes from `Config::participants` when set; with the default
//! `participants = 0` an adaptive rule keeps every ready client whose
//! score is at least the ready-pool mean (at least one), so the scheme
//! degrades gracefully to take-all when the pool is homogeneous.
//!
//! Registered as `ca_paota` in [`super::registry`]; compare against plain
//! PAOTA with `repro ablation scheduling`.

use anyhow::Result;

use crate::channel::Mac;
use crate::config::Config;
use crate::util::vecmath;

use super::coordinator::{AggregationPolicy, RngStreams, RoundAction, RoundTiming, Upload};
use super::paota::Paota;
use super::TrainContext;

/// Update-norm prior for clients that never uploaded: large enough to
/// dominate any observed norm, so unexplored clients are scheduled first
/// (ordered among themselves by channel quality), finite so the fading
/// amplitude still differentiates them.
const NORM_PRIOR: f64 = 1e6;

/// PAOTA with channel/gradient-aware top-`m` participant selection.
pub struct CaPaota {
    inner: Paota,
    mac: Mac,
    /// Fixed upload budget per slot; 0 = adaptive mean-threshold rule.
    target: usize,
    /// Last observed ‖Δw_k‖ per client (NORM_PRIOR until first upload).
    norm_est: Vec<f64>,
}

impl CaPaota {
    pub fn new(ctx: &TrainContext, cfg: &Config) -> Self {
        Self {
            inner: Paota::new(ctx, cfg),
            mac: Mac::new(cfg.channel),
            target: cfg.participants,
            norm_est: vec![NORM_PRIOR; ctx.clients()],
        }
    }
}

impl AggregationPolicy for CaPaota {
    fn name(&self) -> &str {
        "ca_paota"
    }

    fn timing(&self) -> RoundTiming {
        RoundTiming::Periodic
    }

    fn needs_deltas(&self) -> bool {
        true
    }

    fn select_participants(&mut self, offered: &[usize], rngs: &mut RngStreams) -> Vec<usize> {
        if offered.len() <= 1 {
            return offered.to_vec();
        }
        // Scheduling-phase CSI snapshot: one fading draw per ready client
        // (independent of the transmission-phase draws in `on_uploads`).
        let gains = self.mac.draw_fading_gains(&mut rngs.channel, offered.len());
        rank_and_select(offered, &gains, &self.norm_est, self.target)
    }

    fn on_uploads(
        &mut self,
        round: usize,
        global: &[f32],
        uploads: &[Upload],
        rngs: &mut RngStreams,
    ) -> Result<RoundAction> {
        for up in uploads {
            self.norm_est[up.client] = vecmath::norm(&up.delta).max(1e-12);
        }
        self.inner.on_uploads(round, global, uploads, rngs)
    }

    fn on_global_delta(&mut self, delta: &[f32]) {
        self.inner.on_global_delta(delta);
    }
}

/// Rank `offered` by `|h|·‖Δw‖̂` and keep the top `target` (or, with
/// `target == 0`, everyone scoring at least the pool mean — minimum one).
/// Returns client ids in ascending order, the coordinator's deterministic
/// fleet-scan convention.
fn rank_and_select(
    offered: &[usize],
    gains: &[f64],
    norm_est: &[f64],
    target: usize,
) -> Vec<usize> {
    let mut ranked: Vec<(usize, f64)> = offered
        .iter()
        .zip(gains)
        .map(|(&client, &g2)| (client, g2.sqrt() * norm_est[client]))
        .collect();
    let m = if target > 0 {
        target.min(ranked.len())
    } else {
        let mean = ranked.iter().map(|r| r.1).sum::<f64>() / ranked.len() as f64;
        ranked.iter().filter(|r| r.1 >= mean).count().max(1)
    };
    ranked.sort_by(|a, b| {
        b.1.partial_cmp(&a.1)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.0.cmp(&b.0))
    });
    let mut chosen: Vec<usize> = ranked[..m].iter().map(|r| r.0).collect();
    chosen.sort_unstable();
    chosen
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selection_respects_target_and_returns_offered_ids() {
        let offered = vec![1, 3, 4, 7, 8, 9];
        let gains = vec![0.5, 2.0, 0.1, 1.5, 0.9, 3.0];
        let norms = vec![NORM_PRIOR; 10];
        let chosen = rank_and_select(&offered, &gains, &norms, 3);
        assert_eq!(chosen.len(), 3);
        for c in &chosen {
            assert!(offered.contains(c), "chose {c} outside offered set");
        }
        // Equal norms → pure channel ranking: gains 3.0, 2.0, 1.5 belong
        // to clients 9, 3, 7 — returned in client-id order.
        assert_eq!(chosen, vec![3, 7, 9]);
    }

    #[test]
    fn adaptive_rule_keeps_at_least_one_and_not_more_than_offered() {
        let offered: Vec<usize> = (0..6).collect();
        let gains = vec![1.0e-6, 1.0e-6, 1.0e-6, 1.0e-6, 1.0e-6, 9.0];
        let norms = vec![1.0; 6];
        let chosen = rank_and_select(&offered, &gains, &norms, 0);
        // One client dominates the mean: only it survives.
        assert_eq!(chosen, vec![5]);

        let flat = vec![1.0; 6];
        let all = rank_and_select(&offered, &flat, &norms, 0);
        // Homogeneous pool degrades to take-all.
        assert_eq!(all, offered);
    }

    #[test]
    fn target_larger_than_pool_takes_everyone() {
        let offered = vec![2, 5];
        let gains = vec![1.0, 4.0];
        let norms = vec![1.0; 6];
        assert_eq!(rank_and_select(&offered, &gains, &norms, 10), vec![2, 5]);
    }

    #[test]
    fn low_update_norm_client_is_deferred() {
        let offered = vec![0, 1, 2, 3];
        let gains = vec![1.0; 4];
        // Client 0's last update barely moved; the rest sit at the prior.
        let norms = vec![1e-9, NORM_PRIOR, NORM_PRIOR, NORM_PRIOR];
        let chosen = rank_and_select(&offered, &gains, &norms, 2);
        assert!(!chosen.contains(&0), "vanishing-update client was scheduled: {chosen:?}");
    }
}
