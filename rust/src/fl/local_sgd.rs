//! Ideal synchronous Local SGD (FedAvg, McMahan et al.) — baseline (1) of
//! §IV-B, as an [`AggregationPolicy`]: per round, a fixed cohort receives
//! the global model, runs M local SGD steps, and uploads *losslessly*;
//! the PS averages with data-size weights `D_k/ΣD`. Under the
//! coordinator's [`Synchronous`](RoundTiming::Synchronous) timing the
//! round's virtual duration is the slowest participant's compute latency
//! — exactly the straggler bottleneck PAOTA removes.
//!
//! The aggregation reuses the AirComp kernel with `coef = D_k` and zero
//! noise, which is then *exactly* the FedAvg weighted mean — one code
//! path, two semantics.

use anyhow::Result;

use crate::config::Config;

use super::coordinator::{AggregationPolicy, RngStreams, RoundAction, RoundTiming, Upload};
use super::TrainContext;

/// Lossless synchronous FedAvg.
pub struct LocalSgd {
    participants: usize,
    /// D_k per client — the FedAvg aggregation weights.
    sizes: Vec<f32>,
}

impl LocalSgd {
    pub fn new(ctx: &TrainContext, cfg: &Config) -> Self {
        Self {
            participants: ctx.sync_participants(cfg),
            // D_k comes from the partition metadata — no shard pixels
            // are materialized to build the weights.
            sizes: (0..ctx.partition.num_clients())
                .map(|i| ctx.partition.client_len(i) as f32)
                .collect(),
        }
    }
}

impl AggregationPolicy for LocalSgd {
    fn name(&self) -> &str {
        "local_sgd"
    }

    fn timing(&self) -> RoundTiming {
        RoundTiming::Synchronous
    }

    fn select_participants(&mut self, offered: &[usize], rngs: &mut RngStreams) -> Vec<usize> {
        // Positions into `offered` mapped back to client ids (identity for
        // the synchronous full fleet, but correct for any offered set).
        let n = self.participants.min(offered.len());
        rngs.pick
            .choose_indices(offered.len(), n)
            .into_iter()
            .map(|i| offered[i])
            .collect()
    }

    fn on_uploads(
        &mut self,
        _round: usize,
        _global: &[f32],
        uploads: &[Upload],
        _rngs: &mut RngStreams,
    ) -> Result<RoundAction> {
        Ok(RoundAction::Aggregate {
            coefs: uploads.iter().map(|up| self.sizes[up.client]).collect(),
            noise: Vec::new(), // lossless uplink
            deltas: false,
            mean_power: 0.0,
        })
    }
}
