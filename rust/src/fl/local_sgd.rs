//! Ideal synchronous Local SGD (FedAvg, McMahan et al.) — baseline (1) of
//! §IV-B: per round, a fixed number of clients receive the global model,
//! run M local SGD steps, and upload *losslessly*; the PS averages with
//! data-size weights `D_k/ΣD`. The round's virtual duration is the slowest
//! participant's compute latency — exactly the straggler bottleneck PAOTA
//! removes.
//!
//! The aggregation itself reuses the AirComp artifact with `coef = D_k`
//! and zero noise, which is then *exactly* the FedAvg weighted mean —
//! one code path, two semantics.

use anyhow::Result;

use crate::config::Config;
use crate::sim::VirtualClock;
use crate::util::Rng;

use super::{RoundRecord, RunResult, TrainContext};

pub fn run(ctx: &TrainContext, cfg: &Config) -> Result<RunResult> {
    let dim = ctx.dim();
    let k = ctx.clients();
    let m = ctx.rt.manifest().clone();
    let participants = ctx.sync_participants(cfg);
    let latency = cfg.latency();

    let mut lat_rng = Rng::with_stream(cfg.seed, 0x1a7);
    let mut batch_rng = Rng::with_stream(cfg.seed, 0xba7c);
    let mut pick_rng = Rng::with_stream(cfg.seed, 0x91c4);

    let mut w_g = ctx.init_weights();
    let mut clock = VirtualClock::new();
    let mut stack = vec![0.0f32; k * dim];
    let mut coef = vec![0.0f32; k];
    let noise = vec![0.0f32; dim]; // lossless uplink

    let mut records = Vec::with_capacity(cfg.rounds);

    for round in 0..cfg.rounds {
        let chosen = pick_rng.choose_indices(k, participants);

        // Synchronous: the round lasts as long as its slowest participant.
        let mut round_time = 0.0f64;
        let mut train_loss_sum = 0.0f64;
        coef.iter_mut().for_each(|c| *c = 0.0);
        stack.iter_mut().for_each(|v| *v = 0.0);

        let jobs: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = chosen
            .iter()
            .map(|&i| {
                round_time = round_time.max(latency.draw(&mut lat_rng));
                let (xs, ys) = ctx.partition.clients[i].sample_batches(
                    m.local_steps,
                    m.batch,
                    &mut batch_rng,
                );
                (w_g.clone(), xs, ys)
            })
            .collect();
        for (&i, out) in chosen.iter().zip(ctx.train_many(jobs, cfg.lr)?) {
            train_loss_sum += out.loss as f64;
            stack[i * dim..(i + 1) * dim].copy_from_slice(&out.weights);
            coef[i] = ctx.partition.clients[i].data.len() as f32; // D_k
        }
        clock.advance(round_time);

        // Lossless FedAvg: data-size-weighted mean.
        w_g = ctx.rt.aggregate(&stack, &coef, &noise)?;

        let eval = if round % cfg.eval_every == 0 || round + 1 == cfg.rounds {
            Some(ctx.evaluate(&w_g)?)
        } else {
            None
        };
        let probe_loss = if eval.is_some() {
            Some(ctx.probe_loss(&w_g)?)
        } else {
            None
        };
        records.push(RoundRecord {
            round,
            sim_time: clock.now(),
            train_loss: (train_loss_sum / participants as f64) as f32,
            probe_loss,
            eval,
            participants,
            mean_staleness: 0.0,
            mean_power: 0.0,
        });
        crate::debug!(
            "local_sgd r={round} t={:.0}s loss={:.4} acc={:?}",
            clock.now(),
            records.last().unwrap().train_loss,
            records.last().unwrap().eval.map(|e| e.accuracy),
        );
    }

    Ok(RunResult {
        algorithm: crate::config::Algorithm::LocalSgd,
        records,
        final_weights: w_g,
    })
}
