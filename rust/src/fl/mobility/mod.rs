//! `fl::mobility` — client roaming over the multi-cell tree: the
//! client → cell assignment becomes a **function of simulated time**.
//!
//! PR-3's [`crate::fl::topology`] froze the [`GroupMap`] at construction;
//! this subsystem makes it roam. Three parts (the Air-FEEL overview,
//! arXiv 2208.05643, names device mobility/handover as the next
//! deployment axis; Air-FedGA, arXiv 2507.05704, shows grouping must
//! track device state):
//!
//! 1. **Mobility models** ([`MobilityModel`]): seed-deterministic
//!    per-client trajectories over the cell set —
//!    * `static` — the PR-3 degeneracy: nobody ever moves, and a run is
//!      **bitwise** the frozen-assignment run (`tests/mobility.rs`);
//!    * `markov` — per-client cell-transition chain with exponential
//!      dwell times (mean `mobility.dwell_mean` slots), uniform target
//!      over the other cells;
//!    * `waypoint` — random-waypoint motion on the unit square with
//!      cells on a grid; the **nearest-cell rule** yields the
//!      assignment, so geometry (not a transition matrix) drives churn.
//!    Every client's trajectory derives from its own RNG stream
//!    `(seed, client)`, so trajectories are reproducible per client and
//!    independent of how often the runner observes them
//!    (`handover_every` changes *when* moves are applied, never *where*
//!    clients go).
//! 2. **Handover protocol** — applied by
//!    [`crate::fl::topology::multi_cell`] at slot boundaries (every
//!    `mobility.handover_every` slots): the runner detaches movers from
//!    the old cell's event queue ([`crate::fl::Coordinator::detach_client`])
//!    and re-admits them under a [`HandoverPolicy`]:
//!    * [`HandoverPolicy::Deliver`] — the in-flight update still lands
//!      OTA in the old cell; the membership flip is deferred until that
//!      upload is served, then the client respawns fresh in the new cell;
//!    * [`HandoverPolicy::Forward`] — the in-flight state is carried to
//!      the new cell verbatim (base round/weights, finish event), so
//!      staleness keeps accruing across the hop;
//!    * [`HandoverPolicy::Drop`] — the in-flight work is discarded and
//!      the client respawns fresh in the new cell.
//! 3. **Residence-coupled channels** — each cell serves its residents
//!    from its own [`crate::channel::ChannelConfig`] scope
//!    (`mobility.cell_noise_spread_db` spreads the per-cell noise floors
//!    around the configured N₀), and the Gilbert–Elliott latency state
//!    rides along on admit, so roaming actually changes the physical
//!    layer a client sees.
//!
//! [`trace`] replays a config's mobility model without any training —
//! churn (moves per slot, per-cell membership) is a pure function of the
//! config, which is what the `repro ablation mobility` campaign records
//! next to the learning curves, and [`MobilityStats`] reports what the
//! runner actually applied (deliver defers, so applied churn can lag
//! intended churn).

use anyhow::{ensure, Result};

use crate::config::Config;
use crate::util::Rng;

use super::topology::GroupMap;

/// Per-client trajectory stream tag (disjoint from the coordinator's
/// run-time streams and the partitioner's profile streams).
pub mod streams {
    /// Mobility-model trajectory draws.
    pub const MOBILITY: u64 = 0x30_b117;
}

/// Config-selectable mobility model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MobilityKind {
    /// Nobody moves — the frozen PR-3 assignment (bitwise degeneracy).
    Static,
    /// Per-client cell-transition chain with exponential dwell times.
    Markov,
    /// Random-waypoint motion over a cell grid; nearest cell serves.
    Waypoint,
}

impl MobilityKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.trim().to_ascii_lowercase().as_str() {
            "static" | "none" | "off" => MobilityKind::Static,
            "markov" => MobilityKind::Markov,
            "waypoint" | "rwp" => MobilityKind::Waypoint,
            other => anyhow::bail!("unknown mobility model {other:?} (static|markov|waypoint)"),
        })
    }

    /// Canonical name; `parse(name())` round-trips.
    pub fn name(&self) -> &'static str {
        match self {
            MobilityKind::Static => "static",
            MobilityKind::Markov => "markov",
            MobilityKind::Waypoint => "waypoint",
        }
    }
}

/// What happens to a roaming client's in-flight work at handover.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HandoverPolicy {
    /// The stale update still lands OTA in the old cell; the client moves
    /// only after it is served (membership flip deferred).
    Deliver,
    /// The in-flight state is carried to the new cell with staleness
    /// accrued across the hop.
    Forward,
    /// The in-flight work is discarded; the client respawns fresh in the
    /// new cell.
    Drop,
}

impl HandoverPolicy {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.trim().to_ascii_lowercase().as_str() {
            "deliver" => HandoverPolicy::Deliver,
            "forward" | "carry" => HandoverPolicy::Forward,
            "drop" | "discard" => HandoverPolicy::Drop,
            other => anyhow::bail!("unknown handover policy {other:?} (deliver|forward|drop)"),
        })
    }

    /// Canonical name; `parse(name())` round-trips.
    pub fn name(&self) -> &'static str {
        match self {
            HandoverPolicy::Deliver => "deliver",
            HandoverPolicy::Forward => "forward",
            HandoverPolicy::Drop => "drop",
        }
    }
}

/// A time-varying client → cell assignment. Implementations advance
/// per-client state slot by slot; the runner calls [`advance_to`] with
/// non-decreasing slot indices (slot 0 is the construction state — the
/// initial [`GroupMap`] assignment, so every model starts exactly where
/// the static partition put the fleet).
///
/// [`advance_to`]: MobilityModel::advance_to
pub trait MobilityModel: Send {
    /// Display name (telemetry/debug).
    fn name(&self) -> &str;

    /// Advance the trajectories to the boundary of `slot` — the
    /// assignment in force for slots `slot..`. Must be called with
    /// non-decreasing `slot`; intermediate slots are stepped internally,
    /// so the trajectory is independent of the observation cadence.
    fn advance_to(&mut self, slot: usize);

    /// The current client → cell assignment.
    fn assignment(&self) -> &[usize];
}

/// Derive client `c`'s private trajectory RNG from the master seed.
/// Stateless derivation ([`Rng::for_entity`]): client `c`'s stream is a
/// pure function of `(seed, c)`, which is what lets the models below
/// materialize chains lazily — a chain built on first touch is bitwise
/// the chain an eager constructor would have built.
fn client_rng(seed: u64, client: usize) -> Rng {
    Rng::for_entity(seed, streams::MOBILITY, client as u64)
}

/// The degenerate model: the initial assignment, forever.
pub struct StaticMobility {
    assignment: Vec<usize>,
}

impl StaticMobility {
    pub fn new(initial: &GroupMap) -> Self {
        Self {
            assignment: (0..initial.num_clients()).map(|c| initial.group_of(c)).collect(),
        }
    }
}

impl MobilityModel for StaticMobility {
    fn name(&self) -> &str {
        "static"
    }

    fn advance_to(&mut self, _slot: usize) {}

    fn assignment(&self) -> &[usize] {
        &self.assignment
    }
}

/// Per-client cell-transition chain: each client dwells in its cell for
/// `ceil(Exp(mean = dwell_mean))` slots, then jumps to a uniformly random
/// *other* cell and redraws its dwell. Each client owns its RNG stream,
/// so trajectories are seed-deterministic per client.
pub struct MarkovMobility {
    cells: usize,
    dwell_mean: f64,
    seed: u64,
    assignment: Vec<usize>,
    /// Lazily materialized per-client chains (see [`client_rng`]):
    /// construction is chain-free; the first advance grows them in
    /// client order, drawing bitwise what the seed's eager constructor
    /// drew.
    dwell_left: Vec<usize>,
    rngs: Vec<Rng>,
    slot: usize,
}

impl MarkovMobility {
    pub fn new(initial: &GroupMap, cells: usize, dwell_mean: f64, seed: u64) -> Self {
        let k = initial.num_clients();
        Self {
            cells,
            dwell_mean,
            seed,
            assignment: (0..k).map(|c| initial.group_of(c)).collect(),
            dwell_left: Vec::new(),
            rngs: Vec::new(),
            slot: 0,
        }
    }

    fn draw_dwell(rng: &mut Rng, mean: f64) -> usize {
        (rng.exponential(1.0 / mean).ceil() as usize).max(1)
    }

    /// Grow the per-client chains to the fleet size. Each client's
    /// stream is private, so creating chain `c` and drawing its first
    /// dwell on touch yields exactly the values eager construction
    /// would have.
    fn ensure_chains(&mut self) {
        while self.rngs.len() < self.assignment.len() {
            let mut r = client_rng(self.seed, self.rngs.len());
            self.dwell_left.push(Self::draw_dwell(&mut r, self.dwell_mean));
            self.rngs.push(r);
        }
    }
}

impl MobilityModel for MarkovMobility {
    fn name(&self) -> &str {
        "markov"
    }

    fn advance_to(&mut self, slot: usize) {
        if self.slot >= slot {
            return;
        }
        self.ensure_chains();
        while self.slot < slot {
            self.slot += 1;
            for c in 0..self.assignment.len() {
                self.dwell_left[c] -= 1;
                if self.dwell_left[c] == 0 {
                    // Uniform over the other cells.
                    let draw = self.rngs[c].index(self.cells - 1);
                    let cur = self.assignment[c];
                    self.assignment[c] = if draw >= cur { draw + 1 } else { draw };
                    self.dwell_left[c] = Self::draw_dwell(&mut self.rngs[c], self.dwell_mean);
                }
            }
        }
    }

    fn assignment(&self) -> &[usize] {
        &self.assignment
    }
}

/// Random-waypoint motion on the unit square. Cells sit on a
/// `ceil(√n) × ⌈n/cols⌉` grid; each client starts at its initial cell's
/// center, walks toward a uniformly drawn waypoint at a speed of one
/// grid spacing per `dwell_mean` slots, and draws a new waypoint on
/// arrival. The serving cell is the **nearest** cell center (ties break
/// to the lowest index), so churn emerges from geometry.
pub struct WaypointMobility {
    centers: Vec<(f64, f64)>,
    /// Lazily materialized motion state (see [`client_rng`]): empty
    /// until the first advance, then grown in client order from each
    /// client's private stream — bitwise what eager construction drew.
    pos: Vec<(f64, f64)>,
    target: Vec<(f64, f64)>,
    speed: f64,
    seed: u64,
    assignment: Vec<usize>,
    rngs: Vec<Rng>,
    slot: usize,
}

impl WaypointMobility {
    pub fn new(initial: &GroupMap, cells: usize, dwell_mean: f64, seed: u64) -> Self {
        let centers = Self::grid_centers(cells);
        let k = initial.num_clients();
        let (cols, _) = Self::grid_dims(cells);
        Self {
            centers,
            pos: Vec::new(),
            target: Vec::new(),
            speed: (1.0 / cols as f64) / dwell_mean,
            seed,
            assignment: (0..k).map(|c| initial.group_of(c)).collect(),
            rngs: Vec::new(),
            slot: 0,
        }
    }

    /// Grow the per-client motion state to the fleet size. Only called
    /// before the first assignment mutation, so `assignment[c]` is still
    /// the initial cell — the anchor eager construction used for
    /// `pos[c]`.
    fn ensure_chains(&mut self) {
        while self.rngs.len() < self.assignment.len() {
            let c = self.rngs.len();
            let mut r = client_rng(self.seed, c);
            self.pos.push(self.centers[self.assignment[c]]);
            self.target.push((r.f64(), r.f64()));
            self.rngs.push(r);
        }
    }

    /// Near-square grid shape: `(cols, rows)` — the single definition
    /// both the geometry ([`WaypointMobility::grid_centers`]) and the
    /// speed scale (one grid spacing per `dwell_mean` slots) derive from.
    fn grid_dims(cells: usize) -> (usize, usize) {
        let cols = ((cells as f64).sqrt().ceil() as usize).max(1);
        (cols, cells.div_ceil(cols))
    }

    /// Cell centers on a near-square grid over the unit square.
    fn grid_centers(cells: usize) -> Vec<(f64, f64)> {
        let (cols, rows) = Self::grid_dims(cells);
        (0..cells)
            .map(|j| {
                let (col, row) = (j % cols, j / cols);
                (
                    (col as f64 + 0.5) / cols as f64,
                    (row as f64 + 0.5) / rows as f64,
                )
            })
            .collect()
    }

    fn nearest_cell(centers: &[(f64, f64)], p: (f64, f64)) -> usize {
        let mut best = 0usize;
        let mut best_d2 = f64::INFINITY;
        for (j, &(cx, cy)) in centers.iter().enumerate() {
            let (dx, dy) = (p.0 - cx, p.1 - cy);
            let d2 = dx * dx + dy * dy;
            if d2 < best_d2 {
                best_d2 = d2;
                best = j;
            }
        }
        best
    }
}

impl MobilityModel for WaypointMobility {
    fn name(&self) -> &str {
        "waypoint"
    }

    fn advance_to(&mut self, slot: usize) {
        if self.slot >= slot {
            return;
        }
        self.ensure_chains();
        while self.slot < slot {
            self.slot += 1;
            for c in 0..self.pos.len() {
                let (px, py) = self.pos[c];
                let (tx, ty) = self.target[c];
                let (dx, dy) = (tx - px, ty - py);
                let dist = (dx * dx + dy * dy).sqrt();
                if dist <= self.speed {
                    // Arrive this slot; a new waypoint next slot.
                    self.pos[c] = (tx, ty);
                    self.target[c] = (self.rngs[c].f64(), self.rngs[c].f64());
                } else {
                    let step = self.speed / dist;
                    self.pos[c] = (px + dx * step, py + dy * step);
                }
                self.assignment[c] = Self::nearest_cell(&self.centers, self.pos[c]);
            }
        }
    }

    fn assignment(&self) -> &[usize] {
        &self.assignment
    }
}

/// Instantiate the mobility model the config selects, anchored at the
/// initial cell partition (slot 0 ≡ `initial`).
pub fn build_model(cfg: &Config, initial: &GroupMap) -> Result<Box<dyn MobilityModel>> {
    let cells = cfg.topology.cells;
    ensure!(
        initial.num_groups() == cells,
        "mobility model expects the cell partition ({} groups != {} cells)",
        initial.num_groups(),
        cells
    );
    // Same rule as Config::validate — enforced here too so replay paths
    // that skip validation (mobility::trace on raw configs) error cleanly
    // instead of panicking in a cells-1 transition draw.
    ensure!(
        cfg.mobility.kind == MobilityKind::Static || cells >= 2,
        "mobility = {} needs a multi-cell topology (cells ≥ 2) to roam over",
        cfg.mobility.kind.name()
    );
    let dwell = cfg.mobility.dwell_mean;
    let model: Box<dyn MobilityModel> = match cfg.mobility.kind {
        MobilityKind::Static => Box::new(StaticMobility::new(initial)),
        MobilityKind::Markov => Box::new(MarkovMobility::new(initial, cells, dwell, cfg.seed)),
        MobilityKind::Waypoint => Box::new(WaypointMobility::new(initial, cells, dwell, cfg.seed)),
    };
    Ok(model)
}

/// Advance `model` per the handover cadence after slot `round` closed:
/// on-cadence boundaries advance the trajectories to slot `round + 1`
/// and return the target assignment now in force; off-cadence slots
/// return `None` without touching the model. The single definition of
/// "when does the runner look at the model", shared by the live
/// [`crate::fl::topology::multi_cell`] sweep and the training-free
/// [`trace`] replay — which is what keeps the churn sidecar's intent
/// equal to applied churn for the immediate handover policies
/// (`tests/mobility.rs`).
pub fn advanced_target<'m>(
    cfg: &Config,
    model: &'m mut dyn MobilityModel,
    round: usize,
) -> Option<&'m [usize]> {
    if (round + 1) % cfg.mobility.handover_every != 0 {
        return None;
    }
    model.advance_to(round + 1);
    Some(model.assignment())
}

/// What the runner actually applied: handover churn as it landed on the
/// coordinators (the `deliver` policy defers flips until the stale
/// upload is served, so applied churn can lag the model's intent in
/// [`trace`]).
#[derive(Debug, Clone, Default)]
pub struct MobilityStats {
    /// Applied membership flips (all policies).
    pub handovers: usize,
    /// `deliver`-policy moves completed after their upload landed.
    pub delivered: usize,
    /// Per-cell counts of clients that roamed **in**.
    pub arrivals: Vec<usize>,
    /// Per-cell counts of clients that roamed **out**.
    pub departures: Vec<usize>,
    /// Applied membership flips per round (len = rounds).
    pub per_round_moves: Vec<usize>,
    /// Per-round per-cell member counts after that round's sweep
    /// (`per_round_members[r][cell]`; every row sums to K — the
    /// conservation property `tests/mobility.rs` asserts).
    pub per_round_members: Vec<Vec<usize>>,
    /// Handover count per client.
    pub per_client: Vec<usize>,
}

impl MobilityStats {
    pub fn new(cells: usize, clients: usize) -> Self {
        Self {
            arrivals: vec![0; cells],
            departures: vec![0; cells],
            per_client: vec![0; clients],
            ..Self::default()
        }
    }

    /// Record one applied membership flip.
    pub fn record_move(&mut self, client: usize, from: usize, to: usize) {
        self.handovers += 1;
        self.departures[from] += 1;
        self.arrivals[to] += 1;
        self.per_client[client] += 1;
        if let Some(last) = self.per_round_moves.last_mut() {
            *last += 1;
        }
        // Scrape-visible mirror of the applied-churn tally (global
        // registry; tests assert deltas, never absolutes).
        crate::obs::metrics::global()
            .counter("paota_handovers_total")
            .inc();
    }
}

/// Model-level churn of a config, replayed without any training: which
/// clients the model *wants* where, per slot. Pure function of the
/// config (models are seed-deterministic), so this is reproducible
/// independent of `--jobs`/workers — the `repro ablation mobility`
/// churn CSV is written from it.
#[derive(Debug, Clone)]
pub struct MobilityTrace {
    /// Intended moves at each observed boundary (len = rounds; zero on
    /// off-cadence slots).
    pub per_round_moves: Vec<usize>,
    /// `per_round_members[r][cell]`: intended member count after the
    /// boundary of slot r+1.
    pub per_round_members: Vec<Vec<usize>>,
    /// Total intended moves over the horizon.
    pub total_moves: usize,
    /// Intended moves per client.
    pub per_client_moves: Vec<usize>,
}

/// Replay the config's mobility model over its round horizon (no
/// training, no coordinators — model-level intent only).
pub fn trace(cfg: &Config) -> Result<MobilityTrace> {
    let k = cfg.partition.clients;
    let cells = cfg.topology.cells;
    let map = GroupMap::build(k, cells, cfg.topology.partitioner, cfg.seed)?;
    let mut model = build_model(cfg, &map)?;
    let mut assignment: Vec<usize> = model.assignment().to_vec();
    let mut out = MobilityTrace {
        per_round_moves: Vec::with_capacity(cfg.rounds),
        per_round_members: Vec::with_capacity(cfg.rounds),
        total_moves: 0,
        per_client_moves: vec![0; k],
    };
    for round in 0..cfg.rounds {
        let mut moves = 0usize;
        if let Some(target) = advanced_target(cfg, model.as_mut(), round) {
            for c in 0..k {
                if target[c] != assignment[c] {
                    moves += 1;
                    out.per_client_moves[c] += 1;
                    assignment[c] = target[c];
                }
            }
        }
        let mut members = vec![0usize; cells];
        for &cell in &assignment {
            members[cell] += 1;
        }
        out.per_round_moves.push(moves);
        out.per_round_members.push(members);
        out.total_moves += moves;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::topology::PartitionerKind;

    fn map(clients: usize, cells: usize, seed: u64) -> GroupMap {
        GroupMap::build(clients, cells, PartitionerKind::RoundRobin, seed).unwrap()
    }

    fn conserved(assignment: &[usize], cells: usize) {
        assert!(assignment.iter().all(|&a| a < cells), "{assignment:?}");
    }

    #[test]
    fn kind_and_policy_roundtrip() {
        for kind in [MobilityKind::Static, MobilityKind::Markov, MobilityKind::Waypoint] {
            assert_eq!(MobilityKind::parse(kind.name()).unwrap(), kind);
        }
        for pol in [HandoverPolicy::Deliver, HandoverPolicy::Forward, HandoverPolicy::Drop] {
            assert_eq!(HandoverPolicy::parse(pol.name()).unwrap(), pol);
        }
        assert_eq!(MobilityKind::parse("rwp").unwrap(), MobilityKind::Waypoint);
        assert_eq!(HandoverPolicy::parse("carry").unwrap(), HandoverPolicy::Forward);
        assert!(MobilityKind::parse("teleport").is_err());
        assert!(HandoverPolicy::parse("nope").is_err());
    }

    #[test]
    fn every_model_starts_at_the_initial_partition() {
        let m = map(17, 3, 5);
        let want: Vec<usize> = (0..17).map(|c| m.group_of(c)).collect();
        assert_eq!(StaticMobility::new(&m).assignment(), &want[..]);
        assert_eq!(MarkovMobility::new(&m, 3, 2.0, 5).assignment(), &want[..]);
        assert_eq!(WaypointMobility::new(&m, 3, 2.0, 5).assignment(), &want[..]);
    }

    #[test]
    fn static_never_moves() {
        let m = map(10, 2, 1);
        let mut model = StaticMobility::new(&m);
        let initial = model.assignment().to_vec();
        model.advance_to(50);
        assert_eq!(model.assignment(), &initial[..]);
    }

    #[test]
    fn markov_moves_and_is_seed_deterministic() {
        let m = map(20, 3, 7);
        let mut a = MarkovMobility::new(&m, 3, 2.0, 7);
        let mut b = MarkovMobility::new(&m, 3, 2.0, 7);
        a.advance_to(12);
        b.advance_to(12);
        assert_eq!(a.assignment(), b.assignment());
        conserved(a.assignment(), 3);
        let initial: Vec<usize> = (0..20).map(|c| m.group_of(c)).collect();
        assert_ne!(a.assignment(), &initial[..], "nobody moved in 12 slots at dwell 2");
        // A different seed takes different trajectories.
        let mut c = MarkovMobility::new(&m, 3, 2.0, 8);
        c.advance_to(12);
        assert_ne!(a.assignment(), c.assignment());
    }

    #[test]
    fn trajectories_are_independent_of_observation_cadence() {
        // Observing every slot vs jumping straight to slot 12 must land on
        // the same assignment — handover_every only changes when moves are
        // APPLIED, never where clients go.
        let m = map(16, 4, 3);
        let builders: [fn(&GroupMap) -> Box<dyn MobilityModel>; 2] = [
            |m| Box::new(MarkovMobility::new(m, 4, 1.5, 3)),
            |m| Box::new(WaypointMobility::new(m, 4, 1.5, 3)),
        ];
        for build in builders {
            let mut fine = build(&m);
            for s in 1..=12 {
                fine.advance_to(s);
            }
            let mut coarse = build(&m);
            coarse.advance_to(12);
            assert_eq!(fine.assignment(), coarse.assignment(), "{}", fine.name());
        }
    }

    #[test]
    fn chains_materialize_lazily_on_first_advance() {
        // Construction is chain-free regardless of fleet size; the first
        // effective advance grows every chain, and a model advanced to a
        // slot it is already at stays chain-free.
        let m = map(1000, 3, 7);
        let mut markov = MarkovMobility::new(&m, 3, 2.0, 7);
        assert!(markov.rngs.is_empty() && markov.dwell_left.is_empty());
        markov.advance_to(0);
        assert!(markov.rngs.is_empty(), "advance_to(current) materialized chains");
        markov.advance_to(1);
        assert_eq!(markov.rngs.len(), 1000);
        assert_eq!(markov.dwell_left.len(), 1000);

        let mut wp = WaypointMobility::new(&m, 3, 2.0, 7);
        assert!(wp.rngs.is_empty() && wp.pos.is_empty() && wp.target.is_empty());
        wp.advance_to(1);
        assert_eq!(wp.rngs.len(), 1000);
        assert_eq!(wp.pos.len(), 1000);
    }

    #[test]
    fn waypoint_moves_by_geometry_and_conserves() {
        let m = map(24, 4, 11);
        let mut model = WaypointMobility::new(&m, 4, 1.0, 11);
        let initial = model.assignment().to_vec();
        model.advance_to(20);
        conserved(model.assignment(), 4);
        assert_ne!(model.assignment(), &initial[..], "fast waypoints never crossed a cell edge");
    }

    #[test]
    fn waypoint_grid_covers_all_cells_distinctly() {
        for cells in 1..=9 {
            let centers = WaypointMobility::grid_centers(cells);
            assert_eq!(centers.len(), cells);
            for (i, a) in centers.iter().enumerate() {
                assert!(a.0 > 0.0 && a.0 < 1.0 && a.1 > 0.0 && a.1 < 1.0);
                for b in &centers[i + 1..] {
                    assert_ne!(a, b, "cells={cells}");
                }
            }
            // Nearest-cell of a center is that cell.
            for (j, &c) in centers.iter().enumerate() {
                assert_eq!(WaypointMobility::nearest_cell(&centers, c), j);
            }
        }
    }

    #[test]
    fn trace_is_deterministic_and_static_is_churn_free() {
        let mut cfg = Config::default();
        cfg.partition.clients = 12;
        cfg.topology.cells = 3;
        cfg.rounds = 8;

        let quiet = trace(&cfg).unwrap();
        assert_eq!(quiet.total_moves, 0);
        assert!(quiet.per_round_moves.iter().all(|&m| m == 0));
        for members in &quiet.per_round_members {
            assert_eq!(members.iter().sum::<usize>(), 12);
        }

        cfg.mobility.kind = MobilityKind::Markov;
        cfg.mobility.dwell_mean = 1.5;
        let a = trace(&cfg).unwrap();
        let b = trace(&cfg).unwrap();
        assert_eq!(a.per_round_moves, b.per_round_moves);
        assert_eq!(a.per_round_members, b.per_round_members);
        assert!(a.total_moves > 0, "markov trace produced no churn");
        for members in &a.per_round_members {
            assert_eq!(members.iter().sum::<usize>(), 12, "client lost or duplicated");
        }
        assert_eq!(a.per_client_moves.iter().sum::<usize>(), a.total_moves);
    }

    #[test]
    fn roaming_over_one_cell_is_a_clean_error() {
        // Replay paths (trace) run on raw configs that never saw
        // Config::validate — the model builder must reject roaming over
        // a single cell instead of panicking in the transition draw.
        let mut cfg = Config::default();
        cfg.partition.clients = 4;
        cfg.topology.cells = 1;
        cfg.mobility.kind = MobilityKind::Markov;
        let err = trace(&cfg).unwrap_err().to_string();
        assert!(err.contains("multi-cell"), "{err}");
        cfg.mobility.kind = MobilityKind::Static;
        trace(&cfg).unwrap();
    }

    #[test]
    fn stats_accumulate_moves() {
        let mut s = MobilityStats::new(3, 5);
        s.per_round_moves.push(0);
        s.record_move(2, 0, 1);
        s.record_move(2, 1, 2);
        assert_eq!(s.handovers, 2);
        assert_eq!(s.per_client[2], 2);
        assert_eq!(s.departures, vec![1, 1, 0]);
        assert_eq!(s.arrivals, vec![0, 1, 1]);
        assert_eq!(s.per_round_moves, vec![2]);
    }
}
