//! The discrete-event **coordinator core** every FL algorithm runs on.
//!
//! The paper's contribution is a *coordination mechanism*; this module is
//! the one place that mechanism lives. A [`Coordinator`] owns everything
//! every scheme needs —
//!
//! * the [`VirtualClock`](crate::sim::VirtualClock) and the
//!   [`EventQueue`](crate::sim::events::EventQueue) of client-finished
//!   arrivals (the single scheduling driver),
//! * struct-of-arrays client scheduler state (base round, refcounted
//!   base-model snapshot, finish time — [`ClientSlot`] is the by-value
//!   handover view), with `[fleet]`-sampled cohort participation so
//!   memory and per-round cost scale with the active cohort,
//! * deterministic per-purpose RNG streams ([`RngStreams`]),
//! * the reusable `stack`/`coef`/noise buffers of the AirComp kernel,
//! * a [`Telemetry`] recorder that buckets uploads into ΔT windows and
//!   emits the canonical [`RoundRecord`] stream with a single eval/probe
//!   cadence,
//!
//! — while the algorithm itself shrinks to an [`AggregationPolicy`]: *who*
//! uploads ([`AggregationPolicy::select_participants`]), *what the server
//! does with the uploads* ([`AggregationPolicy::on_uploads`] →
//! [`RoundAction`]), and *when aggregation happens*
//! ([`AggregationPolicy::timing`] → [`RoundTiming`]).
//!
//! Local training is always fanned out through
//! [`TrainContext::train_many`], so every policy — including the
//! continuous-time FedAsync extension, whose simultaneous arrivals are
//! coalesced into one batch — shares the parallel PJRT pool.
//!
//! Adding a scheme means writing a policy struct, not a new round loop —
//! grouped AirComp ([`crate::fl::topology::air_fedga`], via
//! [`RoundAction::GroupAggregate`]) and channel-aware scheduling
//! (`ca_paota`) both landed that way. Multi-cell hierarchies drive
//! several coordinators step-wise ([`Coordinator::begin_periodic`] /
//! [`Coordinator::step_periodic`]) and mix their models between slots
//! ([`crate::fl::topology::multi_cell`]).

use std::sync::Arc;

use anyhow::{bail, ensure, Result};

use crate::config::{Algorithm, Config};
use crate::obs::metrics::{self, Counter, Histogram};
use crate::obs::trace::{TraceSink, V};
use crate::runtime::{EvalOut, TrainOut};
use crate::sim::events::EventQueue;
use crate::sim::{LatencySampler, VirtualClock};
use crate::util::{vecmath, Rng};

use super::{RoundRecord, RunResult, TrainContext};

/// Stream tags — one independent PCG stream per stochastic purpose, all
/// derived from the config's master seed. Fixed tags keep runs
/// bit-reproducible and make trajectories comparable across refactors.
pub mod streams {
    /// Client compute-latency draws.
    pub const LATENCY: u64 = 0x1a7;
    /// Local-training minibatch sampling (federated shards).
    pub const BATCH: u64 = 0xba7c;
    /// Pooled-data minibatch sampling (the centralized policy).
    pub const POOLED_BATCH: u64 = 0xce27;
    /// Synchronous cohort selection.
    pub const PICK: u64 = 0x91c4;
    /// Fading gains + receiver noise.
    pub const CHANNEL: u64 = 0xc4a2;
    /// Power-control solver randomness.
    pub const OPT: u64 = 0x0b7;
    /// Cohort membership sampling for partial-fleet simulation
    /// (`[fleet]` config keys). Only consumed when the cohort is
    /// strictly smaller than the fleet, so full-fleet runs are
    /// bit-unchanged.
    pub const FLEET: u64 = 0xf1ee7;
}

/// The coordinator's deterministic per-purpose RNG streams.
pub struct RngStreams {
    pub latency: Rng,
    pub batch: Rng,
    pub pick: Rng,
    pub channel: Rng,
    pub opt: Rng,
}

impl RngStreams {
    /// Derive all streams from the master seed. `batch_stream` is the
    /// policy's choice of minibatch stream (see
    /// [`AggregationPolicy::batch_stream`]).
    pub fn new(seed: u64, batch_stream: u64) -> Self {
        Self {
            latency: Rng::with_stream(seed, streams::LATENCY),
            batch: Rng::with_stream(seed, batch_stream),
            pick: Rng::with_stream(seed, streams::PICK),
            channel: Rng::with_stream(seed, streams::CHANNEL),
            opt: Rng::with_stream(seed, streams::OPT),
        }
    }
}

/// When the coordinator aggregates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoundTiming {
    /// Time-triggered ΔT slots (PAOTA): round r closes at `(r+1)·ΔT`
    /// with whatever finished inside the slot; the PS never waits.
    Periodic,
    /// Synchronous cohorts: each round lasts as long as its slowest
    /// participant's compute latency (Local SGD, COTAF).
    Synchronous,
    /// Aggregate on every client arrival; telemetry is bucketed into ΔT
    /// windows so the record stream stays comparable (FedAsync).
    Continuous,
    /// One pooled-data node, no client fleet; rounds advance by the mean
    /// latency (the `F(w*)` estimator).
    SingleNode,
}

/// One finished local-training job, handed to the policy.
#[derive(Debug, Clone)]
pub struct Upload {
    /// Client index k.
    pub client: usize,
    /// Rounds (or ΔT windows) since this client took its base model.
    pub staleness: usize,
    /// Mean local training loss over the M steps.
    pub loss: f32,
    /// The trained model w_k.
    pub weights: Vec<f32>,
    /// `w_k − base` — filled only when the policy asked via
    /// [`AggregationPolicy::needs_deltas`], else empty.
    pub delta: Vec<f32>,
}

/// An opened periodic slot ([`Coordinator::open_periodic_slot`]): the
/// chosen uploaders and their ready-to-run training jobs, awaiting
/// trained submissions via [`Coordinator::complete_periodic_slot`].
pub struct OpenSlot {
    /// The slot's round index.
    pub round: usize,
    /// Chosen client ids in **dispatch order** — the order submissions
    /// must be reassembled into before completing the slot.
    pub chosen: Vec<usize>,
    /// One `(w0, xs, ys)` training job per chosen client, same order.
    pub jobs: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)>,
}

/// One group's AirComp pass inside a [`RoundAction::GroupAggregate`]:
/// which uploads transmit together, with what coefficients and receiver
/// noise, and how strongly the resulting group aggregate is merged into
/// the global model.
#[derive(Debug, Clone)]
pub struct GroupPass {
    /// Indices into this round's `uploads` slice. Across all passes every
    /// upload must appear exactly once (disjoint cover — enforced).
    pub members: Vec<usize>,
    /// AirComp coefficient per member (pairs with `members`).
    pub coefs: Vec<f32>,
    /// Pre-normalization receiver AWGN for this pass' own OTA
    /// transmission (empty = lossless uplink).
    pub noise: Vec<f32>,
    /// Server-side merge weight μ_g of this group's aggregate; the merge
    /// is `w ← (1 − Σ_g μ_g)·w + Σ_g μ_g·y_g`, so Σ μ_g must be ≤ 1.
    pub mix: f64,
    /// Mean transmit power of this pass (telemetry).
    pub mean_power: f64,
}

/// What the policy tells the coordinator to do with a round's uploads.
#[derive(Debug, Clone)]
pub enum RoundAction {
    /// Weighted aggregation through the L1 AirComp kernel:
    /// `w ← (Σ_j coefs[j]·row_j + noise)/Σ_j coefs[j]`. `coefs[j]` pairs
    /// with `uploads[j]`; an empty `noise` means a lossless uplink. With
    /// `deltas`, the stacked rows are the uploads' update vectors and the
    /// kernel's weighted mean is *added* to the global model (COTAF);
    /// otherwise the rows are full models and the mean *replaces* it.
    Aggregate {
        coefs: Vec<f32>,
        noise: Vec<f32>,
        deltas: bool,
        mean_power: f64,
    },
    /// Per-upload sequential mixing `w_g ← (1−γ_j)·w_g + γ_j·w_j`, each
    /// uploader restarting from the freshly mixed model (FedAsync).
    Mix { gammas: Vec<f64> },
    /// Adopt the single upload's weights as the new global model.
    Adopt,
    /// Hierarchical grouped AirComp (Air-FedGA): one `stack`/`coef`
    /// kernel pass per group — each group transmits over the air on its
    /// own — then an asynchronous server-side merge of the group
    /// aggregates, `w ← (1 − Σ_g μ_g)·w + Σ_g μ_g·y_g`.
    GroupAggregate { passes: Vec<GroupPass> },
    /// Leave the global model untouched this round.
    Skip { mean_power: f64 },
}

/// Accumulated upload statistics for one telemetry round/window.
#[derive(Debug, Clone, Copy, Default)]
pub struct WindowStats {
    pub uploads: usize,
    pub loss_sum: f64,
    pub staleness_sum: f64,
    pub mean_power: f64,
}

impl WindowStats {
    /// Fold one upload into the window.
    pub fn absorb(&mut self, up: &Upload) {
        self.uploads += 1;
        self.loss_sum += up.loss as f64;
        self.staleness_sum += up.staleness as f64;
    }

    /// Mean participant training loss (NaN for an empty window).
    pub fn train_loss(&self) -> f32 {
        if self.uploads > 0 {
            (self.loss_sum / self.uploads as f64) as f32
        } else {
            f32::NAN
        }
    }

    /// Mean upload staleness (0 for an empty window).
    pub fn mean_staleness(&self) -> f64 {
        if self.uploads > 0 {
            self.staleness_sum / self.uploads as f64
        } else {
            0.0
        }
    }
}

/// The canonical [`RoundRecord`] emitter: one eval/probe cadence and one
/// window-bookkeeping rule for every algorithm.
#[derive(Debug)]
pub struct Telemetry {
    rounds: usize,
    eval_every: usize,
    records: Vec<RoundRecord>,
}

impl Telemetry {
    pub fn new(rounds: usize, eval_every: usize) -> Self {
        assert!(eval_every > 0, "eval_every must be ≥ 1");
        Self {
            rounds,
            eval_every,
            records: Vec::with_capacity(rounds),
        }
    }

    /// The shared eval/probe cadence: every `eval_every` rounds plus the
    /// final round, so every run ends with a measurement.
    pub fn should_eval(&self, round: usize) -> bool {
        round % self.eval_every == 0 || round + 1 == self.rounds
    }

    /// Index of the next round/window to be recorded.
    pub fn window(&self) -> usize {
        self.records.len()
    }

    /// True once all `rounds` records are in.
    pub fn is_complete(&self) -> bool {
        self.records.len() >= self.rounds
    }

    /// The records emitted so far (multi-cell runners merge these
    /// mid-run).
    pub fn records(&self) -> &[RoundRecord] {
        &self.records
    }

    /// Append one round's record. Windows must be contiguous and monotone
    /// in `sim_time` — the invariants every consumer of the stream relies
    /// on.
    pub fn record(
        &mut self,
        round: usize,
        sim_time: f64,
        stats: WindowStats,
        eval: Option<EvalOut>,
        probe_loss: Option<f32>,
    ) -> &RoundRecord {
        assert_eq!(round, self.records.len(), "telemetry window out of order");
        if let Some(prev) = self.records.last() {
            assert!(
                sim_time >= prev.sim_time,
                "telemetry time went backwards: {sim_time} after {}",
                prev.sim_time
            );
        }
        self.records.push(RoundRecord {
            round,
            sim_time,
            train_loss: stats.train_loss(),
            probe_loss,
            eval,
            participants: stats.uploads,
            mean_staleness: stats.mean_staleness(),
            mean_power: stats.mean_power,
        });
        self.records.last().expect("just pushed")
    }

    pub fn into_records(self) -> Vec<RoundRecord> {
        self.records
    }
}

/// Per-client scheduler state (what the client trains from, and when its
/// current local run finishes). `base_weights` is a shared snapshot out
/// of the coordinator's round-model store: every client restarting from
/// the same global model holds the same `Arc`, so resident base-model
/// memory is (distinct base rounds)·dim, not K·dim.
#[derive(Debug, Clone)]
pub struct ClientSlot {
    /// Global round (or ΔT window) whose model this client trains from.
    pub base_round: usize,
    /// The base weights it received (refcounted, shared per base round).
    pub base_weights: Arc<[f32]>,
    /// Virtual time its current local training finishes.
    pub finish_time: f64,
}

/// Struct-of-arrays client scheduler state: three dense columns instead
/// of a `Vec<ClientSlot>`, so fleet-wide scans touch only the column
/// they need and the base models stay shared `Arc`s.
#[derive(Debug, Default)]
struct ClientStates {
    base_round: Vec<usize>,
    base: Vec<Arc<[f32]>>,
    finish: Vec<f64>,
}

impl ClientStates {
    /// All K clients on `base` at round 0, finish times unset.
    fn spawn(k: usize, base: &Arc<[f32]>) -> Self {
        Self {
            base_round: vec![0; k],
            base: vec![base.clone(); k],
            finish: vec![0.0; k],
        }
    }

    fn set(&mut self, client: usize, round: usize, base: Arc<[f32]>, finish: f64) {
        self.base_round[client] = round;
        self.base[client] = base;
        self.finish[client] = finish;
    }

    /// Materialize one client's state as a by-value [`ClientSlot`]
    /// (detach/handover snapshot).
    fn slot(&self, client: usize) -> ClientSlot {
        ClientSlot {
            base_round: self.base_round[client],
            base_weights: self.base[client].clone(),
            finish_time: self.finish[client],
        }
    }

    /// Install a carried slot verbatim (admit after handover).
    fn install(&mut self, client: usize, s: ClientSlot) {
        self.base_round[client] = s.base_round;
        self.base[client] = s.base_weights;
        self.finish[client] = s.finish_time;
    }
}

/// A roaming client's scheduling state, lifted out of one cell's
/// coordinator by [`Coordinator::detach_client`] so a multi-cell runner
/// can hand it to another cell (`fl::mobility`'s `forward` handover
/// policy re-installs it verbatim via [`Coordinator::admit_client`];
/// `drop` discards it and re-spawns fresh with
/// [`Coordinator::admit_fresh`]).
#[derive(Debug, Clone)]
pub struct DetachedClient {
    /// The client's slot (base round/weights and scheduled finish) at
    /// detach time.
    pub slot: ClientSlot,
    /// The client had finished training and sat in the ready-pending set
    /// (its upload had not been served yet).
    pub was_ready: bool,
    /// The client's queued finish event, if it was still training.
    pub queued_finish: Option<f64>,
    /// Gilbert–Elliott residence state of the client's latency chain
    /// (carried across the hop — the chain belongs to the device).
    pub latency_slow: bool,
}

/// An FL algorithm, reduced to its decisions. Everything else — the round
/// loop, the clock, client scheduling, batched training, telemetry — is
/// the [`Coordinator`]'s.
///
/// `Send` is a supertrait: multi-cell runners step one coordinator (and
/// its policy) per worker thread when the backend allows it
/// ([`crate::fl::topology::multi_cell`]). Policies are plain decision
/// state — every built-in is trivially `Send`; a policy that needs
/// thread-bound state should own it per call instead.
pub trait AggregationPolicy: Send {
    /// Canonical registry name of this policy (tags [`RunResult`], debug
    /// logs and CSV filenames; see [`crate::fl::registry`]).
    fn name(&self) -> &str;

    /// When the coordinator aggregates.
    fn timing(&self) -> RoundTiming;

    /// RNG stream minibatch sampling draws from. The centralized policy
    /// overrides this to keep its pooled-data stream independent.
    fn batch_stream(&self) -> u64 {
        streams::BATCH
    }

    /// Ask the coordinator to fill [`Upload::delta`] (`w_k − base`) —
    /// needed by similarity factors (PAOTA) and update-precoding (COTAF).
    fn needs_deltas(&self) -> bool {
        false
    }

    /// Choose this round's uploaders among `offered` — the ready clients
    /// under event-driven timing, the whole fleet under synchronous
    /// timing. Ready clients left out stay available next round. The
    /// default takes everyone, in the offered order.
    ///
    /// Contract: every returned value must be a **client id drawn from
    /// `offered`** (not a position into it) — the coordinator trains,
    /// stacks and reschedules by client id.
    fn select_participants(&mut self, offered: &[usize], rngs: &mut RngStreams) -> Vec<usize> {
        let _ = rngs;
        offered.to_vec()
    }

    /// Build one participant's training job `(w0, xs, ys)`. The default
    /// samples M·B rows from the client's own shard and trains from
    /// `base`.
    fn make_job(
        &self,
        client: usize,
        base: &[f32],
        ctx: &TrainContext,
        batch_rng: &mut Rng,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let m = ctx.rt.manifest();
        let (xs, ys) = ctx
            .partition
            .client(client)
            .sample_batches(m.local_steps, m.batch, batch_rng);
        (base.to_vec(), xs, ys)
    }

    /// The aggregation decision: given this round's trained uploads,
    /// return what the server does (weights/powers/noise or mixing
    /// rates). Only called when at least one upload arrived.
    fn on_uploads(
        &mut self,
        round: usize,
        global: &[f32],
        uploads: &[Upload],
        rngs: &mut RngStreams,
    ) -> Result<RoundAction>;

    /// Called after the global model moved by `delta = w_new − w_old`
    /// (PAOTA keeps it as the similarity reference direction).
    fn on_global_delta(&mut self, delta: &[f32]) {
        let _ = delta;
    }

    /// The fleet slice this policy aggregates over changed — called by
    /// hierarchical runners when a cell's membership is (re)established or
    /// churns under handover (`fl::mobility`). `members` is the sorted
    /// list of client ids now attached. Flat policies ignore it; grouped
    /// policies (`air_fedga`) rebuild their [`crate::fl::topology::GroupMap`]
    /// over the slice.
    fn on_membership(&mut self, members: &[usize]) {
        let _ = members;
    }
}

/// Coordinator observability: metric handles on the global registry plus
/// an optional trace journal. Strictly read-only with respect to the
/// simulation — no RNG draw, no clock advance, pure atomics and I/O —
/// so enabling it never perturbs a run (`tests/golden_seed.rs` proves
/// this bitwise).
struct CoordObs {
    rounds: Counter,
    uploads: Counter,
    participants: Histogram,
    staleness: Histogram,
    trace: Option<TraceSink>,
}

impl CoordObs {
    fn new(cfg: &Config) -> Self {
        let r = metrics::global();
        let trace = match TraceSink::from_cfg(&cfg.obs) {
            Ok(t) => t,
            Err(e) => {
                crate::debug!("obs: trace journal disabled: {e:#}");
                None
            }
        };
        Self {
            rounds: r.counter("paota_rounds_total"),
            uploads: r.counter("paota_uploads_total"),
            participants: r.histogram(
                "paota_round_participants",
                &[0.0, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0],
            ),
            staleness: r.histogram(
                "paota_round_mean_staleness",
                &[0.0, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0],
            ),
            trace,
        }
    }
}

/// Drive `policy` over the configured horizon against a prepared context.
pub fn run(
    ctx: &TrainContext,
    cfg: &Config,
    policy: &mut dyn AggregationPolicy,
) -> Result<RunResult> {
    Coordinator::new(ctx, cfg, policy.batch_stream()).run(policy)
}

/// The event-driven simulation core shared by all algorithms.
pub struct Coordinator<'a> {
    ctx: &'a TrainContext,
    cfg: &'a Config,
    latency: LatencySampler,
    clock: VirtualClock,
    /// Client-finished arrivals, keyed by virtual finish time.
    queue: EventQueue<usize>,
    states: ClientStates,
    /// The sampled cohort (sorted client ids). Equals `0..k` unless the
    /// `[fleet]` keys request a strict subset; only members are spawned,
    /// so scheduling and buffer cost scale with the cohort.
    members: Vec<usize>,
    /// Ready clients carried across periodic slots (finished but not yet
    /// scheduled by the policy).
    pending: Vec<usize>,
    rngs: RngStreams,
    telemetry: Telemetry,
    w_g: Vec<f32>,
    /// The round-model store: the shared snapshot of the current `w_g`
    /// handed to every client restarting before the next global-model
    /// mutation. Cleared at every `w_g` write.
    base_cache: Option<Arc<[f32]>>,
    // Reusable flat buffers for the aggregate kernel, grown to the
    // round's participant count (never the fleet size).
    stack: Vec<f32>,
    coef: Vec<f32>,
    zero_noise: Vec<f32>,
    scratch: Vec<f32>,
    obs: CoordObs,
    dim: usize,
    k: usize,
}

impl<'a> Coordinator<'a> {
    pub fn new(ctx: &'a TrainContext, cfg: &'a Config, batch_stream: u64) -> Self {
        let dim = ctx.dim();
        let k = ctx.clients();
        let cohort = cfg.fleet.effective_cohort(k);
        let members: Vec<usize> = if cohort >= k {
            // Full fleet: no sampling draw, so legacy runs are
            // bit-unchanged.
            (0..k).collect()
        } else {
            let mut rng = Rng::with_stream(cfg.seed, streams::FLEET);
            let mut m = rng.choose_indices(k, cohort);
            m.sort_unstable();
            m
        };
        Self {
            ctx,
            cfg,
            latency: LatencySampler::new(cfg.latency(), k),
            clock: VirtualClock::new(),
            queue: EventQueue::new(),
            states: ClientStates::default(),
            members,
            pending: Vec::new(),
            rngs: RngStreams::new(cfg.seed, batch_stream),
            telemetry: Telemetry::new(cfg.rounds, cfg.eval_every),
            w_g: ctx.init_weights(),
            base_cache: None,
            stack: Vec::new(),
            coef: Vec::new(),
            zero_noise: vec![0.0; dim],
            scratch: vec![0.0; dim],
            obs: CoordObs::new(cfg),
            dim,
            k,
        }
    }

    /// The sampled cohort this coordinator schedules (sorted client
    /// ids); the whole fleet unless `[fleet]` requested a subset.
    pub fn cohort(&self) -> &[usize] {
        &self.members
    }

    /// Shared snapshot of the current global model — the round-model
    /// store. Everyone restarting between two `w_g` mutations gets the
    /// same `Arc`.
    fn base_arc(&mut self) -> Arc<[f32]> {
        if let Some(a) = &self.base_cache {
            return a.clone();
        }
        let a: Arc<[f32]> = Arc::from(self.w_g.as_slice());
        self.base_cache = Some(a.clone());
        a
    }

    /// The global model changed — drop the shared snapshot so the next
    /// restart re-materializes it.
    fn touch_global(&mut self) {
        self.base_cache = None;
    }

    /// Run to completion and yield the record stream + final model.
    pub fn run(mut self, policy: &mut dyn AggregationPolicy) -> Result<RunResult> {
        match policy.timing() {
            RoundTiming::Periodic => self.drive_periodic(policy)?,
            RoundTiming::Synchronous => self.drive_synchronous(policy)?,
            RoundTiming::Continuous => self.drive_continuous(policy)?,
            RoundTiming::SingleNode => self.drive_single_node(policy)?,
        }
        Ok(self.into_result(Algorithm::raw(policy.name())))
    }

    /// Consume the coordinator into its run result (used by `run` and by
    /// step-wise drivers like `fl::topology::multi_cell`).
    pub fn into_result(self, algorithm: Algorithm) -> RunResult {
        let Coordinator { telemetry, w_g, .. } = self;
        RunResult {
            algorithm,
            records: telemetry.into_records(),
            final_weights: w_g,
        }
    }

    /// The current global model (step-wise drivers read it to mix cells).
    pub fn global_weights(&self) -> &[f32] {
        &self.w_g
    }

    /// Replace the current global model (inter-cell mixing). Clients
    /// already training keep their recorded base — exactly a real
    /// hierarchical PS, which pushes the mixed model only at the next
    /// dispatch.
    pub fn set_global_weights(&mut self, w: &[f32]) {
        assert_eq!(w.len(), self.dim, "global model dimension mismatch");
        self.w_g.copy_from_slice(w);
        self.touch_global();
    }

    /// The records emitted so far.
    pub fn records(&self) -> &[RoundRecord] {
        self.telemetry.records()
    }

    /// The global round whose model `client` currently trains from —
    /// bumped to `round + 1` whenever its upload is served. Multi-cell
    /// runners watch this to detect a landed upload (`deliver` handover
    /// completes only after the stale update landed in the old cell).
    pub fn client_base_round(&self, client: usize) -> usize {
        self.states.base_round[client]
    }

    /// Detach a roaming client from this cell's scheduling: its queued
    /// finish event and/or ready-pending entry are removed (no other
    /// client's slot, stream or event moves), and its scheduling state is
    /// returned for the handover policy to carry, forward or drop.
    ///
    /// Safe to call for a client this cell never served (the ghost
    /// presence every cell holds from [`Coordinator::spawn_fleet`]): the
    /// returned state then describes that ghost.
    pub fn detach_client(&mut self, client: usize) -> DetachedClient {
        let queued_finish = self.queue.remove_first(&client).map(|(t, _)| t);
        let was_ready = self.pending.iter().any(|&c| c == client);
        self.pending.retain(|&c| c != client);
        DetachedClient {
            slot: self.states.slot(client),
            was_ready,
            queued_finish,
            latency_slow: self.latency.slow_state(client),
        }
    }

    /// Detach a roaming client whose in-flight work is being *discarded*
    /// (`drop` handover, `deliver` completion): purge its queue event and
    /// pending entry and return only the device's latency-chain state —
    /// no base-model clone, unlike [`Coordinator::detach_client`].
    pub fn detach_client_discarding(&mut self, client: usize) -> bool {
        self.purge_client(client);
        self.latency.slow_state(client)
    }

    /// Admit a roaming client carrying its previous cell's state
    /// (`forward` handover): the slot — base round, base weights, finish
    /// time — is installed verbatim, so staleness keeps accruing across
    /// the hop (rounds are global in lock-step hierarchies, and
    /// `base_round` is preserved, so `round − base_round` is monotone in
    /// `round`). An in-flight training job keeps its finish event; a
    /// ready-but-unserved upload lands in this cell's pending set and is
    /// offered at the next slot. Any ghost presence the client had here is
    /// purged first.
    pub fn admit_client(&mut self, client: usize, d: DetachedClient) {
        self.purge_client(client);
        self.latency.set_slow_state(client, d.latency_slow);
        if let Some(t) = d.queued_finish {
            self.queue.push(t, client);
        } else if d.was_ready {
            self.pending.push(client);
        }
        self.states.install(client, d.slot);
    }

    /// Admit a roaming client fresh (`drop` handover, and the tail of
    /// `deliver`): whatever it was *training* elsewhere is gone; it
    /// restarts from this cell's current global model at the boundary of
    /// slot `round`, with a latency draw from this cell's stream. The
    /// Gilbert–Elliott residence state still rides along
    /// (`latency_slow`) — the chain belongs to the device, not to the
    /// discarded work. Any ghost presence is purged first.
    pub fn admit_fresh(&mut self, client: usize, round: usize, latency_slow: bool) {
        self.purge_client(client);
        self.latency.set_slow_state(client, latency_slow);
        let slot_end = (round as f64 + 1.0) * self.cfg.delta_t;
        let base = self.base_arc();
        let finish = slot_end + self.latency.draw(client, &mut self.rngs.latency);
        self.states.set(client, round + 1, base, finish);
        self.queue.push(finish, client);
    }

    /// Remove every trace of `client` from the event queue and pending
    /// set (admit prologue).
    fn purge_client(&mut self, client: usize) {
        self.queue.remove_all(&client);
        self.pending.retain(|&c| c != client);
    }

    /// All cohort members start training on w_g^0 at t = 0 (b_k^1 = 1).
    /// One shared base snapshot for the whole fleet; latency draws stay
    /// in ascending-client order, so full-cohort runs are bit-identical
    /// to the seed's per-client scan.
    fn spawn_fleet(&mut self) {
        let base = self.base_arc();
        self.states = ClientStates::spawn(self.k, &base);
        for i in 0..self.members.len() {
            let client = self.members[i];
            let finish = self.latency.draw(client, &mut self.rngs.latency);
            self.states.finish[client] = finish;
            self.queue.push(finish, client);
        }
    }

    /// Spawn the fleet for step-wise periodic driving (call once, then
    /// [`Coordinator::step_periodic`] for rounds `0..cfg.rounds` in
    /// order). `run` does this internally; multi-cell runners interleave
    /// the steps of several coordinators to mix between slots.
    pub fn begin_periodic(&mut self) {
        self.spawn_fleet();
    }

    /// PAOTA-style time-triggered slots: every round closes after exactly
    /// ΔT virtual seconds, aggregating whatever finished inside it.
    fn drive_periodic(&mut self, policy: &mut dyn AggregationPolicy) -> Result<()> {
        self.begin_periodic();
        for round in 0..self.cfg.rounds {
            self.step_periodic(policy, round)?;
        }
        Ok(())
    }

    /// One ΔT slot of the periodic schedule: collect arrivals, let the
    /// policy pick and aggregate, restart uploaders, close the round.
    /// Rounds must be stepped contiguously from 0 (telemetry asserts).
    ///
    /// Implemented as [`Coordinator::open_periodic_slot`] → local
    /// training → [`Coordinator::complete_periodic_slot`], so a wire
    /// server (`fl::serve`) that farms the training jobs out to remote
    /// sessions and reassembles the submissions in dispatch order is
    /// bitwise identical to this in-process loop by construction.
    pub fn step_periodic(
        &mut self,
        policy: &mut dyn AggregationPolicy,
        round: usize,
    ) -> Result<()> {
        let OpenSlot { chosen, jobs, .. } = self.open_periodic_slot(policy, round);
        let outs = self.ctx.train_many(jobs, self.cfg.lr)?;
        let submissions = chosen.into_iter().zip(outs).collect();
        self.complete_periodic_slot(policy, round, submissions)
    }

    /// Open slot `round` of the periodic schedule: pop the arrivals that
    /// land inside it, let the policy choose the uploaders, and build
    /// their training jobs from their recorded base snapshots. The caller
    /// runs the jobs (locally, or across wire sessions) and hands the
    /// trained outputs to [`Coordinator::complete_periodic_slot`] —
    /// **in the dispatch order of [`OpenSlot::chosen`]**, which the
    /// aggregation draws are aligned to.
    pub fn open_periodic_slot(
        &mut self,
        policy: &mut dyn AggregationPolicy,
        round: usize,
    ) -> OpenSlot {
        let slot_end = (round as f64 + 1.0) * self.cfg.delta_t;
        while let Some((_, client)) = self.queue.pop_until(slot_end) {
            self.pending.push(client);
        }
        // Client-index order keeps the per-purpose streams aligned
        // with a deterministic scan over the fleet.
        self.pending.sort_unstable();
        let offered = std::mem::take(&mut self.pending);
        let chosen = policy.select_participants(&offered, &mut self.rngs);
        self.pending = offered.into_iter().filter(|c| !chosen.contains(c)).collect();

        let mut jobs = Vec::with_capacity(chosen.len());
        for &client in &chosen {
            jobs.push(policy.make_job(
                client,
                &self.states.base[client],
                self.ctx,
                &mut self.rngs.batch,
            ));
        }
        if let Some(tr) = &self.obs.trace {
            tr.emit(
                "slot_open",
                Some(round as f64 * self.cfg.delta_t),
                &[
                    ("round", V::U(round as u64)),
                    ("offered", V::U((chosen.len() + self.pending.len()) as u64)),
                    ("chosen", V::U(chosen.len() as u64)),
                ],
            );
        }
        OpenSlot {
            round,
            chosen,
            jobs,
        }
    }

    /// Complete slot `round`: fold the trained submissions (pairs of
    /// client id and [`TrainOut`]) into uploads with staleness from the
    /// clients' recorded base rounds, run the policy's aggregation,
    /// restart the uploaders at the slot boundary, and close the round.
    ///
    /// Submissions must arrive in dispatch order (see
    /// [`Coordinator::open_periodic_slot`]); clients dispatched in an
    /// *earlier* slot may appear too — their staleness is computed from
    /// their unchanged base round, which is exactly the paper's staleness
    /// path for late arrivals.
    pub fn complete_periodic_slot(
        &mut self,
        policy: &mut dyn AggregationPolicy,
        round: usize,
        submissions: Vec<(usize, TrainOut)>,
    ) -> Result<()> {
        let slot_end = (round as f64 + 1.0) * self.cfg.delta_t;
        let want_deltas = policy.needs_deltas();
        let mut uploads = Vec::with_capacity(submissions.len());
        for (client, out) in submissions {
            let staleness = round.saturating_sub(self.states.base_round[client]);
            if let Some(tr) = &self.obs.trace {
                tr.emit(
                    "arrival",
                    Some(slot_end),
                    &[
                        ("round", V::U(round as u64)),
                        ("client", V::U(client as u64)),
                        ("staleness", V::U(staleness as u64)),
                    ],
                );
            }
            let mut delta = Vec::new();
            if want_deltas {
                delta = vec![0.0f32; self.dim];
                vecmath::sub(&out.weights, &self.states.base[client], &mut delta);
            }
            uploads.push(Upload {
                client,
                staleness,
                loss: out.loss,
                weights: out.weights,
                delta,
            });
        }

        let action = if uploads.is_empty() {
            RoundAction::Skip { mean_power: 0.0 }
        } else {
            policy.on_uploads(round, &self.w_g, &uploads, &mut self.rngs)?
        };
        let stats = self.apply_round_action(action, &mut uploads, policy)?;

        // Uploaders restart from the fresh global model at the next
        // slot boundary — all sharing one snapshot from the store.
        let base = self.base_arc();
        for up in &uploads {
            let finish = slot_end + self.latency.draw(up.client, &mut self.rngs.latency);
            self.states.set(up.client, round + 1, base.clone(), finish);
            self.queue.push(finish, up.client);
        }

        self.clock.advance_to(slot_end);
        self.close_round(policy, round, slot_end, stats)
    }

    /// Synchronous cohorts: the PS waits for everyone it scheduled, so
    /// the round lasts as long as its slowest participant.
    fn drive_synchronous(&mut self, policy: &mut dyn AggregationPolicy) -> Result<()> {
        let fleet = self.members.clone();
        for round in 0..self.cfg.rounds {
            let chosen = policy.select_participants(&fleet, &mut self.rngs);
            let mut round_time = 0.0f64;
            for &client in &chosen {
                round_time = round_time.max(self.latency.draw(client, &mut self.rngs.latency));
            }
            let mut uploads = self.train_uploads(round, &chosen, policy, false)?;
            let action = if uploads.is_empty() {
                RoundAction::Skip { mean_power: 0.0 }
            } else {
                policy.on_uploads(round, &self.w_g, &uploads, &mut self.rngs)?
            };
            let stats = self.apply_round_action(action, &mut uploads, policy)?;
            self.clock.advance(round_time);
            let now = self.clock.now();
            self.close_round(policy, round, now, stats)?;
        }
        Ok(())
    }

    /// One pooled-data node: no stragglers, rounds advance by the mean of
    /// the configured latency span.
    fn drive_single_node(&mut self, policy: &mut dyn AggregationPolicy) -> Result<()> {
        let round_latency = (self.cfg.latency_lo + self.cfg.latency_hi) / 2.0;
        let node = [0usize];
        for round in 0..self.cfg.rounds {
            let mut uploads = self.train_uploads(round, &node, policy, false)?;
            let action = if uploads.is_empty() {
                RoundAction::Skip { mean_power: 0.0 }
            } else {
                policy.on_uploads(round, &self.w_g, &uploads, &mut self.rngs)?
            };
            let stats = self.apply_round_action(action, &mut uploads, policy)?;
            self.clock.advance(round_latency);
            let now = self.clock.now();
            self.close_round(policy, round, now, stats)?;
        }
        Ok(())
    }

    /// Fully-asynchronous arrivals: the model updates on every upload,
    /// telemetry is bucketed per ΔT window, and simultaneous arrivals are
    /// coalesced into one batched `train_many` call — bit-identical to
    /// serving them one by one, because each client's base snapshot was
    /// fixed when it last restarted and the mixing stays in FIFO order.
    fn drive_continuous(&mut self, policy: &mut dyn AggregationPolicy) -> Result<()> {
        self.spawn_fleet();
        let delta_t = self.cfg.delta_t;
        let horizon = self.cfg.rounds as f64 * delta_t;
        let mut stats = WindowStats::default();
        let mut batch: Vec<usize> = Vec::new();
        while let Some((t, first)) = self.queue.pop() {
            if t > horizon {
                break;
            }
            // Close every ΔT window that ended strictly before this
            // arrival (telemetry only — the model updates continuously).
            while (self.telemetry.window() as f64 + 1.0) * delta_t < t {
                let window = self.telemetry.window();
                let end = (window as f64 + 1.0) * delta_t;
                let closed = std::mem::take(&mut stats);
                self.close_round(policy, window, end, closed)?;
            }
            let window = self.telemetry.window();

            batch.clear();
            batch.push(first);
            while self.queue.peek_time() == Some(t) {
                batch.push(self.queue.pop().expect("peeked").1);
            }

            let uploads = self.train_uploads(window, &batch, policy, true)?;
            let action = policy.on_uploads(window, &self.w_g, &uploads, &mut self.rngs)?;
            let RoundAction::Mix { gammas } = action else {
                bail!("Continuous timing expects RoundAction::Mix");
            };
            ensure!(gammas.len() == uploads.len(), "one mixing rate per upload");

            self.clock.advance_to(t);
            for (up, &gamma) in uploads.iter().zip(&gammas) {
                // w_g ← (1 − γ)·w_g + γ·w_k; the client restarts
                // immediately from the freshly mixed global model.
                self.scratch.copy_from_slice(&self.w_g);
                vecmath::scale(&mut self.scratch, (1.0 - gamma) as f32);
                vecmath::axpy(gamma as f32, &up.weights, &mut self.scratch);
                std::mem::swap(&mut self.w_g, &mut self.scratch);
                self.touch_global();
                stats.absorb(up);

                let base = self.base_arc();
                let finish = t + self.latency.draw(up.client, &mut self.rngs.latency);
                self.states.set(up.client, window, base, finish);
                self.queue.push(finish, up.client);
            }
        }
        // Flush the remaining windows to exactly `rounds` records. The
        // first flushed window keeps everything it accumulated before the
        // horizon — including its staleness sum.
        while !self.telemetry.is_complete() {
            let window = self.telemetry.window();
            let end = (window as f64 + 1.0) * delta_t;
            let closed = std::mem::take(&mut stats);
            self.close_round(policy, window, end, closed)?;
        }
        Ok(())
    }

    /// Train `chosen` participants as one batched `train_many` call.
    /// With `from_slots`, bases (and staleness) come from the clients'
    /// scheduler slots; otherwise everyone trains from the current global
    /// model with zero staleness.
    fn train_uploads(
        &mut self,
        round: usize,
        chosen: &[usize],
        policy: &mut dyn AggregationPolicy,
        from_slots: bool,
    ) -> Result<Vec<Upload>> {
        let want_deltas = policy.needs_deltas();
        let mut jobs = Vec::with_capacity(chosen.len());
        for &client in chosen {
            let base: &[f32] = if from_slots {
                &self.states.base[client]
            } else {
                &self.w_g
            };
            jobs.push(policy.make_job(client, base, self.ctx, &mut self.rngs.batch));
        }
        let outs = self.ctx.train_many(jobs, self.cfg.lr)?;
        let mut uploads = Vec::with_capacity(chosen.len());
        for (&client, out) in chosen.iter().zip(outs) {
            let (staleness, base): (usize, &[f32]) = if from_slots {
                (
                    round.saturating_sub(self.states.base_round[client]),
                    &self.states.base[client],
                )
            } else {
                (0, &self.w_g)
            };
            let mut delta = Vec::new();
            if want_deltas {
                delta = vec![0.0f32; self.dim];
                vecmath::sub(&out.weights, base, &mut delta);
            }
            uploads.push(Upload {
                client,
                staleness,
                loss: out.loss,
                weights: out.weights,
                delta,
            });
        }
        Ok(uploads)
    }

    /// Apply the policy's decision to the global model and fold the
    /// round's uploads into a [`WindowStats`].
    fn apply_round_action(
        &mut self,
        action: RoundAction,
        uploads: &mut [Upload],
        policy: &mut dyn AggregationPolicy,
    ) -> Result<WindowStats> {
        let mut stats = WindowStats::default();
        for up in uploads.iter() {
            stats.absorb(up);
        }
        match action {
            RoundAction::Skip { mean_power } => stats.mean_power = mean_power,
            RoundAction::Adopt => {
                ensure!(uploads.len() == 1, "Adopt expects exactly one upload");
                self.w_g = std::mem::take(&mut uploads[0].weights);
                self.touch_global();
            }
            RoundAction::Mix { .. } => bail!("Mix is only valid under Continuous timing"),
            RoundAction::GroupAggregate { passes } => {
                ensure!(!passes.is_empty(), "GroupAggregate needs at least one pass");
                let mut covered = vec![false; uploads.len()];
                let mut total_mix = 0.0f64;
                let mut power_sum = 0.0f64;
                // Σ_g μ_g·y_g, accumulated across the per-group passes.
                let mut blended = vec![0.0f32; self.dim];
                for pass in &passes {
                    ensure!(!pass.members.is_empty(), "empty group pass");
                    ensure!(
                        pass.coefs.len() == pass.members.len(),
                        "one coefficient per pass member"
                    );
                    ensure!(pass.mix > 0.0, "group mix weight must be positive");
                    for &j in &pass.members {
                        ensure!(j < uploads.len(), "pass member {j} out of range");
                        ensure!(
                            !covered[j],
                            "upload {j} appears in more than one group pass"
                        );
                        covered[j] = true;
                    }
                    // Pack the pass rows in ascending client order — the
                    // order the seed's fleet-sized scan visited them, so
                    // the f32 accumulation is bit-identical with
                    // pass-sized buffers.
                    let mut idx: Vec<usize> = (0..pass.members.len()).collect();
                    idx.sort_unstable_by_key(|&i| uploads[pass.members[i]].client);
                    self.coef.clear();
                    self.stack.clear();
                    for &i in &idx {
                        self.coef.push(pass.coefs[i]);
                        self.stack
                            .extend_from_slice(&uploads[pass.members[i]].weights);
                    }
                    let noise_ref: &[f32] = if pass.noise.is_empty() {
                        &self.zero_noise
                    } else {
                        &pass.noise
                    };
                    let y = self.ctx.rt.aggregate_rows(&self.stack, &self.coef, noise_ref)?;
                    vecmath::axpy(pass.mix as f32, &y, &mut blended);
                    total_mix += pass.mix;
                    power_sum += pass.mean_power * pass.members.len() as f64;
                }
                ensure!(
                    covered.iter().all(|&c| c),
                    "every upload must belong to exactly one group pass"
                );
                ensure!(
                    total_mix <= 1.0 + 1e-9,
                    "group mix weights sum to {total_mix} > 1"
                );
                stats.mean_power = power_sum / uploads.len() as f64;
                if let Some(tr) = &self.obs.trace {
                    tr.emit(
                        "ota_aggregate",
                        None,
                        &[
                            ("participants", V::U(uploads.len() as u64)),
                            ("passes", V::U(passes.len() as u64)),
                            ("mean_power", V::F(stats.mean_power)),
                        ],
                    );
                }
                // w ← (1 − Σμ)·w + Σ_g μ_g·y_g.
                self.scratch.copy_from_slice(&self.w_g);
                vecmath::scale(&mut self.w_g, (1.0 - total_mix) as f32);
                vecmath::axpy(1.0, &blended, &mut self.w_g);
                self.touch_global();
                // `blended` is free now — reuse it for the movement report.
                vecmath::sub(&self.w_g, &self.scratch, &mut blended);
                policy.on_global_delta(&blended);
            }
            RoundAction::Aggregate {
                coefs,
                noise,
                deltas,
                mean_power,
            } => {
                ensure!(coefs.len() == uploads.len(), "one coefficient per upload");
                stats.mean_power = mean_power;
                if let Some(tr) = &self.obs.trace {
                    tr.emit(
                        "ota_aggregate",
                        None,
                        &[
                            ("participants", V::U(uploads.len() as u64)),
                            ("mean_power", V::F(mean_power)),
                            ("noisy", V::U(u64::from(!noise.is_empty()))),
                        ],
                    );
                }
                // Pack participant rows in ascending client order — the
                // order the seed's fleet-sized scan visited them, so the
                // f32 accumulation is bit-identical while the buffers
                // stay cohort-sized.
                let mut order: Vec<usize> = (0..uploads.len()).collect();
                order.sort_unstable_by_key(|&j| uploads[j].client);
                self.coef.clear();
                self.stack.clear();
                for &j in &order {
                    self.coef.push(coefs[j]);
                    let up = &uploads[j];
                    let row = if deltas { &up.delta } else { &up.weights };
                    self.stack.extend_from_slice(row);
                }
                let noise_ref: &[f32] = if noise.is_empty() { &self.zero_noise } else { &noise };
                let out = self.ctx.rt.aggregate_rows(&self.stack, &self.coef, noise_ref)?;
                if deltas {
                    // The kernel's weighted mean of update rows IS the
                    // global step.
                    policy.on_global_delta(&out);
                    vecmath::axpy(1.0, &out, &mut self.w_g);
                } else {
                    let prev = std::mem::replace(&mut self.w_g, out);
                    vecmath::sub(&self.w_g, &prev, &mut self.scratch);
                    policy.on_global_delta(&self.scratch);
                }
                self.touch_global();
            }
        }
        Ok(stats)
    }

    /// Evaluate per the shared cadence and emit the round's record.
    fn close_round(
        &mut self,
        policy: &dyn AggregationPolicy,
        round: usize,
        sim_time: f64,
        stats: WindowStats,
    ) -> Result<()> {
        let eval = if self.telemetry.should_eval(round) {
            Some(self.ctx.evaluate(&self.w_g)?)
        } else {
            None
        };
        let probe_loss = match eval {
            Some(_) => Some(self.ctx.probe_loss(&self.w_g)?),
            None => None,
        };
        self.obs.rounds.inc();
        self.obs.uploads.add(stats.uploads as u64);
        self.obs.participants.observe(stats.uploads as f64);
        self.obs.staleness.observe(stats.mean_staleness());
        if let Some(tr) = &self.obs.trace {
            let mut fields = vec![
                ("round", V::U(round as u64)),
                ("uploads", V::U(stats.uploads as u64)),
                ("mean_staleness", V::F(stats.mean_staleness())),
                ("mean_power", V::F(stats.mean_power)),
            ];
            let loss = stats.train_loss();
            if loss.is_finite() {
                // NaN (empty window) would not be valid JSON — omit it.
                fields.push(("train_loss", V::F(loss as f64)));
            }
            tr.emit("round_close", Some(sim_time), &fields);
        }
        let rec = self.telemetry.record(round, sim_time, stats, eval, probe_loss);
        crate::debug!(
            "{} r={round} t={sim_time:.0}s up={} stale={:.2} loss={:.4} acc={:?}",
            policy.name(),
            rec.participants,
            rec.mean_staleness,
            rec.train_loss,
            rec.eval.map(|e| e.accuracy),
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn upload(loss: f32, staleness: usize) -> Upload {
        Upload {
            client: 0,
            staleness,
            loss,
            weights: Vec::new(),
            delta: Vec::new(),
        }
    }

    #[test]
    fn eval_cadence_hits_every_nth_and_final_round() {
        let t = Telemetry::new(10, 3);
        let evaluated: Vec<usize> = (0..10).filter(|&r| t.should_eval(r)).collect();
        assert_eq!(evaluated, vec![0, 3, 6, 9]);
        let t = Telemetry::new(5, 2);
        let evaluated: Vec<usize> = (0..5).filter(|&r| t.should_eval(r)).collect();
        assert_eq!(evaluated, vec![0, 2, 4]);
        // The final round is always evaluated even off-cadence.
        let t = Telemetry::new(4, 3);
        assert!(t.should_eval(3));
    }

    #[test]
    fn window_stats_means_and_empty_window() {
        let mut s = WindowStats::default();
        assert!(s.train_loss().is_nan());
        assert_eq!(s.mean_staleness(), 0.0);
        s.absorb(&upload(1.0, 2));
        s.absorb(&upload(3.0, 4));
        assert_eq!(s.uploads, 2);
        assert_eq!(s.train_loss(), 2.0);
        assert_eq!(s.mean_staleness(), 3.0);
    }

    #[test]
    fn telemetry_records_are_contiguous() {
        let mut t = Telemetry::new(3, 1);
        t.record(0, 8.0, WindowStats::default(), None, None);
        t.record(1, 16.0, WindowStats::default(), None, None);
        t.record(2, 24.0, WindowStats::default(), None, None);
        assert!(t.is_complete());
        let recs = t.into_records();
        for (i, r) in recs.iter().enumerate() {
            assert_eq!(r.round, i);
        }
    }

    #[test]
    #[should_panic(expected = "out of order")]
    fn telemetry_rejects_window_gaps() {
        let mut t = Telemetry::new(3, 1);
        t.record(1, 8.0, WindowStats::default(), None, None);
    }

    #[test]
    #[should_panic(expected = "backwards")]
    fn telemetry_rejects_time_regression() {
        let mut t = Telemetry::new(3, 1);
        t.record(0, 8.0, WindowStats::default(), None, None);
        t.record(1, 4.0, WindowStats::default(), None, None);
    }

    #[test]
    fn rng_streams_are_deterministic_and_distinct() {
        let mut a = RngStreams::new(42, streams::BATCH);
        let mut b = RngStreams::new(42, streams::BATCH);
        assert_eq!(a.latency.next_u32(), b.latency.next_u32());
        assert_eq!(a.batch.next_u32(), b.batch.next_u32());
        // Purposes are independent streams: drawing from one must not
        // perturb another.
        let before = b.channel.next_u32();
        for _ in 0..17 {
            a.pick.next_u32();
        }
        assert_eq!(a.channel.next_u32(), before);
    }
}
