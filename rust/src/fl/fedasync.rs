//! FedAsync — fully-asynchronous FL, the other end of the spectrum the
//! paper positions PAOTA against (its reference [7], Su & Li, "How
//! Asynchronous can Federated Learning Be?"; mixing rule after Xie et
//! al.) — as an [`AggregationPolicy`] under the coordinator's
//! [`Continuous`](RoundTiming::Continuous) timing.
//!
//! No rounds at all: the PS updates the global model **on every client
//! arrival**, with a staleness-discounted mixing rate
//!
//! ```text
//!   w_g ← (1 − γ_s)·w_g + γ_s·w_k,    γ_s = γ₀ · Ω/(s + Ω)
//! ```
//!
//! (the same Ω-discount shape as PAOTA's ρ factor, so the two schemes are
//! directly comparable). Each upload is a single dedicated transmission —
//! no AirComp superposition — which is exactly the scalability cost PAOTA
//! avoids: K simultaneous uploads need K time/frequency slots here but
//! one MAC slot under AirComp.
//!
//! The coordinator drives the run off the continuous-time event queue
//! until `rounds·ΔT` virtual seconds so budgets match the periodic
//! schemes, buckets telemetry per ΔT window into the same
//! [`RoundRecord`](super::RoundRecord) stream, and coalesces
//! simultaneous arrivals into one batched
//! `train_many` call (bit-identical to serving them one by one).
//!
//! This is an *extension* (DESIGN.md step-5 scope): not one of the
//! paper's evaluated baselines, but the natural ablation of "periodic" in
//! Periodic Aggregation Over-The-Air.

use anyhow::Result;

use crate::config::Config;
use crate::power::staleness_factor;

use super::coordinator::{AggregationPolicy, RngStreams, RoundAction, RoundTiming, Upload};
use super::TrainContext;

/// Per-arrival staleness-discounted mixing.
pub struct FedAsync {
    /// Base mixing rate γ₀.
    gamma0: f64,
    /// Staleness bound Ω of the discount γ_s = γ₀·Ω/(s + Ω).
    omega: f64,
}

impl FedAsync {
    pub fn new(_ctx: &TrainContext, cfg: &Config) -> Self {
        Self {
            gamma0: cfg.fedasync_gamma,
            omega: cfg.omega,
        }
    }
}

impl AggregationPolicy for FedAsync {
    fn name(&self) -> &str {
        "fedasync"
    }

    fn timing(&self) -> RoundTiming {
        RoundTiming::Continuous
    }

    fn on_uploads(
        &mut self,
        _window: usize,
        _global: &[f32],
        uploads: &[Upload],
        _rngs: &mut RngStreams,
    ) -> Result<RoundAction> {
        Ok(RoundAction::Mix {
            gammas: uploads
                .iter()
                .map(|up| self.gamma0 * staleness_factor(up.staleness, self.omega))
                .collect(),
        })
    }
}
