//! FedAsync — fully-asynchronous FL, the other end of the spectrum the
//! paper positions PAOTA against (its reference [7], Su & Li, "How
//! Asynchronous can Federated Learning Be?"; mixing rule after Xie et al.).
//!
//! No rounds at all: the PS updates the global model **on every client
//! arrival**, with a staleness-discounted mixing rate
//!
//! ```text
//!   w_g ← (1 − γ_s)·w_g + γ_s·w_k,    γ_s = γ₀ · Ω/(s + Ω)
//! ```
//!
//! (the same Ω-discount shape as PAOTA's ρ factor, so the two schemes are
//! directly comparable). Each upload is a single dedicated transmission —
//! no AirComp superposition — which is exactly the scalability cost PAOTA
//! avoids: K simultaneous uploads need K time/frequency slots here but
//! one MAC slot under AirComp.
//!
//! Driven by the continuous-time [`EventQueue`](crate::sim::events): the
//! trainer runs until `rounds·ΔT` virtual seconds so budgets match the
//! periodic schemes, and telemetry is bucketed per ΔT window to emit the
//! same [`RoundRecord`] stream.
//!
//! This is an *extension* (DESIGN.md step-5 scope): not one of the paper's
//! evaluated baselines, but the natural ablation of "periodic" in
//! Periodic Aggregation Over-The-Air.

use anyhow::Result;

use crate::config::Config;
use crate::power::staleness_factor;
use crate::sim::events::EventQueue;
use crate::util::{vecmath, Rng};

use super::{RoundRecord, RunResult, TrainContext};

/// Client-finished event payload.
#[derive(Debug, Clone, Copy)]
struct Finished {
    client: usize,
    /// Window index when this client's base model was taken.
    base_window: usize,
}

pub fn run(ctx: &TrainContext, cfg: &Config) -> Result<RunResult> {
    let dim = ctx.dim();
    let k = ctx.clients();
    let m = ctx.rt.manifest().clone();
    let latency = cfg.latency();
    let horizon = cfg.rounds as f64 * cfg.delta_t;
    let gamma0 = cfg.fedasync_gamma;

    let mut lat_rng = Rng::with_stream(cfg.seed, 0x1a7);
    let mut batch_rng = Rng::with_stream(cfg.seed, 0xba7c);

    let mut w_g = ctx.init_weights();
    // Per-client base model snapshot (what it trains from).
    let mut bases: Vec<Vec<f32>> = (0..k).map(|_| w_g.clone()).collect();

    let mut q = EventQueue::new();
    for client in 0..k {
        q.push(
            latency.draw(&mut lat_rng),
            Finished {
                client,
                base_window: 0,
            },
        );
    }

    let mut records = Vec::with_capacity(cfg.rounds);
    let mut window = 0usize;
    let mut win_updates = 0usize;
    let mut win_loss = 0.0f64;
    let mut win_stale = 0.0f64;
    let mut mixed = vec![0.0f32; dim];

    while let Some((t, ev)) = q.pop() {
        if t > horizon {
            break;
        }
        // Close any ΔT windows that ended before this event (telemetry
        // only — the model updates continuously).
        while (window as f64 + 1.0) * cfg.delta_t < t {
            let end = (window as f64 + 1.0) * cfg.delta_t;
            let eval = if window % cfg.eval_every == 0 {
                Some(ctx.evaluate(&w_g)?)
            } else {
                None
            };
            records.push(RoundRecord {
                round: window,
                sim_time: end,
                train_loss: if win_updates > 0 {
                    (win_loss / win_updates as f64) as f32
                } else {
                    f32::NAN
                },
                probe_loss: if eval.is_some() {
                    Some(ctx.probe_loss(&w_g)?)
                } else {
                    None
                },
                eval,
                participants: win_updates,
                mean_staleness: if win_updates > 0 {
                    win_stale / win_updates as f64
                } else {
                    0.0
                },
                mean_power: 0.0,
            });
            window += 1;
            win_updates = 0;
            win_loss = 0.0;
            win_stale = 0.0;
        }

        // Local training from this client's base snapshot.
        let (xs, ys) =
            ctx.partition.clients[ev.client].sample_batches(m.local_steps, m.batch, &mut batch_rng);
        let out = ctx
            .rt
            .local_train(&bases[ev.client], &xs, &ys, cfg.lr)?;

        // Staleness in ΔT windows (comparable to PAOTA's s_k).
        let stale = window.saturating_sub(ev.base_window);
        let gamma = gamma0 * staleness_factor(stale, cfg.omega);

        // w_g ← (1−γ)w_g + γ·w_k.
        mixed.copy_from_slice(&w_g);
        vecmath::scale(&mut mixed, (1.0 - gamma) as f32);
        vecmath::axpy(gamma as f32, &out.weights, &mut mixed);
        std::mem::swap(&mut w_g, &mut mixed);

        win_updates += 1;
        win_loss += out.loss as f64;
        win_stale += stale as f64;

        // Client restarts immediately from the fresh global model.
        bases[ev.client] = w_g.clone();
        q.push(
            t + latency.draw(&mut lat_rng),
            Finished {
                client: ev.client,
                base_window: window,
            },
        );
    }

    // Flush remaining windows to exactly `rounds` records.
    while records.len() < cfg.rounds {
        let window = records.len();
        let end = (window as f64 + 1.0) * cfg.delta_t;
        let eval = if window % cfg.eval_every == 0 || window + 1 == cfg.rounds {
            Some(ctx.evaluate(&w_g)?)
        } else {
            None
        };
        records.push(RoundRecord {
            round: window,
            sim_time: end,
            train_loss: if win_updates > 0 {
                (win_loss / win_updates as f64) as f32
            } else {
                f32::NAN
            },
            probe_loss: if eval.is_some() {
                Some(ctx.probe_loss(&w_g)?)
            } else {
                None
            },
            eval,
            participants: win_updates,
            mean_staleness: 0.0,
            mean_power: 0.0,
        });
        win_updates = 0;
        win_loss = 0.0;
        win_stale = 0.0;
    }

    Ok(RunResult {
        algorithm: crate::config::Algorithm::FedAsync,
        records,
        final_weights: w_g,
    })
}
