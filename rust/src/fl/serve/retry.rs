//! `serve::retry` — shared jittered-exponential backoff.
//!
//! One [`Backoff`] instance serves every retry path a loadgen session
//! has: `Busy` backpressure, reply-deadline timeouts, and reconnects.
//! Delays grow as `min(max_ms, base_ms · 2^attempt)` scaled by a
//! uniform jitter in `[0.5, 1.0)` so a fleet of sessions rejected
//! together does not re-dial in lockstep. The jitter stream is
//! seed-deterministic per entity (`Rng::for_entity` with
//! [`STREAM_RETRY`]), like every other randomness source in the repo.
//!
//! `attempt` saturates once the cap is reached: `next_delay` can be
//! called forever (Busy retries are not bounded — backpressure resolves
//! when the server drains). Callers that *do* bound retries (loadgen's
//! reconnect path, capped by `chaos_max_retries`) count attempts
//! themselves and call [`Backoff::reset`] whenever forward progress is
//! observed, so only *consecutive* fruitless attempts count against the
//! cap.

use std::time::Duration;

use crate::config::ChaosConfig;
use crate::util::Rng;

/// RNG stream tag for backoff jitter.
pub const STREAM_RETRY: u64 = 0xbac0;

/// Jittered exponential backoff. See the module docs.
pub struct Backoff {
    base_ms: u64,
    max_ms: u64,
    attempt: u32,
    rng: Rng,
}

impl Backoff {
    /// Build with explicit bounds; `base_ms` is floored at 1 and
    /// `max_ms` at `base_ms`.
    pub fn new(base_ms: u64, max_ms: u64, seed: u64, entity: u64) -> Self {
        let base_ms = base_ms.max(1);
        Self {
            base_ms,
            max_ms: max_ms.max(base_ms),
            attempt: 0,
            rng: Rng::for_entity(seed, STREAM_RETRY, entity),
        }
    }

    /// Build from the `[chaos]` retry knobs.
    pub fn from_cfg(c: &ChaosConfig, seed: u64, entity: u64) -> Self {
        Self::new(c.retry_base_ms, c.retry_max_ms, seed, entity)
    }

    /// Delays handed out since the last reset.
    pub fn attempt(&self) -> u32 {
        self.attempt
    }

    /// Forget the escalation (call on forward progress).
    pub fn reset(&mut self) {
        self.attempt = 0;
    }

    /// Next delay: `min(max, base · 2^attempt) · U[0.5, 1.0)`, never
    /// below 1 ms.
    pub fn next_delay(&mut self) -> Duration {
        let shift = self.attempt.min(20);
        let raw = self.base_ms.saturating_mul(1u64 << shift).min(self.max_ms);
        self.attempt = self.attempt.saturating_add(1);
        let jitter = 0.5 + 0.5 * self.rng.f64();
        Duration::from_millis(((raw as f64 * jitter) as u64).max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delays_grow_then_cap_at_max() {
        let mut b = Backoff::new(10, 80, 1, 0);
        let mut prev_cap = 0u128;
        for i in 0..8 {
            let d = b.next_delay().as_millis();
            let cap = 10u128.saturating_mul(1 << i).min(80);
            assert!(d <= cap, "delay {d} above cap {cap} at attempt {i}");
            assert!(d >= cap / 2, "delay {d} below half-cap {} at {i}", cap / 2);
            assert!(cap >= prev_cap);
            prev_cap = cap;
        }
        assert_eq!(b.attempt(), 8);
    }

    #[test]
    fn reset_restarts_the_escalation() {
        let mut b = Backoff::new(10, 10_000, 1, 0);
        for _ in 0..6 {
            b.next_delay();
        }
        b.reset();
        assert_eq!(b.attempt(), 0);
        assert!(b.next_delay().as_millis() <= 10);
    }

    #[test]
    fn jitter_is_deterministic_per_entity() {
        let seq = |entity: u64| {
            let mut b = Backoff::new(5, 500, 99, entity);
            (0..10).map(|_| b.next_delay()).collect::<Vec<_>>()
        };
        assert_eq!(seq(1), seq(1));
        assert_ne!(seq(1), seq(2));
    }

    #[test]
    fn degenerate_bounds_are_floored() {
        let mut b = Backoff::new(0, 0, 0, 0);
        let d = b.next_delay();
        assert!(d >= Duration::from_millis(1));
        assert!(d <= Duration::from_millis(1));
    }
}
