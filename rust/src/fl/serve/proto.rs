//! `serve::proto` — the compact length-prefixed wire protocol for client
//! sessions.
//!
//! Every frame on the wire is
//!
//! ```text
//! u32 LE payload_len | payload | u32 LE checksum
//! payload := u8 version | u8 msg_type | body
//! ```
//!
//! with an FNV-1a checksum over the payload bytes, so a corrupted or
//! version-skewed peer is rejected at the frame boundary instead of
//! desynchronizing the session. All integers are little-endian;
//! `Vec<f32>` fields are a `u32` element count followed by raw LE f32
//! bits (bit-exact round-trip — the loopback golden test depends on it).
//!
//! The session vocabulary (client ⇄ server):
//!
//! | message | direction | meaning |
//! |---|---|---|
//! | [`Msg::Hello`] / [`Msg::Assign`] | C→S / S→C | session setup: version handshake, session id + optional resume token, run geometry |
//! | [`Msg::FetchJob`] / [`Msg::Job`] / [`Msg::NoJob`] | C→S / S→C | pull one training job (base model + minibatches) |
//! | [`Msg::Submit`] | C→S | submit-update: round id + staleness metadata + trained payload |
//! | [`Msg::Ack`] / [`Msg::Reject`] / [`Msg::Busy`] | S→C | accept, refuse (duplicate / out-of-round), or backpressure |
//! | [`Msg::Bye`] | C→S | orderly session end |

use std::io::{Read, Write};

use anyhow::{bail, ensure, Result};

/// Protocol version byte — bump on any incompatible layout change.
/// v2: [`Msg::Hello`] carries a resume token (reconnect-and-resume).
pub const VERSION: u8 = 2;

/// Upper bound on a single frame's payload (defends the length prefix:
/// a corrupted u32 claiming more is rejected before any allocation, and
/// accepted lengths are read in small chunks so a hostile claim under
/// the cap costs only the bytes actually received).
pub const MAX_FRAME: usize = 1 << 28;

/// Why a [`Msg::Submit`] was refused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectCode {
    /// Same client already had an accepted update for this round.
    Duplicate,
    /// Round id is not an open (dispatched) unit of work for this client
    /// — a future round, or a job this client was never handed.
    OutOfRound,
}

impl RejectCode {
    fn to_u8(self) -> u8 {
        match self {
            RejectCode::Duplicate => 1,
            RejectCode::OutOfRound => 2,
        }
    }

    fn from_u8(b: u8) -> Result<Self> {
        Ok(match b {
            1 => RejectCode::Duplicate,
            2 => RejectCode::OutOfRound,
            other => bail!("unknown reject code {other}"),
        })
    }
}

/// One protocol message (see the module table).
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// Session open; `token` is a caller-chosen tag echoed in logs.
    /// `resume` is 0 for a fresh session, or the prior session id when
    /// reconnecting — the server then re-issues any half-done job it
    /// reclaimed from the dead connection.
    Hello { token: u64, resume: u64 },
    /// Session accepted: id, run horizon and model geometry.
    Assign {
        session: u64,
        rounds: u64,
        dim: u64,
        lr: f32,
    },
    /// Ask for the next unit of work.
    FetchJob,
    /// A training job: act as `client` for `round`, train `w` on the
    /// pre-sampled minibatches `(xs, ys)`.
    Job {
        client: u64,
        round: u64,
        staleness: u64,
        w: Vec<f32>,
        xs: Vec<f32>,
        ys: Vec<f32>,
    },
    /// No work right now; `done` means the run is over — disconnect.
    NoJob { done: bool },
    /// Submit-update: round id + staleness metadata + trained payload.
    Submit {
        client: u64,
        round: u64,
        staleness: u64,
        loss: f32,
        weights: Vec<f32>,
    },
    /// Update accepted into round `round`'s aggregation buffer.
    Ack { round: u64 },
    /// Update refused (duplicate / out-of-round).
    Reject { code: RejectCode, round: u64 },
    /// Backpressure: the aggregation buffer (or session table) is full —
    /// retry after a short pause.
    Busy,
    /// Orderly session end.
    Bye,
}

const T_HELLO: u8 = 1;
const T_ASSIGN: u8 = 2;
const T_FETCH_JOB: u8 = 3;
const T_JOB: u8 = 4;
const T_NO_JOB: u8 = 5;
const T_SUBMIT: u8 = 6;
const T_ACK: u8 = 7;
const T_REJECT: u8 = 8;
const T_BUSY: u8 = 9;
const T_BYE: u8 = 10;

/// FNV-1a over the payload bytes.
pub fn checksum(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811c_9dc5;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(buf: &mut Vec<u8>, v: f32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_vec_f32(buf: &mut Vec<u8>, v: &[f32]) {
    buf.extend_from_slice(&(v.len() as u32).to_le_bytes());
    for &x in v {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.pos + n <= self.buf.len(), "truncated message body");
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn vec_f32(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let raw = self.take(n * 4)?;
        let mut out = Vec::with_capacity(n);
        for chunk in raw.chunks_exact(4) {
            out.push(f32::from_le_bytes(chunk.try_into().unwrap()));
        }
        Ok(out)
    }

    fn finished(&self) -> Result<()> {
        ensure!(self.pos == self.buf.len(), "trailing bytes in message");
        Ok(())
    }
}

/// Serialize `msg` into a complete frame (length prefix + payload +
/// checksum).
pub fn encode(msg: &Msg) -> Vec<u8> {
    let mut p = Vec::with_capacity(64);
    p.push(VERSION);
    match msg {
        Msg::Hello { token, resume } => {
            p.push(T_HELLO);
            put_u64(&mut p, *token);
            put_u64(&mut p, *resume);
        }
        Msg::Assign {
            session,
            rounds,
            dim,
            lr,
        } => {
            p.push(T_ASSIGN);
            put_u64(&mut p, *session);
            put_u64(&mut p, *rounds);
            put_u64(&mut p, *dim);
            put_f32(&mut p, *lr);
        }
        Msg::FetchJob => p.push(T_FETCH_JOB),
        Msg::Job {
            client,
            round,
            staleness,
            w,
            xs,
            ys,
        } => {
            p.push(T_JOB);
            put_u64(&mut p, *client);
            put_u64(&mut p, *round);
            put_u64(&mut p, *staleness);
            put_vec_f32(&mut p, w);
            put_vec_f32(&mut p, xs);
            put_vec_f32(&mut p, ys);
        }
        Msg::NoJob { done } => {
            p.push(T_NO_JOB);
            p.push(u8::from(*done));
        }
        Msg::Submit {
            client,
            round,
            staleness,
            loss,
            weights,
        } => {
            p.push(T_SUBMIT);
            put_u64(&mut p, *client);
            put_u64(&mut p, *round);
            put_u64(&mut p, *staleness);
            put_f32(&mut p, *loss);
            put_vec_f32(&mut p, weights);
        }
        Msg::Ack { round } => {
            p.push(T_ACK);
            put_u64(&mut p, *round);
        }
        Msg::Reject { code, round } => {
            p.push(T_REJECT);
            p.push(code.to_u8());
            put_u64(&mut p, *round);
        }
        Msg::Busy => p.push(T_BUSY),
        Msg::Bye => p.push(T_BYE),
    }
    let mut frame = Vec::with_capacity(p.len() + 8);
    frame.extend_from_slice(&(p.len() as u32).to_le_bytes());
    frame.extend_from_slice(&p);
    frame.extend_from_slice(&checksum(&p).to_le_bytes());
    frame
}

/// Parse one payload (frame minus length prefix and checksum, both
/// already validated) into a [`Msg`].
pub fn decode(payload: &[u8]) -> Result<Msg> {
    let mut c = Cursor {
        buf: payload,
        pos: 0,
    };
    let version = c.u8()?;
    ensure!(
        version == VERSION,
        "protocol version mismatch: peer speaks v{version}, this build v{VERSION}"
    );
    let t = c.u8()?;
    let msg = match t {
        T_HELLO => Msg::Hello {
            token: c.u64()?,
            resume: c.u64()?,
        },
        T_ASSIGN => Msg::Assign {
            session: c.u64()?,
            rounds: c.u64()?,
            dim: c.u64()?,
            lr: c.f32()?,
        },
        T_FETCH_JOB => Msg::FetchJob,
        T_JOB => Msg::Job {
            client: c.u64()?,
            round: c.u64()?,
            staleness: c.u64()?,
            w: c.vec_f32()?,
            xs: c.vec_f32()?,
            ys: c.vec_f32()?,
        },
        T_NO_JOB => Msg::NoJob {
            done: c.u8()? != 0,
        },
        T_SUBMIT => Msg::Submit {
            client: c.u64()?,
            round: c.u64()?,
            staleness: c.u64()?,
            loss: c.f32()?,
            weights: c.vec_f32()?,
        },
        T_ACK => Msg::Ack { round: c.u64()? },
        T_REJECT => Msg::Reject {
            code: RejectCode::from_u8(c.u8()?)?,
            round: c.u64()?,
        },
        T_BUSY => Msg::Busy,
        T_BYE => Msg::Bye,
        other => bail!("unknown message type {other}"),
    };
    c.finished()?;
    Ok(msg)
}

/// Write one message as a frame (single `write_all` — frames never
/// interleave on a stream written from one thread).
pub fn write_msg<W: Write>(w: &mut W, msg: &Msg) -> std::io::Result<()> {
    w.write_all(&encode(msg))
}

/// Outcome of a frame read on a stream that may carry a read timeout.
#[derive(Debug)]
pub enum FrameRead {
    /// A complete, checksum-verified message.
    Msg(Msg),
    /// Clean EOF at a frame boundary (peer closed the session).
    Eof,
    /// The read timed out before the first byte of a frame — no data was
    /// consumed; the caller may poll its shutdown flag and retry.
    IdleTimeout,
}

/// Read one frame. Timeouts *between* frames surface as
/// [`FrameRead::IdleTimeout`]; a timeout in the middle of a frame is
/// retried a bounded number of times before becoming an error (a peer
/// that stalls mid-frame is broken, not idle).
pub fn read_msg<R: Read>(r: &mut R) -> Result<FrameRead> {
    const MID_FRAME_RETRIES: usize = 40;

    let mut header = [0u8; 4];
    match read_exact_retry(r, &mut header, true, MID_FRAME_RETRIES)? {
        ReadState::Eof => return Ok(FrameRead::Eof),
        ReadState::Idle => return Ok(FrameRead::IdleTimeout),
        ReadState::Done => {}
    }
    let len = u32::from_le_bytes(header) as usize;
    ensure!(len >= 2 && len <= MAX_FRAME, "bad frame length {len}");

    // Grow the buffer chunk by chunk instead of trusting the prefix
    // with one up-front allocation: a corrupted length claiming
    // hundreds of MB costs only the bytes the peer actually sends
    // before the stream errors out.
    const CHUNK: usize = 64 << 10;
    let mut payload = Vec::with_capacity(len.min(CHUNK));
    while payload.len() < len {
        let start = payload.len();
        let take = (len - start).min(CHUNK);
        payload.resize(start + take, 0);
        match read_exact_retry(r, &mut payload[start..], false, MID_FRAME_RETRIES)? {
            ReadState::Done => {}
            _ => bail!("peer closed mid-frame"),
        }
    }
    let mut csum = [0u8; 4];
    match read_exact_retry(r, &mut csum, false, MID_FRAME_RETRIES)? {
        ReadState::Done => {}
        _ => bail!("peer closed before checksum"),
    }
    let expect = u32::from_le_bytes(csum);
    let got = checksum(&payload);
    ensure!(
        got == expect,
        "frame checksum mismatch (got {got:#010x}, expect {expect:#010x})"
    );
    Ok(FrameRead::Msg(decode(&payload)?))
}

enum ReadState {
    Done,
    Eof,
    Idle,
}

/// `read_exact` that survives `WouldBlock`/`TimedOut` (SO_RCVTIMEO):
/// with `allow_idle`, a timeout before the first byte returns
/// [`ReadState::Idle`] without consuming anything; mid-buffer timeouts
/// retry up to `retries` times.
fn read_exact_retry<R: Read>(
    r: &mut R,
    buf: &mut [u8],
    allow_idle: bool,
    retries: usize,
) -> Result<ReadState> {
    let mut got = 0usize;
    let mut stalls = 0usize;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => {
                if got == 0 && allow_idle {
                    return Ok(ReadState::Eof);
                }
                if got == 0 {
                    return Ok(ReadState::Eof);
                }
                bail!("peer closed mid-read ({got}/{} bytes)", buf.len());
            }
            Ok(n) => {
                got += n;
                stalls = 0;
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if got == 0 && allow_idle {
                    return Ok(ReadState::Idle);
                }
                stalls += 1;
                ensure!(stalls <= retries, "peer stalled mid-frame");
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    Ok(ReadState::Done)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: Msg) {
        let frame = encode(&msg);
        let mut slice = frame.as_slice();
        match read_msg(&mut slice).unwrap() {
            FrameRead::Msg(got) => assert_eq!(got, msg),
            other => panic!("expected message, got {other:?}"),
        }
        assert!(slice.is_empty(), "frame not fully consumed");
    }

    #[test]
    fn all_messages_roundtrip() {
        roundtrip(Msg::Hello { token: 42, resume: 0 });
        roundtrip(Msg::Hello {
            token: 42,
            resume: 42,
        });
        roundtrip(Msg::Assign {
            session: 7,
            rounds: 30,
            dim: 8070,
            lr: 0.05,
        });
        roundtrip(Msg::FetchJob);
        roundtrip(Msg::Job {
            client: 3,
            round: 9,
            staleness: 2,
            w: vec![0.5, -1.25, f32::MIN_POSITIVE],
            xs: vec![1.0; 7],
            ys: vec![0.0, 1.0],
        });
        roundtrip(Msg::NoJob { done: true });
        roundtrip(Msg::NoJob { done: false });
        roundtrip(Msg::Submit {
            client: 3,
            round: 9,
            staleness: 2,
            loss: 1.5,
            weights: vec![2.0, -0.0, f32::NAN.copysign(1.0).min(1.0)],
        });
        roundtrip(Msg::Ack { round: 9 });
        roundtrip(Msg::Reject {
            code: RejectCode::Duplicate,
            round: 9,
        });
        roundtrip(Msg::Reject {
            code: RejectCode::OutOfRound,
            round: 10,
        });
        roundtrip(Msg::Busy);
        roundtrip(Msg::Bye);
    }

    #[test]
    fn f32_payloads_are_bit_exact() {
        // -0.0 and denormals must survive the wire untouched — the
        // loopback golden run compares final weights bit for bit.
        let weird = vec![-0.0f32, f32::MIN_POSITIVE / 2.0, 1.0e-42, -3.5];
        let frame = encode(&Msg::Submit {
            client: 0,
            round: 0,
            staleness: 0,
            loss: -0.0,
            weights: weird.clone(),
        });
        let mut slice = frame.as_slice();
        let FrameRead::Msg(Msg::Submit { weights, loss, .. }) = read_msg(&mut slice).unwrap()
        else {
            panic!("wrong message");
        };
        assert_eq!(loss.to_bits(), (-0.0f32).to_bits());
        for (a, b) in weird.iter().zip(&weights) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn corrupted_checksum_is_rejected() {
        let mut frame = encode(&Msg::Ack { round: 1 });
        let last = frame.len() - 1;
        frame[last] ^= 0xff;
        let err = match read_msg(&mut frame.as_slice()) {
            Err(e) => e,
            Ok(m) => panic!("corrupted frame accepted: {m:?}"),
        };
        assert!(err.to_string().contains("checksum"), "{err}");
    }

    #[test]
    fn corrupted_body_is_rejected() {
        let mut frame = encode(&Msg::Ack { round: 1 });
        frame[6] ^= 0x01; // flip a payload bit: checksum must catch it
        assert!(read_msg(&mut frame.as_slice()).is_err());
    }

    #[test]
    fn version_mismatch_is_rejected() {
        let mut frame = encode(&Msg::Bye);
        frame[4] = VERSION + 1; // version byte is first payload byte
        // Checksum still matches the tampered payload if we recompute it,
        // so recompute — version rejection must be its own check.
        let len = u32::from_le_bytes(frame[0..4].try_into().unwrap()) as usize;
        let csum = checksum(&frame[4..4 + len]);
        let n = frame.len();
        frame[n - 4..].copy_from_slice(&csum.to_le_bytes());
        let err = read_msg(&mut frame.as_slice()).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");
    }

    #[test]
    fn clean_eof_and_truncation_are_distinguished() {
        let empty: &[u8] = &[];
        assert!(matches!(
            read_msg(&mut { empty }).unwrap(),
            FrameRead::Eof
        ));

        let frame = encode(&Msg::Hello { token: 1, resume: 0 });
        let mut cut = &frame[..frame.len() - 2];
        assert!(read_msg(&mut cut).is_err(), "truncated frame accepted");
    }

    #[test]
    fn oversized_length_prefix_is_rejected() {
        let mut frame = vec![0xff, 0xff, 0xff, 0x7f]; // ~2 GiB claim
        frame.extend_from_slice(&[0u8; 16]);
        assert!(read_msg(&mut frame.as_slice()).is_err());

        // Just past the cap: rejected before any allocation.
        let mut frame = ((MAX_FRAME as u32 + 1).to_le_bytes()).to_vec();
        frame.extend_from_slice(&[0u8; 16]);
        let err = read_msg(&mut frame.as_slice()).unwrap_err();
        assert!(err.to_string().contains("bad frame length"), "{err}");
    }

    #[test]
    fn large_claim_under_the_cap_fails_on_the_bytes_not_the_claim() {
        // A corrupted-but-under-cap length with only a few real bytes
        // behind it must fail from the stream ending, not wedge or
        // eagerly allocate the full claim (the reader chunks its
        // buffer growth — nothing observable here beyond a clean error).
        let mut frame = ((MAX_FRAME as u32).to_le_bytes()).to_vec();
        frame.extend_from_slice(&[0u8; 256]);
        let err = read_msg(&mut frame.as_slice()).unwrap_err();
        assert!(err.to_string().contains("peer closed mid-"), "{err}");
    }
}
