//! `serve::server` — the threaded federation server: TCP sessions speak
//! [`super::proto`], a [`RoundManager`](super::round::RoundManager)
//! tracks the open aggregation period, and the *real*
//! [`Coordinator`]/[`AggregationPolicy`](super::super::AggregationPolicy)
//! stack drives the rounds — paota / air_fedga / every registered
//! periodic policy runs unmodified behind the wire.
//!
//! Division of labor per round:
//!
//! 1. the coordinator [`open_periodic_slot`](Coordinator::open_periodic_slot)s
//!    the slot exactly as the library loop would — same arrivals, same
//!    participant selection, same batch draws;
//! 2. the chosen clients' jobs are queued on the round manager and
//!    pulled by whatever wire sessions are connected (a session is a
//!    *transport*, not a scheduling identity — the virtual schedule
//!    stays the coordinator's);
//! 3. submissions come back over the wire, are classified
//!    (accept / duplicate / out-of-round / `Busy`), and at the close the
//!    accepted updates are re-sorted into dispatch order and folded in
//!    via [`complete_periodic_slot`](Coordinator::complete_periodic_slot).
//!
//! Two closing disciplines, selected by `serve.period_ms`:
//!
//! - **`0` — lockstep** (default): a round closes when every dispatched
//!   job has been accepted; the accepted buffer is drained eagerly so
//!   `queue_depth` never deadlocks the round. With a serial
//!   deterministic schedule this is *bitwise identical* to the library
//!   loop [`fl::run`](crate::fl::run) — the golden tie-down in
//!   `tests/serve.rs`.
//! - **`> 0` — wall-clock period**: the round closes at the deadline (or
//!   early once every job of the current round is in); the buffer is
//!   drained only at the close, so a contended buffer pushes explicit
//!   [`Busy`](super::proto::Msg::Busy) backpressure to the wire, and
//!   retried/slow submissions fold into a later round through the
//!   coordinator's existing staleness path.
//!
//! **Failure handling** (`[chaos]`, PR 9): every admitted session's
//! stream is wrapped in a [`ChaosStream`] (inert and free unless fault
//! rates are configured), each session tracks the jobs it has fetched
//! but not resolved, and those jobs are **reclaimed** — re-queued at
//! the front of the [`RoundManager`](super::round::RoundManager) with
//! their original dispatch position — when the session dies (teardown)
//! or goes silent past `chaos_session_deadline_ms` while chaos is
//! active. A reconnecting client announces its prior session id in
//! `Hello.resume`; since reclaimed work sits at the queue front, its
//! next fetch re-issues the half-done job. Because `local_train` is a
//! pure function of the job payload, a reclaimed-and-retrained job
//! yields a bit-identical update, which is why lockstep stays bitwise
//! equal to `fl::run` under chaos with recovery on.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context as _, Result};

use crate::config::{Algorithm, Config};
use crate::obs::admin::AdminServer;
use crate::obs::metrics::{Counter, Gauge, Registry};
use crate::obs::trace::{TraceSink, V};
use crate::runtime::TrainOut;
use crate::util::Rng;

use super::super::coordinator::{Coordinator, OpenSlot, RoundTiming};
use super::super::{build_policy, RunResult, TrainContext};
use super::chaos::{ChaosStream, FaultKind, FaultPlan, STREAM_CHAOS_SERVER};
use super::proto::{self, FrameRead, Msg, RejectCode};
use super::round::{Accepted, RoundManager, RoundStats, SubmitOutcome};

/// Poll interval for condvar waits and session read timeouts.
const TICK: Duration = Duration::from_millis(100);
/// Lockstep bails when no submission lands for this long.
const STALL_LIMIT: Duration = Duration::from_secs(60);

/// What a training job looks like on the dispatch queue: the staleness
/// metadata stamped at dispatch time plus the `(w0, xs, ys)` payload.
/// Clonable so the round manager can retain dispatched copies for
/// reclaim-on-session-death.
#[derive(Clone)]
struct JobWire {
    staleness: u64,
    w: Vec<f32>,
    xs: Vec<f32>,
    ys: Vec<f32>,
}

struct State {
    rm: RoundManager<JobWire, TrainOut>,
    /// Run over — sessions answer `FetchJob` with `NoJob { done: true }`.
    done: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Signaled on new queued jobs, accepted submissions, and shutdown.
    changed: Condvar,
}

/// Session-constant facts echoed in the `Assign` reply.
#[derive(Clone, Copy)]
struct SessionInfo {
    rounds: u64,
    dim: usize,
    lr: f32,
}

/// Wire-side observability handles on the server's **private** registry
/// — never the process-global one, so a scrape of this server matches
/// its own loadgen's tallies exactly even with concurrent serve runs in
/// one test process. Counters are bumped exactly where the matching
/// reply frame is written (both `Busy` sources — the aggregation buffer
/// and the session cap — land on the same counter, mirroring how the
/// loadgen tallies them).
struct WireObs {
    sessions_total: Counter,
    sessions_active: Gauge,
    rounds: Counter,
    dispatched: Counter,
    acks: Counter,
    duplicates: Counter,
    out_of_round: Counter,
    busy: Counter,
    late: Counter,
    queued: Gauge,
    buffered: Gauge,
    tx_bytes: Counter,
    reconnects: Counter,
    reclaimed: Counter,
    /// Injected-fault counters, [`FaultKind::ALL`]-ordered
    /// (`paota_faults_<kind>_total`).
    faults: [Counter; 5],
    trace: Option<TraceSink>,
}

impl WireObs {
    fn new(reg: &Registry, cfg: &Config) -> Self {
        let trace = match TraceSink::from_cfg(&cfg.obs) {
            Ok(t) => t,
            Err(e) => {
                crate::debug!("obs: trace journal disabled: {e:#}");
                None
            }
        };
        Self {
            sessions_total: reg.counter("paota_serve_sessions_total"),
            sessions_active: reg.gauge("paota_serve_sessions_active"),
            rounds: reg.counter("paota_serve_rounds_total"),
            dispatched: reg.counter("paota_serve_dispatched_total"),
            acks: reg.counter("paota_serve_acks_total"),
            duplicates: reg.counter("paota_serve_duplicates_total"),
            out_of_round: reg.counter("paota_serve_out_of_round_total"),
            busy: reg.counter("paota_serve_busy_total"),
            late: reg.counter("paota_serve_late_total"),
            queued: reg.gauge("paota_serve_queue_jobs"),
            buffered: reg.gauge("paota_serve_buffered_updates"),
            tx_bytes: reg.counter("paota_serve_tx_frame_bytes_total"),
            reconnects: reg.counter("paota_reconnects_total"),
            reclaimed: reg.counter("paota_jobs_reclaimed_total"),
            faults: FaultKind::ALL
                .map(|k| reg.counter(&format!("paota_faults_{}_total", k.name()))),
            trace,
        }
    }

    /// Record one injected fault: bump its per-kind counter and emit a
    /// `fault_injected` trace event.
    fn fault(&self, kind: FaultKind) {
        self.faults[kind.index()].inc();
        if let Some(tr) = &self.trace {
            tr.emit(
                "fault_injected",
                None,
                &[
                    ("kind", V::S(kind.name().into())),
                    ("side", V::S("server".into())),
                ],
            );
        }
    }
}

/// Write one frame, counting its bytes on the wire registry.
fn send<W: std::io::Write>(stream: &mut W, msg: &Msg, obs: &WireObs) -> Result<()> {
    let frame = proto::encode(msg);
    obs.tx_bytes.add(frame.len() as u64);
    stream.write_all(&frame).context("writing frame")?;
    Ok(())
}

/// [`send`] through the session's chaos wrapper, folding any faults the
/// wrapper injected (including on the error path) into metrics/trace.
fn send_faulted(stream: &mut ChaosStream<TcpStream>, msg: &Msg, obs: &WireObs) -> Result<()> {
    let r = send(stream, msg, obs);
    for kind in stream.take_events() {
        obs.fault(kind);
    }
    r
}

/// Result of a completed serve run.
pub struct ServeOutcome {
    /// The same record stream + final model `fl::run` would return.
    pub result: RunResult,
    /// Wire-side counters (dispatched/accepted/duplicate/out-of-round/busy/late).
    pub stats: RoundStats,
    /// Client sessions admitted over the run.
    pub sessions: usize,
    /// The server's private metrics registry: the wire counters a
    /// `/metrics` scrape exposes, still readable after the run.
    pub metrics: Arc<Registry>,
    /// The admin listener (when `obs_admin_bind` asked for one), kept
    /// alive with the outcome so post-run scrapes still answer; dropped
    /// with it.
    pub admin: Option<AdminServer>,
}

/// A bound (but not yet running) federation server.
pub struct Server<'a> {
    ctx: &'a TrainContext,
    cfg: &'a Config,
    listener: TcpListener,
    addr: SocketAddr,
    metrics: Arc<Registry>,
    admin: Option<AdminServer>,
}

impl<'a> Server<'a> {
    /// Bind the listener and validate that the configured algorithm can
    /// be served: serving drives the periodic (ΔT-slotted) schedule, so
    /// the policy must be [`RoundTiming::Periodic`] and the topology a
    /// single cell.
    pub fn bind(ctx: &'a TrainContext, cfg: &'a Config) -> Result<Server<'a>> {
        ensure!(
            cfg.topology.cells == 1,
            "serve drives a single cell; topology.cells = {} (run one server per cell)",
            cfg.topology.cells
        );
        // Probe the policy's timing up front so `repro serve` fails at
        // startup, not at round 0.
        let probe = build_policy(ctx, cfg)?;
        if probe.timing() != RoundTiming::Periodic {
            bail!(
                "--algo {} uses {:?} timing; serve supports the periodic \
                 (time-slotted) schedule — paota, ca_paota, air_fedga",
                cfg.algorithm.name(),
                probe.timing()
            );
        }
        let listener = TcpListener::bind(&cfg.serve.bind)
            .with_context(|| format!("binding serve.bind = {}", cfg.serve.bind))?;
        let addr = listener.local_addr()?;
        // Wire metrics live on a private registry so this server's
        // scrape is exactly attributable to it; the admin listener
        // merges it with the process-global registry.
        let metrics = Arc::new(Registry::new());
        let admin = if cfg.obs.admin_bind.is_empty() {
            None
        } else {
            Some(AdminServer::start(&cfg.obs.admin_bind, vec![metrics.clone()])?)
        };
        Ok(Server {
            ctx,
            cfg,
            listener,
            addr,
            metrics,
            admin,
        })
    }

    /// The bound address (resolves `:0` port requests — tests bind
    /// `127.0.0.1:0` and hand the real address to their clients).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The admin (scrape) listener's address, when `obs_admin_bind`
    /// requested one.
    pub fn admin_addr(&self) -> Option<SocketAddr> {
        self.admin.as_ref().map(|a| a.local_addr())
    }

    /// Serve the full run: accept sessions, dispatch jobs, close rounds,
    /// and return the run result once all `cfg.rounds` slots completed.
    ///
    /// With PJRT artifacts this must run on the thread that built `ctx`
    /// (the executables are thread-bound); the native backend runs
    /// anywhere. Session threads never touch `ctx` — they only move
    /// bytes.
    pub fn run(self) -> Result<ServeOutcome> {
        let Server {
            ctx,
            cfg,
            listener,
            addr,
            metrics,
            admin,
        } = self;
        let obs = WireObs::new(&metrics, cfg);
        let mut policy = build_policy(ctx, cfg)?;
        let mut coord = Coordinator::new(ctx, cfg, policy.batch_stream());
        coord.begin_periodic();

        let shared = Shared {
            state: Mutex::new(State {
                rm: RoundManager::new(cfg.serve.queue_depth),
                done: false,
            }),
            changed: Condvar::new(),
        };
        let stop = AtomicBool::new(false);
        let active = AtomicUsize::new(0);
        let admitted = AtomicUsize::new(0);
        let info = SessionInfo {
            rounds: cfg.rounds as u64,
            dim: ctx.dim(),
            lr: cfg.lr,
        };
        let max_sessions = cfg.serve.max_sessions;
        let period = Duration::from_millis(cfg.serve.period_ms);
        let plan = FaultPlan::from_cfg(&cfg.chaos);
        // Silent-session reclaim only arms alongside fault injection —
        // on a healthy wire, teardown reclaim alone covers dead peers
        // and a slow-but-alive trainer is never robbed of its job.
        let reclaim_after = (!plan.is_inert())
            .then(|| Duration::from_millis(cfg.chaos.session_deadline_ms));
        let seed = cfg.seed;

        let mut outcome: Result<()> = Ok(());
        std::thread::scope(|s| {
            let shared = &shared;
            let stop = &stop;
            let active = &active;
            let admitted = &admitted;
            let obs = &obs;
            s.spawn(move || {
                accept_loop(
                    s,
                    listener,
                    shared,
                    stop,
                    active,
                    admitted,
                    info,
                    max_sessions,
                    obs,
                    plan,
                    seed,
                    reclaim_after,
                );
            });

            outcome = drive_rounds(&mut coord, policy.as_mut(), cfg, shared, period, obs);

            // Shutdown: flag the run done (sessions answer NoJob{done}),
            // wake everyone, and poke the accept loop with a throwaway
            // connection so it observes the stop flag.
            {
                let mut st = shared.state.lock().unwrap();
                st.done = true;
            }
            stop.store(true, Ordering::SeqCst);
            shared.changed.notify_all();
            let _ = TcpStream::connect(addr);
        });
        outcome?;

        let stats = shared.state.into_inner().unwrap().rm.stats();
        Ok(ServeOutcome {
            result: coord.into_result(Algorithm::raw(policy.name())),
            stats,
            sessions: admitted.load(Ordering::SeqCst),
            metrics,
            admin,
        })
    }
}

/// Build the context and serve at `cfg.serve.bind` — the `repro serve`
/// entry point.
pub fn serve(cfg: &Config) -> Result<ServeOutcome> {
    let ctx = TrainContext::new(cfg)?;
    Server::bind(&ctx, cfg)?.run()
}

/// The per-round open → collect → complete loop (see module docs).
fn drive_rounds(
    coord: &mut Coordinator,
    policy: &mut dyn super::super::AggregationPolicy,
    cfg: &Config,
    shared: &Shared,
    period: Duration,
    obs: &WireObs,
) -> Result<()> {
    for round in 0..cfg.rounds {
        let OpenSlot { chosen, jobs, .. } = coord.open_periodic_slot(policy, round);
        let wire_jobs: Vec<(usize, JobWire)> = chosen
            .iter()
            .zip(jobs)
            .map(|(&client, (w, xs, ys))| {
                // Dispatch-time staleness metadata: rounds since this
                // client last took a base model. The authoritative value
                // for aggregation is recomputed at the close.
                let staleness = round.saturating_sub(coord.client_base_round(client)) as u64;
                (
                    client,
                    JobWire {
                        staleness,
                        w,
                        xs,
                        ys,
                    },
                )
            })
            .collect();
        {
            let mut st = shared.state.lock().unwrap();
            st.rm.open_round(round, wire_jobs);
            obs.queued.set(st.rm.queued() as i64);
            obs.buffered.set(st.rm.buffered() as i64);
        }
        shared.changed.notify_all();

        let mut collected: Vec<Accepted<TrainOut>> = Vec::new();
        if period.is_zero() {
            collect_lockstep(shared, round, &mut collected)?;
        } else {
            collect_period(shared, round, period, &mut collected);
        }

        // Rebuild the coordinator's dispatch order: earlier-round
        // (late) submissions first, then this round's participants in
        // the order they were chosen.
        collected.sort_by_key(|a| (a.round, a.pos));
        let submissions: Vec<(usize, TrainOut)> = collected
            .into_iter()
            .map(|a| (a.client, a.payload))
            .collect();
        coord.complete_periodic_slot(policy, round, submissions)?;
        obs.rounds.inc();
    }
    Ok(())
}

/// Lockstep close: wait until every job dispatched for `round` is
/// accepted, draining the buffer eagerly so `queue_depth` can never
/// wedge the round.
fn collect_lockstep(
    shared: &Shared,
    round: usize,
    collected: &mut Vec<Accepted<TrainOut>>,
) -> Result<()> {
    let mut last_progress = Instant::now();
    let mut st = shared.state.lock().unwrap();
    loop {
        let drained = st.rm.take_accepted();
        if !drained.is_empty() {
            last_progress = Instant::now();
            collected.extend(drained);
        }
        if st.rm.round_done(round) {
            return Ok(());
        }
        ensure!(
            last_progress.elapsed() < STALL_LIMIT,
            "serve stalled: lockstep round {round} saw no submission for \
             {}s — are any client sessions connected?",
            STALL_LIMIT.as_secs()
        );
        let (guard, _) = shared.changed.wait_timeout(st, TICK).unwrap();
        st = guard;
    }
}

/// Wall-clock close: hold the round open until the deadline (or until
/// every job of the current round is in), draining the buffer only at
/// the close — a full buffer meanwhile surfaces as `Busy` on the wire.
fn collect_period(
    shared: &Shared,
    round: usize,
    period: Duration,
    collected: &mut Vec<Accepted<TrainOut>>,
) {
    let deadline = Instant::now() + period;
    let mut st = shared.state.lock().unwrap();
    loop {
        if st.rm.round_done(round) {
            break;
        }
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        let wait = (deadline - now).min(TICK);
        let (guard, _) = shared.changed.wait_timeout(st, wait).unwrap();
        st = guard;
    }
    collected.extend(st.rm.take_accepted());
}

/// Accept sessions until the stop flag is raised; each admitted session
/// gets its own thread inside the same scope.
#[allow(clippy::too_many_arguments)]
fn accept_loop<'scope, 'env>(
    scope: &'scope std::thread::Scope<'scope, 'env>,
    listener: TcpListener,
    shared: &'scope Shared,
    stop: &'scope AtomicBool,
    active: &'scope AtomicUsize,
    admitted: &'scope AtomicUsize,
    info: SessionInfo,
    max_sessions: usize,
    obs: &'scope WireObs,
    plan: FaultPlan,
    seed: u64,
    reclaim_after: Option<Duration>,
) {
    loop {
        let stream = match listener.accept() {
            Ok((stream, _)) => stream,
            Err(_) => {
                if stop.load(Ordering::SeqCst) {
                    return;
                }
                continue;
            }
        };
        if stop.load(Ordering::SeqCst) {
            return;
        }
        if active.load(Ordering::SeqCst) >= max_sessions {
            // Session-table backpressure: same explicit Busy the
            // aggregation buffer uses — the client backs off and
            // retries. Counted on the same busy counter, so the scrape
            // matches the loadgen's tally of absorbed Busy replies.
            obs.busy.inc();
            if let Some(tr) = &obs.trace {
                tr.emit("wire_busy", None, &[("reason", V::S("session_cap".into()))]);
            }
            let mut stream = stream;
            let _ = send(&mut stream, &Msg::Busy, obs);
            continue;
        }
        active.fetch_add(1, Ordering::SeqCst);
        // The admission counter doubles as the chaos entity id: every
        // accepted connection — including one session's reconnects —
        // draws a fresh, deterministic fault schedule.
        let entity = admitted.fetch_add(1, Ordering::SeqCst) as u64;
        obs.sessions_total.inc();
        obs.sessions_active.add(1);
        scope.spawn(move || {
            let rng = Rng::for_entity(seed, STREAM_CHAOS_SERVER, entity);
            let stream = ChaosStream::new(stream, plan, rng);
            let mut held: Vec<(usize, usize)> = Vec::new();
            // A misbehaving peer only kills its own session.
            let _ = session(stream, shared, stop, info, obs, reclaim_after, &mut held);
            // Teardown reclaim: whatever this session fetched but never
            // resolved goes back to the queue for another session.
            reclaim_held(shared, obs, &mut held, "teardown");
            active.fetch_sub(1, Ordering::SeqCst);
            obs.sessions_active.add(-1);
        });
    }
}

/// Re-queue every job in `held` (fetched by a session that died or went
/// silent), bumping the reclaim counter and tracing each job. Wakes the
/// round loop and fetchers when anything was actually taken back.
fn reclaim_held(shared: &Shared, obs: &WireObs, held: &mut Vec<(usize, usize)>, why: &str) {
    if held.is_empty() {
        return;
    }
    let mut taken: Vec<(usize, usize)> = Vec::new();
    {
        let mut st = shared.state.lock().unwrap();
        for (client, round) in held.drain(..) {
            if st.rm.reclaim(client, round) {
                taken.push((client, round));
            }
        }
        obs.queued.set(st.rm.queued() as i64);
    }
    if taken.is_empty() {
        return;
    }
    obs.reclaimed.add(taken.len() as u64);
    if let Some(tr) = &obs.trace {
        for (client, round) in &taken {
            tr.emit(
                "wire_reclaim",
                None,
                &[
                    ("client", V::U(*client as u64)),
                    ("round", V::U(*round as u64)),
                    ("why", V::S(why.into())),
                ],
            );
        }
    }
    shared.changed.notify_all();
}

/// One client session: handshake, then serve FetchJob/Submit until the
/// peer leaves or the server stops. `held` tracks the jobs this session
/// fetched but has not resolved — the caller reclaims whatever is left
/// when the session ends, and with `reclaim_after` set, a session that
/// goes silent past the deadline has its jobs taken back in place.
fn session(
    mut stream: ChaosStream<TcpStream>,
    shared: &Shared,
    stop: &AtomicBool,
    info: SessionInfo,
    obs: &WireObs,
    reclaim_after: Option<Duration>,
    held: &mut Vec<(usize, usize)>,
) -> Result<()> {
    stream
        .get_ref()
        .set_read_timeout(Some(TICK))
        .context("set_read_timeout")?;
    stream.get_ref().set_nodelay(true).ok();

    // Handshake: Hello → Assign. Idle ticks before the Hello just poll
    // the stop flag.
    let session_id = loop {
        match proto::read_msg(&mut stream)? {
            FrameRead::Msg(Msg::Hello { token, resume }) => {
                if resume != 0 {
                    // A returning client: its dead predecessor's jobs
                    // were reclaimed to the queue front, so this
                    // session's next fetch resumes the half-done work.
                    obs.reconnects.inc();
                    if let Some(tr) = &obs.trace {
                        tr.emit(
                            "wire_reconnect",
                            None,
                            &[("session", V::U(token)), ("resume", V::U(resume))],
                        );
                    }
                }
                break token;
            }
            FrameRead::Msg(other) => bail!("expected Hello, got {other:?}"),
            FrameRead::Eof => return Ok(()),
            FrameRead::IdleTimeout => {
                if stop.load(Ordering::SeqCst) {
                    return Ok(());
                }
            }
        }
    };
    send_faulted(
        &mut stream,
        &Msg::Assign {
            session: session_id,
            rounds: info.rounds,
            dim: info.dim as u64,
            lr: info.lr,
        },
        obs,
    )?;

    let mut last_activity = Instant::now();
    loop {
        let msg = match proto::read_msg(&mut stream)? {
            FrameRead::Msg(m) => {
                last_activity = Instant::now();
                m
            }
            FrameRead::Eof => return Ok(()),
            FrameRead::IdleTimeout => {
                if stop.load(Ordering::SeqCst) {
                    return Ok(());
                }
                // Deadline reclaim: a connected-but-silent session
                // (e.g. its reply was dropped and it is mid-backoff)
                // must not pin its jobs past the recovery deadline.
                if let Some(after) = reclaim_after {
                    if !held.is_empty() && last_activity.elapsed() >= after {
                        reclaim_held(shared, obs, held, "deadline");
                    }
                }
                continue;
            }
        };
        match msg {
            Msg::FetchJob => {
                let reply = fetch_reply(shared, obs, held);
                send_faulted(&mut stream, &reply, obs)?;
            }
            Msg::Submit {
                client,
                round,
                loss,
                weights,
                ..
            } => {
                ensure!(
                    weights.len() == info.dim,
                    "submit payload has {} weights, model dim is {}",
                    weights.len(),
                    info.dim
                );
                let outcome = {
                    let mut st = shared.state.lock().unwrap();
                    let o = st
                        .rm
                        .submit(client as usize, round as usize, TrainOut { weights, loss });
                    obs.buffered.set(st.rm.buffered() as i64);
                    o
                };
                if matches!(outcome, SubmitOutcome::Accepted { .. }) {
                    // Wake the round loop (and fetchers waiting on the
                    // next round's jobs).
                    shared.changed.notify_all();
                }
                // Terminal outcomes release the held slot; Busy keeps
                // the job outstanding for the client's retry.
                if !matches!(outcome, SubmitOutcome::Busy) {
                    held.retain(|&(c, r)| !(c == client as usize && r == round as usize));
                }
                // Counters track the reply actually written, so a
                // scrape equals the peer's view of the conversation.
                let reply = match outcome {
                    SubmitOutcome::Accepted { late } => {
                        obs.acks.inc();
                        if late {
                            obs.late.inc();
                        }
                        if let Some(tr) = &obs.trace {
                            tr.emit(
                                "wire_accept",
                                None,
                                &[
                                    ("client", V::U(client)),
                                    ("round", V::U(round)),
                                    ("late", V::U(u64::from(late))),
                                ],
                            );
                        }
                        Msg::Ack { round }
                    }
                    SubmitOutcome::Duplicate => {
                        obs.duplicates.inc();
                        if let Some(tr) = &obs.trace {
                            tr.emit(
                                "wire_reject",
                                None,
                                &[
                                    ("client", V::U(client)),
                                    ("round", V::U(round)),
                                    ("code", V::S("duplicate".into())),
                                ],
                            );
                        }
                        Msg::Reject {
                            code: RejectCode::Duplicate,
                            round,
                        }
                    }
                    SubmitOutcome::OutOfRound => {
                        obs.out_of_round.inc();
                        if let Some(tr) = &obs.trace {
                            tr.emit(
                                "wire_reject",
                                None,
                                &[
                                    ("client", V::U(client)),
                                    ("round", V::U(round)),
                                    ("code", V::S("out_of_round".into())),
                                ],
                            );
                        }
                        Msg::Reject {
                            code: RejectCode::OutOfRound,
                            round,
                        }
                    }
                    SubmitOutcome::Busy => {
                        obs.busy.inc();
                        if let Some(tr) = &obs.trace {
                            tr.emit(
                                "wire_busy",
                                None,
                                &[
                                    ("client", V::U(client)),
                                    ("round", V::U(round)),
                                    ("reason", V::S("buffer".into())),
                                ],
                            );
                        }
                        Msg::Busy
                    }
                };
                send_faulted(&mut stream, &reply, obs)?;
            }
            Msg::Bye => return Ok(()),
            other => bail!("unexpected message in session: {other:?}"),
        }
    }
}

/// Answer one `FetchJob`: hand out a queued job if there is (or shortly
/// arrives) one, else report whether the run is over. A dispatched job
/// is recorded in `held` so the session's unresolved work can be
/// reclaimed if it dies.
fn fetch_reply(shared: &Shared, obs: &WireObs, held: &mut Vec<(usize, usize)>) -> Msg {
    let mut st = shared.state.lock().unwrap();
    loop {
        if let Some((client, round, job)) = st.rm.fetch() {
            obs.dispatched.inc();
            obs.queued.set(st.rm.queued() as i64);
            held.push((client, round));
            return Msg::Job {
                client: client as u64,
                round: round as u64,
                staleness: job.staleness,
                w: job.w,
                xs: job.xs,
                ys: job.ys,
            };
        }
        if st.done {
            return Msg::NoJob { done: true };
        }
        let (guard, timeout) = shared.changed.wait_timeout(st, TICK).unwrap();
        st = guard;
        if timeout.timed_out() {
            // One more look under the reacquired lock, then let the
            // client re-poll so the session stays responsive.
            if let Some((client, round, job)) = st.rm.fetch() {
                obs.dispatched.inc();
                obs.queued.set(st.rm.queued() as i64);
                held.push((client, round));
                return Msg::Job {
                    client: client as u64,
                    round: round as u64,
                    staleness: job.staleness,
                    w: job.w,
                    xs: job.xs,
                    ys: job.ys,
                };
            }
            return Msg::NoJob { done: st.done };
        }
    }
}
