//! `serve::server` — the threaded federation server: TCP sessions speak
//! [`super::proto`], a [`RoundManager`](super::round::RoundManager)
//! tracks the open aggregation period, and the *real*
//! [`Coordinator`]/[`AggregationPolicy`](super::super::AggregationPolicy)
//! stack drives the rounds — paota / air_fedga / every registered
//! periodic policy runs unmodified behind the wire.
//!
//! Division of labor per round:
//!
//! 1. the coordinator [`open_periodic_slot`](Coordinator::open_periodic_slot)s
//!    the slot exactly as the library loop would — same arrivals, same
//!    participant selection, same batch draws;
//! 2. the chosen clients' jobs are queued on the round manager and
//!    pulled by whatever wire sessions are connected (a session is a
//!    *transport*, not a scheduling identity — the virtual schedule
//!    stays the coordinator's);
//! 3. submissions come back over the wire, are classified
//!    (accept / duplicate / out-of-round / `Busy`), and at the close the
//!    accepted updates are re-sorted into dispatch order and folded in
//!    via [`complete_periodic_slot`](Coordinator::complete_periodic_slot).
//!
//! Two closing disciplines, selected by `serve.period_ms`:
//!
//! - **`0` — lockstep** (default): a round closes when every dispatched
//!   job has been accepted; the accepted buffer is drained eagerly so
//!   `queue_depth` never deadlocks the round. With a serial
//!   deterministic schedule this is *bitwise identical* to the library
//!   loop [`fl::run`](crate::fl::run) — the golden tie-down in
//!   `tests/serve.rs`.
//! - **`> 0` — wall-clock period**: the round closes at the deadline (or
//!   early once every job of the current round is in); the buffer is
//!   drained only at the close, so a contended buffer pushes explicit
//!   [`Busy`](super::proto::Msg::Busy) backpressure to the wire, and
//!   retried/slow submissions fold into a later round through the
//!   coordinator's existing staleness path.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context as _, Result};

use crate::config::{Algorithm, Config};
use crate::runtime::TrainOut;

use super::super::coordinator::{Coordinator, OpenSlot, RoundTiming};
use super::super::{build_policy, RunResult, TrainContext};
use super::proto::{self, FrameRead, Msg, RejectCode};
use super::round::{Accepted, RoundManager, RoundStats, SubmitOutcome};

/// Poll interval for condvar waits and session read timeouts.
const TICK: Duration = Duration::from_millis(100);
/// Lockstep bails when no submission lands for this long.
const STALL_LIMIT: Duration = Duration::from_secs(60);

/// What a training job looks like on the dispatch queue: the staleness
/// metadata stamped at dispatch time plus the `(w0, xs, ys)` payload.
struct JobWire {
    staleness: u64,
    w: Vec<f32>,
    xs: Vec<f32>,
    ys: Vec<f32>,
}

struct State {
    rm: RoundManager<JobWire, TrainOut>,
    /// Run over — sessions answer `FetchJob` with `NoJob { done: true }`.
    done: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Signaled on new queued jobs, accepted submissions, and shutdown.
    changed: Condvar,
}

/// Session-constant facts echoed in the `Assign` reply.
#[derive(Clone, Copy)]
struct SessionInfo {
    rounds: u64,
    dim: usize,
    lr: f32,
}

/// Result of a completed serve run.
pub struct ServeOutcome {
    /// The same record stream + final model `fl::run` would return.
    pub result: RunResult,
    /// Wire-side counters (dispatched/accepted/duplicate/out-of-round/busy/late).
    pub stats: RoundStats,
    /// Client sessions admitted over the run.
    pub sessions: usize,
}

/// A bound (but not yet running) federation server.
pub struct Server<'a> {
    ctx: &'a TrainContext,
    cfg: &'a Config,
    listener: TcpListener,
    addr: SocketAddr,
}

impl<'a> Server<'a> {
    /// Bind the listener and validate that the configured algorithm can
    /// be served: serving drives the periodic (ΔT-slotted) schedule, so
    /// the policy must be [`RoundTiming::Periodic`] and the topology a
    /// single cell.
    pub fn bind(ctx: &'a TrainContext, cfg: &'a Config) -> Result<Server<'a>> {
        ensure!(
            cfg.topology.cells == 1,
            "serve drives a single cell; topology.cells = {} (run one server per cell)",
            cfg.topology.cells
        );
        // Probe the policy's timing up front so `repro serve` fails at
        // startup, not at round 0.
        let probe = build_policy(ctx, cfg)?;
        if probe.timing() != RoundTiming::Periodic {
            bail!(
                "--algo {} uses {:?} timing; serve supports the periodic \
                 (time-slotted) schedule — paota, ca_paota, air_fedga",
                cfg.algorithm.name(),
                probe.timing()
            );
        }
        let listener = TcpListener::bind(&cfg.serve.bind)
            .with_context(|| format!("binding serve.bind = {}", cfg.serve.bind))?;
        let addr = listener.local_addr()?;
        Ok(Server {
            ctx,
            cfg,
            listener,
            addr,
        })
    }

    /// The bound address (resolves `:0` port requests — tests bind
    /// `127.0.0.1:0` and hand the real address to their clients).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Serve the full run: accept sessions, dispatch jobs, close rounds,
    /// and return the run result once all `cfg.rounds` slots completed.
    ///
    /// With PJRT artifacts this must run on the thread that built `ctx`
    /// (the executables are thread-bound); the native backend runs
    /// anywhere. Session threads never touch `ctx` — they only move
    /// bytes.
    pub fn run(self) -> Result<ServeOutcome> {
        let Server {
            ctx,
            cfg,
            listener,
            addr,
        } = self;
        let mut policy = build_policy(ctx, cfg)?;
        let mut coord = Coordinator::new(ctx, cfg, policy.batch_stream());
        coord.begin_periodic();

        let shared = Shared {
            state: Mutex::new(State {
                rm: RoundManager::new(cfg.serve.queue_depth),
                done: false,
            }),
            changed: Condvar::new(),
        };
        let stop = AtomicBool::new(false);
        let active = AtomicUsize::new(0);
        let admitted = AtomicUsize::new(0);
        let info = SessionInfo {
            rounds: cfg.rounds as u64,
            dim: ctx.dim(),
            lr: cfg.lr,
        };
        let max_sessions = cfg.serve.max_sessions;
        let period = Duration::from_millis(cfg.serve.period_ms);

        let mut outcome: Result<()> = Ok(());
        std::thread::scope(|s| {
            let shared = &shared;
            let stop = &stop;
            let active = &active;
            let admitted = &admitted;
            s.spawn(move || {
                accept_loop(s, listener, shared, stop, active, admitted, info, max_sessions);
            });

            outcome = drive_rounds(&mut coord, policy.as_mut(), cfg, shared, period);

            // Shutdown: flag the run done (sessions answer NoJob{done}),
            // wake everyone, and poke the accept loop with a throwaway
            // connection so it observes the stop flag.
            {
                let mut st = shared.state.lock().unwrap();
                st.done = true;
            }
            stop.store(true, Ordering::SeqCst);
            shared.changed.notify_all();
            let _ = TcpStream::connect(addr);
        });
        outcome?;

        let stats = shared.state.into_inner().unwrap().rm.stats();
        Ok(ServeOutcome {
            result: coord.into_result(Algorithm::raw(policy.name())),
            stats,
            sessions: admitted.load(Ordering::SeqCst),
        })
    }
}

/// Build the context and serve at `cfg.serve.bind` — the `repro serve`
/// entry point.
pub fn serve(cfg: &Config) -> Result<ServeOutcome> {
    let ctx = TrainContext::new(cfg)?;
    Server::bind(&ctx, cfg)?.run()
}

/// The per-round open → collect → complete loop (see module docs).
fn drive_rounds(
    coord: &mut Coordinator,
    policy: &mut dyn super::super::AggregationPolicy,
    cfg: &Config,
    shared: &Shared,
    period: Duration,
) -> Result<()> {
    for round in 0..cfg.rounds {
        let OpenSlot { chosen, jobs, .. } = coord.open_periodic_slot(policy, round);
        let wire_jobs: Vec<(usize, JobWire)> = chosen
            .iter()
            .zip(jobs)
            .map(|(&client, (w, xs, ys))| {
                // Dispatch-time staleness metadata: rounds since this
                // client last took a base model. The authoritative value
                // for aggregation is recomputed at the close.
                let staleness = round.saturating_sub(coord.client_base_round(client)) as u64;
                (
                    client,
                    JobWire {
                        staleness,
                        w,
                        xs,
                        ys,
                    },
                )
            })
            .collect();
        {
            let mut st = shared.state.lock().unwrap();
            st.rm.open_round(round, wire_jobs);
        }
        shared.changed.notify_all();

        let mut collected: Vec<Accepted<TrainOut>> = Vec::new();
        if period.is_zero() {
            collect_lockstep(shared, round, &mut collected)?;
        } else {
            collect_period(shared, round, period, &mut collected);
        }

        // Rebuild the coordinator's dispatch order: earlier-round
        // (late) submissions first, then this round's participants in
        // the order they were chosen.
        collected.sort_by_key(|a| (a.round, a.pos));
        let submissions: Vec<(usize, TrainOut)> = collected
            .into_iter()
            .map(|a| (a.client, a.payload))
            .collect();
        coord.complete_periodic_slot(policy, round, submissions)?;
    }
    Ok(())
}

/// Lockstep close: wait until every job dispatched for `round` is
/// accepted, draining the buffer eagerly so `queue_depth` can never
/// wedge the round.
fn collect_lockstep(
    shared: &Shared,
    round: usize,
    collected: &mut Vec<Accepted<TrainOut>>,
) -> Result<()> {
    let mut last_progress = Instant::now();
    let mut st = shared.state.lock().unwrap();
    loop {
        let drained = st.rm.take_accepted();
        if !drained.is_empty() {
            last_progress = Instant::now();
            collected.extend(drained);
        }
        if st.rm.round_done(round) {
            return Ok(());
        }
        ensure!(
            last_progress.elapsed() < STALL_LIMIT,
            "serve stalled: lockstep round {round} saw no submission for \
             {}s — are any client sessions connected?",
            STALL_LIMIT.as_secs()
        );
        let (guard, _) = shared.changed.wait_timeout(st, TICK).unwrap();
        st = guard;
    }
}

/// Wall-clock close: hold the round open until the deadline (or until
/// every job of the current round is in), draining the buffer only at
/// the close — a full buffer meanwhile surfaces as `Busy` on the wire.
fn collect_period(
    shared: &Shared,
    round: usize,
    period: Duration,
    collected: &mut Vec<Accepted<TrainOut>>,
) {
    let deadline = Instant::now() + period;
    let mut st = shared.state.lock().unwrap();
    loop {
        if st.rm.round_done(round) {
            break;
        }
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        let wait = (deadline - now).min(TICK);
        let (guard, _) = shared.changed.wait_timeout(st, wait).unwrap();
        st = guard;
    }
    collected.extend(st.rm.take_accepted());
}

/// Accept sessions until the stop flag is raised; each admitted session
/// gets its own thread inside the same scope.
#[allow(clippy::too_many_arguments)]
fn accept_loop<'scope, 'env>(
    scope: &'scope std::thread::Scope<'scope, 'env>,
    listener: TcpListener,
    shared: &'scope Shared,
    stop: &'scope AtomicBool,
    active: &'scope AtomicUsize,
    admitted: &'scope AtomicUsize,
    info: SessionInfo,
    max_sessions: usize,
) {
    loop {
        let stream = match listener.accept() {
            Ok((stream, _)) => stream,
            Err(_) => {
                if stop.load(Ordering::SeqCst) {
                    return;
                }
                continue;
            }
        };
        if stop.load(Ordering::SeqCst) {
            return;
        }
        if active.load(Ordering::SeqCst) >= max_sessions {
            // Session-table backpressure: same explicit Busy the
            // aggregation buffer uses — the client backs off and retries.
            let mut stream = stream;
            let _ = proto::write_msg(&mut stream, &Msg::Busy);
            continue;
        }
        active.fetch_add(1, Ordering::SeqCst);
        admitted.fetch_add(1, Ordering::SeqCst);
        scope.spawn(move || {
            // A misbehaving peer only kills its own session.
            let _ = session(stream, shared, stop, info);
            active.fetch_sub(1, Ordering::SeqCst);
        });
    }
}

/// One client session: handshake, then serve FetchJob/Submit until the
/// peer leaves or the server stops.
fn session(
    mut stream: TcpStream,
    shared: &Shared,
    stop: &AtomicBool,
    info: SessionInfo,
) -> Result<()> {
    stream
        .set_read_timeout(Some(TICK))
        .context("set_read_timeout")?;
    stream.set_nodelay(true).ok();

    // Handshake: Hello → Assign. Idle ticks before the Hello just poll
    // the stop flag.
    let session_id = loop {
        match proto::read_msg(&mut stream)? {
            FrameRead::Msg(Msg::Hello { token }) => break token,
            FrameRead::Msg(other) => bail!("expected Hello, got {other:?}"),
            FrameRead::Eof => return Ok(()),
            FrameRead::IdleTimeout => {
                if stop.load(Ordering::SeqCst) {
                    return Ok(());
                }
            }
        }
    };
    proto::write_msg(
        &mut stream,
        &Msg::Assign {
            session: session_id,
            rounds: info.rounds,
            dim: info.dim as u64,
            lr: info.lr,
        },
    )?;

    loop {
        let msg = match proto::read_msg(&mut stream)? {
            FrameRead::Msg(m) => m,
            FrameRead::Eof => return Ok(()),
            FrameRead::IdleTimeout => {
                if stop.load(Ordering::SeqCst) {
                    return Ok(());
                }
                continue;
            }
        };
        match msg {
            Msg::FetchJob => {
                let reply = fetch_reply(shared);
                proto::write_msg(&mut stream, &reply)?;
            }
            Msg::Submit {
                client,
                round,
                loss,
                weights,
                ..
            } => {
                ensure!(
                    weights.len() == info.dim,
                    "submit payload has {} weights, model dim is {}",
                    weights.len(),
                    info.dim
                );
                let outcome = {
                    let mut st = shared.state.lock().unwrap();
                    st.rm
                        .submit(client as usize, round as usize, TrainOut { weights, loss })
                };
                if matches!(outcome, SubmitOutcome::Accepted { .. }) {
                    // Wake the round loop (and fetchers waiting on the
                    // next round's jobs).
                    shared.changed.notify_all();
                }
                let reply = match outcome {
                    SubmitOutcome::Accepted { .. } => Msg::Ack { round },
                    SubmitOutcome::Duplicate => Msg::Reject {
                        code: RejectCode::Duplicate,
                        round,
                    },
                    SubmitOutcome::OutOfRound => Msg::Reject {
                        code: RejectCode::OutOfRound,
                        round,
                    },
                    SubmitOutcome::Busy => Msg::Busy,
                };
                proto::write_msg(&mut stream, &reply)?;
            }
            Msg::Bye => return Ok(()),
            other => bail!("unexpected message in session: {other:?}"),
        }
    }
}

/// Answer one `FetchJob`: hand out a queued job if there is (or shortly
/// arrives) one, else report whether the run is over.
fn fetch_reply(shared: &Shared) -> Msg {
    let mut st = shared.state.lock().unwrap();
    loop {
        if let Some((client, round, job)) = st.rm.fetch() {
            return Msg::Job {
                client: client as u64,
                round: round as u64,
                staleness: job.staleness,
                w: job.w,
                xs: job.xs,
                ys: job.ys,
            };
        }
        if st.done {
            return Msg::NoJob { done: true };
        }
        let (guard, timeout) = shared.changed.wait_timeout(st, TICK).unwrap();
        st = guard;
        if timeout.timed_out() {
            // One more look under the reacquired lock, then let the
            // client re-poll so the session stays responsive.
            if let Some((client, round, job)) = st.rm.fetch() {
                return Msg::Job {
                    client: client as u64,
                    round: round as u64,
                    staleness: job.staleness,
                    w: job.w,
                    xs: job.xs,
                    ys: job.ys,
                };
            }
            return Msg::NoJob { done: st.done };
        }
    }
}
