//! `fl::serve` — the wire-level federation service: real client
//! sessions over TCP, driving the *same* [`Coordinator`]/
//! [`AggregationPolicy`] stack as the in-process library loop.
//!
//! Layers (each its own submodule, each independently testable):
//!
//! - [`proto`] — the compact length-prefixed frame format and message
//!   vocabulary (hello/assign, fetch-job, submit-update with round id +
//!   staleness metadata, ack/reject/busy), version byte + FNV-1a
//!   checksum on every frame;
//! - [`round`] — the transport-free [`RoundManager`](round::RoundManager)
//!   (XAIN `Round` idiom) classifying submissions: duplicate-update
//!   rejection, out-of-round rejection, late routing into the staleness
//!   path, bounded-buffer `Busy` backpressure;
//! - [`server`] — `repro serve`: the threaded TCP server mapping round
//!   manager traffic onto
//!   [`open_periodic_slot`](Coordinator::open_periodic_slot) /
//!   [`complete_periodic_slot`](Coordinator::complete_periodic_slot),
//!   so paota/ca_paota/air_fedga run unmodified behind the wire;
//! - [`loadgen`] — `repro loadgen`: a seed-deterministic concurrent
//!   session fleet reporting requests/sec, submit-latency percentiles
//!   and reject/busy counts (`make bench-serve` → `BENCH_serve.json`);
//! - [`chaos`] — deterministic fault injection: a
//!   [`ChaosStream`](chaos::ChaosStream) wraps both ends' TCP streams
//!   and, driven by its own [`Rng::for_entity`](crate::util::Rng)
//!   stream, drops/delays/truncates/corrupts frames and kills
//!   connections at the `[chaos]`-configured rates;
//! - [`retry`] — the shared jittered-exponential
//!   [`Backoff`](retry::Backoff) schedule behind every client retry
//!   path (Busy backpressure, session-cap redials, reconnects).
//!
//! **Observability** ([`crate::obs`]): the server owns a *private*
//! metrics registry — session/ack/reject/busy counters, queue-depth
//! gauges, frame bytes — merged with the process-global registry on the
//! `obs_admin_bind` scrape listener (`/metrics`, `/metrics.json`,
//! `/healthz`). Counters are bumped exactly where reply frames are
//! written, so a scrape agrees with the loadgen's own tallies; with
//! `obs_trace_path` set, server and loadgen append wire events to the
//! shared JSONL journal. All of it is read-only: the golden tie-down
//! below holds bitwise with observability enabled (`tests/serve.rs`).
//!
//! **Golden tie-down** (`tests/serve.rs`): with `serve.period_ms = 0`
//! the server closes each round only when every dispatched job has been
//! submitted, and the run is bitwise identical — final weights and
//! record stream — to [`fl::run`](crate::fl::run) on the same config.
//! The wire moves raw LE f32 bits, the round manager reassembles
//! submissions into dispatch order, and local training is a pure
//! function of `(w, xs, ys, lr)`, so determinism survives arbitrary
//! session interleaving.
//!
//! **Chaos tie-down** (PR 9, `tests/serve.rs`): the same bitwise
//! identity holds with fault injection *and* recovery on — faults live
//! only on the wire, reclaimed jobs re-dispatch with their original
//! `(pos, staleness, payload)`, and retraining is pure, so every
//! recovered loss reproduces the identical update. And when losses are
//! unrecoverable (recovery off), period-mode rounds still close on the
//! deadline with whoever arrived: chaos degrades throughput, never
//! liveness.
//!
//! [`Coordinator`]: super::Coordinator
//! [`AggregationPolicy`]: super::AggregationPolicy

pub mod chaos;
pub mod loadgen;
pub mod proto;
pub mod retry;
pub mod round;
pub mod server;

pub use chaos::{ChaosStream, FaultKind, FaultPlan};
pub use loadgen::{run_loadgen, LoadgenReport};
pub use proto::{Msg, RejectCode};
pub use retry::Backoff;
pub use round::{RoundManager, RoundStats, SubmitOutcome};
pub use server::{serve, Server, ServeOutcome};
