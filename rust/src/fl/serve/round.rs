//! `serve::round` — per-cell round bookkeeping in the XAIN `Round`
//! idiom: one [`RoundManager`] tracks the open aggregation period — who
//! has been handed work, who has submitted, what is buffered for
//! aggregation — and classifies every incoming submission as accepted
//! (possibly *late*), duplicate, out-of-round, or `Busy` backpressure.
//!
//! The manager is transport-free and generic over the dispatched job
//! payload `J` and the submitted result payload `S`, so its semantics
//! are unit-testable without sockets:
//!
//! - **duplicate-update rejection** — a `(client, round)` pair that
//!   already has an accepted update is refused ([`SubmitOutcome::Duplicate`]);
//! - **out-of-round rejection** — a round id that was never dispatched
//!   to that client (future rounds included) is refused
//!   ([`SubmitOutcome::OutOfRound`]);
//! - **late routing** — a valid submission for an *earlier* round than
//!   the currently open one is accepted and flagged `late: true`; the
//!   server folds it into the next aggregation close, where the
//!   coordinator's existing staleness path weights it down (PAOTA's
//!   Eq. 11) instead of dropping it;
//! - **bounded-queue backpressure** — when the aggregation buffer
//!   already holds `queue_depth` undrained updates, the submission is
//!   refused with [`SubmitOutcome::Busy`] and the job stays
//!   outstanding, so the client can retry after a pause;
//! - **reclaim on session death** — a job dispatched to a session that
//!   dies (or stalls past its deadline) before submitting is handed
//!   back via [`RoundManager::reclaim`]: it returns to the *front* of
//!   the queue with its original `pos`, so the next fetch re-issues it
//!   and the `(round, pos)` sort still rebuilds the deterministic
//!   participant order. Without this, lockstep mode would wait forever
//!   on work held by a dead connection.

use std::collections::{HashMap, HashSet, VecDeque};

/// Classification of one submit-update attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitOutcome {
    /// Buffered for aggregation; `late` means the round had already
    /// moved on when the update arrived.
    Accepted { late: bool },
    /// This `(client, round)` already has an accepted update.
    Duplicate,
    /// Round id not open for this client (never dispatched, or future).
    OutOfRound,
    /// Aggregation buffer full — retry later; the job stays open.
    Busy,
}

/// One update sitting in the aggregation buffer.
#[derive(Debug)]
pub struct Accepted<S> {
    pub client: usize,
    /// Round the job was dispatched for (not the round it lands in).
    pub round: usize,
    /// Dispatch position within that round — lets the server rebuild
    /// the coordinator's deterministic participant order.
    pub pos: usize,
    pub payload: S,
}

/// Monotonic counters over the manager's lifetime.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct RoundStats {
    pub dispatched: usize,
    pub accepted: usize,
    pub duplicates: usize,
    pub out_of_round: usize,
    pub busy: usize,
    /// Subset of `accepted` that arrived after their round closed.
    pub late: usize,
    /// Jobs taken back from dead/stalled sessions and re-queued
    /// (each re-dispatch also counts in `dispatched`).
    pub reclaimed: usize,
}

struct QueuedJob<J> {
    client: usize,
    round: usize,
    pos: usize,
    job: J,
}

/// A dispatched-but-not-accepted job. The payload is retained so the
/// manager can re-queue it if the holding session dies.
struct OutstandingJob<J> {
    pos: usize,
    job: J,
}

/// Tracks the open aggregation period for one cell (see module docs).
pub struct RoundManager<J, S> {
    queue_depth: usize,
    current: usize,
    /// Jobs not yet handed to a session, FIFO across rounds — leftover
    /// work from earlier rounds dispatches first and simply lands late.
    fifo: VecDeque<QueuedJob<J>>,
    /// Dispatched-but-not-accepted `(client, round) →` retained job.
    outstanding: HashMap<(usize, usize), OutstandingJob<J>>,
    /// `(client, round)` pairs with an accepted update.
    submitted: HashSet<(usize, usize)>,
    /// Unaccepted job count per round (queued + outstanding).
    open: HashMap<usize, usize>,
    accepted: Vec<Accepted<S>>,
    stats: RoundStats,
}

impl<J: Clone, S> RoundManager<J, S> {
    pub fn new(queue_depth: usize) -> Self {
        assert!(queue_depth >= 1, "queue_depth must be at least 1");
        Self {
            queue_depth,
            current: 0,
            fifo: VecDeque::new(),
            outstanding: HashMap::new(),
            submitted: HashSet::new(),
            open: HashMap::new(),
            accepted: Vec::new(),
            stats: RoundStats::default(),
        }
    }

    /// Open aggregation period `round`, queueing its jobs in the
    /// coordinator's participant order.
    pub fn open_round(&mut self, round: usize, jobs: Vec<(usize, J)>) {
        debug_assert!(round >= self.current, "rounds must open in order");
        self.current = round;
        *self.open.entry(round).or_insert(0) += jobs.len();
        for (pos, (client, job)) in jobs.into_iter().enumerate() {
            debug_assert!(
                !self.outstanding.contains_key(&(client, round))
                    && !self.submitted.contains(&(client, round)),
                "client {client} dispatched twice for round {round}"
            );
            self.fifo.push_back(QueuedJob {
                client,
                round,
                pos,
                job,
            });
        }
    }

    /// Currently open round id.
    pub fn current_round(&self) -> usize {
        self.current
    }

    /// Hand out the next queued job, marking it outstanding. A clone of
    /// the payload is retained so [`RoundManager::reclaim`] can re-queue
    /// it if the session holding it dies.
    pub fn fetch(&mut self) -> Option<(usize, usize, J)> {
        let q = self.fifo.pop_front()?;
        self.outstanding.insert(
            (q.client, q.round),
            OutstandingJob {
                pos: q.pos,
                job: q.job.clone(),
            },
        );
        self.stats.dispatched += 1;
        Some((q.client, q.round, q.job))
    }

    /// Take back a dispatched job whose session died or stalled before
    /// submitting. The job returns to the *front* of the queue with its
    /// original `pos` (resume priority; the `(round, pos)` sort is
    /// unaffected). Returns `false` if `(client, round)` is not
    /// outstanding — already submitted, already reclaimed, or never
    /// dispatched — so callers may reclaim defensively.
    pub fn reclaim(&mut self, client: usize, round: usize) -> bool {
        let Some(o) = self.outstanding.remove(&(client, round)) else {
            return false;
        };
        self.stats.reclaimed += 1;
        self.fifo.push_front(QueuedJob {
            client,
            round,
            pos: o.pos,
            job: o.job,
        });
        true
    }

    /// Classify and (when valid and there is room) buffer one update.
    pub fn submit(&mut self, client: usize, round: usize, payload: S) -> SubmitOutcome {
        let key = (client, round);
        if self.submitted.contains(&key) {
            self.stats.duplicates += 1;
            return SubmitOutcome::Duplicate;
        }
        if round > self.current || !self.outstanding.contains_key(&key) {
            self.stats.out_of_round += 1;
            return SubmitOutcome::OutOfRound;
        }
        if self.accepted.len() >= self.queue_depth {
            // Buffer contended: refuse *without* consuming the job so a
            // retry after the next drain can succeed.
            self.stats.busy += 1;
            return SubmitOutcome::Busy;
        }
        let pos = self.outstanding.remove(&key).expect("checked above").pos;
        self.submitted.insert(key);
        if let Some(n) = self.open.get_mut(&round) {
            *n -= 1;
            if *n == 0 {
                self.open.remove(&round);
            }
        }
        let late = round < self.current;
        if late {
            self.stats.late += 1;
        }
        self.stats.accepted += 1;
        self.accepted.push(Accepted {
            client,
            round,
            pos,
            payload,
        });
        SubmitOutcome::Accepted { late }
    }

    /// True once every job dispatched for `round` has been accepted.
    pub fn round_done(&self, round: usize) -> bool {
        !self.open.contains_key(&round)
    }

    /// Jobs still queued for pickup.
    pub fn queued(&self) -> usize {
        self.fifo.len()
    }

    /// Updates currently sitting in the aggregation buffer.
    pub fn buffered(&self) -> usize {
        self.accepted.len()
    }

    /// Drain the aggregation buffer (caller sorts by `(round, pos)` to
    /// rebuild the deterministic participant order).
    pub fn take_accepted(&mut self) -> Vec<Accepted<S>> {
        std::mem::take(&mut self.accepted)
    }

    pub fn stats(&self) -> RoundStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manager(depth: usize) -> RoundManager<&'static str, f32> {
        RoundManager::new(depth)
    }

    /// Fetch every queued job so clients may submit.
    fn drain_fifo(rm: &mut RoundManager<&'static str, f32>) {
        while rm.fetch().is_some() {}
    }

    #[test]
    fn duplicate_update_is_rejected() {
        let mut rm = manager(8);
        rm.open_round(0, vec![(3, "job")]);
        drain_fifo(&mut rm);
        assert_eq!(rm.submit(3, 0, 1.0), SubmitOutcome::Accepted { late: false });
        assert_eq!(rm.submit(3, 0, 2.0), SubmitOutcome::Duplicate);
        assert_eq!(rm.stats().duplicates, 1);
        // The buffer holds exactly the first copy.
        let got = rm.take_accepted();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].payload, 1.0);
    }

    #[test]
    fn out_of_round_covers_future_and_undispatched() {
        let mut rm = manager(8);
        rm.open_round(0, vec![(1, "job")]);
        drain_fifo(&mut rm);
        // Future round.
        assert_eq!(rm.submit(1, 5, 1.0), SubmitOutcome::OutOfRound);
        // Client that was never handed this round's job.
        assert_eq!(rm.submit(2, 0, 1.0), SubmitOutcome::OutOfRound);
        assert_eq!(rm.stats().out_of_round, 2);
        assert_eq!(rm.submit(1, 0, 1.0), SubmitOutcome::Accepted { late: false });
    }

    #[test]
    fn busy_under_full_queue_then_retry_succeeds() {
        let mut rm = manager(1);
        rm.open_round(0, vec![(0, "a"), (1, "b")]);
        drain_fifo(&mut rm);
        assert_eq!(rm.submit(0, 0, 1.0), SubmitOutcome::Accepted { late: false });
        // Buffer (depth 1) is full → explicit backpressure, job stays open.
        assert_eq!(rm.submit(1, 0, 2.0), SubmitOutcome::Busy);
        assert_eq!(rm.stats().busy, 1);
        assert!(!rm.round_done(0));
        // After a drain the retry lands.
        assert_eq!(rm.take_accepted().len(), 1);
        assert_eq!(rm.submit(1, 0, 2.0), SubmitOutcome::Accepted { late: false });
        assert!(rm.round_done(0));
    }

    #[test]
    fn late_submission_is_accepted_and_flagged() {
        let mut rm = manager(8);
        rm.open_round(0, vec![(0, "a"), (1, "b")]);
        drain_fifo(&mut rm);
        assert_eq!(rm.submit(0, 0, 1.0), SubmitOutcome::Accepted { late: false });
        // Round moves on while client 1 is still training.
        rm.open_round(1, vec![(2, "c")]);
        assert_eq!(rm.submit(1, 0, 2.0), SubmitOutcome::Accepted { late: true });
        assert_eq!(rm.stats().late, 1);
        assert!(rm.round_done(0));
    }

    #[test]
    fn round_done_tracks_queued_and_outstanding() {
        let mut rm = manager(8);
        assert!(rm.round_done(0)); // nothing dispatched yet
        rm.open_round(0, vec![(0, "a"), (1, "b")]);
        assert!(!rm.round_done(0)); // still queued
        drain_fifo(&mut rm);
        assert!(!rm.round_done(0)); // outstanding
        rm.submit(0, 0, 1.0);
        assert!(!rm.round_done(0));
        rm.submit(1, 0, 2.0);
        assert!(rm.round_done(0));
    }

    #[test]
    fn dispatch_positions_rebuild_participant_order() {
        let mut rm = manager(8);
        rm.open_round(0, vec![(7, "a"), (3, "b"), (9, "c")]);
        drain_fifo(&mut rm);
        // Submissions arrive out of order…
        rm.submit(9, 0, 3.0);
        rm.submit(7, 0, 1.0);
        rm.submit(3, 0, 2.0);
        let mut got = rm.take_accepted();
        got.sort_by_key(|a| (a.round, a.pos));
        // …but (round, pos) restores dispatch order 7, 3, 9.
        let clients: Vec<usize> = got.iter().map(|a| a.client).collect();
        assert_eq!(clients, vec![7, 3, 9]);
    }

    #[test]
    fn reclaim_requeues_at_front_with_original_pos() {
        let mut rm = manager(8);
        rm.open_round(0, vec![(7, "a"), (3, "b")]);
        drain_fifo(&mut rm);
        assert!(!rm.round_done(0));
        // Session holding client 7's job dies before submitting.
        assert!(rm.reclaim(7, 0));
        assert_eq!(rm.stats().reclaimed, 1);
        assert_eq!(rm.queued(), 1);
        assert!(!rm.round_done(0), "reclaimed work keeps the round open");
        // The re-fetch hands back the same job…
        let (c, r, j) = rm.fetch().unwrap();
        assert_eq!((c, r, j), (7, 0, "a"));
        assert_eq!(rm.stats().dispatched, 3, "re-dispatch counts again");
        // …and its submission lands at the original dispatch position.
        rm.submit(3, 0, 2.0);
        rm.submit(7, 0, 1.0);
        assert!(rm.round_done(0));
        let mut got = rm.take_accepted();
        got.sort_by_key(|a| (a.round, a.pos));
        let clients: Vec<usize> = got.iter().map(|a| a.client).collect();
        assert_eq!(clients, vec![7, 3]);
    }

    #[test]
    fn reclaim_is_a_noop_for_unknown_or_submitted_jobs() {
        let mut rm = manager(8);
        rm.open_round(0, vec![(0, "a")]);
        // Never dispatched: nothing outstanding to take back.
        assert!(!rm.reclaim(0, 0));
        drain_fifo(&mut rm);
        rm.submit(0, 0, 1.0);
        // Already accepted: reclaim after submit must not resurrect it.
        assert!(!rm.reclaim(0, 0));
        assert_eq!(rm.stats().reclaimed, 0);
        assert_eq!(rm.queued(), 0);
        assert!(rm.round_done(0));
    }

    #[test]
    fn reclaimed_job_beats_newer_queued_work() {
        let mut rm = manager(8);
        rm.open_round(0, vec![(0, "old")]);
        drain_fifo(&mut rm);
        rm.open_round(1, vec![(1, "new")]);
        assert!(rm.reclaim(0, 0));
        // Front-of-queue priority: the reclaimed round-0 job re-issues
        // before round 1's fresh work.
        let (c, r, _) = rm.fetch().unwrap();
        assert_eq!((c, r), (0, 0));
        let (c, r, _) = rm.fetch().unwrap();
        assert_eq!((c, r), (1, 1));
    }

    #[test]
    fn fifo_hands_out_older_rounds_first() {
        let mut rm = manager(8);
        rm.open_round(0, vec![(0, "old")]);
        rm.open_round(1, vec![(1, "new")]);
        let (c, r, j) = rm.fetch().unwrap();
        assert_eq!((c, r, j), (0, 0, "old"));
        let (c, r, j) = rm.fetch().unwrap();
        assert_eq!((c, r, j), (1, 1, "new"));
        assert!(rm.fetch().is_none());
        assert_eq!(rm.stats().dispatched, 2);
    }
}
