//! `serve::loadgen` — the wire-level load generator behind
//! `repro loadgen`: replays a seed-deterministic fleet as concurrent
//! client sessions against a running [`super::server`] and reports
//! throughput, submit-latency percentiles, and reject/duplicate/busy
//! counts (`make bench-serve` writes them to `BENCH_serve.json`).
//!
//! The *schedule* being replayed lives server-side — the coordinator's
//! virtual mobility/latency model decides which client trains when; a
//! loadgen session is a dumb worker that pulls whatever job is next,
//! trains it on its own native runtime, and submits. What the loadgen
//! adds client-side is seed-deterministic *think time*: with
//! `serve.pace_ms > 0`, each session sleeps a draw from the configured
//! `[latency]` model (its own [`Rng::for_entity`] stream, so the pattern
//! is reproducible across runs) scaled by `pace_ms` between jobs —
//! turning the configured fleet-latency distribution into wall-clock
//! arrival jitter.
//!
//! Every submit is retried through [`Msg::Busy`] backpressure until a
//! terminal reply (ack or reject) lands, so `lost` — jobs with no
//! terminal outcome — must come out 0 on a healthy server.
//!
//! **Failure handling** (`[chaos]`, PR 9): each connection is wrapped
//! in a [`ChaosStream`] so the client faces the same injected adversary
//! as the server. With fault rates configured, a session arms a read
//! timeout and abandons any exchange that sees no reply within
//! `chaos_session_deadline_ms`. A trained-but-unacknowledged update is
//! kept as the session's *pending* job across connection failures; with
//! `chaos_recovery = true` the session reconnects under the shared
//! jittered backoff ([`super::retry::Backoff`]), announces its prior
//! session id in `Hello.resume`, and resubmits — so every injected loss
//! is recovered and `lost` stays 0. With recovery off a failed session
//! ends quietly (`gave_up`), its losses surface in the report, and the
//! server's deadline-reclaim keeps the rounds closing without it.

use std::net::TcpStream;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context as _, Result};

use crate::config::Config;
use crate::obs::hist;
use crate::obs::trace::{TraceSink, V};
use crate::runtime::ModelRuntime;
use crate::util::Rng;

use super::chaos::{ChaosStream, FaultPlan, STREAM_CHAOS_CLIENT};
use super::proto::{self, FrameRead, Msg, RejectCode};
use super::retry::Backoff;

/// Loadgen RNG stream tag (per-session think-time draws).
const STREAM_LOADGEN: u64 = 0x10ad;

/// Read-poll interval when chaos arms a client-side read timeout
/// (matches the server's tick).
const TICK: Duration = Duration::from_millis(100);

/// Aggregated wire metrics for one loadgen run.
#[derive(Debug, Clone)]
pub struct LoadgenReport {
    /// Concurrent sessions replayed.
    pub sessions: usize,
    /// Training jobs pulled and executed.
    pub jobs: usize,
    /// Submits accepted into an aggregation buffer.
    pub acks: usize,
    pub duplicates: usize,
    pub out_of_round: usize,
    /// `Busy` replies absorbed (submit retries + session-cap rejects).
    pub busy: usize,
    /// Jobs that never reached a terminal ack/reject — 0 on a healthy
    /// run, and still 0 under chaos when recovery is on.
    pub lost: usize,
    /// Reconnect-and-resume cycles across all sessions.
    pub reconnects: usize,
    /// Backoff pauses taken (Busy retries + session-cap redials).
    pub retries: usize,
    /// Faults the client-side chaos wrapper injected.
    pub faults: usize,
    /// Sessions that exhausted recovery (or had it off) and ended early.
    pub gave_up: usize,
    pub wall_secs: f64,
    /// All request frames sent (hello + fetch + submit attempts) per second.
    pub requests_per_sec: f64,
    /// Submit latency: first submit frame sent → terminal reply read,
    /// including any Busy retry and reconnect cycles in between.
    pub submit_p50_ms: f64,
    pub submit_p90_ms: f64,
    pub submit_p99_ms: f64,
}

#[derive(Default)]
struct Tally {
    jobs: usize,
    acks: usize,
    duplicates: usize,
    out_of_round: usize,
    busy: usize,
    requests: usize,
    reconnects: usize,
    retries: usize,
    faults: usize,
    gave_up: usize,
    latencies_ms: Vec<f64>,
}

impl Tally {
    /// Jobs with a terminal outcome (ack or reject).
    fn resolved(&self) -> usize {
        self.acks + self.duplicates + self.out_of_round
    }
}

/// Run `cfg.serve.sessions` concurrent client sessions against the
/// server at `addr` until it reports the run done, then aggregate the
/// wire metrics. Requires the native backend (every session owns a
/// runtime on its own thread; PJRT executables are thread-bound).
pub fn run_loadgen(cfg: &Config, addr: &str) -> Result<LoadgenReport> {
    ensure!(
        crate::runtime::is_native_dir(&cfg.artifacts_dir),
        "loadgen requires artifacts_dir = native (each session thread owns \
         its own runtime)"
    );
    let sessions = cfg.serve.sessions.max(1);
    // Optional journal: every job's terminal submit latency goes out as
    // a `wire_submit` event carrying the *same* f64 the percentile
    // report uses, so (at `obs_sample_every = 1`) `repro trace
    // summarize` reproduces the report's percentiles exactly.
    let trace = match TraceSink::from_cfg(&cfg.obs) {
        Ok(t) => t,
        Err(e) => {
            crate::debug!("obs: trace journal disabled: {e:#}");
            None
        }
    };
    let start = Instant::now();
    let mut tallies: Vec<Tally> = Vec::with_capacity(sessions);
    std::thread::scope(|s| -> Result<()> {
        let mut handles = Vec::with_capacity(sessions);
        for idx in 0..sessions {
            let trace = trace.as_ref();
            handles.push(s.spawn(move || client_session(cfg, addr, idx, trace)));
        }
        for h in handles {
            tallies.push(
                h.join()
                    .map_err(|_| anyhow!("loadgen session panicked"))??,
            );
        }
        Ok(())
    })?;
    let wall_secs = start.elapsed().as_secs_f64();

    let mut total = Tally::default();
    for t in tallies {
        total.jobs += t.jobs;
        total.acks += t.acks;
        total.duplicates += t.duplicates;
        total.out_of_round += t.out_of_round;
        total.busy += t.busy;
        total.requests += t.requests;
        total.reconnects += t.reconnects;
        total.retries += t.retries;
        total.faults += t.faults;
        total.gave_up += t.gave_up;
        total.latencies_ms.extend(t.latencies_ms);
    }
    total
        .latencies_ms
        .sort_by(|a, b| a.partial_cmp(b).unwrap());
    let lost = total.jobs.saturating_sub(total.resolved());
    Ok(LoadgenReport {
        sessions,
        jobs: total.jobs,
        acks: total.acks,
        duplicates: total.duplicates,
        out_of_round: total.out_of_round,
        busy: total.busy,
        lost,
        reconnects: total.reconnects,
        retries: total.retries,
        faults: total.faults,
        gave_up: total.gave_up,
        wall_secs,
        requests_per_sec: total.requests as f64 / wall_secs.max(1e-9),
        // Shared nearest-rank helpers (`obs::hist`) — the same math
        // `repro trace summarize` replays a journal through.
        submit_p50_ms: hist::percentile(&total.latencies_ms, 50.0),
        submit_p90_ms: hist::percentile(&total.latencies_ms, 90.0),
        submit_p99_ms: hist::percentile(&total.latencies_ms, 99.0),
    })
}

/// A trained update awaiting its terminal reply. Survives connection
/// failures: the session resubmits it first on every reconnect, so an
/// injected loss between train and ack never loses the work.
struct Pending {
    client: u64,
    round: u64,
    staleness: u64,
    loss: f32,
    weights: Vec<f32>,
    t0: Instant,
}

/// One loadgen session's full state across connect/reconnect cycles.
struct Session<'a> {
    cfg: &'a Config,
    addr: &'a str,
    idx: usize,
    rt: ModelRuntime,
    latency: crate::sim::LatencyModel,
    pace_rng: Rng,
    trace: Option<&'a TraceSink>,
    plan: FaultPlan,
    /// Armed only when chaos is active: how long to wait on a reply
    /// before abandoning the exchange (and the connection).
    reply_deadline: Option<Duration>,
    backoff: Backoff,
    /// Connections dialed so far; combined with `idx` into the chaos
    /// entity id so each reconnect draws a fresh fault schedule.
    conn_seq: u64,
    /// Prior established session id (0 = fresh), sent in `Hello.resume`.
    resume: u64,
    lr: f32,
    pending: Option<Pending>,
    tally: Tally,
}

impl Session<'_> {
    /// Write one frame through the chaos wrapper, folding injected
    /// faults (including on the error path) into the tally/trace.
    fn send(&mut self, stream: &mut ChaosStream<TcpStream>, msg: &Msg) -> Result<()> {
        let r = proto::write_msg(stream, msg);
        for kind in stream.take_events() {
            self.tally.faults += 1;
            if let Some(tr) = self.trace {
                tr.emit(
                    "fault_injected",
                    None,
                    &[
                        ("kind", V::S(kind.name().into())),
                        ("side", V::S("client".into())),
                        ("session", V::U(self.idx as u64)),
                    ],
                );
            }
        }
        r.context("writing frame")?;
        Ok(())
    }

    /// Read one message; with chaos active, gives up after
    /// `reply_deadline` of silence so a dropped reply can't hang the
    /// session (the caller reconnects).
    fn read_reply(&mut self, stream: &mut ChaosStream<TcpStream>) -> Result<Msg> {
        let start = Instant::now();
        loop {
            match proto::read_msg(stream)? {
                FrameRead::Msg(m) => return Ok(m),
                FrameRead::Eof => bail!("server closed the session"),
                FrameRead::IdleTimeout => {
                    if let Some(deadline) = self.reply_deadline {
                        ensure!(
                            start.elapsed() < deadline,
                            "no reply within {} ms — abandoning the connection",
                            deadline.as_millis()
                        );
                    }
                }
            }
        }
    }

    /// Record one backoff pause (`Busy` retries, session-cap redials)
    /// and sleep it.
    fn retry_pause(&mut self, reason: &str) {
        self.tally.retries += 1;
        let delay = self.backoff.next_delay();
        if let Some(tr) = self.trace {
            tr.emit(
                "wire_retry",
                None,
                &[
                    ("session", V::U(self.idx as u64)),
                    ("reason", V::S(reason.into())),
                    ("attempt", V::U(u64::from(self.backoff.attempt()))),
                    ("backoff_ms", V::U(delay.as_millis() as u64)),
                ],
            );
        }
        std::thread::sleep(delay);
    }

    /// Connect + handshake, backing off through session-cap `Busy`
    /// replies and startup connection refusals. Each dial gets a unique
    /// session id (`idx`/`conn_seq`-derived) and announces the prior
    /// one in `Hello.resume` when this is a reconnect.
    fn connect(&mut self) -> Result<ChaosStream<TcpStream>> {
        // Chaos shortens the dial patience: a reconnect race against a
        // finished server should fail fast into the give-up path, not
        // pin the fleet for the healthy-path 30 s.
        let patience = match self.reply_deadline {
            Some(d) => (d * 2).max(Duration::from_millis(500)),
            None => Duration::from_secs(30),
        };
        let deadline = Instant::now() + patience;
        loop {
            let raw = loop {
                match TcpStream::connect(self.addr) {
                    Ok(s) => break s,
                    Err(e) => {
                        if Instant::now() >= deadline {
                            return Err(e)
                                .with_context(|| format!("connecting to {}", self.addr));
                        }
                        std::thread::sleep(Duration::from_millis(20));
                    }
                }
            };
            raw.set_nodelay(true).ok();
            if self.reply_deadline.is_some() {
                raw.set_read_timeout(Some(TICK)).context("set_read_timeout")?;
            }
            self.conn_seq += 1;
            let session_id = ((self.idx as u64) << 24) | self.conn_seq;
            let rng = Rng::for_entity(self.cfg.seed, STREAM_CHAOS_CLIENT, session_id);
            let mut stream = ChaosStream::new(raw, self.plan, rng);
            self.tally.requests += 1;
            self.send(
                &mut stream,
                &Msg::Hello {
                    token: session_id,
                    resume: self.resume,
                },
            )?;
            match self.read_reply(&mut stream)? {
                Msg::Assign { lr, .. } => {
                    self.lr = lr;
                    // A future reconnect resumes from this session.
                    self.resume = session_id;
                    return Ok(stream);
                }
                Msg::Busy => {
                    // Session table full — back off and re-dial.
                    self.tally.busy += 1;
                    ensure!(
                        Instant::now() < deadline,
                        "session {}: server stayed at its session cap for {:?}",
                        self.idx,
                        patience
                    );
                    self.retry_pause("session_cap");
                }
                other => bail!("expected Assign, got {other:?}"),
            }
        }
    }

    /// Drive one connection until the run is done (`Ok`) or the
    /// connection fails (`Err` — the caller decides whether to
    /// reconnect). The pending update, if any, is resubmitted first.
    fn run_connection(&mut self) -> Result<()> {
        let mut stream = self.connect()?;
        loop {
            if self.pending.is_some() {
                self.submit_pending(&mut stream)?;
                continue;
            }
            self.tally.requests += 1;
            self.send(&mut stream, &Msg::FetchJob)?;
            match self.read_reply(&mut stream)? {
                Msg::Job {
                    client,
                    round,
                    staleness,
                    w,
                    xs,
                    ys,
                } => {
                    self.tally.jobs += 1;
                    let out = self.rt.local_train(&w, &xs, &ys, self.lr)?;
                    if self.cfg.serve.pace_ms > 0 {
                        // Think time: the configured fleet-latency model,
                        // scaled to wall-clock by pace_ms.
                        let think =
                            self.latency.draw(&mut self.pace_rng) * self.cfg.serve.pace_ms as f64;
                        std::thread::sleep(Duration::from_millis(think.max(0.0) as u64));
                    }
                    self.pending = Some(Pending {
                        client,
                        round,
                        staleness,
                        loss: out.loss,
                        weights: out.weights,
                        t0: Instant::now(),
                    });
                }
                Msg::NoJob { done: true } => {
                    let _ = self.send(&mut stream, &Msg::Bye);
                    return Ok(());
                }
                Msg::NoJob { done: false } => {
                    std::thread::sleep(Duration::from_millis(2));
                }
                other => bail!("unexpected fetch reply: {other:?}"),
            }
        }
    }

    /// Push the pending update to a terminal reply, retrying through
    /// `Busy`. On success the pending slot is cleared; on a connection
    /// error it is kept for the next connection to resubmit.
    fn submit_pending(&mut self, stream: &mut ChaosStream<TcpStream>) -> Result<()> {
        loop {
            let msg = {
                let p = self.pending.as_ref().expect("submit_pending without a job");
                Msg::Submit {
                    client: p.client,
                    round: p.round,
                    staleness: p.staleness,
                    loss: p.loss,
                    weights: p.weights.clone(),
                }
            };
            self.tally.requests += 1;
            self.send(stream, &msg)?;
            match self.read_reply(stream)? {
                Msg::Ack { .. } => {
                    self.tally.acks += 1;
                    self.backoff.reset();
                }
                Msg::Reject {
                    code: RejectCode::Duplicate,
                    ..
                } => {
                    // A resubmit racing its own recovered copy — the
                    // update is in; terminal.
                    self.tally.duplicates += 1;
                }
                Msg::Reject {
                    code: RejectCode::OutOfRound,
                    ..
                } => {
                    // The server reclaimed this job past our deadline
                    // and will re-dispatch it; terminal for us.
                    self.tally.out_of_round += 1;
                }
                Msg::Busy => {
                    // Aggregation buffer contended: keep the job and
                    // retry after a jittered pause.
                    self.tally.busy += 1;
                    self.retry_pause("busy");
                    continue;
                }
                other => bail!("unexpected submit reply: {other:?}"),
            }
            let p = self.pending.take().expect("pending vanished");
            let ms = p.t0.elapsed().as_secs_f64() * 1000.0;
            self.tally.latencies_ms.push(ms);
            if let Some(tr) = self.trace {
                // Same f64 as the percentile sample above — shortest
                // round-trip formatting makes the journal replay
                // bitwise exact.
                tr.emit(
                    "wire_submit",
                    None,
                    &[
                        ("session", V::U(self.idx as u64)),
                        ("client", V::U(p.client)),
                        ("round", V::U(p.round)),
                        ("ms", V::F(ms)),
                    ],
                );
            }
            return Ok(());
        }
    }
}

/// One session: pull jobs, train them on an own native runtime, submit
/// through backpressure until the server reports the run done —
/// reconnecting and resuming through connection failures when recovery
/// is on.
fn client_session(
    cfg: &Config,
    addr: &str,
    idx: usize,
    trace: Option<&TraceSink>,
) -> Result<Tally> {
    let plan = FaultPlan::from_cfg(&cfg.chaos);
    let mut s = Session {
        cfg,
        addr,
        idx,
        rt: ModelRuntime::native_for(cfg)?,
        latency: cfg.latency(),
        pace_rng: Rng::for_entity(cfg.seed, STREAM_LOADGEN, idx as u64),
        trace,
        plan,
        reply_deadline: (!plan.is_inert())
            .then(|| Duration::from_millis(cfg.chaos.session_deadline_ms)),
        backoff: Backoff::from_cfg(&cfg.chaos, cfg.seed, idx as u64),
        conn_seq: 0,
        resume: 0,
        lr: cfg.lr,
        pending: None,
        tally: Tally::default(),
    };
    // Consecutive no-progress connection failures; any terminal outcome
    // in between resets the count (and the backoff escalation).
    let mut failures = 0usize;
    loop {
        let resolved_before = s.tally.resolved();
        match s.run_connection() {
            Ok(()) => return Ok(s.tally),
            Err(e) => {
                if s.tally.resolved() > resolved_before {
                    failures = 0;
                    s.backoff.reset();
                }
                failures += 1;
                if !s.cfg.chaos.recovery {
                    crate::debug!(
                        "loadgen session {idx}: {e:#} (recovery off — ending the \
                         session; losses surface in the report)"
                    );
                    s.tally.gave_up += 1;
                    return Ok(s.tally);
                }
                if failures > s.cfg.chaos.max_retries {
                    crate::debug!(
                        "loadgen session {idx}: giving up after {failures} \
                         consecutive failures: {e:#}"
                    );
                    s.tally.gave_up += 1;
                    return Ok(s.tally);
                }
                s.tally.reconnects += 1;
                if let Some(tr) = s.trace {
                    tr.emit(
                        "wire_reconnect",
                        None,
                        &[
                            ("session", V::U(idx as u64)),
                            ("attempt", V::U(failures as u64)),
                        ],
                    );
                }
                let delay = s.backoff.next_delay();
                std::thread::sleep(delay);
            }
        }
    }
}
