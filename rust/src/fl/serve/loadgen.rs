//! `serve::loadgen` — the wire-level load generator behind
//! `repro loadgen`: replays a seed-deterministic fleet as concurrent
//! client sessions against a running [`super::server`] and reports
//! throughput, submit-latency percentiles, and reject/duplicate/busy
//! counts (`make bench-serve` writes them to `BENCH_serve.json`).
//!
//! The *schedule* being replayed lives server-side — the coordinator's
//! virtual mobility/latency model decides which client trains when; a
//! loadgen session is a dumb worker that pulls whatever job is next,
//! trains it on its own native runtime, and submits. What the loadgen
//! adds client-side is seed-deterministic *think time*: with
//! `serve.pace_ms > 0`, each session sleeps a draw from the configured
//! `[latency]` model (its own [`Rng::for_entity`] stream, so the pattern
//! is reproducible across runs) scaled by `pace_ms` between jobs —
//! turning the configured fleet-latency distribution into wall-clock
//! arrival jitter.
//!
//! Every submit is retried through [`Msg::Busy`] backpressure until a
//! terminal reply (ack or reject) lands, so `lost` — jobs with no
//! terminal outcome — must come out 0 on a healthy server.

use std::net::TcpStream;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context as _, Result};

use crate::config::Config;
use crate::obs::hist;
use crate::obs::trace::{TraceSink, V};
use crate::runtime::ModelRuntime;
use crate::util::Rng;

use super::proto::{self, FrameRead, Msg, RejectCode};

/// Loadgen RNG stream tag (per-session think-time draws).
const STREAM_LOADGEN: u64 = 0x10ad;

/// Backoff after a `Busy` reply (submit retry / session-cap reconnect).
const BUSY_BACKOFF: Duration = Duration::from_millis(10);

/// Aggregated wire metrics for one loadgen run.
#[derive(Debug, Clone)]
pub struct LoadgenReport {
    /// Concurrent sessions replayed.
    pub sessions: usize,
    /// Training jobs pulled and executed.
    pub jobs: usize,
    /// Submits accepted into an aggregation buffer.
    pub acks: usize,
    pub duplicates: usize,
    pub out_of_round: usize,
    /// `Busy` replies absorbed (submit retries + session-cap rejects).
    pub busy: usize,
    /// Jobs that never reached a terminal ack/reject — 0 on a healthy run.
    pub lost: usize,
    pub wall_secs: f64,
    /// All request frames sent (hello + fetch + submit attempts) per second.
    pub requests_per_sec: f64,
    /// Submit latency: first submit frame sent → terminal reply read,
    /// including any Busy retry cycles in between.
    pub submit_p50_ms: f64,
    pub submit_p90_ms: f64,
    pub submit_p99_ms: f64,
}

#[derive(Default)]
struct Tally {
    jobs: usize,
    acks: usize,
    duplicates: usize,
    out_of_round: usize,
    busy: usize,
    requests: usize,
    latencies_ms: Vec<f64>,
}

/// Run `cfg.serve.sessions` concurrent client sessions against the
/// server at `addr` until it reports the run done, then aggregate the
/// wire metrics. Requires the native backend (every session owns a
/// runtime on its own thread; PJRT executables are thread-bound).
pub fn run_loadgen(cfg: &Config, addr: &str) -> Result<LoadgenReport> {
    ensure!(
        crate::runtime::is_native_dir(&cfg.artifacts_dir),
        "loadgen requires artifacts_dir = native (each session thread owns \
         its own runtime)"
    );
    let sessions = cfg.serve.sessions.max(1);
    // Optional journal: every job's terminal submit latency goes out as
    // a `wire_submit` event carrying the *same* f64 the percentile
    // report uses, so (at `obs_sample_every = 1`) `repro trace
    // summarize` reproduces the report's percentiles exactly.
    let trace = match TraceSink::from_cfg(&cfg.obs) {
        Ok(t) => t,
        Err(e) => {
            crate::debug!("obs: trace journal disabled: {e:#}");
            None
        }
    };
    let start = Instant::now();
    let mut tallies: Vec<Tally> = Vec::with_capacity(sessions);
    std::thread::scope(|s| -> Result<()> {
        let mut handles = Vec::with_capacity(sessions);
        for idx in 0..sessions {
            let trace = trace.as_ref();
            handles.push(s.spawn(move || client_session(cfg, addr, idx, trace)));
        }
        for h in handles {
            tallies.push(
                h.join()
                    .map_err(|_| anyhow!("loadgen session panicked"))??,
            );
        }
        Ok(())
    })?;
    let wall_secs = start.elapsed().as_secs_f64();

    let mut total = Tally::default();
    for t in tallies {
        total.jobs += t.jobs;
        total.acks += t.acks;
        total.duplicates += t.duplicates;
        total.out_of_round += t.out_of_round;
        total.busy += t.busy;
        total.requests += t.requests;
        total.latencies_ms.extend(t.latencies_ms);
    }
    total
        .latencies_ms
        .sort_by(|a, b| a.partial_cmp(b).unwrap());
    let lost = total
        .jobs
        .saturating_sub(total.acks + total.duplicates + total.out_of_round);
    Ok(LoadgenReport {
        sessions,
        jobs: total.jobs,
        acks: total.acks,
        duplicates: total.duplicates,
        out_of_round: total.out_of_round,
        busy: total.busy,
        lost,
        wall_secs,
        requests_per_sec: total.requests as f64 / wall_secs.max(1e-9),
        // Shared nearest-rank helpers (`obs::hist`) — the same math
        // `repro trace summarize` replays a journal through.
        submit_p50_ms: hist::percentile(&total.latencies_ms, 50.0),
        submit_p90_ms: hist::percentile(&total.latencies_ms, 90.0),
        submit_p99_ms: hist::percentile(&total.latencies_ms, 99.0),
    })
}

/// Read one message on a blocking client stream.
fn read_reply(stream: &mut TcpStream) -> Result<Msg> {
    loop {
        match proto::read_msg(stream)? {
            FrameRead::Msg(m) => return Ok(m),
            FrameRead::Eof => bail!("server closed the session"),
            // No read timeout is set client-side, but tolerate one anyway.
            FrameRead::IdleTimeout => continue,
        }
    }
}

/// Connect + handshake, backing off through session-cap `Busy` replies
/// and startup connection refusals.
fn connect(addr: &str, idx: usize, tally: &mut Tally) -> Result<(TcpStream, f32)> {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let mut stream = loop {
            match TcpStream::connect(addr) {
                Ok(s) => break s,
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(e).with_context(|| format!("connecting to {addr}"));
                    }
                    std::thread::sleep(Duration::from_millis(20));
                }
            }
        };
        stream.set_nodelay(true).ok();
        proto::write_msg(&mut stream, &Msg::Hello { token: idx as u64 })?;
        tally.requests += 1;
        match read_reply(&mut stream)? {
            Msg::Assign { lr, .. } => return Ok((stream, lr)),
            Msg::Busy => {
                // Session table full — back off and re-dial.
                tally.busy += 1;
                ensure!(
                    Instant::now() < deadline,
                    "session {idx}: server stayed at its session cap for 30 s"
                );
                std::thread::sleep(BUSY_BACKOFF);
            }
            other => bail!("expected Assign, got {other:?}"),
        }
    }
}

/// One session: pull jobs, train them on an own native runtime, submit
/// through backpressure until the server reports the run done.
fn client_session(
    cfg: &Config,
    addr: &str,
    idx: usize,
    trace: Option<&TraceSink>,
) -> Result<Tally> {
    let rt = ModelRuntime::native_for(cfg)?;
    let latency = cfg.latency();
    let mut pace_rng = Rng::for_entity(cfg.seed, STREAM_LOADGEN, idx as u64);
    let mut tally = Tally::default();
    let (mut stream, lr) = connect(addr, idx, &mut tally)?;

    loop {
        proto::write_msg(&mut stream, &Msg::FetchJob)?;
        tally.requests += 1;
        match read_reply(&mut stream)? {
            Msg::Job {
                client,
                round,
                staleness,
                w,
                xs,
                ys,
            } => {
                tally.jobs += 1;
                let out = rt.local_train(&w, &xs, &ys, lr)?;
                if cfg.serve.pace_ms > 0 {
                    // Think time: the configured fleet-latency model,
                    // scaled to wall-clock by pace_ms.
                    let think = latency.draw(&mut pace_rng) * cfg.serve.pace_ms as f64;
                    std::thread::sleep(Duration::from_millis(think.max(0.0) as u64));
                }
                let t0 = Instant::now();
                loop {
                    proto::write_msg(
                        &mut stream,
                        &Msg::Submit {
                            client,
                            round,
                            staleness,
                            loss: out.loss,
                            weights: out.weights.clone(),
                        },
                    )?;
                    tally.requests += 1;
                    match read_reply(&mut stream)? {
                        Msg::Ack { .. } => {
                            tally.acks += 1;
                            break;
                        }
                        Msg::Reject {
                            code: RejectCode::Duplicate,
                            ..
                        } => {
                            tally.duplicates += 1;
                            break;
                        }
                        Msg::Reject {
                            code: RejectCode::OutOfRound,
                            ..
                        } => {
                            tally.out_of_round += 1;
                            break;
                        }
                        Msg::Busy => {
                            // Aggregation buffer contended: keep the job
                            // and retry after a pause.
                            tally.busy += 1;
                            std::thread::sleep(BUSY_BACKOFF);
                        }
                        other => bail!("unexpected submit reply: {other:?}"),
                    }
                }
                let ms = t0.elapsed().as_secs_f64() * 1000.0;
                tally.latencies_ms.push(ms);
                if let Some(tr) = trace {
                    // Same f64 as the percentile sample above — shortest
                    // round-trip formatting makes the journal replay
                    // bitwise exact.
                    tr.emit(
                        "wire_submit",
                        None,
                        &[
                            ("session", V::U(idx as u64)),
                            ("client", V::U(client)),
                            ("round", V::U(round)),
                            ("ms", V::F(ms)),
                        ],
                    );
                }
            }
            Msg::NoJob { done: true } => {
                let _ = proto::write_msg(&mut stream, &Msg::Bye);
                return Ok(tally);
            }
            Msg::NoJob { done: false } => {
                std::thread::sleep(Duration::from_millis(2));
            }
            other => bail!("unexpected fetch reply: {other:?}"),
        }
    }
}
