//! `serve::chaos` — seed-deterministic wire-fault injection.
//!
//! A [`ChaosStream`] wraps a stream and plays adversary on the **write**
//! side: every outgoing frame (the serve stack writes each frame with a
//! single `write_all` of the fully-encoded buffer, so one `write` call
//! equals one frame) draws once from a dedicated [`crate::util::Rng`]
//! stream and suffers at most one fault per the [`FaultPlan`] rates:
//!
//! | kind       | effect on the frame                                      |
//! |------------|----------------------------------------------------------|
//! | drop       | swallowed — the writer sees success, the peer sees nothing |
//! | delay      | delivered intact after `delay_ms` of extra latency        |
//! | truncate   | a prefix is delivered, then the connection dies           |
//! | corrupt    | delivered with one bit flipped past the length prefix     |
//! | disconnect | nothing delivered, the connection dies                    |
//!
//! Corruption deliberately spares the 4-byte length prefix so the peer
//! reads a complete frame and fails the checksum (a clean `Corrupt`
//! classification) instead of desynchronizing the framing. Truncate and
//! disconnect mark the stream dead and return an error immediately, so
//! the faulted side tears down fast and the peer observes a prompt EOF
//! rather than a mid-frame stall.
//!
//! Reads pass through untouched: each direction of the wire is faulted
//! by its writer, so wrapping both the server's and loadgen's streams
//! makes both directions face the same adversary. Determinism comes
//! from `Rng::for_entity(seed, stream_tag, entity)` — the server keys
//! entities off a per-accept counter and loadgen off
//! `(session_idx, connection_seq)`, so a reconnect draws a *fresh*
//! fault schedule instead of replaying the one that just killed it.
//!
//! An inert plan (all rates zero) short-circuits: no RNG draws, no
//! overhead, byte-identical passthrough — which is what keeps the
//! chaos-off golden tests untouched by this layer.

use std::io::{self, Read, Write};

use crate::config::ChaosConfig;
use crate::util::Rng;

/// RNG stream tag for server-side fault draws.
pub const STREAM_CHAOS_SERVER: u64 = 0xc405;
/// RNG stream tag for client-side (loadgen) fault draws.
pub const STREAM_CHAOS_CLIENT: u64 = 0xc40c;

/// The injectable wire fault kinds. See the module docs for semantics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    Drop,
    Delay,
    Truncate,
    Corrupt,
    Disconnect,
}

impl FaultKind {
    /// All kinds, in metric/report order.
    pub const ALL: [FaultKind; 5] = [
        FaultKind::Drop,
        FaultKind::Delay,
        FaultKind::Truncate,
        FaultKind::Corrupt,
        FaultKind::Disconnect,
    ];

    /// Stable lowercase name (metric suffixes, trace fields).
    pub fn name(self) -> &'static str {
        match self {
            FaultKind::Drop => "drop",
            FaultKind::Delay => "delay",
            FaultKind::Truncate => "truncate",
            FaultKind::Corrupt => "corrupt",
            FaultKind::Disconnect => "disconnect",
        }
    }

    /// Index into [`FaultKind::ALL`]-ordered tables.
    pub fn index(self) -> usize {
        match self {
            FaultKind::Drop => 0,
            FaultKind::Delay => 1,
            FaultKind::Truncate => 2,
            FaultKind::Corrupt => 3,
            FaultKind::Disconnect => 4,
        }
    }
}

/// Per-frame fault rates plus the delay magnitude. Rates are
/// per-outgoing-frame probabilities; at most one fault fires per frame
/// (a single uniform draw against the cumulative rates), so
/// `Config::validate` caps their sum at 1.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultPlan {
    pub drop: f64,
    pub delay: f64,
    pub truncate: f64,
    pub corrupt: f64,
    pub disconnect: f64,
    pub delay_ms: u64,
}

impl FaultPlan {
    /// The all-zero plan: a transparent wire.
    pub fn inert() -> Self {
        Self {
            drop: 0.0,
            delay: 0.0,
            truncate: 0.0,
            corrupt: 0.0,
            disconnect: 0.0,
            delay_ms: 0,
        }
    }

    /// Build the plan from the `[chaos]` config section.
    pub fn from_cfg(c: &ChaosConfig) -> Self {
        Self {
            drop: c.drop,
            delay: c.delay,
            truncate: c.truncate,
            corrupt: c.corrupt,
            disconnect: c.disconnect,
            delay_ms: c.delay_ms,
        }
    }

    /// True when no fault can ever fire. Inert streams never touch
    /// their RNG, so wrapping a healthy wire is free and byte-exact.
    pub fn is_inert(&self) -> bool {
        self.drop <= 0.0
            && self.delay <= 0.0
            && self.truncate <= 0.0
            && self.corrupt <= 0.0
            && self.disconnect <= 0.0
    }
}

fn killed() -> io::Error {
    io::Error::new(
        io::ErrorKind::ConnectionAborted,
        "chaos: connection killed by injected fault",
    )
}

/// A fault-injecting wrapper around a frame-oriented stream. Writes are
/// faulted per the plan; reads pass through until an injected
/// truncate/disconnect marks the stream dead.
pub struct ChaosStream<S> {
    inner: S,
    plan: FaultPlan,
    rng: Rng,
    dead: bool,
    counts: [u64; 5],
    events: Vec<FaultKind>,
}

impl<S> ChaosStream<S> {
    /// Wrap `inner` with a fault plan and a dedicated RNG (use
    /// `Rng::for_entity` with [`STREAM_CHAOS_SERVER`] /
    /// [`STREAM_CHAOS_CLIENT`] and a never-reused entity id).
    pub fn new(inner: S, plan: FaultPlan, rng: Rng) -> Self {
        Self {
            inner,
            plan,
            rng,
            dead: false,
            counts: [0; 5],
            events: Vec::new(),
        }
    }

    /// Borrow the wrapped stream (e.g. to set socket timeouts).
    pub fn get_ref(&self) -> &S {
        &self.inner
    }

    /// Per-kind injected-fault counts, [`FaultKind::ALL`]-ordered.
    pub fn counts(&self) -> [u64; 5] {
        self.counts
    }

    /// Total faults injected so far.
    pub fn injected(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Drain the faults injected since the last call, in order. Callers
    /// fold these into metrics/trace after each send.
    pub fn take_events(&mut self) -> Vec<FaultKind> {
        std::mem::take(&mut self.events)
    }

    /// One uniform draw against the cumulative rates: at most one fault
    /// per frame. Inert plans never touch the RNG.
    fn decide(&mut self) -> Option<FaultKind> {
        if self.plan.is_inert() {
            return None;
        }
        let u = self.rng.f64();
        let p = self.plan;
        let mut acc = 0.0;
        for (kind, rate) in [
            (FaultKind::Drop, p.drop),
            (FaultKind::Delay, p.delay),
            (FaultKind::Truncate, p.truncate),
            (FaultKind::Corrupt, p.corrupt),
            (FaultKind::Disconnect, p.disconnect),
        ] {
            acc += rate;
            if rate > 0.0 && u < acc {
                return Some(kind);
            }
        }
        None
    }

    fn note(&mut self, kind: FaultKind) {
        self.counts[kind.index()] += 1;
        self.events.push(kind);
    }
}

impl<S: Read> Read for ChaosStream<S> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        if self.dead {
            return Err(killed());
        }
        self.inner.read(buf)
    }
}

impl<S: Write> Write for ChaosStream<S> {
    /// Consumes the whole `buf` (one frame) and applies at most one
    /// fault. Always returns `Ok(buf.len())` on the non-fatal paths so
    /// the caller's `write_all` never re-enters with a partial frame.
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        if self.dead {
            return Err(killed());
        }
        match self.decide() {
            None => {
                self.inner.write_all(buf)?;
                Ok(buf.len())
            }
            Some(FaultKind::Drop) => {
                self.note(FaultKind::Drop);
                Ok(buf.len())
            }
            Some(FaultKind::Delay) => {
                self.note(FaultKind::Delay);
                std::thread::sleep(std::time::Duration::from_millis(self.plan.delay_ms));
                self.inner.write_all(buf)?;
                Ok(buf.len())
            }
            Some(FaultKind::Truncate) => {
                self.note(FaultKind::Truncate);
                let cut = if buf.is_empty() {
                    0
                } else {
                    self.rng.index(buf.len())
                };
                let _ = self.inner.write_all(&buf[..cut]);
                let _ = self.inner.flush();
                self.dead = true;
                Err(killed())
            }
            Some(FaultKind::Corrupt) => {
                self.note(FaultKind::Corrupt);
                let mut tampered = buf.to_vec();
                if tampered.len() > 4 {
                    // Flip one bit past the length prefix: the peer reads
                    // a full frame and fails the checksum cleanly.
                    let at = 4 + self.rng.index(tampered.len() - 4);
                    let bit = self.rng.index(8) as u8;
                    tampered[at] ^= 1 << bit;
                } else if let Some(last) = tampered.last_mut() {
                    *last ^= 1;
                }
                self.inner.write_all(&tampered)?;
                Ok(buf.len())
            }
            Some(FaultKind::Disconnect) => {
                self.note(FaultKind::Disconnect);
                self.dead = true;
                Err(killed())
            }
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        if self.dead {
            return Err(killed());
        }
        self.inner.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;

    fn rng() -> Rng {
        Rng::for_entity(7, STREAM_CHAOS_SERVER, 0)
    }

    fn plan_one(kind: FaultKind) -> FaultPlan {
        let mut p = FaultPlan::inert();
        match kind {
            FaultKind::Drop => p.drop = 1.0,
            FaultKind::Delay => p.delay = 1.0,
            FaultKind::Truncate => p.truncate = 1.0,
            FaultKind::Corrupt => p.corrupt = 1.0,
            FaultKind::Disconnect => p.disconnect = 1.0,
        }
        p
    }

    #[test]
    fn inert_plan_is_a_transparent_wire() {
        let mut s = ChaosStream::new(Vec::new(), FaultPlan::inert(), rng());
        s.write_all(&[1, 2, 3, 4, 5, 6]).unwrap();
        s.write_all(&[7, 8]).unwrap();
        assert_eq!(s.get_ref().as_slice(), &[1, 2, 3, 4, 5, 6, 7, 8]);
        assert_eq!(s.injected(), 0);
        assert!(s.take_events().is_empty());
    }

    #[test]
    fn drop_swallows_the_frame_but_reports_success() {
        let mut s = ChaosStream::new(Vec::new(), plan_one(FaultKind::Drop), rng());
        s.write_all(&[9; 16]).unwrap();
        assert!(s.get_ref().is_empty());
        assert_eq!(s.counts()[FaultKind::Drop.index()], 1);
        assert_eq!(s.take_events(), vec![FaultKind::Drop]);
    }

    #[test]
    fn disconnect_kills_the_stream_for_good() {
        let inner = std::io::Cursor::new(Vec::new());
        let mut s = ChaosStream::new(inner, plan_one(FaultKind::Disconnect), rng());
        assert!(s.write_all(&[1, 2, 3]).is_err());
        assert!(s.write_all(&[4]).is_err());
        let mut buf = [0u8; 4];
        assert!(s.read(&mut buf).is_err());
        assert_eq!(s.counts()[FaultKind::Disconnect.index()], 1);
    }

    #[test]
    fn truncate_delivers_a_strict_prefix_then_dies() {
        let frame = [0xabu8; 32];
        let mut s = ChaosStream::new(Vec::new(), plan_one(FaultKind::Truncate), rng());
        assert!(s.write_all(&frame).is_err());
        assert!(s.get_ref().len() < frame.len());
        assert_eq!(s.get_ref().as_slice(), &frame[..s.get_ref().len()]);
        assert!(s.write_all(&frame).is_err());
    }

    #[test]
    fn corrupt_flips_exactly_one_bit_past_the_prefix() {
        let frame: Vec<u8> = (0..64).collect();
        let mut s = ChaosStream::new(Vec::new(), plan_one(FaultKind::Corrupt), rng());
        s.write_all(&frame).unwrap();
        let out = s.get_ref().clone();
        assert_eq!(out.len(), frame.len());
        assert_eq!(&out[..4], &frame[..4], "length prefix must stay intact");
        let flipped: u32 = frame
            .iter()
            .zip(&out)
            .map(|(a, b)| (a ^ b).count_ones())
            .sum();
        assert_eq!(flipped, 1);
    }

    #[test]
    fn fault_schedule_is_deterministic_per_entity() {
        let plan = FaultPlan {
            drop: 0.2,
            delay: 0.0,
            truncate: 0.1,
            corrupt: 0.2,
            disconnect: 0.1,
            delay_ms: 0,
        };
        let run = |entity: u64| {
            let rng = Rng::for_entity(42, STREAM_CHAOS_CLIENT, entity);
            let mut s = ChaosStream::new(Vec::new(), plan, rng);
            let mut kinds = Vec::new();
            for _ in 0..50 {
                if s.write_all(&[0u8; 8]).is_err() {
                    break;
                }
                kinds.extend(s.take_events());
            }
            kinds.extend(s.take_events());
            kinds
        };
        assert_eq!(run(3), run(3));
        assert_ne!(run(3), run(4), "distinct entities draw distinct schedules");
    }
}
