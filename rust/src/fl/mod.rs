//! Federated learning on one event-driven core with an **open policy
//! surface**: a single [`Coordinator`](coordinator::Coordinator) drives
//! every algorithm, each algorithm is an
//! [`AggregationPolicy`](coordinator::AggregationPolicy) — a struct of
//! decisions, not a round loop — and policies are looked up **by name**
//! in the string-keyed [`registry`].
//!
//! The coordinator owns the virtual clock, the client-finished event
//! queue, per-client base-model slots, the deterministic per-purpose RNG
//! streams, the reusable AirComp stack/coefficient buffers, and the
//! [`Telemetry`](coordinator::Telemetry) recorder; local training always
//! fans out through [`TrainContext::train_many`] (the backend-agnostic
//! worker pool — per-thread PJRT engines or per-thread native models).
//! Policies only decide *who* uploads, *what* the server does with
//! the uploads, and *when* aggregation fires. Registered out of the box:
//!
//! * [`paota`]       — periodic semi-asynchronous AirComp with per-round
//!   power control (the paper's Algorithm 1).
//! * [`local_sgd`]   — ideal synchronous Local SGD / FedAvg (baseline 1).
//! * [`cotaf`]       — synchronous AirComp with time-varying precoding
//!   (baseline 2, Sery & Cohen).
//! * [`centralized`] — pooled-data SGD; the `F(w*)` estimator for the
//!   Fig. 3 loss-gap curves.
//! * [`fedasync`]    — fully-asynchronous per-arrival mixing (extension).
//! * [`ca_paota`]    — PAOTA with channel/gradient-aware participant
//!   scheduling (extension, after arXiv 2212.00491).
//! * [`topology::air_fedga`] — grouping-asynchronous AirComp: per-group
//!   `stack`/`coef` passes fired on group readiness (extension, after
//!   arXiv 2507.05704).
//!
//! Above the flat fleet, [`topology`] bends the same core into an
//! **aggregation tree**: `Config`'s `[topology]` surface selects client
//! groups (`air_fedga`) and multi-cell hierarchies — [`run`] routes
//! through [`topology::multi_cell`] whenever `cells > 1`, so campaigns
//! sweep cells × groups declaratively. [`mobility`] then makes the
//! client → cell assignment a function of simulated time: roaming models
//! (`static`/`markov`/`waypoint`), a handover protocol
//! (`deliver`/`forward`/`drop` for in-flight updates) and
//! residence-coupled per-cell channels, all from the `[mobility]` config
//! surface.
//!
//! Every run emits the same [`RoundRecord`] stream so the experiment
//! harness ([`crate::experiments`] campaigns) can overlay algorithms
//! directly. **To add a scheme**, implement `AggregationPolicy` and call
//! [`registry::register`] — no edits to `config`, `cli`, or this module;
//! `examples/custom_policy.rs` does it end-to-end. [`build_policy`] is
//! nothing but the registry lookup for the config's algorithm name.
//!
//! The [`TrainContext`] loads the AOT PJRT artifacts by default; setting
//! `artifacts_dir = native` selects the pure-Rust reference kernel
//! ([`crate::runtime::native`]) so everything here also runs in
//! artifact-free environments (CI, fresh checkouts).

pub mod ca_paota;
pub mod centralized;
pub mod coordinator;
pub mod cotaf;
pub mod fedasync;
pub mod local_sgd;
pub mod mobility;
pub mod paota;
pub mod registry;
pub mod serve;
pub mod topology;

pub use coordinator::{
    AggregationPolicy, Coordinator, GroupPass, OpenSlot, RngStreams, RoundAction, RoundTiming,
    Telemetry, Upload, WindowStats,
};

use anyhow::{bail, Context as _, Result};

use crate::config::{Algorithm, Config};
use crate::data::Partition;
use crate::runtime::{Engine, EvalOut, ModelRuntime};
use crate::util::Rng;

/// One global round's telemetry (shared across all algorithms).
#[derive(Debug, Clone)]
pub struct RoundRecord {
    /// Global round index r (0-based).
    pub round: usize,
    /// Virtual time at the end of this round (seconds).
    pub sim_time: f64,
    /// Mean local training loss reported by this round's participants.
    pub train_loss: f32,
    /// Global objective `F(w)` estimated on the fixed train probe.
    pub probe_loss: Option<f32>,
    /// Test-set evaluation (loss + accuracy), if run this round.
    pub eval: Option<EvalOut>,
    /// Number of uploading clients.
    pub participants: usize,
    /// Mean staleness s_k of this round's uploads (PAOTA; 0 for sync).
    pub mean_staleness: f64,
    /// Mean transmit power of the uploads (watts; p_max-weighted schemes).
    pub mean_power: f64,
}

/// A complete training run.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub algorithm: Algorithm,
    pub records: Vec<RoundRecord>,
    pub final_weights: Vec<f32>,
}

impl RunResult {
    /// Final test accuracy (last evaluated round).
    pub fn final_accuracy(&self) -> Option<f32> {
        self.records.iter().rev().find_map(|r| r.eval.map(|e| e.accuracy))
    }

    /// Best test accuracy across the run.
    pub fn best_accuracy(&self) -> Option<f32> {
        self.records
            .iter()
            .filter_map(|r| r.eval.map(|e| e.accuracy))
            .fold(None, |acc, a| Some(acc.map_or(a, |b: f32| b.max(a))))
    }
}

/// Everything a trainer needs: the compiled runtime, the partitioned data,
/// flattened eval tensors, and a fixed train-loss probe.
///
/// `TrainContext` is `Sync`: the native backend is thread-safe end to
/// end, and the PJRT executables sit behind a thread-ownership guard
/// ([`crate::runtime::ThreadBound`]) — parallel drivers (campaign
/// scenario workers, concurrently stepped cells) check
/// [`ModelRuntime::is_native`] and fall back to serial execution on
/// PJRT, so the guard never trips.
pub struct TrainContext {
    pub rt: ModelRuntime,
    pub partition: Partition,
    /// Parallel local-training pool (§Perf): participants' independent
    /// `local_train` executions fan out over per-thread backends (PJRT
    /// engines or native models). `None` when `perf.workers = 1` or
    /// spawning failed (sequential path).
    pub pool: Option<crate::runtime::TrainPool>,
    /// Seed the model init derives from (the config's master seed).
    pub init_seed: u64,
    /// Test features/one-hot labels, flattened to the eval artifact shape.
    pub test_x: Vec<f32>,
    pub test_y: Vec<f32>,
    /// Fixed subsample of pooled TRAINING data (same eval shape): the
    /// estimator of the global objective `F(w)` used by the Fig. 3 curves.
    pub probe_x: Vec<f32>,
    pub probe_y: Vec<f32>,
    /// Keeps a [`TrainContext::new`]-built PJRT engine alive for the
    /// lifetime of its compiled executables. `None` on the native
    /// backend, or when the engine is owned externally
    /// ([`TrainContext::build`]).
    _engine: Option<crate::runtime::ThreadBound<Engine>>,
}

impl TrainContext {
    /// Build a context straight from a config, constructing a PJRT
    /// engine **only if the config needs one**: with
    /// `artifacts_dir = native` no PJRT state is ever touched, so
    /// native-only environments (CI, fresh checkouts) stay entirely on
    /// the pure-Rust path.
    pub fn new(cfg: &Config) -> Result<Self> {
        if crate::runtime::is_native_dir(&cfg.artifacts_dir) {
            Self::assemble(None, cfg)
        } else {
            let engine = Engine::cpu()?;
            let mut ctx = Self::assemble(Some(&engine), cfg)?;
            ctx._engine = Some(crate::runtime::ThreadBound::new(engine));
            Ok(ctx)
        }
    }

    /// Build data + runtime from a config on an externally owned engine
    /// (`engine` must outlive the context).
    ///
    /// `artifacts_dir = native` selects the pure-Rust reference kernel
    /// (geometry derived from the config) instead of the AOT PJRT
    /// artifacts — same API, no artifacts required. Prefer
    /// [`TrainContext::new`], which skips engine construction entirely
    /// on the native path.
    pub fn build(engine: &Engine, cfg: &Config) -> Result<Self> {
        Self::assemble(Some(engine), cfg)
    }

    fn assemble(engine: Option<&Engine>, cfg: &Config) -> Result<Self> {
        cfg.validate()?;
        let native = crate::runtime::is_native_dir(&cfg.artifacts_dir);
        let rt = if native {
            ModelRuntime::native_for(cfg)?
        } else {
            let engine = engine.context("internal: PJRT artifacts need an engine")?;
            ModelRuntime::load(engine, &cfg.artifacts_dir).context(
                "loading AOT artifacts (run `make artifacts`, or set \
                 artifacts_dir=native for the pure-Rust reference kernel)",
            )?
        };
        let m = rt.manifest().clone();
        if m.d_in != cfg.synth.dim() {
            bail!(
                "artifact d_in = {} but synth dim = {} — re-run `make artifacts`",
                m.d_in,
                cfg.synth.dim()
            );
        }
        if m.clients != cfg.partition.clients {
            bail!(
                "aggregate artifact is compiled for K = {} clients, config wants {}",
                m.clients,
                cfg.partition.clients
            );
        }
        if m.eval_size != cfg.partition.test_size {
            bail!(
                "evaluate artifact is compiled for eval_size = {}, config test_size = {}",
                m.eval_size,
                cfg.partition.test_size
            );
        }

        let mut rng = Rng::with_stream(cfg.seed, 0xda7a);
        let partition = Partition::generate(cfg.synth, &cfg.partition, &mut rng);

        let test_x = partition.test.x.clone();
        let test_y = partition.test.one_hot();

        // Train probe: deterministic subsample of the pooled shards.
        // Drawn by global pooled-row index exactly as if the shards were
        // concatenated, but only the shards a draw lands in are
        // materialized — the partition stays lazy at fleet scale.
        let mut probe_rng = Rng::with_stream(cfg.seed, 0x9806e);
        let dim = partition.test.dim;
        let classes = partition.test.classes;
        let total = partition.total_samples();
        let mut probe_x = Vec::with_capacity(m.eval_size * dim);
        let mut probe_y = vec![0.0f32; m.eval_size * classes];
        for row in 0..m.eval_size {
            let i = probe_rng.index(total);
            let (c, local) = partition.locate(i);
            let shard = &partition.client(c).data;
            probe_x.extend_from_slice(shard.row(local));
            probe_y[row * classes + shard.y[local] as usize] = 1.0;
        }

        // Backend-agnostic fan-out: both model backends ride the same
        // pool abstraction (per-thread PJRT engines / per-thread native
        // models). `perf.workers = 1` keeps the in-line sequential path.
        let workers = cfg.perf.workers.max(1);
        let pool = if workers > 1 {
            let built = if native {
                crate::runtime::TrainPool::native(rt.manifest().clone(), workers)
            } else {
                crate::runtime::TrainPool::pjrt(&cfg.artifacts_dir, workers)
            };
            match built {
                Ok(p) => Some(p),
                Err(e) => {
                    crate::warn_!("train pool unavailable, running sequentially: {e:#}");
                    None
                }
            }
        } else {
            None
        };

        Ok(Self {
            rt,
            partition,
            pool,
            init_seed: cfg.seed,
            test_x,
            test_y,
            probe_x,
            probe_y,
            _engine: None,
        })
    }

    /// Model dimension d.
    pub fn dim(&self) -> usize {
        self.rt.manifest().dim
    }

    /// Client count K.
    pub fn clients(&self) -> usize {
        self.partition.num_clients()
    }

    /// He-initialized global model, deterministic in the config seed.
    ///
    /// Zero init would leave the ReLU hidden layers dead (zero
    /// activations → zero gradients for every layer but the output bias),
    /// so weights get `N(0, √(2/fan_in))` and biases zero — the same
    /// init for every algorithm in a comparison (seed-derived).
    pub fn init_weights(&self) -> Vec<f32> {
        let m = self.rt.manifest();
        let mut rng = Rng::with_stream(self.init_seed, 0x1d17);
        let mut w = vec![0.0f32; m.dim];
        let mut off = 0;
        // [W1, b1, W2, b2, W3, b3] — the flat layout of model.py.
        let layers = [
            (m.d_in * m.hidden, m.d_in),
            (m.hidden, 0), // b1
            (m.hidden * m.hidden, m.hidden),
            (m.hidden, 0), // b2
            (m.hidden * m.classes, m.hidden),
            (m.classes, 0), // b3
        ];
        for (size, fan_in) in layers {
            if fan_in > 0 {
                let std = (2.0 / fan_in as f64).sqrt() as f32;
                rng.fill_normal(&mut w[off..off + size], std);
            }
            off += size;
        }
        w
    }

    /// Evaluate on the test set.
    pub fn evaluate(&self, w: &[f32]) -> Result<EvalOut> {
        self.rt.evaluate(w, &self.test_x, &self.test_y)
    }

    /// Estimate the global objective `F(w)` on the train probe.
    pub fn probe_loss(&self, w: &[f32]) -> Result<f32> {
        Ok(self.rt.evaluate(w, &self.probe_x, &self.probe_y)?.loss)
    }

    /// Run many independent local-training jobs `(w, xs, ys)`, in parallel
    /// over the pool when available, sequentially otherwise. Results are
    /// in submission order and bit-identical across both paths.
    pub fn train_many(
        &self,
        jobs: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)>,
        lr: f32,
    ) -> Result<Vec<crate::runtime::TrainOut>> {
        match (&self.pool, jobs.len()) {
            (Some(pool), n) if n > 1 => pool.run_batch(jobs, lr),
            _ => jobs
                .into_iter()
                .map(|(w, xs, ys)| self.rt.local_train(&w, &xs, &ys, lr))
                .collect(),
        }
    }

    /// The synchronous baselines' per-round participant count, applying
    /// the paper's "equal participation" fairness rule when the config
    /// leaves it at 0: match PAOTA's expected per-round upload count
    /// (K / E[rounds-per-upload] under the latency model).
    pub fn sync_participants(&self, cfg: &Config) -> usize {
        if cfg.participants > 0 {
            return cfg.participants.min(self.clients());
        }
        // A client uploads every ceil(ℓ/ΔT) rounds; E over U(lo,hi).
        let (lo, hi) = (cfg.latency_lo, cfg.latency_hi);
        let dt = cfg.delta_t;
        let mut acc = 0.0;
        let steps = 1000;
        for i in 0..steps {
            let l = lo + (hi - lo) * (i as f64 + 0.5) / steps as f64;
            acc += (l / dt).ceil();
        }
        let mean_rounds = acc / steps as f64;
        ((self.clients() as f64 / mean_rounds).round() as usize)
            .clamp(1, self.clients())
    }
}

/// Run the algorithm selected by the config. Engine construction is
/// lazy: `artifacts_dir = native` never touches the PJRT path.
pub fn run(cfg: &Config) -> Result<RunResult> {
    let ctx = TrainContext::new(cfg)?;
    run_with_context(&ctx, cfg)
}

/// Run against a pre-built context (lets the harness reuse data+runtime
/// across algorithm sweeps — same partition, same probe, same test set).
///
/// Topology dispatch: `cells > 1` routes through the hierarchical
/// [`topology::multi_cell`] runner and returns its merged (cloud-level)
/// stream, so multi-cell scenarios drop into every harness — CLI runs,
/// campaigns, figures — unchanged.
pub fn run_with_context(ctx: &TrainContext, cfg: &Config) -> Result<RunResult> {
    // `groups` only feeds policies that read the group map (air_fedga out
    // of the box). Warn instead of erroring so downstream grouped
    // policies registered via `registry` keep the knob available.
    if cfg.topology.groups > 1 && cfg.algorithm.name() != "air_fedga" {
        crate::warn_!(
            "topology.groups = {} is set but --algo {} does not consume the \
             group map (of the built-ins only air_fedga does) — the setting \
             has no effect on this run",
            cfg.topology.groups,
            cfg.algorithm.name()
        );
    }
    if cfg.topology.cells > 1 {
        return Ok(topology::multi_cell::run(ctx, cfg)?.merged);
    }
    let mut policy = build_policy(ctx, cfg)?;
    coordinator::run(ctx, cfg, policy.as_mut())
}

/// Construct the aggregation policy the config selects — a pure
/// [`registry`] lookup. New schemes call [`registry::register`] and are
/// immediately buildable here; nothing in this module enumerates them.
pub fn build_policy(ctx: &TrainContext, cfg: &Config) -> Result<Box<dyn AggregationPolicy>> {
    registry::build(cfg.algorithm.name(), ctx, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn train_context_is_sync_and_send() {
        // The whole parallel execution layer (campaign scenario workers,
        // concurrently stepped cells) rests on this bound; a new `!Sync`
        // field would silently force everything back to serial.
        fn assert_sync<T: Sync + Send>() {}
        assert_sync::<TrainContext>();
    }

    #[test]
    fn native_context_builds_without_any_engine() {
        let mut cfg = Config::default();
        cfg.artifacts_dir = "native".into();
        cfg.synth.side = 6;
        cfg.partition.clients = 4;
        cfg.partition.sizes = vec![20];
        cfg.partition.test_size = 12;
        cfg.perf.workers = 2;
        let ctx = TrainContext::new(&cfg).unwrap();
        assert!(ctx.rt.is_native());
        assert!(ctx.pool.is_some(), "native pool should spawn at workers > 1");
        cfg.perf.workers = 1;
        let seq = TrainContext::new(&cfg).unwrap();
        assert!(seq.pool.is_none());
    }
}
