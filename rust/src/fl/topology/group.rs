//! Fleet → group assignment: the [`GroupMap`] every topology layer hangs
//! off, built by one of three deterministic partitioners.
//!
//! The simulator has no persistent per-device latency/channel traces (the
//! per-round draws are i.i.d. streams), so the profile-based partitioners
//! derive a fixed per-client *profile score* from the master seed — a
//! stand-in for the device/link profiling data a real deployment would
//! cluster on — and group clients with adjacent scores. What matters for
//! the mechanism is the structure this induces: groups are stable,
//! disjoint, non-empty, and reproducible from the seed alone.

use anyhow::{bail, ensure, Result};

use crate::util::Rng;

/// Per-client profile-score streams (derived from the master seed;
/// disjoint from the coordinator's run-time streams in
/// [`crate::fl::coordinator::streams`]).
mod streams {
    /// Device compute-latency profile.
    pub const LATENCY_PROFILE: u64 = 0x70_1a7;
    /// Uplink channel-quality profile.
    pub const CHANNEL_PROFILE: u64 = 0x70_c4a2;
}

/// How clients are assigned to groups (and cells).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartitionerKind {
    /// Client `c` → group `c mod G`: balanced, profile-blind (the
    /// "by size" baseline).
    RoundRobin,
    /// Contiguous chunks of clients sorted by a seed-derived compute-
    /// latency profile score — groups of similar device speed, so one
    /// straggler only delays its own (slow) group.
    Latency,
    /// Contiguous chunks sorted by a seed-derived channel-quality profile
    /// score — groups of similar uplink SNR, the Air-FedGA alignment
    /// criterion.
    Channel,
}

impl PartitionerKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.trim().to_ascii_lowercase().as_str() {
            "round_robin" | "roundrobin" | "rr" | "size" => PartitionerKind::RoundRobin,
            "latency" => PartitionerKind::Latency,
            "channel" => PartitionerKind::Channel,
            other => bail!("unknown group partitioner {other:?} (round_robin|latency|channel)"),
        })
    }

    /// Canonical name; `parse(name())` round-trips.
    pub fn name(&self) -> &'static str {
        match self {
            PartitionerKind::RoundRobin => "round_robin",
            PartitionerKind::Latency => "latency",
            PartitionerKind::Channel => "channel",
        }
    }
}

/// A disjoint, exhaustive, non-empty partition of the fleet into named
/// groups. Construction enforces the invariants every consumer relies
/// on: every client appears in exactly one group, and no group is empty.
#[derive(Debug, Clone)]
pub struct GroupMap {
    groups: Vec<Vec<usize>>,
    /// client → group index.
    assignment: Vec<usize>,
}

impl GroupMap {
    /// Partition `clients` into `n_groups` with the given partitioner.
    /// Deterministic in `(clients, n_groups, how, seed)`.
    pub fn build(
        clients: usize,
        n_groups: usize,
        how: PartitionerKind,
        seed: u64,
    ) -> Result<Self> {
        ensure!(clients > 0, "group map needs at least one client");
        let members: Vec<usize> = (0..clients).collect();
        Self::build_over(&members, clients, n_groups, how, seed)
    }

    /// Partition an arbitrary **member slice** of a `clients_total`-sized
    /// fleet into `n_groups` — the nested-topology form: a cell builds
    /// its group map over its own members only (and rebuilds it after
    /// handover churn). Profile scores are drawn for the *whole* fleet
    /// from the same seed-derived streams as [`GroupMap::build`], so a
    /// client keeps its profile score whichever cell it resides in, and
    /// `build_over(0..K) ≡ build(K)` exactly. Non-members are left
    /// unassigned ([`GroupMap::group_of_checked`] returns `None`).
    pub fn build_over(
        members: &[usize],
        clients_total: usize,
        n_groups: usize,
        how: PartitionerKind,
        seed: u64,
    ) -> Result<Self> {
        ensure!(!members.is_empty(), "group map needs at least one member");
        ensure!(n_groups > 0, "group map needs at least one group");
        ensure!(
            n_groups <= members.len(),
            "{n_groups} groups over {} members would leave a group empty",
            members.len()
        );

        let mut groups = vec![Vec::new(); n_groups];
        match how {
            PartitionerKind::RoundRobin => {
                for (i, &c) in members.iter().enumerate() {
                    groups[i % n_groups].push(c);
                }
            }
            PartitionerKind::Latency | PartitionerKind::Channel => {
                let tag = match how {
                    PartitionerKind::Latency => streams::LATENCY_PROFILE,
                    _ => streams::CHANNEL_PROFILE,
                };
                // Fleet-wide profile scores (stable per client id), then
                // restricted to the member slice.
                let mut rng = Rng::with_stream(seed, tag);
                let scores: Vec<f64> = (0..clients_total).map(|_| rng.f64()).collect();
                let mut scored: Vec<(f64, usize)> = members
                    .iter()
                    .map(|&c| {
                        ensure!(c < clients_total, "member {c} out of range");
                        Ok((scores[c], c))
                    })
                    .collect::<Result<_>>()?;
                // Total order: score first, client id as the tiebreak.
                scored.sort_by(|a, b| {
                    a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal).then(a.1.cmp(&b.1))
                });
                // Balanced contiguous chunks: the first `rem` groups get
                // one extra client.
                let base = members.len() / n_groups;
                let rem = members.len() % n_groups;
                let mut it = scored.into_iter().map(|(_, c)| c);
                for (g, group) in groups.iter_mut().enumerate() {
                    let size = base + usize::from(g < rem);
                    group.extend(it.by_ref().take(size));
                    group.sort_unstable();
                }
            }
        }

        let mut assignment = vec![usize::MAX; clients_total];
        for (g, group) in groups.iter().enumerate() {
            ensure!(!group.is_empty(), "partitioner produced an empty group");
            for &c in group {
                ensure!(c < clients_total, "client {c} out of range");
                ensure!(
                    assignment[c] == usize::MAX,
                    "client {c} assigned to two groups"
                );
                assignment[c] = g;
            }
        }
        let assigned = assignment.iter().filter(|&&g| g != usize::MAX).count();
        ensure!(
            assigned == members.len(),
            "partitioner left a member unassigned"
        );
        Ok(Self { groups, assignment })
    }

    /// Number of groups.
    pub fn num_groups(&self) -> usize {
        self.groups.len()
    }

    /// Number of clients covered.
    pub fn num_clients(&self) -> usize {
        self.assignment.len()
    }

    /// The members of group `g`, in ascending client order for
    /// round-robin/profile chunks.
    pub fn group(&self, g: usize) -> &[usize] {
        &self.groups[g]
    }

    /// All groups.
    pub fn groups(&self) -> &[Vec<usize>] {
        &self.groups
    }

    /// The group `client` belongs to. Panics for a non-member of a map
    /// built over a slice ([`GroupMap::build_over`]); use
    /// [`GroupMap::group_of_checked`] when membership is uncertain.
    pub fn group_of(&self, client: usize) -> usize {
        let g = self.assignment[client];
        assert!(g != usize::MAX, "client {client} is not covered by this group map");
        g
    }

    /// The group `client` belongs to, or `None` for a non-member.
    pub fn group_of_checked(&self, client: usize) -> Option<usize> {
        match self.assignment.get(client) {
            Some(&g) if g != usize::MAX => Some(g),
            _ => None,
        }
    }

    /// Display name of group `g` (telemetry/debug).
    pub fn name(&self, g: usize) -> String {
        format!("g{g}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const KINDS: [PartitionerKind; 3] = [
        PartitionerKind::RoundRobin,
        PartitionerKind::Latency,
        PartitionerKind::Channel,
    ];

    #[test]
    fn every_client_in_exactly_one_group() {
        for kind in KINDS {
            for (clients, groups) in [(1, 1), (7, 3), (24, 4), (100, 5), (10, 10)] {
                let map = GroupMap::build(clients, groups, kind, 42).unwrap();
                assert_eq!(map.num_groups(), groups);
                assert_eq!(map.num_clients(), clients);
                let mut seen = vec![0usize; clients];
                for g in 0..groups {
                    assert!(!map.group(g).is_empty(), "{kind:?} {clients}x{groups}: empty g{g}");
                    for &c in map.group(g) {
                        seen[c] += 1;
                        assert_eq!(map.group_of(c), g);
                    }
                }
                assert!(seen.iter().all(|&n| n == 1), "{kind:?}: {seen:?}");
            }
        }
    }

    #[test]
    fn groups_are_balanced() {
        for kind in KINDS {
            let map = GroupMap::build(23, 4, kind, 1).unwrap();
            let mut sizes: Vec<usize> = map.groups().iter().map(Vec::len).collect();
            sizes.sort_unstable();
            assert_eq!(sizes, vec![5, 6, 6, 6]);
        }
    }

    #[test]
    fn empty_and_oversized_group_counts_rejected() {
        assert!(GroupMap::build(10, 0, PartitionerKind::RoundRobin, 0).is_err());
        assert!(GroupMap::build(10, 11, PartitionerKind::Latency, 0).is_err());
        assert!(GroupMap::build(0, 1, PartitionerKind::RoundRobin, 0).is_err());
        GroupMap::build(10, 10, PartitionerKind::Channel, 0).unwrap();
    }

    #[test]
    fn profile_partitioners_are_seed_deterministic_and_seed_sensitive() {
        for kind in [PartitionerKind::Latency, PartitionerKind::Channel] {
            let a = GroupMap::build(40, 4, kind, 7).unwrap();
            let b = GroupMap::build(40, 4, kind, 7).unwrap();
            assert_eq!(a.groups(), b.groups());
            let c = GroupMap::build(40, 4, kind, 8).unwrap();
            assert_ne!(a.groups(), c.groups(), "{kind:?} ignored the seed");
        }
        // The two profiles are independent streams.
        let lat = GroupMap::build(40, 4, PartitionerKind::Latency, 7).unwrap();
        let chan = GroupMap::build(40, 4, PartitionerKind::Channel, 7).unwrap();
        assert_ne!(lat.groups(), chan.groups());
    }

    #[test]
    fn build_over_full_slice_is_exactly_build() {
        for kind in KINDS {
            let full = GroupMap::build(24, 4, kind, 9).unwrap();
            let members: Vec<usize> = (0..24).collect();
            let over = GroupMap::build_over(&members, 24, 4, kind, 9).unwrap();
            assert_eq!(full.groups(), over.groups(), "{kind:?}");
        }
    }

    #[test]
    fn build_over_slice_covers_members_only_and_stays_deterministic() {
        let members: Vec<usize> = (0..30).step_by(2).collect(); // 15 even clients
        for kind in KINDS {
            let a = GroupMap::build_over(&members, 30, 3, kind, 4).unwrap();
            let b = GroupMap::build_over(&members, 30, 3, kind, 4).unwrap();
            assert_eq!(a.groups(), b.groups(), "{kind:?} not deterministic");
            // Every member covered exactly once; non-members uncovered.
            let mut seen = vec![0usize; 30];
            for g in 0..a.num_groups() {
                assert!(!a.group(g).is_empty());
                for &c in a.group(g) {
                    seen[c] += 1;
                    assert_eq!(a.group_of(c), g);
                }
            }
            for c in 0..30 {
                let want = usize::from(c % 2 == 0);
                assert_eq!(seen[c], want, "{kind:?} client {c}");
                assert_eq!(a.group_of_checked(c).is_some(), want == 1);
            }
            // Balanced 5/5/5 chunks.
            let mut sizes: Vec<usize> = a.groups().iter().map(Vec::len).collect();
            sizes.sort_unstable();
            assert_eq!(sizes, vec![5, 5, 5], "{kind:?}");
        }
    }

    #[test]
    fn build_over_rejects_bad_slices() {
        assert!(GroupMap::build_over(&[], 10, 1, PartitionerKind::RoundRobin, 0).is_err());
        assert!(GroupMap::build_over(&[1, 2], 10, 3, PartitionerKind::RoundRobin, 0).is_err());
        assert!(GroupMap::build_over(&[11], 10, 1, PartitionerKind::Latency, 0).is_err());
        let m = GroupMap::build_over(&[3, 7, 9], 10, 2, PartitionerKind::Latency, 0).unwrap();
        assert_eq!(m.num_clients(), 10);
        assert_eq!(m.group_of_checked(0), None);
    }

    #[test]
    fn partitioner_kind_roundtrip() {
        for kind in KINDS {
            assert_eq!(PartitionerKind::parse(kind.name()).unwrap(), kind);
        }
        assert_eq!(PartitionerKind::parse("rr").unwrap(), PartitionerKind::RoundRobin);
        assert!(PartitionerKind::parse("nope").is_err());
    }
}
