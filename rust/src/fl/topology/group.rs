//! Fleet → group assignment: the [`GroupMap`] every topology layer hangs
//! off, built by one of three deterministic partitioners.
//!
//! The simulator has no persistent per-device latency/channel traces (the
//! per-round draws are i.i.d. streams), so the profile-based partitioners
//! derive a fixed per-client *profile score* from the master seed — a
//! stand-in for the device/link profiling data a real deployment would
//! cluster on — and group clients with adjacent scores. What matters for
//! the mechanism is the structure this induces: groups are stable,
//! disjoint, non-empty, and reproducible from the seed alone.

use anyhow::{bail, ensure, Result};

use crate::util::Rng;

/// Per-client profile-score streams (derived from the master seed;
/// disjoint from the coordinator's run-time streams in
/// [`crate::fl::coordinator::streams`]).
mod streams {
    /// Device compute-latency profile.
    pub const LATENCY_PROFILE: u64 = 0x70_1a7;
    /// Uplink channel-quality profile.
    pub const CHANNEL_PROFILE: u64 = 0x70_c4a2;
}

/// How clients are assigned to groups (and cells).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartitionerKind {
    /// Client `c` → group `c mod G`: balanced, profile-blind (the
    /// "by size" baseline).
    RoundRobin,
    /// Contiguous chunks of clients sorted by a seed-derived compute-
    /// latency profile score — groups of similar device speed, so one
    /// straggler only delays its own (slow) group.
    Latency,
    /// Contiguous chunks sorted by a seed-derived channel-quality profile
    /// score — groups of similar uplink SNR, the Air-FedGA alignment
    /// criterion.
    Channel,
}

impl PartitionerKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.trim().to_ascii_lowercase().as_str() {
            "round_robin" | "roundrobin" | "rr" | "size" => PartitionerKind::RoundRobin,
            "latency" => PartitionerKind::Latency,
            "channel" => PartitionerKind::Channel,
            other => bail!("unknown group partitioner {other:?} (round_robin|latency|channel)"),
        })
    }

    /// Canonical name; `parse(name())` round-trips.
    pub fn name(&self) -> &'static str {
        match self {
            PartitionerKind::RoundRobin => "round_robin",
            PartitionerKind::Latency => "latency",
            PartitionerKind::Channel => "channel",
        }
    }
}

/// A disjoint, exhaustive, non-empty partition of the fleet into named
/// groups. Construction enforces the invariants every consumer relies
/// on: every client appears in exactly one group, and no group is empty.
#[derive(Debug, Clone)]
pub struct GroupMap {
    groups: Vec<Vec<usize>>,
    /// client → group index.
    assignment: Vec<usize>,
}

impl GroupMap {
    /// Partition `clients` into `n_groups` with the given partitioner.
    /// Deterministic in `(clients, n_groups, how, seed)`.
    pub fn build(
        clients: usize,
        n_groups: usize,
        how: PartitionerKind,
        seed: u64,
    ) -> Result<Self> {
        ensure!(clients > 0, "group map needs at least one client");
        ensure!(n_groups > 0, "group map needs at least one group");
        ensure!(
            n_groups <= clients,
            "{n_groups} groups over {clients} clients would leave a group empty"
        );

        let mut groups = vec![Vec::new(); n_groups];
        match how {
            PartitionerKind::RoundRobin => {
                for c in 0..clients {
                    groups[c % n_groups].push(c);
                }
            }
            PartitionerKind::Latency | PartitionerKind::Channel => {
                let tag = match how {
                    PartitionerKind::Latency => streams::LATENCY_PROFILE,
                    _ => streams::CHANNEL_PROFILE,
                };
                let mut rng = Rng::with_stream(seed, tag);
                let mut scored: Vec<(f64, usize)> =
                    (0..clients).map(|c| (rng.f64(), c)).collect();
                // Total order: score first, client id as the tiebreak.
                scored.sort_by(|a, b| {
                    a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal).then(a.1.cmp(&b.1))
                });
                // Balanced contiguous chunks: the first `rem` groups get
                // one extra client.
                let base = clients / n_groups;
                let rem = clients % n_groups;
                let mut it = scored.into_iter().map(|(_, c)| c);
                for (g, group) in groups.iter_mut().enumerate() {
                    let size = base + usize::from(g < rem);
                    group.extend(it.by_ref().take(size));
                    group.sort_unstable();
                }
            }
        }

        let mut assignment = vec![usize::MAX; clients];
        for (g, group) in groups.iter().enumerate() {
            ensure!(!group.is_empty(), "partitioner produced an empty group");
            for &c in group {
                ensure!(c < clients, "client {c} out of range");
                ensure!(
                    assignment[c] == usize::MAX,
                    "client {c} assigned to two groups"
                );
                assignment[c] = g;
            }
        }
        ensure!(
            assignment.iter().all(|&g| g != usize::MAX),
            "partitioner left a client unassigned"
        );
        Ok(Self { groups, assignment })
    }

    /// Number of groups.
    pub fn num_groups(&self) -> usize {
        self.groups.len()
    }

    /// Number of clients covered.
    pub fn num_clients(&self) -> usize {
        self.assignment.len()
    }

    /// The members of group `g`, in ascending client order for
    /// round-robin/profile chunks.
    pub fn group(&self, g: usize) -> &[usize] {
        &self.groups[g]
    }

    /// All groups.
    pub fn groups(&self) -> &[Vec<usize>] {
        &self.groups
    }

    /// The group `client` belongs to.
    pub fn group_of(&self, client: usize) -> usize {
        self.assignment[client]
    }

    /// Display name of group `g` (telemetry/debug).
    pub fn name(&self, g: usize) -> String {
        format!("g{g}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const KINDS: [PartitionerKind; 3] = [
        PartitionerKind::RoundRobin,
        PartitionerKind::Latency,
        PartitionerKind::Channel,
    ];

    #[test]
    fn every_client_in_exactly_one_group() {
        for kind in KINDS {
            for (clients, groups) in [(1, 1), (7, 3), (24, 4), (100, 5), (10, 10)] {
                let map = GroupMap::build(clients, groups, kind, 42).unwrap();
                assert_eq!(map.num_groups(), groups);
                assert_eq!(map.num_clients(), clients);
                let mut seen = vec![0usize; clients];
                for g in 0..groups {
                    assert!(!map.group(g).is_empty(), "{kind:?} {clients}x{groups}: empty g{g}");
                    for &c in map.group(g) {
                        seen[c] += 1;
                        assert_eq!(map.group_of(c), g);
                    }
                }
                assert!(seen.iter().all(|&n| n == 1), "{kind:?}: {seen:?}");
            }
        }
    }

    #[test]
    fn groups_are_balanced() {
        for kind in KINDS {
            let map = GroupMap::build(23, 4, kind, 1).unwrap();
            let mut sizes: Vec<usize> = map.groups().iter().map(Vec::len).collect();
            sizes.sort_unstable();
            assert_eq!(sizes, vec![5, 6, 6, 6]);
        }
    }

    #[test]
    fn empty_and_oversized_group_counts_rejected() {
        assert!(GroupMap::build(10, 0, PartitionerKind::RoundRobin, 0).is_err());
        assert!(GroupMap::build(10, 11, PartitionerKind::Latency, 0).is_err());
        assert!(GroupMap::build(0, 1, PartitionerKind::RoundRobin, 0).is_err());
        GroupMap::build(10, 10, PartitionerKind::Channel, 0).unwrap();
    }

    #[test]
    fn profile_partitioners_are_seed_deterministic_and_seed_sensitive() {
        for kind in [PartitionerKind::Latency, PartitionerKind::Channel] {
            let a = GroupMap::build(40, 4, kind, 7).unwrap();
            let b = GroupMap::build(40, 4, kind, 7).unwrap();
            assert_eq!(a.groups(), b.groups());
            let c = GroupMap::build(40, 4, kind, 8).unwrap();
            assert_ne!(a.groups(), c.groups(), "{kind:?} ignored the seed");
        }
        // The two profiles are independent streams.
        let lat = GroupMap::build(40, 4, PartitionerKind::Latency, 7).unwrap();
        let chan = GroupMap::build(40, 4, PartitionerKind::Channel, 7).unwrap();
        assert_ne!(lat.groups(), chan.groups());
    }

    #[test]
    fn partitioner_kind_roundtrip() {
        for kind in KINDS {
            assert_eq!(PartitionerKind::parse(kind.name()).unwrap(), kind);
        }
        assert_eq!(PartitionerKind::parse("rr").unwrap(), PartitionerKind::RoundRobin);
        assert!(PartitionerKind::parse("nope").is_err());
    }
}
