//! Air-FedGA (arXiv 2507.05704) — grouping-asynchronous AirComp as an
//! [`AggregationPolicy`] on the coordinator's periodic timing.
//!
//! The fleet is partitioned into `cfg.topology.groups` groups (see
//! [`GroupMap`]) — over the whole fleet for a flat run, or over one
//! cell's member slice when nested inside a multi-cell hierarchy (the
//! runner drives [`AggregationPolicy::on_membership`], which also
//! rebuilds the map after handover churn). Each ΔT slot:
//!
//! 1. **Group readiness** ([`AggregationPolicy::select_participants`]):
//!    a group *fires* when at least `group_ready_frac` of its members
//!    have finished local training (1.0 = the whole group, the paper's
//!    setting). Ready members of non-fired groups stay pending — they
//!    wait for their group, not for the fleet, which is the whole point:
//!    a straggler only delays its own group.
//! 2. **Per-group OTA pass** ([`AggregationPolicy::on_uploads`] →
//!    [`RoundAction::GroupAggregate`]): every fired group transmits its
//!    members' models in one AirComp `stack`/`coef` pass of its own,
//!    with its own receiver-noise draw. Member transmit powers come from
//!    the configured [`GroupPowerMode`]:
//!    * [`GroupPowerMode::Dinkelbach`] (default) — the paper's
//!      Theorem-1 power program (eq. (25)–(27)) run **per group**, with
//!      the bound's noise term scoped to that group's own OTA pass
//!      (`K` = the group's size, σ² = this pass' AWGN) — the grouped
//!      regime of the PAOTA machinery;
//!    * [`GroupPowerMode::Discounted`] — the legacy staleness-discounted
//!      `p_max·ρ(s_k)` coefficients (ρ = Ω/(s+Ω), eq. (25) with β = 1).
//! 3. **Asynchronous group merge**: the server folds the group aggregates
//!    into the global model, `w ← (1 − Σ_g μ_g)·w + Σ_g μ_g·y_g`, with
//!    `μ_g = group_mix · ρ(s̄_g)` discounted by the group's mean staleness
//!    (and normalized if the fired groups' weights exceed 1).
//!
//! Degenerate corner: with `groups = 1` and `group_ready_frac → 0` this
//! collapses to per-slot semi-async aggregation — the flat regime; the
//! mechanism's value shows up under heterogeneous fleets, where the
//! `latency` partitioner isolates stragglers into their own group.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::channel::Mac;
use crate::config::Config;
use crate::power::{
    solve_power_control, staleness_factor, BoundConstants, ClientFactors, PowerSolverConfig,
};
use crate::util::vecmath;

use super::super::coordinator::{
    AggregationPolicy, GroupPass, RngStreams, RoundAction, RoundTiming, Upload,
};
use super::super::TrainContext;
use super::group::{GroupMap, PartitionerKind};

/// How `air_fedga` allocates member transmit powers inside a group pass.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GroupPowerMode {
    /// Per-group Dinkelbach program (Theorem-1 machinery, noise term
    /// scoped to the group's own OTA pass).
    Dinkelbach,
    /// Staleness-discounted `p_max·ρ(s_k)` (the pre-group-power scheme).
    Discounted,
}

impl GroupPowerMode {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.trim().to_ascii_lowercase().as_str() {
            "dinkelbach" | "optimized" => GroupPowerMode::Dinkelbach,
            "discounted" | "rho" => GroupPowerMode::Discounted,
            other => anyhow::bail!("unknown group power mode {other:?} (dinkelbach|discounted)"),
        })
    }

    /// Canonical name; `parse(name())` round-trips.
    pub fn name(&self) -> &'static str {
        match self {
            GroupPowerMode::Dinkelbach => "dinkelbach",
            GroupPowerMode::Discounted => "discounted",
        }
    }
}

/// Grouping-asynchronous over-the-air aggregation.
pub struct AirFedGa {
    map: GroupMap,
    mac: Mac,
    omega: f64,
    p_max: f64,
    ready_frac: f64,
    group_mix: f64,
    power_mode: GroupPowerMode,
    /// Group-scoped Dinkelbach inputs (k_total is re-scoped per pass).
    consts: BoundConstants,
    solver_cfg: PowerSolverConfig,
    /// w_g^r − w_g^{r−1}: the similarity reference (Dinkelbach mode).
    last_delta: Vec<f32>,
    /// Kept for membership rebuilds.
    groups_cfg: usize,
    partitioner: PartitionerKind,
    seed: u64,
    clients_total: usize,
    dim: usize,
}

impl AirFedGa {
    /// Build from a validated config (`Config::validate` guarantees
    /// `1 ≤ groups ≤ clients`).
    pub fn new(ctx: &TrainContext, cfg: &Config) -> Self {
        let map = GroupMap::build(
            ctx.clients(),
            cfg.topology.groups,
            cfg.topology.partitioner,
            cfg.seed,
        )
        .expect("validated topology config");
        let dim = ctx.dim();
        Self {
            map,
            mac: Mac::new(cfg.channel),
            omega: cfg.omega,
            p_max: cfg.p_max,
            ready_frac: cfg.topology.group_ready_frac,
            group_mix: cfg.topology.group_mix,
            power_mode: cfg.topology.group_power,
            consts: BoundConstants {
                l_smooth: cfg.l_smooth,
                epsilon2: cfg.epsilon2,
                k_total: ctx.clients(), // re-scoped to the group per pass
                dim,
                noise_power: cfg.channel.noise_power(),
                omega: cfg.omega,
            },
            solver_cfg: PowerSolverConfig {
                solver: cfg.solver,
                mip_max_k: cfg.mip_max_k,
                pla_segments: cfg.pla_segments,
                mip_max_nodes: cfg.mip_max_nodes,
                dinkelbach_eps: cfg.dinkelbach_eps,
                dinkelbach_iters: cfg.dinkelbach_iters,
                force_beta: cfg.force_beta,
            },
            last_delta: vec![0.0; dim],
            groups_cfg: cfg.topology.groups,
            partitioner: cfg.topology.partitioner,
            seed: cfg.seed,
            clients_total: ctx.clients(),
            dim,
        }
    }

    /// The fleet partition this policy aggregates over.
    pub fn group_map(&self) -> &GroupMap {
        &self.map
    }

    /// Members a group needs ready before it fires.
    fn quorum(&self, group: usize) -> usize {
        let size = self.map.group(group).len();
        ((self.ready_frac * size as f64).ceil() as usize).clamp(1, size)
    }

    /// One pass' member powers under the configured mode. `group` is the
    /// map group every member of this pass belongs to.
    fn pass_powers(
        &self,
        group: usize,
        members: &[usize],
        uploads: &[Upload],
        rngs: &mut RngStreams,
    ) -> Result<Vec<f32>> {
        match self.power_mode {
            GroupPowerMode::Discounted => {
                let coefs: Vec<f32> = members
                    .iter()
                    .map(|&j| {
                        (self.p_max * staleness_factor(uploads[j].staleness, self.omega)) as f32
                    })
                    .collect();
                Ok(coefs)
            }
            GroupPowerMode::Dinkelbach => {
                let factors: Vec<ClientFactors> = members
                    .iter()
                    .map(|&j| ClientFactors {
                        stale_rounds: uploads[j].staleness,
                        cosine: vecmath::cosine(&uploads[j].delta, &self.last_delta),
                        p_cap: self.p_max,
                    })
                    .collect();
                // The bound's fleet term scoped to THIS group's pass: the
                // group transmits alone, so its aggregation error sees its
                // own K and its own receiver noise.
                let mut consts = self.consts;
                consts.k_total = self.map.group(group).len();
                let alloc =
                    solve_power_control(&factors, &consts, &self.solver_cfg, &mut rngs.opt)?;
                Ok(alloc.powers.iter().map(|&p| p as f32).collect())
            }
        }
    }
}

impl AggregationPolicy for AirFedGa {
    fn name(&self) -> &str {
        "air_fedga"
    }

    fn timing(&self) -> RoundTiming {
        RoundTiming::Periodic
    }

    fn needs_deltas(&self) -> bool {
        // The Dinkelbach program needs the similarity factor θ (cosine of
        // the update against the last global step).
        self.power_mode == GroupPowerMode::Dinkelbach
    }

    fn select_participants(&mut self, offered: &[usize], _rngs: &mut RngStreams) -> Vec<usize> {
        let mut ready = vec![0usize; self.map.num_groups()];
        for &c in offered {
            ready[self.map.group_of(c)] += 1;
        }
        let fired: Vec<bool> = (0..self.map.num_groups())
            .map(|g| ready[g] >= self.quorum(g))
            .collect();
        offered
            .iter()
            .copied()
            .filter(|&c| fired[self.map.group_of(c)])
            .collect()
    }

    fn on_uploads(
        &mut self,
        _round: usize,
        _global: &[f32],
        uploads: &[Upload],
        rngs: &mut RngStreams,
    ) -> Result<RoundAction> {
        // Bucket upload indices by group (BTreeMap: deterministic group
        // order for the per-pass channel-noise and solver draws).
        let mut buckets: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for (j, up) in uploads.iter().enumerate() {
            buckets.entry(self.map.group_of(up.client)).or_default().push(j);
        }

        let mut passes = Vec::with_capacity(buckets.len());
        for (group, members) in buckets {
            let coefs = self.pass_powers(group, &members, uploads, rngs)?;
            let mean_power =
                coefs.iter().map(|&c| c as f64).sum::<f64>() / members.len() as f64;
            // Each group is its own OTA transmission → its own AWGN draw.
            let noise = self.mac.channel_noise(&mut rngs.channel, self.dim);
            let mean_staleness = members
                .iter()
                .map(|&j| uploads[j].staleness as f64)
                .sum::<f64>()
                / members.len() as f64;
            let mix = self.group_mix * self.omega / (mean_staleness + self.omega);
            passes.push(GroupPass {
                members,
                coefs,
                noise,
                mix,
                mean_power,
            });
        }
        // Keep the merge convex when many groups fire at once.
        let total: f64 = passes.iter().map(|p| p.mix).sum();
        if total > 1.0 {
            for p in &mut passes {
                p.mix /= total;
            }
        }
        Ok(RoundAction::GroupAggregate { passes })
    }

    fn on_global_delta(&mut self, delta: &[f32]) {
        self.last_delta.copy_from_slice(delta);
    }

    /// Rebuild the group map over a cell's member slice — called by the
    /// multi-cell runner at construction and after handover churn. Group
    /// count is clamped to the slice size; an empty slice keeps the old
    /// map (the cell offers no one, so the map is never consulted).
    fn on_membership(&mut self, members: &[usize]) {
        if members.is_empty() {
            return;
        }
        let groups = self.groups_cfg.clamp(1, members.len());
        self.map = GroupMap::build_over(
            members,
            self.clients_total,
            groups,
            self.partitioner,
            self.seed,
        )
        .expect("member slice within the fleet");
    }
}
