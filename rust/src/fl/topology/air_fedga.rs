//! Air-FedGA (arXiv 2507.05704) — grouping-asynchronous AirComp as an
//! [`AggregationPolicy`] on the coordinator's periodic timing.
//!
//! The fleet is partitioned once into `cfg.topology.groups` groups (see
//! [`GroupMap`]). Each ΔT slot:
//!
//! 1. **Group readiness** ([`AggregationPolicy::select_participants`]):
//!    a group *fires* when at least `group_ready_frac` of its members
//!    have finished local training (1.0 = the whole group, the paper's
//!    setting). Ready members of non-fired groups stay pending — they
//!    wait for their group, not for the fleet, which is the whole point:
//!    a straggler only delays its own group.
//! 2. **Per-group OTA pass** ([`AggregationPolicy::on_uploads`] →
//!    [`RoundAction::GroupAggregate`]): every fired group transmits its
//!    members' models in one AirComp `stack`/`coef` pass of its own, with
//!    its own receiver-noise draw and staleness-discounted coefficients
//!    `p_max·ρ(s_k)` (ρ = Ω/(s+Ω), eq. (25) of the PAOTA paper).
//! 3. **Asynchronous group merge**: the server folds the group aggregates
//!    into the global model, `w ← (1 − Σ_g μ_g)·w + Σ_g μ_g·y_g`, with
//!    `μ_g = group_mix · ρ(s̄_g)` discounted by the group's mean staleness
//!    (and normalized if the fired groups' weights exceed 1).
//!
//! Degenerate corner: with `groups = 1` and `group_ready_frac → 0` this
//! collapses to per-slot semi-async aggregation — the flat regime; the
//! mechanism's value shows up under heterogeneous fleets, where the
//! `latency` partitioner isolates stragglers into their own group.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::channel::Mac;
use crate::config::Config;
use crate::power::staleness_factor;

use super::super::coordinator::{
    AggregationPolicy, GroupPass, RngStreams, RoundAction, RoundTiming, Upload,
};
use super::super::TrainContext;
use super::group::GroupMap;

/// Grouping-asynchronous over-the-air aggregation.
pub struct AirFedGa {
    map: GroupMap,
    mac: Mac,
    omega: f64,
    p_max: f64,
    ready_frac: f64,
    group_mix: f64,
    dim: usize,
}

impl AirFedGa {
    /// Build from a validated config (`Config::validate` guarantees
    /// `1 ≤ groups ≤ clients`).
    pub fn new(ctx: &TrainContext, cfg: &Config) -> Self {
        let map = GroupMap::build(
            ctx.clients(),
            cfg.topology.groups,
            cfg.topology.partitioner,
            cfg.seed,
        )
        .expect("validated topology config");
        Self {
            map,
            mac: Mac::new(cfg.channel),
            omega: cfg.omega,
            p_max: cfg.p_max,
            ready_frac: cfg.topology.group_ready_frac,
            group_mix: cfg.topology.group_mix,
            dim: ctx.dim(),
        }
    }

    /// The fleet partition this policy aggregates over.
    pub fn group_map(&self) -> &GroupMap {
        &self.map
    }

    /// Members a group needs ready before it fires.
    fn quorum(&self, group: usize) -> usize {
        let size = self.map.group(group).len();
        ((self.ready_frac * size as f64).ceil() as usize).clamp(1, size)
    }
}

impl AggregationPolicy for AirFedGa {
    fn name(&self) -> &str {
        "air_fedga"
    }

    fn timing(&self) -> RoundTiming {
        RoundTiming::Periodic
    }

    fn select_participants(&mut self, offered: &[usize], _rngs: &mut RngStreams) -> Vec<usize> {
        let mut ready = vec![0usize; self.map.num_groups()];
        for &c in offered {
            ready[self.map.group_of(c)] += 1;
        }
        let fired: Vec<bool> = (0..self.map.num_groups())
            .map(|g| ready[g] >= self.quorum(g))
            .collect();
        offered
            .iter()
            .copied()
            .filter(|&c| fired[self.map.group_of(c)])
            .collect()
    }

    fn on_uploads(
        &mut self,
        _round: usize,
        _global: &[f32],
        uploads: &[Upload],
        rngs: &mut RngStreams,
    ) -> Result<RoundAction> {
        // Bucket upload indices by group (BTreeMap: deterministic group
        // order for the per-pass channel-noise draws).
        let mut buckets: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for (j, up) in uploads.iter().enumerate() {
            buckets.entry(self.map.group_of(up.client)).or_default().push(j);
        }

        let mut passes = Vec::with_capacity(buckets.len());
        for members in buckets.into_values() {
            let coefs: Vec<f32> = members
                .iter()
                .map(|&j| (self.p_max * staleness_factor(uploads[j].staleness, self.omega)) as f32)
                .collect();
            let mean_power =
                coefs.iter().map(|&c| c as f64).sum::<f64>() / members.len() as f64;
            // Each group is its own OTA transmission → its own AWGN draw.
            let noise = self.mac.channel_noise(&mut rngs.channel, self.dim);
            let mean_staleness = members
                .iter()
                .map(|&j| uploads[j].staleness as f64)
                .sum::<f64>()
                / members.len() as f64;
            let mix = self.group_mix * self.omega / (mean_staleness + self.omega);
            passes.push(GroupPass {
                members,
                coefs,
                noise,
                mix,
                mean_power,
            });
        }
        // Keep the merge convex when many groups fire at once.
        let total: f64 = passes.iter().map(|p| p.mix).sum();
        if total > 1.0 {
            for p in &mut passes {
                p.mix /= total;
            }
        }
        Ok(RoundAction::GroupAggregate { passes })
    }
}
