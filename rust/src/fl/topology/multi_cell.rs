//! Multi-cell hierarchical AirComp: several [`Coordinator`]s — one per
//! cell, each owning a disjoint slice of the fleet — advance in lock-step
//! ΔT slots over **one shared [`TrainContext`]** (same data partition,
//! same PJRT/native train pool), with a pluggable [`InterCellMixing`]
//! policy merging the cell models between slots.
//!
//! Determinism: cell 0 runs on the base seed (so a 1-cell run is
//! *bitwise* the flat run — covered by `tests/golden_seed.rs`), every
//! further cell derives an independent seed, and each cell's coordinator
//! keeps its own per-purpose RNG streams. Client → cell assignment is a
//! [`GroupMap`] built with the configured partitioner.
//!
//! Telemetry: per-cell [`RunResult`]s keep the canonical stream shape,
//! and a **merged** stream (participant-weighted window stats; eval of
//! the cloud model — the uniform mean of the cell models) makes
//! hierarchical runs directly comparable to flat ones in campaigns.
//!
//! Parallelism: between mixing points the cells are fully independent —
//! each owns its coordinator, policy and RNG streams. On the thread-safe
//! native backend with `perf.workers > 1` every slot steps all cells
//! **concurrently** (one scoped thread per cell; training jobs from all
//! cells funnel into the one shared train pool), which is bitwise
//! identical to the serial sweep because no state crosses cells until
//! the runner mixes models after the step (`tests/golden_seed.rs`
//! asserts the equivalence).
//!
//! Mobility ([`crate::fl::mobility`]): when `cfg.mobility` selects a
//! roaming model, the runner consults it at slot boundaries (every
//! `handover_every` slots, on the runner thread — between the concurrent
//! cell steps, so the sweep is bitwise independent of `workers`/`--jobs`)
//! and hands movers over between the cells' event queues under the
//! configured [`HandoverPolicy`]. With `mobility = static` the sweep
//! finds zero movers and touches nothing — the run is **bitwise** the
//! frozen-assignment run (`tests/mobility.rs`). Residence-coupled
//! channels: `mobility.cell_noise_spread_db` spreads the per-cell noise
//! floors linearly over ±spread/2 dB around the configured N₀, so a
//! handover re-draws the client's uplink from the new cell's
//! [`crate::channel::ChannelConfig`] scope.

use anyhow::{ensure, Result};

use crate::config::{Algorithm, Config};
use crate::fl::coordinator::{
    AggregationPolicy, Coordinator, RngStreams, RoundAction, RoundTiming, Telemetry, Upload,
    WindowStats,
};
use crate::fl::mobility::{self, HandoverPolicy, MobilityStats};
use crate::fl::{registry, RunResult, TrainContext};
use crate::obs::metrics::{self, Gauge};
use crate::obs::trace::{TraceSink, V};
use crate::util::Rng;

use super::group::GroupMap;

/// Inter-cell model-mixing policy: called after every closed ΔT slot
/// with each cell's current global model; mutate the slice in place to
/// mix. Implementations decide their own cadence.
pub trait InterCellMixing {
    /// Display name (telemetry/debug).
    fn name(&self) -> &str;

    /// Whether this policy will act after slot `round` closes — lets the
    /// runner skip the per-cell model snapshot/write-back entirely on
    /// off-cadence rounds. Defaults to always.
    fn mixes_at(&self, round: usize) -> bool {
        let _ = round;
        true
    }

    /// `round` is the slot that just closed (0-based); `cells[c]` is cell
    /// `c`'s current global model.
    fn mix(&mut self, round: usize, cells: &mut [Vec<f32>]);
}

/// Cloud FedAvg: every `every` slots, replace every cell model with the
/// fleet-uniform mean — a two-level hierarchy with a lossless backhaul.
#[derive(Debug, Clone)]
pub struct CloudFedAvg {
    pub every: usize,
}

impl InterCellMixing for CloudFedAvg {
    fn name(&self) -> &str {
        "cloud"
    }

    fn mixes_at(&self, round: usize) -> bool {
        (round + 1) % self.every == 0
    }

    fn mix(&mut self, round: usize, cells: &mut [Vec<f32>]) {
        if cells.len() < 2 || (round + 1) % self.every != 0 {
            return;
        }
        let mean = mean_models(cells);
        for cell in cells.iter_mut() {
            cell.copy_from_slice(&mean);
        }
    }
}

/// Decentralized pairwise gossip: every `every` slots, neighboring cells
/// (on a ring whose origin rotates each mixing event) average pairwise —
/// no cloud, information diffuses in O(cells) mixing events.
#[derive(Debug, Clone)]
pub struct PairwiseGossip {
    pub every: usize,
}

impl InterCellMixing for PairwiseGossip {
    fn name(&self) -> &str {
        "gossip"
    }

    fn mixes_at(&self, round: usize) -> bool {
        (round + 1) % self.every == 0
    }

    fn mix(&mut self, round: usize, cells: &mut [Vec<f32>]) {
        let n = cells.len();
        if n < 2 || (round + 1) % self.every != 0 {
            return;
        }
        // Rotate the pairing origin so every adjacency is exercised.
        let offset = ((round + 1) / self.every) % n;
        let mut k = 0;
        while k + 1 < n {
            let i = (offset + k) % n;
            let j = (offset + k + 1) % n;
            let mid: Vec<f32> = cells[i]
                .iter()
                .zip(&cells[j])
                .map(|(&a, &b)| ((a as f64 + b as f64) * 0.5) as f32)
                .collect();
            cells[i].copy_from_slice(&mid);
            cells[j].copy_from_slice(&mid);
            k += 2;
        }
    }
}

/// No inter-cell communication (isolated cells; ablation baseline).
#[derive(Debug, Clone)]
pub struct NoMixing;

impl InterCellMixing for NoMixing {
    fn name(&self) -> &str {
        "none"
    }

    fn mixes_at(&self, _round: usize) -> bool {
        false
    }

    fn mix(&mut self, _round: usize, _cells: &mut [Vec<f32>]) {}
}

/// Config-selectable inter-cell mixing scheme.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MixingKind {
    None,
    Cloud,
    Gossip,
}

impl MixingKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.trim().to_ascii_lowercase().as_str() {
            "none" | "off" => MixingKind::None,
            "cloud" | "fedavg" => MixingKind::Cloud,
            "gossip" | "pairwise" => MixingKind::Gossip,
            other => anyhow::bail!("unknown mixing scheme {other:?} (none|cloud|gossip)"),
        })
    }

    /// Canonical name; `parse(name())` round-trips.
    pub fn name(&self) -> &'static str {
        match self {
            MixingKind::None => "none",
            MixingKind::Cloud => "cloud",
            MixingKind::Gossip => "gossip",
        }
    }

    /// Instantiate the mixing policy at cadence `every`.
    pub fn build(&self, every: usize) -> Box<dyn InterCellMixing> {
        match self {
            MixingKind::None => Box::new(NoMixing),
            MixingKind::Cloud => Box::new(CloudFedAvg { every }),
            MixingKind::Gossip => Box::new(PairwiseGossip { every }),
        }
    }
}

/// A complete hierarchical run: every cell's canonical record stream plus
/// the merged (cloud-level) stream campaigns compare against flat runs,
/// and the handover churn the runner actually applied.
#[derive(Debug, Clone)]
pub struct MultiCellResult {
    pub cells: Vec<RunResult>,
    pub merged: RunResult,
    /// Applied handover telemetry (all-zero for `mobility = static`).
    pub mobility: MobilityStats,
}

/// Restricts a policy to one cell's members: `offered` is intersected
/// with the membership mask before the inner policy selects. With a
/// single all-member cell the filter is the identity, so the 1-cell
/// hierarchy stays bitwise the flat run. The mask is **mutable**: the
/// mobility sweep flips it at handover and then replays the member slice
/// into the inner policy ([`AggregationPolicy::on_membership`]) so
/// grouped policies re-partition over the churned slice.
struct CellPolicy {
    inner: Box<dyn AggregationPolicy>,
    member: Vec<bool>,
}

impl CellPolicy {
    fn new(inner: Box<dyn AggregationPolicy>, members: &[usize], clients: usize) -> Self {
        let mut cell = Self {
            inner,
            member: vec![false; clients],
        };
        // One membership path for construction and churn alike: set the
        // mask and scope the inner policy to this cell's slice
        // (air_fedga builds its group map over the members it serves).
        cell.on_membership(members);
        cell
    }

    fn set_member(&mut self, client: usize, is_member: bool) {
        self.member[client] = is_member;
    }

    fn members(&self) -> Vec<usize> {
        (0..self.member.len()).filter(|&c| self.member[c]).collect()
    }

    fn member_count(&self) -> usize {
        self.member.iter().filter(|&&m| m).count()
    }

    /// Replay the (churned) member slice into the inner policy.
    fn refresh_membership(&mut self) {
        let members = self.members();
        self.inner.on_membership(&members);
    }
}

impl AggregationPolicy for CellPolicy {
    fn name(&self) -> &str {
        self.inner.name()
    }

    fn timing(&self) -> RoundTiming {
        self.inner.timing()
    }

    fn batch_stream(&self) -> u64 {
        self.inner.batch_stream()
    }

    fn needs_deltas(&self) -> bool {
        self.inner.needs_deltas()
    }

    fn select_participants(&mut self, offered: &[usize], rngs: &mut RngStreams) -> Vec<usize> {
        let mine: Vec<usize> = offered.iter().copied().filter(|&c| self.member[c]).collect();
        self.inner.select_participants(&mine, rngs)
    }

    fn make_job(
        &self,
        client: usize,
        base: &[f32],
        ctx: &TrainContext,
        batch_rng: &mut Rng,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        self.inner.make_job(client, base, ctx, batch_rng)
    }

    fn on_uploads(
        &mut self,
        round: usize,
        global: &[f32],
        uploads: &[Upload],
        rngs: &mut RngStreams,
    ) -> Result<RoundAction> {
        self.inner.on_uploads(round, global, uploads, rngs)
    }

    fn on_global_delta(&mut self, delta: &[f32]) {
        self.inner.on_global_delta(delta);
    }

    fn on_membership(&mut self, members: &[usize]) {
        self.member.iter_mut().for_each(|m| *m = false);
        for &c in members {
            self.member[c] = true;
        }
        self.inner.on_membership(members);
    }
}

/// Drives `cfg.topology.cells` coordinators in lock-step with the
/// config's mixing policy (override via [`MultiCellRunner::with_mixing`]).
pub struct MultiCellRunner<'a> {
    ctx: &'a TrainContext,
    cfg: &'a Config,
    mixing: Box<dyn InterCellMixing>,
}

impl<'a> MultiCellRunner<'a> {
    pub fn new(ctx: &'a TrainContext, cfg: &'a Config) -> Self {
        let mixing = cfg.topology.mixing.build(cfg.topology.mixing_every);
        Self { ctx, cfg, mixing }
    }

    /// Swap in a custom inter-cell mixing policy.
    pub fn with_mixing(mut self, mixing: Box<dyn InterCellMixing>) -> Self {
        self.mixing = mixing;
        self
    }

    pub fn run(mut self) -> Result<MultiCellResult> {
        run_with_mixing(self.ctx, self.cfg, self.mixing.as_mut())
    }
}

/// Run the hierarchical topology the config describes (config-selected
/// mixing).
pub fn run(ctx: &TrainContext, cfg: &Config) -> Result<MultiCellResult> {
    MultiCellRunner::new(ctx, cfg).run()
}

/// Run with an explicit mixing policy.
pub fn run_with_mixing(
    ctx: &TrainContext,
    cfg: &Config,
    mixing: &mut dyn InterCellMixing,
) -> Result<MultiCellResult> {
    cfg.validate()?;
    let n = cfg.topology.cells;
    let k = ctx.clients();
    let map = GroupMap::build(k, n, cfg.topology.partitioner, cfg.seed)?;

    // Per-cell configs: cell 0 keeps the base seed (the 1-cell degeneracy
    // contract), every further cell derives an independent one. The
    // residence-coupled channel scope spreads the cells' noise floors
    // linearly over ±spread/2 dB around the configured N₀ (spread = 0
    // keeps every cell bitwise on the base channel).
    let spread = cfg.mobility.cell_noise_spread_db;
    let cell_cfgs: Vec<Config> = (0..n)
        .map(|c| {
            let mut cc = cfg.clone();
            if c > 0 {
                cc.seed = cfg.seed ^ (c as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
            }
            if spread != 0.0 && n > 1 {
                let offset = spread * (c as f64 / (n as f64 - 1.0) - 0.5);
                cc.channel = cc.channel.with_n0_offset(offset);
            }
            cc
        })
        .collect();

    let mut policies: Vec<CellPolicy> = Vec::with_capacity(n);
    for (c, cc) in cell_cfgs.iter().enumerate() {
        // Policies are built on the BASE seed: the only constructor that
        // consumes it is air_fedga's profile-scored GroupMap, and profile
        // scores are device properties — fleet-global, so a client keeps
        // its score whichever cell it roams to (the `build_over`
        // stability contract). The coordinator's runtime RNG streams
        // still derive from the cell-specific seed in `cc`; flat policies
        // never read the seed, so this is bitwise-invisible to them.
        let mut pc = cc.clone();
        pc.seed = cfg.seed;
        let inner = registry::build(cfg.algorithm.name(), ctx, &pc)?;
        ensure!(
            inner.timing() == RoundTiming::Periodic,
            "multi-cell topology drives periodic-timing policies; {:?} is not",
            inner.name()
        );
        policies.push(CellPolicy::new(inner, map.group(c), k));
    }
    let mut coords: Vec<Coordinator> = cell_cfgs
        .iter()
        .zip(&policies)
        .map(|(cc, p)| Coordinator::new(ctx, cc, p.batch_stream()))
        .collect();
    for coord in &mut coords {
        coord.begin_periodic();
    }

    // Mobility: the client → cell assignment as a function of slot time.
    // The model is consulted on the runner thread between slot steps, so
    // the sweep is bitwise independent of workers/jobs; with the static
    // model every sweep finds zero movers and mutates nothing.
    let mut model = mobility::build_model(cfg, &map)?;
    let mut assignment: Vec<usize> = (0..k).map(|c| map.group_of(c)).collect();
    // Deliver-policy deferrals: (target cell, base_round at defer time) —
    // the move completes once the old cell served the stale upload.
    let mut deferred: Vec<Option<(usize, usize)>> = vec![None; k];
    let mut mob_stats = MobilityStats::new(n, k);

    // Runner-level observability: handover trace events (the runner —
    // not any one cell — owns the hop) and per-cell member gauges.
    // Read-only with respect to the sweep; cells share the journal path
    // safely (O_APPEND, one write per line).
    let trace = match TraceSink::from_cfg(&cfg.obs) {
        Ok(t) => t,
        Err(e) => {
            crate::debug!("obs: trace journal disabled: {e:#}");
            None
        }
    };
    let member_gauges: Vec<Gauge> = (0..n)
        .map(|c| metrics::global().gauge(&format!("paota_cell_members{{cell=\"{c}\"}}")))
        .collect();

    // The merged (cloud-level) stream only exists for true hierarchies;
    // a 1-cell run's merged stream IS its cell stream.
    let mut merged_tel = (n > 1).then(|| Telemetry::new(cfg.rounds, cfg.eval_every));

    // Cells inside one slot are independent between mixing points; step
    // them concurrently when the backend is thread-safe and the config
    // asked for parallelism at all. Bitwise-identical either way.
    let parallel_cells = n > 1 && ctx.rt.is_native() && cfg.perf.workers > 1;

    for round in 0..cfg.rounds {
        if parallel_cells {
            std::thread::scope(|scope| -> Result<()> {
                let mut handles = Vec::with_capacity(n);
                for (coord, policy) in coords.iter_mut().zip(policies.iter_mut()) {
                    let cell = scope.spawn(move || coord.step_periodic(policy, round));
                    handles.push(cell);
                }
                for handle in handles {
                    handle.join().expect("cell thread panicked")?;
                }
                Ok(())
            })?;
        } else {
            for (coord, policy) in coords.iter_mut().zip(policies.iter_mut()) {
                coord.step_periodic(policy, round)?;
            }
        }
        handover_sweep(
            cfg,
            round,
            model.as_mut(),
            &mut coords,
            &mut policies,
            &mut assignment,
            &mut deferred,
            &mut mob_stats,
            trace.as_ref(),
        )?;
        if let Some(row) = mob_stats.per_round_members.last() {
            for (gauge, &count) in member_gauges.iter().zip(row) {
                gauge.set(count as i64);
            }
        }
        if n > 1 && mixing.mixes_at(round) {
            let mut models: Vec<Vec<f32>> =
                coords.iter().map(|c| c.global_weights().to_vec()).collect();
            mixing.mix(round, &mut models);
            for (coord, model) in coords.iter_mut().zip(&models) {
                coord.set_global_weights(model);
            }
        }
        if let Some(tel) = merged_tel.as_mut() {
            let slot_end = (round as f64 + 1.0) * cfg.delta_t;
            let mut stats = WindowStats::default();
            let mut power_weighted = 0.0f64;
            for coord in &coords {
                let rec = &coord.records()[round];
                if rec.participants > 0 {
                    stats.uploads += rec.participants;
                    stats.loss_sum += rec.train_loss as f64 * rec.participants as f64;
                    stats.staleness_sum += rec.mean_staleness * rec.participants as f64;
                    power_weighted += rec.mean_power * rec.participants as f64;
                }
            }
            if stats.uploads > 0 {
                stats.mean_power = power_weighted / stats.uploads as f64;
            }
            // Cloud model: uniform mean of the (post-mixing) cell models.
            let (eval, probe) = if tel.should_eval(round) {
                let cloud = mean_cell_models(&coords);
                (Some(ctx.evaluate(&cloud)?), Some(ctx.probe_loss(&cloud)?))
            } else {
                (None, None)
            };
            tel.record(round, slot_end, stats, eval, probe);
        }
    }

    let cells: Vec<RunResult> = coords
        .into_iter()
        .zip(&policies)
        .map(|(coord, p)| coord.into_result(Algorithm::raw(p.name())))
        .collect();
    let merged = match merged_tel {
        None => cells[0].clone(),
        Some(tel) => {
            let mut final_weights = vec![0.0f64; cells[0].final_weights.len()];
            for cell in &cells {
                for (acc, &v) in final_weights.iter_mut().zip(&cell.final_weights) {
                    *acc += v as f64;
                }
            }
            let inv = 1.0 / cells.len() as f64;
            RunResult {
                algorithm: cfg.algorithm.clone(),
                records: tel.into_records(),
                final_weights: final_weights.iter().map(|&a| (a * inv) as f32).collect(),
            }
        }
    };
    Ok(MultiCellResult {
        cells,
        merged,
        mobility: mob_stats,
    })
}

/// One slot boundary of the mobility protocol: complete any deferred
/// `deliver` moves whose stale upload landed, then (on the
/// `handover_every` cadence) consult the model and hand new movers over
/// under the configured policy. Runs strictly between cell steps on the
/// runner thread — no coordinator is mid-slot — so detaching a mover
/// never disturbs another client's slot, stream or queued event.
#[allow(clippy::too_many_arguments)]
fn handover_sweep(
    cfg: &Config,
    round: usize,
    model: &mut dyn mobility::MobilityModel,
    coords: &mut [Coordinator],
    policies: &mut [CellPolicy],
    assignment: &mut [usize],
    deferred: &mut [Option<(usize, usize)>],
    stats: &mut MobilityStats,
    trace: Option<&TraceSink>,
) -> Result<()> {
    // Apply one membership flip to the masks, the authoritative
    // assignment, the churn markers and the stats.
    #[allow(clippy::too_many_arguments)]
    fn flip(
        c: usize,
        from: usize,
        to: usize,
        round: usize,
        slot_end: f64,
        assignment: &mut [usize],
        policies: &mut [CellPolicy],
        churned: &mut [bool],
        stats: &mut MobilityStats,
        trace: Option<&TraceSink>,
    ) {
        policies[from].set_member(c, false);
        policies[to].set_member(c, true);
        assignment[c] = to;
        churned[from] = true;
        churned[to] = true;
        stats.record_move(c, from, to);
        if let Some(tr) = trace {
            tr.emit(
                "handover",
                Some(slot_end),
                &[
                    ("round", V::U(round as u64)),
                    ("client", V::U(c as u64)),
                    ("from", V::U(from as u64)),
                    ("to", V::U(to as u64)),
                ],
            );
        }
    }
    let slot_end = (round as f64 + 1.0) * cfg.delta_t;

    let k = assignment.len();
    let n = coords.len();
    stats.per_round_moves.push(0);
    let mut churned = vec![false; n];

    // 1. Deferred deliver moves: the old cell bumps the client's base
    //    round when it serves the upload — the stale update has landed
    //    OTA there; complete the move with a fresh spawn in the new cell.
    for c in 0..k {
        if let Some((to, base_at_defer)) = deferred[c] {
            let from = assignment[c];
            if coords[from].client_base_round(c) > base_at_defer {
                let slow = coords[from].detach_client_discarding(c);
                coords[to].admit_fresh(c, round, slow);
                flip(
                    c, from, to, round, slot_end, assignment, policies, &mut churned, stats,
                    trace,
                );
                stats.delivered += 1;
                deferred[c] = None;
            }
        }
    }

    // 2. New moves, on the handover cadence (one shared cadence rule
    //    with the trace replay — `mobility::advanced_target`). The model
    //    advances through every intermediate slot internally, so the
    //    trajectory itself is cadence-independent.
    if let Some(target) = mobility::advanced_target(cfg, model, round) {
        for c in 0..k {
            let to = target[c];
            if let Some((_, base)) = deferred[c] {
                // Retarget (or cancel) an in-progress deliver move.
                deferred[c] = if to == assignment[c] { None } else { Some((to, base)) };
                continue;
            }
            let from = assignment[c];
            if to == from {
                continue;
            }
            match cfg.mobility.handover {
                HandoverPolicy::Deliver => {
                    deferred[c] = Some((to, coords[from].client_base_round(c)));
                }
                HandoverPolicy::Forward => {
                    let d = coords[from].detach_client(c);
                    coords[to].admit_client(c, d);
                    flip(
                        c, from, to, round, slot_end, assignment, policies, &mut churned,
                        stats, trace,
                    );
                }
                HandoverPolicy::Drop => {
                    let slow = coords[from].detach_client_discarding(c);
                    coords[to].admit_fresh(c, round, slow);
                    flip(
                        c, from, to, round, slot_end, assignment, policies, &mut churned,
                        stats, trace,
                    );
                }
            }
        }
    }

    // 3. Re-partition churned cells' group maps over their new slices.
    for (cell, dirty) in churned.iter().enumerate() {
        if *dirty {
            policies[cell].refresh_membership();
        }
    }

    // 4. Conservation snapshot: the masks must partition the fleet.
    let members: Vec<usize> = policies.iter().map(|p| p.member_count()).collect();
    ensure!(
        members.iter().sum::<usize>() == k,
        "handover broke fleet conservation: cell members {members:?} != {k} clients"
    );
    stats.per_round_members.push(members);
    Ok(())
}

/// f64-accumulated uniform mean of a model set.
fn mean_models(models: &[Vec<f32>]) -> Vec<f32> {
    let dim = models[0].len();
    let mut acc = vec![0.0f64; dim];
    for model in models {
        for (a, &v) in acc.iter_mut().zip(model) {
            *a += v as f64;
        }
    }
    let inv = 1.0 / models.len() as f64;
    acc.iter().map(|&a| (a * inv) as f32).collect()
}

/// Uniform mean of the coordinators' current global models.
fn mean_cell_models(coords: &[Coordinator<'_>]) -> Vec<f32> {
    let models: Vec<Vec<f32>> = coords.iter().map(|c| c.global_weights().to_vec()).collect();
    mean_models(&models)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cloud_fedavg_replaces_all_with_mean_on_cadence() {
        let mut m = CloudFedAvg { every: 2 };
        let mut cells = vec![vec![0.0f32, 2.0], vec![4.0f32, 6.0]];
        m.mix(0, &mut cells); // slot 1: off-cadence
        assert_eq!(cells[0], vec![0.0, 2.0]);
        m.mix(1, &mut cells); // slot 2: mix
        assert_eq!(cells[0], vec![2.0, 4.0]);
        assert_eq!(cells[0], cells[1]);
    }

    #[test]
    fn gossip_averages_disjoint_pairs_and_rotates() {
        let mut m = PairwiseGossip { every: 1 };
        let mut cells = vec![vec![0.0f32], vec![8.0f32], vec![100.0f32]];
        // round 0 → offset 1: pair (1,2); cell 0 sits out.
        m.mix(0, &mut cells);
        assert_eq!(cells[0], vec![0.0]);
        assert_eq!(cells[1], vec![54.0]);
        assert_eq!(cells[2], vec![54.0]);
        // round 1 → offset 2: pair (2,0).
        m.mix(1, &mut cells);
        assert_eq!(cells[0], vec![27.0]);
        assert_eq!(cells[2], vec![27.0]);
        assert_eq!(cells[1], vec![54.0]);
    }

    #[test]
    fn single_cell_mixing_is_identity() {
        let mut cells = vec![vec![1.0f32, 2.0]];
        CloudFedAvg { every: 1 }.mix(0, &mut cells);
        PairwiseGossip { every: 1 }.mix(0, &mut cells);
        NoMixing.mix(0, &mut cells);
        assert_eq!(cells[0], vec![1.0, 2.0]);
    }

    #[test]
    fn mixing_kind_roundtrip_and_build() {
        for kind in [MixingKind::None, MixingKind::Cloud, MixingKind::Gossip] {
            assert_eq!(MixingKind::parse(kind.name()).unwrap(), kind);
            assert_eq!(kind.build(3).name(), kind.name());
        }
        assert_eq!(MixingKind::parse("fedavg").unwrap(), MixingKind::Cloud);
        assert!(MixingKind::parse("nope").is_err());
    }

    #[test]
    fn mixes_at_matches_the_cadence() {
        // The runner snapshots cell models only when mixes_at says so —
        // it must agree with each policy's internal cadence guard.
        let cloud = CloudFedAvg { every: 3 };
        assert!(!cloud.mixes_at(0));
        assert!(!cloud.mixes_at(1));
        assert!(cloud.mixes_at(2));
        assert!(cloud.mixes_at(5));
        let gossip = PairwiseGossip { every: 2 };
        assert!(!gossip.mixes_at(0));
        assert!(gossip.mixes_at(1));
        assert!(!NoMixing.mixes_at(0));
        assert!(!NoMixing.mixes_at(7));
    }
}
