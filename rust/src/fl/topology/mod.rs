//! `fl::topology` — the **aggregation tree** over the flat coordinator
//! core: grouped AirComp inside a cell, and multi-cell hierarchies above
//! it.
//!
//! PAOTA (the source paper) aggregates one flat fleet at one parameter
//! server. This module composes that core into the deployment shapes the
//! Air-FEEL literature targets (Air-FedGA, arXiv 2507.05704; the Air-FEEL
//! overview, arXiv 2208.05643) without touching the round loop:
//!
//! ```text
//!                 cloud / gossip fabric          (InterCellMixing)
//!                /         |         \
//!           cell 0      cell 1      cell 2       (MultiCellRunner:
//!          Coordinator Coordinator Coordinator    one per cell, lock-step
//!            |    \       |    \      |           ΔT slots, shared
//!          g0      g1   g0      g1   g0  g1       TrainContext/TrainPool)
//!         clients ...   clients ...  clients      (GroupMap partition)
//! ```
//!
//! * **Layer 1 — groups** ([`group`], [`air_fedga`]): a [`GroupMap`]
//!   partitions the fleet (by size, or by seed-derived latency/channel
//!   profiles), and the registered `air_fedga` policy fires one AirComp
//!   `stack`/`coef` pass per group *when that group's members are ready*,
//!   merging the group aggregates asynchronously with staleness-
//!   discounted weights ([`crate::fl::coordinator::RoundAction::GroupAggregate`]).
//! * **Layer 2 — cells** ([`multi_cell`]): a [`MultiCellRunner`] drives
//!   one [`Coordinator`](crate::fl::Coordinator) per cell over disjoint
//!   client slices of one shared [`TrainContext`](crate::fl::TrainContext)
//!   (per-cell RNG streams, cell 0 on the base seed), with a pluggable
//!   [`InterCellMixing`] fabric — cloud FedAvg every K slots, or pairwise
//!   gossip — and a merged telemetry stream so hierarchical
//!   [`RoundRecord`](crate::fl::RoundRecord) series stay comparable to
//!   flat runs.
//!
//! Everything is driven from `Config`'s `[topology]` surface (`cells`,
//! `groups`, `group_partitioner`, `mixing`, `mixing_every`,
//! `group_ready_frac`, `group_mix`): `fl::run` routes through
//! [`multi_cell`] whenever `cells > 1`, `--algo air_fedga` selects
//! grouped AirComp, and `repro ablation topology` sweeps cells × groups
//! against flat PAOTA from one declarative campaign. Degeneracy contract:
//! a 1-cell/1-group topology is **bitwise** the flat run at the same seed
//! (`tests/golden_seed.rs`).

pub mod air_fedga;
pub mod group;
pub mod multi_cell;

pub use air_fedga::{AirFedGa, GroupPowerMode};
pub use group::{GroupMap, PartitionerKind};
pub use multi_cell::{
    CloudFedAvg, InterCellMixing, MixingKind, MultiCellResult, MultiCellRunner, NoMixing,
    PairwiseGossip,
};
