//! COTAF (Sery & Cohen, "On Analog Gradient Descent Learning Over Multiple
//! Access Fading Channels") — baseline (2) of §IV-B: synchronous AirComp
//! FL with *time-varying precoding*.
//!
//! Participants upload their model **updates** `Δw_k = w_k − w_g` over the
//! MAC, pre-scaled by `√α_t` with
//!
//! ```text
//!   α_t = P_max / max_k ‖Δw_k‖²
//! ```
//!
//! so every transmitted signal satisfies the power constraint with
//! equality for the largest update. The PS receives
//! `√α_t·Σ_k Δw_k + n`, unscales and averages:
//!
//! ```text
//!   w_g ← w_g + (Σ_k Δw_k + n/√α_t) / |participants|
//! ```
//!
//! As training converges, ‖Δw‖ shrinks → `α_t` grows → effective noise
//! `n/√α_t` shrinks: precoding matched to the update scale. The weakness
//! the paper exploits (Fig. 3b) is that the *instantaneous* update norm is
//! what bounds α_t; in loud channels (N₀ = −74 dBm/Hz) the unscaled noise
//! floor is large relative to shrunken updates, degrading the model —
//! PAOTA instead keeps full-scale *models* on the air and adapts powers.
//!
//! Synchronous timing: like Local SGD, the round lasts as long as its
//! slowest participant (same participant count for fairness, §IV-B).

use anyhow::Result;

use crate::channel::Mac;
use crate::config::Config;
use crate::sim::VirtualClock;
use crate::util::{vecmath, Rng};

use super::{RoundRecord, RunResult, TrainContext};

pub fn run(ctx: &TrainContext, cfg: &Config) -> Result<RunResult> {
    let dim = ctx.dim();
    let k = ctx.clients();
    let m = ctx.rt.manifest().clone();
    let participants = ctx.sync_participants(cfg);
    let latency = cfg.latency();
    let mac = Mac::new(cfg.channel);

    let mut lat_rng = Rng::with_stream(cfg.seed, 0x1a7);
    let mut batch_rng = Rng::with_stream(cfg.seed, 0xba7c);
    let mut pick_rng = Rng::with_stream(cfg.seed, 0x91c4);
    let mut chan_rng = Rng::with_stream(cfg.seed, 0xc4a2);

    let mut w_g = ctx.init_weights();
    let mut clock = VirtualClock::new();
    let mut stack = vec![0.0f32; k * dim];
    let mut coef = vec![0.0f32; k];
    let mut delta = vec![0.0f32; dim];

    let mut records = Vec::with_capacity(cfg.rounds);

    for round in 0..cfg.rounds {
        let chosen = pick_rng.choose_indices(k, participants);

        let mut round_time = 0.0f64;
        let mut train_loss_sum = 0.0f64;
        let mut max_delta_norm2 = 0.0f64;
        coef.iter_mut().for_each(|c| *c = 0.0);
        stack.iter_mut().for_each(|v| *v = 0.0);

        let jobs: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = chosen
            .iter()
            .map(|&i| {
                round_time = round_time.max(latency.draw(&mut lat_rng));
                let (xs, ys) = ctx.partition.clients[i].sample_batches(
                    m.local_steps,
                    m.batch,
                    &mut batch_rng,
                );
                (w_g.clone(), xs, ys)
            })
            .collect();
        for (&i, out) in chosen.iter().zip(ctx.train_many(jobs, cfg.lr)?) {
            train_loss_sum += out.loss as f64;
            // Stack the UPDATE, not the model.
            vecmath::sub(&out.weights, &w_g, &mut delta);
            let n2 = vecmath::dot(&delta, &delta);
            max_delta_norm2 = max_delta_norm2.max(n2);
            stack[i * dim..(i + 1) * dim].copy_from_slice(&delta);
            coef[i] = 1.0;
        }
        clock.advance(round_time);

        // Time-varying precoder α_t = P_max / max‖Δw‖² (guard tiny norms).
        let alpha_t = if max_delta_norm2 > 1e-20 {
            cfg.p_max / max_delta_norm2
        } else {
            f64::INFINITY
        };
        // aggregate() computes (Σ Δw + noise)/participants when coef = 1;
        // the channel noise is already unscaled by the precoder: the noise
        // handed to the kernel must be n/√α_t (the kernel then divides by
        // the participant count).
        let noise_std = if alpha_t.is_finite() {
            (mac.config().noise_power().sqrt() / alpha_t.sqrt()) as f32
        } else {
            0.0
        };
        // After unscaling, the PS sees n/√α_t; the kernel's division by
        // Σcoef (= participant count) then yields n/(√α_t·|P|) — exactly
        // the COTAF estimator above.
        let mut noise = vec![0.0f32; dim];
        chan_rng.fill_normal(&mut noise, noise_std);
        let mean_update = ctx.rt.aggregate(&stack, &coef, &noise)?;
        vecmath::axpy(1.0, &mean_update, &mut w_g);

        let eval = if round % cfg.eval_every == 0 || round + 1 == cfg.rounds {
            Some(ctx.evaluate(&w_g)?)
        } else {
            None
        };
        let probe_loss = if eval.is_some() {
            Some(ctx.probe_loss(&w_g)?)
        } else {
            None
        };
        records.push(RoundRecord {
            round,
            sim_time: clock.now(),
            train_loss: (train_loss_sum / participants as f64) as f32,
            probe_loss,
            eval,
            participants,
            mean_staleness: 0.0,
            mean_power: cfg.p_max,
        });
        crate::debug!(
            "cotaf r={round} t={:.0}s α={alpha_t:.2e} loss={:.4} acc={:?}",
            clock.now(),
            records.last().unwrap().train_loss,
            records.last().unwrap().eval.map(|e| e.accuracy),
        );
    }

    Ok(RunResult {
        algorithm: crate::config::Algorithm::Cotaf,
        records,
        final_weights: w_g,
    })
}
