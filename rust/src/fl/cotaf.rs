//! COTAF (Sery & Cohen, "On Analog Gradient Descent Learning Over
//! Multiple Access Fading Channels") — baseline (2) of §IV-B, as an
//! [`AggregationPolicy`]: synchronous AirComp with *time-varying
//! precoding*.
//!
//! Participants upload their model **updates** `Δw_k = w_k − w_g` over
//! the MAC, pre-scaled by `√α_t` with
//!
//! ```text
//!   α_t = P_max / max_k ‖Δw_k‖²
//! ```
//!
//! so every transmitted signal satisfies the power constraint with
//! equality for the largest update. The PS receives
//! `√α_t·Σ_k Δw_k + n`, unscales and averages:
//!
//! ```text
//!   w_g ← w_g + (Σ_k Δw_k + n/√α_t) / |participants|
//! ```
//!
//! As training converges, ‖Δw‖ shrinks → `α_t` grows → effective noise
//! `n/√α_t` shrinks: precoding matched to the update scale. The weakness
//! the paper exploits (Fig. 3b) is that the *instantaneous* update norm
//! bounds α_t; in loud channels (N₀ = −74 dBm/Hz) the unscaled noise
//! floor is large relative to shrunken updates, degrading the model —
//! PAOTA instead keeps full-scale *models* on the air and adapts powers.
//!
//! Timing is synchronous like Local SGD (same participant count for
//! fairness, §IV-B): the coordinator stacks the update rows
//! (`deltas: true`) with unit coefficients, so the kernel's division by
//! the participant count yields exactly the COTAF estimator above.

use anyhow::Result;

use crate::config::Config;
use crate::util::vecmath;

use super::coordinator::{AggregationPolicy, RngStreams, RoundAction, RoundTiming, Upload};
use super::TrainContext;

/// Synchronous AirComp with time-varying precoding.
pub struct Cotaf {
    participants: usize,
    p_max: f64,
    /// Channel noise power σ_n² = B·N₀ (watts).
    noise_power: f64,
    dim: usize,
}

impl Cotaf {
    pub fn new(ctx: &TrainContext, cfg: &Config) -> Self {
        Self {
            participants: ctx.sync_participants(cfg),
            p_max: cfg.p_max,
            noise_power: cfg.channel.noise_power(),
            dim: ctx.dim(),
        }
    }
}

impl AggregationPolicy for Cotaf {
    fn name(&self) -> &str {
        "cotaf"
    }

    fn timing(&self) -> RoundTiming {
        RoundTiming::Synchronous
    }

    fn needs_deltas(&self) -> bool {
        true
    }

    fn select_participants(&mut self, offered: &[usize], rngs: &mut RngStreams) -> Vec<usize> {
        // Positions into `offered` mapped back to client ids (identity for
        // the synchronous full fleet, but correct for any offered set).
        let n = self.participants.min(offered.len());
        rngs.pick
            .choose_indices(offered.len(), n)
            .into_iter()
            .map(|i| offered[i])
            .collect()
    }

    fn on_uploads(
        &mut self,
        _round: usize,
        _global: &[f32],
        uploads: &[Upload],
        rngs: &mut RngStreams,
    ) -> Result<RoundAction> {
        let mut max_delta_norm2 = 0.0f64;
        for up in uploads {
            max_delta_norm2 = max_delta_norm2.max(vecmath::dot(&up.delta, &up.delta));
        }
        // Time-varying precoder α_t = P_max / max‖Δw‖² (guard tiny norms).
        let alpha_t = if max_delta_norm2 > 1e-20 {
            self.p_max / max_delta_norm2
        } else {
            f64::INFINITY
        };
        // The noise handed to the kernel must already be unscaled by the
        // precoder (n/√α_t); the kernel's division by Σcoef = |P| then
        // yields n/(√α_t·|P|) — exactly the COTAF estimator.
        let noise_std = if alpha_t.is_finite() {
            (self.noise_power.sqrt() / alpha_t.sqrt()) as f32
        } else {
            0.0
        };
        let mut noise = vec![0.0f32; self.dim];
        rngs.channel.fill_normal(&mut noise, noise_std);
        Ok(RoundAction::Aggregate {
            coefs: vec![1.0; uploads.len()],
            noise,
            deltas: true,
            mean_power: self.p_max,
        })
    }
}
