//! String-keyed **policy registry** — the open end of the run API.
//!
//! PR 1 reduced every algorithm to an
//! [`AggregationPolicy`](super::AggregationPolicy); this module removes the
//! last closed seam by replacing enum dispatch with a registry of named
//! factories `(&TrainContext, &Config) -> Box<dyn AggregationPolicy>`:
//!
//! * the built-in schemes self-register under their canonical names (plus
//!   aliases) when the global registry is first touched;
//! * [`crate::config::Algorithm`] is a *validated name* — parsing resolves
//!   aliases and rejects anything no factory claims;
//! * the CLI `help` text and [`names`] enumerate whatever is registered.
//!
//! Net effect: a new scheme — an example binary, a test, a downstream
//! crate — calls [`register`] once and is immediately reachable through
//! `repro run --algo <name>`, config files, and campaign declarations,
//! with **zero edits** to `config`, `cli`, or the `fl` dispatch path. See
//! `examples/custom_policy.rs` for the end-to-end demonstration and
//! [`super::ca_paota`] for a registered-from-a-module scheme.

use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, OnceLock, RwLock, RwLockReadGuard, RwLockWriteGuard};

use anyhow::{bail, Result};

use crate::config::Config;

use super::coordinator::AggregationPolicy;
use super::TrainContext;

/// A policy factory: builds a ready-to-run policy for one training run.
pub type PolicyFactory =
    Arc<dyn Fn(&TrainContext, &Config) -> Box<dyn AggregationPolicy> + Send + Sync>;

/// Public metadata of one registered policy (help text, listings).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PolicyInfo {
    /// Canonical registry key (what `Algorithm::name()` returns).
    pub name: String,
    /// Human-readable label for tables and plots (e.g. "Local SGD").
    pub label: String,
    /// Accepted aliases, resolved to `name` at parse time.
    pub aliases: Vec<String>,
}

struct Entry {
    label: String,
    aliases: Vec<String>,
    factory: PolicyFactory,
}

/// The registry itself. Most callers use the free functions, which act on
/// the process-global instance; owning a [`PolicyRegistry`] directly is
/// for tests and embedders that want isolation.
#[derive(Default)]
pub struct PolicyRegistry {
    /// Canonical name → entry (BTreeMap keeps listings sorted).
    entries: BTreeMap<String, Entry>,
    /// Alias → canonical name.
    aliases: HashMap<String, String>,
}

impl PolicyRegistry {
    /// An empty registry (no built-ins).
    pub fn new() -> Self {
        Self::default()
    }

    /// A registry pre-seeded with the five reproduction policies plus the
    /// channel-aware scheduling and grouped-AirComp extensions.
    pub fn with_builtins() -> Self {
        let mut r = Self::new();
        let seed = "seeding built-in policy";
        r.register("paota", "PAOTA", &[], |ctx, cfg| {
            Box::new(super::paota::Paota::new(ctx, cfg)) as Box<dyn AggregationPolicy>
        })
        .expect(seed);
        r.register("local_sgd", "Local SGD", &["localsgd", "fedavg"], |ctx, cfg| {
            Box::new(super::local_sgd::LocalSgd::new(ctx, cfg)) as Box<dyn AggregationPolicy>
        })
        .expect(seed);
        r.register("cotaf", "COTAF", &[], |ctx, cfg| {
            Box::new(super::cotaf::Cotaf::new(ctx, cfg)) as Box<dyn AggregationPolicy>
        })
        .expect(seed);
        r.register("centralized", "Centralized", &["central"], |ctx, cfg| {
            Box::new(super::centralized::Centralized::new(ctx, cfg))
                as Box<dyn AggregationPolicy>
        })
        .expect(seed);
        r.register("fedasync", "FedAsync", &["fed_async", "async"], |ctx, cfg| {
            Box::new(super::fedasync::FedAsync::new(ctx, cfg)) as Box<dyn AggregationPolicy>
        })
        .expect(seed);
        r.register("ca_paota", "CA-PAOTA", &["ca-paota", "channel_aware"], |ctx, cfg| {
            Box::new(super::ca_paota::CaPaota::new(ctx, cfg)) as Box<dyn AggregationPolicy>
        })
        .expect(seed);
        r.register("air_fedga", "Air-FedGA", &["air-fedga", "airfedga", "grouped"], |ctx, cfg| {
            Box::new(super::topology::AirFedGa::new(ctx, cfg)) as Box<dyn AggregationPolicy>
        })
        .expect(seed);
        r
    }

    /// Register a policy under `name` (lowercased). Fails if the name or
    /// any alias collides with an existing name or alias.
    pub fn register<F>(
        &mut self,
        name: &str,
        label: &str,
        aliases: &[&str],
        factory: F,
    ) -> Result<()>
    where
        F: Fn(&TrainContext, &Config) -> Box<dyn AggregationPolicy> + Send + Sync + 'static,
    {
        let name = normalize(name)?;
        if self.entries.contains_key(&name) || self.aliases.contains_key(&name) {
            bail!("policy {name:?} is already registered");
        }
        let mut normalized_aliases = Vec::with_capacity(aliases.len());
        for alias in aliases {
            let alias = normalize(alias)?;
            if alias == name
                || self.entries.contains_key(&alias)
                || self.aliases.contains_key(&alias)
                || normalized_aliases.contains(&alias)
            {
                bail!("policy alias {alias:?} is already taken");
            }
            normalized_aliases.push(alias);
        }
        for alias in &normalized_aliases {
            self.aliases.insert(alias.clone(), name.clone());
        }
        self.entries.insert(
            name,
            Entry {
                label: label.to_string(),
                aliases: normalized_aliases,
                factory: Arc::new(factory),
            },
        );
        Ok(())
    }

    /// Resolve a user-supplied name or alias to its canonical name. The
    /// error lists every available policy.
    pub fn canonical(&self, query: &str) -> Result<String> {
        let q = query.trim().to_ascii_lowercase();
        if self.entries.contains_key(&q) {
            return Ok(q);
        }
        if let Some(name) = self.aliases.get(&q) {
            return Ok(name.clone());
        }
        bail!(
            "unknown algorithm {query:?} — available: {}",
            self.names().join(", ")
        );
    }

    /// The factory registered under a name/alias (cloned out so callers
    /// can invoke it without holding any registry lock).
    pub fn factory(&self, query: &str) -> Result<PolicyFactory> {
        let name = self.canonical(query)?;
        Ok(Arc::clone(
            &self.entries.get(&name).expect("canonical name present").factory,
        ))
    }

    /// Build the policy a name selects.
    pub fn build(
        &self,
        query: &str,
        ctx: &TrainContext,
        cfg: &Config,
    ) -> Result<Box<dyn AggregationPolicy>> {
        let factory = self.factory(query)?;
        Ok((*factory)(ctx, cfg))
    }

    /// Canonical names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.entries.keys().cloned().collect()
    }

    /// Display label for a name/alias (falls back to the query itself for
    /// unregistered names, so ad-hoc series still print something).
    pub fn label(&self, query: &str) -> String {
        match self.canonical(query) {
            Ok(name) => self.entries[&name].label.clone(),
            Err(_) => query.to_string(),
        }
    }

    /// Metadata of every registered policy, sorted by name.
    pub fn infos(&self) -> Vec<PolicyInfo> {
        self.entries
            .iter()
            .map(|(name, e)| PolicyInfo {
                name: name.clone(),
                label: e.label.clone(),
                aliases: e.aliases.clone(),
            })
            .collect()
    }
}

fn normalize(name: &str) -> Result<String> {
    let name = name.trim().to_ascii_lowercase();
    if name.is_empty() {
        bail!("policy name must be non-empty");
    }
    if !name
        .chars()
        .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
    {
        bail!("policy name {name:?} may only contain [a-z0-9_-]");
    }
    Ok(name)
}

// ---------------------------------------------------------------------
// The process-global registry (what `Algorithm::parse`, the CLI and the
// coordinator dispatch consult).
// ---------------------------------------------------------------------

static GLOBAL: OnceLock<RwLock<PolicyRegistry>> = OnceLock::new();

fn global() -> &'static RwLock<PolicyRegistry> {
    GLOBAL.get_or_init(|| RwLock::new(PolicyRegistry::with_builtins()))
}

fn read() -> RwLockReadGuard<'static, PolicyRegistry> {
    global().read().unwrap_or_else(|e| e.into_inner())
}

fn write() -> RwLockWriteGuard<'static, PolicyRegistry> {
    global().write().unwrap_or_else(|e| e.into_inner())
}

/// Register a policy in the global registry (see
/// [`PolicyRegistry::register`]).
pub fn register<F>(name: &str, label: &str, aliases: &[&str], factory: F) -> Result<()>
where
    F: Fn(&TrainContext, &Config) -> Box<dyn AggregationPolicy> + Send + Sync + 'static,
{
    write().register(name, label, aliases, factory)
}

/// Resolve a name/alias to its canonical registered name.
pub fn canonical(query: &str) -> Result<String> {
    read().canonical(query)
}

/// Build the policy a name selects against a prepared context.
pub fn build(query: &str, ctx: &TrainContext, cfg: &Config) -> Result<Box<dyn AggregationPolicy>> {
    // Clone the factory out first: it must run without holding the lock,
    // so a factory may itself consult the registry.
    let factory = read().factory(query)?;
    Ok((*factory)(ctx, cfg))
}

/// Every registered canonical name, sorted.
pub fn names() -> Vec<String> {
    read().names()
}

/// Display label for a policy name.
pub fn label(query: &str) -> String {
    read().label(query)
}

/// Metadata of every registered policy (help text, listings).
pub fn infos() -> Vec<PolicyInfo> {
    read().infos()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::coordinator::{RngStreams, RoundAction, RoundTiming, Upload};

    struct Noop;
    impl AggregationPolicy for Noop {
        fn name(&self) -> &str {
            "noop"
        }
        fn timing(&self) -> RoundTiming {
            RoundTiming::Periodic
        }
        fn on_uploads(
            &mut self,
            _round: usize,
            _global: &[f32],
            _uploads: &[Upload],
            _rngs: &mut RngStreams,
        ) -> Result<RoundAction> {
            Ok(RoundAction::Skip { mean_power: 0.0 })
        }
    }

    fn noop_factory(_ctx: &TrainContext, _cfg: &Config) -> Box<dyn AggregationPolicy> {
        Box::new(Noop)
    }

    #[test]
    fn builtins_are_seeded_and_sorted() {
        let r = PolicyRegistry::with_builtins();
        assert_eq!(
            r.names(),
            vec![
                "air_fedga",
                "ca_paota",
                "centralized",
                "cotaf",
                "fedasync",
                "local_sgd",
                "paota"
            ]
        );
        assert_eq!(r.label("paota"), "PAOTA");
        assert_eq!(r.label("fedavg"), "Local SGD");
        assert_eq!(r.label("grouped"), "Air-FedGA");
    }

    #[test]
    fn aliases_resolve_case_insensitively() {
        let r = PolicyRegistry::with_builtins();
        assert_eq!(r.canonical("FedAvg").unwrap(), "local_sgd");
        assert_eq!(r.canonical("CA-PAOTA").unwrap(), "ca_paota");
        assert_eq!(r.canonical(" paota ").unwrap(), "paota");
    }

    #[test]
    fn unknown_name_error_lists_available_policies() {
        let r = PolicyRegistry::with_builtins();
        let msg = r.canonical("nope").unwrap_err().to_string();
        assert!(msg.contains("unknown algorithm"), "{msg}");
        for name in
            ["paota", "local_sgd", "cotaf", "centralized", "fedasync", "ca_paota", "air_fedga"]
        {
            assert!(msg.contains(name), "{msg} missing {name}");
        }
    }

    #[test]
    fn duplicate_name_and_alias_rejected() {
        let mut r = PolicyRegistry::with_builtins();
        assert!(r.register("paota", "dup", &[], noop_factory).is_err());
        // Alias colliding with an existing name.
        assert!(r.register("fresh", "x", &["cotaf"], noop_factory).is_err());
        // Alias colliding with an existing alias.
        assert!(r.register("fresh", "x", &["fedavg"], noop_factory).is_err());
        // Name colliding with an existing alias.
        assert!(r.register("fedavg", "x", &[], noop_factory).is_err());
        // A clean registration still works afterwards.
        r.register("fresh", "Fresh", &["f"], noop_factory).unwrap();
        assert_eq!(r.canonical("f").unwrap(), "fresh");
    }

    #[test]
    fn invalid_names_rejected() {
        let mut r = PolicyRegistry::new();
        assert!(r.register("", "x", &[], noop_factory).is_err());
        assert!(r.register("has space", "x", &[], noop_factory).is_err());
        assert!(r.register("ok_name-1", "x", &[], noop_factory).is_ok());
    }

    #[test]
    fn infos_carry_aliases() {
        let r = PolicyRegistry::with_builtins();
        let infos = r.infos();
        let sgd = infos.iter().find(|i| i.name == "local_sgd").unwrap();
        assert_eq!(sgd.aliases, vec!["localsgd", "fedavg"]);
        assert_eq!(sgd.label, "Local SGD");
    }

    #[test]
    fn global_registry_serves_builtins() {
        assert_eq!(canonical("fedavg").unwrap(), "local_sgd");
        assert!(names().contains(&"ca_paota".to_string()));
        assert_eq!(label("cotaf"), "COTAF");
    }
}
