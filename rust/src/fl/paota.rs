//! PAOTA — the paper's Algorithm 1 as an [`AggregationPolicy`]:
//! time-triggered semi-asynchronous aggregation over the air with
//! per-round power control, riding the coordinator's
//! [`Periodic`](RoundTiming::Periodic) timing (Fig. 2's ΔT slot anatomy).
//!
//! A client whose latency exceeds the period misses `s_k` aggregation
//! slots; its eventual upload is trained from the stale base `w_g^{r−s_k}`
//! (eq. (4)). The PS never waits: every round closes after exactly ΔT
//! virtual seconds — that is the whole point of the scheme (no straggler
//! bottleneck, Table I's time column).
//!
//! Per slot this policy:
//! 1. draws the round's Rayleigh fades and derives per-client effective
//!    power caps (channel inversion, eq. (5)/(7)),
//! 2. computes staleness factors ρ and gradient-similarity factors θ and
//!    solves the Dinkelbach power control (eq. (25)–(27)) for p_k,
//! 3. returns the powers as AirComp coefficients with AWGN of power B·N₀
//!    (eq. (6)+(8); the L1 kernel performs the ς division).

use anyhow::Result;

use crate::channel::Mac;
use crate::config::{Config, PowerCapMode};
use crate::power::{
    solve_power_control, BoundConstants, ClientFactors, PowerSolverConfig,
};
use crate::util::vecmath;

use super::coordinator::{AggregationPolicy, RngStreams, RoundAction, RoundTiming, Upload};
use super::TrainContext;

/// The paper's semi-asynchronous periodic-aggregation scheme.
pub struct Paota {
    mac: Mac,
    consts: BoundConstants,
    solver_cfg: PowerSolverConfig,
    power_cap_mode: PowerCapMode,
    p_max: f64,
    dim: usize,
    /// w_g^r − w_g^{r−1}: the similarity reference direction (eq. (25)).
    last_delta: Vec<f32>,
}

impl Paota {
    pub fn new(ctx: &TrainContext, cfg: &Config) -> Self {
        let dim = ctx.dim();
        Self {
            mac: Mac::new(cfg.channel),
            consts: BoundConstants {
                l_smooth: cfg.l_smooth,
                epsilon2: cfg.epsilon2,
                k_total: ctx.clients(),
                dim,
                noise_power: cfg.channel.noise_power(),
                omega: cfg.omega,
            },
            solver_cfg: PowerSolverConfig {
                solver: cfg.solver,
                mip_max_k: cfg.mip_max_k,
                pla_segments: cfg.pla_segments,
                mip_max_nodes: cfg.mip_max_nodes,
                dinkelbach_eps: cfg.dinkelbach_eps,
                dinkelbach_iters: cfg.dinkelbach_iters,
                force_beta: cfg.force_beta,
            },
            power_cap_mode: cfg.power_cap_mode,
            p_max: cfg.p_max,
            dim,
            last_delta: vec![0.0; dim],
        }
    }
}

impl AggregationPolicy for Paota {
    fn name(&self) -> &str {
        "paota"
    }

    fn timing(&self) -> RoundTiming {
        RoundTiming::Periodic
    }

    fn needs_deltas(&self) -> bool {
        true
    }

    fn on_uploads(
        &mut self,
        _round: usize,
        _global: &[f32],
        uploads: &[Upload],
        rngs: &mut RngStreams,
    ) -> Result<RoundAction> {
        // Channel draws + per-client factor inputs.
        let gains = self.mac.draw_fading_gains(&mut rngs.channel, uploads.len());
        let factors: Vec<ClientFactors> = uploads
            .iter()
            .zip(&gains)
            .map(|(up, &g2)| ClientFactors {
                stale_rounds: up.staleness,
                // cos(Δw_k, w_g^r − w_g^{r−1}) — the θ input of eq. (25).
                cosine: vecmath::cosine(&up.delta, &self.last_delta),
                p_cap: match self.power_cap_mode {
                    // eq. (25) uses P_max directly (perfect inversion).
                    PowerCapMode::Paper => self.p_max,
                    // Stricter eq. (7) reading: inverting the fade spends
                    // energy ∝ ‖w‖²/|h|².
                    PowerCapMode::Inversion => {
                        self.mac
                            .effective_power_cap(self.p_max, g2, vecmath::norm(&up.weights))
                    }
                },
            })
            .collect();
        let alloc = solve_power_control(&factors, &self.consts, &self.solver_cfg, &mut rngs.opt)?;

        let sigma_sum: f64 = alloc.powers.iter().sum();
        let mean_power = sigma_sum / uploads.len() as f64;
        if sigma_sum <= 0.0 {
            return Ok(RoundAction::Skip { mean_power });
        }
        // Raw eq.-(6) AWGN: the kernel performs the ς division.
        let noise = self.mac.channel_noise(&mut rngs.channel, self.dim);
        Ok(RoundAction::Aggregate {
            coefs: alloc.powers.iter().map(|&p| p as f32).collect(),
            noise,
            deltas: false,
            mean_power,
        })
    }

    fn on_global_delta(&mut self, delta: &[f32]) {
        self.last_delta.copy_from_slice(delta);
    }
}
