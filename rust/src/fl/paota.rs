//! PAOTA — the paper's Algorithm 1: time-triggered semi-asynchronous
//! federated learning with over-the-air aggregation and per-round
//! power control.
//!
//! Round structure (Fig. 2):
//!
//! ```text
//!  t = r·ΔT                t = (r+1)·ΔT
//!  ├────────── round r ──────────┤ aggregation slot
//!  ready clients receive w_g^r    clients whose local training finished
//!  and start M local SGD steps    inside (r·ΔT, (r+1)·ΔT] upload their
//!  (compute latency ℓ ~ U(5,15))  models simultaneously over the MAC
//! ```
//!
//! A client whose latency exceeds the period misses `s_k` aggregation
//! slots; its eventual upload is trained from the stale base `w_g^{r−s_k}`
//! (eq. (4)). The PS never waits: every round closes after exactly ΔT
//! virtual seconds — that is the whole point of the scheme (no straggler
//! bottleneck, Table I's time column).
//!
//! Per aggregation slot the coordinator:
//! 1. collects the finished clients, runs their M-step local training
//!    (AOT `local_train` artifact) from their stale bases,
//! 2. draws the round's Rayleigh fades, computes per-client effective
//!    power caps (channel inversion, eq. (5)/(7)),
//! 3. computes staleness factors ρ and gradient-similarity factors θ and
//!    solves the Dinkelbach power control (eq. (25)–(27)) for p_k,
//! 4. aggregates over the air: `w_g ← (Σ_k p_k·w_k + n)/Σ_k p_k`
//!    (eq. (6)+(8), the L1 Pallas reduction) with AWGN of power B·N₀,
//! 5. hands `w_g^{r+1}` to every client that uploaded (they restart
//!    immediately at the next round boundary).

use anyhow::Result;

use crate::channel::Mac;
use crate::config::Config;
use crate::power::{
    solve_power_control, BoundConstants, ClientFactors, PowerSolverConfig,
};
use crate::util::vecmath;
use crate::util::Rng;

use super::{RoundRecord, RunResult, TrainContext};

/// Per-client scheduler state.
#[derive(Debug, Clone)]
struct ClientSlot {
    /// Global round whose model this client is training from.
    base_round: usize,
    /// The base weights w_g^{base_round} it received.
    base_weights: Vec<f32>,
    /// Virtual time its current local training finishes.
    finish_time: f64,
}

/// Run PAOTA per the config. See the module docs for the round anatomy.
pub fn run(ctx: &TrainContext, cfg: &Config) -> Result<RunResult> {
    let dim = ctx.dim();
    let k = ctx.clients();
    let latency = cfg.latency();
    let mac = Mac::new(cfg.channel);
    let consts = BoundConstants {
        l_smooth: cfg.l_smooth,
        epsilon2: cfg.epsilon2,
        k_total: k,
        dim,
        noise_power: cfg.channel.noise_power(),
        omega: cfg.omega,
    };
    let solver_cfg = PowerSolverConfig {
        solver: cfg.solver,
        mip_max_k: cfg.mip_max_k,
        pla_segments: cfg.pla_segments,
        mip_max_nodes: cfg.mip_max_nodes,
        dinkelbach_eps: cfg.dinkelbach_eps,
        dinkelbach_iters: cfg.dinkelbach_iters,
        force_beta: cfg.force_beta,
    };

    // Independent deterministic streams.
    let mut lat_rng = Rng::with_stream(cfg.seed, 0x1a7);
    let mut batch_rng = Rng::with_stream(cfg.seed, 0xba7c);
    let mut chan_rng = Rng::with_stream(cfg.seed, 0xc4a2);
    let mut opt_rng = Rng::with_stream(cfg.seed, 0x0b7);

    let mut w_g = ctx.init_weights();
    // w_g^r − w_g^{r−1}: the similarity reference direction (eq. (25)).
    let mut last_delta = vec![0.0f32; dim];

    // All clients start training on w_g^0 at t = 0 (b_k^1 = 1 ∀k).
    let mut slots: Vec<ClientSlot> = (0..k)
        .map(|_| ClientSlot {
            base_round: 0,
            base_weights: w_g.clone(),
            finish_time: latency.draw(&mut lat_rng),
        })
        .collect();

    // Reusable flat buffers for the aggregate artifact.
    let mut stack = vec![0.0f32; k * dim];
    let mut coef = vec![0.0f32; k];

    let mut records = Vec::with_capacity(cfg.rounds);
    let mut scratch = vec![0.0f32; dim];

    for round in 0..cfg.rounds {
        let slot_end = (round as f64 + 1.0) * cfg.delta_t;

        // 1. Who finished inside this window?
        let ready: Vec<usize> = (0..k)
            .filter(|&i| slots[i].finish_time <= slot_end)
            .collect();

        let mut train_loss_sum = 0.0f64;
        let mut staleness_sum = 0.0f64;
        let mut updates: Vec<(usize, Vec<f32>, usize, f64)> = Vec::with_capacity(ready.len());

        // 2. Local training for each finisher (M SGD steps from its base) —
        // fanned out over the PJRT worker pool (§Perf; bit-identical to
        // the sequential path, deterministic order).
        let jobs: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = ready
            .iter()
            .map(|&i| {
                let (xs, ys) = ctx.partition.clients[i].sample_batches(
                    ctx.rt.manifest().local_steps,
                    ctx.rt.manifest().batch,
                    &mut batch_rng,
                );
                (slots[i].base_weights.clone(), xs, ys)
            })
            .collect();
        let outs = ctx.train_many(jobs, cfg.lr)?;
        for (&i, out) in ready.iter().zip(outs) {
            let staleness = round.saturating_sub(slots[i].base_round);
            train_loss_sum += out.loss as f64;
            staleness_sum += staleness as f64;

            // Gradient-similarity factor input: cos(Δw_k, w_g^r − w_g^{r−1}).
            vecmath::sub(&out.weights, &slots[i].base_weights, &mut scratch);
            let cosine = vecmath::cosine(&scratch, &last_delta);
            updates.push((i, out.weights, staleness, cosine));
        }

        let mut mean_power = 0.0;
        if !updates.is_empty() {
            // 3. Channel draws + power control.
            let gains = mac.draw_fading_gains(&mut chan_rng, updates.len());
            let factors: Vec<ClientFactors> = updates
                .iter()
                .zip(&gains)
                .map(|((_, w_k, stale, cosine), &g2)| ClientFactors {
                    stale_rounds: *stale,
                    cosine: *cosine,
                    p_cap: match cfg.power_cap_mode {
                        // eq. (25) uses P_max directly (perfect inversion).
                        crate::config::PowerCapMode::Paper => cfg.p_max,
                        // Stricter eq. (7) reading: inverting the fade
                        // spends energy ∝ ‖w‖²/|h|².
                        crate::config::PowerCapMode::Inversion => mac
                            .effective_power_cap(cfg.p_max, g2, vecmath::norm(w_k)),
                    },
                })
                .collect();
            let alloc = solve_power_control(&factors, &consts, &solver_cfg, &mut opt_rng)?;

            // 4. Over-the-air aggregation.
            coef.iter_mut().for_each(|c| *c = 0.0);
            stack.iter_mut().for_each(|v| *v = 0.0);
            let mut sigma_sum = 0.0f64;
            for (slot_idx, (i, w_k, _, _)) in updates.iter().enumerate() {
                coef[*i] = alloc.powers[slot_idx] as f32;
                sigma_sum += alloc.powers[slot_idx];
                stack[i * dim..(i + 1) * dim].copy_from_slice(w_k);
            }
            mean_power = sigma_sum / updates.len() as f64;
            if sigma_sum > 0.0 {
                // Raw eq.-(6) noise: the kernel performs the ς division.
                let noise = mac.channel_noise(&mut chan_rng, dim);
                let new_w = ctx.rt.aggregate(&stack, &coef, &noise)?;
                vecmath::sub(&new_w, &w_g, &mut last_delta.as_mut_slice());
                w_g = new_w;
            }

            // 5. Uploaders restart from the fresh global model at the next
            // round boundary.
            for (i, _, _, _) in &updates {
                slots[*i] = ClientSlot {
                    base_round: round + 1,
                    base_weights: w_g.clone(),
                    finish_time: slot_end + latency.draw(&mut lat_rng),
                };
            }
        }

        // Telemetry.
        let n_up = updates.len();
        let eval = if round % cfg.eval_every == 0 || round + 1 == cfg.rounds {
            Some(ctx.evaluate(&w_g)?)
        } else {
            None
        };
        let probe_loss = if eval.is_some() {
            Some(ctx.probe_loss(&w_g)?)
        } else {
            None
        };
        records.push(RoundRecord {
            round,
            sim_time: slot_end,
            train_loss: if n_up > 0 {
                (train_loss_sum / n_up as f64) as f32
            } else {
                f32::NAN
            },
            probe_loss,
            eval,
            participants: n_up,
            mean_staleness: if n_up > 0 {
                staleness_sum / n_up as f64
            } else {
                0.0
            },
            mean_power,
        });
        crate::debug!(
            "paota r={round} t={slot_end:.0}s up={n_up} stale={:.2} loss={:.4} acc={:?}",
            records.last().unwrap().mean_staleness,
            records.last().unwrap().train_loss,
            records.last().unwrap().eval.map(|e| e.accuracy),
        );
    }

    Ok(RunResult {
        algorithm: crate::config::Algorithm::Paota,
        records,
        final_weights: w_g,
    })
}
