//! Centralized SGD on the pooled client data, as an
//! [`AggregationPolicy`].
//!
//! Not a paper baseline per se — it estimates `F(w*)`, the optimal global
//! loss the Fig. 3 curves subtract (`E[F(w^r)] − F(w*)`). The model, step
//! count and batch geometry are identical to the federated runs (the same
//! `local_train` artifact), only the sampling pool differs: all data,
//! centrally — hence the [`make_job`](AggregationPolicy::make_job)
//! override and a dedicated minibatch RNG stream.
//!
//! Virtual timing ([`SingleNode`](RoundTiming::SingleNode)): one "round"
//! is one M-step pass; time advances by the mean latency (a centralized
//! node has no stragglers). The timing is not used by the gap metric,
//! only recorded for completeness.

use anyhow::Result;

use crate::config::{Algorithm, Config};
use crate::data::Dataset;
use crate::util::Rng;

use super::coordinator::{
    streams, AggregationPolicy, RngStreams, RoundAction, RoundTiming, Upload,
};
use super::TrainContext;

/// Pooled-data SGD (the `F(w*)` estimator).
pub struct Centralized {
    pooled: Dataset,
}

impl Centralized {
    pub fn new(ctx: &TrainContext, _cfg: &Config) -> Self {
        Self {
            pooled: ctx.partition.pooled(),
        }
    }
}

impl AggregationPolicy for Centralized {
    fn name(&self) -> &str {
        "centralized"
    }

    fn timing(&self) -> RoundTiming {
        RoundTiming::SingleNode
    }

    fn batch_stream(&self) -> u64 {
        streams::POOLED_BATCH
    }

    /// Sample M minibatches from the pooled data instead of a client
    /// shard.
    fn make_job(
        &self,
        _client: usize,
        base: &[f32],
        ctx: &TrainContext,
        batch_rng: &mut Rng,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let m = ctx.rt.manifest();
        let d = &self.pooled;
        let rows = m.local_steps * m.batch;
        let mut xs = Vec::with_capacity(rows * d.dim);
        let mut ys = vec![0.0f32; rows * d.classes];
        for row in 0..rows {
            let i = batch_rng.index(d.len());
            xs.extend_from_slice(d.row(i));
            ys[row * d.classes + d.y[i] as usize] = 1.0;
        }
        (base.to_vec(), xs, ys)
    }

    fn on_uploads(
        &mut self,
        _round: usize,
        _global: &[f32],
        _uploads: &[Upload],
        _rngs: &mut RngStreams,
    ) -> Result<RoundAction> {
        Ok(RoundAction::Adopt)
    }
}

/// Estimate `F(w*)`: run centralized SGD for `rounds` and return the
/// minimum probe loss seen (the paper's optimum reference for Fig. 3).
pub fn estimate_f_star(ctx: &TrainContext, cfg: &Config, rounds: usize) -> Result<f32> {
    let mut c = cfg.clone();
    c.algorithm = Algorithm::raw("centralized");
    c.rounds = rounds;
    c.eval_every = 5.min(rounds).max(1);
    let run = super::run_with_context(ctx, &c)?;
    let best = run
        .records
        .iter()
        .filter_map(|r| r.probe_loss)
        .fold(f32::INFINITY, f32::min);
    Ok(best)
}
