//! Centralized SGD on the pooled client data.
//!
//! Not a paper baseline per se — it estimates `F(w*)`, the optimal global
//! loss the Fig. 3 curves subtract (`E[F(w^r)] − F(w*)`). The model, step
//! count and batch geometry are identical to the federated runs (the same
//! `local_train` artifact), only the sampling pool differs: all data,
//! centrally.
//!
//! Virtual timing: one "round" is one M-step pass; time advances by the
//! mean latency (a centralized node has no stragglers). The timing is not
//! used by the gap metric, only recorded for completeness.

use anyhow::Result;

use crate::config::Config;
use crate::sim::VirtualClock;
use crate::util::Rng;

use super::{RoundRecord, RunResult, TrainContext};

pub fn run(ctx: &TrainContext, cfg: &Config) -> Result<RunResult> {
    let m = ctx.rt.manifest().clone();
    let pooled = ctx.partition.pooled();
    let mut batch_rng = Rng::with_stream(cfg.seed, 0xce27);

    let mut w = ctx.init_weights();
    let mut clock = VirtualClock::new();
    let mean_latency = (cfg.latency_lo + cfg.latency_hi) / 2.0;

    let mut records = Vec::with_capacity(cfg.rounds);

    for round in 0..cfg.rounds {
        // Sample M minibatches from the pooled data.
        let mut xs = Vec::with_capacity(m.local_steps * m.batch * pooled.dim);
        let mut ys = vec![0.0f32; m.local_steps * m.batch * pooled.classes];
        for row in 0..(m.local_steps * m.batch) {
            let i = batch_rng.index(pooled.len());
            xs.extend_from_slice(pooled.row(i));
            ys[row * pooled.classes + pooled.y[i] as usize] = 1.0;
        }
        let out = ctx.rt.local_train(&w, &xs, &ys, cfg.lr)?;
        w = out.weights;
        clock.advance(mean_latency);

        let eval = if round % cfg.eval_every == 0 || round + 1 == cfg.rounds {
            Some(ctx.evaluate(&w)?)
        } else {
            None
        };
        let probe_loss = if eval.is_some() {
            Some(ctx.probe_loss(&w)?)
        } else {
            None
        };
        records.push(RoundRecord {
            round,
            sim_time: clock.now(),
            train_loss: out.loss,
            probe_loss,
            eval,
            participants: 1,
            mean_staleness: 0.0,
            mean_power: 0.0,
        });
    }

    Ok(RunResult {
        algorithm: crate::config::Algorithm::Centralized,
        records,
        final_weights: w,
    })
}

/// Estimate `F(w*)`: run centralized SGD for `rounds` and return the
/// minimum probe loss seen (the paper's optimum reference for Fig. 3).
pub fn estimate_f_star(ctx: &TrainContext, cfg: &Config, rounds: usize) -> Result<f32> {
    let mut c = cfg.clone();
    c.algorithm = crate::config::Algorithm::Centralized;
    c.rounds = rounds;
    c.eval_every = 5.min(rounds).max(1);
    let run = run(ctx, &c)?;
    let best = run
        .records
        .iter()
        .filter_map(|r| r.probe_loss)
        .fold(f32::INFINITY, f32::min);
    Ok(best)
}
