//! Mini property-testing framework (offline stand-in for `proptest`).
//!
//! Provides seeded-random case generation with automatic *input shrinking*
//! on failure, so coordinator invariants can be tested the proptest way:
//!
//! ```ignore
//! use paota::testing::{check, Gen};
//! check("weights normalize", 200, |g| {
//!     let v = g.vec_f64(1..20, 0.0..10.0);
//!     let s: f64 = v.iter().sum();
//!     prop_assert(s >= 0.0)
//! });
//! ```
//!
//! Failures report the seed of the failing case so it can be replayed with
//! `PAOTA_PROP_SEED=<seed>`; `PAOTA_PROP_CASES` scales case counts.

use crate::util::Rng;

/// Outcome of a property over one generated case.
pub type PropResult = Result<(), String>;

/// Assert inside a property.
pub fn prop_assert(cond: bool, msg: &str) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.to_string())
    }
}

/// Assert approximate equality inside a property.
pub fn prop_close(a: f64, b: f64, tol: f64, what: &str) -> PropResult {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{what}: {a} !≈ {b} (tol {tol})"))
    }
}

/// Case generator handed to properties — a thin layer over [`Rng`] with
/// range-style helpers.
pub struct Gen {
    rng: Rng,
    /// Size hint in [0,1]: grows over the run so early cases are small.
    size: f64,
}

impl Gen {
    fn new(seed: u64, size: f64) -> Self {
        Self {
            rng: Rng::new(seed),
            size,
        }
    }

    /// Raw access to the underlying RNG.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    /// Integer in `[lo, hi)`, biased small early in the run.
    pub fn usize_in(&mut self, range: std::ops::Range<usize>) -> usize {
        assert!(range.start < range.end);
        let span = range.end - range.start;
        let scaled = ((span as f64 * self.size).ceil() as usize).clamp(1, span);
        range.start + self.rng.index(scaled)
    }

    /// f64 in `[lo, hi)`.
    pub fn f64_in(&mut self, range: std::ops::Range<f64>) -> f64 {
        self.rng.uniform(range.start, range.end)
    }

    /// Bool with probability `p` of true.
    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.f64() < p
    }

    /// Vec of f64 with length drawn from `len` and values from `vals`.
    pub fn vec_f64(
        &mut self,
        len: std::ops::Range<usize>,
        vals: std::ops::Range<f64>,
    ) -> Vec<f64> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.f64_in(vals.clone())).collect()
    }

    /// Vec of f32.
    pub fn vec_f32(
        &mut self,
        len: std::ops::Range<usize>,
        vals: std::ops::Range<f64>,
    ) -> Vec<f32> {
        self.vec_f64(len, vals).into_iter().map(|v| v as f32).collect()
    }
}

fn env_cases(default_cases: usize) -> usize {
    std::env::var("PAOTA_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default_cases)
}

fn env_seed() -> Option<u64> {
    std::env::var("PAOTA_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
}

/// Run `prop` over `cases` generated inputs; panics with the failing seed
/// on the first failure. The per-case seed is derived deterministically
/// from the property name so adding properties elsewhere doesn't reshuffle
/// this one's cases.
pub fn check(name: &str, cases: usize, prop: impl Fn(&mut Gen) -> PropResult) {
    let base = name
        .bytes()
        .fold(0xcbf29ce484222325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100000001b3));

    if let Some(seed) = env_seed() {
        let mut g = Gen::new(seed, 1.0);
        if let Err(msg) = prop(&mut g) {
            panic!("property {name:?} failed (replay seed {seed}): {msg}");
        }
        return;
    }

    let cases = env_cases(cases);
    for i in 0..cases {
        let seed = base.wrapping_add(i as u64).wrapping_mul(0x9e3779b97f4a7c15);
        let size = 0.1 + 0.9 * (i as f64 + 1.0) / cases as f64;
        let mut g = Gen::new(seed, size);
        if let Err(msg) = prop(&mut g) {
            // Shrink pass: retry with smaller size hints on the same seed;
            // report the smallest size that still fails.
            let mut fail_size = size;
            for shrink in [0.05, 0.1, 0.2, 0.4] {
                if shrink >= size {
                    break;
                }
                let mut g2 = Gen::new(seed, shrink);
                if prop(&mut g2).is_err() {
                    fail_size = shrink;
                    break;
                }
            }
            panic!(
                "property {name:?} failed on case {i}/{cases} \
                 (seed {seed}, size {fail_size:.2}): {msg}\n\
                 replay with PAOTA_PROP_SEED={seed}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum of abs is nonneg", 50, |g| {
            let v = g.vec_f64(0..10, -5.0..5.0);
            let s: f64 = v.iter().map(|x| x.abs()).sum();
            prop_assert(s >= 0.0, "negative abs-sum")
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_panics_with_seed() {
        check("always fails", 20, |g| {
            let v = g.f64_in(0.0..1.0);
            prop_assert(v < 0.0, "uniform draw is never negative")
        });
    }

    #[test]
    fn gen_ranges_respected() {
        check("usize_in respects range", 100, |g| {
            let n = g.usize_in(3..17);
            prop_assert((3..17).contains(&n), "out of range")
        });
        check("f64_in respects range", 100, |g| {
            let x = g.f64_in(-2.0..3.0);
            prop_assert((-2.0..3.0).contains(&x), "out of range")
        });
    }

    #[test]
    fn prop_close_tolerance() {
        assert!(prop_close(1.0, 1.0 + 1e-12, 1e-9, "x").is_ok());
        assert!(prop_close(1.0, 2.0, 1e-9, "x").is_err());
    }
}
