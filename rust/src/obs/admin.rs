//! `obs::admin` — the scrape surface: a minimal HTTP listener serving
//! live metrics snapshots and a health probe.
//!
//! Deliberately tiny (no HTTP library in the tree): one accept thread,
//! one request per connection, `GET` only.
//!
//! | path | response |
//! |---|---|
//! | `/metrics` | Prometheus text exposition (merged snapshot) |
//! | `/metrics.json` | the same snapshot as one JSON object |
//! | `/healthz` | `200 ok` while the listener is up |
//!
//! The served snapshot merges the process-wide
//! [`global`](crate::obs::metrics::global) registry with any extra
//! registries handed to [`AdminServer::start`] (the wire server's
//! private registry). The listener polls a stop flag with a
//! non-blocking accept loop, so dropping the handle shuts it down
//! without a poke connection.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context as _, Result};

use crate::obs::metrics::{self, Registry, Snapshot};

/// How often the accept loop polls the stop flag while idle.
const ACCEPT_POLL: Duration = Duration::from_millis(25);
/// Per-connection read/write budget — a stalled scraper cannot wedge
/// the listener for longer than this.
const IO_TIMEOUT: Duration = Duration::from_secs(2);

/// A running admin listener. Dropping it stops the thread and closes
/// the socket.
pub struct AdminServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl AdminServer {
    /// Bind `bind` (e.g. `127.0.0.1:0`) and start serving. `extra`
    /// registries are merged into every snapshot after the global one.
    pub fn start(bind: &str, extra: Vec<Arc<Registry>>) -> Result<Self> {
        let listener = TcpListener::bind(bind)
            .with_context(|| format!("binding obs admin listener on {bind}"))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("paota-obs-admin".into())
            .spawn(move || accept_loop(listener, &stop2, &extra))
            .context("spawning obs admin thread")?;
        Ok(Self {
            addr,
            stop,
            handle: Some(handle),
        })
    }

    /// The bound address (resolves `:0` to the real port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for AdminServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn accept_loop(listener: TcpListener, stop: &AtomicBool, extra: &[Arc<Registry>]) {
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                let _ = handle_conn(stream, extra);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(_) => std::thread::sleep(ACCEPT_POLL),
        }
    }
}

fn merged_snapshot(extra: &[Arc<Registry>]) -> Snapshot {
    let mut parts = vec![metrics::global().snapshot()];
    for r in extra {
        parts.push(r.snapshot());
    }
    Snapshot::merge(parts)
}

fn handle_conn(mut stream: TcpStream, extra: &[Arc<Registry>]) -> std::io::Result<()> {
    stream.set_read_timeout(Some(IO_TIMEOUT))?;
    stream.set_write_timeout(Some(IO_TIMEOUT))?;
    // Read until the end of the request head (we ignore the body; GETs
    // have none) or a small cap.
    let mut buf = Vec::with_capacity(512);
    let mut chunk = [0u8; 512];
    loop {
        if buf.windows(4).any(|w| w == b"\r\n\r\n") || buf.len() > 8192 {
            break;
        }
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(_) => break,
        }
    }
    let head = String::from_utf8_lossy(&buf);
    let mut parts = head.split_whitespace();
    let method = parts.next().unwrap_or("");
    let path = parts.next().unwrap_or("");
    let (status, ctype, body) = if method != "GET" {
        ("405 Method Not Allowed", "text/plain", "method not allowed\n".to_string())
    } else {
        match path {
            "/healthz" => ("200 OK", "text/plain", "ok\n".to_string()),
            "/metrics" => (
                "200 OK",
                "text/plain; version=0.0.4",
                merged_snapshot(extra).to_prometheus(),
            ),
            "/metrics.json" => (
                "200 OK",
                "application/json",
                merged_snapshot(extra).to_json(),
            ),
            _ => ("404 Not Found", "text/plain", "not found\n".to_string()),
        }
    };
    let resp = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(resp.as_bytes())
}

/// Client-side helper for tests/benches: issue one GET and return the
/// response body (headers stripped).
pub fn http_get(addr: SocketAddr, path: &str) -> Result<String> {
    let mut stream = TcpStream::connect(addr).context("connecting to admin listener")?;
    stream.set_read_timeout(Some(IO_TIMEOUT))?;
    stream.set_write_timeout(Some(IO_TIMEOUT))?;
    let req = format!("GET {path} HTTP/1.1\r\nHost: paota\r\nConnection: close\r\n\r\n");
    stream.write_all(req.as_bytes())?;
    let mut raw = String::new();
    stream.read_to_string(&mut raw)?;
    let body = raw
        .split_once("\r\n\r\n")
        .map(|(_, b)| b.to_string())
        .unwrap_or(raw);
    Ok(body)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serves_metrics_health_and_404() {
        let reg = Arc::new(Registry::new());
        reg.counter("admin_test_total").add(3);
        let admin = AdminServer::start("127.0.0.1:0", vec![Arc::clone(&reg)]).unwrap();
        let addr = admin.local_addr();

        assert_eq!(http_get(addr, "/healthz").unwrap(), "ok\n");

        let text = http_get(addr, "/metrics").unwrap();
        assert!(text.contains("# TYPE admin_test_total counter"), "{text}");
        assert!(text.contains("admin_test_total 3"), "{text}");

        let js = http_get(addr, "/metrics.json").unwrap();
        assert!(js.contains("\"admin_test_total\":3"), "{js}");

        let missing = http_get(addr, "/nope").unwrap();
        assert_eq!(missing, "not found\n");
    }

    #[test]
    fn drop_stops_the_listener() {
        let admin = AdminServer::start("127.0.0.1:0", Vec::new()).unwrap();
        let addr = admin.local_addr();
        drop(admin);
        // The port is released once the thread exits; a fresh bind on
        // the same address must succeed.
        let rebound = TcpListener::bind(addr);
        assert!(rebound.is_ok(), "listener still holding {addr}");
    }
}
