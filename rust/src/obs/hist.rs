//! `obs::hist` — shared nearest-rank percentile helpers.
//!
//! One definition of "p99" for the whole tree: `repro loadgen` reports
//! submit-latency percentiles with these functions and
//! `repro trace summarize` recomputes them from the trace journal, so
//! the two agree bit for bit on the same samples (the loopback suite
//! asserts exactly that).

/// Nearest-rank percentile over an ascending-sorted slice.
///
/// `p` is in percent (e.g. `99.0`). The rank is
/// `round(p/100 · (n−1))` — the historical `loadgen` definition — and
/// an empty slice yields `0.0` so callers can report "no samples"
/// without branching.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Sort `samples` ascending (NaN-free input expected) and return
/// `(p50, p90, p99)` — the tuple every latency report in the tree
/// prints.
pub fn p50_p90_p99(samples: &mut [f64]) -> (f64, f64, f64) {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (
        percentile(samples, 50.0),
        percentile(samples, 90.0),
        percentile(samples, 99.0),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_zero() {
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[], 99.0), 0.0);
        let (a, b, c) = p50_p90_p99(&mut []);
        assert_eq!((a, b, c), (0.0, 0.0, 0.0));
    }

    #[test]
    fn single_sample_is_every_percentile() {
        let s = [7.25];
        assert_eq!(percentile(&s, 0.0), 7.25);
        assert_eq!(percentile(&s, 50.0), 7.25);
        assert_eq!(percentile(&s, 99.0), 7.25);
        assert_eq!(percentile(&s, 100.0), 7.25);
    }

    #[test]
    fn tie_heavy_distribution_returns_the_tied_value() {
        // 97 copies of 1.0 with a couple of outliers: p50/p90 must land
        // on the tie, p99 on the tail.
        let mut s = vec![1.0; 97];
        s.push(50.0);
        s.push(80.0);
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(percentile(&s, 50.0), 1.0);
        assert_eq!(percentile(&s, 90.0), 1.0);
        assert_eq!(percentile(&s, 99.0), 80.0);
    }

    #[test]
    fn nearest_rank_matches_the_loadgen_formula() {
        let s: Vec<f64> = (0..100).map(|i| i as f64).collect();
        // round(0.5 · 99) = 50, round(0.9 · 99) = 89, round(0.99 · 99) = 98.
        assert_eq!(percentile(&s, 50.0), 50.0);
        assert_eq!(percentile(&s, 90.0), 89.0);
        assert_eq!(percentile(&s, 99.0), 98.0);
    }

    #[test]
    fn p_above_100_clamps_to_max() {
        let s = [1.0, 2.0, 3.0];
        assert_eq!(percentile(&s, 400.0), 3.0);
    }

    #[test]
    fn tuple_helper_sorts_first() {
        let mut s = [3.0, 1.0, 2.0];
        let (p50, p90, p99) = p50_p90_p99(&mut s);
        assert_eq!(p50, 2.0);
        assert_eq!(p90, 3.0);
        assert_eq!(p99, 3.0);
    }
}
