//! `obs::metrics` — a lock-light registry of named counters, gauges and
//! fixed-bucket histograms.
//!
//! Registration (`counter`/`gauge`/`histogram`) takes a short mutex to
//! insert into a name-keyed map and hands back a cheap cloneable handle
//! (an `Arc` around atomics). Instrumentation sites register **once**
//! (at construction) and then update through the handle — the hot path
//! is a single relaxed atomic op, no lock, no allocation. Histograms
//! have fixed bucket bounds chosen at registration; `observe` is a
//! short linear scan over those bounds plus two atomic adds.
//!
//! [`Registry::snapshot`] reads everything at one instant (per-metric
//! atomic loads; counters may move between loads — fine for scraping)
//! and renders as Prometheus text exposition ([`Snapshot::to_prometheus`])
//! or JSON ([`Snapshot::to_json`]). Names may carry Prometheus-style
//! labels inline (`paota_cell_members{cell="0"}`): the renderer splits
//! the base name off for `# TYPE` lines.
//!
//! A process-wide registry is available as [`global`] (coordinator,
//! pool and mobility instrumentation lands there); components that
//! need isolated, exactly-attributable counts — the wire server, whose
//! scrape must match its loadgen's tallies even with concurrent runs
//! in one process — own a private `Arc<Registry>` instead.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Monotonic counter handle (clone freely; clones share the cell).
#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge handle.
#[derive(Clone)]
pub struct Gauge(Arc<AtomicI64>);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

struct HistCore {
    /// Ascending upper bounds; an implicit +Inf bucket follows.
    bounds: Vec<f64>,
    /// `bounds.len() + 1` non-cumulative buckets.
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    /// Sum in fixed-point microunits (values are clamped at 0 — every
    /// histogram in the tree measures a non-negative quantity).
    sum_micros: AtomicU64,
}

/// Fixed-bucket histogram handle.
#[derive(Clone)]
pub struct Histogram(Arc<HistCore>);

impl Histogram {
    pub fn observe(&self, v: f64) {
        let c = &self.0;
        let mut slot = c.bounds.len();
        for (i, b) in c.bounds.iter().enumerate() {
            if v <= *b {
                slot = i;
                break;
            }
        }
        c.buckets[slot].fetch_add(1, Ordering::Relaxed);
        c.count.fetch_add(1, Ordering::Relaxed);
        c.sum_micros
            .fetch_add((v.max(0.0) * 1e6).round() as u64, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }
}

#[derive(Default)]
struct Inner {
    counters: BTreeMap<String, Counter>,
    gauges: BTreeMap<String, Gauge>,
    hists: BTreeMap<String, Histogram>,
}

/// A registry of named metrics. See the module docs for the
/// registration-vs-update cost split.
#[derive(Default)]
pub struct Registry {
    inner: Mutex<Inner>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register (or fetch) the counter `name`.
    pub fn counter(&self, name: &str) -> Counter {
        let mut g = self.inner.lock().unwrap();
        g.counters
            .entry(name.to_string())
            .or_insert_with(|| Counter(Arc::new(AtomicU64::new(0))))
            .clone()
    }

    /// Register (or fetch) the gauge `name`.
    pub fn gauge(&self, name: &str) -> Gauge {
        let mut g = self.inner.lock().unwrap();
        g.gauges
            .entry(name.to_string())
            .or_insert_with(|| Gauge(Arc::new(AtomicI64::new(0))))
            .clone()
    }

    /// Register (or fetch) the histogram `name` with the given ascending
    /// upper bounds (an implicit +Inf bucket is appended). If `name`
    /// already exists its original bounds win.
    pub fn histogram(&self, name: &str, bounds: &[f64]) -> Histogram {
        let mut g = self.inner.lock().unwrap();
        g.hists
            .entry(name.to_string())
            .or_insert_with(|| {
                let mut buckets = Vec::with_capacity(bounds.len() + 1);
                for _ in 0..=bounds.len() {
                    buckets.push(AtomicU64::new(0));
                }
                Histogram(Arc::new(HistCore {
                    bounds: bounds.to_vec(),
                    buckets,
                    count: AtomicU64::new(0),
                    sum_micros: AtomicU64::new(0),
                }))
            })
            .clone()
    }

    /// Read every metric at one instant.
    pub fn snapshot(&self) -> Snapshot {
        let g = self.inner.lock().unwrap();
        Snapshot {
            counters: g.counters.iter().map(|(k, v)| (k.clone(), v.get())).collect(),
            gauges: g.gauges.iter().map(|(k, v)| (k.clone(), v.get())).collect(),
            hists: g
                .hists
                .iter()
                .map(|(k, h)| {
                    let c = &h.0;
                    HistSnapshot {
                        name: k.clone(),
                        bounds: c.bounds.clone(),
                        buckets: c.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
                        count: c.count.load(Ordering::Relaxed),
                        sum: c.sum_micros.load(Ordering::Relaxed) as f64 / 1e6,
                    }
                })
                .collect(),
        }
    }
}

/// One histogram, frozen.
pub struct HistSnapshot {
    pub name: String,
    pub bounds: Vec<f64>,
    /// Non-cumulative per-bucket counts, `bounds.len() + 1` long.
    pub buckets: Vec<u64>,
    pub count: u64,
    pub sum: f64,
}

/// A frozen, renderable view of a registry (name-sorted — scrapes are
/// byte-stable for a fixed set of values).
pub struct Snapshot {
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, i64)>,
    pub hists: Vec<HistSnapshot>,
}

/// `name{label="x"}` → `name` for `# TYPE` lines.
fn base_name(name: &str) -> &str {
    name.split('{').next().unwrap_or(name)
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

impl Snapshot {
    /// Merge several snapshots into one exposition (admin listener:
    /// global registry + the server's private registry).
    pub fn merge(parts: Vec<Snapshot>) -> Snapshot {
        let mut out = Snapshot {
            counters: Vec::new(),
            gauges: Vec::new(),
            hists: Vec::new(),
        };
        for p in parts {
            out.counters.extend(p.counters);
            out.gauges.extend(p.gauges);
            out.hists.extend(p.hists);
        }
        out.counters.sort_by(|a, b| a.0.cmp(&b.0));
        out.gauges.sort_by(|a, b| a.0.cmp(&b.0));
        out.hists.sort_by(|a, b| a.name.cmp(&b.name));
        out
    }

    /// Prometheus text exposition (version 0.0.4): `# TYPE` line per
    /// base name, cumulative `_bucket{le=...}` series for histograms.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        for (name, v) in &self.counters {
            out.push_str(&format!("# TYPE {} counter\n{name} {v}\n", base_name(name)));
        }
        for (name, v) in &self.gauges {
            out.push_str(&format!("# TYPE {} gauge\n{name} {v}\n", base_name(name)));
        }
        for h in &self.hists {
            let base = base_name(&h.name);
            out.push_str(&format!("# TYPE {base} histogram\n"));
            let mut cum = 0u64;
            for (i, b) in h.bounds.iter().enumerate() {
                cum += h.buckets[i];
                out.push_str(&format!("{base}_bucket{{le=\"{b}\"}} {cum}\n"));
            }
            cum += h.buckets.last().copied().unwrap_or(0);
            out.push_str(&format!("{base}_bucket{{le=\"+Inf\"}} {cum}\n"));
            out.push_str(&format!("{base}_sum {}\n", h.sum));
            out.push_str(&format!("{base}_count {}\n", h.count));
        }
        out
    }

    /// The same snapshot as one JSON object.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\"counters\":{");
        for (i, (name, v)) in self.counters.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("\"{}\":{v}", json_escape(name)));
        }
        out.push_str("},\"gauges\":{");
        for (i, (name, v)) in self.gauges.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("\"{}\":{v}", json_escape(name)));
        }
        out.push_str("},\"histograms\":{");
        for (i, h) in self.hists.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "\"{}\":{{\"count\":{},\"sum\":{},\"buckets\":[",
                json_escape(&h.name),
                h.count,
                h.sum
            ));
            let mut cum = 0u64;
            for (j, b) in h.bounds.iter().enumerate() {
                cum += h.buckets[j];
                if j > 0 {
                    out.push(',');
                }
                out.push_str(&format!("[{b},{cum}]"));
            }
            if !h.bounds.is_empty() {
                out.push(',');
            }
            cum += h.buckets.last().copied().unwrap_or(0);
            out.push_str(&format!("[null,{cum}]"));
            out.push_str("]}");
        }
        out.push_str("}}");
        out
    }
}

/// The process-wide registry. Library-path instrumentation (coordinator,
/// pool, mobility) registers here; counts aggregate across every run in
/// the process, so tests assert deltas/monotonicity, never absolutes.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handles_share_the_cell_and_names_are_stable() {
        let r = Registry::new();
        let a = r.counter("x_total");
        let b = r.counter("x_total");
        a.inc();
        b.add(2);
        assert_eq!(a.get(), 3);
        let g = r.gauge("depth");
        g.set(5);
        g.add(-2);
        assert_eq!(r.gauge("depth").get(), 3);
    }

    #[test]
    fn histogram_buckets_are_cumulative_in_the_exposition() {
        let r = Registry::new();
        let h = r.histogram("lat_seconds", &[0.1, 1.0]);
        h.observe(0.05); // bucket le=0.1
        h.observe(0.5); // bucket le=1.0
        h.observe(0.7); // bucket le=1.0
        h.observe(3.0); // +Inf
        let snap = r.snapshot();
        let text = snap.to_prometheus();
        assert!(text.contains("lat_seconds_bucket{le=\"0.1\"} 1\n"), "{text}");
        assert!(text.contains("lat_seconds_bucket{le=\"1\"} 3\n"), "{text}");
        assert!(text.contains("lat_seconds_bucket{le=\"+Inf\"} 4\n"), "{text}");
        assert!(text.contains("lat_seconds_count 4\n"), "{text}");
        // Fixed-point sum: 0.05 + 0.5 + 0.7 + 3.0 = 4.25 exactly.
        assert!(text.contains("lat_seconds_sum 4.25\n"), "{text}");
    }

    #[test]
    fn labeled_names_keep_their_base_for_type_lines() {
        let r = Registry::new();
        r.gauge("cell_members{cell=\"0\"}").set(4);
        r.gauge("cell_members{cell=\"1\"}").set(8);
        let text = r.snapshot().to_prometheus();
        assert!(text.contains("# TYPE cell_members gauge\n"), "{text}");
        assert!(text.contains("cell_members{cell=\"0\"} 4\n"), "{text}");
        assert!(text.contains("cell_members{cell=\"1\"} 8\n"), "{text}");
    }

    #[test]
    fn json_rendering_is_well_formed_enough_to_grep() {
        let r = Registry::new();
        r.counter("acks_total").add(7);
        let h = r.histogram("ms", &[1.0]);
        h.observe(0.5);
        let js = r.snapshot().to_json();
        assert!(js.contains("\"acks_total\":7"), "{js}");
        assert!(js.contains("\"ms\":{\"count\":1"), "{js}");
        assert!(js.starts_with('{') && js.ends_with('}'), "{js}");
    }

    #[test]
    fn merge_combines_and_sorts() {
        let a = Registry::new();
        a.counter("b_total").inc();
        let b = Registry::new();
        b.counter("a_total").inc();
        let merged = Snapshot::merge(vec![a.snapshot(), b.snapshot()]);
        assert_eq!(merged.counters[0].0, "a_total");
        assert_eq!(merged.counters[1].0, "b_total");
    }
}
