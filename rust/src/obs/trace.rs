//! `obs::trace` — a structured, sim-time-stamped JSONL event journal.
//!
//! Schema `paota-trace/1`: one JSON object per line,
//!
//! ```text
//! {"v":1,"kind":"round_close","t":12.5,"round":4,"uploads":7,...}
//! ```
//!
//! `v` is the schema version, `kind` the event name, `t` the **virtual**
//! clock (seconds) for simulation events — wire events carry wall-clock
//! fields (`ms`) instead. Numeric fields use Rust's shortest
//! round-trip `f64` formatting, so a parsed journal reproduces the
//! emitter's values bit for bit (the loadgen-percentile tie-down in
//! `tests/serve.rs` depends on this). The event vocabulary is
//! documented in EXPERIMENTS.md §obs.
//!
//! A [`TraceSink`] appends to its path (`O_APPEND`, one `write` per
//! line) so several emitters — per-cell coordinators, a server and its
//! in-process loadgen — can share one journal without interleaving
//! partial lines. `sample_every = n` keeps every n-th event **per
//! kind** (the first is always kept), thinning high-frequency kinds
//! without silencing rare ones.
//!
//! [`summarize`] replays a journal into per-kind counts, per-phase
//! latency percentiles (every kind carrying an `ms` field) and the
//! staleness distribution (every event carrying `staleness`), using the
//! same nearest-rank helpers ([`crate::obs::hist`]) as `repro loadgen`.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::fs::OpenOptions;
use std::io::Write as _;
use std::sync::Mutex;

use anyhow::{Context as _, Result};

use crate::config::ObsConfig;
use crate::obs::hist;

/// Trace JSONL schema version (the `"v"` field).
pub const SCHEMA_VERSION: u32 = 1;

/// One field value in a trace event.
pub enum V {
    U(u64),
    F(f64),
    S(String),
}

struct Inner {
    file: std::fs::File,
    sample_every: u64,
    /// Per-kind emit counters (sampling is per kind so rare events are
    /// never starved by frequent ones).
    seen: BTreeMap<String, u64>,
}

/// An append-only JSONL journal. Cheap to share by reference; `emit`
/// serializes under a private mutex and issues one `write` per line.
pub struct TraceSink {
    inner: Mutex<Inner>,
}

impl TraceSink {
    /// Open (append-create) the journal at `path`.
    pub fn open(path: &str, sample_every: u64) -> Result<Self> {
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .with_context(|| format!("opening trace journal {path}"))?;
        Ok(Self {
            inner: Mutex::new(Inner {
                file,
                sample_every: sample_every.max(1),
                seen: BTreeMap::new(),
            }),
        })
    }

    /// Open a sink if the `[obs]` section asks for one (`obs_trace_path`
    /// non-empty), `None` otherwise.
    pub fn from_cfg(obs: &ObsConfig) -> Result<Option<Self>> {
        if obs.trace_path.is_empty() {
            return Ok(None);
        }
        Ok(Some(Self::open(&obs.trace_path, obs.sample_every as u64)?))
    }

    /// Append one event. `sim_time` becomes the `"t"` field when
    /// present. Never touches simulation state — pure I/O.
    pub fn emit(&self, kind: &str, sim_time: Option<f64>, fields: &[(&str, V)]) {
        let mut g = self.inner.lock().unwrap();
        let n = g.seen.entry(kind.to_string()).or_insert(0);
        *n += 1;
        if (*n - 1) % g.sample_every != 0 {
            return;
        }
        let mut line = format!("{{\"v\":{SCHEMA_VERSION},\"kind\":\"{kind}\"");
        if let Some(t) = sim_time {
            let _ = write!(line, ",\"t\":{t}");
        }
        for (k, v) in fields {
            match v {
                V::U(x) => {
                    let _ = write!(line, ",\"{k}\":{x}");
                }
                V::F(x) => {
                    let _ = write!(line, ",\"{k}\":{x}");
                }
                V::S(x) => {
                    let esc = x.replace('\\', "\\\\").replace('"', "\\\"");
                    let _ = write!(line, ",\"{k}\":\"{esc}\"");
                }
            }
        }
        line.push_str("}\n");
        // One write per line + O_APPEND: concurrent sinks on the same
        // path never interleave partial lines.
        let _ = g.file.write_all(line.as_bytes());
    }
}

/// A parsed flat JSON value (trace events are flat objects).
#[derive(Debug, Clone, PartialEq)]
pub enum Val {
    Num(f64),
    Str(String),
}

impl Val {
    fn as_f64(&self) -> Option<f64> {
        match self {
            Val::Num(n) => Some(*n),
            Val::Str(_) => None,
        }
    }
}

/// Parse one flat JSONL trace line into key → value. Returns `None` on
/// anything that is not a flat object of strings/numbers (summaries
/// skip unparseable lines instead of failing the whole replay).
pub fn parse_line(line: &str) -> Option<BTreeMap<String, Val>> {
    let s = line.trim();
    let body = s.strip_prefix('{')?.strip_suffix('}')?;
    let mut out = BTreeMap::new();
    let bytes = body.as_bytes();
    let mut i = 0usize;
    while i < bytes.len() {
        // Key: "..."
        if bytes[i] != b'"' {
            return None;
        }
        i += 1;
        let kstart = i;
        while i < bytes.len() && bytes[i] != b'"' {
            if bytes[i] == b'\\' {
                i += 1;
            }
            i += 1;
        }
        if i >= bytes.len() {
            return None;
        }
        let key = body[kstart..i].to_string();
        i += 1;
        if i >= bytes.len() || bytes[i] != b':' {
            return None;
        }
        i += 1;
        // Value: string or number.
        if i < bytes.len() && bytes[i] == b'"' {
            i += 1;
            let vstart = i;
            while i < bytes.len() && bytes[i] != b'"' {
                if bytes[i] == b'\\' {
                    i += 1;
                }
                i += 1;
            }
            if i >= bytes.len() {
                return None;
            }
            let raw = &body[vstart..i];
            out.insert(key, Val::Str(raw.replace("\\\"", "\"").replace("\\\\", "\\")));
            i += 1;
        } else {
            let vstart = i;
            while i < bytes.len() && bytes[i] != b',' {
                i += 1;
            }
            let num: f64 = body[vstart..i].trim().parse().ok()?;
            out.insert(key, Val::Num(num));
        }
        if i < bytes.len() {
            if bytes[i] != b',' {
                return None;
            }
            i += 1;
        }
    }
    Some(out)
}

/// Replay a journal into per-phase latency and staleness distribution
/// tables (returned as printable text; `repro trace summarize` prints
/// it verbatim).
pub fn summarize(path: &str) -> Result<String> {
    let raw = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace journal {path}"))?;
    let mut counts: BTreeMap<String, u64> = BTreeMap::new();
    let mut latency: BTreeMap<String, Vec<f64>> = BTreeMap::new();
    let mut staleness: Vec<f64> = Vec::new();
    let mut total = 0u64;
    let mut skipped = 0u64;
    for line in raw.lines() {
        if line.trim().is_empty() {
            continue;
        }
        let Some(ev) = parse_line(line) else {
            skipped += 1;
            continue;
        };
        let Some(Val::Str(kind)) = ev.get("kind").cloned() else {
            skipped += 1;
            continue;
        };
        total += 1;
        *counts.entry(kind.clone()).or_insert(0) += 1;
        if let Some(ms) = ev.get("ms").and_then(Val::as_f64) {
            latency.entry(kind.clone()).or_default().push(ms);
        }
        if let Some(s) = ev.get("staleness").and_then(Val::as_f64) {
            staleness.push(s);
        }
    }

    let mut out = String::new();
    let _ = writeln!(
        out,
        "# trace summary — {total} events, {} kinds (schema paota-trace/{SCHEMA_VERSION}{})",
        counts.len(),
        if skipped > 0 {
            format!("; {skipped} unparseable lines skipped")
        } else {
            String::new()
        }
    );
    let _ = writeln!(out, "# events");
    for (kind, n) in &counts {
        let _ = writeln!(out, "{kind} {n}");
    }
    if !latency.is_empty() {
        let _ = writeln!(out, "# latency_ms (nearest-rank)");
        let _ = writeln!(out, "kind count p50 p90 p99");
        for (kind, samples) in latency.iter_mut() {
            let (p50, p90, p99) = hist::p50_p90_p99(samples);
            let _ = writeln!(
                out,
                "{kind} {} {p50:.3} {p90:.3} {p99:.3}",
                samples.len()
            );
            if kind == "wire_submit" {
                // The loadgen's own summary line, reproduced from the
                // journal — same samples, same nearest-rank helpers,
                // same `{:.2}` formatting, so the two lines agree
                // byte for byte.
                let _ = writeln!(
                    out,
                    "# submit_ms p50={p50:.2} p90={p90:.2} p99={p99:.2}"
                );
            }
        }
    }
    if !staleness.is_empty() {
        staleness.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let max = staleness.last().copied().unwrap_or(0.0);
        let mean = staleness.iter().sum::<f64>() / staleness.len() as f64;
        let _ = writeln!(out, "# staleness (rounds)");
        let _ = writeln!(out, "count mean p50 p90 p99 max");
        let _ = writeln!(
            out,
            "{} {mean:.3} {:.3} {:.3} {:.3} {max:.3}",
            staleness.len(),
            hist::percentile(&staleness, 50.0),
            hist::percentile(&staleness, 90.0),
            hist::percentile(&staleness, 99.0),
        );
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    fn tmp_path(tag: &str) -> String {
        static N: AtomicU64 = AtomicU64::new(0);
        let n = N.fetch_add(1, Ordering::Relaxed);
        std::env::temp_dir()
            .join(format!("paota_trace_{tag}_{}_{n}.jsonl", std::process::id()))
            .to_string_lossy()
            .into_owned()
    }

    #[test]
    fn emit_parse_roundtrip_is_exact() {
        let path = tmp_path("roundtrip");
        let sink = TraceSink::open(&path, 1).unwrap();
        let ms = 1.0 / 3.0 * 100.0; // not representable in short decimal
        sink.emit(
            "wire_submit",
            None,
            &[("ms", V::F(ms)), ("round", V::U(4)), ("who", V::S("s\"1".into()))],
        );
        sink.emit("round_close", Some(2.5), &[("staleness", V::F(1.0))]);
        drop(sink);
        let raw = std::fs::read_to_string(&path).unwrap();
        let ev = parse_line(raw.lines().next().unwrap()).unwrap();
        assert_eq!(ev.get("kind"), Some(&Val::Str("wire_submit".into())));
        // Shortest round-trip f64 formatting: parsed == emitted, bitwise.
        let got = ev.get("ms").unwrap().as_f64().unwrap();
        assert_eq!(got.to_bits(), ms.to_bits());
        assert_eq!(ev.get("who"), Some(&Val::Str("s\"1".into())));
        let ev2 = parse_line(raw.lines().nth(1).unwrap()).unwrap();
        assert_eq!(ev2.get("t").unwrap().as_f64().unwrap(), 2.5);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn sampling_keeps_every_nth_per_kind() {
        let path = tmp_path("sample");
        let sink = TraceSink::open(&path, 3).unwrap();
        for i in 0..7 {
            sink.emit("frequent", None, &[("i", V::U(i))]);
        }
        sink.emit("rare", None, &[]);
        drop(sink);
        let raw = std::fs::read_to_string(&path).unwrap();
        let frequent = raw.lines().filter(|l| l.contains("frequent")).count();
        let rare = raw.lines().filter(|l| l.contains("rare")).count();
        assert_eq!(frequent, 3, "kept 0,3,6 of 0..7:\n{raw}");
        assert_eq!(rare, 1, "first event of a kind always kept:\n{raw}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn summarize_builds_latency_and_staleness_tables() {
        let path = tmp_path("summary");
        let sink = TraceSink::open(&path, 1).unwrap();
        for ms in [5.0, 1.0, 9.0] {
            sink.emit("wire_submit", None, &[("ms", V::F(ms))]);
        }
        sink.emit("arrival", Some(1.0), &[("staleness", V::F(2.0))]);
        sink.emit("arrival", Some(2.0), &[("staleness", V::F(0.0))]);
        drop(sink);
        let text = summarize(&path).unwrap();
        assert!(text.contains("wire_submit 3"), "{text}");
        assert!(text.contains("# submit_ms p50=5.00 p90=9.00 p99=9.00"), "{text}");
        assert!(text.contains("# staleness (rounds)"), "{text}");
        assert!(text.contains("2 1.000 "), "{text}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn summarize_skips_garbage_lines() {
        let path = tmp_path("garbage");
        std::fs::write(
            &path,
            "{\"v\":1,\"kind\":\"x\"}\nnot json at all\n{\"v\":1,\"kind\":\"x\"}\n",
        )
        .unwrap();
        let text = summarize(&path).unwrap();
        assert!(text.contains("x 2"), "{text}");
        assert!(text.contains("1 unparseable"), "{text}");
        std::fs::remove_file(&path).ok();
    }
}
