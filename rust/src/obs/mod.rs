//! `obs` — observability for live runs: a lock-light metrics registry,
//! a structured sim-time-stamped trace journal, and a scrape endpoint.
//!
//! The post-hoc [`RoundRecord`](crate::fl::RoundRecord) stream answers
//! "what happened" after a run; `obs` answers "what is happening" while
//! a campaign or a `repro serve` soak is in flight:
//!
//! * [`metrics`] — named counters, gauges and fixed-bucket histograms
//!   backed by atomics. Registration takes a short lock once per
//!   (name, process); updates through the returned handles are
//!   wait-free and allocation-free. Snapshots render as Prometheus
//!   text exposition or JSON.
//! * [`trace`] — an append-only JSONL event journal (schema
//!   `paota-trace/1`): round open/close, slot dispatch, OTA aggregate
//!   with power, handover, wire accept/reject/busy, submit latency.
//!   Every simulation event carries the **virtual** clock; wire events
//!   carry wall time. A sampling knob (`obs_sample_every`) thins
//!   high-frequency kinds. `repro trace summarize` replays a journal
//!   into per-phase latency and staleness distribution tables.
//! * [`admin`] — a minimal HTTP listener (`/metrics`, `/metrics.json`,
//!   `/healthz`) so a loadgen soak can be watched live with `curl`.
//! * [`hist`] — the shared nearest-rank percentile helpers used by
//!   both `repro loadgen` and `trace summarize`.
//!
//! ## The neutrality contract
//!
//! Observation is strictly **read-only on simulation state**: no obs
//! call ever draws from an RNG stream, advances the virtual clock, or
//! reorders work. With the `[obs]` config section unset (the default)
//! no file is opened and no socket is bound; with it set, runs must
//! stay bitwise identical to unobserved runs — `tests/golden_seed.rs`
//! and `tests/serve.rs` pin this (the `obs_*_neutral` tests).

pub mod admin;
pub mod hist;
pub mod metrics;
pub mod trace;

pub use admin::AdminServer;
pub use metrics::{Counter, Gauge, Histogram, Registry};
pub use trace::TraceSink;
