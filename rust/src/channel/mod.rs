//! Wireless MAC channel simulator (paper §II-C, §IV-A).
//!
//! Models exactly what the paper assumes:
//! * error-free downlink broadcast;
//! * uplink wireless multiple-access channel with **AirComp**: perfect CSI
//!   at transmitters and PS, channel-inversion pre-processing
//!   `φ_k = b_k p_k h_kᴴ/|h_k|²` (eq. (5)), so the received superposition
//!   is `Σ_k b_k p_k w_k + n` (eq. (6));
//! * i.i.d. Rayleigh block fading per round (`h_k ~ CN(0,1)`, so
//!   `|h_k|² ~ Exp(1)`), independent across rounds;
//! * AWGN with `σ_n² = B·N₀` (paper: B = 20 MHz,
//!   N₀ ∈ {−174, −74} dBm/Hz).
//!
//! The fading realization enters through the transmit-power constraint
//! (eq. (7)): inverting a deep fade costs power, so the usable transmit
//! coefficient is capped at `|h_k|·√(P_max)/‖w_k‖` — see
//! [`Mac::effective_power_cap`].

use crate::util::Rng;

/// Convert a dBm value to watts.
pub fn dbm_to_watts(dbm: f64) -> f64 {
    10f64.powf(dbm / 10.0) / 1000.0
}

/// Convert watts to dBm.
pub fn watts_to_dbm(w: f64) -> f64 {
    10.0 * (w * 1000.0).log10()
}

/// Static channel parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChannelConfig {
    /// Uplink bandwidth in Hz (paper: 20 MHz).
    pub bandwidth_hz: f64,
    /// Noise power spectral density in dBm/Hz (paper: −174 or −74).
    pub n0_dbm_per_hz: f64,
}

impl Default for ChannelConfig {
    fn default() -> Self {
        Self {
            bandwidth_hz: 20e6,
            n0_dbm_per_hz: -174.0,
        }
    }
}

impl ChannelConfig {
    /// AWGN power `σ_n² = B·N₀` in watts.
    pub fn noise_power(&self) -> f64 {
        self.bandwidth_hz * dbm_to_watts(self.n0_dbm_per_hz)
    }

    /// This channel with the noise floor shifted by `delta_db` dB — the
    /// per-cell residence scope of `fl::mobility`: each cell serves its
    /// residents from its own `ChannelConfig`, so a client's effective
    /// uplink is re-drawn from the *new* cell's scope the moment it hands
    /// over (`mobility.cell_noise_spread_db` spreads cells around the
    /// configured N₀; 0 dB keeps every cell on the base channel).
    pub fn with_n0_offset(mut self, delta_db: f64) -> Self {
        self.n0_dbm_per_hz += delta_db;
        self
    }
}

/// Per-round state of the MAC uplink.
#[derive(Debug, Clone)]
pub struct Mac {
    cfg: ChannelConfig,
}

impl Mac {
    pub fn new(cfg: ChannelConfig) -> Self {
        Self { cfg }
    }

    pub fn config(&self) -> &ChannelConfig {
        &self.cfg
    }

    /// Draw one round of i.i.d. Rayleigh fading power gains `|h_k|²`
    /// (Exp(1), unit mean — `h_k ~ CN(0,1)`).
    pub fn draw_fading_gains(&self, rng: &mut Rng, k: usize) -> Vec<f64> {
        (0..k).map(|_| rng.exponential(1.0)).collect()
    }

    /// Effective transmit-coefficient cap for client `k` this round.
    ///
    /// Channel inversion (eq. (5)) spends `p_k²‖w‖²/|h_k|²` watts of
    /// instantaneous signal power (eq. (7)): the largest usable `p_k` is
    /// `|h_k|·√P_max/‖w‖`. Capped additionally at `p_max` itself so a
    /// lucky fade never *raises* the nominal budget.
    pub fn effective_power_cap(&self, p_max: f64, gain2: f64, w_norm: f64) -> f64 {
        if w_norm <= 0.0 {
            return p_max;
        }
        let cap = gain2.sqrt() * p_max.sqrt() / w_norm;
        cap.min(p_max)
    }

    /// Draw the raw received AWGN vector `n` of eq. (6): i.i.d.
    /// `N(0, σ_n²)` per entry.
    ///
    /// This is what the aggregation kernel consumes — the kernel itself
    /// performs the PS normalization `(…+n)/ς` of eq. (8), so the noise
    /// handed to it must be *pre*-normalization. (Dividing here too would
    /// silently attenuate the channel by another factor of ς — covered by
    /// the `paota_more_noise_worse_or_equal` integration test.)
    pub fn channel_noise(&self, rng: &mut Rng, dim: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; dim];
        let std = self.cfg.noise_power().sqrt();
        rng.fill_normal(&mut out, std as f32);
        out
    }

    /// Draw the post-normalization AWGN vector `ñ = n/ς` (eq. (8)):
    /// i.i.d. `N(0, σ_n²)` scaled by `1/ς` with `ς = Σ_k b_k p_k`.
    ///
    /// For consumers that do NOT normalize again (diagnostics, direct
    /// model perturbation). Returns zeros when `ς = 0` (no participants —
    /// the coordinator skips aggregation in that case anyway).
    pub fn equivalent_noise(&self, rng: &mut Rng, dim: usize, sigma_sum: f64) -> Vec<f32> {
        let mut out = vec![0.0f32; dim];
        if sigma_sum <= 0.0 {
            return out;
        }
        let std = self.cfg.noise_power().sqrt() / sigma_sum;
        rng.fill_normal(&mut out, std as f32);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, prop_assert, prop_close};

    #[test]
    fn dbm_conversions_roundtrip() {
        assert!((dbm_to_watts(0.0) - 1e-3).abs() < 1e-12);
        assert!((dbm_to_watts(30.0) - 1.0).abs() < 1e-9);
        for dbm in [-174.0, -74.0, 0.0, 15.0] {
            assert!((watts_to_dbm(dbm_to_watts(dbm)) - dbm).abs() < 1e-9);
        }
    }

    #[test]
    fn paper_noise_powers() {
        // B = 20 MHz, N0 = -174 dBm/Hz -> σ² ≈ 7.96e-14 W.
        let quiet = ChannelConfig {
            bandwidth_hz: 20e6,
            n0_dbm_per_hz: -174.0,
        };
        assert!((quiet.noise_power() - 7.96e-14).abs() < 1e-15);
        // N0 = -74 dBm/Hz -> 1e10 times more noise.
        let loud = ChannelConfig {
            bandwidth_hz: 20e6,
            n0_dbm_per_hz: -74.0,
        };
        let ratio = loud.noise_power() / quiet.noise_power();
        assert!((ratio - 1e10).abs() / 1e10 < 1e-9);
    }

    #[test]
    fn n0_offset_shifts_noise_power_multiplicatively() {
        let base = ChannelConfig::default();
        let hot = base.with_n0_offset(10.0);
        assert_eq!(hot.bandwidth_hz, base.bandwidth_hz);
        assert!((hot.n0_dbm_per_hz - (base.n0_dbm_per_hz + 10.0)).abs() < 1e-12);
        // +10 dB = 10× the noise power; 0 dB is the identity.
        let ratio = hot.noise_power() / base.noise_power();
        assert!((ratio - 10.0).abs() < 1e-9, "ratio={ratio}");
        assert_eq!(base.with_n0_offset(0.0), base);
    }

    #[test]
    fn fading_gains_exp1_moments() {
        let mac = Mac::new(ChannelConfig::default());
        let mut rng = Rng::new(1);
        let n = 100_000;
        let gains = mac.draw_fading_gains(&mut rng, n);
        let mean: f64 = gains.iter().sum::<f64>() / n as f64;
        let var: f64 =
            gains.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}"); // Exp(1): var = 1
        assert!(gains.iter().all(|&g| g >= 0.0));
    }

    #[test]
    fn effective_cap_properties() {
        let mac = Mac::new(ChannelConfig::default());
        check("power cap ≤ p_max and monotone in gain", 100, |g| {
            let p_max = g.f64_in(0.1..20.0);
            let gain = g.f64_in(0.0..5.0);
            let wn = g.f64_in(0.1..50.0);
            let cap = mac.effective_power_cap(p_max, gain, wn);
            prop_assert(cap <= p_max + 1e-12, "cap exceeds p_max")?;
            prop_assert(cap >= 0.0, "negative cap")?;
            let cap2 = mac.effective_power_cap(p_max, gain * 2.0, wn);
            prop_assert(cap2 >= cap - 1e-12, "not monotone in gain")
        });
    }

    #[test]
    fn effective_cap_zero_norm_is_pmax() {
        let mac = Mac::new(ChannelConfig::default());
        assert_eq!(mac.effective_power_cap(15.0, 0.5, 0.0), 15.0);
    }

    #[test]
    fn equivalent_noise_scaling() {
        let cfg = ChannelConfig {
            bandwidth_hz: 20e6,
            n0_dbm_per_hz: -74.0,
        };
        let mac = Mac::new(cfg);
        let mut rng = Rng::new(2);
        let dim = 50_000;
        let sigma_sum = 100.0;
        let v = mac.equivalent_noise(&mut rng, dim, sigma_sum);
        let want_std = cfg.noise_power().sqrt() / sigma_sum;
        let emp_var: f64 =
            v.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>() / dim as f64;
        prop_close(emp_var.sqrt(), want_std, 0.02, "noise std").unwrap();
    }

    #[test]
    fn channel_noise_has_sigma_n_std() {
        let cfg = ChannelConfig {
            bandwidth_hz: 20e6,
            n0_dbm_per_hz: -74.0,
        };
        let mac = Mac::new(cfg);
        let mut rng = Rng::new(7);
        let dim = 50_000;
        let v = mac.channel_noise(&mut rng, dim);
        let emp_var: f64 =
            v.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>() / dim as f64;
        prop_close(emp_var.sqrt(), cfg.noise_power().sqrt(), 0.02, "raw noise std")
            .unwrap();
    }

    #[test]
    fn equivalent_noise_zero_participants_is_zero() {
        let mac = Mac::new(ChannelConfig::default());
        let mut rng = Rng::new(3);
        let v = mac.equivalent_noise(&mut rng, 100, 0.0);
        assert!(v.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn quiet_channel_noise_is_negligible_vs_model_scale() {
        // At the paper's default (-174 dBm/Hz) the per-entry noise after
        // normalization by ς ~ 100 W is ~1e-9 — the "close to ideal" regime
        // of Fig. 3a.
        let mac = Mac::new(ChannelConfig::default());
        let std = mac.config().noise_power().sqrt() / 100.0;
        assert!(std < 1e-8, "std={std}");
    }
}
