//! Continuous-time event queue — the single scheduling driver behind
//! [`crate::fl::coordinator::Coordinator`]: client-finished arrivals for
//! every timing mode (periodic PAOTA slots, continuous FedAsync arrivals)
//! flow through one of these. A min-heap over f64 timestamps with FIFO
//! tie-breaking (stable order for simultaneous events keeps runs
//! reproducible, and lets the coordinator coalesce same-timestamp
//! arrivals into one batched `train_many` call).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// A timestamped event.
#[derive(Debug, Clone)]
struct Entry<T> {
    time: f64,
    seq: u64,
    payload: T,
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}

impl<T> Eq for Entry<T> {}

impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse: BinaryHeap is a max-heap, we want the earliest time.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Min-heap event queue keyed by virtual time.
#[derive(Debug)]
pub struct EventQueue<T> {
    heap: BinaryHeap<Entry<T>>,
    seq: u64,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> Self {
        Self {
            heap: BinaryHeap::new(),
            seq: 0,
        }
    }

    /// Schedule `payload` at `time` (must be finite).
    pub fn push(&mut self, time: f64, payload: T) {
        assert!(time.is_finite(), "event time must be finite");
        self.heap.push(Entry {
            time,
            seq: self.seq,
            payload,
        });
        self.seq += 1;
    }

    /// Pop the earliest event as `(time, payload)`.
    pub fn pop(&mut self) -> Option<(f64, T)> {
        self.heap.pop().map(|e| (e.time, e.payload))
    }

    /// Earliest scheduled time without popping.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time)
    }

    /// Pop the earliest event only if it is due at or before `t` — the
    /// drain primitive for time-triggered aggregation slots.
    pub fn pop_until(&mut self, t: f64) -> Option<(f64, T)> {
        if self.peek_time()? <= t {
            self.pop()
        } else {
            None
        }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, prop_assert};
    use crate::util::Rng;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        assert_eq!(q.pop(), Some((1.0, "a")));
        assert_eq!(q.pop(), Some((2.0, "b")));
        assert_eq!(q.pop(), Some((3.0, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn simultaneous_events_are_fifo() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.push(5.0, i);
        }
        for i in 0..10 {
            assert_eq!(q.pop(), Some((5.0, i)));
        }
    }

    #[test]
    fn random_schedule_sorts() {
        check("event queue sorts any schedule", 50, |g| {
            let mut q = EventQueue::new();
            let mut rng = Rng::new(g.rng().next_u64());
            let n = g.usize_in(1..200);
            for i in 0..n {
                q.push(rng.uniform(0.0, 100.0), i);
            }
            prop_assert(q.len() == n, "length")?;
            let mut last = f64::NEG_INFINITY;
            while let Some((t, _)) = q.pop() {
                prop_assert(t >= last, "out of order")?;
                last = t;
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn rejects_nan_time() {
        EventQueue::new().push(f64::NAN, 0);
    }

    #[test]
    fn pop_until_respects_deadline() {
        let mut q = EventQueue::new();
        q.push(1.0, "a");
        q.push(2.0, "b");
        q.push(3.0, "c");
        // Boundary is inclusive: an event exactly at the slot end is due.
        assert_eq!(q.pop_until(2.0), Some((1.0, "a")));
        assert_eq!(q.pop_until(2.0), Some((2.0, "b")));
        assert_eq!(q.pop_until(2.0), None);
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop_until(10.0), Some((3.0, "c")));
        assert_eq!(q.pop_until(10.0), None);
    }
}
