//! Continuous-time event queue — the single scheduling driver behind
//! [`crate::fl::coordinator::Coordinator`]: client-finished arrivals for
//! every timing mode (periodic PAOTA slots, continuous FedAsync arrivals)
//! flow through one of these. A min-heap over f64 timestamps with FIFO
//! tie-breaking (stable order for simultaneous events keeps runs
//! reproducible, and lets the coordinator coalesce same-timestamp
//! arrivals into one batched `train_many` call).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// A timestamped event.
#[derive(Debug, Clone)]
struct Entry<T> {
    time: f64,
    seq: u64,
    payload: T,
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}

impl<T> Eq for Entry<T> {}

impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse: BinaryHeap is a max-heap, we want the earliest time.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Min-heap event queue keyed by virtual time.
#[derive(Debug)]
pub struct EventQueue<T> {
    heap: BinaryHeap<Entry<T>>,
    seq: u64,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> Self {
        Self {
            heap: BinaryHeap::new(),
            seq: 0,
        }
    }

    /// Schedule `payload` at `time` (must be finite).
    pub fn push(&mut self, time: f64, payload: T) {
        assert!(time.is_finite(), "event time must be finite");
        self.heap.push(Entry {
            time,
            seq: self.seq,
            payload,
        });
        self.seq += 1;
    }

    /// Pop the earliest event as `(time, payload)`.
    pub fn pop(&mut self) -> Option<(f64, T)> {
        self.heap.pop().map(|e| (e.time, e.payload))
    }

    /// Earliest scheduled time without popping.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time)
    }

    /// Pop the earliest event only if it is due at or before `t` — the
    /// drain primitive for time-triggered aggregation slots.
    pub fn pop_until(&mut self, t: f64) -> Option<(f64, T)> {
        if self.peek_time()? <= t {
            self.pop()
        } else {
            None
        }
    }

    /// Remove and return the earliest-scheduled event whose payload
    /// matches `pred`, leaving every other entry (and the FIFO order of
    /// simultaneous events) untouched — the detach primitive for client
    /// handover ([`crate::fl::Coordinator::detach_client`]).
    pub fn remove_first(&mut self, pred: impl Fn(&T) -> bool) -> Option<(f64, T)> {
        let entries = std::mem::take(&mut self.heap).into_sorted_vec();
        let mut removed = None;
        let mut kept = Vec::with_capacity(entries.len());
        // `into_sorted_vec` is ascending by `Ord`, i.e. *latest* first
        // under our reversed ordering — scan from the back for the
        // earliest match.
        for entry in entries.into_iter().rev() {
            if removed.is_none() && pred(&entry.payload) {
                removed = Some((entry.time, entry.payload));
            } else {
                kept.push(entry);
            }
        }
        self.heap = BinaryHeap::from(kept);
        removed
    }

    /// Remove every event whose payload matches `pred` in one pass (one
    /// heap rebuild, FIFO order of survivors preserved); returns how many
    /// were dropped. The purge primitive behind handover admits.
    pub fn remove_all(&mut self, pred: impl Fn(&T) -> bool) -> usize {
        let before = self.heap.len();
        let kept: Vec<Entry<T>> = std::mem::take(&mut self.heap)
            .into_vec()
            .into_iter()
            .filter(|e| !pred(&e.payload))
            .collect();
        let removed = before - kept.len();
        self.heap = BinaryHeap::from(kept);
        removed
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, prop_assert};
    use crate::util::Rng;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        assert_eq!(q.pop(), Some((1.0, "a")));
        assert_eq!(q.pop(), Some((2.0, "b")));
        assert_eq!(q.pop(), Some((3.0, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn simultaneous_events_are_fifo() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.push(5.0, i);
        }
        for i in 0..10 {
            assert_eq!(q.pop(), Some((5.0, i)));
        }
    }

    #[test]
    fn random_schedule_sorts() {
        check("event queue sorts any schedule", 50, |g| {
            let mut q = EventQueue::new();
            let mut rng = Rng::new(g.rng().next_u64());
            let n = g.usize_in(1..200);
            for i in 0..n {
                q.push(rng.uniform(0.0, 100.0), i);
            }
            prop_assert(q.len() == n, "length")?;
            let mut last = f64::NEG_INFINITY;
            while let Some((t, _)) = q.pop() {
                prop_assert(t >= last, "out of order")?;
                last = t;
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn rejects_nan_time() {
        EventQueue::new().push(f64::NAN, 0);
    }

    #[test]
    fn remove_first_takes_earliest_match_and_preserves_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "late-a");
        q.push(1.0, "b");
        q.push(2.0, "a");
        q.push(2.0, "a2");
        // Earliest "a*" match is at t = 2 (payload "a", pushed before "a2").
        let got = q.remove_first(|p| p.starts_with('a'));
        assert_eq!(got, Some((2.0, "a")));
        // Everything else pops in the original time/FIFO order.
        assert_eq!(q.pop(), Some((1.0, "b")));
        assert_eq!(q.pop(), Some((2.0, "a2")));
        assert_eq!(q.pop(), Some((3.0, "late-a")));
        // No match leaves the queue untouched.
        let mut q = EventQueue::new();
        q.push(1.0, 7usize);
        assert_eq!(q.remove_first(|&p| p == 9), None);
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn remove_all_drops_every_match_in_one_pass() {
        let mut q = EventQueue::new();
        for i in 0..8 {
            q.push(i as f64, i);
        }
        assert_eq!(q.remove_all(|&p| p % 2 == 0), 4);
        assert_eq!(q.remove_all(|&p| p % 2 == 0), 0);
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, vec![1, 3, 5, 7]);
    }

    #[test]
    fn remove_first_keeps_fifo_among_simultaneous_survivors() {
        let mut q = EventQueue::new();
        for i in 0..6 {
            q.push(5.0, i);
        }
        assert_eq!(q.remove_first(|&p| p == 3), Some((5.0, 3)));
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, vec![0, 1, 2, 4, 5]);
    }

    #[test]
    fn pop_until_respects_deadline() {
        let mut q = EventQueue::new();
        q.push(1.0, "a");
        q.push(2.0, "b");
        q.push(3.0, "c");
        // Boundary is inclusive: an event exactly at the slot end is due.
        assert_eq!(q.pop_until(2.0), Some((1.0, "a")));
        assert_eq!(q.pop_until(2.0), Some((2.0, "b")));
        assert_eq!(q.pop_until(2.0), None);
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop_until(10.0), Some((3.0, "c")));
        assert_eq!(q.pop_until(10.0), None);
    }
}
