//! Continuous-time event queue — the single scheduling driver behind
//! [`crate::fl::coordinator::Coordinator`]: client-finished arrivals for
//! every timing mode (periodic PAOTA slots, continuous FedAsync arrivals)
//! flow through one of these. A min-heap over f64 timestamps with FIFO
//! tie-breaking (stable order for simultaneous events keeps runs
//! reproducible, and lets the coordinator coalesce same-timestamp
//! arrivals into one batched `train_many` call).
//!
//! Removal (`remove_first` / `remove_all`) is O(log n) per entry via a
//! payload index plus tombstones: every live entry is tracked in a
//! `payload -> BTreeSet<(time, seq)>` side map, removal tombstones the
//! entry's sequence number, and `pop`/`peek_time` lazily skip tombstoned
//! entries as they surface. The heap is compacted once tombstones
//! outnumber live entries, so memory stays proportional to the live set.
//! The seed implementation rebuilt the entire heap per removal — O(n log n)
//! per handover, which the mobility sweep hits every `handover_every`
//! slots (see `benches/fleet_scale.rs` for the trajectory).

use std::cmp::Ordering;
use std::collections::{BTreeSet, BinaryHeap, HashMap, HashSet};
use std::hash::Hash;

/// A timestamped event.
#[derive(Debug, Clone)]
struct Entry<T> {
    time: f64,
    seq: u64,
    payload: T,
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}

impl<T> Eq for Entry<T> {}

impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse: BinaryHeap is a max-heap, we want the earliest time.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Monotone u64 image of a finite, non-`-0.0` f64: preserves `<` so the
/// index BTreeSet orders entries exactly as the heap's time comparison.
fn order_bits(t: f64) -> u64 {
    let b = t.to_bits() as i64;
    if b < 0 {
        !(b as u64)
    } else {
        (b as u64) | (1 << 63)
    }
}

fn time_of_bits(b: u64) -> f64 {
    if b >> 63 == 1 {
        f64::from_bits(b & !(1 << 63))
    } else {
        f64::from_bits(!b)
    }
}

/// Min-heap event queue keyed by virtual time, with an O(log n) payload
/// index for targeted removal.
#[derive(Debug)]
pub struct EventQueue<T> {
    heap: BinaryHeap<Entry<T>>,
    /// Live entries per payload, ordered by (time, seq) — the earliest
    /// match for a payload is the set's first element.
    index: HashMap<T, BTreeSet<(u64, u64)>>,
    /// Sequence numbers removed through the index but still buried in
    /// `heap`; skipped lazily by `pop`/`peek_time`.
    dead: HashSet<u64>,
    seq: u64,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> Self {
        Self {
            heap: BinaryHeap::new(),
            index: HashMap::new(),
            dead: HashSet::new(),
            seq: 0,
        }
    }

    /// Number of live (non-tombstoned) events.
    pub fn len(&self) -> usize {
        self.heap.len() - self.dead.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T: Clone + Eq + Hash> EventQueue<T> {
    /// Schedule `payload` at `time` (must be finite).
    pub fn push(&mut self, time: f64, payload: T) {
        assert!(time.is_finite(), "event time must be finite");
        // Normalize -0.0 so the index's total order agrees with the
        // heap's partial_cmp (which ties -0.0 and +0.0 by seq).
        let time = if time == 0.0 { 0.0 } else { time };
        self.index
            .entry(payload.clone())
            .or_default()
            .insert((order_bits(time), self.seq));
        self.heap.push(Entry {
            time,
            seq: self.seq,
            payload,
        });
        self.seq += 1;
    }

    /// Pop the earliest event as `(time, payload)`.
    pub fn pop(&mut self) -> Option<(f64, T)> {
        while let Some(e) = self.heap.pop() {
            if self.dead.remove(&e.seq) {
                continue;
            }
            self.unindex(&e.payload, e.time, e.seq);
            return Some((e.time, e.payload));
        }
        None
    }

    /// Earliest scheduled time without popping. `&mut` because
    /// tombstoned entries are discarded as they surface.
    pub fn peek_time(&mut self) -> Option<f64> {
        loop {
            let (time, seq) = match self.heap.peek() {
                Some(e) => (e.time, e.seq),
                None => return None,
            };
            if self.dead.contains(&seq) {
                self.heap.pop();
                self.dead.remove(&seq);
            } else {
                return Some(time);
            }
        }
    }

    /// Pop the earliest event only if it is due at or before `t` — the
    /// drain primitive for time-triggered aggregation slots.
    pub fn pop_until(&mut self, t: f64) -> Option<(f64, T)> {
        if self.peek_time()? <= t {
            self.pop()
        } else {
            None
        }
    }

    /// Remove and return the earliest-scheduled event whose payload
    /// equals `key`, leaving every other entry (and the FIFO order of
    /// simultaneous events) untouched — the detach primitive for client
    /// handover ([`crate::fl::Coordinator::detach_client`]). O(log n):
    /// the payload index pinpoints the entry, a tombstone retires it.
    pub fn remove_first(&mut self, key: &T) -> Option<(f64, T)> {
        let set = self.index.get_mut(key)?;
        let &(bits, seq) = set.iter().next()?;
        set.remove(&(bits, seq));
        if set.is_empty() {
            self.index.remove(key);
        }
        self.dead.insert(seq);
        self.maybe_compact();
        Some((time_of_bits(bits), key.clone()))
    }

    /// Remove every event whose payload equals `key`; returns how many
    /// were dropped. The purge primitive behind handover admits.
    pub fn remove_all(&mut self, key: &T) -> usize {
        let Some(set) = self.index.remove(key) else {
            return 0;
        };
        let removed = set.len();
        for (_, seq) in set {
            self.dead.insert(seq);
        }
        self.maybe_compact();
        removed
    }

    fn unindex(&mut self, payload: &T, time: f64, seq: u64) {
        if let Some(set) = self.index.get_mut(payload) {
            set.remove(&(order_bits(time), seq));
            if set.is_empty() {
                self.index.remove(payload);
            }
        }
    }

    /// Rebuild the heap without tombstoned entries once they outnumber
    /// the live set, bounding memory at O(live).
    fn maybe_compact(&mut self) {
        if self.dead.len() < 64 || self.dead.len() * 2 < self.heap.len() {
            return;
        }
        let dead = std::mem::take(&mut self.dead);
        let kept: Vec<Entry<T>> = std::mem::take(&mut self.heap)
            .into_vec()
            .into_iter()
            .filter(|e| !dead.contains(&e.seq))
            .collect();
        self.heap = BinaryHeap::from(kept);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, prop_assert};
    use crate::util::Rng;

    /// Verbatim copy of the seed's rebuild-based queue — the behavioral
    /// reference the indexed implementation must match bitwise.
    mod baseline {
        use super::super::Entry;
        use std::collections::BinaryHeap;

        pub struct BaselineQueue<T> {
            heap: BinaryHeap<Entry<T>>,
            seq: u64,
        }

        impl<T: Eq> BaselineQueue<T> {
            pub fn new() -> Self {
                Self {
                    heap: BinaryHeap::new(),
                    seq: 0,
                }
            }

            pub fn push(&mut self, time: f64, payload: T) {
                assert!(time.is_finite(), "event time must be finite");
                self.heap.push(Entry {
                    time,
                    seq: self.seq,
                    payload,
                });
                self.seq += 1;
            }

            pub fn pop(&mut self) -> Option<(f64, T)> {
                self.heap.pop().map(|e| (e.time, e.payload))
            }

            pub fn peek_time(&self) -> Option<f64> {
                self.heap.peek().map(|e| e.time)
            }

            pub fn pop_until(&mut self, t: f64) -> Option<(f64, T)> {
                if self.peek_time()? <= t {
                    self.pop()
                } else {
                    None
                }
            }

            pub fn remove_first(&mut self, key: &T) -> Option<(f64, T)> {
                let entries = std::mem::take(&mut self.heap).into_sorted_vec();
                let mut removed = None;
                let mut kept = Vec::with_capacity(entries.len());
                for entry in entries.into_iter().rev() {
                    if removed.is_none() && entry.payload == *key {
                        removed = Some((entry.time, entry.payload));
                    } else {
                        kept.push(entry);
                    }
                }
                self.heap = BinaryHeap::from(kept);
                removed
            }

            pub fn remove_all(&mut self, key: &T) -> usize {
                let before = self.heap.len();
                let kept: Vec<Entry<T>> = std::mem::take(&mut self.heap)
                    .into_vec()
                    .into_iter()
                    .filter(|e| e.payload != *key)
                    .collect();
                let removed = before - kept.len();
                self.heap = BinaryHeap::from(kept);
                removed
            }

            pub fn len(&self) -> usize {
                self.heap.len()
            }
        }
    }

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        assert_eq!(q.pop(), Some((1.0, "a")));
        assert_eq!(q.pop(), Some((2.0, "b")));
        assert_eq!(q.pop(), Some((3.0, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn simultaneous_events_are_fifo() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.push(5.0, i);
        }
        for i in 0..10 {
            assert_eq!(q.pop(), Some((5.0, i)));
        }
    }

    #[test]
    fn random_schedule_sorts() {
        check("event queue sorts any schedule", 50, |g| {
            let mut q = EventQueue::new();
            let mut rng = Rng::new(g.rng().next_u64());
            let n = g.usize_in(1..200);
            for i in 0..n {
                q.push(rng.uniform(0.0, 100.0), i);
            }
            prop_assert(q.len() == n, "length")?;
            let mut last = f64::NEG_INFINITY;
            while let Some((t, _)) = q.pop() {
                prop_assert(t >= last, "out of order")?;
                last = t;
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn rejects_nan_time() {
        EventQueue::new().push(f64::NAN, 0);
    }

    #[test]
    fn remove_first_takes_earliest_match_and_preserves_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "a");
        q.push(1.0, "b");
        q.push(2.0, "a");
        q.push(2.5, "a");
        // Earliest "a" is at t = 2 (the t = 3 push came first but later).
        let got = q.remove_first(&"a");
        assert_eq!(got, Some((2.0, "a")));
        // Everything else pops in the original time/FIFO order.
        assert_eq!(q.pop(), Some((1.0, "b")));
        assert_eq!(q.pop(), Some((2.5, "a")));
        assert_eq!(q.pop(), Some((3.0, "a")));
        // No match leaves the queue untouched.
        let mut q = EventQueue::new();
        q.push(1.0, 7usize);
        assert_eq!(q.remove_first(&9), None);
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn remove_all_drops_every_match_in_one_pass() {
        let mut q = EventQueue::new();
        for i in 0..8 {
            q.push(i as f64, i % 2);
        }
        assert_eq!(q.remove_all(&0), 4);
        assert_eq!(q.remove_all(&0), 0);
        let times: Vec<f64> = std::iter::from_fn(|| q.pop().map(|(t, _)| t)).collect();
        assert_eq!(times, vec![1.0, 3.0, 5.0, 7.0]);
    }

    #[test]
    fn remove_first_keeps_fifo_among_simultaneous_survivors() {
        let mut q = EventQueue::new();
        for i in 0..6 {
            q.push(5.0, i);
        }
        assert_eq!(q.remove_first(&3), Some((5.0, 3)));
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, vec![0, 1, 2, 4, 5]);
    }

    #[test]
    fn remove_first_takes_fifo_earliest_among_simultaneous_matches() {
        // Two entries for the same payload at the same time: removal must
        // take the earlier-pushed one, exactly as the seed scan did.
        let mut q = EventQueue::new();
        q.push(5.0, "x");
        q.push(5.0, "y");
        q.push(5.0, "x");
        assert_eq!(q.remove_first(&"x"), Some((5.0, "x")));
        assert_eq!(q.pop(), Some((5.0, "y")));
        assert_eq!(q.pop(), Some((5.0, "x")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn pop_until_respects_deadline() {
        let mut q = EventQueue::new();
        q.push(1.0, "a");
        q.push(2.0, "b");
        q.push(3.0, "c");
        // Boundary is inclusive: an event exactly at the slot end is due.
        assert_eq!(q.pop_until(2.0), Some((1.0, "a")));
        assert_eq!(q.pop_until(2.0), Some((2.0, "b")));
        assert_eq!(q.pop_until(2.0), None);
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop_until(10.0), Some((3.0, "c")));
        assert_eq!(q.pop_until(10.0), None);
    }

    #[test]
    fn len_and_peek_ignore_tombstones() {
        let mut q = EventQueue::new();
        q.push(1.0, 0);
        q.push(2.0, 1);
        q.push(3.0, 0);
        assert_eq!(q.remove_all(&0), 2);
        assert_eq!(q.len(), 1);
        assert!(!q.is_empty());
        assert_eq!(q.peek_time(), Some(2.0));
        assert_eq!(q.pop(), Some((2.0, 1)));
        assert!(q.is_empty());
        assert_eq!(q.peek_time(), None);
    }

    #[test]
    fn compaction_survives_heavy_removal() {
        // Push/remove far past the compaction threshold; live contents
        // must stay exact throughout.
        let mut q = EventQueue::new();
        for i in 0..500usize {
            q.push(i as f64, i);
        }
        for i in (0..500).step_by(2) {
            assert_eq!(q.remove_first(&i), Some((i as f64, i)));
        }
        assert_eq!(q.len(), 250);
        let order: Vec<usize> = std::iter::from_fn(|| q.pop().map(|(_, p)| p)).collect();
        let want: Vec<usize> = (0..500).filter(|i| i % 2 == 1).collect();
        assert_eq!(order, want);
    }

    #[test]
    fn indexed_queue_matches_seed_rebuild_queue_bitwise() {
        // Satellite: random interleavings of push / pop / pop_until /
        // remove_first / remove_all, with duplicate payloads and
        // simultaneous timestamps to exercise FIFO tie-breaking. Every
        // observable (returned times bit-for-bit, payloads, counts,
        // lengths) must match the frozen seed implementation.
        check("indexed queue ≡ seed rebuild queue", 60, |g| {
            let mut new_q = EventQueue::new();
            let mut old_q = baseline::BaselineQueue::new();
            let mut rng = Rng::new(g.rng().next_u64());
            let steps = g.usize_in(20..200);
            for _ in 0..steps {
                match rng.index(6) {
                    0 | 1 => {
                        // Coarse time grid forces plenty of exact ties.
                        let t = (rng.index(16) as f64) * 0.5;
                        let p = rng.index(8);
                        new_q.push(t, p);
                        old_q.push(t, p);
                    }
                    2 => {
                        let a = new_q.pop();
                        let b = old_q.pop();
                        prop_assert(
                            a.map(|(t, p)| (t.to_bits(), p)) == b.map(|(t, p)| (t.to_bits(), p)),
                            "pop mismatch",
                        )?;
                    }
                    3 => {
                        let t = (rng.index(16) as f64) * 0.5;
                        let a = new_q.pop_until(t);
                        let b = old_q.pop_until(t);
                        prop_assert(
                            a.map(|(t, p)| (t.to_bits(), p)) == b.map(|(t, p)| (t.to_bits(), p)),
                            "pop_until mismatch",
                        )?;
                    }
                    4 => {
                        let p = rng.index(8);
                        let a = new_q.remove_first(&p);
                        let b = old_q.remove_first(&p);
                        prop_assert(
                            a.map(|(t, p)| (t.to_bits(), p)) == b.map(|(t, p)| (t.to_bits(), p)),
                            "remove_first mismatch",
                        )?;
                    }
                    _ => {
                        let p = rng.index(8);
                        prop_assert(
                            new_q.remove_all(&p) == old_q.remove_all(&p),
                            "remove_all count mismatch",
                        )?;
                    }
                }
                prop_assert(new_q.len() == old_q.len(), "length mismatch")?;
            }
            // Drain both: the full residual schedule must agree.
            loop {
                let a = new_q.pop();
                let b = old_q.pop();
                prop_assert(
                    a.map(|(t, p)| (t.to_bits(), p)) == b.map(|(t, p)| (t.to_bits(), p)),
                    "drain mismatch",
                )?;
                if a.is_none() {
                    break;
                }
            }
            Ok(())
        });
    }
}
