//! Device-heterogeneity simulation: per-round compute-latency models, the
//! virtual clock used for all "training time" reporting, and the
//! continuous-time [`events::EventQueue`] the FL
//! [`Coordinator`](crate::fl::Coordinator) is driven by.
//!
//! The paper's testbed (§IV-A) draws each client's per-round computation
//! latency from U(5, 15) s; Table I's "time/s" column is virtual time under
//! that model (PAOTA rounds take exactly ΔT; synchronous rounds take the
//! max participant latency). Ablations swap in the other models.

pub mod events;

use crate::util::Rng;

/// Per-round client compute-latency model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LatencyModel {
    /// U(lo, hi) seconds — the paper's setting (5, 15).
    Uniform { lo: f64, hi: f64 },
    /// All clients identical (no stragglers; sanity/ablation).
    Homogeneous { value: f64 },
    /// Two device classes: fast clients at `fast`, a `slow_frac` fraction
    /// of draws at `slow` (severe-straggler ablation).
    Bimodal { fast: f64, slow: f64, slow_frac: f64 },
    /// Heavy-tailed: `ln X ~ N(mu, sigma²)` — a small set of draws lands
    /// far out in the tail, the straggler regime the topology ablation
    /// stresses (median `e^mu`, mean `e^{mu+sigma²/2}`).
    Lognormal { mu: f64, sigma: f64 },
    /// Time-correlated two-state Gilbert–Elliott chain: each client is
    /// either `fast` or `slow` and *stays* in its state across rounds
    /// (`p_enter` = P(fast→slow), `p_exit` = P(slow→fast) per draw).
    ///
    /// The per-client Markov state lives in [`LatencySampler`];
    /// [`LatencyModel::draw`] on this variant samples the *stationary
    /// marginal* (state-free approximation) for callers without per-client
    /// state.
    GilbertElliott {
        fast: f64,
        slow: f64,
        p_enter: f64,
        p_exit: f64,
    },
}

impl LatencyModel {
    /// Draw one per-round latency (stateless; for the time-correlated
    /// Gilbert–Elliott variant this is the stationary marginal — use a
    /// [`LatencySampler`] for the actual per-client chain).
    pub fn draw(&self, rng: &mut Rng) -> f64 {
        match *self {
            LatencyModel::Uniform { lo, hi } => rng.uniform(lo, hi),
            LatencyModel::Homogeneous { value } => value,
            LatencyModel::Bimodal {
                fast,
                slow,
                slow_frac,
            } => {
                if rng.f64() < slow_frac {
                    slow
                } else {
                    fast
                }
            }
            LatencyModel::Lognormal { mu, sigma } => (mu + sigma * rng.normal()).exp(),
            LatencyModel::GilbertElliott {
                fast,
                slow,
                p_enter,
                p_exit,
            } => {
                if rng.f64() < stationary_slow(p_enter, p_exit) {
                    slow
                } else {
                    fast
                }
            }
        }
    }

    /// Mean latency (closed form; Gilbert–Elliott: stationary mean).
    pub fn mean(&self) -> f64 {
        match *self {
            LatencyModel::Uniform { lo, hi } => (lo + hi) / 2.0,
            LatencyModel::Homogeneous { value } => value,
            LatencyModel::Bimodal {
                fast,
                slow,
                slow_frac,
            } => fast * (1.0 - slow_frac) + slow * slow_frac,
            LatencyModel::Lognormal { mu, sigma } => (mu + sigma * sigma / 2.0).exp(),
            LatencyModel::GilbertElliott {
                fast,
                slow,
                p_enter,
                p_exit,
            } => {
                let pi_slow = stationary_slow(p_enter, p_exit);
                fast * (1.0 - pi_slow) + slow * pi_slow
            }
        }
    }
}

/// Stationary probability of the slow state of a Gilbert–Elliott chain
/// (`0` when the chain never enters it).
fn stationary_slow(p_enter: f64, p_exit: f64) -> f64 {
    if p_enter + p_exit <= 0.0 {
        0.0
    } else {
        p_enter / (p_enter + p_exit)
    }
}

/// Per-client latency sampler: wraps a [`LatencyModel`] with the
/// per-client Markov state the time-correlated Gilbert–Elliott variant
/// needs. Every client starts in the fast state; each draw first advances
/// that client's chain (one uniform draw), then emits the state's value.
///
/// For the stateless variants this delegates to [`LatencyModel::draw`]
/// with an identical RNG-consumption pattern, so swapping the sampler in
/// for a bare model is bit-transparent.
///
/// Chain state is lazily grown on first touch: construction allocates
/// nothing regardless of fleet size, untouched clients are implicitly in
/// the fast state, and only Gilbert–Elliott draws materialize the flag
/// vector (up to the highest client actually drawn). Since the default
/// state is `false` everywhere, lazy growth is semantically identical to
/// the eager `vec![false; clients]` the seed allocated.
#[derive(Debug, Clone)]
pub struct LatencySampler {
    model: LatencyModel,
    /// Per-client "currently slow" flag (Gilbert–Elliott only),
    /// grow-on-touch.
    slow_state: Vec<bool>,
}

impl LatencySampler {
    /// `clients` is the fleet size the sampler serves; per-client state
    /// is not allocated here (lazy), so this is O(1) for every latency
    /// kind.
    pub fn new(model: LatencyModel, clients: usize) -> Self {
        let _ = clients;
        Self {
            model,
            slow_state: Vec::new(),
        }
    }

    pub fn model(&self) -> &LatencyModel {
        &self.model
    }

    /// The client's current Gilbert–Elliott "slow" flag (always `false`
    /// for the stateless models). Roaming clients carry this residence
    /// state across cells (`Coordinator::detach_client` /
    /// `admit_client`): a device deep in a slow phase stays slow when
    /// it hands over — the chain is a property of the device, not of the
    /// serving cell.
    pub fn slow_state(&self, client: usize) -> bool {
        self.slow_state.get(client).copied().unwrap_or(false)
    }

    /// Rebind the client's Gilbert–Elliott chain state (handover admit).
    /// A no-op in effect for the stateless models, whose draws ignore the
    /// flag.
    pub fn set_slow_state(&mut self, client: usize, slow: bool) {
        if client >= self.slow_state.len() {
            if !slow {
                // Untouched clients are already implicitly fast.
                return;
            }
            self.slow_state.resize(client + 1, false);
        }
        self.slow_state[client] = slow;
    }

    /// Draw `client`'s next per-round latency.
    pub fn draw(&mut self, client: usize, rng: &mut Rng) -> f64 {
        match self.model {
            LatencyModel::GilbertElliott {
                fast,
                slow,
                p_enter,
                p_exit,
            } => {
                if client >= self.slow_state.len() {
                    self.slow_state.resize(client + 1, false);
                }
                let u = rng.f64();
                let state = &mut self.slow_state[client];
                *state = if *state { u >= p_exit } else { u < p_enter };
                if *state {
                    slow
                } else {
                    fast
                }
            }
            ref m => m.draw(rng),
        }
    }

    /// Bytes of per-client chain state currently materialized (test
    /// hook for the lazy-allocation contract).
    pub fn state_footprint(&self) -> usize {
        self.slow_state.capacity()
    }
}

/// Monotone virtual clock — all reported "training time" comes from here,
/// never from the wall clock, so runs are machine-independent.
#[derive(Debug, Clone, Copy, Default)]
pub struct VirtualClock {
    now: f64,
}

impl VirtualClock {
    pub fn new() -> Self {
        Self { now: 0.0 }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Advance by `dt` (must be non-negative); returns the new time.
    pub fn advance(&mut self, dt: f64) -> f64 {
        assert!(dt >= 0.0, "time cannot go backwards (dt = {dt})");
        self.now += dt;
        self.now
    }

    /// Advance to an absolute time (must not be in the past).
    pub fn advance_to(&mut self, t: f64) -> f64 {
        assert!(
            t >= self.now - 1e-9,
            "advance_to({t}) is before now ({})",
            self.now
        );
        self.now = self.now.max(t);
        self.now
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, prop_assert};

    #[test]
    fn uniform_latency_range_and_mean() {
        let m = LatencyModel::Uniform { lo: 5.0, hi: 15.0 };
        let mut rng = Rng::new(1);
        let n = 50_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let l = m.draw(&mut rng);
            assert!((5.0..15.0).contains(&l));
            sum += l;
        }
        assert!((sum / n as f64 - 10.0).abs() < 0.05);
        assert_eq!(m.mean(), 10.0);
    }

    #[test]
    fn homogeneous_is_constant() {
        let m = LatencyModel::Homogeneous { value: 7.5 };
        let mut rng = Rng::new(2);
        for _ in 0..10 {
            assert_eq!(m.draw(&mut rng), 7.5);
        }
    }

    #[test]
    fn bimodal_fraction() {
        let m = LatencyModel::Bimodal {
            fast: 2.0,
            slow: 30.0,
            slow_frac: 0.2,
        };
        let mut rng = Rng::new(3);
        let n = 50_000;
        let slow = (0..n).filter(|_| m.draw(&mut rng) == 30.0).count();
        assert!((slow as f64 / n as f64 - 0.2).abs() < 0.01);
        assert!((m.mean() - (2.0 * 0.8 + 30.0 * 0.2)).abs() < 1e-12);
    }

    #[test]
    fn lognormal_median_mean_and_heavy_tail() {
        // median 10 s, sigma 0.6 — the topology-ablation setting.
        let mu = 10.0f64.ln();
        let m = LatencyModel::Lognormal { mu, sigma: 0.6 };
        assert!((m.mean() - (mu + 0.18).exp()).abs() < 1e-12);
        let mut rng = Rng::new(7);
        let n = 50_000;
        let mut below_median = 0usize;
        let mut beyond_3x = 0usize;
        for _ in 0..n {
            let l = m.draw(&mut rng);
            assert!(l > 0.0);
            if l < 10.0 {
                below_median += 1;
            }
            if l > 30.0 {
                beyond_3x += 1;
            }
        }
        assert!((below_median as f64 / n as f64 - 0.5).abs() < 0.01);
        // Heavy tail: a non-negligible mass lands beyond 3× the median
        // (U(5,15) puts exactly zero mass there).
        assert!(beyond_3x > n / 200, "tail too light: {beyond_3x}/{n}");
    }

    #[test]
    fn gilbert_elliott_sampler_is_time_correlated() {
        let model = LatencyModel::GilbertElliott {
            fast: 5.0,
            slow: 30.0,
            p_enter: 0.1,
            p_exit: 0.3,
        };
        // Stationary: π_slow = 0.1/0.4 = 0.25.
        assert!((model.mean() - (5.0 * 0.75 + 30.0 * 0.25)).abs() < 1e-12);

        let mut s = LatencySampler::new(model, 1);
        let mut rng = Rng::new(11);
        let n = 50_000;
        let mut prev_slow = false;
        let mut slow_count = 0usize;
        let mut stay_slow = 0usize;
        let mut slow_pairs = 0usize;
        for i in 0..n {
            let l = s.draw(0, &mut rng);
            let is_slow = l == 30.0;
            if is_slow {
                slow_count += 1;
            }
            if i > 0 && prev_slow {
                slow_pairs += 1;
                if is_slow {
                    stay_slow += 1;
                }
            }
            prev_slow = is_slow;
        }
        // Occupancy matches the stationary distribution...
        assert!((slow_count as f64 / n as f64 - 0.25).abs() < 0.02);
        // ...but consecutive draws are correlated: P(slow | slow) = 0.7,
        // far above the marginal 0.25.
        let p_stay = stay_slow as f64 / slow_pairs as f64;
        assert!((p_stay - 0.7).abs() < 0.03, "P(slow|slow) = {p_stay}");
    }

    #[test]
    fn gilbert_elliott_states_are_per_client() {
        let model = LatencyModel::GilbertElliott {
            fast: 1.0,
            slow: 9.0,
            p_enter: 1.0, // enter slow immediately...
            p_exit: 0.0,  // ...and never leave.
        };
        let mut s = LatencySampler::new(model, 2);
        let mut rng = Rng::new(3);
        assert_eq!(s.draw(0, &mut rng), 9.0);
        // Client 1 starts fast regardless of client 0's chain, and with
        // p_enter = 1 transitions on its first draw too.
        assert_eq!(s.draw(1, &mut rng), 9.0);
        assert_eq!(s.draw(0, &mut rng), 9.0);
    }

    #[test]
    fn sampler_is_bit_transparent_for_stateless_models() {
        for model in [
            LatencyModel::Uniform { lo: 5.0, hi: 15.0 },
            LatencyModel::Homogeneous { value: 7.0 },
            LatencyModel::Bimodal {
                fast: 2.0,
                slow: 30.0,
                slow_frac: 0.2,
            },
            LatencyModel::Lognormal { mu: 2.0, sigma: 0.5 },
        ] {
            let mut a = Rng::new(99);
            let mut b = Rng::new(99);
            let mut s = LatencySampler::new(model, 4);
            for client in [0usize, 3, 1, 1] {
                assert_eq!(s.draw(client, &mut a), model.draw(&mut b));
            }
        }
    }

    #[test]
    fn sampler_construction_is_allocation_free() {
        // Fleet-scale contract: building a sampler for 10⁶ clients must
        // not materialize per-client chains — for any latency kind.
        for model in [
            LatencyModel::Uniform { lo: 5.0, hi: 15.0 },
            LatencyModel::Homogeneous { value: 7.0 },
            LatencyModel::Lognormal { mu: 2.0, sigma: 0.5 },
            LatencyModel::GilbertElliott {
                fast: 5.0,
                slow: 30.0,
                p_enter: 0.1,
                p_exit: 0.3,
            },
        ] {
            let s = LatencySampler::new(model, 1_000_000);
            assert_eq!(s.state_footprint(), 0, "eager chain alloc for {model:?}");
        }

        // Stateless kinds stay allocation-free even after draws…
        let mut s = LatencySampler::new(LatencyModel::Uniform { lo: 5.0, hi: 15.0 }, 1_000_000);
        let mut rng = Rng::new(5);
        for client in [0usize, 999_999, 17] {
            s.draw(client, &mut rng);
        }
        assert_eq!(s.state_footprint(), 0);
        assert!(!s.slow_state(999_999));

        // …while Gilbert–Elliott grows only to the highest touched
        // client, not the declared fleet.
        let model = LatencyModel::GilbertElliott {
            fast: 5.0,
            slow: 30.0,
            p_enter: 0.1,
            p_exit: 0.3,
        };
        let mut s = LatencySampler::new(model, 1_000_000);
        s.draw(7, &mut rng);
        assert!(s.state_footprint() >= 8);
        assert!(s.state_footprint() < 1024);
        // Installing the default fast state for an untouched client is
        // also free; a slow install materializes it.
        s.set_slow_state(500, false);
        assert!(s.state_footprint() < 1024);
        assert!(!s.slow_state(500));
        s.set_slow_state(500, true);
        assert!(s.slow_state(500));
    }

    #[test]
    fn clock_monotone() {
        check("clock never goes backwards", 50, |g| {
            let mut c = VirtualClock::new();
            let mut last = 0.0;
            for _ in 0..g.usize_in(1..20) {
                let t = c.advance(g.f64_in(0.0..10.0));
                prop_assert(t >= last, "clock went backwards")?;
                last = t;
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "time cannot go backwards")]
    fn clock_rejects_negative() {
        VirtualClock::new().advance(-1.0);
    }
}
