//! Device-heterogeneity simulation: per-round compute-latency models, the
//! virtual clock used for all "training time" reporting, and the
//! continuous-time [`events::EventQueue`] the FL
//! [`Coordinator`](crate::fl::Coordinator) is driven by.
//!
//! The paper's testbed (§IV-A) draws each client's per-round computation
//! latency from U(5, 15) s; Table I's "time/s" column is virtual time under
//! that model (PAOTA rounds take exactly ΔT; synchronous rounds take the
//! max participant latency). Ablations swap in the other models.

pub mod events;

use crate::util::Rng;

/// Per-round client compute-latency model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LatencyModel {
    /// U(lo, hi) seconds — the paper's setting (5, 15).
    Uniform { lo: f64, hi: f64 },
    /// All clients identical (no stragglers; sanity/ablation).
    Homogeneous { value: f64 },
    /// Two device classes: fast clients at `fast`, a `slow_frac` fraction
    /// of draws at `slow` (severe-straggler ablation).
    Bimodal { fast: f64, slow: f64, slow_frac: f64 },
}

impl LatencyModel {
    /// Draw one per-round latency.
    pub fn draw(&self, rng: &mut Rng) -> f64 {
        match *self {
            LatencyModel::Uniform { lo, hi } => rng.uniform(lo, hi),
            LatencyModel::Homogeneous { value } => value,
            LatencyModel::Bimodal {
                fast,
                slow,
                slow_frac,
            } => {
                if rng.f64() < slow_frac {
                    slow
                } else {
                    fast
                }
            }
        }
    }

    /// Mean latency (closed form).
    pub fn mean(&self) -> f64 {
        match *self {
            LatencyModel::Uniform { lo, hi } => (lo + hi) / 2.0,
            LatencyModel::Homogeneous { value } => value,
            LatencyModel::Bimodal {
                fast,
                slow,
                slow_frac,
            } => fast * (1.0 - slow_frac) + slow * slow_frac,
        }
    }
}

/// Monotone virtual clock — all reported "training time" comes from here,
/// never from the wall clock, so runs are machine-independent.
#[derive(Debug, Clone, Copy, Default)]
pub struct VirtualClock {
    now: f64,
}

impl VirtualClock {
    pub fn new() -> Self {
        Self { now: 0.0 }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Advance by `dt` (must be non-negative); returns the new time.
    pub fn advance(&mut self, dt: f64) -> f64 {
        assert!(dt >= 0.0, "time cannot go backwards (dt = {dt})");
        self.now += dt;
        self.now
    }

    /// Advance to an absolute time (must not be in the past).
    pub fn advance_to(&mut self, t: f64) -> f64 {
        assert!(
            t >= self.now - 1e-9,
            "advance_to({t}) is before now ({})",
            self.now
        );
        self.now = self.now.max(t);
        self.now
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, prop_assert};

    #[test]
    fn uniform_latency_range_and_mean() {
        let m = LatencyModel::Uniform { lo: 5.0, hi: 15.0 };
        let mut rng = Rng::new(1);
        let n = 50_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let l = m.draw(&mut rng);
            assert!((5.0..15.0).contains(&l));
            sum += l;
        }
        assert!((sum / n as f64 - 10.0).abs() < 0.05);
        assert_eq!(m.mean(), 10.0);
    }

    #[test]
    fn homogeneous_is_constant() {
        let m = LatencyModel::Homogeneous { value: 7.5 };
        let mut rng = Rng::new(2);
        for _ in 0..10 {
            assert_eq!(m.draw(&mut rng), 7.5);
        }
    }

    #[test]
    fn bimodal_fraction() {
        let m = LatencyModel::Bimodal {
            fast: 2.0,
            slow: 30.0,
            slow_frac: 0.2,
        };
        let mut rng = Rng::new(3);
        let n = 50_000;
        let slow = (0..n).filter(|_| m.draw(&mut rng) == 30.0).count();
        assert!((slow as f64 / n as f64 - 0.2).abs() < 0.01);
        assert!((m.mean() - (2.0 * 0.8 + 30.0 * 0.2)).abs() < 1e-12);
    }

    #[test]
    fn clock_monotone() {
        check("clock never goes backwards", 50, |g| {
            let mut c = VirtualClock::new();
            let mut last = 0.0;
            for _ in 0..g.usize_in(1..20) {
                let t = c.advance(g.f64_in(0.0..10.0));
                prop_assert(t >= last, "clock went backwards")?;
                last = t;
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "time cannot go backwards")]
    fn clock_rejects_negative() {
        VirtualClock::new().advance(-1.0);
    }
}
