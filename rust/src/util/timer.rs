//! Wall-clock timing helpers for the benchmark harness and perf logging.

use std::time::{Duration, Instant};

/// A simple stopwatch with lap support.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
    laps: Vec<Duration>,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self {
            start: Instant::now(),
            laps: Vec::new(),
        }
    }

    /// Time since start (or last `lap`).
    pub fn lap(&mut self) -> Duration {
        let now = Instant::now();
        let d = now - self.start;
        self.start = now;
        self.laps.push(d);
        d
    }

    /// Time since start without resetting.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn laps(&self) -> &[Duration] {
        &self.laps
    }
}

/// Format a duration compactly (`1.23ms`, `4.56s`).
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}µs", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_laps_accumulate() {
        let mut sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(2));
        let l1 = sw.lap();
        assert!(l1 >= Duration::from_millis(1));
        assert_eq!(sw.laps().len(), 1);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_duration(Duration::from_secs(2)).ends_with('s'));
        assert!(fmt_duration(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_duration(Duration::from_micros(5)).ends_with("µs"));
    }
}
