//! Minimal leveled logger (offline environment: no `tracing`/`env_logger`).
//!
//! Global level, set once from the CLI (`--log-level`) or
//! `PAOTA_LOG=debug|info|warn|error`. Macros mirror the `log` crate's shape
//! so call sites read conventionally.

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, ordered.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

impl Level {
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "debug" => Some(Level::Debug),
            "info" => Some(Level::Info),
            "warn" | "warning" => Some(Level::Warn),
            "error" => Some(Level::Error),
            _ => None,
        }
    }

    pub fn tag(self) -> &'static str {
        match self {
            Level::Debug => "DEBUG",
            Level::Info => "INFO ",
            Level::Warn => "WARN ",
            Level::Error => "ERROR",
        }
    }
}

/// Set the global level.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Initialize from `PAOTA_LOG` if present.
pub fn init_from_env() {
    if let Ok(v) = std::env::var("PAOTA_LOG") {
        if let Some(l) = Level::parse(&v) {
            set_level(l);
        }
    }
}

/// Whether `level` is currently enabled.
pub fn enabled(level: Level) -> bool {
    level as u8 >= LEVEL.load(Ordering::Relaxed)
}

/// Log at `level` (used by the macros; prefer those at call sites).
pub fn emit(level: Level, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        eprintln!("[{}] {}", level.tag(), args);
    }
}

#[macro_export]
macro_rules! debug {
    ($($t:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Debug, format_args!($($t)*)) };
}

#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Info, format_args!($($t)*)) };
}

#[macro_export]
macro_rules! warn_ {
    ($($t:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Warn, format_args!($($t)*)) };
}

#[macro_export]
macro_rules! error {
    ($($t:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Error, format_args!($($t)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("warning"), Some(Level::Warn));
        assert_eq!(Level::parse("nope"), None);
    }

    #[test]
    fn level_ordering_gates() {
        set_level(Level::Warn);
        assert!(!enabled(Level::Debug));
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Error));
        set_level(Level::Info); // restore default for other tests
    }
}
