//! Small shared substrates: deterministic PRNG with the distributions the
//! paper's simulation needs, vector math over flat `f32` models, logging,
//! and wall-clock timing helpers.
//!
//! The build environment is fully offline (only the `xla` crate closure is
//! vendored), so these are hand-rolled rather than pulled from `rand`/
//! `tracing` — and unit-tested like any other substrate.

pub mod log;
pub mod rng;
pub mod timer;
pub mod vecmath;

pub use log::{set_level, Level};
pub use rng::Rng;
pub use timer::Stopwatch;
