//! Deterministic PRNG + the distributions the PAOTA simulation draws from.
//!
//! Core generator: PCG-XSH-RR 64/32 (O'Neill 2014) — small state, good
//! statistical quality, trivially reproducible across platforms. On top of
//! it: uniform/normal (Box–Muller), Rayleigh (the paper's fading model,
//! §II-C), exponential (|h|² of a CN(0,1) coefficient), and the sampling
//! utilities the data partitioner uses (shuffle, choice without
//! replacement).
//!
//! Every stochastic component in the system takes an explicit `Rng` so runs
//! are bit-reproducible given a seed; independent streams are derived with
//! [`Rng::split`].

/// PCG-XSH-RR 64/32 with 64-bit state and a per-stream increment.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    inc: u64,
    /// Cached second output of Box–Muller.
    gauss_spare: Option<f64>,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Rng {
    /// Seeded generator on the default stream.
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e39cb94b95bdb)
    }

    /// Seeded generator on an explicit stream (odd-ified internally).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Self {
            state: 0,
            inc: (stream << 1) | 1,
            gauss_spare: None,
        };
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Derive an independent child stream; deterministic in (self, tag).
    pub fn split(&mut self, tag: u64) -> Rng {
        let seed = ((self.next_u32() as u64) << 32) | self.next_u32() as u64;
        Rng::with_stream(seed ^ tag.wrapping_mul(0x9e3779b97f4a7c15), tag)
    }

    /// A per-entity stream derived purely from `(seed, stream, entity)` —
    /// no parent generator state involved, so entity `i`'s stream can be
    /// materialized lazily at any time (or on any thread) and is always
    /// the same. The golden-ratio mix keeps adjacent entities far apart
    /// in seed space.
    pub fn for_entity(seed: u64, stream: u64, entity: u64) -> Rng {
        let mix = entity.wrapping_add(1).wrapping_mul(0x9e37_79b9_7f4a_7c15);
        Rng::with_stream(seed ^ mix, stream)
    }

    /// Next raw 32-bit output.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)` with 32 bits of resolution.
    pub fn f64(&mut self) -> f64 {
        self.next_u32() as f64 * (1.0 / 4294967296.0)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` via Lemire's rejection method (unbiased).
    pub fn below(&mut self, n: u32) -> u32 {
        assert!(n > 0, "below(0)");
        loop {
            let x = self.next_u32() as u64;
            let m = x * n as u64;
            let lo = m as u32;
            if lo >= n || lo >= (u32::MAX - n + 1) % n {
                return (m >> 32) as u32;
            }
        }
    }

    /// Uniform usize in `[0, n)`.
    pub fn index(&mut self, n: usize) -> usize {
        assert!(n > 0 && n <= u32::MAX as usize);
        self.below(n as u32) as usize
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.gauss_spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with given mean/std.
    pub fn normal_scaled(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Rayleigh with scale σ (mode). `E[X] = σ√(π/2)`, `E[X²] = 2σ²`.
    ///
    /// This is the paper's uplink fading magnitude model: `|h| ~ Rayleigh`.
    pub fn rayleigh(&mut self, sigma: f64) -> f64 {
        loop {
            let u = self.f64();
            if u > 0.0 {
                return sigma * (-2.0 * (1.0 - u).ln()).sqrt();
            }
        }
    }

    /// Exponential with rate λ (`|h|²` of CN(0,1) is Exp(1)).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        loop {
            let u = self.f64();
            if u > 0.0 {
                return -(1.0 - u).ln() / lambda;
            }
        }
    }

    /// Fill a slice with i.i.d. `N(0, std²)` f32 samples.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = (self.normal() * std as f64) as f32;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.index(i + 1);
            items.swap(i, j);
        }
    }

    /// `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn choose_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "choose {k} from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.index(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn for_entity_is_stateless_and_distinct() {
        // Same (seed, stream, entity) → identical stream, whenever built.
        let mut a = Rng::for_entity(42, 0x30_b117, 7);
        let mut b = Rng::for_entity(42, 0x30_b117, 7);
        for _ in 0..16 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
        // Adjacent entities are decorrelated.
        let mut c = Rng::for_entity(42, 0x30_b117, 8);
        let same = (0..64).filter(|_| a.next_u32() == c.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn split_streams_are_independentish() {
        let mut root = Rng::new(7);
        let mut a = root.split(1);
        let mut b = root.split(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_moments() {
        let mut rng = Rng::new(3);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.f64();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 5e-3, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 5e-3, "var={var}");
    }

    #[test]
    fn below_is_unbiased_over_range() {
        let mut rng = Rng::new(11);
        let mut counts = [0u32; 7];
        for _ in 0..70_000 {
            counts[rng.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(5);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 1e-2, "mean={mean}");
        assert!((var - 1.0).abs() < 2e-2, "var={var}");
    }

    #[test]
    fn rayleigh_moments() {
        // E[X] = σ√(π/2), Var = (2 − π/2)σ².
        let sigma = 2.0;
        let mut rng = Rng::new(9);
        let n = 200_000;
        let mut sum = 0.0;
        let mut sq = 0.0;
        for _ in 0..n {
            let x = rng.rayleigh(sigma);
            assert!(x >= 0.0);
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let want_mean = sigma * (std::f64::consts::PI / 2.0).sqrt();
        assert!((mean - want_mean).abs() < 2e-2, "mean={mean} want={want_mean}");
        let e2 = sq / n as f64;
        assert!((e2 - 2.0 * sigma * sigma).abs() < 0.1, "E[X²]={e2}");
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Rng::new(13);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            sum += rng.exponential(0.5);
        }
        assert!((sum / n as f64 - 2.0).abs() < 5e-2);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(17);
        let mut v: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn choose_indices_distinct_and_in_range() {
        let mut rng = Rng::new(19);
        for _ in 0..50 {
            let k = rng.index(20) + 1;
            let picked = rng.choose_indices(30, k);
            assert_eq!(picked.len(), k);
            let mut s = picked.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), k, "duplicates in {picked:?}");
            assert!(picked.iter().all(|&i| i < 30));
        }
    }
}
