//! Flat-vector math over `f32` models.
//!
//! The whole coordinator works on flat `f32[dim]` model vectors (see
//! DESIGN.md "flat-parameter convention"); these are the few primitives it
//! needs, written to be allocation-free on the hot path.

/// Dot product (f64 accumulator for stability over 8k+ elements).
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| *x as f64 * *y as f64).sum()
}

/// Euclidean norm.
pub fn norm(a: &[f32]) -> f64 {
    dot(a, a).sqrt()
}

/// Cosine of the angle between two vectors, in `[-1, 1]`.
///
/// This is the paper's `Θ(a, b)` (eq. (25)) — the gradient-similarity
/// measure between a client update and the last global update direction.
/// Zero vectors get cosine 0 (neutral similarity).
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    let na = norm(a);
    let nb = norm(b);
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    (dot(a, b) / (na * nb)).clamp(-1.0, 1.0)
}

/// `out = a - b`.
pub fn sub(a: &[f32], b: &[f32], out: &mut [f32]) {
    assert!(a.len() == b.len() && a.len() == out.len());
    for i in 0..a.len() {
        out[i] = a[i] - b[i];
    }
}

/// `y += alpha * x` (axpy).
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// `y *= s`.
pub fn scale(y: &mut [f32], s: f32) {
    for v in y.iter_mut() {
        *v *= s;
    }
}

/// Squared L2 distance.
pub fn dist2(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| {
            let d = *x as f64 - *y as f64;
            d * d
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_and_norm() {
        assert_eq!(dot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
        assert!((norm(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn cosine_basic() {
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-12);
        assert!((cosine(&[1.0, 0.0], &[-1.0, 0.0]) + 1.0).abs() < 1e-12);
        assert!(cosine(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-12);
    }

    #[test]
    fn cosine_zero_vector_is_neutral() {
        assert_eq!(cosine(&[0.0, 0.0], &[1.0, 2.0]), 0.0);
        assert_eq!(cosine(&[1.0, 2.0], &[0.0, 0.0]), 0.0);
    }

    #[test]
    fn cosine_clamped() {
        // Nearly-parallel vectors must not exceed 1 from rounding.
        let a = vec![0.1f32; 1000];
        let c = cosine(&a, &a);
        assert!(c <= 1.0 && c > 0.999999);
    }

    #[test]
    fn axpy_scale_sub() {
        let mut y = vec![1.0f32, 2.0];
        axpy(2.0, &[10.0, 20.0], &mut y);
        assert_eq!(y, vec![21.0, 42.0]);
        scale(&mut y, 0.5);
        assert_eq!(y, vec![10.5, 21.0]);
        let mut out = vec![0.0f32; 2];
        sub(&[5.0, 5.0], &[1.0, 2.0], &mut out);
        assert_eq!(out, vec![4.0, 3.0]);
    }

    #[test]
    fn dist2_matches_norm_of_diff() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [4.0f32, 6.0, 3.0];
        assert!((dist2(&a, &b) - 25.0).abs() < 1e-12);
    }
}
