//! Dataset substrate: synthetic MNIST-like digits + the paper's non-IID
//! partition.
//!
//! The build environment has no network, so MNIST itself is unavailable;
//! per DESIGN.md §4.1 we generate a deterministic 10-class, 784-dim (28×28)
//! dataset in the same learning regime (an MLP of the paper's size reaches
//! ~85% test accuracy) and apply the paper's heterogeneity *exactly*:
//! every client holds at most 5 of the 10 classes and a sample count drawn
//! from {300, 600, 900, 1200, 1500} (§IV-A).
//!
//! Generator: per class, a smooth prototype "glyph" (random strokes on the
//! 28×28 grid, box-blurred); a sample is the prototype under a small random
//! translation plus pixel noise, clipped to [0,1], with a configurable
//! label-noise rate. Translation + pixel noise give intra-class variance;
//! stroke overlap between classes gives inter-class confusion — the two
//! knobs that set the accuracy ceiling.

pub mod partition;
pub mod synth;

pub use partition::{ClientData, Partition, PartitionConfig};
pub use synth::{Dataset, SynthConfig};
