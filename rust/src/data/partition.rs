//! The paper's non-IID partition (§IV-A): each of the K clients holds at
//! most `max_classes` (5) of the 10 classes, with a sample count drawn
//! uniformly from `sizes` ({300, 600, 900, 1200, 1500}); a balanced global
//! test set is held out at the PS for the accuracy curves.
//!
//! ## Lazy materialization
//!
//! Shard *synthesis* is the expensive part of setup (each sample renders
//! side² stroke pixels through blur passes), and at fleet scale
//! (K = 10⁶, `[fleet]` cohort sampling) only a sliver of clients is ever
//! trained. [`Partition::generate`] therefore draws one partition seed
//! and derives every client's pixels from its **own stateless RNG stream**
//! ([`Rng::for_entity`]): generation records only the cheap per-client
//! metadata (size, class assignment) eagerly, and the pixel data
//! materializes behind a `OnceLock` on first [`Partition::client`] touch.
//! Because each shard's stream is independent of every other shard's,
//! the contents are bit-identical no matter which clients are touched in
//! which order — eager synthesis (touch all, in order) and lazy synthesis
//! agree byte for byte (asserted by `lazy_touch_order_is_bit_invariant`).

use std::sync::OnceLock;

use crate::util::Rng;

use super::synth::{Dataset, Prototypes, SynthConfig};

/// RNG stream tags for the partition's independent draw families. All
/// derive from the single partition seed drawn from the caller's stream,
/// so `Partition::generate` still consumes exactly one value from the
/// caller's RNG.
mod pstreams {
    /// Class prototype rendering.
    pub const PROTO: u64 = 0x9807_0;
    /// Per-client metadata (shard size, class assignment).
    pub const META: u64 = 0x3e7a;
    /// Per-client pixel synthesis (one independent stream per client).
    pub const DATA: u64 = 0xda7a_c11e;
    /// The balanced held-out test set.
    pub const TEST: u64 = 0x7e57;
}

/// Partition parameters (defaults = the paper's setting).
#[derive(Debug, Clone, PartialEq)]
pub struct PartitionConfig {
    /// Number of clients K (paper: 100).
    pub clients: usize,
    /// Candidate local dataset sizes (paper: {300..1500} step 300).
    pub sizes: Vec<usize>,
    /// Max distinct classes per client (paper: 5).
    pub max_classes: usize,
    /// Test-set size (balanced across classes).
    pub test_size: usize,
}

impl Default for PartitionConfig {
    fn default() -> Self {
        Self {
            clients: 100,
            sizes: vec![300, 600, 900, 1200, 1500],
            max_classes: 5,
            test_size: 2000,
        }
    }
}

/// One client's local shard.
#[derive(Debug, Clone)]
pub struct ClientData {
    pub data: Dataset,
    /// The classes this client was assigned (≤ max_classes).
    pub classes: Vec<usize>,
}

impl ClientData {
    /// Sample `m` minibatches of size `b` with replacement, returning flat
    /// `[m*b*dim]` features and `[m*b*classes]` one-hot labels — exactly
    /// the `local_train` artifact's input layout.
    pub fn sample_batches(&self, m: usize, b: usize, rng: &mut Rng) -> (Vec<f32>, Vec<f32>) {
        let d = &self.data;
        let mut xs = Vec::with_capacity(m * b * d.dim);
        let mut ys = vec![0.0f32; m * b * d.classes];
        for row in 0..(m * b) {
            let i = rng.index(d.len());
            xs.extend_from_slice(d.row(i));
            ys[row * d.classes + d.y[i] as usize] = 1.0;
        }
        (xs, ys)
    }
}

/// Cheap per-client facts known without synthesizing a single pixel.
#[derive(Debug, Clone)]
struct ClientMeta {
    size: usize,
    classes: Vec<usize>,
}

/// The full federated data layout: K client shards + a global test set.
///
/// Shards materialize lazily on first [`Partition::client`] touch; the
/// size/class metadata ([`Partition::client_len`],
/// [`Partition::client_classes`], [`Partition::total_samples`]) is always
/// available for free.
pub struct Partition {
    protos: Prototypes,
    n_classes: usize,
    /// Partition seed every per-client stream derives from.
    seed: u64,
    meta: Vec<ClientMeta>,
    /// Cumulative shard-size end offsets (global-row → client lookup).
    cum: Vec<usize>,
    shards: Vec<OnceLock<ClientData>>,
    pub test: Dataset,
}

impl Partition {
    /// Generate synthetic data and split it per the paper's recipe.
    /// Consumes exactly one draw from `rng` (the partition seed); the
    /// expensive per-client pixel synthesis is deferred to first touch.
    pub fn generate(synth: SynthConfig, cfg: &PartitionConfig, rng: &mut Rng) -> Self {
        let seed = rng.next_u64();
        let n_classes = synth.classes;
        assert!(cfg.max_classes >= 1 && cfg.max_classes <= n_classes);

        let protos = Prototypes::generate(synth, &mut Rng::with_stream(seed, pstreams::PROTO));

        let mut meta = Vec::with_capacity(cfg.clients);
        let mut cum = Vec::with_capacity(cfg.clients);
        let mut total = 0usize;
        for i in 0..cfg.clients {
            let mut r = Rng::for_entity(seed, pstreams::META, i as u64);
            let size = cfg.sizes[r.index(cfg.sizes.len())];
            let k = 1 + r.index(cfg.max_classes); // 1..=max_classes
            let classes = r.choose_indices(n_classes, k);
            total += size;
            cum.push(total);
            meta.push(ClientMeta { size, classes });
        }

        // Balanced test set with no label noise (ground-truth metric).
        let mut trng = Rng::with_stream(seed, pstreams::TEST);
        let mut test_x = Vec::with_capacity(cfg.test_size * synth.dim());
        let mut test_y = Vec::with_capacity(cfg.test_size);
        for i in 0..cfg.test_size {
            let c = i % n_classes;
            test_x.extend_from_slice(&protos.sample(c, &mut trng));
            test_y.push(c as u8);
        }
        let test = Dataset {
            x: test_x,
            y: test_y,
            dim: synth.dim(),
            classes: n_classes,
        };

        Self {
            protos,
            n_classes,
            seed,
            meta,
            cum,
            shards: (0..cfg.clients).map(|_| OnceLock::new()).collect(),
            test,
        }
    }

    /// Number of clients K.
    pub fn num_clients(&self) -> usize {
        self.meta.len()
    }

    /// Client `i`'s shard, synthesizing it on first touch. The shard's
    /// pixels come from an RNG stream keyed only by (partition seed, `i`),
    /// so the result is independent of which other shards exist yet.
    pub fn client(&self, i: usize) -> &ClientData {
        self.shards[i].get_or_init(|| self.build_shard(i))
    }

    fn build_shard(&self, i: usize) -> ClientData {
        let m = &self.meta[i];
        let mut weights = vec![0.0f64; self.n_classes];
        for &c in &m.classes {
            weights[c] = 1.0;
        }
        let mut rng = Rng::for_entity(self.seed, pstreams::DATA, i as u64);
        let data = self.protos.dataset(m.size, Some(&weights), &mut rng);
        ClientData {
            data,
            classes: m.classes.clone(),
        }
    }

    /// Client `i`'s shard size `D_k` — free, no materialization.
    pub fn client_len(&self, i: usize) -> usize {
        self.meta[i].size
    }

    /// The classes assigned to client `i` — free, no materialization.
    pub fn client_classes(&self, i: usize) -> &[usize] {
        &self.meta[i].classes
    }

    /// How many shards have been materialized so far (lazy-contract test
    /// hook).
    pub fn materialized(&self) -> usize {
        self.shards.iter().filter(|s| s.get().is_some()).count()
    }

    /// Map a global pooled-row index to `(client, local_row)` — the
    /// pooled dataset is the client shards concatenated in client order.
    pub fn locate(&self, row: usize) -> (usize, usize) {
        debug_assert!(row < self.total_samples());
        let c = self.cum.partition_point(|&end| end <= row);
        let start = if c == 0 { 0 } else { self.cum[c - 1] };
        (c, row - start)
    }

    /// Total training samples across clients (the paper's `D`) — free,
    /// no materialization.
    pub fn total_samples(&self) -> usize {
        self.cum.last().copied().unwrap_or(0)
    }

    /// Pool all client shards into one centralized dataset (for the
    /// `F(w*)` estimator). Materializes every shard.
    pub fn pooled(&self) -> Dataset {
        let dim = self.test.dim;
        let classes = self.test.classes;
        let mut x = Vec::with_capacity(self.total_samples() * dim);
        let mut y = Vec::with_capacity(self.total_samples());
        for i in 0..self.num_clients() {
            let c = self.client(i);
            x.extend_from_slice(&c.data.x);
            y.extend_from_slice(&c.data.y);
        }
        Dataset { x, y, dim, classes }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, prop_assert};

    fn tiny_synth() -> SynthConfig {
        SynthConfig {
            side: 10,
            classes: 6,
            strokes: 3,
            blur_passes: 1,
            jitter: 1,
            pixel_noise: 0.2,
            label_noise: 0.0,
        }
    }

    fn tiny_cfg() -> PartitionConfig {
        PartitionConfig {
            clients: 12,
            sizes: vec![30, 60, 90],
            max_classes: 3,
            test_size: 60,
        }
    }

    #[test]
    fn partition_shapes() {
        let mut rng = Rng::new(1);
        let p = Partition::generate(tiny_synth(), &tiny_cfg(), &mut rng);
        assert_eq!(p.num_clients(), 12);
        assert_eq!(p.test.len(), 60);
        for i in 0..p.num_clients() {
            let c = p.client(i);
            assert!([30, 60, 90].contains(&c.data.len()));
            assert_eq!(c.data.len(), p.client_len(i));
            assert!(!c.classes.is_empty() && c.classes.len() <= 3);
            assert_eq!(c.classes, p.client_classes(i));
        }
    }

    #[test]
    fn label_skew_respected() {
        check("clients only hold assigned classes", 10, |g| {
            let mut rng = Rng::new(g.rng().next_u64());
            let p = Partition::generate(tiny_synth(), &tiny_cfg(), &mut rng);
            for i in 0..p.num_clients() {
                let c = p.client(i);
                for &label in &c.data.y {
                    prop_assert(
                        c.classes.contains(&(label as usize)),
                        &format!("label {label} outside classes {:?}", c.classes),
                    )?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn test_set_balanced() {
        let mut rng = Rng::new(2);
        let p = Partition::generate(tiny_synth(), &tiny_cfg(), &mut rng);
        let counts = p.test.class_counts();
        for &c in &counts {
            assert_eq!(c, 10); // 60 / 6 classes
        }
    }

    #[test]
    fn conservation_pooled_equals_sum() {
        let mut rng = Rng::new(3);
        let p = Partition::generate(tiny_synth(), &tiny_cfg(), &mut rng);
        let pooled = p.pooled();
        assert_eq!(pooled.len(), p.total_samples());
        assert_eq!(pooled.x.len(), pooled.len() * pooled.dim);
    }

    #[test]
    fn sample_batches_layout() {
        let mut rng = Rng::new(4);
        let p = Partition::generate(tiny_synth(), &tiny_cfg(), &mut rng);
        let (m, b) = (3, 8);
        let (xs, ys) = p.client(0).sample_batches(m, b, &mut rng);
        let d = &p.client(0).data;
        assert_eq!(xs.len(), m * b * d.dim);
        assert_eq!(ys.len(), m * b * d.classes);
        for row in 0..(m * b) {
            let one: f32 = ys[row * d.classes..(row + 1) * d.classes].iter().sum();
            assert_eq!(one, 1.0);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let p1 = Partition::generate(tiny_synth(), &tiny_cfg(), &mut Rng::new(7));
        let p2 = Partition::generate(tiny_synth(), &tiny_cfg(), &mut Rng::new(7));
        assert_eq!(p1.client(3).data.y, p2.client(3).data.y);
        assert_eq!(p1.test.x, p2.test.x);
    }

    #[test]
    fn generation_is_lazy() {
        let mut rng = Rng::new(9);
        let p = Partition::generate(tiny_synth(), &tiny_cfg(), &mut rng);
        // Generation + the metadata surface synthesize zero shards.
        assert_eq!(p.materialized(), 0);
        let _ = p.total_samples();
        let _ = p.client_len(5);
        let _ = p.client_classes(5);
        let _ = p.locate(p.total_samples() - 1);
        assert_eq!(p.materialized(), 0);
        // First touch materializes exactly the touched shard.
        let _ = p.client(5);
        assert_eq!(p.materialized(), 1);
        let _ = p.client(5);
        assert_eq!(p.materialized(), 1);
    }

    #[test]
    fn lazy_touch_order_is_bit_invariant() {
        // Eager synthesis ≡ lazy synthesis, bit for bit: the same seed
        // touched forward, backward, and via pooled() yields identical
        // shard contents, because each shard has its own entity stream.
        let fwd = Partition::generate(tiny_synth(), &tiny_cfg(), &mut Rng::new(21));
        let bwd = Partition::generate(tiny_synth(), &tiny_cfg(), &mut Rng::new(21));
        let via_pool = Partition::generate(tiny_synth(), &tiny_cfg(), &mut Rng::new(21));

        for i in 0..fwd.num_clients() {
            let _ = fwd.client(i); // eager order: 0, 1, 2, ...
        }
        for i in (0..bwd.num_clients()).rev() {
            let _ = bwd.client(i); // reverse order
        }
        let pooled = via_pool.pooled(); // materialize-all path

        let mut off = 0usize;
        for i in 0..fwd.num_clients() {
            let a = fwd.client(i);
            let b = bwd.client(i);
            assert_eq!(a.data.x, b.data.x, "client {i} pixels diverge");
            assert_eq!(a.data.y, b.data.y, "client {i} labels diverge");
            assert_eq!(a.classes, b.classes, "client {i} classes diverge");
            // And the pooled concatenation is those same bytes in order.
            let n = a.data.len();
            assert_eq!(
                &pooled.x[off * pooled.dim..(off + n) * pooled.dim],
                &a.data.x[..],
                "pooled pixels diverge at client {i}"
            );
            assert_eq!(&pooled.y[off..off + n], &a.data.y[..]);
            off += n;
        }
    }

    #[test]
    fn locate_maps_pooled_rows() {
        let p = Partition::generate(tiny_synth(), &tiny_cfg(), &mut Rng::new(13));
        let pooled = p.pooled();
        for row in [0, 1, 29, 30, p.total_samples() - 1] {
            let (c, local) = p.locate(row);
            assert_eq!(pooled.row(row), p.client(c).data.row(local));
            assert_eq!(pooled.y[row], p.client(c).data.y[local]);
        }
    }
}
