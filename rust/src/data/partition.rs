//! The paper's non-IID partition (§IV-A): each of the K clients holds at
//! most `max_classes` (5) of the 10 classes, with a sample count drawn
//! uniformly from `sizes` ({300, 600, 900, 1200, 1500}); a balanced global
//! test set is held out at the PS for the accuracy curves.

use crate::util::Rng;

use super::synth::{Dataset, Prototypes, SynthConfig};

/// Partition parameters (defaults = the paper's setting).
#[derive(Debug, Clone, PartialEq)]
pub struct PartitionConfig {
    /// Number of clients K (paper: 100).
    pub clients: usize,
    /// Candidate local dataset sizes (paper: {300..1500} step 300).
    pub sizes: Vec<usize>,
    /// Max distinct classes per client (paper: 5).
    pub max_classes: usize,
    /// Test-set size (balanced across classes).
    pub test_size: usize,
}

impl Default for PartitionConfig {
    fn default() -> Self {
        Self {
            clients: 100,
            sizes: vec![300, 600, 900, 1200, 1500],
            max_classes: 5,
            test_size: 2000,
        }
    }
}

/// One client's local shard.
#[derive(Debug, Clone)]
pub struct ClientData {
    pub data: Dataset,
    /// The classes this client was assigned (≤ max_classes).
    pub classes: Vec<usize>,
}

impl ClientData {
    /// Sample `m` minibatches of size `b` with replacement, returning flat
    /// `[m*b*dim]` features and `[m*b*classes]` one-hot labels — exactly
    /// the `local_train` artifact's input layout.
    pub fn sample_batches(&self, m: usize, b: usize, rng: &mut Rng) -> (Vec<f32>, Vec<f32>) {
        let d = &self.data;
        let mut xs = Vec::with_capacity(m * b * d.dim);
        let mut ys = vec![0.0f32; m * b * d.classes];
        for row in 0..(m * b) {
            let i = rng.index(d.len());
            xs.extend_from_slice(d.row(i));
            ys[row * d.classes + d.y[i] as usize] = 1.0;
        }
        (xs, ys)
    }
}

/// The full federated data layout: K client shards + a global test set.
pub struct Partition {
    pub clients: Vec<ClientData>,
    pub test: Dataset,
}

impl Partition {
    /// Generate synthetic data and split it per the paper's recipe.
    pub fn generate(synth: SynthConfig, cfg: &PartitionConfig, rng: &mut Rng) -> Self {
        let protos = Prototypes::generate(synth, rng);
        let n_classes = synth.classes;
        assert!(cfg.max_classes >= 1 && cfg.max_classes <= n_classes);

        let mut clients = Vec::with_capacity(cfg.clients);
        for _ in 0..cfg.clients {
            let n = cfg.sizes[rng.index(cfg.sizes.len())];
            let k = 1 + rng.index(cfg.max_classes); // 1..=max_classes
            let classes = rng.choose_indices(n_classes, k);
            let mut weights = vec![0.0f64; n_classes];
            for &c in &classes {
                weights[c] = 1.0;
            }
            let data = protos.dataset(n, Some(&weights), rng);
            clients.push(ClientData { data, classes });
        }

        // Balanced test set with no label noise (ground-truth metric).
        let mut test_x = Vec::with_capacity(cfg.test_size * synth.dim());
        let mut test_y = Vec::with_capacity(cfg.test_size);
        for i in 0..cfg.test_size {
            let c = i % n_classes;
            test_x.extend_from_slice(&protos.sample(c, rng));
            test_y.push(c as u8);
        }
        let test = Dataset {
            x: test_x,
            y: test_y,
            dim: synth.dim(),
            classes: n_classes,
        };

        Self { clients, test }
    }

    /// Total training samples across clients (the paper's `D`).
    pub fn total_samples(&self) -> usize {
        self.clients.iter().map(|c| c.data.len()).sum()
    }

    /// Pool all client shards into one centralized dataset (for the
    /// `F(w*)` estimator).
    pub fn pooled(&self) -> Dataset {
        let dim = self.test.dim;
        let classes = self.test.classes;
        let mut x = Vec::new();
        let mut y = Vec::new();
        for c in &self.clients {
            x.extend_from_slice(&c.data.x);
            y.extend_from_slice(&c.data.y);
        }
        Dataset { x, y, dim, classes }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, prop_assert};

    fn tiny_synth() -> SynthConfig {
        SynthConfig {
            side: 10,
            classes: 6,
            strokes: 3,
            blur_passes: 1,
            jitter: 1,
            pixel_noise: 0.2,
            label_noise: 0.0,
        }
    }

    fn tiny_cfg() -> PartitionConfig {
        PartitionConfig {
            clients: 12,
            sizes: vec![30, 60, 90],
            max_classes: 3,
            test_size: 60,
        }
    }

    #[test]
    fn partition_shapes() {
        let mut rng = Rng::new(1);
        let p = Partition::generate(tiny_synth(), &tiny_cfg(), &mut rng);
        assert_eq!(p.clients.len(), 12);
        assert_eq!(p.test.len(), 60);
        for c in &p.clients {
            assert!([30, 60, 90].contains(&c.data.len()));
            assert!(!c.classes.is_empty() && c.classes.len() <= 3);
        }
    }

    #[test]
    fn label_skew_respected() {
        check("clients only hold assigned classes", 10, |g| {
            let mut rng = Rng::new(g.rng().next_u64());
            let p = Partition::generate(tiny_synth(), &tiny_cfg(), &mut rng);
            for c in &p.clients {
                for &label in &c.data.y {
                    prop_assert(
                        c.classes.contains(&(label as usize)),
                        &format!("label {label} outside classes {:?}", c.classes),
                    )?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn test_set_balanced() {
        let mut rng = Rng::new(2);
        let p = Partition::generate(tiny_synth(), &tiny_cfg(), &mut rng);
        let counts = p.test.class_counts();
        for &c in &counts {
            assert_eq!(c, 10); // 60 / 6 classes
        }
    }

    #[test]
    fn conservation_pooled_equals_sum() {
        let mut rng = Rng::new(3);
        let p = Partition::generate(tiny_synth(), &tiny_cfg(), &mut rng);
        let pooled = p.pooled();
        assert_eq!(pooled.len(), p.total_samples());
        assert_eq!(pooled.x.len(), pooled.len() * pooled.dim);
    }

    #[test]
    fn sample_batches_layout() {
        let mut rng = Rng::new(4);
        let p = Partition::generate(tiny_synth(), &tiny_cfg(), &mut rng);
        let (m, b) = (3, 8);
        let (xs, ys) = p.clients[0].sample_batches(m, b, &mut rng);
        let d = &p.clients[0].data;
        assert_eq!(xs.len(), m * b * d.dim);
        assert_eq!(ys.len(), m * b * d.classes);
        for row in 0..(m * b) {
            let one: f32 = ys[row * d.classes..(row + 1) * d.classes].iter().sum();
            assert_eq!(one, 1.0);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let p1 = Partition::generate(tiny_synth(), &tiny_cfg(), &mut Rng::new(7));
        let p2 = Partition::generate(tiny_synth(), &tiny_cfg(), &mut Rng::new(7));
        assert_eq!(p1.clients[3].data.y, p2.clients[3].data.y);
        assert_eq!(p1.test.x, p2.test.x);
    }
}
