//! Synthetic 28×28 "digits": deterministic, MNIST-shaped, tunable
//! difficulty. See the module docs in `data/mod.rs` for the rationale.

use crate::util::Rng;

/// Generator parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SynthConfig {
    /// Image side (28 → 784 features).
    pub side: usize,
    /// Number of classes.
    pub classes: usize,
    /// Strokes per class prototype.
    pub strokes: usize,
    /// Box-blur passes over the prototype (smoothness).
    pub blur_passes: usize,
    /// Max |translation| in pixels applied per sample.
    pub jitter: i32,
    /// Per-pixel Gaussian noise std.
    pub pixel_noise: f32,
    /// Probability a sample's label is re-drawn uniformly (paper-regime
    /// imperfection; keeps the accuracy ceiling below 100%).
    pub label_noise: f64,
}

impl Default for SynthConfig {
    fn default() -> Self {
        Self {
            side: 28,
            classes: 10,
            strokes: 4,
            blur_passes: 2,
            jitter: 3,
            pixel_noise: 0.58,
            label_noise: 0.05,
        }
    }
}

impl SynthConfig {
    pub fn dim(&self) -> usize {
        self.side * self.side
    }
}

/// A labeled dataset with row-major flat features.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// `n × dim` features in `[0,1]`.
    pub x: Vec<f32>,
    /// `n` labels in `[0, classes)`.
    pub y: Vec<u8>,
    pub dim: usize,
    pub classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Feature row `i`.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.dim..(i + 1) * self.dim]
    }

    /// One-hot encode labels into a flat `n × classes` f32 buffer.
    pub fn one_hot(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.len() * self.classes];
        for (i, &c) in self.y.iter().enumerate() {
            out[i * self.classes + c as usize] = 1.0;
        }
        out
    }

    /// Per-class sample counts.
    pub fn class_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.classes];
        for &c in &self.y {
            counts[c as usize] += 1;
        }
        counts
    }
}

/// The class prototypes (shared between train and test generation).
pub struct Prototypes {
    protos: Vec<Vec<f32>>,
    cfg: SynthConfig,
}

impl Prototypes {
    /// Build the per-class glyphs deterministically from `rng`.
    pub fn generate(cfg: SynthConfig, rng: &mut Rng) -> Self {
        let protos = (0..cfg.classes)
            .map(|_| Self::make_glyph(&cfg, rng))
            .collect();
        Self { protos, cfg }
    }

    fn make_glyph(cfg: &SynthConfig, rng: &mut Rng) -> Vec<f32> {
        let s = cfg.side;
        let mut img = vec![0.0f32; s * s];
        // Random strokes: straight segments with thickness 1–2.
        for _ in 0..cfg.strokes {
            let (x0, y0) = (rng.index(s) as f64, rng.index(s) as f64);
            let (x1, y1) = (rng.index(s) as f64, rng.index(s) as f64);
            let steps = 2 * s;
            for t in 0..=steps {
                let f = t as f64 / steps as f64;
                let x = x0 + (x1 - x0) * f;
                let y = y0 + (y1 - y0) * f;
                for dy in -1..=1i64 {
                    for dx in -1..=1i64 {
                        let xi = x.round() as i64 + dx;
                        let yi = y.round() as i64 + dy;
                        if (0..s as i64).contains(&xi) && (0..s as i64).contains(&yi) {
                            let w = if dx == 0 && dy == 0 { 1.0 } else { 0.45 };
                            let idx = (yi as usize) * s + xi as usize;
                            img[idx] = (img[idx] + w as f32).min(1.0);
                        }
                    }
                }
            }
        }
        // Box blur for smooth gradients.
        for _ in 0..cfg.blur_passes {
            let src = img.clone();
            for y in 0..s {
                for x in 0..s {
                    let mut sum = 0.0f32;
                    let mut n = 0.0f32;
                    for dy in -1..=1i64 {
                        for dx in -1..=1i64 {
                            let xi = x as i64 + dx;
                            let yi = y as i64 + dy;
                            if (0..s as i64).contains(&xi) && (0..s as i64).contains(&yi) {
                                sum += src[(yi as usize) * s + xi as usize];
                                n += 1.0;
                            }
                        }
                    }
                    img[y * s + x] = sum / n;
                }
            }
        }
        img
    }

    /// Draw one sample of class `c`: translated prototype + pixel noise,
    /// clipped to [0,1].
    pub fn sample(&self, c: usize, rng: &mut Rng) -> Vec<f32> {
        let s = self.cfg.side;
        let j = self.cfg.jitter;
        let dx = rng.index((2 * j + 1) as usize) as i64 - j as i64;
        let dy = rng.index((2 * j + 1) as usize) as i64 - j as i64;
        let proto = &self.protos[c];
        let mut out = vec![0.0f32; s * s];
        for y in 0..s as i64 {
            for x in 0..s as i64 {
                let sx = x - dx;
                let sy = y - dy;
                let base = if (0..s as i64).contains(&sx) && (0..s as i64).contains(&sy) {
                    proto[(sy as usize) * s + sx as usize]
                } else {
                    0.0
                };
                let v = base + (rng.normal() as f32) * self.cfg.pixel_noise;
                out[(y as usize) * s + x as usize] = v.clamp(0.0, 1.0);
            }
        }
        out
    }

    /// Generate `n` samples with (approximately) the given class weights
    /// (`None` = uniform), applying label noise.
    pub fn dataset(&self, n: usize, class_weights: Option<&[f64]>, rng: &mut Rng) -> Dataset {
        let cfg = &self.cfg;
        let mut x = Vec::with_capacity(n * cfg.dim());
        let mut y = Vec::with_capacity(n);
        // Cumulative weights for class draw.
        let weights: Vec<f64> = match class_weights {
            Some(w) => {
                assert_eq!(w.len(), cfg.classes);
                w.to_vec()
            }
            None => vec![1.0; cfg.classes],
        };
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "all-zero class weights");
        for _ in 0..n {
            // Draw true class by weight.
            let mut t = rng.f64() * total;
            let mut c = 0;
            for (i, &w) in weights.iter().enumerate() {
                if t < w {
                    c = i;
                    break;
                }
                t -= w;
                c = i;
            }
            x.extend_from_slice(&self.sample(c, rng));
            // Label noise.
            let label = if rng.f64() < cfg.label_noise {
                rng.index(cfg.classes) as u8
            } else {
                c as u8
            };
            y.push(label);
        }
        Dataset {
            x,
            y,
            dim: cfg.dim(),
            classes: cfg.classes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, prop_assert};

    fn small_cfg() -> SynthConfig {
        SynthConfig {
            side: 12,
            classes: 4,
            strokes: 3,
            blur_passes: 1,
            jitter: 1,
            pixel_noise: 0.2,
            label_noise: 0.0,
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = small_cfg();
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        let p1 = Prototypes::generate(cfg, &mut r1);
        let p2 = Prototypes::generate(cfg, &mut r2);
        let d1 = p1.dataset(20, None, &mut r1);
        let d2 = p2.dataset(20, None, &mut r2);
        assert_eq!(d1.x, d2.x);
        assert_eq!(d1.y, d2.y);
    }

    #[test]
    fn samples_in_unit_range() {
        check("pixels stay in [0,1]", 20, |g| {
            let cfg = small_cfg();
            let mut rng = Rng::new(g.rng().next_u64());
            let protos = Prototypes::generate(cfg, &mut rng);
            let d = protos.dataset(5, None, &mut rng);
            prop_assert(
                d.x.iter().all(|&v| (0.0..=1.0).contains(&v)),
                "pixel out of range",
            )
        });
    }

    #[test]
    fn class_weights_respected() {
        let cfg = small_cfg();
        let mut rng = Rng::new(3);
        let protos = Prototypes::generate(cfg, &mut rng);
        // Only classes 1 and 3.
        let d = protos.dataset(400, Some(&[0.0, 1.0, 0.0, 1.0]), &mut rng);
        let counts = d.class_counts();
        assert_eq!(counts[0], 0);
        assert_eq!(counts[2], 0);
        assert!(counts[1] > 100 && counts[3] > 100, "{counts:?}");
    }

    #[test]
    fn one_hot_shape_and_content() {
        let cfg = small_cfg();
        let mut rng = Rng::new(4);
        let protos = Prototypes::generate(cfg, &mut rng);
        let d = protos.dataset(7, None, &mut rng);
        let oh = d.one_hot();
        assert_eq!(oh.len(), 7 * cfg.classes);
        for i in 0..7 {
            let row = &oh[i * cfg.classes..(i + 1) * cfg.classes];
            assert_eq!(row.iter().sum::<f32>(), 1.0);
            assert_eq!(row[d.y[i] as usize], 1.0);
        }
    }

    #[test]
    fn label_noise_rate() {
        let mut cfg = small_cfg();
        cfg.label_noise = 0.5;
        let mut rng = Rng::new(5);
        let protos = Prototypes::generate(cfg, &mut rng);
        // Single-class weights: true class always 0, so any other label is
        // noise (noise redraw hits 0 itself 1/4 of the time).
        let d = protos.dataset(2000, Some(&[1.0, 0.0, 0.0, 0.0]), &mut rng);
        let flipped = d.y.iter().filter(|&&c| c != 0).count() as f64 / 2000.0;
        // Expected: 0.5 * 3/4 = 0.375.
        assert!((flipped - 0.375).abs() < 0.05, "flipped={flipped}");
    }

    #[test]
    fn prototypes_differ_between_classes() {
        let cfg = small_cfg();
        let mut rng = Rng::new(6);
        let protos = Prototypes::generate(cfg, &mut rng);
        let diff: f32 = protos.protos[0]
            .iter()
            .zip(&protos.protos[1])
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(diff > 1.0, "prototypes nearly identical: diff={diff}");
    }

    #[test]
    fn classes_are_separable_by_nearest_prototype() {
        // Sanity that the task is learnable: nearest-prototype classifier
        // on noiseless labels should beat chance by a wide margin.
        let cfg = SynthConfig {
            label_noise: 0.0,
            ..SynthConfig::default()
        };
        let mut rng = Rng::new(7);
        let protos = Prototypes::generate(cfg, &mut rng);
        let d = protos.dataset(300, None, &mut rng);
        let mut correct = 0;
        for i in 0..d.len() {
            let row = d.row(i);
            let mut best = (f64::INFINITY, 0usize);
            for (c, p) in protos.protos.iter().enumerate() {
                let dist: f64 = row
                    .iter()
                    .zip(p)
                    .map(|(a, b)| ((a - b) as f64).powi(2))
                    .sum();
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            if best.1 == d.y[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / d.len() as f64;
        assert!(acc > 0.5, "nearest-prototype accuracy only {acc}");
    }
}
