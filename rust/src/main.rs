//! `repro` — the PAOTA reproduction driver (leader entrypoint).
//!
//! See `repro help` for the full command/flag reference, DESIGN.md for the
//! experiment index, and EXPERIMENTS.md for recorded paper-vs-measured
//! results.

use anyhow::Result;

use paota::cli::{self, Command};
use paota::{experiments, fl};

fn main() -> Result<()> {
    paota::util::log::init_from_env();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cli = cli::parse(&args)?;

    match &cli.command {
        Command::Help => {
            print!("{}", cli::help_text());
        }
        Command::ShowConfig => {
            // Re-parseable `key = value` lines: pipe to a file and replay
            // the exact configuration with `repro <cmd> --config <file>`.
            print!("{}", cli.config.to_kv_string());
        }
        Command::Run => {
            let run = fl::run(&cli.config)?;
            println!(
                "round,time_s,train_loss,probe_loss,test_loss,test_acc,participants,mean_staleness,mean_power"
            );
            for r in &run.records {
                println!(
                    "{},{:.2},{:.5},{},{},{},{},{:.2},{:.3}",
                    r.round,
                    r.sim_time,
                    r.train_loss,
                    r.probe_loss.map_or("-".into(), |v| format!("{v:.5}")),
                    r.eval.map_or("-".into(), |e| format!("{:.5}", e.loss)),
                    r.eval.map_or("-".into(), |e| format!("{:.4}", e.accuracy)),
                    r.participants,
                    r.mean_staleness,
                    r.mean_power,
                );
            }
            if let Some(acc) = run.final_accuracy() {
                println!("# final test accuracy: {:.2}%", acc * 100.0);
            }
        }
        Command::Serve => {
            let ctx = fl::TrainContext::new(&cli.config)?;
            let server = fl::serve::Server::bind(&ctx, &cli.config)?;
            println!("# serving on {}", server.local_addr());
            if let Some(addr) = server.admin_addr() {
                println!("# obs admin on http://{addr} (/metrics /metrics.json /healthz)");
            }
            println!(
                "# algo={} rounds={} period_ms={} max_sessions={} queue_depth={}",
                cli.config.algorithm.name(),
                cli.config.rounds,
                cli.config.serve.period_ms,
                cli.config.serve.max_sessions,
                cli.config.serve.queue_depth,
            );
            let out = server.run()?;
            let s = out.stats;
            println!(
                "# served {} rounds over {} sessions: dispatched={} accepted={} \
                 late={} duplicates={} out_of_round={} busy={} reclaimed={}",
                out.result.records.len(),
                out.sessions,
                s.dispatched,
                s.accepted,
                s.late,
                s.duplicates,
                s.out_of_round,
                s.busy,
                s.reclaimed,
            );
            if let Some(acc) = out.result.final_accuracy() {
                println!("# final test accuracy: {:.2}%", acc * 100.0);
            }
        }
        Command::Loadgen => {
            let addr = cli.config.serve.bind.clone();
            println!(
                "# loadgen → {} ({} sessions, pace_ms={})",
                addr, cli.config.serve.sessions, cli.config.serve.pace_ms
            );
            let r = fl::serve::run_loadgen(&cli.config, &addr)?;
            println!(
                "# jobs={} acks={} duplicates={} out_of_round={} busy={} lost={} \
                 reconnects={} retries={} faults={} gave_up={}",
                r.jobs,
                r.acks,
                r.duplicates,
                r.out_of_round,
                r.busy,
                r.lost,
                r.reconnects,
                r.retries,
                r.faults,
                r.gave_up
            );
            println!(
                "# wall={:.2}s requests/s={:.1} submit_ms p50={:.2} p90={:.2} p99={:.2}",
                r.wall_secs,
                r.requests_per_sec,
                r.submit_p50_ms,
                r.submit_p90_ms,
                r.submit_p99_ms
            );
        }
        Command::Trace(_) => {
            // Only `summarize` parses today; the journal path rides the
            // `obs_trace_path` config key (`--obs_trace_path FILE`).
            let path = &cli.config.obs.trace_path;
            if path.is_empty() {
                anyhow::bail!("trace summarize needs --obs_trace_path <journal.jsonl>");
            }
            print!("{}", paota::obs::trace::summarize(path)?);
        }
        Command::Fig3 => experiments::fig3(&cli.config, &cli.out_dir, cli.f_star_rounds)?,
        Command::Fig4 => experiments::fig4(&cli.config, &cli.out_dir)?,
        Command::Table1 => {
            experiments::table1(&cli.config, &cli.out_dir, &[0.5, 0.6, 0.7, 0.8])?
        }
        Command::Ablation(which) => experiments::ablation(which, &cli.config, &cli.out_dir)?,
    }
    Ok(())
}
