//! Experiment harness — one function per paper artifact (DESIGN.md §5).
//!
//! Each regenerates the corresponding figure/table: runs every algorithm
//! on the *same* partition/probe/test data, prints the series or rows the
//! paper reports, and writes CSVs under the chosen output directory.
//! Every run goes through the shared event-driven
//! [`Coordinator`](crate::fl::Coordinator) core, so curves across
//! algorithms differ only in their aggregation policy — never in the
//! round loop, RNG streams, or telemetry bucketing.

use std::path::Path;

use anyhow::Result;

use crate::config::{Algorithm, Config};
use crate::fl::{self, centralized, RunResult, TrainContext};
use crate::metrics::{
    format_table1, time_to_accuracy, write_curves_csv, write_records_csv, Curve,
};
use crate::runtime::Engine;

/// The three compared algorithms, in the paper's order.
pub const COMPARED: [Algorithm; 3] = [Algorithm::Paota, Algorithm::LocalSgd, Algorithm::Cotaf];

/// Pretty label for plots/tables.
pub fn label(algo: Algorithm) -> &'static str {
    match algo {
        Algorithm::Paota => "PAOTA",
        Algorithm::LocalSgd => "Local SGD",
        Algorithm::Cotaf => "COTAF",
        Algorithm::Centralized => "Centralized",
        Algorithm::FedAsync => "FedAsync",
    }
}

/// Run all compared algorithms on one shared context.
pub fn run_compared(ctx: &TrainContext, base: &Config) -> Result<Vec<(Algorithm, RunResult)>> {
    COMPARED
        .iter()
        .map(|&algo| {
            let mut cfg = base.clone();
            cfg.algorithm = algo;
            crate::info!("running {} ({} rounds)...", label(algo), cfg.rounds);
            Ok((algo, fl::run_with_context(ctx, &cfg)?))
        })
        .collect()
}

/// **Fig. 3** — train-loss gap `E[F(w^r)] − F(w*)` vs rounds, at the
/// config's noise level (run once with `--n0 -174` and once with
/// `--n0 -74` to reproduce 3a/3b).
pub fn fig3(base: &Config, out_dir: &Path, f_star_rounds: usize) -> Result<()> {
    let engine = Engine::cpu()?;
    let ctx = TrainContext::build(&engine, base)?;

    crate::info!("estimating F(w*) ({f_star_rounds} centralized rounds)...");
    let f_star = centralized::estimate_f_star(&ctx, base, f_star_rounds)? as f64;
    println!("# F(w*) estimate = {f_star:.6}");

    let runs = run_compared(&ctx, base)?;
    let curves: Vec<Curve> = runs
        .iter()
        .map(|(algo, run)| Curve::loss_gap(label(*algo), run, f_star))
        .collect();

    println!(
        "# Fig.3 loss gap — N0 = {} dBm/Hz, B = {} MHz",
        base.channel.n0_dbm_per_hz,
        base.channel.bandwidth_hz / 1e6
    );
    println!("round,{}", curves.iter().map(|c| c.name.clone()).collect::<Vec<_>>().join(","));
    let rounds: Vec<usize> = curves[0].points.iter().map(|p| p.0).collect();
    for (idx, r) in rounds.iter().enumerate() {
        let row: Vec<String> = curves
            .iter()
            .map(|c| {
                c.points
                    .get(idx)
                    .map(|p| format!("{:.6}", p.2))
                    .unwrap_or_default()
            })
            .collect();
        println!("{r},{}", row.join(","));
    }

    let tag = format!("fig3_n0_{}", base.channel.n0_dbm_per_hz.abs() as i64);
    write_curves_csv(&out_dir.join(format!("{tag}.csv")), &curves)?;
    for (algo, run) in &runs {
        write_records_csv(
            &out_dir.join(format!("{tag}_{}.csv", algo.name())),
            run,
        )?;
    }
    println!("# wrote {}/{tag}.csv", out_dir.display());
    Ok(())
}

/// **Fig. 4** — test accuracy vs communication rounds (4a) and vs
/// training time (4b).
pub fn fig4(base: &Config, out_dir: &Path) -> Result<()> {
    let engine = Engine::cpu()?;
    let ctx = TrainContext::build(&engine, base)?;
    let runs = run_compared(&ctx, base)?;

    let curves: Vec<Curve> = runs
        .iter()
        .map(|(algo, run)| Curve::accuracy(label(*algo), run))
        .collect();

    println!("# Fig.4 test accuracy (a: vs rounds, b: vs time)");
    println!("series,round,time_s,accuracy");
    for c in &curves {
        for (r, t, v) in &c.points {
            println!("{},{r},{t:.1},{v:.4}", c.name);
        }
    }
    for (algo, run) in &runs {
        println!(
            "# {} final accuracy: {:.1}%",
            label(*algo),
            run.final_accuracy().unwrap_or(f32::NAN) * 100.0
        );
    }

    write_curves_csv(&out_dir.join("fig4_accuracy.csv"), &curves)?;
    for (algo, run) in &runs {
        write_records_csv(&out_dir.join(format!("fig4_{}.csv", algo.name())), run)?;
    }
    println!("# wrote {}/fig4_accuracy.csv", out_dir.display());
    Ok(())
}

/// **Table I** — rounds & virtual time to target accuracies.
pub fn table1(base: &Config, out_dir: &Path, targets: &[f64]) -> Result<()> {
    let engine = Engine::cpu()?;
    let ctx = TrainContext::build(&engine, base)?;
    let runs = run_compared(&ctx, base)?;

    let rows: Vec<(String, Vec<crate::metrics::TimeToAccuracy>)> = runs
        .iter()
        .map(|(algo, run)| {
            (
                label(*algo).to_string(),
                time_to_accuracy(&run.records, targets),
            )
        })
        .collect();

    println!("# Table I — convergence time (targets as in the paper)");
    print!("{}", format_table1(&rows, targets));

    // CSV.
    let mut csv = String::from("algorithm,target,rounds,time_s\n");
    for (name, ttas) in &rows {
        for t in ttas {
            csv.push_str(&format!(
                "{name},{:.2},{},{}\n",
                t.target,
                t.rounds.map_or(String::new(), |r| r.to_string()),
                t.time_s.map_or(String::new(), |s| format!("{s:.1}")),
            ));
        }
    }
    std::fs::create_dir_all(out_dir).ok();
    std::fs::write(out_dir.join("table1.csv"), csv)?;
    println!("# wrote {}/table1.csv", out_dir.display());
    Ok(())
}

/// Ablations (DESIGN.md A1–A4): each sweeps one knob of PAOTA and prints
/// final accuracy + time-to-70%.
pub fn ablation(which: &str, base: &Config, out_dir: &Path) -> Result<()> {
    let engine = Engine::cpu()?;
    let ctx = TrainContext::build(&engine, base)?;

    let variants: Vec<(String, Config)> = match which {
        "beta" => vec![
            ("optimized".into(), base.clone()),
            ("staleness_only(beta=1)".into(), {
                let mut c = base.clone();
                c.force_beta = Some(1.0);
                c
            }),
            ("similarity_only(beta=0)".into(), {
                let mut c = base.clone();
                c.force_beta = Some(0.0);
                c
            }),
        ],
        "dt" => [4.0, 6.0, 8.0, 12.0]
            .iter()
            .map(|&dt| {
                let mut c = base.clone();
                c.delta_t = dt;
                (format!("dt={dt}"), c)
            })
            .collect(),
        "omega" => [1.0, 3.0, 10.0]
            .iter()
            .map(|&om| {
                let mut c = base.clone();
                c.omega = om;
                (format!("omega={om}"), c)
            })
            .collect(),
        "latency" => vec![
            ("uniform(5,15)".into(), base.clone()),
            ("homogeneous(10)".into(), {
                let mut c = base.clone();
                c.latency_kind = crate::config::LatencyKind::Homogeneous;
                c
            }),
            ("bimodal(20% slow)".into(), {
                let mut c = base.clone();
                c.latency_kind = crate::config::LatencyKind::Bimodal;
                c
            }),
        ],
        "solver" => vec![
            ("pcd".into(), base.clone()),
            ("pla_mip".into(), {
                let mut c = base.clone();
                c.solver = crate::config::SolverKind::PlaMip;
                c
            }),
        ],
        other => anyhow::bail!("unknown ablation {other:?} (beta|dt|omega|latency|solver)"),
    };

    println!("# Ablation `{which}` — PAOTA variants");
    println!("variant,final_acc,best_acc,time_to_70%_s,mean_staleness");
    let mut curves = Vec::new();
    for (name, mut cfg) in variants {
        cfg.algorithm = Algorithm::Paota;
        crate::info!("ablation {which}: {name}");
        let run = fl::run_with_context(&ctx, &cfg)?;
        let tta = time_to_accuracy(&run.records, &[0.7]);
        let mean_stale: f64 = run
            .records
            .iter()
            .map(|r| r.mean_staleness)
            .sum::<f64>()
            / run.records.len().max(1) as f64;
        println!(
            "{name},{:.4},{:.4},{},{:.3}",
            run.final_accuracy().unwrap_or(f32::NAN),
            run.best_accuracy().unwrap_or(f32::NAN),
            tta[0].time_s.map_or("-".into(), |t| format!("{t:.1}")),
            mean_stale
        );
        curves.push(Curve::accuracy(&name, &run));
    }
    write_curves_csv(&out_dir.join(format!("ablation_{which}.csv")), &curves)?;
    println!("# wrote {}/ablation_{which}.csv", out_dir.display());
    Ok(())
}
