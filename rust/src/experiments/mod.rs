//! Experiment harness — every paper artifact is a **campaign
//! declaration** (DESIGN.md §5) on the engine in [`campaign`].
//!
//! A [`Scenario`] is a named config-delta; a [`Campaign`] runs its
//! scenarios on one shared [`TrainContext`] (same partition, probe and
//! test data — the §IV-B fairness setup) and streams results through
//! [`RunObserver`] sinks: the generic [`CurvesCsv`]/[`RecordsCsv`] CSV
//! writers plus the figure-specific stdout tables defined privately
//! below. The functions here — [`fig3`], [`fig4`], [`table1`],
//! [`ablation`] — only *declare* scenarios and attach sinks; the run
//! loop, validation and ordering live in the engine, so a new comparison
//! or sweep is a few lines of declaration, not another copied harness.
//!
//! Algorithms are referred to **by registry name**
//! ([`crate::fl::registry`]); anything registered — including policies
//! registered by examples or downstream code — can appear in a scenario.
//! Every run still goes through the shared event-driven
//! [`Coordinator`](crate::fl::Coordinator) core, so curves across
//! algorithms differ only in their aggregation policy — never in the
//! round loop, RNG streams, or telemetry bucketing.

pub mod campaign;

use std::path::Path;

use anyhow::Result;

pub use campaign::{
    records_csv_path, replicate_key, Campaign, CurveKind, CurvesCsv, GridAxis, MeanStdCurves,
    RecordsCsv, RunObserver, Scenario, ScenarioResult,
};

use crate::config::{Algorithm, Config};
use crate::fl::{centralized, registry, RunResult, TrainContext};
use crate::metrics::{format_table1, time_to_accuracy, write_csv_lines, Curve};

/// Pretty label for a registered policy name (plots/tables).
pub fn label(name: &str) -> String {
    registry::label(name)
}

/// The paper's three compared algorithms as scenarios, in its order.
fn compared_scenarios(base: &Config) -> Vec<Scenario> {
    ["paota", "local_sgd", "cotaf"]
        .iter()
        .map(|&name| {
            let mut cfg = base.clone();
            cfg.algorithm = Algorithm::parse(name).expect("built-in policy");
            Scenario::from_config(label(name), cfg)
        })
        .collect()
}

/// **Fig. 3** — train-loss gap `E[F(w^r)] − F(w*)` vs rounds, at the
/// config's noise level (run once with `--n0 -174` and once with
/// `--n0 -74` to reproduce 3a/3b).
pub fn fig3(base: &Config, out_dir: &Path, f_star_rounds: usize) -> Result<()> {
    let ctx = TrainContext::new(base)?;

    crate::info!("estimating F(w*) ({f_star_rounds} centralized rounds)...");
    let f_star = centralized::estimate_f_star(&ctx, base, f_star_rounds)? as f64;
    println!("# F(w*) estimate = {f_star:.6}");

    let tag = format!("fig3_n0_{}", base.channel.n0_dbm_per_hz.abs() as i64);
    Campaign::new("fig3", base.clone())
        .scenarios(compared_scenarios(base))
        .observe(LossGapStdout {
            n0: base.channel.n0_dbm_per_hz,
            bandwidth_mhz: base.channel.bandwidth_hz / 1e6,
            f_star,
        })
        .observe(CurvesCsv::loss_gap(out_dir.join(format!("{tag}.csv")), f_star))
        .observe(RecordsCsv::new(out_dir, tag.clone()))
        .run_with_context(&ctx)?;
    println!("# wrote {}/{tag}.csv", out_dir.display());
    Ok(())
}

/// **Fig. 4** — test accuracy vs communication rounds (4a) and vs
/// training time (4b).
pub fn fig4(base: &Config, out_dir: &Path) -> Result<()> {
    let ctx = TrainContext::new(base)?;

    Campaign::new("fig4", base.clone())
        .scenarios(compared_scenarios(base))
        .observe(AccuracyStdout)
        .observe(CurvesCsv::accuracy(out_dir.join("fig4_accuracy.csv")))
        .observe(RecordsCsv::new(out_dir, "fig4"))
        .run_with_context(&ctx)?;
    println!("# wrote {}/fig4_accuracy.csv", out_dir.display());
    Ok(())
}

/// **Table I** — rounds & virtual time to target accuracies.
pub fn table1(base: &Config, out_dir: &Path, targets: &[f64]) -> Result<()> {
    let ctx = TrainContext::new(base)?;

    Campaign::new("table1", base.clone())
        .scenarios(compared_scenarios(base))
        .observe(Table1Stdout { targets: targets.to_vec() })
        .observe(Table1Csv {
            path: out_dir.join("table1.csv"),
            targets: targets.to_vec(),
        })
        .run_with_context(&ctx)?;
    println!("# wrote {}/table1.csv", out_dir.display());
    Ok(())
}

/// Ablations (DESIGN.md A1–A4 plus `scheduling`, `topology`, `mobility`,
/// `replicates`): each sweeps one knob of the PAOTA family and prints
/// final accuracy + time-to-70%.
pub fn ablation(which: &str, base: &Config, out_dir: &Path) -> Result<()> {
    if which == "replicates" {
        return replicates_ablation(base, out_dir);
    }
    let ctx = TrainContext::new(base)?;
    let scenarios = ablation_scenarios(which, base)?;
    // The mobility sweep's churn sidecar is a pure function of the
    // scenario configs (model replay, no training), so it is byte-stable
    // across `--jobs`; write it up front, next to the accuracy CSV.
    if which == "mobility" {
        write_mobility_churn(&scenarios, out_dir)?;
    }

    println!("# Ablation `{which}` — PAOTA variants");
    println!("variant,final_acc,best_acc,time_to_70%_s,mean_staleness");
    Campaign::new(format!("ablation_{which}"), base.clone())
        .scenarios(scenarios)
        .observe(AblationStdout)
        .observe(CurvesCsv::accuracy(out_dir.join(format!("ablation_{which}.csv"))))
        .run_with_context(&ctx)?;
    println!("# wrote {}/ablation_{which}.csv", out_dir.display());
    if which == "mobility" {
        println!("# wrote {}/ablation_mobility_churn.csv", out_dir.display());
    }
    Ok(())
}

/// The mobility ablation's churn CSV: intended (model-level) handover
/// activity per scenario — `series,round,moves,members_per_cell` with
/// the per-cell member counts slash-joined (`members_per_cell` always
/// sums to K: the conservation property). Replayed from the configs via
/// [`crate::fl::mobility::trace`] — no training involved.
fn write_mobility_churn(scenarios: &[Scenario], out_dir: &Path) -> Result<()> {
    let mut rows = Vec::new();
    for s in scenarios {
        let t = crate::fl::mobility::trace(&s.cfg)?;
        for (round, (moves, members)) in
            t.per_round_moves.iter().zip(&t.per_round_members).enumerate()
        {
            let cells = members
                .iter()
                .map(|m| m.to_string())
                .collect::<Vec<_>>()
                .join("/");
            rows.push(format!("{},{round},{moves},{cells}", s.name));
        }
    }
    write_csv_lines(
        &out_dir.join("ablation_mobility_churn.csv"),
        "series,round,moves,members_per_cell",
        rows,
    )
}

/// `ablation replicates` — the paper-grade error-bar harness: a
/// [`Campaign::grid`] of algorithms × seed replicates whose
/// [`MeanStdCurves`] sink emits mean ± std accuracy curves per
/// algorithm. Three replicates by default (`--seed` shifts the set).
fn replicates_ablation(base: &Config, out_dir: &Path) -> Result<()> {
    let ctx = TrainContext::new(base)?;
    let seeds: Vec<u64> = (0..3).map(|i| base.seed + i).collect();

    println!("# Ablation `replicates` — {} seeds per algorithm", seeds.len());
    println!("variant,final_acc,best_acc,time_to_70%_s,mean_staleness");
    Campaign::new("ablation_replicates", base.clone())
        .grid(vec![
            GridAxis::algorithms(&["paota", "local_sgd", "cotaf"])?,
            GridAxis::seeds(&seeds),
        ])
        .observe(AblationStdout)
        .observe(MeanStdCurves::accuracy(out_dir.join("ablation_replicates.csv")))
        .run_with_context(&ctx)?;
    println!("# wrote {}/ablation_replicates.csv", out_dir.display());
    Ok(())
}

/// The variant set of one ablation, as scenarios.
fn ablation_scenarios(which: &str, base: &Config) -> Result<Vec<Scenario>> {
    let paota = Algorithm::parse("paota").expect("built-in policy");
    let variants: Vec<(String, Config)> = match which {
        "beta" => vec![
            ("optimized".into(), base.clone()),
            ("staleness_only(beta=1)".into(), {
                let mut c = base.clone();
                c.force_beta = Some(1.0);
                c
            }),
            ("similarity_only(beta=0)".into(), {
                let mut c = base.clone();
                c.force_beta = Some(0.0);
                c
            }),
        ],
        "dt" => [4.0, 6.0, 8.0, 12.0]
            .iter()
            .map(|&dt| {
                let mut c = base.clone();
                c.delta_t = dt;
                (format!("dt={dt}"), c)
            })
            .collect(),
        "omega" => [1.0, 3.0, 10.0]
            .iter()
            .map(|&om| {
                let mut c = base.clone();
                c.omega = om;
                (format!("omega={om}"), c)
            })
            .collect(),
        "latency" => vec![
            ("uniform(5,15)".into(), base.clone()),
            ("homogeneous(10)".into(), {
                let mut c = base.clone();
                c.latency_kind = crate::config::LatencyKind::Homogeneous;
                c
            }),
            ("bimodal(20% slow)".into(), {
                let mut c = base.clone();
                c.latency_kind = crate::config::LatencyKind::Bimodal;
                c
            }),
        ],
        "solver" => vec![
            ("pcd".into(), base.clone()),
            ("pla_mip".into(), {
                let mut c = base.clone();
                c.solver = crate::config::SolverKind::PlaMip;
                c
            }),
        ],
        // Channel/gradient-aware participant scheduling (arXiv 2212.00491)
        // vs PAOTA's take-all rule, at the same energy budget and data.
        "scheduling" => {
            let ca = Algorithm::parse("ca_paota").expect("built-in policy");
            let m = (base.partition.clients / 5).max(1);
            vec![
                ("paota_take_all".into(), base.clone()),
                ("ca_adaptive".into(), {
                    let mut c = base.clone();
                    c.algorithm = ca.clone();
                    c
                }),
                (format!("ca_top{m}"), {
                    let mut c = base.clone();
                    c.algorithm = ca;
                    c.participants = m;
                    c
                }),
            ]
        }
        // Aggregation-topology sweep (`fl::topology`): flat PAOTA vs
        // grouped AirComp (Air-FedGA, two profile partitioners + the
        // size baseline) vs ≥2-cell hierarchies (cloud & gossip mixing),
        // plus the heavy-tailed / time-correlated arrival processes the
        // grouping is meant to absorb — one declarative campaign.
        "topology" => {
            let air = Algorithm::parse("air_fedga").expect("built-in policy");
            let k = base.partition.clients;
            let groups = (k / 3).clamp(2, 5);
            // Every variant sets its algorithm explicitly (a user-supplied
            // --algo must never leak into the comparison set).
            let flat = || {
                let mut c = base.clone();
                c.algorithm = paota.clone();
                c.topology = Default::default();
                c
            };
            let grouped = |part: crate::fl::topology::PartitionerKind| {
                let mut c = flat();
                c.algorithm = air.clone();
                c.topology.groups = groups;
                c.topology.partitioner = part;
                c
            };
            let cells = |n: usize, mixing: crate::fl::topology::MixingKind| {
                let mut c = flat();
                c.topology.cells = n.min(k);
                c.topology.mixing = mixing;
                c.topology.mixing_every = 2;
                c
            };
            vec![
                ("paota_flat".into(), flat()),
                (
                    format!("air_fedga_rr_g{groups}"),
                    grouped(crate::fl::topology::PartitionerKind::RoundRobin),
                ),
                (
                    format!("air_fedga_latency_g{groups}"),
                    grouped(crate::fl::topology::PartitionerKind::Latency),
                ),
                (
                    format!("air_fedga_channel_g{groups}"),
                    grouped(crate::fl::topology::PartitionerKind::Channel),
                ),
                (
                    "hier_2cell_cloud".into(),
                    cells(2, crate::fl::topology::MixingKind::Cloud),
                ),
                (
                    "hier_3cell_gossip".into(),
                    cells(3, crate::fl::topology::MixingKind::Gossip),
                ),
                ("paota_flat_lognormal".into(), {
                    let mut c = flat();
                    c.latency_kind = crate::config::LatencyKind::Lognormal;
                    c
                }),
                (format!("air_fedga_latency_g{groups}_ge"), {
                    let mut c = grouped(crate::fl::topology::PartitionerKind::Latency);
                    c.latency_kind = crate::config::LatencyKind::GilbertElliott;
                    c
                }),
            ]
        }
        // Client roaming over a 3-cell tree (`fl::mobility`): the frozen
        // baseline vs markov/waypoint trajectories under each handover
        // policy, plus one residence-coupled channel variant — all paota
        // per cell, cloud mixing, one declarative campaign (with a churn
        // sidecar CSV replayed from the mobility models).
        "mobility" => {
            use crate::fl::mobility::{HandoverPolicy, MobilityKind};
            let cells = 3usize.min(base.partition.clients);
            let roam = |kind: MobilityKind, policy: HandoverPolicy| {
                let mut c = base.clone();
                c.algorithm = paota.clone();
                c.topology = Default::default();
                c.mobility = Default::default();
                c.topology.cells = cells;
                c.topology.mixing = crate::fl::topology::MixingKind::Cloud;
                c.topology.mixing_every = 2;
                c.mobility.kind = kind;
                c.mobility.handover = policy;
                c.mobility.dwell_mean = 2.0;
                c.mobility.handover_every = 1;
                c
            };
            let mut variants =
                vec![("static".to_string(), roam(MobilityKind::Static, HandoverPolicy::Deliver))];
            for kind in [MobilityKind::Markov, MobilityKind::Waypoint] {
                for policy in
                    [HandoverPolicy::Deliver, HandoverPolicy::Forward, HandoverPolicy::Drop]
                {
                    variants.push((
                        format!("{}_{}", kind.name(), policy.name()),
                        roam(kind, policy),
                    ));
                }
            }
            variants.push(("markov_deliver_snr6".to_string(), {
                let mut c = roam(MobilityKind::Markov, HandoverPolicy::Deliver);
                c.mobility.cell_noise_spread_db = 6.0;
                c
            }));
            variants
        }
        other => anyhow::bail!(
            "unknown ablation {other:?} \
             (beta|dt|omega|latency|solver|scheduling|topology|mobility|replicates)"
        ),
    };
    Ok(variants
        .into_iter()
        .map(|(name, mut cfg)| {
            // Every ablation runs the PAOTA family: only the variants that
            // deliberately picked an extension scheme (scheduling →
            // ca_paota, topology → air_fedga) keep it; everything else —
            // including a user-supplied --algo on the base config — is
            // pinned to the paper's algorithm.
            let keep = (which == "scheduling" && cfg.algorithm.name() == "ca_paota")
                || (which == "topology" && cfg.algorithm.name() == "air_fedga");
            if !keep {
                cfg.algorithm = paota.clone();
            }
            Scenario::from_config(name, cfg)
        })
        .collect())
}

// ---------------------------------------------------------------------
// Figure-specific stdout sinks.
// ---------------------------------------------------------------------

/// Fig. 3 stdout table: one row per *evaluated round in any series*
/// (algorithms may eval at different cadences; cells a series did not
/// evaluate stay empty instead of misaligning the row).
struct LossGapStdout {
    n0: f64,
    bandwidth_mhz: f64,
    f_star: f64,
}

impl RunObserver for LossGapStdout {
    fn on_campaign_end(&mut self, results: &[ScenarioResult]) -> Result<()> {
        let curves: Vec<Curve> = results
            .iter()
            .map(|r| Curve::loss_gap(&r.name, &r.run, self.f_star))
            .collect();
        println!(
            "# Fig.3 loss gap — N0 = {} dBm/Hz, B = {} MHz",
            self.n0, self.bandwidth_mhz
        );
        println!(
            "round,{}",
            curves.iter().map(|c| c.name.clone()).collect::<Vec<_>>().join(",")
        );
        let mut rounds: Vec<usize> = curves
            .iter()
            .flat_map(|c| c.points.iter().map(|p| p.0))
            .collect();
        rounds.sort_unstable();
        rounds.dedup();
        for r in rounds {
            let row: Vec<String> = curves
                .iter()
                .map(|c| {
                    c.points
                        .iter()
                        .find(|p| p.0 == r)
                        .map(|p| format!("{:.6}", p.2))
                        .unwrap_or_default()
                })
                .collect();
            println!("{r},{}", row.join(","));
        }
        Ok(())
    }
}

/// Fig. 4 stdout: the long-form accuracy series plus final accuracies.
struct AccuracyStdout;

impl RunObserver for AccuracyStdout {
    fn on_campaign_end(&mut self, results: &[ScenarioResult]) -> Result<()> {
        let curves: Vec<Curve> = results
            .iter()
            .map(|r| Curve::accuracy(&r.name, &r.run))
            .collect();
        println!("# Fig.4 test accuracy (a: vs rounds, b: vs time)");
        println!("series,round,time_s,accuracy");
        for c in &curves {
            for (r, t, v) in &c.points {
                println!("{},{r},{t:.1},{v:.4}", c.name);
            }
        }
        for r in results {
            println!(
                "# {} final accuracy: {:.1}%",
                r.name,
                r.run.final_accuracy().unwrap_or(f32::NAN) * 100.0
            );
        }
        Ok(())
    }
}

/// Table I rows for a result set.
fn table1_rows(
    results: &[ScenarioResult],
    targets: &[f64],
) -> Vec<(String, Vec<crate::metrics::TimeToAccuracy>)> {
    results
        .iter()
        .map(|r| (r.name.clone(), time_to_accuracy(&r.run.records, targets)))
        .collect()
}

/// Table I stdout: the paper's row layout.
struct Table1Stdout {
    targets: Vec<f64>,
}

impl RunObserver for Table1Stdout {
    fn on_campaign_end(&mut self, results: &[ScenarioResult]) -> Result<()> {
        println!("# Table I — convergence time (targets as in the paper)");
        print!("{}", format_table1(&table1_rows(results, &self.targets), &self.targets));
        Ok(())
    }
}

/// Table I CSV through the shared metrics writer.
struct Table1Csv {
    path: std::path::PathBuf,
    targets: Vec<f64>,
}

impl RunObserver for Table1Csv {
    fn on_campaign_end(&mut self, results: &[ScenarioResult]) -> Result<()> {
        let mut rows = Vec::new();
        for (name, ttas) in table1_rows(results, &self.targets) {
            for t in ttas {
                rows.push(format!(
                    "{name},{:.2},{},{}",
                    t.target,
                    t.rounds.map_or(String::new(), |r| r.to_string()),
                    t.time_s.map_or(String::new(), |s| format!("{s:.1}")),
                ));
            }
        }
        write_csv_lines(&self.path, "algorithm,target,rounds,time_s", rows)
    }
}

/// Ablation stdout: one summary row per finished variant.
struct AblationStdout;

impl RunObserver for AblationStdout {
    fn on_scenario_end(&mut self, scenario: &Scenario, run: &RunResult) -> Result<()> {
        let tta = time_to_accuracy(&run.records, &[0.7]);
        let mean_stale: f64 = run.records.iter().map(|r| r.mean_staleness).sum::<f64>()
            / run.records.len().max(1) as f64;
        println!(
            "{},{:.4},{:.4},{},{:.3}",
            scenario.name,
            run.final_accuracy().unwrap_or(f32::NAN),
            run.best_accuracy().unwrap_or(f32::NAN),
            tta[0].time_s.map_or("-".into(), |t| format!("{t:.1}")),
            mean_stale
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablation_scenario_sets_match_the_published_variants() {
        let base = Config::default();
        for (which, count) in [
            ("beta", 3),
            ("dt", 4),
            ("omega", 3),
            ("latency", 3),
            ("solver", 2),
            ("scheduling", 3),
            ("topology", 8),
            ("mobility", 8),
        ] {
            let s = ablation_scenarios(which, &base).unwrap();
            assert_eq!(s.len(), count, "ablation {which}");
        }
        assert!(ablation_scenarios("nope", &base).is_err());
    }

    #[test]
    fn mobility_ablation_spans_models_and_handover_policies() {
        use crate::fl::mobility::{HandoverPolicy, MobilityKind};
        let base = Config::default();
        let s = ablation_scenarios("mobility", &base).unwrap();
        // The frozen baseline leads; every variant is valid multi-cell
        // paota on the same 3-cell tree.
        assert_eq!(s[0].name, "static");
        assert_eq!(s[0].cfg.mobility.kind, MobilityKind::Static);
        for x in &s {
            assert_eq!(x.cfg.algorithm.name(), "paota", "{}", x.name);
            assert_eq!(x.cfg.topology.cells, 3, "{}", x.name);
            x.cfg.validate().unwrap();
        }
        // Both roaming models × all three handover policies appear.
        for kind in [MobilityKind::Markov, MobilityKind::Waypoint] {
            for policy in
                [HandoverPolicy::Deliver, HandoverPolicy::Forward, HandoverPolicy::Drop]
            {
                assert!(
                    s.iter().any(|x| x.cfg.mobility.kind == kind
                        && x.cfg.mobility.handover == policy),
                    "missing {}/{}",
                    kind.name(),
                    policy.name()
                );
            }
        }
        // The residence-coupled channel variant rides along.
        assert!(s.iter().any(|x| x.cfg.mobility.cell_noise_spread_db != 0.0));
    }

    #[test]
    fn topology_ablation_spans_flat_grouped_and_hierarchical() {
        let base = Config::default();
        let s = ablation_scenarios("topology", &base).unwrap();
        // Flat PAOTA reference.
        assert_eq!(s[0].cfg.algorithm.name(), "paota");
        assert_eq!(s[0].cfg.topology.cells, 1);
        // Grouped AirComp with ≥ 2 distinct partitioners.
        let partitioners: std::collections::BTreeSet<&str> = s
            .iter()
            .filter(|x| x.cfg.algorithm.name() == "air_fedga")
            .map(|x| x.cfg.topology.partitioner.name())
            .collect();
        assert!(partitioners.len() >= 2, "{partitioners:?}");
        for x in s.iter().filter(|x| x.cfg.algorithm.name() == "air_fedga") {
            assert!(x.cfg.topology.groups >= 2, "{}", x.name);
            assert_eq!(x.cfg.topology.cells, 1, "{}", x.name);
        }
        // ≥ 2-cell hierarchical runs on a flat per-cell policy.
        let hier: Vec<&Scenario> = s.iter().filter(|x| x.cfg.topology.cells > 1).collect();
        assert!(hier.len() >= 2);
        for x in &hier {
            assert_eq!(x.cfg.algorithm.name(), "paota", "{}", x.name);
            x.cfg.validate().unwrap();
        }
        // The richer arrival processes ride along.
        assert!(s
            .iter()
            .any(|x| x.cfg.latency_kind == crate::config::LatencyKind::Lognormal));
        assert!(s
            .iter()
            .any(|x| x.cfg.latency_kind == crate::config::LatencyKind::GilbertElliott));
        for x in &s {
            x.cfg.validate().unwrap();
        }
    }

    #[test]
    fn user_algo_on_the_base_config_never_leaks_into_ablation_variants() {
        // `repro ablation X --algo <extension>` must not re-route the
        // comparison set: knob ablations stay pure paota, and the
        // topology set keeps its declared per-variant algorithms.
        for user_algo in ["ca_paota", "air_fedga", "fedasync"] {
            let mut base = Config::default();
            base.algorithm = Algorithm::parse(user_algo).unwrap();
            for which in ["beta", "dt", "omega", "latency", "solver"] {
                for s in ablation_scenarios(which, &base).unwrap() {
                    assert_eq!(s.cfg.algorithm.name(), "paota", "{which}/{}", s.name);
                }
            }
            for s in ablation_scenarios("topology", &base).unwrap() {
                let want = if s.name.starts_with("air_fedga") { "air_fedga" } else { "paota" };
                assert_eq!(s.cfg.algorithm.name(), want, "topology/{}", s.name);
                s.cfg.validate().unwrap();
            }
        }
    }

    #[test]
    fn knob_ablations_always_run_paota() {
        let base = Config::default();
        for which in ["beta", "dt", "omega", "latency", "solver"] {
            for s in ablation_scenarios(which, &base).unwrap() {
                assert_eq!(s.cfg.algorithm.name(), "paota", "{which}/{}", s.name);
            }
        }
    }

    #[test]
    fn scheduling_ablation_compares_paota_and_ca_paota() {
        let base = Config::default();
        let s = ablation_scenarios("scheduling", &base).unwrap();
        assert_eq!(s[0].cfg.algorithm.name(), "paota");
        assert_eq!(s[1].cfg.algorithm.name(), "ca_paota");
        assert_eq!(s[2].cfg.algorithm.name(), "ca_paota");
        assert_eq!(s[2].cfg.participants, 20); // K/5 at the paper's K=100
    }

    #[test]
    fn compared_scenarios_use_registry_labels() {
        let base = Config::default();
        let s = compared_scenarios(&base);
        let names: Vec<&str> = s.iter().map(|x| x.name.as_str()).collect();
        assert_eq!(names, vec!["PAOTA", "Local SGD", "COTAF"]);
        assert_eq!(s[1].cfg.algorithm.name(), "local_sgd");
    }
}
