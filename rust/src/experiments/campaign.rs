//! The declarative **campaign engine**: run a set of named config-deltas
//! ([`Scenario`]s) on one shared [`TrainContext`] and stream the results
//! through pluggable [`RunObserver`] sinks.
//!
//! This replaces the hand-rolled run-loop + println + CSV harness that
//! every figure used to copy: a figure/table/ablation is now a *data
//! declaration* —
//!
//! ```ignore
//! Campaign::new("fig4", base.clone())
//!     .scenario("PAOTA", |c| c.algorithm = Algorithm::parse("paota").unwrap())
//!     .scenario("COTAF", |c| c.algorithm = Algorithm::parse("cotaf").unwrap())
//!     .observe(CurvesCsv::accuracy(out.join("fig4_accuracy.csv")))
//!     .observe(RecordsCsv::new(out, "fig4"))
//!     .run()?;
//! ```
//!
//! All scenarios share the context built from the campaign's base config
//! (same partition, probe and test set — the paper's §IV-B fairness
//! requirement), while each run's RNG streams derive solely from its own
//! config seed, so a campaign run is bit-identical to the equivalent
//! sequence of single [`crate::fl::run_with_context`] calls (covered by
//! `tests/registry_campaign.rs`). Generic sinks live here
//! ([`CurvesCsv`], [`RecordsCsv`]); figure-specific stdout tables are
//! small observers next to their campaign declarations in
//! [`crate::experiments`].
//!
//! # Parallel execution & observer replay
//!
//! Scenarios are independent given the shared context, so with
//! `perf.campaign_jobs > 1` (CLI `--jobs N`) the engine fans them out
//! over a pool of worker threads — requires the thread-safe native
//! backend (`artifacts_dir = native`; a PJRT campaign degrades to serial
//! with a warning). Determinism is preserved *exactly*: each run's RNG
//! streams derive only from its own config seed, finished
//! [`ScenarioResult`]s are buffered, and the [`RunObserver`] hooks are
//! **replayed on the campaign thread in declaration order** once every
//! run completed — `start(s₀), end(s₀), start(s₁), end(s₁), …` — so
//! every CSV and stdout table is byte-identical to the serial path
//! (covered by `tests/golden_seed.rs`). The only observable difference:
//! under parallel execution `on_scenario_start` fires after the runs, at
//! replay time, rather than just before each run starts.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::Result;

use crate::config::{Algorithm, Config};
use crate::fl::{self, RunResult, TrainContext};
use crate::metrics::{write_csv_lines, write_curves_csv, write_records_csv, Curve};

/// A named config-delta: one run of a campaign.
pub struct Scenario {
    /// Series label (tables, CSV series column, plots).
    pub name: String,
    /// The full effective config of this run.
    pub cfg: Config,
}

impl Scenario {
    /// Apply `delta` to a copy of `base`.
    pub fn new(name: impl Into<String>, base: &Config, delta: impl FnOnce(&mut Config)) -> Self {
        let mut cfg = base.clone();
        delta(&mut cfg);
        Self { name: name.into(), cfg }
    }

    /// Wrap an already-prepared config.
    pub fn from_config(name: impl Into<String>, cfg: Config) -> Self {
        Self { name: name.into(), cfg }
    }
}

/// One finished scenario.
pub struct ScenarioResult {
    pub name: String,
    pub cfg: Config,
    pub run: RunResult,
}

/// A sink observing campaign progress. All methods default to no-ops so
/// an observer implements only the hooks it needs.
#[allow(unused_variables)]
pub trait RunObserver {
    /// Before a scenario's run starts.
    fn on_scenario_start(&mut self, scenario: &Scenario) -> Result<()> {
        Ok(())
    }

    /// After a scenario's run finished (called in declaration order).
    fn on_scenario_end(&mut self, scenario: &Scenario, run: &RunResult) -> Result<()> {
        Ok(())
    }

    /// Once, after every scenario ran.
    fn on_campaign_end(&mut self, results: &[ScenarioResult]) -> Result<()> {
        Ok(())
    }
}

/// A named set of scenarios sharing one training context and a list of
/// observer sinks.
pub struct Campaign {
    name: String,
    base: Config,
    scenarios: Vec<Scenario>,
    observers: Vec<Box<dyn RunObserver>>,
}

impl Campaign {
    /// A campaign whose shared context is built from `base`.
    pub fn new(name: impl Into<String>, base: Config) -> Self {
        Self {
            name: name.into(),
            base,
            scenarios: Vec::new(),
            observers: Vec::new(),
        }
    }

    /// The campaign's name (progress logging).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Declare one scenario as a delta over the campaign base.
    pub fn scenario(mut self, name: impl Into<String>, delta: impl FnOnce(&mut Config)) -> Self {
        let s = Scenario::new(name, &self.base, delta);
        self.scenarios.push(s);
        self
    }

    /// Declare a batch of prepared scenarios.
    pub fn scenarios(mut self, list: impl IntoIterator<Item = Scenario>) -> Self {
        self.scenarios.extend(list);
        self
    }

    /// Declare the **cartesian product** of the axes as scenarios — the
    /// paper-grade sweep shape (algorithms × noise levels × seeds) in one
    /// call. Scenario names are the axis labels joined with `|`
    /// (`"PAOTA|n0=-74|seed=43"`), which is exactly what
    /// [`replicate_key`] strips the seed part from, so a `seeds` axis
    /// plus a [`MeanStdCurves`] sink yields mean ± std curves per
    /// non-seed combination.
    pub fn grid(mut self, axes: Vec<GridAxis>) -> Self {
        let mut combos: Vec<(String, Config)> = vec![(String::new(), self.base.clone())];
        for axis in &axes {
            let mut next = Vec::with_capacity(combos.len() * axis.variants.len().max(1));
            for (name, cfg) in &combos {
                for (label, delta) in &axis.variants {
                    let mut c = cfg.clone();
                    delta(&mut c);
                    let combined = if name.is_empty() {
                        label.clone()
                    } else {
                        format!("{name}|{label}")
                    };
                    next.push((combined, c));
                }
            }
            combos = next;
        }
        for (name, cfg) in combos {
            self.scenarios.push(Scenario::from_config(name, cfg));
        }
        self
    }

    /// Attach an observer sink.
    pub fn observe(mut self, observer: impl RunObserver + 'static) -> Self {
        self.observers.push(Box::new(observer));
        self
    }

    /// Build the shared context from the base config and run. Engine
    /// construction is lazy ([`TrainContext::new`]): a native-backend
    /// campaign never touches the PJRT path.
    pub fn run(self) -> Result<Vec<ScenarioResult>> {
        let ctx = TrainContext::new(&self.base)?;
        self.run_with_context(&ctx)
    }

    /// Run every scenario against a prepared context, feeding observers.
    ///
    /// Every scenario is checked *before the first run starts*: it must
    /// pass [`Config::validate`] and must not change any field the shared
    /// context was built from (partition, synthetic-data geometry,
    /// artifacts/backend selection) — a delta there would silently run on
    /// data the scenario's config no longer describes. Changing `seed` is
    /// allowed: the partition stays the base's, while the run's RNG
    /// streams re-derive from the scenario seed (seed-replicate sweeps on
    /// fixed data).
    pub fn run_with_context(mut self, ctx: &TrainContext) -> Result<Vec<ScenarioResult>> {
        let base_ctx = context_fingerprint(&self.base);
        for scenario in &self.scenarios {
            scenario.cfg.validate()?;
            let got = context_fingerprint(&scenario.cfg);
            if got != base_ctx {
                anyhow::bail!(
                    "scenario {:?} changes context-defining config (partition/synth/\
                     artifacts_dir): campaign scenarios share one TrainContext built \
                     from the base config — run a separate campaign instead",
                    scenario.name
                );
            }
        }

        let mut jobs = self
            .base
            .perf
            .campaign_jobs
            .max(1)
            .min(self.scenarios.len().max(1));
        if jobs > 1 && !ctx.rt.is_native() {
            crate::warn_!(
                "campaign_jobs = {jobs} needs the thread-safe native backend \
                 (artifacts_dir = native); the PJRT client is pinned to its \
                 creating thread — running scenarios serially"
            );
            jobs = 1;
        }

        let mut results = Vec::with_capacity(self.scenarios.len());
        if jobs > 1 {
            // Runs complete in any order; observers are then REPLAYED on
            // this thread in strict declaration order with the serial
            // hook interleaving — start(s0), end(s0), start(s1), … — so
            // every sink's output bytes match the serial path. The only
            // observable difference: `on_scenario_start` fires at replay
            // time, after the runs, not just before each run starts.
            let runs = Self::run_scenarios_parallel(ctx, &self.scenarios, jobs);
            for (scenario, run) in self.scenarios.iter().zip(runs) {
                let run = run?;
                for obs in self.observers.iter_mut() {
                    obs.on_scenario_start(scenario)?;
                }
                for obs in self.observers.iter_mut() {
                    obs.on_scenario_end(scenario, &run)?;
                }
                results.push(ScenarioResult {
                    name: scenario.name.clone(),
                    cfg: scenario.cfg.clone(),
                    run,
                });
            }
        } else {
            // Serial: fail-fast, hooks fire as each scenario runs.
            for scenario in &self.scenarios {
                for obs in self.observers.iter_mut() {
                    obs.on_scenario_start(scenario)?;
                }
                crate::info!("running {} ({} rounds)...", scenario.name, scenario.cfg.rounds);
                let run = fl::run_with_context(ctx, &scenario.cfg)?;
                for obs in self.observers.iter_mut() {
                    obs.on_scenario_end(scenario, &run)?;
                }
                results.push(ScenarioResult {
                    name: scenario.name.clone(),
                    cfg: scenario.cfg.clone(),
                    run,
                });
            }
        }
        for obs in self.observers.iter_mut() {
            obs.on_campaign_end(&results)?;
        }
        Ok(results)
    }

    /// Fan the scenarios out over `jobs` worker threads sharing `ctx`
    /// (native backend: `TrainContext` is `Sync` and the train pool
    /// accepts concurrent batches). Work-steals by atomic index so long
    /// and short scenarios pack; results land in declaration order.
    ///
    /// Fail-fast is approximate: a failed scenario stops workers from
    /// *claiming* further scenarios (in-flight ones finish), and since
    /// indices are claimed monotonically every unclaimed slot sits
    /// strictly after some failed one — the replay loop therefore always
    /// surfaces a real error, never a skipped-scenario placeholder.
    fn run_scenarios_parallel(
        ctx: &TrainContext,
        scenarios: &[Scenario],
        jobs: usize,
    ) -> Vec<Result<RunResult>> {
        let next = AtomicUsize::new(0);
        let failed = AtomicBool::new(false);
        let slots: Vec<Mutex<Option<Result<RunResult>>>> =
            scenarios.iter().map(|_| Mutex::new(None)).collect();
        std::thread::scope(|scope| {
            for _ in 0..jobs {
                scope.spawn(|| loop {
                    if failed.load(Ordering::Relaxed) {
                        break;
                    }
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    let Some(scenario) = scenarios.get(i) else {
                        break;
                    };
                    crate::info!(
                        "running {} ({} rounds)...",
                        scenario.name,
                        scenario.cfg.rounds
                    );
                    let run = fl::run_with_context(ctx, &scenario.cfg);
                    if run.is_err() {
                        failed.store(true, Ordering::Relaxed);
                    }
                    *slots[i].lock().unwrap_or_else(|e| e.into_inner()) = Some(run);
                });
            }
        });
        slots
            .into_iter()
            .map(|slot| {
                slot.into_inner().unwrap_or_else(|e| e.into_inner()).unwrap_or_else(|| {
                    Err(anyhow::anyhow!(
                        "scenario skipped: an earlier scenario failed"
                    ))
                })
            })
            .collect()
    }
}

/// Which per-round series a [`CurvesCsv`] sink extracts.
#[derive(Debug, Clone, Copy)]
pub enum CurveKind {
    /// Test accuracy at evaluated rounds.
    Accuracy,
    /// Probe-loss gap `F(w^r) − F(w*)`.
    LossGap {
        f_star: f64,
    },
}

/// Observer writing one `series,round,time_s,value` CSV with a curve per
/// scenario, in declaration order.
pub struct CurvesCsv {
    path: PathBuf,
    kind: CurveKind,
}

impl CurvesCsv {
    pub fn accuracy(path: impl Into<PathBuf>) -> Self {
        Self { path: path.into(), kind: CurveKind::Accuracy }
    }

    pub fn loss_gap(path: impl Into<PathBuf>, f_star: f64) -> Self {
        Self { path: path.into(), kind: CurveKind::LossGap { f_star } }
    }
}

impl RunObserver for CurvesCsv {
    fn on_campaign_end(&mut self, results: &[ScenarioResult]) -> Result<()> {
        let curves: Vec<Curve> = results
            .iter()
            .map(|r| match self.kind {
                CurveKind::Accuracy => Curve::accuracy(&r.name, &r.run),
                CurveKind::LossGap { f_star } => Curve::loss_gap(&r.name, &r.run, f_star),
            })
            .collect();
        write_curves_csv(&self.path, &curves)
    }
}

/// Observer writing one full per-round telemetry CSV per scenario, named
/// `{prefix}_{algorithm}.csv` under `dir`.
pub struct RecordsCsv {
    dir: PathBuf,
    prefix: String,
}

impl RecordsCsv {
    pub fn new(dir: impl Into<PathBuf>, prefix: impl Into<String>) -> Self {
        Self { dir: dir.into(), prefix: prefix.into() }
    }

    fn path_for(&self, scenario: &Scenario) -> PathBuf {
        records_csv_path(&self.dir, &self.prefix, scenario.cfg.algorithm.name())
    }
}

impl RunObserver for RecordsCsv {
    fn on_scenario_end(&mut self, scenario: &Scenario, run: &RunResult) -> Result<()> {
        write_records_csv(&self.path_for(scenario), run)
    }
}

/// The records-CSV path a [`RecordsCsv`] sink writes for an algorithm —
/// the single definition of the `{prefix}_{algorithm}.csv` scheme.
pub fn records_csv_path(dir: &Path, prefix: &str, algorithm: &str) -> PathBuf {
    dir.join(format!("{prefix}_{algorithm}.csv"))
}

/// One axis of a [`Campaign::grid`] product: an ordered list of labeled
/// config deltas. Compose axes freely; the named constructors cover the
/// common dimensions (algorithms, seed replicates, channel-noise levels).
#[derive(Default)]
pub struct GridAxis {
    variants: Vec<(String, Box<dyn Fn(&mut Config)>)>,
}

impl GridAxis {
    /// An empty axis (add variants with [`GridAxis::variant`]). An axis
    /// left empty annihilates the product — zero scenarios.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append one labeled delta.
    pub fn variant(
        mut self,
        label: impl Into<String>,
        delta: impl Fn(&mut Config) + 'static,
    ) -> Self {
        self.variants.push((label.into(), Box::new(delta)));
        self
    }

    /// An axis over registered algorithms, labeled by their registry
    /// labels. Errors on names no factory claims.
    pub fn algorithms(names: &[&str]) -> Result<Self> {
        let mut axis = Self::new();
        for name in names {
            let algo = Algorithm::parse(name)?;
            let label = crate::fl::registry::label(algo.name());
            axis = axis.variant(label, move |c: &mut Config| c.algorithm = algo.clone());
        }
        Ok(axis)
    }

    /// A seed-replicate axis (labels `seed=<n>`, the convention
    /// [`replicate_key`] recognizes). Campaign contexts are shared, so
    /// replicates re-run the *training* streams on fixed data.
    pub fn seeds(seeds: &[u64]) -> Self {
        let mut axis = Self::new();
        for &seed in seeds {
            axis = axis.variant(format!("seed={seed}"), move |c: &mut Config| c.seed = seed);
        }
        axis
    }

    /// A channel-noise axis (labels `n0=<dBm/Hz>`).
    pub fn noise_levels(n0s: &[f64]) -> Self {
        let mut axis = Self::new();
        for &n0 in n0s {
            axis = axis.variant(format!("n0={n0}"), move |c: &mut Config| {
                c.channel.n0_dbm_per_hz = n0
            });
        }
        axis
    }
}

/// The replicate-grouping key of a scenario name: the name with any
/// `seed=<...>` segments (as produced by [`GridAxis::seeds`]) removed, so
/// `"PAOTA|n0=-74|seed=43"` and `"PAOTA|n0=-74|seed=44"` aggregate
/// together. A name that is *only* a seed label collapses to
/// `"replicates"`.
pub fn replicate_key(name: &str) -> String {
    let kept: Vec<&str> = name
        .split('|')
        .map(str::trim)
        .filter(|part| !part.starts_with("seed="))
        .collect();
    if kept.is_empty() {
        "replicates".to_string()
    } else {
        kept.join("|")
    }
}

/// Observer aggregating seed replicates into **mean ± std curves** — one
/// `series,round,time_s,mean,std,n` CSV row per replicate group and
/// evaluated round (std = sample standard deviation, 0 for n = 1).
/// Groups are scenario names modulo their `seed=<n>` segment
/// ([`replicate_key`]); pair with [`Campaign::grid`] + [`GridAxis::seeds`].
pub struct MeanStdCurves {
    path: PathBuf,
    kind: CurveKind,
}

impl MeanStdCurves {
    pub fn accuracy(path: impl Into<PathBuf>) -> Self {
        Self { path: path.into(), kind: CurveKind::Accuracy }
    }

    pub fn loss_gap(path: impl Into<PathBuf>, f_star: f64) -> Self {
        Self { path: path.into(), kind: CurveKind::LossGap { f_star } }
    }
}

impl RunObserver for MeanStdCurves {
    fn on_campaign_end(&mut self, results: &[ScenarioResult]) -> Result<()> {
        // Group curves by replicate key, preserving first-seen order.
        let mut order: Vec<String> = Vec::new();
        let mut groups: std::collections::HashMap<String, Vec<Curve>> =
            std::collections::HashMap::new();
        for r in results {
            let curve = match self.kind {
                CurveKind::Accuracy => Curve::accuracy(&r.name, &r.run),
                CurveKind::LossGap { f_star } => Curve::loss_gap(&r.name, &r.run, f_star),
            };
            let key = replicate_key(&r.name);
            if !groups.contains_key(&key) {
                order.push(key.clone());
            }
            groups.entry(key).or_default().push(curve);
        }

        let mut rows = Vec::new();
        for key in &order {
            let curves = &groups[key];
            let mut rounds: Vec<usize> = curves
                .iter()
                .flat_map(|c| c.points.iter().map(|p| p.0))
                .collect();
            rounds.sort_unstable();
            rounds.dedup();
            for round in rounds {
                let mut vals = Vec::new();
                let mut time_sum = 0.0f64;
                for c in curves {
                    if let Some(p) = c.points.iter().find(|p| p.0 == round) {
                        vals.push(p.2);
                        time_sum += p.1;
                    }
                }
                let n = vals.len();
                let mean = vals.iter().sum::<f64>() / n as f64;
                let std = if n > 1 {
                    (vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>()
                        / (n as f64 - 1.0))
                        .sqrt()
                } else {
                    0.0
                };
                rows.push(format!(
                    "{key},{round},{:.1},{mean:.6},{std:.6},{n}",
                    time_sum / n as f64
                ));
            }
        }
        write_csv_lines(&self.path, "series,round,time_s,mean,std,n", rows)
    }
}

/// The config fields a [`TrainContext`] is built from. Scenarios sharing
/// a campaign context must agree on all of them.
fn context_fingerprint(cfg: &Config) -> String {
    format!("{:?}|{:?}|{:?}", cfg.partition, cfg.synth, cfg.artifacts_dir)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Algorithm;
    use crate::fl::RoundRecord;
    use crate::runtime::EvalOut;

    fn fake_result(name: &str, algo: &str, acc: f32) -> ScenarioResult {
        let mut cfg = Config::default();
        cfg.algorithm = Algorithm::raw(algo);
        ScenarioResult {
            name: name.to_string(),
            cfg,
            run: RunResult {
                algorithm: Algorithm::raw(algo),
                records: vec![RoundRecord {
                    round: 0,
                    sim_time: 8.0,
                    train_loss: 1.0,
                    probe_loss: Some(2.0),
                    eval: Some(EvalOut { loss: 1.5, accuracy: acc }),
                    participants: 3,
                    mean_staleness: 0.5,
                    mean_power: 1.0,
                }],
                final_weights: vec![],
            },
        }
    }

    #[test]
    fn curves_csv_sink_writes_scenarios_in_order() {
        let dir = std::env::temp_dir().join("paota_campaign_test");
        let path = dir.join("curves.csv");
        let results = vec![
            fake_result("B-first", "paota", 0.5),
            fake_result("A-second", "cotaf", 0.7),
        ];
        let mut sink = CurvesCsv::accuracy(&path);
        sink.on_campaign_end(&results).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "series,round,time_s,value");
        assert!(lines[1].starts_with("B-first,0,"), "{}", lines[1]);
        assert!(lines[2].starts_with("A-second,0,"), "{}", lines[2]);
    }

    #[test]
    fn records_csv_sink_names_files_by_algorithm() {
        let dir = std::env::temp_dir().join("paota_campaign_test");
        let r = fake_result("PAOTA", "paota", 0.6);
        let scenario = Scenario::from_config(r.name.clone(), r.cfg.clone());
        let mut sink = RecordsCsv::new(&dir, "figX");
        sink.on_scenario_end(&scenario, &r.run).unwrap();
        let want = records_csv_path(&dir, "figX", "paota");
        let text = std::fs::read_to_string(want).unwrap();
        assert!(text.starts_with("round,time_s,train_loss"));
        assert_eq!(text.lines().count(), 2);
    }

    #[test]
    fn scenario_delta_applies_to_base_copy() {
        let base = Config::default();
        let s = Scenario::new("more rounds", &base, |c| c.rounds = 123);
        assert_eq!(s.cfg.rounds, 123);
        assert_eq!(base.rounds, Config::default().rounds);
    }

    #[test]
    fn grid_builds_the_full_product_in_order() {
        let campaign = Campaign::new("grid", Config::default()).grid(vec![
            GridAxis::algorithms(&["paota", "cotaf"]).unwrap(),
            GridAxis::noise_levels(&[-174.0, -74.0]),
            GridAxis::seeds(&[1, 2]),
        ]);
        let names: Vec<&str> = campaign.scenarios.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names.len(), 8);
        assert_eq!(names[0], "PAOTA|n0=-174|seed=1");
        assert_eq!(names[1], "PAOTA|n0=-174|seed=2");
        assert_eq!(names[7], "COTAF|n0=-74|seed=2");
        let s = &campaign.scenarios[7];
        assert_eq!(s.cfg.algorithm.name(), "cotaf");
        assert_eq!(s.cfg.channel.n0_dbm_per_hz, -74.0);
        assert_eq!(s.cfg.seed, 2);
        // Unknown algorithm names fail at declaration time.
        assert!(GridAxis::algorithms(&["nope"]).is_err());
    }

    #[test]
    fn replicate_key_strips_only_seed_segments() {
        assert_eq!(replicate_key("PAOTA|n0=-74|seed=43"), "PAOTA|n0=-74");
        assert_eq!(replicate_key("PAOTA"), "PAOTA");
        assert_eq!(replicate_key("seed=7"), "replicates");
        assert_eq!(replicate_key("a|seed=1|b"), "a|b");
    }

    #[test]
    fn mean_std_curves_aggregate_replicates() {
        let dir = std::env::temp_dir().join("paota_meanstd_test");
        let path = dir.join("meanstd.csv");
        let results = vec![
            fake_result("PAOTA|seed=1", "paota", 0.5),
            fake_result("PAOTA|seed=2", "paota", 0.7),
            fake_result("COTAF|seed=1", "cotaf", 0.4),
        ];
        let mut sink = MeanStdCurves::accuracy(&path);
        sink.on_campaign_end(&results).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "series,round,time_s,mean,std,n");
        // mean(0.5, 0.7) = 0.6, sample std = 0.1414..., n = 2.
        assert!(lines[1].starts_with("PAOTA,0,8.0,0.600000,0.141421,2"), "{}", lines[1]);
        assert!(lines[2].starts_with("COTAF,0,8.0,0.400000,0.000000,1"), "{}", lines[2]);
    }

    fn tiny_native_base() -> Config {
        let mut base = Config::default();
        base.artifacts_dir = "native".into();
        base.synth.side = 6;
        base.partition.clients = 4;
        base.partition.sizes = vec![20];
        base.partition.test_size = 12;
        base
    }

    #[test]
    fn campaign_validates_scenario_configs_up_front() {
        // An invalid delta (rounds = 0) must fail before any run starts —
        // even as the SECOND scenario, so no partial artifacts are left.
        let _ = std::fs::remove_dir_all(std::env::temp_dir().join("paota_campaign_nowrite"));
        let campaign = Campaign::new("bad", tiny_native_base())
            .scenario("fine", |_| {})
            .scenario("broken", |c| c.rounds = 0)
            .observe(RecordsCsv::new(
                std::env::temp_dir().join("paota_campaign_nowrite"),
                "never",
            ));
        assert!(campaign.run().is_err());
        let leaked = records_csv_path(
            &std::env::temp_dir().join("paota_campaign_nowrite"),
            "never",
            "paota",
        );
        assert!(!leaked.exists(), "a run executed before validation finished");
    }

    #[test]
    fn campaign_rejects_context_changing_deltas() {
        // The shared context is built from the base config; a scenario
        // that alters what the context was built from must be refused.
        let campaign = Campaign::new("bad", tiny_native_base())
            .scenario("more clients", |c| c.partition.clients = 50);
        let err = campaign.run().unwrap_err().to_string();
        assert!(err.contains("context-defining"), "{err}");
        // A seed-only delta is allowed (seed replicates on fixed data).
        let ok = Campaign::new("ok", tiny_native_base()).scenario("seed 7", |c| c.seed = 7);
        assert!(ok.run().is_ok());
    }
}
