//! # PAOTA — Semi-Asynchronous Federated Edge Learning via AirComp
//!
//! A production-grade reproduction of *"Semi-Asynchronous Federated Edge
//! Learning for Over-the-air Computation"* (Kou, Ji, Zhong, Zhang; 2023) as
//! a three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the paper's coordination contribution, built
//!   around one discrete-event core: [`fl::coordinator::Coordinator`] owns
//!   the virtual clock, the client-arrival event queue, per-client
//!   base-model slots, deterministic per-purpose RNG streams, the AirComp
//!   aggregation buffers, and the telemetry recorder; every algorithm —
//!   PAOTA itself plus the baselines (ideal Local SGD, COTAF, pooled-data
//!   SGD) and the FedAsync extension — is an
//!   [`fl::coordinator::AggregationPolicy`] that only decides participant
//!   selection, aggregation weights/powers (for PAOTA: the Dinkelbach
//!   fractional program over the convergence bound of Theorem 1, see
//!   [`power`]), and its round timing (synchronous, periodic, or
//!   continuous). The wireless MAC channel simulator lives in
//!   [`channel`]; device heterogeneity in [`sim`].
//! * **L2/L1 (build time)** — the learning workload (MLP fwd/bwd, local SGD,
//!   AirComp reduction) authored in JAX + Pallas and AOT-lowered to HLO-text
//!   artifacts which [`runtime`] loads through PJRT. Python never runs at
//!   request time.
//!
//! Start at [`fl`] for the coordinator/policy architecture, [`power`] for
//! the paper's power control, and `examples/quickstart.rs` for a minimal
//! end-to-end run.

pub mod runtime;
pub mod util;
pub mod linalg;
pub mod optim;
pub mod testing;
pub mod channel;
pub mod config;
pub mod data;
pub mod power;
pub mod sim;
pub mod fl;
pub mod metrics;
pub mod obs;
pub mod cli;
pub mod experiments;
pub mod benchlib;
