//! # PAOTA — Semi-Asynchronous Federated Edge Learning via AirComp
//!
//! A production-grade reproduction of *"Semi-Asynchronous Federated Edge
//! Learning for Over-the-air Computation"* (Kou, Ji, Zhong, Zhang; 2023) as
//! a three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the paper's coordination contribution: a
//!   time-triggered, semi-asynchronous FL server with over-the-air (AirComp)
//!   aggregation, per-round uplink power-control optimization (Dinkelbach
//!   fractional programming over the convergence bound of Theorem 1), a
//!   discrete-event device simulator and a wireless MAC channel simulator,
//!   plus the paper's baselines (ideal Local SGD, COTAF).
//! * **L2/L1 (build time)** — the learning workload (MLP fwd/bwd, local SGD,
//!   AirComp reduction) authored in JAX + Pallas and AOT-lowered to HLO-text
//!   artifacts which [`runtime`] loads through PJRT. Python never runs at
//!   request time.
//!
//! Start at [`fl`] for the training loops, [`power`] for the paper's power
//! control, and `examples/quickstart.rs` for a minimal end-to-end run.

pub mod runtime;
pub mod util;
pub mod linalg;
pub mod optim;
pub mod testing;
pub mod channel;
pub mod config;
pub mod data;
pub mod power;
pub mod sim;
pub mod fl;
pub mod metrics;
pub mod cli;
pub mod experiments;
pub mod benchlib;
