//! Dense row-major f64 matrix + the three factorizations the optimizer uses.

use anyhow::{bail, Result};

/// Dense row-major matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    /// Identity.
    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// From row-major data.
    pub fn from_rows(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Self { rows, cols, data }
    }

    /// Diagonal matrix from a vector.
    pub fn diag(d: &[f64]) -> Self {
        let mut m = Self::zeros(d.len(), d.len());
        for (i, &v) in d.iter().enumerate() {
            m[(i, i)] = v;
        }
        m
    }

    /// Rank-1 outer product `a·bᵀ`.
    pub fn outer(a: &[f64], b: &[f64]) -> Self {
        let mut m = Self::zeros(a.len(), b.len());
        for i in 0..a.len() {
            for j in 0..b.len() {
                m[(i, j)] = a[i] * b[j];
            }
        }
        m
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Transpose.
    pub fn t(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    /// Matrix product.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                for j in 0..other.cols {
                    out[(i, j)] += a * other[(k, j)];
                }
            }
        }
        out
    }

    /// Matrix–vector product.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len(), "matvec shape mismatch");
        (0..self.rows)
            .map(|i| (0..self.cols).map(|j| self[(i, j)] * v[j]).sum())
            .collect()
    }

    /// Quadratic form `xᵀ A x`.
    pub fn quad_form(&self, x: &[f64]) -> f64 {
        let ax = self.matvec(x);
        x.iter().zip(&ax).map(|(a, b)| a * b).sum()
    }

    /// Sum with `alpha`-scaled other: `self + alpha·other`.
    pub fn add_scaled(&self, other: &Matrix, alpha: f64) -> Matrix {
        assert!(self.rows == other.rows && self.cols == other.cols);
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a + alpha * b)
            .collect();
        Matrix::from_rows(self.rows, self.cols, data)
    }

    /// Max |a_ij − b_ij|.
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Inverse via LU with partial pivoting. Errors on singularity.
    pub fn inverse(&self) -> Result<Matrix> {
        assert_eq!(self.rows, self.cols);
        let n = self.rows;
        let mut inv = Matrix::zeros(n, n);
        let lu = LuFactors::new(self)?;
        let mut e = vec![0.0; n];
        for j in 0..n {
            e.iter_mut().for_each(|v| *v = 0.0);
            e[j] = 1.0;
            let col = lu.solve(&e);
            for i in 0..n {
                inv[(i, j)] = col[i];
            }
        }
        Ok(inv)
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

/// Cholesky factorization `A = L·Lᵀ` (lower-triangular `L`).
///
/// The Dinkelbach transform needs the nonsingular `M₁` with `G = M₁ᵀM₁`;
/// that is `M₁ = Lᵀ`. Errors when `A` is not (numerically) positive
/// definite — the caller regularizes with `+εI` as the paper's `G` is only
/// guaranteed positive *semi*-definite.
pub fn cholesky(a: &Matrix) -> Result<Matrix> {
    assert_eq!(a.rows(), a.cols());
    let n = a.rows();
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[(i, j)];
            for k in 0..j {
                sum -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                if sum <= 0.0 {
                    bail!("matrix not positive definite at pivot {i} (sum={sum})");
                }
                l[(i, j)] = sum.sqrt();
            } else {
                l[(i, j)] = sum / l[(j, j)];
            }
        }
    }
    Ok(l)
}

/// LU factorization with partial pivoting (Doolittle).
struct LuFactors {
    n: usize,
    lu: Matrix,
    piv: Vec<usize>,
}

impl LuFactors {
    fn new(a: &Matrix) -> Result<Self> {
        assert_eq!(a.rows(), a.cols());
        let n = a.rows();
        let mut lu = a.clone();
        let mut piv: Vec<usize> = (0..n).collect();
        for col in 0..n {
            // Pivot.
            let mut pivot = col;
            let mut max = lu[(col, col)].abs();
            for r in col + 1..n {
                if lu[(r, col)].abs() > max {
                    max = lu[(r, col)].abs();
                    pivot = r;
                }
            }
            if max < 1e-14 {
                bail!("singular matrix at column {col}");
            }
            if pivot != col {
                for j in 0..n {
                    let tmp = lu[(col, j)];
                    lu[(col, j)] = lu[(pivot, j)];
                    lu[(pivot, j)] = tmp;
                }
                piv.swap(col, pivot);
            }
            // Eliminate.
            for r in col + 1..n {
                let f = lu[(r, col)] / lu[(col, col)];
                lu[(r, col)] = f;
                for j in col + 1..n {
                    let v = lu[(col, j)];
                    lu[(r, j)] -= f * v;
                }
            }
        }
        Ok(Self { n, lu, piv })
    }

    fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.n;
        // Apply permutation, then forward/back substitution.
        let mut y: Vec<f64> = (0..n).map(|i| b[self.piv[i]]).collect();
        for i in 1..n {
            for j in 0..i {
                y[i] -= self.lu[(i, j)] * y[j];
            }
        }
        for i in (0..n).rev() {
            for j in i + 1..n {
                y[i] -= self.lu[(i, j)] * y[j];
            }
            y[i] /= self.lu[(i, i)];
        }
        y
    }
}

/// Solve `A x = b` by LU with partial pivoting.
pub fn lu_solve(a: &Matrix, b: &[f64]) -> Result<Vec<f64>> {
    Ok(LuFactors::new(a)?.solve(b))
}

/// Cyclic Jacobi eigendecomposition of a symmetric matrix.
///
/// Returns `(eigenvalues, V)` with `A = V·diag(λ)·Vᵀ` and orthonormal
/// columns in `V` — i.e. `M₂ = V` satisfies `M₂ᵀAM₂ = diag(λ)` (eq. (29)).
pub fn jacobi_eigen(a: &Matrix, max_sweeps: usize) -> (Vec<f64>, Matrix) {
    assert_eq!(a.rows(), a.cols());
    let n = a.rows();
    let mut m = a.clone();
    let mut v = Matrix::eye(n);

    for _sweep in 0..max_sweeps {
        // Off-diagonal Frobenius mass.
        let mut off = 0.0;
        for i in 0..n {
            for j in i + 1..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if off.sqrt() < 1e-12 {
            break;
        }
        for p in 0..n {
            for q in p + 1..n {
                let apq = m[(p, q)];
                if apq.abs() < 1e-15 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Rotate rows/cols p, q of m.
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                // Accumulate eigenvectors.
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }
    let eig = (0..n).map(|i| m[(i, i)]).collect();
    (eig, v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, prop_assert, prop_close};
    use crate::util::Rng;

    fn random_spd(rng: &mut Rng, n: usize) -> Matrix {
        // AᵀA + n·I is SPD.
        let mut a = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                a[(i, j)] = rng.normal();
            }
        }
        let mut spd = a.t().matmul(&a);
        for i in 0..n {
            spd[(i, i)] += n as f64;
        }
        spd
    }

    fn random_symmetric(rng: &mut Rng, n: usize) -> Matrix {
        let mut a = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let v = rng.normal();
                a[(i, j)] = v;
                a[(j, i)] = v;
            }
        }
        a
    }

    #[test]
    fn matmul_matvec_agree() {
        let a = Matrix::from_rows(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let x = vec![1.0, 0.5, -1.0];
        let got = a.matvec(&x);
        assert_eq!(got, vec![1.0 + 1.0 - 3.0, 4.0 + 2.5 - 6.0]);
    }

    #[test]
    fn transpose_involution() {
        let a = Matrix::from_rows(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(a.t().t(), a);
    }

    #[test]
    fn quad_form_matches_manual() {
        let a = Matrix::from_rows(2, 2, vec![2.0, 1.0, 1.0, 3.0]);
        let x = vec![1.0, -1.0];
        // xᵀAx = 2 - 1 - 1 + 3 = 3.
        assert!((a.quad_form(&x) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn cholesky_reconstructs() {
        check("cholesky LLᵀ = A", 40, |g| {
            let n = g.usize_in(1..9);
            let a = random_spd(g.rng(), n);
            let l = cholesky(&a).map_err(|e| e.to_string())?;
            let rec = l.matmul(&l.t());
            prop_close(rec.max_abs_diff(&a), 0.0, 1e-8, "reconstruction")
        });
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Matrix::from_rows(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eig −1, 3
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn lu_solve_random_systems() {
        check("LU solves Ax=b", 40, |g| {
            let n = g.usize_in(1..9);
            let a = random_spd(g.rng(), n);
            let x_true: Vec<f64> = (0..n).map(|_| g.rng().normal()).collect();
            let b = a.matvec(&x_true);
            let x = lu_solve(&a, &b).map_err(|e| e.to_string())?;
            for i in 0..n {
                prop_close(x[i], x_true[i], 1e-7, "solution")?;
            }
            Ok(())
        });
    }

    #[test]
    fn inverse_times_self_is_identity() {
        check("A·A⁻¹ = I", 30, |g| {
            let n = g.usize_in(1..7);
            let a = random_spd(g.rng(), n);
            let inv = a.inverse().map_err(|e| e.to_string())?;
            let prod = a.matmul(&inv);
            prop_close(prod.max_abs_diff(&Matrix::eye(n)), 0.0, 1e-7, "identity")
        });
    }

    #[test]
    fn lu_rejects_singular() {
        let a = Matrix::from_rows(2, 2, vec![1.0, 2.0, 2.0, 4.0]);
        assert!(lu_solve(&a, &[1.0, 1.0]).is_err());
    }

    #[test]
    fn jacobi_diagonalizes() {
        check("VᵀAV diagonal, V orthogonal", 30, |g| {
            let n = g.usize_in(1..8);
            let a = random_symmetric(g.rng(), n);
            let (eig, v) = jacobi_eigen(&a, 50);
            // V orthogonal.
            let vtv = v.t().matmul(&v);
            prop_close(vtv.max_abs_diff(&Matrix::eye(n)), 0.0, 1e-8, "orthogonality")?;
            // Reconstruction A = V diag V^T.
            let rec = v.matmul(&Matrix::diag(&eig)).matmul(&v.t());
            prop_close(rec.max_abs_diff(&a), 0.0, 1e-7, "reconstruction")?;
            Ok(())
        });
    }

    #[test]
    fn jacobi_known_eigenvalues() {
        // [[2,1],[1,2]] has eigenvalues 1 and 3.
        let a = Matrix::from_rows(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let (mut eig, _) = jacobi_eigen(&a, 50);
        eig.sort_by(|x, y| x.partial_cmp(y).unwrap());
        assert!((eig[0] - 1.0).abs() < 1e-10);
        assert!((eig[1] - 3.0).abs() < 1e-10);
    }

    #[test]
    fn spd_eigen_all_positive() {
        check("SPD spectra positive", 20, |g| {
            let n = g.usize_in(1..7);
            let a = random_spd(g.rng(), n);
            let (eig, _) = jacobi_eigen(&a, 60);
            prop_assert(eig.iter().all(|&l| l > 0.0), "nonpositive eigenvalue")
        });
    }

    #[test]
    fn outer_and_diag() {
        let o = Matrix::outer(&[1.0, 2.0], &[3.0, 4.0, 5.0]);
        assert_eq!(o[(1, 2)], 10.0);
        let d = Matrix::diag(&[7.0, 8.0]);
        assert_eq!(d[(0, 0)], 7.0);
        assert_eq!(d[(0, 1)], 0.0);
    }
}
