//! Dense linear algebra substrate (f64), hand-rolled for the offline build.
//!
//! Exactly what the paper's power-control pipeline (§III-B) needs:
//!
//! * [`Matrix`] — small dense row-major matrix with the usual ops.
//! * [`cholesky`] — `G = LLᵀ`, giving the nonsingular `M₁ = Lᵀ` with
//!   `G = M₁ᵀM₁` used by the Dinkelbach transform (eq. (28)).
//! * [`jacobi_eigen`] — cyclic Jacobi eigendecomposition of a symmetric
//!   matrix, giving the orthogonal `M₂` with `M₂ᵀSM₂ = N = diag(nᵢ)`
//!   (eq. (29)).
//! * [`lu_solve`] / [`Matrix::inverse`] — for `M⁻¹z` in problem P4.

pub mod matrix;

pub use matrix::{cholesky, jacobi_eigen, lu_solve, Matrix};
