//! Dense linear algebra substrate, hand-rolled for the offline build.
//!
//! Two layers live here:
//!
//! * **f64 factorizations** ([`matrix`]) — exactly what the paper's
//!   power-control pipeline (§III-B) needs:
//!   * [`Matrix`] — small dense row-major matrix with the usual ops.
//!   * [`cholesky`] — `G = LLᵀ`, giving the nonsingular `M₁ = Lᵀ` with
//!     `G = M₁ᵀM₁` used by the Dinkelbach transform (eq. (28)).
//!   * [`jacobi_eigen`] — cyclic Jacobi eigendecomposition of a symmetric
//!     matrix, giving the orthogonal `M₂` with `M₂ᵀSM₂ = N = diag(nᵢ)`
//!     (eq. (29)).
//!   * [`lu_solve`] / [`Matrix::inverse`] — for `M⁻¹z` in problem P4.
//!
//! * **f32 GEMM kernels** ([`gemm`]) — the register-tiled, zero-alloc
//!   affine/gradient/backprop routines behind the native model backend's
//!   hot path, blocked over output rows/columns only so results stay
//!   bit-identical to the naive triple loops (see the module docs for
//!   the "tile i/j, never k" contract).

pub mod gemm;
pub mod matrix;

pub use matrix::{cholesky, jacobi_eigen, lu_solve, Matrix};
