//! Register-tiled f32 GEMM kernels for the native model backend
//! (`runtime::native`) — the hot path of every `local_train`, `evaluate`
//! and `grad_probe` call when `artifacts_dir = native`.
//!
//! # The bitwise-determinism contract: tile i/j, never k
//!
//! Every routine here computes exactly the same floating-point result,
//! bit for bit, as the naive triple loop it replaced. That works because
//! blocking is applied **only to output rows and columns** — independent
//! elements — while each output element's reduction runs over its k
//! (respectively i or j) index in the original ascending order, one
//! partial sum per element, never split into tiles that would be
//! re-combined. Splitting a reduction reassociates floating-point
//! addition and changes low bits; splitting the *outputs* cannot.
//! Concretely:
//!
//! * [`affine_into`]: `out[i][j]` accumulates `x[i][k]·w[k][j]` with k
//!   ascending. Rows are processed four at a time so each `w` row is
//!   loaded once per row block instead of once per row (¼ the memory
//!   traffic on the skinny paper-geometry matrices), but the four rows
//!   are four *independent* accumulators.
//! * [`grad_affine_acc`]: `gw[k][j]` accumulates `a[i][k]·dz[i][j]` with
//!   i ascending. The i-reduction is register-blocked four rows at a
//!   time, and inside a block the four contributions are added to the
//!   accumulator **sequentially in i order** (`t += c0; t += c1; …`,
//!   never a pairwise tree), so the addition order is untouched.
//! * [`backprop_relu_into`]: `dx[i][k]` reduces `dz[i][j]·w[k][j]` with
//!   j ascending; four k-outputs share one pass over the `dz` row.
//!
//! The naive kernels also skipped multiply-accumulates whose left factor
//! was exactly `0.0` (ReLU activations are ~half zeros, and `+= 0.0·w`
//! is not a bitwise no-op on a `-0.0` accumulator). The blocked paths
//! preserve those skips: a block whose four lane factors are all nonzero
//! takes the branch-free fast path; any zero lane falls back to per-lane
//! guarded updates in the same lane order.
//!
//! All routines write into **caller-provided buffers** — no allocation
//! here; `runtime::native` owns per-thread scratch so steady-state
//! training allocates nothing in the kernel.

/// Dense affine map `out[n, d_out] = x[n, d_in] · w[d_in, d_out] + b`,
/// with `w` row-major by input dimension (fan-in convention). `out` is
/// fully overwritten.
pub fn affine_into(
    out: &mut [f32],
    x: &[f32],
    w: &[f32],
    b: &[f32],
    n: usize,
    d_in: usize,
    d_out: usize,
) {
    assert_eq!(out.len(), n * d_out, "affine_into: out shape");
    assert_eq!(x.len(), n * d_in, "affine_into: x shape");
    assert_eq!(w.len(), d_in * d_out, "affine_into: w shape");
    assert_eq!(b.len(), d_out, "affine_into: b shape");
    if n == 0 || d_out == 0 {
        return;
    }
    if d_in == 0 {
        for row in out.chunks_exact_mut(d_out) {
            row.copy_from_slice(b);
        }
        return;
    }

    let nb = n - n % 4;
    let (x_blocks, x_tail) = x.split_at(nb * d_in);
    let (out_blocks, out_tail) = out.split_at_mut(nb * d_out);
    for (xb, ob) in x_blocks
        .chunks_exact(4 * d_in)
        .zip(out_blocks.chunks_exact_mut(4 * d_out))
    {
        let (x0, xr) = xb.split_at(d_in);
        let (x1, xr) = xr.split_at(d_in);
        let (x2, x3) = xr.split_at(d_in);
        let (r0, or) = ob.split_at_mut(d_out);
        let (r1, or) = or.split_at_mut(d_out);
        let (r2, r3) = or.split_at_mut(d_out);
        r0.copy_from_slice(b);
        r1.copy_from_slice(b);
        r2.copy_from_slice(b);
        r3.copy_from_slice(b);
        for (k, wr) in w.chunks_exact(d_out).enumerate() {
            let (v0, v1, v2, v3) = (x0[k], x1[k], x2[k], x3[k]);
            if v0 != 0.0 && v1 != 0.0 && v2 != 0.0 && v3 != 0.0 {
                // Four independent output rows share one pass over wr.
                for ((((o0, o1), o2), o3), &wv) in r0
                    .iter_mut()
                    .zip(r1.iter_mut())
                    .zip(r2.iter_mut())
                    .zip(r3.iter_mut())
                    .zip(wr)
                {
                    *o0 += v0 * wv;
                    *o1 += v1 * wv;
                    *o2 += v2 * wv;
                    *o3 += v3 * wv;
                }
            } else {
                axpy_nonzero(r0, v0, wr);
                axpy_nonzero(r1, v1, wr);
                axpy_nonzero(r2, v2, wr);
                axpy_nonzero(r3, v3, wr);
            }
        }
    }
    // Remainder rows: the original single-row loop.
    for (xr_, orow) in x_tail
        .chunks_exact(d_in)
        .zip(out_tail.chunks_exact_mut(d_out))
    {
        orow.copy_from_slice(b);
        for (k, wr) in w.chunks_exact(d_out).enumerate() {
            axpy_nonzero(orow, xr_[k], wr);
        }
    }
}

/// `row += v · wr` unless `v == 0.0` (the naive kernels' skip, kept for
/// bit-identity on `-0.0` accumulators and for ReLU-sparse inputs).
#[inline]
fn axpy_nonzero(row: &mut [f32], v: f32, wr: &[f32]) {
    if v != 0.0 {
        for (o, &wv) in row.iter_mut().zip(wr) {
            *o += v * wv;
        }
    }
}

/// Accumulate the affine-layer weight/bias gradients:
/// `gw[d_in, d_out] += aᵀ·dz` and `gb[d_out] += Σ_i dz[i]`, with the
/// i-reduction of every output element running in ascending i order.
pub fn grad_affine_acc(
    gw: &mut [f32],
    gb: &mut [f32],
    a: &[f32],
    dz: &[f32],
    n: usize,
    d_in: usize,
    d_out: usize,
) {
    assert_eq!(gw.len(), d_in * d_out, "grad_affine_acc: gw shape");
    assert_eq!(gb.len(), d_out, "grad_affine_acc: gb shape");
    assert_eq!(a.len(), n * d_in, "grad_affine_acc: a shape");
    assert_eq!(dz.len(), n * d_out, "grad_affine_acc: dz shape");
    if n == 0 || d_out == 0 {
        return;
    }

    // Bias gradient: i ascending (separated from the weight loop; the
    // per-element accumulation order is identical to the fused original).
    for dr in dz.chunks_exact(d_out) {
        for (g, &dv) in gb.iter_mut().zip(dr) {
            *g += dv;
        }
    }
    if d_in == 0 {
        return;
    }

    let nb = n - n % 4;
    for (ab, db) in a[..nb * d_in]
        .chunks_exact(4 * d_in)
        .zip(dz[..nb * d_out].chunks_exact(4 * d_out))
    {
        let (a0, ar) = ab.split_at(d_in);
        let (a1, ar) = ar.split_at(d_in);
        let (a2, a3) = ar.split_at(d_in);
        let (d0, dr) = db.split_at(d_out);
        let (d1, dr) = dr.split_at(d_out);
        let (d2, d3) = dr.split_at(d_out);
        for (k, gr) in gw.chunks_exact_mut(d_out).enumerate() {
            let (v0, v1, v2, v3) = (a0[k], a1[k], a2[k], a3[k]);
            if v0 != 0.0 && v1 != 0.0 && v2 != 0.0 && v3 != 0.0 {
                // One load/store of gr for four i contributions, added
                // sequentially in i order (no pairwise tree).
                for ((((g, &c0), &c1), &c2), &c3) in
                    gr.iter_mut().zip(d0).zip(d1).zip(d2).zip(d3)
                {
                    let mut t = *g;
                    t += v0 * c0;
                    t += v1 * c1;
                    t += v2 * c2;
                    t += v3 * c3;
                    *g = t;
                }
            } else {
                axpy_nonzero(gr, v0, d0);
                axpy_nonzero(gr, v1, d1);
                axpy_nonzero(gr, v2, d2);
                axpy_nonzero(gr, v3, d3);
            }
        }
    }
    // Remainder rows, i ascending after the blocks.
    for (ar_, dr_) in a[nb * d_in..]
        .chunks_exact(d_in)
        .zip(dz[nb * d_out..].chunks_exact(d_out))
    {
        for (k, gr) in gw.chunks_exact_mut(d_out).enumerate() {
            axpy_nonzero(gr, ar_[k], dr_);
        }
    }
}

/// Backprop through an affine layer and its preceding ReLU:
/// `dx[n, d_in] = (dz[n, d_out] · wᵀ) ⊙ (a > 0)`, where `a` is the ReLU
/// *output* that fed the layer. `dx` is fully overwritten (masked
/// entries get `0.0`); each dot product runs over j ascending.
pub fn backprop_relu_into(
    dx: &mut [f32],
    dz: &[f32],
    w: &[f32],
    a: &[f32],
    n: usize,
    d_in: usize,
    d_out: usize,
) {
    assert_eq!(dx.len(), n * d_in, "backprop_relu_into: dx shape");
    assert_eq!(dz.len(), n * d_out, "backprop_relu_into: dz shape");
    assert_eq!(w.len(), d_in * d_out, "backprop_relu_into: w shape");
    assert_eq!(a.len(), n * d_in, "backprop_relu_into: a shape");
    if n == 0 || d_in == 0 {
        return;
    }
    if d_out == 0 {
        // Empty reduction: every (masked or not) entry is exactly 0.0.
        dx.iter_mut().for_each(|v| *v = 0.0);
        return;
    }

    let kb = d_in - d_in % 4;
    for ((xrow, arow), dr) in dx
        .chunks_exact_mut(d_in)
        .zip(a.chunks_exact(d_in))
        .zip(dz.chunks_exact(d_out))
    {
        let (xblk, xtail) = xrow.split_at_mut(kb);
        let (ablk, atail) = arow.split_at(kb);
        // Four k-outputs share one pass over the dz row.
        for ((x4, a4), w4) in xblk
            .chunks_exact_mut(4)
            .zip(ablk.chunks_exact(4))
            .zip(w[..kb * d_out].chunks_exact(4 * d_out))
        {
            let (w0, wr) = w4.split_at(d_out);
            let (w1, wr) = wr.split_at(d_out);
            let (w2, w3) = wr.split_at(d_out);
            let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            for ((((&dv, &u0), &u1), &u2), &u3) in dr.iter().zip(w0).zip(w1).zip(w2).zip(w3) {
                s0 += dv * u0;
                s1 += dv * u1;
                s2 += dv * u2;
                s3 += dv * u3;
            }
            x4[0] = if a4[0] <= 0.0 { 0.0 } else { s0 };
            x4[1] = if a4[1] <= 0.0 { 0.0 } else { s1 };
            x4[2] = if a4[2] <= 0.0 { 0.0 } else { s2 };
            x4[3] = if a4[3] <= 0.0 { 0.0 } else { s3 };
        }
        // Remainder outputs: the original per-element dot product.
        for ((x, &av), wr) in xtail
            .iter_mut()
            .zip(atail)
            .zip(w[kb * d_out..].chunks_exact(d_out))
        {
            if av <= 0.0 {
                *x = 0.0;
            } else {
                let mut acc = 0.0f32;
                for (&dv, &wv) in dr.iter().zip(wr) {
                    acc += dv * wv;
                }
                *x = acc;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    // Naive references — verbatim ports of the pre-tiling triple loops
    // the blocked kernels must match bit for bit.

    fn naive_affine(x: &[f32], w: &[f32], b: &[f32], n: usize, d_in: usize, d_out: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; n * d_out];
        for i in 0..n {
            let row = &mut out[i * d_out..(i + 1) * d_out];
            row.copy_from_slice(b);
            let xr = &x[i * d_in..(i + 1) * d_in];
            for (k, &xv) in xr.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let wr = &w[k * d_out..(k + 1) * d_out];
                for (o, &wv) in row.iter_mut().zip(wr) {
                    *o += xv * wv;
                }
            }
        }
        out
    }

    fn naive_grad_affine(
        a: &[f32],
        dz: &[f32],
        n: usize,
        d_in: usize,
        d_out: usize,
        gw: &mut [f32],
        gb: &mut [f32],
    ) {
        for i in 0..n {
            let ar = &a[i * d_in..(i + 1) * d_in];
            let dr = &dz[i * d_out..(i + 1) * d_out];
            for (g, &dv) in gb.iter_mut().zip(dr) {
                *g += dv;
            }
            for (k, &av) in ar.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let gr = &mut gw[k * d_out..(k + 1) * d_out];
                for (g, &dv) in gr.iter_mut().zip(dr) {
                    *g += av * dv;
                }
            }
        }
    }

    fn naive_backprop(
        dz: &[f32],
        w: &[f32],
        a: &[f32],
        n: usize,
        d_in: usize,
        d_out: usize,
    ) -> Vec<f32> {
        let mut dx = vec![0.0f32; n * d_in];
        for i in 0..n {
            let dr = &dz[i * d_out..(i + 1) * d_out];
            let ar = &a[i * d_in..(i + 1) * d_in];
            let xr = &mut dx[i * d_in..(i + 1) * d_in];
            for (k, x) in xr.iter_mut().enumerate() {
                if ar[k] <= 0.0 {
                    continue;
                }
                let wr = &w[k * d_out..(k + 1) * d_out];
                let mut acc = 0.0f32;
                for (&dv, &wv) in dr.iter().zip(wr) {
                    acc += dv * wv;
                }
                *x = acc;
            }
        }
        dx
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    /// Random case with zeros scattered in (the ReLU regime) plus a few
    /// `-0.0` bias entries to pin the skip semantics.
    fn case(n: usize, d_in: usize, d_out: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let mut x = vec![0.0f32; n * d_in];
        rng.fill_normal(&mut x, 1.0);
        for v in x.iter_mut() {
            if *v < -0.4 {
                *v = 0.0; // sparse lanes exercise the guarded path
            }
        }
        let mut w = vec![0.0f32; d_in * d_out];
        rng.fill_normal(&mut w, 0.5);
        let mut b = vec![0.0f32; d_out];
        rng.fill_normal(&mut b, 0.1);
        if !b.is_empty() {
            b[0] = -0.0;
        }
        (x, w, b)
    }

    #[test]
    fn affine_matches_naive_bitwise_over_odd_shapes() {
        for &(n, d_in, d_out) in &[(1, 3, 2), (4, 8, 10), (5, 7, 3), (9, 784, 10), (6, 1, 1)] {
            let (x, w, b) = case(n, d_in, d_out, 7 + n as u64);
            let want = naive_affine(&x, &w, &b, n, d_in, d_out);
            let mut got = vec![f32::NAN; n * d_out]; // must be fully overwritten
            affine_into(&mut got, &x, &w, &b, n, d_in, d_out);
            assert_eq!(bits(&got), bits(&want), "n={n} d_in={d_in} d_out={d_out}");
        }
    }

    #[test]
    fn grad_affine_matches_naive_bitwise() {
        for &(n, d_in, d_out) in &[(1, 2, 3), (4, 10, 10), (7, 784, 10), (8, 5, 4)] {
            let (a, _, _) = case(n, d_in, d_out, 31 + n as u64);
            let mut rng = Rng::new(91 + n as u64);
            let mut dz = vec![0.0f32; n * d_out];
            rng.fill_normal(&mut dz, 0.3);
            let mut gw_want = vec![0.0f32; d_in * d_out];
            let mut gb_want = vec![-0.0f32; d_out];
            naive_grad_affine(&a, &dz, n, d_in, d_out, &mut gw_want, &mut gb_want);
            let mut gw = vec![0.0f32; d_in * d_out];
            let mut gb = vec![-0.0f32; d_out];
            grad_affine_acc(&mut gw, &mut gb, &a, &dz, n, d_in, d_out);
            assert_eq!(bits(&gw), bits(&gw_want), "gw n={n} d_in={d_in} d_out={d_out}");
            assert_eq!(bits(&gb), bits(&gb_want), "gb n={n}");
        }
    }

    #[test]
    fn grad_affine_accumulates_on_top_of_existing_gradient() {
        let (a, _, _) = case(4, 6, 3, 5);
        let mut rng = Rng::new(6);
        let mut dz = vec![0.0f32; 4 * 3];
        rng.fill_normal(&mut dz, 0.3);
        let mut gw_want = vec![0.25f32; 6 * 3];
        let mut gb_want = vec![0.5f32; 3];
        naive_grad_affine(&a, &dz, 4, 6, 3, &mut gw_want, &mut gb_want);
        let mut gw = vec![0.25f32; 6 * 3];
        let mut gb = vec![0.5f32; 3];
        grad_affine_acc(&mut gw, &mut gb, &a, &dz, 4, 6, 3);
        assert_eq!(bits(&gw), bits(&gw_want));
        assert_eq!(bits(&gb), bits(&gb_want));
    }

    #[test]
    fn backprop_matches_naive_bitwise_and_masks_nonpositive() {
        for &(n, d_in, d_out) in &[(1, 4, 2), (3, 10, 10), (5, 9, 3), (2, 13, 7)] {
            let mut rng = Rng::new(17 + n as u64);
            let mut a = vec![0.0f32; n * d_in];
            rng.fill_normal(&mut a, 1.0);
            for v in a.iter_mut() {
                if *v < 0.0 {
                    *v = 0.0; // ReLU output: zeros must mask
                }
            }
            let mut dz = vec![0.0f32; n * d_out];
            rng.fill_normal(&mut dz, 0.4);
            let mut w = vec![0.0f32; d_in * d_out];
            rng.fill_normal(&mut w, 0.5);
            let want = naive_backprop(&dz, &w, &a, n, d_in, d_out);
            let mut got = vec![f32::NAN; n * d_in]; // fully overwritten incl. masked
            backprop_relu_into(&mut got, &dz, &w, &a, n, d_in, d_out);
            assert_eq!(bits(&got), bits(&want), "n={n} d_in={d_in} d_out={d_out}");
        }
    }

    #[test]
    fn empty_and_degenerate_shapes_are_safe() {
        let mut out: Vec<f32> = Vec::new();
        affine_into(&mut out, &[], &[], &[], 0, 0, 0);
        let mut gw: Vec<f32> = Vec::new();
        let mut gb: Vec<f32> = Vec::new();
        grad_affine_acc(&mut gw, &mut gb, &[], &[], 0, 0, 0);
        let mut dx: Vec<f32> = Vec::new();
        backprop_relu_into(&mut dx, &[], &[], &[], 0, 0, 0);
    }
}
