//! Parallel local-training pool (§Perf, L3).
//!
//! ~84% of a PAOTA round is the participants' `local_train` executions,
//! which are independent — but `PjRtClient` is `Rc`-backed (not `Send`),
//! so the pool spawns N worker threads that each build their *own* PJRT
//! engine and compile the `local_train` artifact once. Jobs are
//! distributed over a shared channel; results carry the submission index
//! so callers get deterministic, order-preserving output regardless of
//! completion order (bit-identical to the sequential path: each job's
//! numerics are self-contained).
//!
//! Worker count defaults to `min(available_parallelism, 8)`; set
//! `PAOTA_WORKERS=1` to force the sequential path (used by the perf bench
//! to measure the speedup).

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use super::artifacts::TrainOut;
use super::pjrt::{Engine, Input};

/// One local-training job.
struct Job {
    idx: usize,
    w: Vec<f32>,
    xs: Vec<f32>,
    ys: Vec<f32>,
    lr: f32,
}

/// Worker → caller result.
struct JobResult {
    idx: usize,
    out: Result<TrainOut>,
}

/// A pool of PJRT workers dedicated to the `local_train` artifact.
pub struct TrainPool {
    jobs: Sender<Job>,
    results: Receiver<JobResult>,
    workers: usize,
    _threads: Vec<std::thread::JoinHandle<()>>,
}

/// Geometry a worker needs to validate/shape inputs.
#[derive(Clone, Copy)]
struct Geom {
    dim: usize,
    local_steps: usize,
    batch: usize,
    d_in: usize,
    classes: usize,
}

impl TrainPool {
    /// Number of workers chosen for this machine (≥ 1).
    pub fn default_workers() -> usize {
        if let Ok(v) = std::env::var("PAOTA_WORKERS") {
            if let Ok(n) = v.parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get().min(8))
            .unwrap_or(1)
    }

    /// Spawn `workers` threads, each compiling `local_train.hlo.txt` from
    /// `artifacts_dir` on its own engine.
    pub fn new(artifacts_dir: &std::path::Path, workers: usize) -> Result<Self> {
        let manifest = super::Manifest::load(artifacts_dir)?;
        let geom = Geom {
            dim: manifest.dim,
            local_steps: manifest.local_steps,
            batch: manifest.batch,
            d_in: manifest.d_in,
            classes: manifest.classes,
        };
        let workers = workers.max(1);
        let (job_tx, job_rx) = channel::<Job>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let (res_tx, res_rx) = channel::<JobResult>();

        let mut threads = Vec::with_capacity(workers);
        let dir: PathBuf = artifacts_dir.to_path_buf();
        for worker_id in 0..workers {
            let job_rx = Arc::clone(&job_rx);
            let res_tx = res_tx.clone();
            let dir = dir.clone();
            let handle = std::thread::Builder::new()
                .name(format!("paota-train-{worker_id}"))
                .spawn(move || {
                    // Each worker owns its engine + executable.
                    let built = (|| -> Result<_> {
                        let engine = Engine::cpu()?;
                        let exe = engine
                            .load_hlo_text(&dir.join("local_train.hlo.txt"))
                            .context("pool worker compiling local_train")?;
                        Ok((engine, exe))
                    })();
                    let (_engine, exe) = match built {
                        Ok(pair) => pair,
                        Err(e) => {
                            // Surface the failure on the first job instead
                            // of dying silently.
                            while let Ok(job) = job_rx.lock().unwrap().recv() {
                                let _ = res_tx.send(JobResult {
                                    idx: job.idx,
                                    out: Err(anyhow::anyhow!(
                                        "pool worker failed to initialize: {e:#}"
                                    )),
                                });
                            }
                            return;
                        }
                    };
                    loop {
                        let job = match job_rx.lock().unwrap().recv() {
                            Ok(j) => j,
                            Err(_) => return, // pool dropped
                        };
                        let out = (|| -> Result<TrainOut> {
                            let lr_v = [job.lr];
                            let got = exe.run(&[
                                Input::new(&job.w, &[geom.dim as i64]),
                                Input::new(
                                    &job.xs,
                                    &[
                                        geom.local_steps as i64,
                                        geom.batch as i64,
                                        geom.d_in as i64,
                                    ],
                                ),
                                Input::new(
                                    &job.ys,
                                    &[
                                        geom.local_steps as i64,
                                        geom.batch as i64,
                                        geom.classes as i64,
                                    ],
                                ),
                                Input::new(&lr_v, &[]),
                            ])?;
                            anyhow::ensure!(got.len() == 2, "local_train arity");
                            let loss = *got[1]
                                .first()
                                .context("local_train loss scalar")?;
                            Ok(TrainOut {
                                weights: got.into_iter().next().unwrap(),
                                loss,
                            })
                        })();
                        if res_tx.send(JobResult { idx: job.idx, out }).is_err() {
                            return;
                        }
                    }
                })
                .context("spawning pool worker")?;
            threads.push(handle);
        }

        Ok(Self {
            jobs: job_tx,
            results: res_rx,
            workers,
            _threads: threads,
        })
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run a batch of local-training jobs; returns outputs in submission
    /// order. Inputs are `(w, xs, ys)` with the artifact's fixed shapes.
    pub fn run_batch(
        &self,
        jobs: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)>,
        lr: f32,
    ) -> Result<Vec<TrainOut>> {
        let n = jobs.len();
        for (idx, (w, xs, ys)) in jobs.into_iter().enumerate() {
            self.jobs
                .send(Job { idx, w, xs, ys, lr })
                .context("pool submit (workers died?)")?;
        }
        let mut out: Vec<Option<TrainOut>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let res = self.results.recv().context("pool collect")?;
            out[res.idx] = Some(res.out?);
        }
        Ok(out.into_iter().map(|o| o.unwrap()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ModelRuntime;
    use crate::util::Rng;

    fn artifacts() -> Option<std::path::PathBuf> {
        let dir = ModelRuntime::default_dir();
        if dir.join("manifest.txt").exists() {
            Some(dir)
        } else {
            eprintln!("SKIP: no artifacts");
            None
        }
    }

    fn job(m: &crate::runtime::Manifest, rng: &mut Rng) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut w = vec![0.0f32; m.dim];
        rng.fill_normal(&mut w, 0.05);
        let mut xs = vec![0.0f32; m.local_steps * m.batch * m.d_in];
        rng.fill_normal(&mut xs, 0.5);
        let mut ys = vec![0.0f32; m.local_steps * m.batch * m.classes];
        for r in 0..(m.local_steps * m.batch) {
            ys[r * m.classes + rng.index(m.classes)] = 1.0;
        }
        (w, xs, ys)
    }

    #[test]
    fn pool_matches_sequential_runtime_bitwise() {
        let Some(dir) = artifacts() else { return };
        let engine = Engine::cpu().unwrap();
        let rt = ModelRuntime::load(&engine, &dir).unwrap();
        let m = rt.manifest().clone();
        let pool = TrainPool::new(&dir, 3).unwrap();

        let mut rng = Rng::new(42);
        let jobs: Vec<_> = (0..7).map(|_| job(&m, &mut rng)).collect();
        let seq: Vec<TrainOut> = jobs
            .iter()
            .map(|(w, xs, ys)| rt.local_train(w, xs, ys, 0.1).unwrap())
            .collect();
        let par = pool.run_batch(jobs, 0.1).unwrap();
        assert_eq!(seq.len(), par.len());
        for (s, p) in seq.iter().zip(&par) {
            assert_eq!(s.loss, p.loss);
            assert_eq!(s.weights, p.weights);
        }
    }

    #[test]
    fn pool_preserves_submission_order() {
        let Some(dir) = artifacts() else { return };
        let engine = Engine::cpu().unwrap();
        let rt = ModelRuntime::load(&engine, &dir).unwrap();
        let m = rt.manifest().clone();
        let pool = TrainPool::new(&dir, 4).unwrap();

        // Jobs with distinct, recognizable losses (different label layouts).
        let mut rng = Rng::new(7);
        let jobs: Vec<_> = (0..8).map(|_| job(&m, &mut rng)).collect();
        let expect: Vec<f32> = jobs
            .iter()
            .map(|(w, xs, ys)| rt.local_train(w, xs, ys, 0.05).unwrap().loss)
            .collect();
        let got: Vec<f32> = pool
            .run_batch(jobs, 0.05)
            .unwrap()
            .into_iter()
            .map(|t| t.loss)
            .collect();
        assert_eq!(expect, got);
    }

    #[test]
    fn single_worker_pool_works() {
        let Some(dir) = artifacts() else { return };
        let pool = TrainPool::new(&dir, 1).unwrap();
        assert_eq!(pool.workers(), 1);
        let engine = Engine::cpu().unwrap();
        let rt = ModelRuntime::load(&engine, &dir).unwrap();
        let m = rt.manifest().clone();
        let mut rng = Rng::new(3);
        let out = pool.run_batch(vec![job(&m, &mut rng)], 0.1).unwrap();
        assert_eq!(out.len(), 1);
        assert!(out[0].loss.is_finite());
    }
}
