//! Backend-agnostic parallel local-training pool (§Perf, L3).
//!
//! ~84% of a PAOTA round is the participants' `local_train` executions,
//! which are independent. The pool spawns N worker threads, each owning
//! its **own backend instance**:
//!
//! * **PJRT** ([`TrainPool::pjrt`]) — `PjRtClient` is `Rc`-backed (not
//!   `Send`), so every worker builds its own engine and compiles the
//!   `local_train` artifact once (milliseconds at paper scale).
//! * **Native** ([`TrainPool::native`]) — the pure-Rust
//!   [`NativeModel`](super::NativeModel) is `Send + Sync` and carries
//!   only its geometry; each worker gets a clone and its own
//!   thread-local scratch buffers.
//!
//! # Execution model
//!
//! Jobs flow through one shared MPMC-style channel; each
//! [`TrainPool::run_batch`] call carries its **own reply channel**, so
//! the pool is safe to drive from several threads at once (parallel
//! campaign scenarios, concurrently stepped cells) without results
//! crossing between batches — `TrainPool` is `Sync`. Results carry the
//! submission index, so callers get deterministic, order-preserving
//! output regardless of completion order: the parallel path is
//! **bit-identical** to the sequential one because each job's numerics
//! are self-contained (covered by `tests/golden_seed.rs`).
//!
//! Worker count comes from the `[perf]` config section
//! (`Config::perf.workers`); its default is `PAOTA_WORKERS` or
//! `min(available_parallelism, 8)` — set `workers = 1` (or
//! `PAOTA_WORKERS=1`) to force the sequential path (the perf bench does,
//! to measure the speedup).

use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::artifacts::{Manifest, TrainOut};
use super::native::NativeModel;
use super::pjrt::{Engine, Exec, Input};
use crate::obs::metrics::{self, Counter, Gauge, Histogram};

/// One local-training job, with the reply channel of the batch it
/// belongs to.
struct Job {
    idx: usize,
    w: Vec<f32>,
    xs: Vec<f32>,
    ys: Vec<f32>,
    lr: f32,
    reply: Sender<JobResult>,
    /// Submission wall-clock stamp — queue-wait observability only,
    /// never feeds back into results or simulation time.
    enqueued: Instant,
}

/// Pool observability handles (global registry; wall-clock only, so the
/// numerics and the job schedule are untouched).
#[derive(Clone)]
struct PoolMetrics {
    jobs: Counter,
    queue_wait_ms: Histogram,
    exec_ms: Histogram,
    busy_workers: Gauge,
}

impl PoolMetrics {
    fn new() -> Self {
        let r = metrics::global();
        let bounds = [0.1, 0.5, 1.0, 5.0, 10.0, 50.0, 100.0, 500.0, 1000.0, 5000.0];
        Self {
            jobs: r.counter("paota_pool_jobs_total"),
            queue_wait_ms: r.histogram("paota_pool_queue_wait_ms", &bounds),
            exec_ms: r.histogram("paota_pool_exec_ms", &bounds),
            busy_workers: r.gauge("paota_pool_busy_workers"),
        }
    }
}

/// Worker → batch-owner result.
struct JobResult {
    idx: usize,
    out: Result<TrainOut>,
}

/// What a worker thread builds its model from.
#[derive(Clone)]
enum Backend {
    /// Compile `local_train.hlo.txt` from this directory on a fresh
    /// per-thread engine.
    Pjrt { dir: PathBuf, m: Manifest },
    /// Instantiate the in-process reference kernel at this geometry.
    Native(Manifest),
}

/// A worker's ready-to-run model.
enum WorkerModel {
    Pjrt {
        // Engine must outlive the executable it compiled.
        _engine: Engine,
        exe: Exec,
        m: Manifest,
    },
    Native(NativeModel),
}

impl WorkerModel {
    fn build(backend: Backend) -> Result<Self> {
        match backend {
            Backend::Native(m) => Ok(WorkerModel::Native(NativeModel::new(m))),
            Backend::Pjrt { dir, m } => {
                let engine = Engine::cpu()?;
                let exe = engine
                    .load_hlo_text(&dir.join("local_train.hlo.txt"))
                    .context("pool worker compiling local_train")?;
                Ok(WorkerModel::Pjrt {
                    _engine: engine,
                    exe,
                    m,
                })
            }
        }
    }

    fn train(&self, job: &Job) -> Result<TrainOut> {
        match self {
            WorkerModel::Native(nm) => nm.local_train(&job.w, &job.xs, &job.ys, job.lr),
            WorkerModel::Pjrt { exe, m, .. } => {
                let lr_v = [job.lr];
                let got = exe.run(&[
                    Input::new(&job.w, &[m.dim as i64]),
                    Input::new(
                        &job.xs,
                        &[m.local_steps as i64, m.batch as i64, m.d_in as i64],
                    ),
                    Input::new(
                        &job.ys,
                        &[m.local_steps as i64, m.batch as i64, m.classes as i64],
                    ),
                    Input::new(&lr_v, &[]),
                ])?;
                anyhow::ensure!(got.len() == 2, "local_train arity");
                let loss = *got[1].first().context("local_train loss scalar")?;
                Ok(TrainOut {
                    weights: got.into_iter().next().unwrap(),
                    loss,
                })
            }
        }
    }
}

/// A pool of worker threads dedicated to `local_train` jobs, on either
/// model backend. `Sync`: concurrent [`TrainPool::run_batch`] calls are
/// safe and never mix results.
pub struct TrainPool {
    jobs: Mutex<Sender<Job>>,
    workers: usize,
    _threads: Vec<std::thread::JoinHandle<()>>,
}

impl TrainPool {
    /// Default worker count for this machine (≥ 1): `PAOTA_WORKERS` if
    /// set, else `min(available_parallelism, 8)`. This seeds the `[perf]`
    /// config section's `workers` default.
    pub fn default_workers() -> usize {
        if let Ok(v) = std::env::var("PAOTA_WORKERS") {
            if let Ok(n) = v.parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get().min(8))
            .unwrap_or(1)
    }

    /// Spawn `workers` threads, each compiling `local_train.hlo.txt` from
    /// `artifacts_dir` on its own PJRT engine.
    pub fn pjrt(artifacts_dir: &Path, workers: usize) -> Result<Self> {
        let m = Manifest::load(artifacts_dir)?;
        Self::spawn(
            Backend::Pjrt {
                dir: artifacts_dir.to_path_buf(),
                m,
            },
            workers,
        )
    }

    /// Spawn `workers` threads on the pure-Rust reference kernel at the
    /// given geometry (no artifacts, no PJRT).
    pub fn native(manifest: Manifest, workers: usize) -> Result<Self> {
        manifest.validate()?;
        Self::spawn(Backend::Native(manifest), workers)
    }

    fn spawn(backend: Backend, workers: usize) -> Result<Self> {
        let workers = workers.max(1);
        let (job_tx, job_rx) = channel::<Job>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let mut threads = Vec::with_capacity(workers);
        let obs = PoolMetrics::new();
        for worker_id in 0..workers {
            let job_rx = Arc::clone(&job_rx);
            let backend = backend.clone();
            let obs = obs.clone();
            let handle = std::thread::Builder::new()
                .name(format!("paota-train-{worker_id}"))
                .spawn(move || worker_loop(backend, &job_rx, &obs))
                .context("spawning pool worker")?;
            threads.push(handle);
        }
        Ok(Self {
            jobs: Mutex::new(job_tx),
            workers,
            _threads: threads,
        })
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run a batch of local-training jobs; returns outputs in submission
    /// order, bit-identical to running them sequentially. Inputs are
    /// `(w, xs, ys)` with the backend's fixed shapes. Callable
    /// concurrently from several threads: every batch collects on its
    /// own private reply channel.
    pub fn run_batch(
        &self,
        jobs: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)>,
        lr: f32,
    ) -> Result<Vec<TrainOut>> {
        let n = jobs.len();
        let (reply_tx, reply_rx) = channel::<JobResult>();
        {
            let tx = self.jobs.lock().unwrap_or_else(|e| e.into_inner());
            for (idx, (w, xs, ys)) in jobs.into_iter().enumerate() {
                tx.send(Job {
                    idx,
                    w,
                    xs,
                    ys,
                    lr,
                    reply: reply_tx.clone(),
                    enqueued: Instant::now(),
                })
                .map_err(|_| anyhow!("pool submit (workers died?)"))?;
            }
        }
        drop(reply_tx);
        let mut out: Vec<Option<TrainOut>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let res = reply_rx.recv().context("pool collect (worker died?)")?;
            out[res.idx] = Some(res.out?);
        }
        Ok(out.into_iter().map(|o| o.expect("every index replied")).collect())
    }
}

/// Worker body: build the backend model once, then serve jobs until the
/// pool (the job sender) is dropped. A failed build surfaces the error on
/// every subsequently received job instead of dying silently.
fn worker_loop(backend: Backend, jobs: &Mutex<Receiver<Job>>, obs: &PoolMetrics) {
    let recv = || -> Option<Job> {
        jobs.lock().unwrap_or_else(|e| e.into_inner()).recv().ok()
    };
    let model = match WorkerModel::build(backend) {
        Ok(model) => model,
        Err(e) => {
            let msg = format!("pool worker failed to initialize: {e:#}");
            while let Some(job) = recv() {
                let _ = job.reply.send(JobResult {
                    idx: job.idx,
                    out: Err(anyhow!("{msg}")),
                });
            }
            return;
        }
    };
    while let Some(job) = recv() {
        obs.jobs.inc();
        obs.queue_wait_ms
            .observe(job.enqueued.elapsed().as_secs_f64() * 1e3);
        obs.busy_workers.add(1);
        let started = Instant::now();
        let out = model.train(&job);
        obs.exec_ms.observe(started.elapsed().as_secs_f64() * 1e3);
        obs.busy_workers.add(-1);
        // A dropped reply receiver means that batch's owner bailed early
        // (e.g. on another job's error) — keep serving other batches.
        let _ = job.reply.send(JobResult { idx: job.idx, out });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ModelRuntime;
    use crate::util::Rng;

    fn artifacts() -> Option<std::path::PathBuf> {
        let dir = ModelRuntime::default_dir();
        if dir.join("manifest.txt").exists() {
            Some(dir)
        } else {
            eprintln!("SKIP: no artifacts");
            None
        }
    }

    fn job(m: &Manifest, rng: &mut Rng) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut w = vec![0.0f32; m.dim];
        rng.fill_normal(&mut w, 0.05);
        let mut xs = vec![0.0f32; m.local_steps * m.batch * m.d_in];
        rng.fill_normal(&mut xs, 0.5);
        let mut ys = vec![0.0f32; m.local_steps * m.batch * m.classes];
        for r in 0..(m.local_steps * m.batch) {
            ys[r * m.classes + rng.index(m.classes)] = 1.0;
        }
        (w, xs, ys)
    }

    fn tiny_manifest() -> Manifest {
        let (d, h, c) = (6usize, 10usize, 3usize);
        Manifest {
            d_in: d,
            hidden: h,
            classes: c,
            dim: d * h + h + h * h + h + h * c + c,
            local_steps: 2,
            batch: 4,
            clients: 5,
            eval_size: 6,
            probe_batch: 4,
        }
    }

    #[test]
    fn pool_matches_sequential_runtime_bitwise() {
        let Some(dir) = artifacts() else { return };
        let engine = Engine::cpu().unwrap();
        let rt = ModelRuntime::load(&engine, &dir).unwrap();
        let m = rt.manifest().clone();
        let pool = TrainPool::pjrt(&dir, 3).unwrap();

        let mut rng = Rng::new(42);
        let jobs: Vec<_> = (0..7).map(|_| job(&m, &mut rng)).collect();
        let seq: Vec<TrainOut> = jobs
            .iter()
            .map(|(w, xs, ys)| rt.local_train(w, xs, ys, 0.1).unwrap())
            .collect();
        let par = pool.run_batch(jobs, 0.1).unwrap();
        assert_eq!(seq.len(), par.len());
        for (s, p) in seq.iter().zip(&par) {
            assert_eq!(s.loss, p.loss);
            assert_eq!(s.weights, p.weights);
        }
    }

    #[test]
    fn pool_preserves_submission_order() {
        let Some(dir) = artifacts() else { return };
        let engine = Engine::cpu().unwrap();
        let rt = ModelRuntime::load(&engine, &dir).unwrap();
        let m = rt.manifest().clone();
        let pool = TrainPool::pjrt(&dir, 4).unwrap();

        // Jobs with distinct, recognizable losses (different label layouts).
        let mut rng = Rng::new(7);
        let jobs: Vec<_> = (0..8).map(|_| job(&m, &mut rng)).collect();
        let expect: Vec<f32> = jobs
            .iter()
            .map(|(w, xs, ys)| rt.local_train(w, xs, ys, 0.05).unwrap().loss)
            .collect();
        let got: Vec<f32> = pool
            .run_batch(jobs, 0.05)
            .unwrap()
            .into_iter()
            .map(|t| t.loss)
            .collect();
        assert_eq!(expect, got);
    }

    #[test]
    fn single_worker_pool_works() {
        let Some(dir) = artifacts() else { return };
        let pool = TrainPool::pjrt(&dir, 1).unwrap();
        assert_eq!(pool.workers(), 1);
        let engine = Engine::cpu().unwrap();
        let rt = ModelRuntime::load(&engine, &dir).unwrap();
        let m = rt.manifest().clone();
        let mut rng = Rng::new(3);
        let out = pool.run_batch(vec![job(&m, &mut rng)], 0.1).unwrap();
        assert_eq!(out.len(), 1);
        assert!(out[0].loss.is_finite());
    }

    #[test]
    fn native_pool_matches_sequential_kernel_bitwise() {
        // Runs everywhere (no artifacts needed): the native backend of
        // the same pool must be bit-identical to in-line execution.
        let m = tiny_manifest();
        let nm = NativeModel::new(m.clone());
        let pool = TrainPool::native(m.clone(), 3).unwrap();
        let mut rng = Rng::new(11);
        let jobs: Vec<_> = (0..9).map(|_| job(&m, &mut rng)).collect();
        let seq: Vec<TrainOut> = jobs
            .iter()
            .map(|(w, xs, ys)| nm.local_train(w, xs, ys, 0.1).unwrap())
            .collect();
        let par = pool.run_batch(jobs, 0.1).unwrap();
        assert_eq!(seq.len(), par.len());
        for (s, p) in seq.iter().zip(&par) {
            assert_eq!(s.loss.to_bits(), p.loss.to_bits());
            let same = s
                .weights
                .iter()
                .zip(&p.weights)
                .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "pool path drifted from the sequential kernel");
        }
    }

    #[test]
    fn native_pool_serves_concurrent_batches_without_crossing() {
        // Two threads drive the SAME pool at once; each batch must get
        // exactly its own results (per-batch reply channels).
        let m = tiny_manifest();
        let nm = NativeModel::new(m.clone());
        let pool = TrainPool::native(m.clone(), 2).unwrap();
        let mut rng_a = Rng::new(100);
        let mut rng_b = Rng::new(200);
        let jobs_a: Vec<_> = (0..6).map(|_| job(&m, &mut rng_a)).collect();
        let jobs_b: Vec<_> = (0..5).map(|_| job(&m, &mut rng_b)).collect();
        let want_a: Vec<f32> = jobs_a
            .iter()
            .map(|(w, xs, ys)| nm.local_train(w, xs, ys, 0.1).unwrap().loss)
            .collect();
        let want_b: Vec<f32> = jobs_b
            .iter()
            .map(|(w, xs, ys)| nm.local_train(w, xs, ys, 0.1).unwrap().loss)
            .collect();
        let (got_a, got_b) = std::thread::scope(|s| {
            let ha = s.spawn(|| pool.run_batch(jobs_a, 0.1).unwrap());
            let hb = s.spawn(|| pool.run_batch(jobs_b, 0.1).unwrap());
            (ha.join().unwrap(), hb.join().unwrap())
        });
        let losses = |v: Vec<TrainOut>| v.into_iter().map(|t| t.loss).collect::<Vec<_>>();
        assert_eq!(losses(got_a), want_a);
        assert_eq!(losses(got_b), want_b);
    }

    #[test]
    fn train_pool_is_sync() {
        fn assert_sync<T: Sync + Send>() {}
        assert_sync::<TrainPool>();
    }

    #[test]
    fn pool_metrics_count_jobs_without_changing_results() {
        // Global-registry metrics: other tests bump the same counters
        // concurrently, so assert deltas, never absolutes.
        let m = tiny_manifest();
        let nm = NativeModel::new(m.clone());
        let pool = TrainPool::native(m.clone(), 2).unwrap();
        let jobs_before = crate::obs::metrics::global()
            .counter("paota_pool_jobs_total")
            .get();
        let waits_before = crate::obs::metrics::global()
            .histogram("paota_pool_queue_wait_ms", &[1.0])
            .count();
        let mut rng = Rng::new(21);
        let jobs: Vec<_> = (0..4).map(|_| job(&m, &mut rng)).collect();
        let want: Vec<f32> = jobs
            .iter()
            .map(|(w, xs, ys)| nm.local_train(w, xs, ys, 0.1).unwrap().loss)
            .collect();
        let got: Vec<f32> = pool
            .run_batch(jobs, 0.1)
            .unwrap()
            .into_iter()
            .map(|t| t.loss)
            .collect();
        assert_eq!(want, got, "instrumentation must not perturb results");
        let jobs_after = crate::obs::metrics::global()
            .counter("paota_pool_jobs_total")
            .get();
        let waits_after = crate::obs::metrics::global()
            .histogram("paota_pool_queue_wait_ms", &[1.0])
            .count();
        assert!(jobs_after >= jobs_before + 4, "{jobs_before} -> {jobs_after}");
        assert!(waits_after >= waits_before + 4, "{waits_before} -> {waits_after}");
    }
}
