//! Artifact manifest + the typed model runtime the FL layer drives.
//!
//! `make artifacts` writes `artifacts/manifest.txt` (key=value geometry) and
//! four HLO-text entry points; [`ModelRuntime`] loads all of them on one
//! [`Engine`](super::Engine) and exposes the paper's operations with plain
//! slices:
//!
//! * [`ModelRuntime::local_train`] — M-step local SGD (paper eq. (3)/(4)).
//! * [`ModelRuntime::evaluate`]    — test loss + accuracy.
//! * [`ModelRuntime::aggregate`]   — AirComp superposition + normalization
//!   (eq. (6)+(8)); the weighted sum is the L1 Pallas kernel.
//! * [`ModelRuntime::grad_probe`]  — one full-batch gradient (diagnostics).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::pjrt::{Engine, Exec, Input};

/// Geometry of the AOT artifacts — parsed from `artifacts/manifest.txt`,
/// the single source of truth shared with `python/compile/aot.py`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Manifest {
    /// Input feature dimension (paper: 784).
    pub d_in: usize,
    /// Hidden width of both hidden layers (paper: 10).
    pub hidden: usize,
    /// Number of classes (paper: 10).
    pub classes: usize,
    /// Flat parameter count (paper model: 8070).
    pub dim: usize,
    /// M — local SGD steps per round (paper: 5).
    pub local_steps: usize,
    /// Local minibatch size.
    pub batch: usize,
    /// K — client rows in the aggregate artifact (paper: 100).
    pub clients: usize,
    /// Evaluation set size baked into `evaluate.hlo.txt`.
    pub eval_size: usize,
    /// Batch size of the `grad_probe` artifact.
    pub probe_batch: usize,
}

impl Manifest {
    /// Parse `manifest.txt` (lines of `key=value`; `#` comments).
    pub fn parse(text: &str) -> Result<Self> {
        let mut kv = HashMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("manifest line without '=': {line:?}"))?;
            kv.insert(k.trim().to_string(), v.trim().to_string());
        }
        let get = |key: &str| -> Result<usize> {
            kv.get(key)
                .with_context(|| format!("manifest missing key {key:?}"))?
                .parse::<usize>()
                .with_context(|| format!("manifest key {key:?} not an integer"))
        };
        let m = Self {
            d_in: get("d_in")?,
            hidden: get("hidden")?,
            classes: get("classes")?,
            dim: get("dim")?,
            local_steps: get("local_steps")?,
            batch: get("batch")?,
            clients: get("clients")?,
            eval_size: get("eval_size")?,
            probe_batch: get("probe_batch")?,
        };
        m.validate()?;
        Ok(m)
    }

    /// Load from `dir/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    /// Internal consistency: flat dim must match the MLP geometry.
    pub fn validate(&self) -> Result<()> {
        let want = self.d_in * self.hidden
            + self.hidden
            + self.hidden * self.hidden
            + self.hidden
            + self.hidden * self.classes
            + self.classes;
        if want != self.dim {
            bail!(
                "manifest dim {} inconsistent with geometry ({} expected)",
                self.dim,
                want
            );
        }
        if self.local_steps == 0 || self.batch == 0 || self.clients == 0 {
            bail!("manifest has zero-sized geometry");
        }
        Ok(())
    }
}

/// Result of one local training call.
#[derive(Debug, Clone)]
pub struct TrainOut {
    /// Updated flat model after M local SGD steps.
    pub weights: Vec<f32>,
    /// Mean of the M minibatch losses.
    pub loss: f32,
}

/// Result of one evaluation call.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvalOut {
    /// Mean softmax-CE loss over the eval set.
    pub loss: f32,
    /// Fraction of correct predictions in `[0, 1]`.
    pub accuracy: f32,
}

/// The four compiled PJRT entry points. `!Send` (the executables are
/// `Rc`-backed), hence held behind [`super::ThreadBound`] so the runtime
/// — and everything holding it — is `Sync` while PJRT use stays pinned
/// to its creating thread.
struct PjrtExecs {
    local_train: Exec,
    evaluate: Exec,
    aggregate: Exec,
    grad_probe: Exec,
}

/// The execution backend behind a [`ModelRuntime`]: AOT-compiled PJRT
/// executables (the default) or the pure-Rust reference kernel
/// ([`super::native`], selected with `artifacts_dir = native`).
enum Backend {
    Pjrt(super::ThreadBound<PjrtExecs>),
    Native(super::native::NativeModel),
}

/// All four model entry points plus their geometry.
pub struct ModelRuntime {
    manifest: Manifest,
    backend: Backend,
}

impl ModelRuntime {
    /// Load and compile every artifact in `dir` on `engine`.
    pub fn load(engine: &Engine, dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let load = |name: &str| -> Result<Exec> {
            engine.load_hlo_text(&dir.join(format!("{name}.hlo.txt")))
        };
        Ok(Self {
            manifest,
            backend: Backend::Pjrt(super::ThreadBound::new(PjrtExecs {
                local_train: load("local_train")?,
                evaluate: load("evaluate")?,
                aggregate: load("aggregate")?,
                grad_probe: load("grad_probe")?,
            })),
        })
    }

    /// A runtime on the pure-Rust reference kernel with explicit geometry
    /// (no artifacts, no PJRT).
    pub fn native(manifest: Manifest) -> Result<Self> {
        manifest.validate()?;
        Ok(Self {
            backend: Backend::Native(super::native::NativeModel::new(manifest.clone())),
            manifest,
        })
    }

    /// A native-kernel runtime whose geometry is derived from `cfg` (the
    /// paper's hidden width and local-step/batch shape, the config's data
    /// dimensions) — what `artifacts_dir = native` resolves to.
    pub fn native_for(cfg: &crate::config::Config) -> Result<Self> {
        let (d_in, hidden, classes) = (cfg.synth.dim(), 10usize, cfg.synth.classes);
        let manifest = Manifest {
            d_in,
            hidden,
            classes,
            dim: d_in * hidden + hidden + hidden * hidden + hidden + hidden * classes + classes,
            local_steps: 5,
            batch: 32,
            clients: cfg.partition.clients,
            eval_size: cfg.partition.test_size,
            probe_batch: 256,
        };
        Self::native(manifest)
    }

    /// Default artifact directory: `$PAOTA_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("PAOTA_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Whether this runtime runs the pure-Rust reference kernel. The
    /// native backend is `Send + Sync`; the parallel campaign and
    /// multi-cell paths require it (PJRT is pinned to its creating
    /// thread) and fall back to serial execution otherwise.
    pub fn is_native(&self) -> bool {
        matches!(self.backend, Backend::Native(_))
    }

    /// M local SGD steps: `w ← w − η ∇F_k(w; D_k^τ)` for τ = 1..M.
    ///
    /// `xs` is `[M, B, d_in]` flat, `ys` is `[M, B, classes]` flat one-hot.
    pub fn local_train(&self, w: &[f32], xs: &[f32], ys: &[f32], lr: f32) -> Result<TrainOut> {
        let m = &self.manifest;
        let (ms, b) = (m.local_steps as i64, m.batch as i64);
        self.check_len("local_train.w", w, m.dim)?;
        self.check_len("local_train.xs", xs, m.local_steps * m.batch * m.d_in)?;
        self.check_len("local_train.ys", ys, m.local_steps * m.batch * m.classes)?;
        let exec = match &self.backend {
            Backend::Native(nm) => return nm.local_train(w, xs, ys, lr),
            Backend::Pjrt(execs) => &execs.get().local_train,
        };
        let lr_v = [lr];
        let out = exec.run(&[
            Input::new(w, &[m.dim as i64]),
            Input::new(xs, &[ms, b, m.d_in as i64]),
            Input::new(ys, &[ms, b, m.classes as i64]),
            Input::new(&lr_v, &[]),
        ])?;
        let [weights, loss] = take2(out, "local_train")?;
        Ok(TrainOut {
            weights,
            loss: scalar(&loss, "local_train.loss")?,
        })
    }

    /// Evaluate on the baked eval set shape `[eval_size, d_in]`.
    pub fn evaluate(&self, w: &[f32], x: &[f32], y: &[f32]) -> Result<EvalOut> {
        let m = &self.manifest;
        self.check_len("evaluate.w", w, m.dim)?;
        self.check_len("evaluate.x", x, m.eval_size * m.d_in)?;
        self.check_len("evaluate.y", y, m.eval_size * m.classes)?;
        let exec = match &self.backend {
            Backend::Native(nm) => return nm.evaluate(w, x, y),
            Backend::Pjrt(execs) => &execs.get().evaluate,
        };
        let out = exec.run(&[
            Input::new(w, &[m.dim as i64]),
            Input::new(x, &[m.eval_size as i64, m.d_in as i64]),
            Input::new(y, &[m.eval_size as i64, m.classes as i64]),
        ])?;
        let [loss, correct] = take2(out, "evaluate")?;
        Ok(EvalOut {
            loss: scalar(&loss, "evaluate.loss")?,
            accuracy: scalar(&correct, "evaluate.correct")? / m.eval_size as f32,
        })
    }

    /// AirComp aggregation: `w_g = (coefᵀ·W + n) / Σ coef` over the full
    /// K-row stack (rows with `coef == 0` are non-participants).
    pub fn aggregate(&self, w_stack: &[f32], coef: &[f32], noise: &[f32]) -> Result<Vec<f32>> {
        let m = &self.manifest;
        self.check_len("aggregate.w_stack", w_stack, m.clients * m.dim)?;
        self.check_len("aggregate.coef", coef, m.clients)?;
        self.check_len("aggregate.noise", noise, m.dim)?;
        let exec = match &self.backend {
            Backend::Native(nm) => return nm.aggregate(w_stack, coef, noise),
            Backend::Pjrt(execs) => &execs.get().aggregate,
        };
        let out = exec.run(&[
            Input::new(w_stack, &[m.clients as i64, m.dim as i64]),
            Input::new(coef, &[m.clients as i64]),
            Input::new(noise, &[m.dim as i64]),
        ])?;
        let [w_g] = take1(out, "aggregate")?;
        Ok(w_g)
    }

    /// AirComp aggregation over a **participant-only** row stack:
    /// `rows` holds `coef.len()` packed rows of `dim` (no fleet-sized
    /// buffer), the kernel computes `(coefᵀ·rows + noise) / Σ coef`.
    ///
    /// This is the fleet-scale entry point: the coordinator packs only
    /// the round's participants (in ascending client order), so buffer
    /// memory scales with the cohort instead of K. The native kernel is
    /// row-count-agnostic and is called directly; the AOT PJRT program
    /// is compiled for a fixed `[K, dim]` stack, so rows are scattered
    /// into the leading slots of a zero stack — zero-coefficient rows
    /// contribute exact `+0.0` terms, leaving the result bitwise equal.
    pub fn aggregate_rows(&self, rows: &[f32], coef: &[f32], noise: &[f32]) -> Result<Vec<f32>> {
        let m = &self.manifest;
        self.check_len("aggregate_rows.rows", rows, coef.len() * m.dim)?;
        self.check_len("aggregate_rows.noise", noise, m.dim)?;
        if coef.len() > m.clients {
            bail!(
                "aggregate_rows: {} rows exceed the compiled fleet size {}",
                coef.len(),
                m.clients
            );
        }
        match &self.backend {
            Backend::Native(nm) => nm.aggregate(rows, coef, noise),
            Backend::Pjrt(_) => {
                let mut stack = vec![0.0f32; m.clients * m.dim];
                let mut full_coef = vec![0.0f32; m.clients];
                stack[..rows.len()].copy_from_slice(rows);
                full_coef[..coef.len()].copy_from_slice(coef);
                self.aggregate(&stack, &full_coef, noise)
            }
        }
    }

    /// One full-batch gradient over `[probe_batch, d_in]`.
    pub fn grad_probe(&self, w: &[f32], x: &[f32], y: &[f32]) -> Result<Vec<f32>> {
        let m = &self.manifest;
        self.check_len("grad_probe.w", w, m.dim)?;
        self.check_len("grad_probe.x", x, m.probe_batch * m.d_in)?;
        self.check_len("grad_probe.y", y, m.probe_batch * m.classes)?;
        let exec = match &self.backend {
            Backend::Native(nm) => return nm.grad_probe(w, x, y),
            Backend::Pjrt(execs) => &execs.get().grad_probe,
        };
        let out = exec.run(&[
            Input::new(w, &[m.dim as i64]),
            Input::new(x, &[m.probe_batch as i64, m.d_in as i64]),
            Input::new(y, &[m.probe_batch as i64, m.classes as i64]),
        ])?;
        let [g] = take1(out, "grad_probe")?;
        Ok(g)
    }

    fn check_len(&self, what: &str, data: &[f32], want: usize) -> Result<()> {
        if data.len() != want {
            bail!("{what}: expected {want} elements, got {}", data.len());
        }
        Ok(())
    }
}

fn take1(mut out: Vec<Vec<f32>>, name: &str) -> Result<[Vec<f32>; 1]> {
    if out.len() != 1 {
        bail!("{name}: expected 1 output, got {}", out.len());
    }
    Ok([out.remove(0)])
}

fn take2(mut out: Vec<Vec<f32>>, name: &str) -> Result<[Vec<f32>; 2]> {
    if out.len() != 2 {
        bail!("{name}: expected 2 outputs, got {}", out.len());
    }
    let b = out.remove(1);
    let a = out.remove(0);
    Ok([a, b])
}

fn scalar(v: &[f32], what: &str) -> Result<f32> {
    if v.len() != 1 {
        bail!("{what}: expected scalar, got {} elements", v.len());
    }
    Ok(v[0])
}

#[cfg(test)]
mod tests {
    use super::*;

    const GOOD: &str = "# comment\nd_in=784\nhidden=10\nclasses=10\ndim=8070\n\
                        local_steps=5\nbatch=32\nclients=100\neval_size=2000\nprobe_batch=256\n";

    #[test]
    fn parse_good_manifest() {
        let m = Manifest::parse(GOOD).unwrap();
        assert_eq!(m.dim, 8070);
        assert_eq!(m.clients, 100);
        assert_eq!(m.local_steps, 5);
    }

    #[test]
    fn parse_rejects_missing_key() {
        let broken = GOOD.replace("clients=100\n", "");
        assert!(Manifest::parse(&broken).is_err());
    }

    #[test]
    fn parse_rejects_non_integer() {
        let broken = GOOD.replace("dim=8070", "dim=abc");
        assert!(Manifest::parse(&broken).is_err());
    }

    #[test]
    fn parse_rejects_inconsistent_dim() {
        let broken = GOOD.replace("dim=8070", "dim=9999");
        assert!(Manifest::parse(&broken).is_err());
    }

    #[test]
    fn parse_tolerates_whitespace_and_comments() {
        let spaced = GOOD.replace("d_in=784", "  d_in = 784  ");
        assert_eq!(Manifest::parse(&spaced).unwrap().d_in, 784);
    }

    #[test]
    fn scalar_helper() {
        assert_eq!(scalar(&[3.5], "x").unwrap(), 3.5);
        assert!(scalar(&[1.0, 2.0], "x").is_err());
        assert!(scalar(&[], "x").is_err());
    }

    #[test]
    fn native_runtime_geometry_derives_from_config() {
        let mut cfg = crate::config::Config::default();
        cfg.partition.clients = 7;
        cfg.partition.test_size = 33;
        cfg.synth.side = 6; // d_in = 36
        let rt = ModelRuntime::native_for(&cfg).unwrap();
        let m = rt.manifest();
        assert_eq!(m.d_in, 36);
        assert_eq!(m.clients, 7);
        assert_eq!(m.eval_size, 33);
        m.validate().unwrap();
        // The native backend actually serves the aggregate entry point.
        let stack = vec![0.0f32; m.clients * m.dim];
        let mut coef = vec![0.0f32; m.clients];
        coef[0] = 1.0;
        let noise = vec![0.0f32; m.dim];
        assert_eq!(rt.aggregate(&stack, &coef, &noise).unwrap().len(), m.dim);
    }
}
