//! Thin, checked wrapper around the `xla` crate's PJRT client.
//!
//! Everything the FL layer feeds the accelerator is a flat `f32` slice plus
//! a shape; everything that comes back is a `Vec<f32>` (plus scalars). This
//! module owns the Literal plumbing and tuple unpacking so no other module
//! touches `xla::` types.

use anyhow::{bail, Context, Result};

/// A PJRT engine: one CPU client. Not `Send` (the underlying client is
/// `Rc`-backed) — build one per thread.
pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    /// Create a CPU PJRT engine.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client })
    }

    /// Platform name as reported by PJRT (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it into an executable.
    ///
    /// The text parser reassigns instruction ids, which is exactly why the
    /// interchange format is text (jax ≥ 0.5 emits 64-bit ids that
    /// xla_extension 0.5.1 rejects in proto form).
    pub fn load_hlo_text(&self, path: &std::path::Path) -> Result<Exec> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Exec {
            exe,
            name: path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
        })
    }
}

/// A compiled executable plus its name (for error messages).
pub struct Exec {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

/// One input tensor: flat `f32` data + dims. Scalars use `dims = &[]`.
pub struct Input<'a> {
    pub data: &'a [f32],
    pub dims: &'a [i64],
}

impl<'a> Input<'a> {
    pub fn new(data: &'a [f32], dims: &'a [i64]) -> Self {
        Self { data, dims }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let expect: i64 = self.dims.iter().product::<i64>();
        if self.dims.is_empty() {
            if self.data.len() != 1 {
                bail!("scalar input must have exactly 1 element");
            }
            return Ok(xla::Literal::scalar(self.data[0]));
        }
        if expect as usize != self.data.len() {
            bail!(
                "shape {:?} wants {} elements, got {}",
                self.dims,
                expect,
                self.data.len()
            );
        }
        // Single-copy path (§Perf): build the shaped literal directly from
        // the raw bytes instead of vec1 + reshape (two copies). The 3.2 MB
        // aggregate stack goes through here every round.
        let dims_usize: Vec<usize> = self.dims.iter().map(|&d| d as usize).collect();
        let bytes = unsafe {
            std::slice::from_raw_parts(self.data.as_ptr() as *const u8, self.data.len() * 4)
        };
        Ok(xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F32,
            &dims_usize,
            bytes,
        )?)
    }
}

impl Exec {
    /// Execute with flat-f32 inputs; returns each tuple element as a flat
    /// `Vec<f32>` (artifacts are lowered with `return_tuple=True`).
    pub fn run(&self, inputs: &[Input<'_>]) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|i| i.to_literal())
            .collect::<Result<_>>()
            .with_context(|| format!("building inputs for {}", self.name))?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.name))?;
        let buf = result
            .first()
            .and_then(|replica| replica.first())
            .with_context(|| format!("{}: empty result", self.name))?;
        let root = buf
            .to_literal_sync()
            .with_context(|| format!("{}: fetching result", self.name))?;
        let parts = root
            .to_tuple()
            .with_context(|| format!("{}: untupling result", self.name))?;
        parts
            .iter()
            .map(|lit| {
                lit.to_vec::<f32>()
                    .with_context(|| format!("{}: output to f32", self.name))
            })
            .collect()
    }

    /// Name of the artifact this executable came from.
    pub fn name(&self) -> &str {
        &self.name
    }
}
