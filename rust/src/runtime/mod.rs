//! Runtime layer: loads the AOT-compiled HLO artifacts (`artifacts/*.hlo.txt`,
//! produced once by `make artifacts`) and executes them on the PJRT CPU
//! client from the coordinator hot path.
//!
//! Python never runs here — the interchange is HLO *text* (see
//! `python/compile/aot.py` for why text rather than serialized protos), and
//! after `make artifacts` the `repro` binary is fully self-contained.
//!
//! Layering:
//! * [`pjrt`] — thin, checked wrapper over the `xla` crate
//!   (`PjRtClient::cpu` → `HloModuleProto::from_text_file` → `compile` →
//!   `execute`), flat `f32` in/out.
//! * [`native`] — a pure-Rust reference kernel implementing the same four
//!   entry points in-process on the register-tiled
//!   [`crate::linalg::gemm`] routines; selected with
//!   `artifacts_dir = native` so artifact-free environments (CI, fresh
//!   checkouts) still run the full coordinator stack. `Send + Sync` —
//!   the thread-safe backend every parallel execution path requires.
//! * [`artifacts`] — the manifest parser plus [`artifacts::ModelRuntime`],
//!   the typed façade the FL layer calls (`local_train`, `evaluate`,
//!   `aggregate`, `grad_probe`), dispatching to either backend.
//! * [`pool`] — the backend-agnostic worker pool fanning out
//!   `local_train` jobs across threads (per-thread PJRT engines or
//!   per-thread native models), safe to drive from several threads at
//!   once.
//!
//! # Thread ownership
//!
//! `PjRtClient` is `Rc`-backed (not `Send`). Pool workers therefore build
//! their own [`pjrt::Engine`] each (compilation of the paper-scale
//! artifacts takes milliseconds). The façade-level executables are held
//! behind [`ThreadBound`], which makes the containing types `Sync` for
//! the parallel campaign/multi-cell machinery while enforcing at runtime
//! that PJRT is only ever *used* from the thread that built it — those
//! parallel paths check [`artifacts::ModelRuntime::is_native`] first and
//! fall back to serial execution on the PJRT backend.

pub mod artifacts;
pub mod native;
pub mod pjrt;
pub mod pool;

pub use artifacts::{EvalOut, Manifest, ModelRuntime, TrainOut};
pub use native::NativeModel;
pub use pjrt::{Engine, Exec};
pub use pool::TrainPool;

/// Whether an `artifacts_dir` value selects the pure-Rust reference
/// kernel instead of on-disk AOT artifacts.
pub fn is_native_dir(dir: &std::path::Path) -> bool {
    dir.as_os_str() == "native"
}

/// Moves a `!Send` value (the PJRT client/executables) behind a
/// thread-ownership check so the *containing* type can be `Sync`.
///
/// Every access goes through [`ThreadBound::get`], which panics when
/// called from any thread other than the one that constructed the
/// value, and [`Drop`] only runs the inner destructor on the owner
/// thread — an off-thread drop **leaks** the value (with a loud
/// warning) rather than racing the non-atomic `Rc` refcounts inside
/// the PJRT client. The parallel execution paths never hit either
/// guard: they gate on [`artifacts::ModelRuntime::is_native`], so a
/// PJRT-backed context is shareable but only ever *used* (and dropped)
/// serially, from its creating thread.
pub struct ThreadBound<T> {
    value: std::mem::ManuallyDrop<T>,
    owner: std::thread::ThreadId,
}

impl<T> ThreadBound<T> {
    pub fn new(value: T) -> Self {
        Self {
            value: std::mem::ManuallyDrop::new(value),
            owner: std::thread::current().id(),
        }
    }

    /// The inner value. Panics off the owner thread.
    pub fn get(&self) -> &T {
        assert!(
            std::thread::current().id() == self.owner,
            "PJRT backend touched from a non-owner thread — parallel \
             execution requires `artifacts_dir = native`"
        );
        &self.value
    }
}

impl<T> Drop for ThreadBound<T> {
    fn drop(&mut self) {
        if std::thread::current().id() == self.owner {
            // SAFETY: dropped exactly once, here, on the owner thread.
            unsafe { std::mem::ManuallyDrop::drop(&mut self.value) }
        } else {
            // Dropping an Rc-backed PJRT value off its owner thread
            // would race the refcounts; leaking is the only sound exit.
            crate::warn_!(
                "ThreadBound value dropped off its owner thread — leaking \
                 it (move PJRT-backed contexts back to their creating \
                 thread, or use artifacts_dir = native)"
            );
        }
    }
}

// SAFETY: the inner value is only reachable through `get`, and the
// destructor only runs through `Drop` — both check that the calling
// thread is the constructing thread (off-thread drop leaks instead), so
// the `!Send` inner value is never touched from any other thread.
unsafe impl<T> Send for ThreadBound<T> {}
unsafe impl<T> Sync for ThreadBound<T> {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_bound_serves_its_owner_thread() {
        let tb = ThreadBound::new(41);
        assert_eq!(*tb.get() + 1, 42);
    }

    #[test]
    fn thread_bound_panics_off_thread() {
        let tb = ThreadBound::new(7);
        let caught = std::thread::scope(|s| {
            s.spawn(|| {
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| *tb.get())).is_err()
            })
            .join()
            .unwrap()
        });
        assert!(caught, "off-thread access must panic");
        assert_eq!(*tb.get(), 7); // owner still fine
    }

    #[test]
    fn thread_bound_off_thread_drop_leaks_instead_of_running_destructor() {
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;

        struct NoisyDrop(Arc<AtomicBool>);
        impl Drop for NoisyDrop {
            fn drop(&mut self) {
                self.0.store(true, Ordering::SeqCst);
            }
        }

        let dropped = Arc::new(AtomicBool::new(false));
        let tb = ThreadBound::new(NoisyDrop(Arc::clone(&dropped)));
        std::thread::scope(|s| {
            s.spawn(move || drop(tb));
        });
        assert!(
            !dropped.load(Ordering::SeqCst),
            "inner destructor must not run off the owner thread"
        );

        // On-thread drop still runs the destructor.
        let dropped_here = Arc::new(AtomicBool::new(false));
        drop(ThreadBound::new(NoisyDrop(Arc::clone(&dropped_here))));
        assert!(dropped_here.load(Ordering::SeqCst));
    }
}
