//! Runtime layer: loads the AOT-compiled HLO artifacts (`artifacts/*.hlo.txt`,
//! produced once by `make artifacts`) and executes them on the PJRT CPU
//! client from the coordinator hot path.
//!
//! Python never runs here — the interchange is HLO *text* (see
//! `python/compile/aot.py` for why text rather than serialized protos), and
//! after `make artifacts` the `repro` binary is fully self-contained.
//!
//! Layering:
//! * [`pjrt`] — thin, checked wrapper over the `xla` crate
//!   (`PjRtClient::cpu` → `HloModuleProto::from_text_file` → `compile` →
//!   `execute`), flat `f32` in/out.
//! * [`native`] — a pure-Rust reference kernel implementing the same four
//!   entry points in-process; selected with `artifacts_dir = native` so
//!   artifact-free environments (CI, fresh checkouts) still run the full
//!   coordinator stack, including the golden-seed equivalence suite.
//! * [`artifacts`] — the manifest parser plus [`artifacts::ModelRuntime`],
//!   the typed façade the FL layer calls (`local_train`, `evaluate`,
//!   `aggregate`, `grad_probe`), dispatching to either backend.
//!
//! `PjRtClient` is `Rc`-backed (not `Send`): each worker thread builds its
//! own [`pjrt::Engine`]. Compilation of the paper-scale artifacts takes
//! milliseconds, so per-thread engines are cheap.

pub mod artifacts;
pub mod native;
pub mod pjrt;
pub mod pool;

pub use artifacts::{EvalOut, Manifest, ModelRuntime, TrainOut};
pub use native::NativeModel;
pub use pjrt::{Engine, Exec};
pub use pool::TrainPool;

/// Whether an `artifacts_dir` value selects the pure-Rust reference
/// kernel instead of on-disk AOT artifacts.
pub fn is_native_dir(dir: &std::path::Path) -> bool {
    dir.as_os_str() == "native"
}
