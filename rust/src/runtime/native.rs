//! Pure-Rust **reference kernel**: the same four model operations the AOT
//! PJRT artifacts expose (`local_train`, `evaluate`, `aggregate`,
//! `grad_probe`), implemented directly on flat `f32` slices.
//!
//! Purpose: artifact-free environments. CI has no Python/XLA toolchain to
//! run `make artifacts`, which used to make the golden-seed equivalence
//! suite self-skip (ROADMAP open item). With this backend,
//! `artifacts_dir = native` gives [`super::ModelRuntime`] a fully
//! functional in-process model whose geometry is derived from the config
//! ([`super::ModelRuntime::native_for`]), so every coordinator/policy
//! path — including the equivalence tests, campaigns and examples — runs
//! from a fresh checkout.
//!
//! The model is the paper's MLP (`d_in → hidden → hidden → classes`,
//! ReLU, softmax cross-entropy) over the flat parameter layout
//! `[W1, b1, W2, b2, W3, b3]` used by `TrainContext::init_weights` and
//! `python/compile/model.py`. It is a *reference*, not a drop-in bitwise
//! twin of the XLA artifacts: results are deterministic and correct, but
//! not float-identical to the PJRT backend — which is all the
//! equivalence suite needs, since it compares two drivers over the *same*
//! backend.

use anyhow::{ensure, Result};

use super::artifacts::{EvalOut, Manifest, TrainOut};

/// The in-process model backend.
pub struct NativeModel {
    m: Manifest,
}

/// Parameter views over the flat weight vector.
struct Params<'a> {
    w1: &'a [f32],
    b1: &'a [f32],
    w2: &'a [f32],
    b2: &'a [f32],
    w3: &'a [f32],
    b3: &'a [f32],
}

fn split<'a>(m: &Manifest, w: &'a [f32]) -> Params<'a> {
    let (d, h, c) = (m.d_in, m.hidden, m.classes);
    let s1 = d * h;
    let s2 = s1 + h;
    let s3 = s2 + h * h;
    let s4 = s3 + h;
    let s5 = s4 + h * c;
    let s6 = s5 + c;
    Params {
        w1: &w[..s1],
        b1: &w[s1..s2],
        w2: &w[s2..s3],
        b2: &w[s3..s4],
        w3: &w[s4..s5],
        b3: &w[s5..s6],
    }
}

/// `out[n, d_out] = x[n, d_in] · w[d_in, d_out] + b` (w row-major by
/// input dimension, matching the init layout's fan-in convention).
fn affine(x: &[f32], w: &[f32], b: &[f32], n: usize, d_in: usize, d_out: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n * d_out];
    for i in 0..n {
        let row = &mut out[i * d_out..(i + 1) * d_out];
        row.copy_from_slice(b);
        let xr = &x[i * d_in..(i + 1) * d_in];
        for (k, &xv) in xr.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wr = &w[k * d_out..(k + 1) * d_out];
            for (o, &wv) in row.iter_mut().zip(wr) {
                *o += xv * wv;
            }
        }
    }
    out
}

fn relu(z: &mut [f32]) {
    for v in z.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// Softmax cross-entropy over `logits[n, c]` against one-hot `y`.
/// Returns `(mean loss, d_logits = (p − y)/n)`.
fn softmax_ce(logits: &[f32], y: &[f32], n: usize, c: usize) -> (f32, Vec<f32>) {
    let mut d = vec![0.0f32; n * c];
    let mut loss = 0.0f64;
    for i in 0..n {
        let lr = &logits[i * c..(i + 1) * c];
        let yr = &y[i * c..(i + 1) * c];
        let dr = &mut d[i * c..(i + 1) * c];
        let max = lr.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut sum = 0.0f32;
        for (dv, &lv) in dr.iter_mut().zip(lr) {
            let e = (lv - max).exp();
            *dv = e;
            sum += e;
        }
        for (dv, &yv) in dr.iter_mut().zip(yr) {
            let p = *dv / sum;
            if yv > 0.0 {
                loss -= f64::from(yv) * f64::from(p.max(1e-30).ln());
            }
            *dv = (p - yv) / n as f32;
        }
    }
    ((loss / n as f64) as f32, d)
}

/// Accumulate `gw += aᵀ·dz` and `gb += Σ_i dz_i` for one affine layer.
fn grad_affine(
    a: &[f32],
    dz: &[f32],
    n: usize,
    d_in: usize,
    d_out: usize,
    gw: &mut [f32],
    gb: &mut [f32],
) {
    for i in 0..n {
        let ar = &a[i * d_in..(i + 1) * d_in];
        let dr = &dz[i * d_out..(i + 1) * d_out];
        for (g, &dv) in gb.iter_mut().zip(dr) {
            *g += dv;
        }
        for (k, &av) in ar.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let gr = &mut gw[k * d_out..(k + 1) * d_out];
            for (g, &dv) in gr.iter_mut().zip(dr) {
                *g += av * dv;
            }
        }
    }
}

/// `dx[n, d_in] = (dz[n, d_out] · wᵀ) ⊙ (a > 0)` — backprop through an
/// affine layer and its preceding ReLU (whose output was `a`).
fn backprop_masked(
    dz: &[f32],
    w: &[f32],
    a: &[f32],
    n: usize,
    d_in: usize,
    d_out: usize,
) -> Vec<f32> {
    let mut dx = vec![0.0f32; n * d_in];
    for i in 0..n {
        let dr = &dz[i * d_out..(i + 1) * d_out];
        let ar = &a[i * d_in..(i + 1) * d_in];
        let xr = &mut dx[i * d_in..(i + 1) * d_in];
        for (k, x) in xr.iter_mut().enumerate() {
            if ar[k] <= 0.0 {
                continue;
            }
            let wr = &w[k * d_out..(k + 1) * d_out];
            let mut acc = 0.0f32;
            for (&dv, &wv) in dr.iter().zip(wr) {
                acc += dv * wv;
            }
            *x = acc;
        }
    }
    dx
}

fn argmax(v: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best
}

impl NativeModel {
    pub fn new(m: Manifest) -> Self {
        Self { m }
    }

    fn logits(&self, w: &[f32], x: &[f32], n: usize) -> Vec<f32> {
        let p = split(&self.m, w);
        let (d, h, c) = (self.m.d_in, self.m.hidden, self.m.classes);
        let mut a1 = affine(x, p.w1, p.b1, n, d, h);
        relu(&mut a1);
        let mut a2 = affine(&a1, p.w2, p.b2, n, h, h);
        relu(&mut a2);
        affine(&a2, p.w3, p.b3, n, h, c)
    }

    /// Mean softmax-CE loss and full flat gradient on one batch.
    fn loss_and_grad(&self, w: &[f32], x: &[f32], y: &[f32], n: usize) -> (f32, Vec<f32>) {
        let p = split(&self.m, w);
        let (d, h, c) = (self.m.d_in, self.m.hidden, self.m.classes);
        let mut a1 = affine(x, p.w1, p.b1, n, d, h);
        relu(&mut a1);
        let mut a2 = affine(&a1, p.w2, p.b2, n, h, h);
        relu(&mut a2);
        let logits = affine(&a2, p.w3, p.b3, n, h, c);
        let (loss, dz3) = softmax_ce(&logits, y, n, c);

        let mut g = vec![0.0f32; self.m.dim];
        {
            let (gw1, rest) = g.split_at_mut(d * h);
            let (gb1, rest) = rest.split_at_mut(h);
            let (gw2, rest) = rest.split_at_mut(h * h);
            let (gb2, rest) = rest.split_at_mut(h);
            let (gw3, gb3) = rest.split_at_mut(h * c);
            grad_affine(&a2, &dz3, n, h, c, gw3, gb3);
            let dz2 = backprop_masked(&dz3, p.w3, &a2, n, h, c);
            grad_affine(&a1, &dz2, n, h, h, gw2, gb2);
            let dz1 = backprop_masked(&dz2, p.w2, &a1, n, h, h);
            grad_affine(x, &dz1, n, d, h, gw1, gb1);
        }
        (loss, g)
    }

    /// M local SGD steps; `xs`/`ys` hold the M pre-sampled minibatches.
    pub fn local_train(&self, w: &[f32], xs: &[f32], ys: &[f32], lr: f32) -> Result<TrainOut> {
        let m = &self.m;
        let b = m.batch;
        let mut w_cur = w.to_vec();
        let mut loss_sum = 0.0f64;
        for step in 0..m.local_steps {
            let x = &xs[step * b * m.d_in..(step + 1) * b * m.d_in];
            let y = &ys[step * b * m.classes..(step + 1) * b * m.classes];
            let (loss, g) = self.loss_and_grad(&w_cur, x, y, b);
            loss_sum += f64::from(loss);
            for (wv, gv) in w_cur.iter_mut().zip(&g) {
                *wv -= lr * gv;
            }
        }
        Ok(TrainOut {
            weights: w_cur,
            loss: (loss_sum / m.local_steps as f64) as f32,
        })
    }

    /// Test loss + accuracy over the baked eval-set shape.
    pub fn evaluate(&self, w: &[f32], x: &[f32], y: &[f32]) -> Result<EvalOut> {
        let (n, c) = (self.m.eval_size, self.m.classes);
        let logits = self.logits(w, x, n);
        let (loss, _d) = softmax_ce(&logits, y, n, c);
        let mut correct = 0usize;
        for i in 0..n {
            let lr = &logits[i * c..(i + 1) * c];
            let yr = &y[i * c..(i + 1) * c];
            if argmax(lr) == argmax(yr) {
                correct += 1;
            }
        }
        Ok(EvalOut {
            loss,
            accuracy: correct as f32 / n as f32,
        })
    }

    /// AirComp superposition + normalization:
    /// `out = (Σ_k coef_k · stack_k + noise) / Σ_k coef_k`.
    pub fn aggregate(&self, stack: &[f32], coef: &[f32], noise: &[f32]) -> Result<Vec<f32>> {
        let dim = self.m.dim;
        let s: f32 = coef.iter().sum();
        ensure!(s != 0.0, "aggregate: zero coefficient sum");
        let mut out = noise.to_vec();
        for (k, &ck) in coef.iter().enumerate() {
            if ck == 0.0 {
                continue;
            }
            let row = &stack[k * dim..(k + 1) * dim];
            for (o, &rv) in out.iter_mut().zip(row) {
                *o += ck * rv;
            }
        }
        for o in out.iter_mut() {
            *o /= s;
        }
        Ok(out)
    }

    /// One full-batch gradient over the probe shape.
    pub fn grad_probe(&self, w: &[f32], x: &[f32], y: &[f32]) -> Result<Vec<f32>> {
        let (_loss, g) = self.loss_and_grad(w, x, y, self.m.probe_batch);
        Ok(g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn tiny_manifest() -> Manifest {
        let (d, h, c) = (4usize, 3usize, 3usize);
        Manifest {
            d_in: d,
            hidden: h,
            classes: c,
            dim: d * h + h + h * h + h + h * c + c,
            local_steps: 2,
            batch: 4,
            clients: 5,
            eval_size: 6,
            probe_batch: 4,
        }
    }

    fn random_case(m: &Manifest, n: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let mut w = vec![0.0f32; m.dim];
        rng.fill_normal(&mut w, 0.4);
        let mut x = vec![0.0f32; n * m.d_in];
        rng.fill_normal(&mut x, 1.0);
        let mut y = vec![0.0f32; n * m.classes];
        for i in 0..n {
            y[i * m.classes + rng.index(m.classes)] = 1.0;
        }
        (w, x, y)
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let m = tiny_manifest();
        let nm = NativeModel::new(m.clone());
        let n = 4;
        let (w, x, y) = random_case(&m, n, 11);
        let (_loss, g) = nm.loss_and_grad(&w, &x, &y, n);
        let eps = 1e-2f32;
        // Full numeric gradient (the model is tiny). A central difference
        // that straddles a ReLU kink can clip a coordinate or two, so the
        // contract is: near-zero aggregate error plus almost-everywhere
        // coordinate agreement.
        let mut num = vec![0.0f32; m.dim];
        let mut mismatches = 0usize;
        for (idx, nv) in num.iter_mut().enumerate() {
            let mut wp = w.clone();
            wp[idx] += eps;
            let (lp, _) = nm.loss_and_grad(&wp, &x, &y, n);
            let mut wm = w.clone();
            wm[idx] -= eps;
            let (lm, _) = nm.loss_and_grad(&wm, &x, &y, n);
            *nv = (lp - lm) / (2.0 * eps);
            if (*nv - g[idx]).abs() > 2e-2 * (1.0 + g[idx].abs()) {
                mismatches += 1;
            }
        }
        assert!(mismatches <= 2, "{mismatches} of {} coordinates disagree", m.dim);
        let err2: f64 = num
            .iter()
            .zip(&g)
            .map(|(a, b)| f64::from(a - b) * f64::from(a - b))
            .sum();
        let norm2: f64 = g.iter().map(|v| f64::from(*v) * f64::from(*v)).sum();
        assert!(
            err2.sqrt() <= 0.05 * (1.0 + norm2.sqrt()),
            "relative gradient error too large: {} vs ‖g‖ {}",
            err2.sqrt(),
            norm2.sqrt()
        );
    }

    #[test]
    fn local_train_reduces_loss() {
        let m = tiny_manifest();
        let nm = NativeModel::new(m.clone());
        let rows = m.local_steps * m.batch;
        let (w, xs, ys) = random_case(&m, rows, 5);
        let first = nm.local_train(&w, &xs, &ys, 0.1).unwrap();
        let second = nm.local_train(&first.weights, &xs, &ys, 0.1).unwrap();
        assert!(first.loss.is_finite() && second.loss.is_finite());
        assert!(
            second.loss < first.loss,
            "no progress on a refittable batch: {} -> {}",
            first.loss,
            second.loss
        );
        assert_eq!(first.weights.len(), m.dim);
    }

    #[test]
    fn aggregate_is_the_coef_weighted_mean() {
        let m = tiny_manifest();
        let nm = NativeModel::new(m.clone());
        let mut stack = vec![0.0f32; m.clients * m.dim];
        stack[..m.dim].iter_mut().for_each(|v| *v = 3.0); // client 0
        stack[m.dim..2 * m.dim].iter_mut().for_each(|v| *v = 9.0); // client 1
        let mut coef = vec![0.0f32; m.clients];
        coef[0] = 2.0;
        coef[1] = 1.0;
        let noise = vec![0.0f32; m.dim];
        let out = nm.aggregate(&stack, &coef, &noise).unwrap();
        for v in &out {
            assert!((v - 5.0).abs() < 1e-6, "(2·3 + 1·9)/3 = 5, got {v}");
        }
        // Noise is added pre-normalization.
        let noisy = vec![3.0f32; m.dim];
        let out = nm.aggregate(&stack, &coef, &noisy).unwrap();
        for v in &out {
            assert!((v - 6.0).abs() < 1e-6, "(15 + 3)/3 = 6, got {v}");
        }
    }

    #[test]
    fn aggregate_zero_sum_errors() {
        let m = tiny_manifest();
        let nm = NativeModel::new(m.clone());
        let stack = vec![0.0f32; m.clients * m.dim];
        let coef = vec![0.0f32; m.clients];
        let noise = vec![0.0f32; m.dim];
        assert!(nm.aggregate(&stack, &coef, &noise).is_err());
    }

    #[test]
    fn evaluate_reports_sane_ranges() {
        let m = tiny_manifest();
        let nm = NativeModel::new(m.clone());
        let (w, x, y) = random_case(&m, m.eval_size, 9);
        let e = nm.evaluate(&w, &x, &y).unwrap();
        assert!(e.loss.is_finite() && e.loss > 0.0);
        assert!((0.0..=1.0).contains(&e.accuracy));
    }

    #[test]
    fn grad_probe_shape_and_argmax() {
        let m = tiny_manifest();
        let nm = NativeModel::new(m.clone());
        let (w, x, y) = random_case(&m, m.probe_batch, 2);
        assert_eq!(nm.grad_probe(&w, &x, &y).unwrap().len(), m.dim);
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(argmax(&[2.0, 2.0, 1.0]), 0); // ties break low
    }
}
