//! Pure-Rust **reference kernel**: the same four model operations the AOT
//! PJRT artifacts expose (`local_train`, `evaluate`, `aggregate`,
//! `grad_probe`), implemented directly on flat `f32` slices.
//!
//! Purpose: artifact-free environments. CI has no Python/XLA toolchain to
//! run `make artifacts`, which used to make the golden-seed equivalence
//! suite self-skip (ROADMAP open item). With this backend,
//! `artifacts_dir = native` gives [`super::ModelRuntime`] a fully
//! functional in-process model whose geometry is derived from the config
//! ([`super::ModelRuntime::native_for`]), so every coordinator/policy
//! path — including the equivalence tests, campaigns and examples — runs
//! from a fresh checkout.
//!
//! The model is the paper's MLP (`d_in → hidden → hidden → classes`,
//! ReLU, softmax cross-entropy) over the flat parameter layout
//! `[W1, b1, W2, b2, W3, b3]` used by `TrainContext::init_weights` and
//! `python/compile/model.py`. It is a *reference*, not a drop-in bitwise
//! twin of the XLA artifacts: results are deterministic and correct, but
//! not float-identical to the PJRT backend — which is all the
//! equivalence suite needs, since it compares two drivers over the *same*
//! backend.
//!
//! # Execution model (§Perf)
//!
//! The hot path runs on the register-tiled GEMM kernels in
//! [`crate::linalg::gemm`] (blocked over output rows/columns only, so
//! every bit matches the naive triple loops they replaced — see that
//! module's "tile i/j, never k" contract) over **per-thread scratch
//! buffers**: after warm-up a `local_train`/`evaluate`/`grad_probe` call
//! allocates nothing but its returned output. Scratch is `thread_local`,
//! which keeps [`NativeModel`] `Send + Sync` — the backend-agnostic
//! [`super::pool::TrainPool`], parallel campaigns and concurrent
//! multi-cell stepping all drive it from several threads at once, each
//! thread on its own buffers.

use std::cell::RefCell;

use anyhow::{ensure, Result};

use crate::linalg::gemm;

use super::artifacts::{EvalOut, Manifest, TrainOut};

/// The in-process model backend. Stateless apart from its geometry
/// (scratch is per-thread), hence freely shared across threads.
pub struct NativeModel {
    m: Manifest,
}

/// Parameter views over the flat weight vector.
struct Params<'a> {
    w1: &'a [f32],
    b1: &'a [f32],
    w2: &'a [f32],
    b2: &'a [f32],
    w3: &'a [f32],
    b3: &'a [f32],
}

fn split<'a>(m: &Manifest, w: &'a [f32]) -> Params<'a> {
    let (d, h, c) = (m.d_in, m.hidden, m.classes);
    let s1 = d * h;
    let s2 = s1 + h;
    let s3 = s2 + h * h;
    let s4 = s3 + h;
    let s5 = s4 + h * c;
    let s6 = s5 + c;
    Params {
        w1: &w[..s1],
        b1: &w[s1..s2],
        w2: &w[s2..s3],
        b2: &w[s3..s4],
        w3: &w[s4..s5],
        b3: &w[s5..s6],
    }
}

/// Reusable per-thread buffers for the forward/backward pass. Grow-only,
/// sized for the largest row count seen on this thread, so steady-state
/// training performs zero allocations inside the kernel.
#[derive(Default)]
struct Scratch {
    a1: Vec<f32>,
    a2: Vec<f32>,
    logits: Vec<f32>,
    dz3: Vec<f32>,
    dz2: Vec<f32>,
    dz1: Vec<f32>,
    g: Vec<f32>,
    w: Vec<f32>,
}

fn grow(buf: &mut Vec<f32>, len: usize) {
    if buf.len() < len {
        buf.resize(len, 0.0);
    }
}

impl Scratch {
    fn ensure(&mut self, m: &Manifest, rows: usize) {
        grow(&mut self.a1, rows * m.hidden);
        grow(&mut self.a2, rows * m.hidden);
        grow(&mut self.logits, rows * m.classes);
        grow(&mut self.dz3, rows * m.classes);
        grow(&mut self.dz2, rows * m.hidden);
        grow(&mut self.dz1, rows * m.hidden);
        grow(&mut self.g, m.dim);
        grow(&mut self.w, m.dim);
    }
}

thread_local! {
    /// One scratch set per thread: pool workers, parallel campaign
    /// scenarios and concurrently stepped cells never contend, and
    /// `NativeModel` itself stays `Sync`.
    static SCRATCH: RefCell<Scratch> = RefCell::new(Scratch::default());
}

fn relu(z: &mut [f32]) {
    for v in z.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// Softmax cross-entropy over `logits[n, c]` against one-hot `y`, with
/// `d_logits = (p − y)/n` written into the caller's `d` buffer.
/// Returns the mean loss.
fn softmax_ce_into(logits: &[f32], y: &[f32], n: usize, c: usize, d: &mut [f32]) -> f32 {
    let mut loss = 0.0f64;
    for ((lr, yr), dr) in logits
        .chunks_exact(c)
        .zip(y.chunks_exact(c))
        .zip(d.chunks_exact_mut(c))
    {
        let max = lr.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut sum = 0.0f32;
        for (dv, &lv) in dr.iter_mut().zip(lr) {
            let e = (lv - max).exp();
            *dv = e;
            sum += e;
        }
        for (dv, &yv) in dr.iter_mut().zip(yr) {
            let p = *dv / sum;
            if yv > 0.0 {
                loss -= f64::from(yv) * f64::from(p.max(1e-30).ln());
            }
            *dv = (p - yv) / n as f32;
        }
    }
    (loss / n as f64) as f32
}

fn argmax(v: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best
}

impl NativeModel {
    pub fn new(m: Manifest) -> Self {
        Self { m }
    }

    /// Forward pass over `n` rows into the scratch activations
    /// (`s.a1`, `s.a2`, `s.logits`; each `[..n*width]` fully overwritten).
    fn forward(&self, s: &mut Scratch, w: &[f32], x: &[f32], n: usize) {
        let (d, h, c) = (self.m.d_in, self.m.hidden, self.m.classes);
        let p = split(&self.m, w);
        let Scratch { a1, a2, logits, .. } = s;
        gemm::affine_into(&mut a1[..n * h], x, p.w1, p.b1, n, d, h);
        relu(&mut a1[..n * h]);
        gemm::affine_into(&mut a2[..n * h], &a1[..n * h], p.w2, p.b2, n, h, h);
        relu(&mut a2[..n * h]);
        gemm::affine_into(&mut logits[..n * c], &a2[..n * h], p.w3, p.b3, n, h, c);
    }

    /// Mean softmax-CE loss on one batch; the full flat gradient is left
    /// in `s.g[..dim]` (fully overwritten).
    fn loss_and_grad_into(&self, s: &mut Scratch, w: &[f32], x: &[f32], y: &[f32], n: usize) -> f32 {
        self.forward(s, w, x, n);
        let (d, h, c) = (self.m.d_in, self.m.hidden, self.m.classes);
        let p = split(&self.m, w);
        let Scratch {
            a1,
            a2,
            logits,
            dz3,
            dz2,
            dz1,
            g,
            ..
        } = s;
        let loss = softmax_ce_into(&logits[..n * c], y, n, c, &mut dz3[..n * c]);

        let g = &mut g[..self.m.dim];
        g.iter_mut().for_each(|v| *v = 0.0);
        let (gw1, rest) = g.split_at_mut(d * h);
        let (gb1, rest) = rest.split_at_mut(h);
        let (gw2, rest) = rest.split_at_mut(h * h);
        let (gb2, rest) = rest.split_at_mut(h);
        let (gw3, gb3) = rest.split_at_mut(h * c);
        gemm::grad_affine_acc(gw3, gb3, &a2[..n * h], &dz3[..n * c], n, h, c);
        gemm::backprop_relu_into(&mut dz2[..n * h], &dz3[..n * c], p.w3, &a2[..n * h], n, h, c);
        gemm::grad_affine_acc(gw2, gb2, &a1[..n * h], &dz2[..n * h], n, h, h);
        gemm::backprop_relu_into(&mut dz1[..n * h], &dz2[..n * h], p.w2, &a1[..n * h], n, h, h);
        gemm::grad_affine_acc(gw1, gb1, x, &dz1[..n * h], n, d, h);
        loss
    }

    /// Mean loss + owned flat gradient (diagnostics/tests; the training
    /// loop uses [`NativeModel::loss_and_grad_into`] without the copy).
    fn loss_and_grad(&self, w: &[f32], x: &[f32], y: &[f32], n: usize) -> (f32, Vec<f32>) {
        SCRATCH.with(|cell| {
            let s = &mut *cell.borrow_mut();
            s.ensure(&self.m, n);
            let loss = self.loss_and_grad_into(s, w, x, y, n);
            (loss, s.g[..self.m.dim].to_vec())
        })
    }

    /// M local SGD steps; `xs`/`ys` hold the M pre-sampled minibatches.
    pub fn local_train(&self, w: &[f32], xs: &[f32], ys: &[f32], lr: f32) -> Result<TrainOut> {
        let m = &self.m;
        let b = m.batch;
        SCRATCH.with(|cell| {
            let s = &mut *cell.borrow_mut();
            s.ensure(m, b);
            // The evolving weights live outside the scratch borrow so the
            // gradient pass can read them while writing scratch.
            let mut w_cur = std::mem::take(&mut s.w);
            w_cur[..m.dim].copy_from_slice(w);
            let mut loss_sum = 0.0f64;
            for step in 0..m.local_steps {
                let x = &xs[step * b * m.d_in..(step + 1) * b * m.d_in];
                let y = &ys[step * b * m.classes..(step + 1) * b * m.classes];
                let loss = self.loss_and_grad_into(s, &w_cur[..m.dim], x, y, b);
                loss_sum += f64::from(loss);
                for (wv, gv) in w_cur[..m.dim].iter_mut().zip(&s.g[..m.dim]) {
                    *wv -= lr * gv;
                }
            }
            let out = TrainOut {
                weights: w_cur[..m.dim].to_vec(),
                loss: (loss_sum / m.local_steps as f64) as f32,
            };
            s.w = w_cur;
            Ok(out)
        })
    }

    /// Test loss + accuracy over the baked eval-set shape.
    pub fn evaluate(&self, w: &[f32], x: &[f32], y: &[f32]) -> Result<EvalOut> {
        let (n, c) = (self.m.eval_size, self.m.classes);
        SCRATCH.with(|cell| {
            let s = &mut *cell.borrow_mut();
            s.ensure(&self.m, n);
            self.forward(s, w, x, n);
            let Scratch { logits, dz3, .. } = s;
            let loss = softmax_ce_into(&logits[..n * c], y, n, c, &mut dz3[..n * c]);
            let mut correct = 0usize;
            for (lr, yr) in logits[..n * c].chunks_exact(c).zip(y.chunks_exact(c)) {
                if argmax(lr) == argmax(yr) {
                    correct += 1;
                }
            }
            Ok(EvalOut {
                loss,
                accuracy: correct as f32 / n as f32,
            })
        })
    }

    /// AirComp superposition + normalization:
    /// `out = (Σ_k coef_k · stack_k + noise) / Σ_k coef_k`.
    pub fn aggregate(&self, stack: &[f32], coef: &[f32], noise: &[f32]) -> Result<Vec<f32>> {
        let dim = self.m.dim;
        let s: f32 = coef.iter().sum();
        ensure!(s != 0.0, "aggregate: zero coefficient sum");
        let mut out = noise.to_vec();
        for (k, &ck) in coef.iter().enumerate() {
            if ck == 0.0 {
                continue;
            }
            let row = &stack[k * dim..(k + 1) * dim];
            for (o, &rv) in out.iter_mut().zip(row) {
                *o += ck * rv;
            }
        }
        for o in out.iter_mut() {
            *o /= s;
        }
        Ok(out)
    }

    /// One full-batch gradient over the probe shape.
    pub fn grad_probe(&self, w: &[f32], x: &[f32], y: &[f32]) -> Result<Vec<f32>> {
        let (_loss, g) = self.loss_and_grad(w, x, y, self.m.probe_batch);
        Ok(g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn tiny_manifest() -> Manifest {
        let (d, h, c) = (4usize, 3usize, 3usize);
        Manifest {
            d_in: d,
            hidden: h,
            classes: c,
            dim: d * h + h + h * h + h + h * c + c,
            local_steps: 2,
            batch: 4,
            clients: 5,
            eval_size: 6,
            probe_batch: 4,
        }
    }

    fn random_case(m: &Manifest, n: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let mut w = vec![0.0f32; m.dim];
        rng.fill_normal(&mut w, 0.4);
        let mut x = vec![0.0f32; n * m.d_in];
        rng.fill_normal(&mut x, 1.0);
        let mut y = vec![0.0f32; n * m.classes];
        for i in 0..n {
            y[i * m.classes + rng.index(m.classes)] = 1.0;
        }
        (w, x, y)
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let m = tiny_manifest();
        let nm = NativeModel::new(m.clone());
        let n = 4;
        let (w, x, y) = random_case(&m, n, 11);
        let (_loss, g) = nm.loss_and_grad(&w, &x, &y, n);
        let eps = 1e-2f32;
        // Full numeric gradient (the model is tiny). A central difference
        // that straddles a ReLU kink can clip a coordinate or two, so the
        // contract is: near-zero aggregate error plus almost-everywhere
        // coordinate agreement.
        let mut num = vec![0.0f32; m.dim];
        let mut mismatches = 0usize;
        for (idx, nv) in num.iter_mut().enumerate() {
            let mut wp = w.clone();
            wp[idx] += eps;
            let (lp, _) = nm.loss_and_grad(&wp, &x, &y, n);
            let mut wm = w.clone();
            wm[idx] -= eps;
            let (lm, _) = nm.loss_and_grad(&wm, &x, &y, n);
            *nv = (lp - lm) / (2.0 * eps);
            if (*nv - g[idx]).abs() > 2e-2 * (1.0 + g[idx].abs()) {
                mismatches += 1;
            }
        }
        assert!(mismatches <= 2, "{mismatches} of {} coordinates disagree", m.dim);
        let err2: f64 = num
            .iter()
            .zip(&g)
            .map(|(a, b)| f64::from(a - b) * f64::from(a - b))
            .sum();
        let norm2: f64 = g.iter().map(|v| f64::from(*v) * f64::from(*v)).sum();
        assert!(
            err2.sqrt() <= 0.05 * (1.0 + norm2.sqrt()),
            "relative gradient error too large: {} vs ‖g‖ {}",
            err2.sqrt(),
            norm2.sqrt()
        );
    }

    #[test]
    fn local_train_reduces_loss() {
        let m = tiny_manifest();
        let nm = NativeModel::new(m.clone());
        let rows = m.local_steps * m.batch;
        let (w, xs, ys) = random_case(&m, rows, 5);
        let first = nm.local_train(&w, &xs, &ys, 0.1).unwrap();
        let second = nm.local_train(&first.weights, &xs, &ys, 0.1).unwrap();
        assert!(first.loss.is_finite() && second.loss.is_finite());
        assert!(
            second.loss < first.loss,
            "no progress on a refittable batch: {} -> {}",
            first.loss,
            second.loss
        );
        assert_eq!(first.weights.len(), m.dim);
    }

    #[test]
    fn repeated_calls_reuse_scratch_and_stay_deterministic() {
        // The per-thread scratch must be invisible: same inputs → same
        // bits on every call, including after a *larger* model resized the
        // buffers in between.
        let m = tiny_manifest();
        let nm = NativeModel::new(m.clone());
        let rows = m.local_steps * m.batch;
        let (w, xs, ys) = random_case(&m, rows, 21);
        let first = nm.local_train(&w, &xs, &ys, 0.1).unwrap();

        let mut big = tiny_manifest();
        big.d_in = 9;
        big.eval_size = 11;
        big.dim = big.d_in * big.hidden
            + big.hidden
            + big.hidden * big.hidden
            + big.hidden
            + big.hidden * big.classes
            + big.classes;
        let other = NativeModel::new(big.clone());
        let (bw, bx, by) = random_case(&big, big.eval_size, 3);
        other.evaluate(&bw, &bx, &by).unwrap();

        let again = nm.local_train(&w, &xs, &ys, 0.1).unwrap();
        assert_eq!(first.loss.to_bits(), again.loss.to_bits());
        let same = first
            .weights
            .iter()
            .zip(&again.weights)
            .all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(same, "scratch reuse perturbed the weights");
    }

    #[test]
    fn aggregate_is_the_coef_weighted_mean() {
        let m = tiny_manifest();
        let nm = NativeModel::new(m.clone());
        let mut stack = vec![0.0f32; m.clients * m.dim];
        stack[..m.dim].iter_mut().for_each(|v| *v = 3.0); // client 0
        stack[m.dim..2 * m.dim].iter_mut().for_each(|v| *v = 9.0); // client 1
        let mut coef = vec![0.0f32; m.clients];
        coef[0] = 2.0;
        coef[1] = 1.0;
        let noise = vec![0.0f32; m.dim];
        let out = nm.aggregate(&stack, &coef, &noise).unwrap();
        for v in &out {
            assert!((v - 5.0).abs() < 1e-6, "(2·3 + 1·9)/3 = 5, got {v}");
        }
        // Noise is added pre-normalization.
        let noisy = vec![3.0f32; m.dim];
        let out = nm.aggregate(&stack, &coef, &noisy).unwrap();
        for v in &out {
            assert!((v - 6.0).abs() < 1e-6, "(15 + 3)/3 = 6, got {v}");
        }
    }

    #[test]
    fn aggregate_zero_sum_errors() {
        let m = tiny_manifest();
        let nm = NativeModel::new(m.clone());
        let stack = vec![0.0f32; m.clients * m.dim];
        let coef = vec![0.0f32; m.clients];
        let noise = vec![0.0f32; m.dim];
        assert!(nm.aggregate(&stack, &coef, &noise).is_err());
    }

    #[test]
    fn evaluate_reports_sane_ranges() {
        let m = tiny_manifest();
        let nm = NativeModel::new(m.clone());
        let (w, x, y) = random_case(&m, m.eval_size, 9);
        let e = nm.evaluate(&w, &x, &y).unwrap();
        assert!(e.loss.is_finite() && e.loss > 0.0);
        assert!((0.0..=1.0).contains(&e.accuracy));
    }

    #[test]
    fn grad_probe_shape_and_argmax() {
        let m = tiny_manifest();
        let nm = NativeModel::new(m.clone());
        let (w, x, y) = random_case(&m, m.probe_batch, 2);
        assert_eq!(nm.grad_probe(&w, &x, &y).unwrap().len(), m.dim);
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(argmax(&[2.0, 2.0, 1.0]), 0); // ties break low
    }

    #[test]
    fn native_model_is_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<NativeModel>();
    }
}
