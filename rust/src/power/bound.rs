//! Theorem 1 — the convergence upper bound, computable.
//!
//! The paper's analysis (Section III-A) bounds the expected optimality gap
//! after R rounds:
//!
//! ```text
//!   E[F(w^{R+1})] − F(w*) ≤ (Π_r A^r)·(F(w¹) − F(w*)) + Σ_r (Π_{i>r} A^i)·G^r
//! ```
//!
//! with the per-round contraction factor `A^r` (eq. (22)) and the noise
//! floor `G^r` (eq. (23), terms (a)–(e)). This module evaluates the bound
//! for concrete constants so that:
//!
//! * the power optimizer's objective (terms (d)+(e)) is *derived from* the
//!   same expression it minimizes — P1 is literally `term_d + term_e`
//!   below, keeping the optimizer and the analysis in lockstep;
//! * `repro` can print the theoretical envelope next to the measured gap
//!   curve (the Fig. 3 overlay), and the tests can assert the bound's
//!   qualitative properties (contraction needs A < 1; more noise or more
//!   weight concentration ⇒ larger floor).

/// The constants of Assumptions 1–4 plus the run geometry.
#[derive(Debug, Clone, Copy)]
pub struct BoundParams {
    /// Smoothness L (paper experiments: 10).
    pub l_smooth: f64,
    /// Learning rate η.
    pub eta: f64,
    /// Local steps M (paper: 5).
    pub local_steps: usize,
    /// Staleness direction bound δ (Assumption 3, eq. (13)).
    pub delta: f64,
    /// Staleness drift bound ε (Assumption 3, eq. (14)).
    pub epsilon: f64,
    /// Local-gradient drift bound ϑ (Assumption 3, eq. (15)).
    pub vartheta: f64,
    /// Data-heterogeneity bound ζ (Assumption 2).
    pub zeta: f64,
    /// SGD variance bound σ² (Assumption 4).
    pub sigma2: f64,
    /// Total clients K.
    pub k_total: usize,
    /// Model dimension d.
    pub dim: usize,
    /// Channel noise power σ_n² = B·N₀.
    pub noise_power: f64,
}

impl BoundParams {
    /// Shorthand used throughout eq. (22)/(23):
    /// `1 − 2η²M²L²` (must be positive for the bound to hold).
    fn denom(&self) -> f64 {
        1.0 - 2.0 * self.eta * self.eta * (self.local_steps * self.local_steps) as f64
            * self.l_smooth * self.l_smooth
    }

    /// Whether the step size satisfies the bound's validity condition.
    pub fn step_size_valid(&self) -> bool {
        self.denom() > 0.0
    }

    /// Per-round contraction factor `A^r` (eq. (22)).
    pub fn contraction(&self) -> f64 {
        let (l, eta, m) = (self.l_smooth, self.eta, self.local_steps as f64);
        let v2 = self.vartheta * self.vartheta;
        1.0 + 2.0 * l * self.delta - l * eta * m
            + 8.0 * l * l * eta * eta * m * v2
            + (eta * l * l + 4.0 * m * eta * eta * l * l * l)
                * (8.0 * l * eta * eta * m * m * m * v2)
                / self.denom()
    }

    /// Terms (a)–(c) of `G^r` (eq. (23)) — power-independent.
    pub fn floor_static(&self) -> f64 {
        let (l, eta, m) = (self.l_smooth, self.eta, self.local_steps as f64);
        let denom = self.denom();
        // (a) heterogeneity.
        let a = (2.0 * eta * m
            + 8.0 * l * eta * m * m
            + 4.0 * eta * eta * m.powi(3) * l * l * (eta * l * l + 4.0 * m * eta * eta * l.powi(3))
                / denom)
            * self.zeta;
        // (b) staleness drift.
        let b = 2.0 * eta * m * l * l * self.epsilon * self.epsilon;
        // (c) SGD variance.
        let c = (2.0 * eta * eta * l * m * m
            + (eta * l * l + 4.0 * m * eta * eta * l.powi(3)) * eta * eta * m.powi(3) / denom)
            * self.sigma2;
        a + b + c
    }

    /// Term (d) of `G^r`: `L·ε²·K·Σ_k α_k²` — weight concentration.
    pub fn term_d(&self, alphas: &[f64]) -> f64 {
        let sum_sq: f64 = alphas.iter().map(|a| a * a).sum();
        self.l_smooth * self.epsilon * self.epsilon * self.k_total as f64 * sum_sq
    }

    /// Term (e) of `G^r`: `2·L·d·σ_n² / (Σ_k b_k p_k)²` — channel noise.
    pub fn term_e(&self, sigma_sum: f64) -> f64 {
        if sigma_sum <= 0.0 {
            return f64::INFINITY;
        }
        2.0 * self.l_smooth * self.dim as f64 * self.noise_power / (sigma_sum * sigma_sum)
    }

    /// Full per-round floor `G^r` for a round's powers.
    pub fn floor(&self, powers: &[f64]) -> f64 {
        let sigma_sum: f64 = powers.iter().sum();
        if sigma_sum <= 0.0 {
            return f64::INFINITY;
        }
        let alphas: Vec<f64> = powers.iter().map(|p| p / sigma_sum).collect();
        self.floor_static() + self.term_d(&alphas) + self.term_e(sigma_sum)
    }

    /// Evaluate the R-round bound trajectory from an initial gap, given
    /// each round's powers. Returns the per-round bound values
    /// (eq. (21) unrolled via the recursion of eq. (58)).
    pub fn trajectory(&self, initial_gap: f64, per_round_powers: &[Vec<f64>]) -> Vec<f64> {
        let a = self.contraction();
        let mut gap = initial_gap;
        let mut out = Vec::with_capacity(per_round_powers.len());
        for powers in per_round_powers {
            gap = a * gap + self.floor(powers);
            out.push(gap);
        }
        out
    }
}

/// Paper-flavored defaults for the experiment geometry (the assumption
/// constants δ, ε, ϑ, ζ, σ² are not given numerically in the paper; these
/// are the values DESIGN.md §4.4 documents, chosen so A < 1 at the
/// default η).
pub fn paper_defaults(dim: usize, k_total: usize, noise_power: f64) -> BoundParams {
    BoundParams {
        l_smooth: 10.0,
        eta: 0.002,
        local_steps: 5,
        delta: 0.001,
        epsilon: 0.05,
        vartheta: 1.0,
        zeta: 0.1,
        sigma2: 0.1,
        k_total,
        dim,
        noise_power,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, prop_assert};

    fn params() -> BoundParams {
        paper_defaults(8070, 100, 7.96e-14)
    }

    #[test]
    fn step_size_condition() {
        let mut p = params();
        assert!(p.step_size_valid());
        p.eta = 0.5; // 2η²M²L² = 2·0.25·25·100 ≫ 1
        assert!(!p.step_size_valid());
    }

    #[test]
    fn contraction_below_one_at_defaults() {
        let p = params();
        let a = p.contraction();
        assert!(a < 1.0, "A = {a} should contract at paper defaults");
        assert!(a > 0.0);
    }

    #[test]
    fn uniform_weights_minimize_term_d() {
        // Σα² over the simplex is minimized by uniform weights.
        let p = params();
        check("uniform minimizes term (d)", 50, |g| {
            let n = g.usize_in(2..20);
            let uniform = vec![1.0 / n as f64; n];
            let mut random: Vec<f64> = (0..n).map(|_| g.f64_in(0.01..1.0)).collect();
            let s: f64 = random.iter().sum();
            random.iter_mut().for_each(|v| *v /= s);
            prop_assert(
                p.term_d(&uniform) <= p.term_d(&random) + 1e-12,
                "uniform not minimal",
            )
        });
    }

    #[test]
    fn term_e_decreases_with_total_power() {
        let p = params();
        let mut last = f64::INFINITY;
        for sum in [1.0, 10.0, 100.0, 1000.0] {
            let e = p.term_e(sum);
            assert!(e < last);
            last = e;
        }
        assert_eq!(p.term_e(0.0), f64::INFINITY);
    }

    #[test]
    fn noisier_channel_raises_floor() {
        let quiet = params();
        let mut loud = params();
        loud.noise_power = 7.96e-4;
        let powers = vec![7.5; 50];
        assert!(loud.floor(&powers) > quiet.floor(&powers));
    }

    #[test]
    fn trajectory_converges_to_fixed_point() {
        // With A < 1 and constant G, the bound converges to G/(1−A).
        let p = params();
        let powers: Vec<Vec<f64>> = (0..500).map(|_| vec![7.5; 50]).collect();
        let traj = p.trajectory(2.0, &powers);
        let a = p.contraction();
        let g = p.floor(&powers[0]);
        let fixed = g / (1.0 - a);
        let last = *traj.last().unwrap();
        assert!(
            (last - fixed).abs() / fixed < 1e-6,
            "trajectory end {last} vs fixed point {fixed}"
        );
        // Monotone approach from above.
        for w in traj.windows(2) {
            assert!(w[1] <= w[0] + 1e-12 || w[0] < fixed * 1.001);
        }
    }

    #[test]
    fn optimizer_objective_matches_bound_terms() {
        // The P1 objective the power controller minimizes must equal
        // term_d + term_e of this module for the same powers — the
        // analysis and the optimizer cannot drift apart.
        let p = params();
        let powers = vec![3.0, 7.0, 11.0, 2.0];
        let sum: f64 = powers.iter().sum();
        let alphas: Vec<f64> = powers.iter().map(|v| v / sum).collect();
        let objective = p.term_d(&alphas) + p.term_e(sum);
        // Rebuild from the power module's constants.
        let manual_d: f64 = p.l_smooth
            * p.epsilon
            * p.epsilon
            * p.k_total as f64
            * alphas.iter().map(|a| a * a).sum::<f64>();
        let manual_e = 2.0 * p.l_smooth * p.dim as f64 * p.noise_power / (sum * sum);
        assert!((objective - (manual_d + manual_e)).abs() < 1e-15);
    }

    #[test]
    fn staler_direction_bound_raises_contraction() {
        let mut p = params();
        let base = p.contraction();
        p.delta = 0.01;
        assert!(p.contraction() > base);
    }
}
