//! Uplink power control — the paper's §III-B pipeline, end to end.
//!
//! Per aggregation round, every participating client k gets a transmit
//! power (eq. (25))
//!
//! ```text
//!   p_k = p_k^max · (β_k·ρ_k + (1-β_k)·θ_k)
//!   ρ_k = Ω/(s_k + Ω)                       staleness discount
//!   θ_k = (cos(Δw_k, w_g^t − w_g^{t-1}) + 1)/2   similarity factor
//! ```
//!
//! and the trade-off vector β ∈ [0,1]^K minimizes the power-dependent part
//! of the convergence bound (Theorem 1, terms (d)+(e)) — problem **P1**:
//!
//! ```text
//!   min_p  L·ε²·K·Σ_k α_k²  +  2·L·d·σ_n² / (Σ_k b_k p_k)²
//! ```
//!
//! Substituting α_k = p_k/Σp and p = P·(θ + Dβ) (P = diag of per-round
//! effective power caps, D = diag(ρ−θ)) turns P1 into the quadratic
//! fractional program **P2** = h₁(β)/h₂(β), minimized by maximizing
//! h₂/h₁ with Dinkelbach (Algorithm 2, [`crate::optim::dinkelbach`]);
//! the parametric subproblem is solved faithfully by PLA→0-1 MIP for
//! small active sets and by projected coordinate descent at scale
//! (DESIGN.md §4.2).

pub mod bound;

use anyhow::Result;

use crate::config::SolverKind;
use crate::linalg::Matrix;
use crate::optim::dinkelbach::{maximize_ratio, maximize_ratio_generic, Quadratic};
use crate::optim::quadratic::RankOneQp;
use crate::optim::QpSolver;
use crate::util::Rng;

/// Staleness discount ρ_k = Ω/(s_k + Ω) (eq. (25)).
pub fn staleness_factor(stale_rounds: usize, omega: f64) -> f64 {
    assert!(omega > 0.0);
    omega / (stale_rounds as f64 + omega)
}

/// Similarity factor θ_k = (cos + 1)/2 ∈ [0, 1] (eq. (25)).
pub fn similarity_factor(cosine: f64) -> f64 {
    debug_assert!((-1.0 - 1e-9..=1.0 + 1e-9).contains(&cosine));
    (cosine.clamp(-1.0, 1.0) + 1.0) / 2.0
}

/// One participating client's inputs to the power optimizer.
#[derive(Debug, Clone, Copy)]
pub struct ClientFactors {
    /// s_k — global rounds this update is stale by.
    pub stale_rounds: usize,
    /// cos(Δw_k, w_g^t − w_g^{t−1}) ∈ [−1, 1].
    pub cosine: f64,
    /// Per-round effective power cap (channel-inversion limited), watts.
    pub p_cap: f64,
}

/// Static problem constants (from the bound).
#[derive(Debug, Clone, Copy)]
pub struct BoundConstants {
    /// Smoothness L (paper: 10).
    pub l_smooth: f64,
    /// Staleness drift bound ε² (Assumption 3).
    pub epsilon2: f64,
    /// Total client count K (the paper's term (d) uses the full K).
    pub k_total: usize,
    /// Model dimension d.
    pub dim: usize,
    /// Channel noise power σ_n² = B·N₀, watts.
    pub noise_power: f64,
    /// Staleness bound Ω.
    pub omega: f64,
}

/// Solver configuration.
#[derive(Debug, Clone, Copy)]
pub struct PowerSolverConfig {
    pub solver: SolverKind,
    /// Active sets larger than this always use PCD (MIP blowup guard).
    pub mip_max_k: usize,
    pub pla_segments: usize,
    pub mip_max_nodes: usize,
    pub dinkelbach_eps: f64,
    pub dinkelbach_iters: usize,
    /// Ablation A1: skip the optimization and use a fixed β for all
    /// clients (1.0 = staleness-only, 0.0 = similarity-only).
    pub force_beta: Option<f64>,
}

impl Default for PowerSolverConfig {
    fn default() -> Self {
        Self {
            solver: SolverKind::Pcd,
            mip_max_k: 12,
            pla_segments: 6,
            mip_max_nodes: 4000,
            dinkelbach_eps: 1e-6,
            dinkelbach_iters: 25,
            force_beta: None,
        }
    }
}

/// Result of one power-control solve.
#[derive(Debug, Clone)]
pub struct PowerAllocation {
    /// Transmit powers for the active clients (same order as input).
    pub powers: Vec<f64>,
    /// The β trade-off vector chosen.
    pub beta: Vec<f64>,
    /// Final Dinkelbach ratio h₂/h₁ (larger = smaller bound).
    pub ratio: f64,
    /// Dinkelbach iterations used.
    pub iters: usize,
}

/// Build the P2 quadratics (h₁ = bound numerator, h₂ = (Σp)² denominator)
/// over the *active* clients only.
///
/// Exposed for the optimizer integration tests and the ablation bench.
pub fn build_p2(
    factors: &[ClientFactors],
    consts: &BoundConstants,
) -> (Quadratic, Quadratic, Vec<f64>, Vec<f64>) {
    let n = factors.len();
    let rho: Vec<f64> = factors
        .iter()
        .map(|f| staleness_factor(f.stale_rounds, consts.omega))
        .collect();
    let theta: Vec<f64> = factors.iter().map(|f| similarity_factor(f.cosine)).collect();

    // p(β) = P·(θ + D·β): per-client affine. Coefficients of p_k:
    //   p_k = cap_k·θ_k + cap_k·(ρ_k − θ_k)·β_k  =: t_k + d_k·β_k.
    let t: Vec<f64> = (0..n).map(|i| factors[i].p_cap * theta[i]).collect();
    let d: Vec<f64> = (0..n)
        .map(|i| factors[i].p_cap * (rho[i] - theta[i]))
        .collect();

    // h₁(β) = c1·Σ p_k² + c2  (bound numerator; c1 = L·ε²·K, c2 = 2Ldσ²).
    let c1 = consts.l_smooth * consts.epsilon2 * consts.k_total as f64;
    let c2 = 2.0 * consts.l_smooth * consts.dim as f64 * consts.noise_power;
    // Σ p² = Σ (t + dβ)² = Σ d²β² + 2Σ t·d·β + Σ t².
    let mut a1 = Matrix::zeros(n, n);
    let mut b1 = vec![0.0; n];
    let mut k1 = 0.0;
    for i in 0..n {
        a1[(i, i)] = c1 * d[i] * d[i];
        b1[i] = 2.0 * c1 * t[i] * d[i];
        k1 += c1 * t[i] * t[i];
    }
    let h1 = Quadratic {
        a: a1,
        b: b1,
        c: k1 + c2,
    };

    // h₂(β) = (Σ p)² = (T + Σ dᵢβᵢ)², T = Σ t.
    let t_sum: f64 = t.iter().sum();
    let mut a2 = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            a2[(i, j)] = d[i] * d[j];
        }
    }
    let b2: Vec<f64> = d.iter().map(|&di| 2.0 * t_sum * di).collect();
    let h2 = Quadratic {
        a: a2,
        b: b2,
        c: t_sum * t_sum,
    };

    (h1, h2, t, d)
}

/// Solve the round's power control: returns per-active-client powers.
///
/// Empty active set returns an empty allocation. A single client gets its
/// staleness-discounted cap directly (the ratio is β-independent up to
/// degeneracies; eq. (25) with β = 1 preserves the staleness discount).
pub fn solve_power_control(
    factors: &[ClientFactors],
    consts: &BoundConstants,
    cfg: &PowerSolverConfig,
    rng: &mut Rng,
) -> Result<PowerAllocation> {
    let n = factors.len();
    if n == 0 {
        return Ok(PowerAllocation {
            powers: vec![],
            beta: vec![],
            ratio: 0.0,
            iters: 0,
        });
    }

    let (h1, h2, t, d) = build_p2(factors, consts);

    // Ablation path: fixed β, no optimization (eq. (25) directly).
    if let Some(b) = cfg.force_beta {
        let beta = vec![b; n];
        let powers: Vec<f64> = (0..n).map(|i| (t[i] + d[i] * b).max(0.0)).collect();
        let ratio = h2.eval(&beta) / h1.eval(&beta);
        return Ok(PowerAllocation {
            powers,
            beta,
            ratio,
            iters: 0,
        });
    }

    // Degenerate single-client round: any β gives α = 1; keep the paper's
    // parametric form with β = 1 (pure staleness discount).
    if n == 1 {
        let beta = vec![1.0];
        let p = (t[0] + d[0]).max(0.0);
        return Ok(PowerAllocation {
            powers: vec![p],
            beta,
            ratio: h2.eval(&[1.0]) / h1.eval(&[1.0]),
            iters: 0,
        });
    }

    let use_mip = matches!(cfg.solver, SolverKind::PlaMip) && n <= cfg.mip_max_k;
    let rep = if use_mip {
        maximize_ratio(
            &h1,
            &h2,
            QpSolver::PlaMip {
                segments: cfg.pla_segments,
                max_nodes: cfg.mip_max_nodes,
            },
            cfg.dinkelbach_eps,
            cfg.dinkelbach_iters,
            rng,
        )?
    } else {
        // §Perf fast path: F(β;λ) = (T + dᵀβ)² − λ·(c1·Σ(tᵢ+dᵢβᵢ)² + c2)
        // is rank-one + diagonal, so coordinate sweeps are O(K) instead of
        // the dense solver's O(K²) — ~40× at the paper's K = 100.
        let c1 = consts.l_smooth * consts.epsilon2 * consts.k_total as f64;
        let c2 = 2.0 * consts.l_smooth * consts.dim as f64 * consts.noise_power;
        let t_sum: f64 = t.iter().sum();
        let t2_sum: f64 = t.iter().map(|v| v * v).sum();
        // O(K) closed-form evaluators (h₁ = c1·Σ(tᵢ+dᵢβᵢ)² + c2,
        // h₂ = (T + dᵀβ)²) — avoids the dense matvec per Dinkelbach step.
        let h1_fast = |x: &[f64]| {
            c1 * x
                .iter()
                .enumerate()
                .map(|(i, &xi)| {
                    let p = t[i] + d[i] * xi;
                    p * p
                })
                .sum::<f64>()
                + c2
        };
        let h2_fast = |x: &[f64]| {
            let s: f64 = t_sum + d.iter().zip(x).map(|(a, b)| a * b).sum::<f64>();
            s * s
        };
        maximize_ratio_generic(
            n,
            h1_fast,
            h2_fast,
            |lambda| {
                let qp = RankOneQp {
                    s: 1.0,
                    u: d.clone(),
                    t: t_sum,
                    diag: d.iter().map(|&di| -lambda * c1 * di * di).collect(),
                    b: (0..n)
                        .map(|i| -lambda * 2.0 * c1 * t[i] * d[i])
                        .collect(),
                    c: -lambda * (c1 * t2_sum + c2),
                };
                Ok(qp.maximize_pcd(8, 60, rng))
            },
            cfg.dinkelbach_eps,
            cfg.dinkelbach_iters,
        )?
    };

    let powers: Vec<f64> = rep
        .beta
        .iter()
        .enumerate()
        .map(|(i, &b)| (t[i] + d[i] * b).max(0.0))
        .collect();
    Ok(PowerAllocation {
        powers,
        beta: rep.beta,
        ratio: rep.ratio,
        iters: rep.iters,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, prop_assert, prop_close};

    fn consts() -> BoundConstants {
        BoundConstants {
            l_smooth: 10.0,
            epsilon2: 1.0,
            k_total: 100,
            dim: 8070,
            noise_power: 7.96e-14,
            omega: 3.0,
        }
    }

    fn cfg() -> PowerSolverConfig {
        PowerSolverConfig::default()
    }

    #[test]
    fn staleness_factor_values() {
        assert_eq!(staleness_factor(0, 3.0), 1.0);
        assert_eq!(staleness_factor(3, 3.0), 0.5);
        assert!((staleness_factor(9, 3.0) - 0.25).abs() < 1e-12);
        // Monotone decreasing in staleness.
        for s in 0..10 {
            assert!(staleness_factor(s + 1, 3.0) < staleness_factor(s, 3.0));
        }
    }

    #[test]
    fn similarity_factor_range() {
        assert_eq!(similarity_factor(1.0), 1.0);
        assert_eq!(similarity_factor(-1.0), 0.0);
        assert_eq!(similarity_factor(0.0), 0.5);
    }

    #[test]
    fn powers_within_caps_property() {
        check("0 ≤ p_k ≤ cap_k", 30, |g| {
            let n = g.usize_in(1..10);
            let factors: Vec<ClientFactors> = (0..n)
                .map(|_| ClientFactors {
                    stale_rounds: g.usize_in(0..5),
                    cosine: g.f64_in(-1.0..1.0),
                    p_cap: g.f64_in(0.1..15.0),
                })
                .collect();
            let mut rng = Rng::new(g.rng().next_u64());
            let alloc = solve_power_control(&factors, &consts(), &cfg(), &mut rng)
                .map_err(|e| e.to_string())?;
            for (f, &p) in factors.iter().zip(&alloc.powers) {
                prop_assert(p >= -1e-9, "negative power")?;
                prop_assert(p <= f.p_cap + 1e-9, "power exceeds cap")?;
            }
            for &b in &alloc.beta {
                prop_assert((-1e-9..=1.0 + 1e-9).contains(&b), "β outside box")?;
            }
            Ok(())
        });
    }

    #[test]
    fn h1_strictly_positive_on_box() {
        check("h1 > 0", 30, |g| {
            let n = g.usize_in(1..8);
            let factors: Vec<ClientFactors> = (0..n)
                .map(|_| ClientFactors {
                    stale_rounds: g.usize_in(0..4),
                    cosine: g.f64_in(-1.0..1.0),
                    p_cap: g.f64_in(0.1..15.0),
                })
                .collect();
            let (h1, _h2, _, _) = build_p2(&factors, &consts());
            let beta: Vec<f64> = (0..n).map(|_| g.f64_in(0.0..1.0)).collect();
            prop_assert(h1.eval(&beta) > 0.0, "h1 not positive")
        });
    }

    #[test]
    fn identical_clients_get_identical_power() {
        let factors = vec![
            ClientFactors {
                stale_rounds: 1,
                cosine: 0.4,
                p_cap: 10.0,
            };
            5
        ];
        let mut rng = Rng::new(3);
        let alloc = solve_power_control(&factors, &consts(), &cfg(), &mut rng).unwrap();
        for w in alloc.powers.windows(2) {
            assert!(
                (w[0] - w[1]).abs() < 1e-6,
                "symmetric clients got asymmetric powers: {:?}",
                alloc.powers
            );
        }
    }

    #[test]
    fn fresh_aligned_beats_stale_opposed() {
        // A fresh, gradient-aligned client should end up with at least the
        // power of a very stale, opposed client with the same cap.
        let factors = vec![
            ClientFactors {
                stale_rounds: 0,
                cosine: 0.9,
                p_cap: 15.0,
            },
            ClientFactors {
                stale_rounds: 6,
                cosine: -0.9,
                p_cap: 15.0,
            },
        ];
        let mut rng = Rng::new(4);
        let alloc = solve_power_control(&factors, &consts(), &cfg(), &mut rng).unwrap();
        assert!(
            alloc.powers[0] >= alloc.powers[1],
            "powers = {:?}",
            alloc.powers
        );
        // The fresh/aligned client's p is high in absolute terms: both ρ
        // and θ are ≥ 0.9 of cap, so p ≥ 0.9·cap whatever β is.
        assert!(alloc.powers[0] > 0.85 * 15.0);
    }

    #[test]
    fn mip_and_pcd_agree_small() {
        check("MIP ≈ PCD power ratios", 6, |g| {
            let n = g.usize_in(2..5);
            let factors: Vec<ClientFactors> = (0..n)
                .map(|_| ClientFactors {
                    stale_rounds: g.usize_in(0..4),
                    cosine: g.f64_in(-1.0..1.0),
                    p_cap: g.f64_in(1.0..15.0),
                })
                .collect();
            let mut rng = Rng::new(9);
            let pcd = solve_power_control(&factors, &consts(), &cfg(), &mut rng)
                .map_err(|e| e.to_string())?;
            let mip_cfg = PowerSolverConfig {
                solver: SolverKind::PlaMip,
                ..cfg()
            };
            let mip = solve_power_control(&factors, &consts(), &mip_cfg, &mut rng)
                .map_err(|e| e.to_string())?;
            prop_close(mip.ratio, pcd.ratio, 1e-2, "Dinkelbach ratio")
        });
    }

    #[test]
    fn empty_and_single_active_set() {
        let mut rng = Rng::new(5);
        let empty = solve_power_control(&[], &consts(), &cfg(), &mut rng).unwrap();
        assert!(empty.powers.is_empty());

        let single = solve_power_control(
            &[ClientFactors {
                stale_rounds: 3,
                cosine: 0.0,
                p_cap: 15.0,
            }],
            &consts(),
            &cfg(),
            &mut rng,
        )
        .unwrap();
        // β = 1: p = cap·ρ = 15·(3/(3+3)) = 7.5.
        assert!((single.powers[0] - 7.5).abs() < 1e-9);
    }

    #[test]
    fn noisier_channel_shifts_allocation_up() {
        // With huge σ², term (e) dominates: the optimizer should push the
        // total power Σp higher than in the quiet-channel solution.
        let factors: Vec<ClientFactors> = (0..6)
            .map(|i| ClientFactors {
                stale_rounds: i % 3,
                cosine: 0.5 - 0.2 * i as f64,
                p_cap: 15.0,
            })
            .collect();
        let quiet = consts();
        let mut loud = consts();
        loud.noise_power = 7.96e-4; // −74 dBm/Hz regime
        loud.epsilon2 = 1e-4; // make (e) matter vs (d)
        let mut rng = Rng::new(6);
        let q = solve_power_control(&factors, &quiet, &cfg(), &mut rng).unwrap();
        let l = solve_power_control(&factors, &loud, &cfg(), &mut rng).unwrap();
        let sum_q: f64 = q.powers.iter().sum();
        let sum_l: f64 = l.powers.iter().sum();
        assert!(
            sum_l >= sum_q - 1e-6,
            "loud channel did not raise total power: {sum_l} vs {sum_q}"
        );
    }
}
