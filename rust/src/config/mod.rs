//! Experiment configuration: one struct, defaulting to the paper's §IV-A
//! setting, overridable from a `key = value` config file and/or CLI flags
//! (`--key value` / `--key=value`). Offline build — no serde/clap — so the
//! parser is hand-rolled and unit-tested here.

use anyhow::{bail, Context, Result};

use crate::channel::ChannelConfig;
use crate::data::{PartitionConfig, SynthConfig};

/// Which training algorithm to run — a **validated policy name** resolved
/// through the string-keyed registry ([`crate::fl::registry`]).
///
/// [`Algorithm::parse`] canonicalizes aliases (`fedavg` → `local_sgd`) and
/// rejects names no registered factory claims, so a new scheme becomes
/// selectable here — and on the CLI, and in config files — the moment it
/// calls [`crate::fl::registry::register`], with zero edits to this
/// module.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Algorithm(String);

impl Algorithm {
    /// Resolve a user-supplied name or alias through the policy registry.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(Algorithm(crate::fl::registry::canonical(s)?))
    }

    /// The canonical registry name.
    pub fn name(&self) -> &str {
        &self.0
    }

    /// Trusted constructor that bypasses registry validation (run-result
    /// tagging, defaults). Prefer [`Algorithm::parse`] for user input.
    pub fn raw(name: impl Into<String>) -> Self {
        Algorithm(name.into())
    }

    /// Every registered policy, canonical names in sorted order.
    pub fn all() -> Vec<Algorithm> {
        crate::fl::registry::names().into_iter().map(Algorithm).collect()
    }
}

impl Default for Algorithm {
    /// The paper's scheme.
    fn default() -> Self {
        Algorithm("paota".to_string())
    }
}

impl std::fmt::Display for Algorithm {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

/// Inner solver for the Dinkelbach subproblem P3.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SolverKind {
    /// Projected coordinate descent (scales to K = 100; default).
    Pcd,
    /// Paper-faithful piecewise-linear-approximation 0-1 MIP.
    PlaMip,
}

impl SolverKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "pcd" => SolverKind::Pcd,
            "mip" | "pla_mip" | "plamip" => SolverKind::PlaMip,
            other => bail!("unknown solver {other:?}"),
        })
    }

    /// Canonical name; `parse(name())` round-trips.
    pub fn name(&self) -> &'static str {
        match self {
            SolverKind::Pcd => "pcd",
            SolverKind::PlaMip => "pla_mip",
        }
    }
}

/// Power-cap derivation mode (see `Config::power_cap_mode`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PowerCapMode {
    /// p_cap = P_max (the paper's eq. (25) usage).
    Paper,
    /// p_cap = min(P_max, |h|·√P_max/‖w‖) — channel-inversion energy.
    Inversion,
}

impl PowerCapMode {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "paper" => PowerCapMode::Paper,
            "inversion" => PowerCapMode::Inversion,
            other => bail!("unknown power cap mode {other:?}"),
        })
    }

    /// Canonical name; `parse(name())` round-trips.
    pub fn name(&self) -> &'static str {
        match self {
            PowerCapMode::Paper => "paper",
            PowerCapMode::Inversion => "inversion",
        }
    }
}

/// Latency-model selector (ablation A-latency; paper = Uniform).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LatencyKind {
    Uniform,
    Homogeneous,
    Bimodal,
    /// Heavy-tailed lognormal (median `(lo+hi)/2`, shape `latency_sigma`).
    Lognormal,
    /// Time-correlated two-state Gilbert–Elliott chain (`latency_lo` fast,
    /// `latency_slow` slow, `latency_ge_enter`/`latency_ge_exit`
    /// transition probabilities).
    GilbertElliott,
}

impl LatencyKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "uniform" => LatencyKind::Uniform,
            "homogeneous" | "constant" => LatencyKind::Homogeneous,
            "bimodal" => LatencyKind::Bimodal,
            "lognormal" | "log_normal" => LatencyKind::Lognormal,
            "gilbert_elliott" | "gilbert-elliott" | "ge" => LatencyKind::GilbertElliott,
            other => bail!("unknown latency model {other:?}"),
        })
    }

    /// Canonical name; `parse(name())` round-trips.
    pub fn name(&self) -> &'static str {
        match self {
            LatencyKind::Uniform => "uniform",
            LatencyKind::Homogeneous => "homogeneous",
            LatencyKind::Bimodal => "bimodal",
            LatencyKind::Lognormal => "lognormal",
            LatencyKind::GilbertElliott => "gilbert_elliott",
        }
    }
}

/// Aggregation-topology configuration (`fl::topology`): how the flat
/// fleet is bent into an aggregation tree. The defaults describe the
/// paper's single-cell, ungrouped deployment, so every pre-topology
/// config keeps its exact meaning.
#[derive(Debug, Clone, PartialEq)]
pub struct TopologyConfig {
    /// Number of cells (parameter servers). 1 = flat single-cell; > 1
    /// routes the run through `fl::topology::multi_cell`.
    pub cells: usize,
    /// Groups per fleet for the grouped-AirComp policy (`air_fedga`).
    pub groups: usize,
    /// How clients are assigned to groups/cells.
    pub partitioner: crate::fl::topology::PartitionerKind,
    /// Inter-cell mixing scheme (multi-cell runs only).
    pub mixing: crate::fl::topology::MixingKind,
    /// Mixing cadence: merge cell models every `mixing_every` ΔT slots.
    pub mixing_every: usize,
    /// Fraction of a group's members that must be ready before the group
    /// fires its AirComp pass (1.0 = wait for the whole group).
    pub group_ready_frac: f64,
    /// Base server-side merge rate of one group aggregate (staleness-
    /// discounted per round; see `fl::topology::air_fedga`).
    pub group_mix: f64,
    /// How `air_fedga` sets per-member transmit powers inside a group
    /// pass: `dinkelbach` runs the paper's Theorem-1 program per group
    /// (noise term scoped to that group's OTA pass), `discounted` is the
    /// legacy staleness-discounted `p_max`.
    pub group_power: crate::fl::topology::GroupPowerMode,
}

impl Default for TopologyConfig {
    fn default() -> Self {
        Self {
            cells: 1,
            groups: 1,
            partitioner: crate::fl::topology::PartitionerKind::RoundRobin,
            mixing: crate::fl::topology::MixingKind::Cloud,
            mixing_every: 5,
            group_ready_frac: 1.0,
            group_mix: 0.5,
            group_power: crate::fl::topology::GroupPowerMode::Dinkelbach,
        }
    }
}

/// Client-mobility configuration (`fl::mobility`): how the client → cell
/// assignment moves over simulated time, and what happens to in-flight
/// work at handover. The defaults describe a frozen fleet, so every
/// pre-mobility config keeps its exact (bitwise) meaning.
#[derive(Debug, Clone, PartialEq)]
pub struct MobilityConfig {
    /// Mobility model: `static` (nobody moves), `markov` (cell-transition
    /// chain), `waypoint` (random-waypoint over a cell grid).
    pub kind: crate::fl::mobility::MobilityKind,
    /// Mean cell-residence time in ΔT slots (markov dwell / waypoint
    /// speed scale).
    pub dwell_mean: f64,
    /// What happens to a mover's in-flight update: `deliver` (lands OTA
    /// in the old cell, move deferred), `forward` (carried with accrued
    /// staleness), `drop` (discarded).
    pub handover: crate::fl::mobility::HandoverPolicy,
    /// Consult the mobility model every `handover_every` ΔT slots (1 =
    /// every slot boundary; set to `mixing_every` to hand over only at
    /// mixing points).
    pub handover_every: usize,
    /// Residence-coupled channel scope: cells' noise floors are spread
    /// linearly over `±cell_noise_spread_db/2` dB around the configured
    /// N₀ (0 = all cells share the base channel).
    pub cell_noise_spread_db: f64,
}

impl Default for MobilityConfig {
    fn default() -> Self {
        Self {
            kind: crate::fl::mobility::MobilityKind::Static,
            dwell_mean: 4.0,
            handover: crate::fl::mobility::HandoverPolicy::Deliver,
            handover_every: 1,
            cell_noise_spread_db: 0.0,
        }
    }
}

/// Execution-parallelism knobs (`[perf]`): how hard the host machine is
/// driven. **Neither knob affects numerics** — parallel execution is
/// bitwise identical to serial (per-run RNG streams derive only from the
/// config seed; see `runtime::pool` and `experiments::campaign`), so
/// these are pure wall-clock levers and are *not* context-defining for
/// campaigns.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PerfConfig {
    /// Worker threads in the local-training pool (1 = in-line
    /// sequential execution). Default: the `PAOTA_WORKERS` environment
    /// variable if set, else `min(available_parallelism, 8)`.
    pub workers: usize,
    /// Concurrent scenarios per campaign (`--jobs` on the CLI; 1 =
    /// serial). Parallel campaigns require the thread-safe native
    /// backend (`artifacts_dir = native`); on PJRT the setting degrades
    /// to serial with a warning.
    pub campaign_jobs: usize,
}

impl Default for PerfConfig {
    fn default() -> Self {
        Self {
            workers: crate::runtime::TrainPool::default_workers(),
            campaign_jobs: 1,
        }
    }
}

/// Fleet-scale knobs (`[fleet]`): how much of a very large client
/// population actually participates each run. The defaults (full
/// cohort) keep every pre-fleet config bitwise identical — cohort
/// sampling consumes zero RNG draws when the cohort covers the fleet.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetConfig {
    /// Fraction of the fleet sampled into the active cohort, in (0, 1].
    /// 1.0 = everyone participates (the paper's setting). Ignored when
    /// `cohort_size` is set.
    pub cohort_frac: f64,
    /// Absolute cohort size; 0 = derive from `cohort_frac`. Takes
    /// precedence over `cohort_frac` when non-zero.
    pub cohort_size: usize,
}

impl Default for FleetConfig {
    fn default() -> Self {
        Self {
            cohort_frac: 1.0,
            cohort_size: 0,
        }
    }
}

impl FleetConfig {
    /// The number of active clients for a fleet of `k`: `cohort_size`
    /// (capped at `k`) when set, else `ceil(cohort_frac · k)` clamped to
    /// `[1, k]`.
    pub fn effective_cohort(&self, k: usize) -> usize {
        if self.cohort_size > 0 {
            return self.cohort_size.min(k);
        }
        let n = (self.cohort_frac * k as f64).ceil() as usize;
        n.clamp(1, k.max(1))
    }

    /// Whether the cohort covers the whole fleet (the legacy path).
    pub fn is_full(&self, k: usize) -> bool {
        self.effective_cohort(k) >= k
    }
}

/// Wire-service knobs (`[serve]`): how `repro serve` exposes the
/// coordinator over TCP and how `repro loadgen` drives it. All keys are
/// `serve_`-prefixed on the flat `key = value` surface.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeConfig {
    /// Listen address for `repro serve` (`addr:port`; port 0 = ephemeral,
    /// the bound address is printed at startup).
    pub bind: String,
    /// Concurrent client sessions admitted; further connects get an
    /// explicit `Busy` and should back off and retry.
    pub max_sessions: usize,
    /// Aggregation-buffer depth: submissions accepted but not yet folded
    /// into a round close. A full buffer answers `Busy` (backpressure)
    /// instead of dropping the update.
    pub queue_depth: usize,
    /// Wall-clock aggregation period per round in milliseconds.
    /// 0 = lockstep: a round closes when every dispatched job has been
    /// submitted — the serial-deterministic mode whose result is bitwise
    /// identical to the in-process `fl::run` loop.
    pub period_ms: u64,
    /// Concurrent sessions `repro loadgen` replays.
    pub sessions: usize,
    /// Loadgen think-time scale: each session sleeps a seed-deterministic
    /// draw from the `[latency]` model × `pace_ms` between jobs.
    /// 0 = no pacing (max pressure).
    pub pace_ms: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            bind: "127.0.0.1:7447".into(),
            max_sessions: 64,
            queue_depth: 256,
            period_ms: 0,
            sessions: 4,
            pace_ms: 0,
        }
    }
}

/// Observability knobs (`[obs]`): the structured trace journal and the
/// live scrape endpoint (`crate::obs`). Everything defaults to **off**
/// — with this section unset no file is opened, no socket is bound, and
/// every run is bitwise identical to a pre-obs build; with it set,
/// observation stays strictly read-only on simulation state (the
/// neutrality tests in `tests/golden_seed.rs` / `tests/serve.rs` pin
/// bit-identical records + weights either way).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ObsConfig {
    /// JSONL trace journal path (schema `paota-trace/1`; appended, so
    /// several emitters may share it). Empty = tracing off.
    pub trace_path: String,
    /// Keep every n-th trace event **per kind** (1 = everything; the
    /// first event of each kind is always kept).
    pub sample_every: usize,
    /// Admin scrape listener (`/metrics`, `/metrics.json`, `/healthz`)
    /// bind address for `repro serve` (`addr:port`; port 0 =
    /// ephemeral). Empty = no listener.
    pub admin_bind: String,
}

impl Default for ObsConfig {
    fn default() -> Self {
        Self {
            trace_path: String::new(),
            sample_every: 1,
            admin_bind: String::new(),
        }
    }
}

/// Chaos-engineering knobs (`[chaos]`): seed-deterministic wire-fault
/// injection for `repro serve` / `repro loadgen` plus the recovery
/// machinery that absorbs the faults (`fl::serve::{chaos, retry}`).
/// All rates are per-outgoing-frame probabilities and default to 0 —
/// with this section unset the wire is a transparent passthrough and
/// every serve/loadgen run is bitwise identical to a pre-chaos build.
/// At most one fault fires per frame (a single uniform draw against
/// the cumulative rates), so `validate` caps the rate sum at 1.
#[derive(Debug, Clone, PartialEq)]
pub struct ChaosConfig {
    /// P(frame silently swallowed — writer sees success, peer nothing).
    pub drop: f64,
    /// P(frame delivered intact after `delay_ms` extra latency).
    pub delay: f64,
    /// Extra latency injected by a `delay` fault, in milliseconds.
    pub delay_ms: u64,
    /// P(a strict prefix of the frame is delivered, then the
    /// connection dies — the peer sees a prompt mid-frame EOF).
    pub truncate: f64,
    /// P(one bit flipped past the length prefix — the peer reads a
    /// full frame and fails the checksum cleanly).
    pub corrupt: f64,
    /// P(connection killed before the frame leaves).
    pub disconnect: f64,
    /// Loadgen recovery switch. `true` (default): sessions reconnect
    /// with a resume token under jittered exponential backoff and
    /// resubmit the pending update, so every injected loss is
    /// recovered (`lost == 0`, lockstep stays bitwise). `false`:
    /// a failed session ends quietly and its losses surface in the
    /// report / obs counters — rounds still close via the period
    /// deadline (liveness, no wedge).
    pub recovery: bool,
    /// Both sides' patience, in milliseconds: the server reclaims and
    /// re-queues jobs held by a session idle this long, and a chaos-on
    /// loadgen session abandons an exchange (and reconnects) after
    /// waiting this long for a reply.
    pub session_deadline_ms: u64,
    /// Backoff base delay (first retry ≈ `retry_base_ms`, then ×2 per
    /// consecutive failure, jittered to [0.5, 1.0)× — `serve::retry`).
    pub retry_base_ms: u64,
    /// Backoff ceiling in milliseconds.
    pub retry_max_ms: u64,
    /// Consecutive no-progress reconnect attempts before a loadgen
    /// session gives up (progress resets the count).
    pub max_retries: usize,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        Self {
            drop: 0.0,
            delay: 0.0,
            delay_ms: 20,
            truncate: 0.0,
            corrupt: 0.0,
            disconnect: 0.0,
            recovery: true,
            session_deadline_ms: 2000,
            retry_base_ms: 10,
            retry_max_ms: 500,
            max_retries: 8,
        }
    }
}

impl ChaosConfig {
    /// True when any fault can fire (loadgen uses this to decide
    /// whether to arm read timeouts / reply deadlines).
    pub fn any_faults(&self) -> bool {
        self.drop > 0.0
            || self.delay > 0.0
            || self.truncate > 0.0
            || self.corrupt > 0.0
            || self.disconnect > 0.0
    }
}

/// Full experiment configuration. Field defaults reproduce the paper.
#[derive(Debug, Clone, PartialEq)]
pub struct Config {
    /// Master seed; all streams derive from it.
    pub seed: u64,
    /// Algorithm to run.
    pub algorithm: Algorithm,
    /// Global rounds R.
    pub rounds: usize,
    /// Aggregation period ΔT in seconds (paper: 8).
    pub delta_t: f64,
    /// Per-round client compute latency ~ U(lo, hi) seconds (paper: 5–15).
    pub latency_lo: f64,
    pub latency_hi: f64,
    /// Latency model selector: "uniform" (paper), "homogeneous",
    /// "bimodal" (severe stragglers; see `latency_slow*`).
    pub latency_kind: LatencyKind,
    /// Bimodal ablation: slow-device latency and draw fraction.
    pub latency_slow: f64,
    pub latency_slow_frac: f64,
    /// Lognormal shape σ (heavy-tail severity; median stays (lo+hi)/2).
    pub latency_sigma: f64,
    /// Gilbert–Elliott transition probabilities per draw:
    /// fast→slow (`enter`) and slow→fast (`exit`).
    pub latency_ge_enter: f64,
    pub latency_ge_exit: f64,
    /// Participants per round for the synchronous baselines ("equal number
    /// of participating clients" fairness rule, §IV-B). 0 = all clients.
    pub participants: usize,
    /// Learning rate η.
    pub lr: f32,
    /// Per-client max transmit power P_max in watts (paper: 15).
    pub p_max: f64,
    /// How the per-round power cap is derived (DESIGN.md §4.3):
    /// `paper` — p_cap = P_max directly, as eq. (25) uses it (default);
    /// `inversion` — channel-inversion energy coupling
    ///   p_cap = min(P_max, |h|·√P_max/‖w‖), a stricter reading of eq. (7).
    pub power_cap_mode: PowerCapMode,
    /// Staleness bound Ω in eq. (25) (paper: 3).
    pub omega: f64,
    /// FedAsync extension: base mixing rate γ₀ (staleness-discounted; 0.1 default — per-arrival mixing needs γ ≪ 1 at K = 100).
    pub fedasync_gamma: f64,
    /// Force β to a fixed value instead of solving P2 (ablation A1):
    /// `None` = optimize; `Some(1.0)` = staleness-only weighting;
    /// `Some(0.0)` = similarity-only weighting.
    pub force_beta: Option<f64>,
    /// Trade-off solver for P3.
    pub solver: SolverKind,
    /// Max active-set size routed to the MIP solver before PCD fallback.
    pub mip_max_k: usize,
    /// PLA segment count ϱ.
    pub pla_segments: usize,
    /// B&B node budget.
    pub mip_max_nodes: usize,
    /// Dinkelbach tolerance ε and iteration cap.
    pub dinkelbach_eps: f64,
    pub dinkelbach_iters: usize,
    /// Smoothness constant L used in the bound (paper: 10).
    pub l_smooth: f64,
    /// Staleness-drift bound ε² of Assumption 3 (scales term (d)).
    pub epsilon2: f64,
    /// Channel.
    pub channel: ChannelConfig,
    /// Dataset generation.
    pub synth: SynthConfig,
    /// Partition (K clients etc.).
    pub partition: PartitionConfig,
    /// Aggregation topology (cells / groups / inter-cell mixing).
    pub topology: TopologyConfig,
    /// Client mobility (roaming model / handover policy).
    pub mobility: MobilityConfig,
    /// Execution parallelism (pool workers / campaign jobs).
    pub perf: PerfConfig,
    /// Fleet-scale cohort sampling (active participants vs fleet size).
    pub fleet: FleetConfig,
    /// Wire service (`repro serve` / `repro loadgen`).
    pub serve: ServeConfig,
    /// Observability (trace journal / scrape endpoint).
    pub obs: ObsConfig,
    /// Wire-fault injection & recovery (`repro serve` / `repro loadgen`).
    pub chaos: ChaosConfig,
    /// Evaluate every `eval_every` rounds (1 = every round).
    pub eval_every: usize,
    /// Where AOT artifacts live.
    pub artifacts_dir: std::path::PathBuf,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            seed: 42,
            algorithm: Algorithm::default(),
            rounds: 60,
            delta_t: 8.0,
            latency_lo: 5.0,
            latency_hi: 15.0,
            latency_kind: LatencyKind::Uniform,
            latency_slow: 30.0,
            latency_slow_frac: 0.2,
            latency_sigma: 0.6,
            latency_ge_enter: 0.1,
            latency_ge_exit: 0.3,
            participants: 0,
            lr: 0.2,
            p_max: 15.0,
            power_cap_mode: PowerCapMode::Paper,
            omega: 3.0,
            fedasync_gamma: 0.1,
            force_beta: None,
            solver: SolverKind::Pcd,
            mip_max_k: 12,
            pla_segments: 6,
            mip_max_nodes: 4000,
            dinkelbach_eps: 1e-6,
            dinkelbach_iters: 25,
            l_smooth: 10.0,
            epsilon2: 1.0,
            channel: ChannelConfig::default(),
            synth: SynthConfig::default(),
            partition: PartitionConfig::default(),
            topology: TopologyConfig::default(),
            mobility: MobilityConfig::default(),
            perf: PerfConfig::default(),
            fleet: FleetConfig::default(),
            serve: ServeConfig::default(),
            obs: ObsConfig::default(),
            chaos: ChaosConfig::default(),
            eval_every: 1,
            artifacts_dir: crate::runtime::ModelRuntime::default_dir(),
        }
    }
}

impl Config {
    /// Apply one `key = value` override. Keys use dotted/flat names; see
    /// the match arms (also the `--help` text in the CLI).
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        fn p<T: std::str::FromStr>(key: &str, v: &str) -> Result<T>
        where
            T::Err: std::fmt::Display,
        {
            v.parse::<T>()
                .map_err(|e| anyhow::anyhow!("bad value for {key}: {e}"))
        }
        match key {
            "seed" => self.seed = p(key, value)?,
            "algorithm" | "algo" => self.algorithm = Algorithm::parse(value)?,
            "rounds" => self.rounds = p(key, value)?,
            "delta_t" => self.delta_t = p(key, value)?,
            "latency_lo" => self.latency_lo = p(key, value)?,
            "latency_hi" => self.latency_hi = p(key, value)?,
            "latency_kind" | "latency_model" => self.latency_kind = LatencyKind::parse(value)?,
            "latency_slow" => self.latency_slow = p(key, value)?,
            "latency_slow_frac" => self.latency_slow_frac = p(key, value)?,
            "latency_sigma" => self.latency_sigma = p(key, value)?,
            "latency_ge_enter" => self.latency_ge_enter = p(key, value)?,
            "latency_ge_exit" => self.latency_ge_exit = p(key, value)?,
            "cells" => self.topology.cells = p(key, value)?,
            "groups" => self.topology.groups = p(key, value)?,
            "group_partitioner" | "partitioner" => {
                self.topology.partitioner = crate::fl::topology::PartitionerKind::parse(value)?
            }
            "mixing" => self.topology.mixing = crate::fl::topology::MixingKind::parse(value)?,
            "mixing_every" => self.topology.mixing_every = p(key, value)?,
            "group_ready_frac" => self.topology.group_ready_frac = p(key, value)?,
            "group_mix" => self.topology.group_mix = p(key, value)?,
            "group_power" => {
                self.topology.group_power = crate::fl::topology::GroupPowerMode::parse(value)?
            }
            "mobility" | "mobility_kind" => {
                self.mobility.kind = crate::fl::mobility::MobilityKind::parse(value)?
            }
            "dwell_mean" => self.mobility.dwell_mean = p(key, value)?,
            "handover" | "handover_policy" => {
                self.mobility.handover = crate::fl::mobility::HandoverPolicy::parse(value)?
            }
            "handover_every" => self.mobility.handover_every = p(key, value)?,
            "cell_noise_spread_db" => self.mobility.cell_noise_spread_db = p(key, value)?,
            "workers" => self.perf.workers = p(key, value)?,
            "campaign_jobs" | "jobs" => self.perf.campaign_jobs = p(key, value)?,
            "cohort_frac" => self.fleet.cohort_frac = p(key, value)?,
            "cohort_size" => self.fleet.cohort_size = p(key, value)?,
            "serve_bind" => self.serve.bind = value.to_string(),
            "serve_max_sessions" => self.serve.max_sessions = p(key, value)?,
            "serve_queue_depth" => self.serve.queue_depth = p(key, value)?,
            "serve_period_ms" => self.serve.period_ms = p(key, value)?,
            "serve_sessions" => self.serve.sessions = p(key, value)?,
            "serve_pace_ms" => self.serve.pace_ms = p(key, value)?,
            "obs_trace_path" => self.obs.trace_path = value.to_string(),
            "obs_sample_every" => self.obs.sample_every = p(key, value)?,
            "obs_admin_bind" => self.obs.admin_bind = value.to_string(),
            "chaos_drop" => self.chaos.drop = p(key, value)?,
            "chaos_delay" => self.chaos.delay = p(key, value)?,
            "chaos_delay_ms" => self.chaos.delay_ms = p(key, value)?,
            "chaos_truncate" => self.chaos.truncate = p(key, value)?,
            "chaos_corrupt" => self.chaos.corrupt = p(key, value)?,
            "chaos_disconnect" => self.chaos.disconnect = p(key, value)?,
            "chaos_recovery" => self.chaos.recovery = p(key, value)?,
            "chaos_session_deadline_ms" => self.chaos.session_deadline_ms = p(key, value)?,
            "chaos_retry_base_ms" => self.chaos.retry_base_ms = p(key, value)?,
            "chaos_retry_max_ms" => self.chaos.retry_max_ms = p(key, value)?,
            "chaos_max_retries" => self.chaos.max_retries = p(key, value)?,
            "force_beta" => {
                self.force_beta = if value.eq_ignore_ascii_case("none") {
                    None
                } else {
                    let b: f64 = p(key, value)?;
                    if !(0.0..=1.0).contains(&b) {
                        bail!("force_beta must be in [0,1] or 'none'");
                    }
                    Some(b)
                }
            }
            "participants" => self.participants = p(key, value)?,
            "lr" => self.lr = p(key, value)?,
            "p_max" => self.p_max = p(key, value)?,
            "power_cap_mode" => self.power_cap_mode = PowerCapMode::parse(value)?,
            "omega" => self.omega = p(key, value)?,
            "fedasync_gamma" => self.fedasync_gamma = p(key, value)?,
            "solver" => self.solver = SolverKind::parse(value)?,
            "mip_max_k" => self.mip_max_k = p(key, value)?,
            "pla_segments" => self.pla_segments = p(key, value)?,
            "mip_max_nodes" => self.mip_max_nodes = p(key, value)?,
            "dinkelbach_eps" => self.dinkelbach_eps = p(key, value)?,
            "dinkelbach_iters" => self.dinkelbach_iters = p(key, value)?,
            "l_smooth" => self.l_smooth = p(key, value)?,
            "epsilon2" => self.epsilon2 = p(key, value)?,
            "bandwidth_hz" => self.channel.bandwidth_hz = p(key, value)?,
            "n0" | "n0_dbm_per_hz" => self.channel.n0_dbm_per_hz = p(key, value)?,
            "clients" => self.partition.clients = p(key, value)?,
            "max_classes" => self.partition.max_classes = p(key, value)?,
            "test_size" => self.partition.test_size = p(key, value)?,
            "sizes" => {
                self.partition.sizes = value
                    .split(',')
                    .map(|s| p::<usize>(key, s.trim()))
                    .collect::<Result<_>>()?;
                if self.partition.sizes.is_empty() {
                    bail!("sizes must be non-empty");
                }
            }
            "side" => self.synth.side = p(key, value)?,
            "pixel_noise" => self.synth.pixel_noise = p(key, value)?,
            "label_noise" => self.synth.label_noise = p(key, value)?,
            "jitter" => self.synth.jitter = p(key, value)?,
            "eval_every" => self.eval_every = p(key, value)?,
            "artifacts_dir" => self.artifacts_dir = value.into(),
            other => bail!("unknown config key {other:?}"),
        }
        Ok(())
    }

    /// Parse a config file: `key = value` lines, `#` comments.
    pub fn apply_file(&mut self, path: &std::path::Path) -> Result<()> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("{}:{}: missing '='", path.display(), lineno + 1))?;
            self.set(k.trim(), v.trim())
                .with_context(|| format!("{}:{}", path.display(), lineno + 1))?;
        }
        Ok(())
    }

    /// Validate cross-field consistency.
    pub fn validate(&self) -> Result<()> {
        if self.latency_lo > self.latency_hi {
            bail!("latency_lo > latency_hi");
        }
        if self.delta_t <= 0.0 {
            bail!("delta_t must be positive");
        }
        if self.rounds == 0 {
            bail!("rounds must be ≥ 1");
        }
        if self.partition.clients == 0 {
            bail!("clients must be ≥ 1");
        }
        if self.participants > self.partition.clients {
            bail!("participants exceeds client count");
        }
        if !(0.0..=1.0).contains(&self.synth.label_noise) {
            bail!("label_noise must be in [0,1]");
        }
        if self.p_max <= 0.0 {
            bail!("p_max must be positive");
        }
        if self.eval_every == 0 {
            bail!("eval_every must be ≥ 1");
        }
        if self.latency_kind == LatencyKind::Lognormal {
            if self.latency_sigma <= 0.0 {
                bail!("latency_sigma must be positive for the lognormal model");
            }
            if self.latency_lo + self.latency_hi <= 0.0 {
                bail!(
                    "the lognormal latency median is (latency_lo + latency_hi)/2, \
                     which must be positive"
                );
            }
        }
        if !(0.0..=1.0).contains(&self.latency_ge_enter)
            || !(0.0..=1.0).contains(&self.latency_ge_exit)
        {
            bail!("latency_ge_enter/latency_ge_exit must be probabilities in [0,1]");
        }
        let t = &self.topology;
        if t.cells == 0 {
            bail!("cells must be ≥ 1");
        }
        if t.cells > self.partition.clients {
            bail!("cells exceeds client count (a cell would be empty)");
        }
        if t.groups == 0 {
            bail!("groups must be ≥ 1");
        }
        if t.groups > self.partition.clients {
            bail!("groups exceeds client count (a group would be empty)");
        }
        if t.mixing_every == 0 {
            bail!("mixing_every must be ≥ 1");
        }
        if !(t.group_ready_frac > 0.0 && t.group_ready_frac <= 1.0) {
            bail!("group_ready_frac must be in (0,1]");
        }
        if !(t.group_mix > 0.0 && t.group_mix <= 1.0) {
            bail!("group_mix must be in (0,1]");
        }
        if self.perf.workers == 0 {
            bail!("workers must be ≥ 1 (1 = sequential)");
        }
        if self.perf.campaign_jobs == 0 {
            bail!("campaign_jobs must be ≥ 1 (1 = serial)");
        }
        let mob = &self.mobility;
        if mob.dwell_mean <= 0.0 {
            bail!("dwell_mean must be positive (slots of mean cell residence)");
        }
        if mob.handover_every == 0 {
            bail!("handover_every must be ≥ 1");
        }
        if mob.kind != crate::fl::mobility::MobilityKind::Static && t.cells < 2 {
            bail!(
                "mobility = {} needs a multi-cell topology (cells ≥ 2) to roam over",
                mob.kind.name()
            );
        }
        let fleet = &self.fleet;
        if !(fleet.cohort_frac > 0.0 && fleet.cohort_frac <= 1.0) {
            bail!("cohort_frac must be in (0,1]");
        }
        if fleet.cohort_size > self.partition.clients {
            bail!(
                "cohort_size {} exceeds client count {}",
                fleet.cohort_size,
                self.partition.clients
            );
        }
        if !fleet.is_full(self.partition.clients) && t.cells > 1 {
            bail!(
                "cohort sampling (cohort_frac/cohort_size below the fleet size) \
                 is only supported on the flat single-cell topology (cells = 1)"
            );
        }
        let serve = &self.serve;
        if serve.bind.parse::<std::net::SocketAddr>().is_err() {
            bail!(
                "serve_bind {:?} is not an addr:port (e.g. 127.0.0.1:7447; \
                 port 0 requests an ephemeral port)",
                serve.bind
            );
        }
        if serve.max_sessions == 0 || serve.max_sessions > 4096 {
            bail!("serve_max_sessions must be in 1..=4096");
        }
        if serve.queue_depth == 0 {
            bail!("serve_queue_depth must be ≥ 1");
        }
        if serve.period_ms > 600_000 {
            bail!("serve_period_ms must be ≤ 600000 (10 min); 0 = lockstep");
        }
        if serve.sessions == 0 || serve.sessions > 4096 {
            bail!("serve_sessions must be in 1..=4096");
        }
        if serve.pace_ms > 60_000 {
            bail!("serve_pace_ms must be ≤ 60000");
        }
        let obs = &self.obs;
        if obs.sample_every == 0 {
            bail!("obs_sample_every must be ≥ 1 (1 = keep every event)");
        }
        if !obs.admin_bind.is_empty() && obs.admin_bind.parse::<std::net::SocketAddr>().is_err() {
            bail!(
                "obs_admin_bind {:?} is not an addr:port (e.g. 127.0.0.1:7448; \
                 port 0 requests an ephemeral port; empty = no admin listener)",
                obs.admin_bind
            );
        }
        let chaos = &self.chaos;
        for (key, rate) in [
            ("chaos_drop", chaos.drop),
            ("chaos_delay", chaos.delay),
            ("chaos_truncate", chaos.truncate),
            ("chaos_corrupt", chaos.corrupt),
            ("chaos_disconnect", chaos.disconnect),
        ] {
            if !(0.0..=1.0).contains(&rate) {
                bail!("{key} must be a probability in [0,1]");
            }
        }
        if chaos.drop + chaos.delay + chaos.truncate + chaos.corrupt + chaos.disconnect > 1.0 {
            bail!("chaos fault rates must sum to ≤ 1 (at most one fault fires per frame)");
        }
        if chaos.delay_ms > 10_000 {
            bail!("chaos_delay_ms must be ≤ 10000");
        }
        if !(100..=600_000).contains(&chaos.session_deadline_ms) {
            bail!("chaos_session_deadline_ms must be in 100..=600000");
        }
        if chaos.retry_base_ms == 0 || chaos.retry_base_ms > chaos.retry_max_ms {
            bail!("chaos_retry_base_ms must be in 1..=chaos_retry_max_ms");
        }
        if chaos.retry_max_ms > 60_000 {
            bail!("chaos_retry_max_ms must be ≤ 60000");
        }
        if chaos.max_retries == 0 || chaos.max_retries > 1000 {
            bail!("chaos_max_retries must be in 1..=1000");
        }
        Ok(())
    }

    /// The configured latency model.
    pub fn latency(&self) -> crate::sim::LatencyModel {
        match self.latency_kind {
            LatencyKind::Uniform => crate::sim::LatencyModel::Uniform {
                lo: self.latency_lo,
                hi: self.latency_hi,
            },
            LatencyKind::Homogeneous => crate::sim::LatencyModel::Homogeneous {
                value: (self.latency_lo + self.latency_hi) / 2.0,
            },
            LatencyKind::Bimodal => crate::sim::LatencyModel::Bimodal {
                fast: self.latency_lo,
                slow: self.latency_slow,
                slow_frac: self.latency_slow_frac,
            },
            LatencyKind::Lognormal => crate::sim::LatencyModel::Lognormal {
                mu: ((self.latency_lo + self.latency_hi) / 2.0).ln(),
                sigma: self.latency_sigma,
            },
            LatencyKind::GilbertElliott => crate::sim::LatencyModel::GilbertElliott {
                fast: self.latency_lo,
                slow: self.latency_slow,
                p_enter: self.latency_ge_enter,
                p_exit: self.latency_ge_exit,
            },
        }
    }

    /// Expected PAOTA participants per round: clients whose latency draw
    /// lands within one ΔT window (used for the fairness rule when
    /// `participants == 0`).
    pub fn expected_participation(&self) -> f64 {
        let span = self.latency_hi - self.latency_lo;
        if span <= 0.0 {
            return if self.delta_t >= self.latency_lo {
                self.partition.clients as f64
            } else {
                0.0
            };
        }
        let frac = ((self.delta_t - self.latency_lo) / span).clamp(0.0, 1.0);
        frac * self.partition.clients as f64
    }

    /// Serialize the *settable* configuration surface as `key = value`
    /// lines that round-trip through [`Config::apply_file`] — what
    /// `repro show-config` prints, so an effective config can be saved
    /// and replayed verbatim with `--config`.
    pub fn to_kv_string(&self) -> String {
        let mut s = String::new();
        let mut kv = |k: &str, v: String| {
            s.push_str(k);
            s.push_str(" = ");
            s.push_str(&v);
            s.push('\n');
        };
        kv("seed", self.seed.to_string());
        kv("algo", self.algorithm.name().to_string());
        kv("rounds", self.rounds.to_string());
        kv("delta_t", self.delta_t.to_string());
        kv("latency_lo", self.latency_lo.to_string());
        kv("latency_hi", self.latency_hi.to_string());
        kv("latency_kind", self.latency_kind.name().to_string());
        kv("latency_slow", self.latency_slow.to_string());
        kv("latency_slow_frac", self.latency_slow_frac.to_string());
        kv("latency_sigma", self.latency_sigma.to_string());
        kv("latency_ge_enter", self.latency_ge_enter.to_string());
        kv("latency_ge_exit", self.latency_ge_exit.to_string());
        kv("participants", self.participants.to_string());
        kv("lr", self.lr.to_string());
        kv("p_max", self.p_max.to_string());
        kv("power_cap_mode", self.power_cap_mode.name().to_string());
        kv("omega", self.omega.to_string());
        kv("fedasync_gamma", self.fedasync_gamma.to_string());
        kv(
            "force_beta",
            self.force_beta.map_or("none".to_string(), |b| b.to_string()),
        );
        kv("solver", self.solver.name().to_string());
        kv("mip_max_k", self.mip_max_k.to_string());
        kv("pla_segments", self.pla_segments.to_string());
        kv("mip_max_nodes", self.mip_max_nodes.to_string());
        kv("dinkelbach_eps", self.dinkelbach_eps.to_string());
        kv("dinkelbach_iters", self.dinkelbach_iters.to_string());
        kv("l_smooth", self.l_smooth.to_string());
        kv("epsilon2", self.epsilon2.to_string());
        kv("bandwidth_hz", self.channel.bandwidth_hz.to_string());
        kv("n0", self.channel.n0_dbm_per_hz.to_string());
        kv("clients", self.partition.clients.to_string());
        kv("max_classes", self.partition.max_classes.to_string());
        kv("test_size", self.partition.test_size.to_string());
        kv(
            "sizes",
            self.partition
                .sizes
                .iter()
                .map(|n| n.to_string())
                .collect::<Vec<_>>()
                .join(","),
        );
        kv("cells", self.topology.cells.to_string());
        kv("groups", self.topology.groups.to_string());
        kv("group_partitioner", self.topology.partitioner.name().to_string());
        kv("mixing", self.topology.mixing.name().to_string());
        kv("mixing_every", self.topology.mixing_every.to_string());
        kv("group_ready_frac", self.topology.group_ready_frac.to_string());
        kv("group_mix", self.topology.group_mix.to_string());
        kv("group_power", self.topology.group_power.name().to_string());
        kv("mobility", self.mobility.kind.name().to_string());
        kv("dwell_mean", self.mobility.dwell_mean.to_string());
        kv("handover", self.mobility.handover.name().to_string());
        kv("handover_every", self.mobility.handover_every.to_string());
        kv("cell_noise_spread_db", self.mobility.cell_noise_spread_db.to_string());
        kv("workers", self.perf.workers.to_string());
        kv("campaign_jobs", self.perf.campaign_jobs.to_string());
        kv("cohort_frac", self.fleet.cohort_frac.to_string());
        kv("cohort_size", self.fleet.cohort_size.to_string());
        kv("serve_bind", self.serve.bind.clone());
        kv("serve_max_sessions", self.serve.max_sessions.to_string());
        kv("serve_queue_depth", self.serve.queue_depth.to_string());
        kv("serve_period_ms", self.serve.period_ms.to_string());
        kv("serve_sessions", self.serve.sessions.to_string());
        kv("serve_pace_ms", self.serve.pace_ms.to_string());
        kv("obs_trace_path", self.obs.trace_path.clone());
        kv("obs_sample_every", self.obs.sample_every.to_string());
        kv("obs_admin_bind", self.obs.admin_bind.clone());
        kv("chaos_drop", self.chaos.drop.to_string());
        kv("chaos_delay", self.chaos.delay.to_string());
        kv("chaos_delay_ms", self.chaos.delay_ms.to_string());
        kv("chaos_truncate", self.chaos.truncate.to_string());
        kv("chaos_corrupt", self.chaos.corrupt.to_string());
        kv("chaos_disconnect", self.chaos.disconnect.to_string());
        kv("chaos_recovery", self.chaos.recovery.to_string());
        kv(
            "chaos_session_deadline_ms",
            self.chaos.session_deadline_ms.to_string(),
        );
        kv("chaos_retry_base_ms", self.chaos.retry_base_ms.to_string());
        kv("chaos_retry_max_ms", self.chaos.retry_max_ms.to_string());
        kv("chaos_max_retries", self.chaos.max_retries.to_string());
        kv("side", self.synth.side.to_string());
        kv("pixel_noise", self.synth.pixel_noise.to_string());
        kv("label_noise", self.synth.label_noise.to_string());
        kv("jitter", self.synth.jitter.to_string());
        kv("eval_every", self.eval_every.to_string());
        kv("artifacts_dir", self.artifacts_dir.display().to_string());
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = Config::default();
        assert_eq!(c.delta_t, 8.0);
        assert_eq!(c.latency_lo, 5.0);
        assert_eq!(c.latency_hi, 15.0);
        assert_eq!(c.p_max, 15.0);
        assert_eq!(c.omega, 3.0);
        assert_eq!(c.l_smooth, 10.0);
        assert_eq!(c.partition.clients, 100);
        assert_eq!(c.partition.max_classes, 5);
        assert_eq!(c.partition.sizes, vec![300, 600, 900, 1200, 1500]);
        assert_eq!(c.channel.bandwidth_hz, 20e6);
        assert_eq!(c.channel.n0_dbm_per_hz, -174.0);
        c.validate().unwrap();
    }

    #[test]
    fn set_simple_keys() {
        let mut c = Config::default();
        c.set("rounds", "120").unwrap();
        c.set("algo", "cotaf").unwrap();
        c.set("n0", "-74").unwrap();
        c.set("lr", "0.1").unwrap();
        assert_eq!(c.rounds, 120);
        assert_eq!(c.algorithm.name(), "cotaf");
        assert_eq!(c.channel.n0_dbm_per_hz, -74.0);
        assert_eq!(c.lr, 0.1);
    }

    #[test]
    fn set_sizes_list() {
        let mut c = Config::default();
        c.set("sizes", "100, 200,300").unwrap();
        assert_eq!(c.partition.sizes, vec![100, 200, 300]);
        assert!(c.set("sizes", "").is_err());
    }

    #[test]
    fn rejects_unknown_key_and_bad_value() {
        let mut c = Config::default();
        assert!(c.set("no_such_key", "1").is_err());
        assert!(c.set("rounds", "abc").is_err());
        assert!(c.set("algorithm", "nope").is_err());
    }

    #[test]
    fn validation_catches_inconsistency() {
        let mut c = Config::default();
        c.latency_lo = 20.0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.participants = 1000;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.rounds = 0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.eval_every = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn perf_keys_parse_and_validate() {
        let mut c = Config::default();
        c.set("workers", "3").unwrap();
        c.set("campaign_jobs", "4").unwrap();
        assert_eq!(c.perf.workers, 3);
        assert_eq!(c.perf.campaign_jobs, 4);
        // `--jobs` is the CLI-facing alias.
        c.set("jobs", "2").unwrap();
        assert_eq!(c.perf.campaign_jobs, 2);
        c.validate().unwrap();
        // Zero is rejected: 1 is the explicit "sequential/serial" value.
        let mut c = Config::default();
        c.set("workers", "0").unwrap();
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.set("jobs", "0").unwrap();
        assert!(c.validate().is_err());
        // Defaults are sane and machine-derived.
        let d = Config::default();
        assert!(d.perf.workers >= 1);
        assert_eq!(d.perf.campaign_jobs, 1);
    }

    #[test]
    fn apply_file_roundtrip() {
        let dir = std::env::temp_dir().join("paota_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("exp.cfg");
        std::fs::write(&path, "# paper fig3b\nn0 = -74\nrounds=30\nalgo = paota\n").unwrap();
        let mut c = Config::default();
        c.apply_file(&path).unwrap();
        assert_eq!(c.channel.n0_dbm_per_hz, -74.0);
        assert_eq!(c.rounds, 30);
    }

    #[test]
    fn expected_participation_paper_setting() {
        // ΔT = 8, latency U(5,15): P(ℓ ≤ 8) = 0.3 → 30 clients.
        let c = Config::default();
        assert!((c.expected_participation() - 30.0).abs() < 1e-9);
    }

    #[test]
    fn topology_validation() {
        let mut c = Config::default();
        c.topology.cells = 0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.topology.groups = c.partition.clients + 1;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.topology.group_ready_frac = 0.0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.topology.mixing_every = 0;
        assert!(c.validate().is_err());
        // Lognormal needs a positive median and shape.
        let mut c = Config::default();
        c.latency_kind = LatencyKind::Lognormal;
        c.latency_lo = -20.0;
        c.latency_hi = 10.0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.latency_kind = LatencyKind::Lognormal;
        c.latency_sigma = 0.0;
        assert!(c.validate().is_err());
        // Multi-cell now composes with grouped AirComp: each cell builds
        // its GroupMap over its own member slice.
        let mut c = Config::default();
        c.algorithm = Algorithm::parse("air_fedga").unwrap();
        c.topology.cells = 2;
        c.topology.groups = 5;
        c.validate().unwrap();
        c.topology.cells = 1;
        c.validate().unwrap();
    }

    #[test]
    fn mobility_validation_and_keys() {
        use crate::fl::mobility::{HandoverPolicy, MobilityKind};
        let mut c = Config::default();
        c.set("cells", "3").unwrap();
        c.set("mobility", "markov").unwrap();
        c.set("dwell_mean", "2.5").unwrap();
        c.set("handover", "forward").unwrap();
        c.set("handover_every", "2").unwrap();
        c.set("cell_noise_spread_db", "6").unwrap();
        assert_eq!(c.mobility.kind, MobilityKind::Markov);
        assert_eq!(c.mobility.dwell_mean, 2.5);
        assert_eq!(c.mobility.handover, HandoverPolicy::Forward);
        assert_eq!(c.mobility.handover_every, 2);
        assert_eq!(c.mobility.cell_noise_spread_db, 6.0);
        c.validate().unwrap();

        // Roaming needs a multi-cell tree.
        let mut c = Config::default();
        c.set("mobility", "waypoint").unwrap();
        assert!(c.validate().is_err());
        c.set("cells", "2").unwrap();
        c.validate().unwrap();
        // Degenerate knobs rejected.
        let mut c = Config::default();
        c.set("dwell_mean", "0").unwrap();
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.set("handover_every", "0").unwrap();
        assert!(c.validate().is_err());
        // Unknown model / policy names rejected at set time.
        assert!(Config::default().set("mobility", "teleport").is_err());
        assert!(Config::default().set("handover", "nope").is_err());
        assert!(Config::default().set("group_power", "nope").is_err());
    }

    #[test]
    fn fleet_keys_parse_validate_and_size_the_cohort() {
        let mut c = Config::default();
        c.set("cohort_frac", "0.3").unwrap();
        assert_eq!(c.fleet.cohort_frac, 0.3);
        c.validate().unwrap();
        // ceil(0.3 · 100) = 30 active of 100.
        assert_eq!(c.fleet.effective_cohort(c.partition.clients), 30);
        assert!(!c.fleet.is_full(c.partition.clients));
        // cohort_size takes precedence over cohort_frac.
        c.set("cohort_size", "7").unwrap();
        assert_eq!(c.fleet.effective_cohort(c.partition.clients), 7);
        c.validate().unwrap();
        // The default is the full fleet and consumes no sampling.
        let d = Config::default();
        assert!(d.fleet.is_full(d.partition.clients));
        assert_eq!(d.fleet.effective_cohort(10), 10);
        // cohort_frac outside (0,1] rejected.
        let mut c = Config::default();
        c.set("cohort_frac", "0").unwrap();
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.set("cohort_frac", "1.5").unwrap();
        assert!(c.validate().is_err());
        // cohort_size above the fleet rejected.
        let mut c = Config::default();
        c.set("cohort_size", "101").unwrap();
        assert!(c.validate().is_err());
        // Partial cohorts don't compose with multi-cell trees (yet).
        let mut c = Config::default();
        c.set("cells", "2").unwrap();
        c.set("cohort_frac", "0.5").unwrap();
        assert!(c.validate().is_err());
        c.set("cohort_frac", "1.0").unwrap();
        c.validate().unwrap();
    }

    #[test]
    fn serve_keys_parse_and_validate() {
        let mut c = Config::default();
        c.set("serve_bind", "0.0.0.0:0").unwrap();
        c.set("serve_max_sessions", "128").unwrap();
        c.set("serve_queue_depth", "32").unwrap();
        c.set("serve_period_ms", "500").unwrap();
        c.set("serve_sessions", "16").unwrap();
        c.set("serve_pace_ms", "100").unwrap();
        c.validate().unwrap();
        assert_eq!(c.serve.max_sessions, 128);
        assert_eq!(c.serve.queue_depth, 32);

        // Degenerate values rejected.
        let mut c = Config::default();
        c.set("serve_bind", "not-an-address").unwrap();
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.set("serve_max_sessions", "0").unwrap();
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.set("serve_queue_depth", "0").unwrap();
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.set("serve_sessions", "0").unwrap();
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.set("serve_period_ms", "600001").unwrap();
        assert!(c.validate().is_err());
        // Non-numeric values rejected at set time.
        assert!(Config::default().set("serve_period_ms", "fast").is_err());
    }

    #[test]
    fn obs_keys_parse_and_validate() {
        let mut c = Config::default();
        // Defaults: everything off, every-event sampling.
        assert!(c.obs.trace_path.is_empty());
        assert!(c.obs.admin_bind.is_empty());
        assert_eq!(c.obs.sample_every, 1);
        c.validate().unwrap();

        c.set("obs_trace_path", "/tmp/run_trace.jsonl").unwrap();
        c.set("obs_sample_every", "10").unwrap();
        c.set("obs_admin_bind", "127.0.0.1:0").unwrap();
        assert_eq!(c.obs.trace_path, "/tmp/run_trace.jsonl");
        assert_eq!(c.obs.sample_every, 10);
        assert_eq!(c.obs.admin_bind, "127.0.0.1:0");
        c.validate().unwrap();

        // Degenerate values rejected.
        let mut c = Config::default();
        c.set("obs_sample_every", "0").unwrap();
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.set("obs_admin_bind", "not-an-address").unwrap();
        assert!(c.validate().is_err());
        assert!(Config::default().set("obs_sample_every", "often").is_err());
    }

    #[test]
    fn chaos_keys_parse_and_validate() {
        let mut c = Config::default();
        // Defaults: every rate zero, recovery on.
        assert!(!c.chaos.any_faults());
        assert!(c.chaos.recovery);
        c.validate().unwrap();

        c.set("chaos_drop", "0.05").unwrap();
        c.set("chaos_delay", "0.1").unwrap();
        c.set("chaos_delay_ms", "15").unwrap();
        c.set("chaos_truncate", "0.02").unwrap();
        c.set("chaos_corrupt", "0.03").unwrap();
        c.set("chaos_disconnect", "0.02").unwrap();
        c.set("chaos_recovery", "false").unwrap();
        c.set("chaos_session_deadline_ms", "400").unwrap();
        c.set("chaos_retry_base_ms", "5").unwrap();
        c.set("chaos_retry_max_ms", "100").unwrap();
        c.set("chaos_max_retries", "12").unwrap();
        c.validate().unwrap();
        assert!(c.chaos.any_faults());
        assert!(!c.chaos.recovery);
        assert_eq!(c.chaos.session_deadline_ms, 400);
        assert_eq!(c.chaos.max_retries, 12);

        // Degenerate values rejected.
        let mut c = Config::default();
        c.set("chaos_drop", "1.5").unwrap();
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.set("chaos_corrupt", "-0.1").unwrap();
        assert!(c.validate().is_err());
        // Rates summing past 1 rejected (one draw per frame).
        let mut c = Config::default();
        c.set("chaos_drop", "0.6").unwrap();
        c.set("chaos_disconnect", "0.6").unwrap();
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.set("chaos_session_deadline_ms", "50").unwrap();
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.set("chaos_retry_base_ms", "0").unwrap();
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.set("chaos_retry_base_ms", "600").unwrap();
        assert!(c.validate().is_err(), "base above max rejected");
        let mut c = Config::default();
        c.set("chaos_max_retries", "0").unwrap();
        assert!(c.validate().is_err());
        // Non-parseable values rejected at set time.
        assert!(Config::default().set("chaos_recovery", "maybe").is_err());
        assert!(Config::default().set("chaos_drop", "lots").is_err());
    }

    #[test]
    fn latency_kind_roundtrip_and_models() {
        for kind in ["uniform", "homogeneous", "bimodal", "lognormal", "gilbert_elliott"] {
            assert_eq!(LatencyKind::parse(kind).unwrap().name(), kind);
        }
        assert_eq!(LatencyKind::parse("ge").unwrap(), LatencyKind::GilbertElliott);
        let mut c = Config::default();
        c.latency_kind = LatencyKind::Lognormal;
        let crate::sim::LatencyModel::Lognormal { mu, sigma } = c.latency() else {
            panic!("wrong model");
        };
        assert!((mu - 10.0f64.ln()).abs() < 1e-12);
        assert_eq!(sigma, 0.6);
        c.latency_kind = LatencyKind::GilbertElliott;
        assert!(matches!(
            c.latency(),
            crate::sim::LatencyModel::GilbertElliott { .. }
        ));
    }

    #[test]
    fn algorithm_parse_aliases() {
        assert_eq!(Algorithm::parse("FedAvg").unwrap().name(), "local_sgd");
        assert_eq!(Algorithm::parse("central").unwrap().name(), "centralized");
        assert_eq!(Algorithm::parse("ca-paota").unwrap().name(), "ca_paota");
    }

    #[test]
    fn algorithm_names_roundtrip_for_every_registered_policy() {
        let all = Algorithm::all();
        assert!(all.len() >= 6, "expected the built-ins to be registered");
        for algo in all {
            assert_eq!(Algorithm::parse(algo.name()).unwrap(), algo);
        }
    }

    #[test]
    fn show_config_roundtrips_through_apply_file() {
        let dir = std::env::temp_dir().join("paota_showcfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("effective.cfg");

        // A config mutated away from every default category: numbers,
        // enums, the option, the list, the path.
        let mut c = Config::default();
        c.set("algo", "fedasync").unwrap();
        c.set("rounds", "7").unwrap();
        c.set("latency_kind", "bimodal").unwrap();
        c.set("force_beta", "0.25").unwrap();
        c.set("solver", "pla_mip").unwrap();
        c.set("power_cap_mode", "inversion").unwrap();
        c.set("sizes", "100,200").unwrap();
        c.set("n0", "-74").unwrap();
        c.set("dinkelbach_eps", "0.000001").unwrap();
        c.set("artifacts_dir", "native").unwrap();
        c.set("cells", "3").unwrap();
        c.set("groups", "4").unwrap();
        c.set("group_partitioner", "latency").unwrap();
        c.set("mixing", "gossip").unwrap();
        c.set("mixing_every", "2").unwrap();
        c.set("group_ready_frac", "0.75").unwrap();
        c.set("group_mix", "0.4").unwrap();
        c.set("group_power", "discounted").unwrap();
        c.set("mobility", "markov").unwrap();
        c.set("dwell_mean", "2.5").unwrap();
        c.set("handover", "drop").unwrap();
        c.set("handover_every", "3").unwrap();
        c.set("cell_noise_spread_db", "4").unwrap();
        c.set("side", "12").unwrap();
        c.set("workers", "5").unwrap();
        c.set("jobs", "3").unwrap();
        c.set("latency_sigma", "0.9").unwrap();
        c.set("latency_ge_enter", "0.2").unwrap();
        c.set("latency_ge_exit", "0.4").unwrap();
        c.set("cohort_frac", "0.5").unwrap();
        c.set("cohort_size", "0").unwrap();
        c.set("serve_bind", "127.0.0.1:9000").unwrap();
        c.set("serve_max_sessions", "8").unwrap();
        c.set("serve_queue_depth", "16").unwrap();
        c.set("serve_period_ms", "250").unwrap();
        c.set("serve_sessions", "2").unwrap();
        c.set("serve_pace_ms", "5").unwrap();
        c.set("obs_trace_path", "/tmp/t.jsonl").unwrap();
        c.set("obs_sample_every", "4").unwrap();
        c.set("obs_admin_bind", "127.0.0.1:7448").unwrap();
        c.set("chaos_drop", "0.05").unwrap();
        c.set("chaos_delay", "0.1").unwrap();
        c.set("chaos_delay_ms", "15").unwrap();
        c.set("chaos_truncate", "0.01").unwrap();
        c.set("chaos_corrupt", "0.02").unwrap();
        c.set("chaos_disconnect", "0.03").unwrap();
        c.set("chaos_recovery", "false").unwrap();
        c.set("chaos_session_deadline_ms", "750").unwrap();
        c.set("chaos_retry_base_ms", "7").unwrap();
        c.set("chaos_retry_max_ms", "300").unwrap();
        c.set("chaos_max_retries", "11").unwrap();

        std::fs::write(&path, c.to_kv_string()).unwrap();
        let mut back = Config::default();
        back.apply_file(&path).unwrap();
        // Field-level equality (not string equality, which would be
        // vacuous for any key to_kv_string forgot to emit).
        assert_eq!(back, c);
        assert_eq!(back.algorithm.name(), "fedasync");
        assert_eq!(back.force_beta, Some(0.25));
        assert_eq!(back.partition.sizes, vec![100, 200]);
        assert_eq!(back.topology.cells, 3);
        assert_eq!(
            back.topology.partitioner,
            crate::fl::topology::PartitionerKind::Latency
        );
        assert_eq!(back.topology.mixing, crate::fl::topology::MixingKind::Gossip);
        assert_eq!(back.synth.side, 12);
        assert_eq!(back.fleet.cohort_frac, 0.5);
        assert_eq!(back.fleet.cohort_size, 0);
        assert_eq!(back.serve.bind, "127.0.0.1:9000");
        assert_eq!(back.serve.period_ms, 250);
        assert_eq!(back.obs.trace_path, "/tmp/t.jsonl");
        assert_eq!(back.obs.sample_every, 4);
        assert_eq!(back.obs.admin_bind, "127.0.0.1:7448");
        assert_eq!(back.chaos.drop, 0.05);
        assert!(!back.chaos.recovery);
        assert_eq!(back.chaos.session_deadline_ms, 750);
        assert_eq!(back.chaos.max_retries, 11);

        // The default config round-trips too.
        let d = Config::default();
        std::fs::write(&path, d.to_kv_string()).unwrap();
        let mut back = Config::default();
        back.set("rounds", "999").unwrap(); // will be overwritten
        back.apply_file(&path).unwrap();
        assert_eq!(back, d);
    }
}
