//! Hand-rolled CLI layer (offline build: no clap).
//!
//! Grammar: `repro <subcommand> [--key value | --key=value]...`
//! Every `--key value` pair is routed to [`crate::config::Config::set`],
//! plus a few harness-level flags (`--config <file>`, `--out <dir>`,
//! `--log-level <l>`, `--f-star-rounds <n>`). The `--algo` key is a
//! **registry name**: it resolves through
//! [`crate::fl::registry`], and [`help_text`] enumerates whatever is
//! registered — a newly registered policy shows up here with zero edits
//! to this module.

use anyhow::{bail, Result};

use crate::config::Config;

/// Parsed command line.
#[derive(Debug, Clone)]
pub struct Cli {
    pub command: Command,
    pub config: Config,
    /// Output directory for CSVs (default `results/`).
    pub out_dir: std::path::PathBuf,
    /// Rounds used to estimate F(w*) for the fig3 gap curves.
    pub f_star_rounds: usize,
}

/// Subcommands (one per experiment in DESIGN.md §5 + `run`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Command {
    /// Run one algorithm and print per-round telemetry.
    Run,
    /// Fig. 3: loss-gap curves, PAOTA vs Local SGD vs COTAF.
    Fig3,
    /// Fig. 4: test accuracy vs rounds and vs time.
    Fig4,
    /// Table I: rounds & time to target accuracies.
    Table1,
    /// Ablations: `beta`, `dt`, `omega`, `latency`, `solver`,
    /// `scheduling`, `topology`, `mobility`, `replicates`.
    Ablation(String),
    /// Serve the coordinator over TCP (`fl::serve`).
    Serve,
    /// Replay a deterministic client fleet against a running server.
    Loadgen,
    /// Trace-journal tools (`summarize`): replay an `obs_trace_path`
    /// JSONL journal into latency/staleness tables.
    Trace(String),
    /// Print the effective config and exit.
    ShowConfig,
    /// Print help.
    Help,
}

/// Render the full help text. The ALGORITHMS section is generated from
/// the live policy registry, so registered extensions are listed without
/// any edit here.
pub fn help_text() -> String {
    let infos = crate::fl::registry::infos();
    let names: Vec<&str> = infos.iter().map(|i| i.name.as_str()).collect();
    let mut algos = String::new();
    for i in &infos {
        algos.push_str(&format!("    {:<13} {}", i.name, i.label));
        if !i.aliases.is_empty() {
            algos.push_str(&format!("  (aliases: {})", i.aliases.join(", ")));
        }
        algos.push('\n');
    }
    format!(
        "\
repro — PAOTA reproduction driver (semi-async FEEL via AirComp)

USAGE:
    repro <COMMAND> [--key value]...

COMMANDS:
    run           run one algorithm (--algo <name>, see ALGORITHMS)
    fig3          loss-gap curves E[F(w)]-F(w*)  (paper Fig. 3; use --n0 -74 for 3b)
    fig4          test accuracy vs rounds & time (paper Fig. 4)
    table1        time/rounds to target accuracy (paper Table I)
    ablation X    X ∈ beta | dt | omega | latency | solver | scheduling
                      | topology (cells × groups vs flat, fl::topology)
                      | mobility (roaming × handover policies, fl::mobility)
                      | replicates (seed grid → mean ± std curves)
    serve         serve the coordinator over TCP at serve_bind (fl::serve);
                      periodic algorithms only (paota | ca_paota | air_fedga)
    loadgen       replay serve_sessions concurrent client sessions against a
                      running server and report wire metrics (needs
                      artifacts_dir=native)
    trace summarize
                  replay the obs_trace_path JSONL journal into per-phase
                      latency + staleness tables (obs; schema paota-trace/1)
    show-config   print the effective configuration (re-parseable `key = value`)
    help          this text

ALGORITHMS (from the policy registry — register more, they appear here):
{algos}
HARNESS FLAGS:
    --config FILE        apply `key = value` lines before CLI overrides
    --out DIR            CSV output directory (default: results)
    --log-level L        debug|info|warn|error (or PAOTA_LOG env)
    --f-star-rounds N    centralized rounds for the F(w*) estimate (default 400)
    --jobs N             run N campaign scenarios concurrently
                         (alias for campaign_jobs; needs artifacts_dir=native)

CONFIG KEYS (defaults = paper §IV-A):
    seed rounds algo delta_t latency_lo latency_hi latency_kind
    latency_slow latency_slow_frac latency_sigma
    latency_ge_enter latency_ge_exit participants lr
    p_max power_cap_mode omega fedasync_gamma force_beta
    solver mip_max_k pla_segments mip_max_nodes
    dinkelbach_eps dinkelbach_iters l_smooth epsilon2
    bandwidth_hz n0 clients max_classes test_size sizes
    cells groups group_partitioner mixing mixing_every
    group_ready_frac group_mix group_power workers campaign_jobs
    mobility dwell_mean handover handover_every cell_noise_spread_db
    cohort_frac cohort_size
    serve_bind serve_max_sessions serve_queue_depth serve_period_ms
    serve_sessions serve_pace_ms
    obs_trace_path obs_sample_every obs_admin_bind
    chaos_drop chaos_delay chaos_delay_ms chaos_truncate chaos_corrupt
    chaos_disconnect chaos_recovery chaos_session_deadline_ms
    chaos_retry_base_ms chaos_retry_max_ms chaos_max_retries
    side pixel_noise label_noise jitter eval_every artifacts_dir
    (--algo accepts any of: {})
    (latency_kind: uniform|homogeneous|bimodal|lognormal|gilbert_elliott)
    (topology: cells>1 = hierarchical multi-cell; --algo air_fedga = grouped,
     flat or nested inside cells; group_power: dinkelbach|discounted)
    (mobility: static|markov|waypoint over cells>1; handover:
     deliver|forward|drop for in-flight updates at cell handover)
    (artifacts_dir=native selects the pure-Rust reference kernel)
    (perf: workers = train-pool threads, default PAOTA_WORKERS or auto;
     campaign_jobs/--jobs = concurrent scenarios — both bitwise-neutral)
    (fleet: cohort_frac/cohort_size sample the active cohort from a large
     fleet — memory & scheduling scale with the cohort, not clients;
     defaults = full participation, bitwise-identical to pre-fleet runs)
    (serve: serve_period_ms=0 closes rounds in lockstep — bitwise equal to
     the library loop; >0 holds each round open for that wall-clock period,
     surfacing Busy backpressure when serve_queue_depth is contended)
    (obs: obs_trace_path appends a sim-time-stamped JSONL event journal,
     obs_sample_every thins it per event kind, obs_admin_bind serves live
     /metrics + /healthz from `repro serve` — all off by default and
     bitwise-neutral when on; `trace summarize --obs_trace_path F` replays
     a journal)
    (chaos: per-frame fault rates on the serve/loadgen wire — drop, delay
     [delay_ms], truncate, corrupt, disconnect; deterministic per seed.
     chaos_recovery reconnects-and-resumes with jittered backoff
     [chaos_retry_base_ms..chaos_retry_max_ms, chaos_max_retries] and the
     server reclaims jobs idle past chaos_session_deadline_ms — with it,
     lockstep serve stays bitwise equal to the library loop; without it,
     period-mode rounds still close with whoever arrived)
",
        names.join("|")
    )
}

/// Parse `args` (without argv[0]).
pub fn parse(args: &[String]) -> Result<Cli> {
    let mut cli = Cli {
        command: Command::Help,
        config: Config::default(),
        out_dir: "results".into(),
        f_star_rounds: 400,
    };

    let mut it = args.iter().peekable();
    let Some(cmd) = it.next() else {
        return Ok(cli);
    };
    cli.command = match cmd.as_str() {
        "run" => Command::Run,
        "fig3" => Command::Fig3,
        "fig4" => Command::Fig4,
        "table1" => Command::Table1,
        "ablation" => {
            let Some(which) = it.next() else {
                bail!(
                    "ablation requires an argument \
                     (beta|dt|omega|latency|solver|scheduling|topology|mobility|replicates)"
                );
            };
            Command::Ablation(which.clone())
        }
        "serve" => Command::Serve,
        "loadgen" => Command::Loadgen,
        "trace" => {
            let Some(action) = it.next() else {
                bail!("trace requires an action (summarize)");
            };
            if action != "summarize" {
                bail!("unknown trace action {action:?} (try `trace summarize`)");
            }
            Command::Trace(action.clone())
        }
        "show-config" => Command::ShowConfig,
        "help" | "--help" | "-h" => Command::Help,
        other => bail!("unknown command {other:?} (try `repro help`)"),
    };

    // Flags: --key value or --key=value.
    let mut pending: Vec<(String, String)> = Vec::new();
    let mut config_file: Option<String> = None;
    while let Some(arg) = it.next() {
        let Some(stripped) = arg.strip_prefix("--") else {
            bail!("unexpected positional argument {arg:?}");
        };
        let (key, value) = if let Some((k, v)) = stripped.split_once('=') {
            (k.to_string(), v.to_string())
        } else {
            let Some(v) = it.next() else {
                bail!("flag --{stripped} needs a value");
            };
            (stripped.to_string(), v.clone())
        };
        match key.as_str() {
            "config" => config_file = Some(value),
            "out" => cli.out_dir = value.into(),
            "log-level" | "log_level" => {
                let Some(l) = crate::util::log::Level::parse(&value) else {
                    bail!("bad log level {value:?}");
                };
                crate::util::log::set_level(l);
            }
            "f-star-rounds" | "f_star_rounds" => {
                cli.f_star_rounds = value.parse()?;
            }
            _ => pending.push((key, value)),
        }
    }

    // File first, then CLI overrides (CLI wins).
    if let Some(path) = config_file {
        cli.config.apply_file(std::path::Path::new(&path))?;
    }
    for (k, v) in pending {
        cli.config.set(&k, &v)?;
    }
    cli.config.validate()?;
    Ok(cli)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Algorithm;

    fn args(s: &[&str]) -> Vec<String> {
        s.iter().map(|t| t.to_string()).collect()
    }

    #[test]
    fn parse_run_with_flags() {
        let cli = parse(&args(&["run", "--algo", "cotaf", "--rounds=10", "--n0", "-74"])).unwrap();
        assert_eq!(cli.command, Command::Run);
        assert_eq!(cli.config.algorithm, Algorithm::parse("cotaf").unwrap());
        assert_eq!(cli.config.rounds, 10);
        assert_eq!(cli.config.channel.n0_dbm_per_hz, -74.0);
    }

    #[test]
    fn registered_policies_parse_from_the_cli() {
        // ca_paota exists without any edit to this module or to config.
        let cli = parse(&args(&["run", "--algo", "ca_paota"])).unwrap();
        assert_eq!(cli.config.algorithm.name(), "ca_paota");
    }

    #[test]
    fn help_lists_registered_algorithms_dynamically() {
        let h = help_text();
        for name in ["paota", "local_sgd", "cotaf", "centralized", "fedasync", "ca_paota"] {
            assert!(h.contains(name), "help text missing {name}");
        }
        assert!(h.contains("aliases: localsgd, fedavg"), "{h}");
    }

    #[test]
    fn jobs_flag_maps_to_campaign_jobs() {
        let cli = parse(&args(&["fig4", "--jobs", "4", "--workers", "2"])).unwrap();
        assert_eq!(cli.config.perf.campaign_jobs, 4);
        assert_eq!(cli.config.perf.workers, 2);
        // Zero is rejected at parse time (validation runs there).
        assert!(parse(&args(&["run", "--jobs", "0"])).is_err());
    }

    #[test]
    fn mobility_keys_parse_from_the_cli() {
        let cli = parse(&args(&[
            "run",
            "--cells",
            "3",
            "--mobility",
            "markov",
            "--handover",
            "forward",
            "--dwell_mean",
            "2.5",
            "--handover_every",
            "2",
            "--cell_noise_spread_db",
            "6",
            "--group_power",
            "discounted",
        ]))
        .unwrap();
        assert_eq!(cli.config.mobility.kind, crate::fl::mobility::MobilityKind::Markov);
        assert_eq!(
            cli.config.mobility.handover,
            crate::fl::mobility::HandoverPolicy::Forward
        );
        assert_eq!(cli.config.mobility.dwell_mean, 2.5);
        assert_eq!(cli.config.mobility.handover_every, 2);
        assert_eq!(cli.config.mobility.cell_noise_spread_db, 6.0);
        assert_eq!(
            cli.config.topology.group_power,
            crate::fl::topology::GroupPowerMode::Discounted
        );
        // Validation runs at parse time: roaming needs cells ≥ 2.
        assert!(parse(&args(&["run", "--mobility", "waypoint"])).is_err());
    }

    #[test]
    fn fleet_keys_parse_from_the_cli() {
        let cli = parse(&args(&[
            "run",
            "--clients",
            "1000",
            "--cohort_frac",
            "0.1",
        ]))
        .unwrap();
        assert_eq!(cli.config.fleet.cohort_frac, 0.1);
        assert_eq!(cli.config.fleet.effective_cohort(1000), 100);
        let cli = parse(&args(&["run", "--cohort_size", "25"])).unwrap();
        assert_eq!(cli.config.fleet.cohort_size, 25);
        // Validation runs at parse time.
        assert!(parse(&args(&["run", "--cohort_frac", "0"])).is_err());
        assert!(parse(&args(&["run", "--cohort_size", "101"])).is_err());
        // Help advertises the keys.
        let h = help_text();
        assert!(h.contains("cohort_frac"), "{h}");
        assert!(h.contains("cohort_size"), "{h}");
    }

    #[test]
    fn serve_commands_and_keys_parse_from_the_cli() {
        let cli = parse(&args(&[
            "serve",
            "--serve_bind",
            "127.0.0.1:0",
            "--serve_max_sessions",
            "8",
            "--serve_queue_depth",
            "4",
            "--serve_period_ms",
            "250",
        ]))
        .unwrap();
        assert_eq!(cli.command, Command::Serve);
        assert_eq!(cli.config.serve.bind, "127.0.0.1:0");
        assert_eq!(cli.config.serve.max_sessions, 8);
        assert_eq!(cli.config.serve.queue_depth, 4);
        assert_eq!(cli.config.serve.period_ms, 250);

        let cli = parse(&args(&["loadgen", "--serve_sessions", "3", "--serve_pace_ms", "2"]))
            .unwrap();
        assert_eq!(cli.command, Command::Loadgen);
        assert_eq!(cli.config.serve.sessions, 3);
        assert_eq!(cli.config.serve.pace_ms, 2);

        // Validation runs at parse time.
        assert!(parse(&args(&["serve", "--serve_queue_depth", "0"])).is_err());
        assert!(parse(&args(&["serve", "--serve_bind", "nonsense"])).is_err());

        // Help advertises the commands and every [serve] key.
        let h = help_text();
        for needle in [
            "serve",
            "loadgen",
            "serve_bind",
            "serve_max_sessions",
            "serve_queue_depth",
            "serve_period_ms",
            "serve_sessions",
            "serve_pace_ms",
        ] {
            assert!(h.contains(needle), "help text missing {needle}");
        }
    }

    #[test]
    fn trace_command_and_obs_keys_parse_from_the_cli() {
        let cli = parse(&args(&[
            "trace",
            "summarize",
            "--obs_trace_path",
            "/tmp/t.jsonl",
        ]))
        .unwrap();
        assert_eq!(cli.command, Command::Trace("summarize".into()));
        assert_eq!(cli.config.obs.trace_path, "/tmp/t.jsonl");

        let cli = parse(&args(&[
            "serve",
            "--obs_admin_bind",
            "127.0.0.1:0",
            "--obs_sample_every",
            "5",
        ]))
        .unwrap();
        assert_eq!(cli.config.obs.admin_bind, "127.0.0.1:0");
        assert_eq!(cli.config.obs.sample_every, 5);

        // Missing/unknown action and invalid knobs are parse errors.
        assert!(parse(&args(&["trace"])).is_err());
        assert!(parse(&args(&["trace", "replay"])).is_err());
        assert!(parse(&args(&["serve", "--obs_sample_every", "0"])).is_err());
        assert!(parse(&args(&["serve", "--obs_admin_bind", "nonsense"])).is_err());

        // Help advertises the command and every [obs] key.
        let h = help_text();
        for needle in [
            "trace summarize",
            "obs_trace_path",
            "obs_sample_every",
            "obs_admin_bind",
        ] {
            assert!(h.contains(needle), "help text missing {needle}");
        }
    }

    #[test]
    fn chaos_keys_parse_from_the_cli() {
        let cli = parse(&args(&[
            "serve",
            "--chaos_drop",
            "0.05",
            "--chaos_disconnect",
            "0.01",
            "--chaos_recovery",
            "false",
            "--chaos_session_deadline_ms",
            "750",
            "--chaos_max_retries",
            "3",
        ]))
        .unwrap();
        assert_eq!(cli.config.chaos.drop, 0.05);
        assert_eq!(cli.config.chaos.disconnect, 0.01);
        assert!(!cli.config.chaos.recovery);
        assert_eq!(cli.config.chaos.session_deadline_ms, 750);
        assert_eq!(cli.config.chaos.max_retries, 3);

        // Out-of-range rates and degenerate knobs are parse errors.
        assert!(parse(&args(&["serve", "--chaos_drop", "1.5"])).is_err());
        assert!(parse(&args(&["loadgen", "--chaos_max_retries", "0"])).is_err());
        assert!(parse(&args(&["serve", "--chaos_delay", "lots"])).is_err());

        // Help advertises every [chaos] key.
        let h = help_text();
        for needle in [
            "chaos_drop",
            "chaos_delay",
            "chaos_delay_ms",
            "chaos_truncate",
            "chaos_corrupt",
            "chaos_disconnect",
            "chaos_recovery",
            "chaos_session_deadline_ms",
            "chaos_retry_base_ms",
            "chaos_retry_max_ms",
            "chaos_max_retries",
        ] {
            assert!(h.contains(needle), "help text missing {needle}");
        }
    }

    #[test]
    fn parse_ablation_arg() {
        let cli = parse(&args(&["ablation", "beta"])).unwrap();
        assert_eq!(cli.command, Command::Ablation("beta".into()));
        assert!(parse(&args(&["ablation"])).is_err());
    }

    #[test]
    fn empty_is_help() {
        assert_eq!(parse(&[]).unwrap().command, Command::Help);
    }

    #[test]
    fn unknown_command_and_flags_error() {
        assert!(parse(&args(&["frobnicate"])).is_err());
        assert!(parse(&args(&["run", "--no-such", "1"])).is_err());
        assert!(parse(&args(&["run", "stray"])).is_err());
        assert!(parse(&args(&["run", "--rounds"])).is_err());
    }

    #[test]
    fn cli_overrides_config_file() {
        let dir = std::env::temp_dir().join("paota_cli_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("base.cfg");
        std::fs::write(&path, "rounds = 7\nlr = 0.2\n").unwrap();
        let cli = parse(&args(&[
            "run",
            "--config",
            path.to_str().unwrap(),
            "--rounds",
            "99",
        ]))
        .unwrap();
        assert_eq!(cli.config.rounds, 99); // CLI wins
        assert_eq!(cli.config.lr, 0.2); // file survives
    }

    #[test]
    fn out_dir_and_fstar_flags() {
        let cli = parse(&args(&["fig3", "--out", "/tmp/x", "--f-star-rounds", "50"])).unwrap();
        assert_eq!(cli.out_dir, std::path::PathBuf::from("/tmp/x"));
        assert_eq!(cli.f_star_rounds, 50);
    }

    #[test]
    fn validation_runs_at_parse_time() {
        assert!(parse(&args(&["run", "--rounds", "0"])).is_err());
    }
}
