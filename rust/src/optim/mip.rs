//! 0-1 mixed-integer programming by branch-and-bound over the simplex LP
//! relaxation — the stand-in for the paper's IBM CPLEX call on problem
//! P4/(39).
//!
//! Binary variables are relaxed to `[0,1]` (upper-bound rows are added
//! automatically); branching fixes the most-fractional binary to 0/1 via
//! equality rows. Depth-first with best-bound pruning against the
//! incumbent; a node budget bounds worst-case blowup (the power-control
//! driver falls back to PCD for large instances — DESIGN.md §4.2).

use anyhow::Result;

use super::simplex::{Constraint, LinearProgram, LpStatus};

/// Outcome of a B&B run.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MipStatus {
    /// Proven optimal (within tolerance).
    Optimal,
    /// Node budget exhausted; best incumbent returned.
    NodeLimit,
    /// No feasible integer point found.
    Infeasible,
}

/// A 0-1 MIP: maximize `objective·x` over `constraints`, `x ≥ 0`, with
/// `binaries` constrained to {0,1}.
#[derive(Debug, Clone)]
pub struct Mip {
    pub lp: LinearProgram,
    /// Indices of binary variables.
    pub binaries: Vec<usize>,
    /// Node budget (default 5000).
    pub max_nodes: usize,
    /// Integrality tolerance.
    pub int_tol: f64,
}

/// B&B result.
#[derive(Debug, Clone)]
pub struct MipSolution {
    pub status: MipStatus,
    pub x: Vec<f64>,
    pub value: f64,
    pub nodes: usize,
}

impl Mip {
    pub fn new(lp: LinearProgram, binaries: Vec<usize>) -> Self {
        Self {
            lp,
            binaries,
            max_nodes: 5000,
            int_tol: 1e-6,
        }
    }

    /// Solve by DFS branch-and-bound.
    pub fn solve(&self) -> Result<MipSolution> {
        let n = self.lp.n_vars();
        // Base LP with binary upper bounds.
        let mut base = self.lp.clone();
        for &b in &self.binaries {
            let mut row = vec![0.0; n];
            row[b] = 1.0;
            base.constraints.push(Constraint::le(row, 1.0));
        }

        let mut best: Option<(Vec<f64>, f64)> = None;
        let mut nodes = 0usize;
        // Stack of (fixings) — each fixing is (var, value).
        let mut stack: Vec<Vec<(usize, f64)>> = vec![Vec::new()];
        let mut exhausted = true;

        while let Some(fixings) = stack.pop() {
            if nodes >= self.max_nodes {
                exhausted = false;
                break;
            }
            nodes += 1;

            let mut lp = base.clone();
            for &(var, val) in &fixings {
                let mut row = vec![0.0; n];
                row[var] = 1.0;
                lp.constraints.push(Constraint::eq(row, val));
            }
            let relax = lp.solve()?;
            match relax.status {
                LpStatus::Infeasible => continue,
                LpStatus::Unbounded => {
                    // Unbounded relaxation of a box-constrained binary
                    // problem means the continuous part is unbounded;
                    // propagate as an error-free prune is impossible.
                    anyhow::bail!("MIP relaxation unbounded");
                }
                LpStatus::Optimal => {}
            }
            // Bound: prune if it cannot beat the incumbent.
            if let Some((_, inc)) = &best {
                if relax.value <= *inc + 1e-9 {
                    continue;
                }
            }
            // Find most-fractional binary.
            let mut frac_var = None;
            let mut frac_dist = self.int_tol;
            for &b in &self.binaries {
                let v = relax.x[b];
                let d = (v - v.round()).abs();
                if d > frac_dist {
                    frac_dist = d;
                    frac_var = Some(b);
                }
            }
            match frac_var {
                None => {
                    // Integer-feasible.
                    if best.as_ref().map_or(true, |(_, inc)| relax.value > *inc) {
                        best = Some((relax.x.clone(), relax.value));
                    }
                }
                Some(var) => {
                    // Branch: explore the rounding-nearest child last so
                    // it is popped first (DFS dives toward the relaxation).
                    let v = relax.x[var];
                    let (first, second) = if v >= 0.5 { (0.0, 1.0) } else { (1.0, 0.0) };
                    for val in [first, second] {
                        let mut f = fixings.clone();
                        f.push((var, val));
                        stack.push(f);
                    }
                }
            }
        }

        Ok(match best {
            Some((x, value)) => MipSolution {
                status: if exhausted {
                    MipStatus::Optimal
                } else {
                    MipStatus::NodeLimit
                },
                x,
                value,
                nodes,
            },
            None => MipSolution {
                status: MipStatus::Infeasible,
                x: vec![0.0; n],
                value: f64::NEG_INFINITY,
                nodes,
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn knapsack_small() {
        // max 10a + 6b + 4c ; 5a + 4b + 3c ≤ 10 ; binaries → a=b=1 (16).
        let lp = LinearProgram {
            objective: vec![10.0, 6.0, 4.0],
            constraints: vec![Constraint::le(vec![5.0, 4.0, 3.0], 10.0)],
        };
        let sol = Mip::new(lp, vec![0, 1, 2]).solve().unwrap();
        assert_eq!(sol.status, MipStatus::Optimal);
        assert!((sol.value - 16.0).abs() < 1e-6, "value={}", sol.value);
        assert!((sol.x[0] - 1.0).abs() < 1e-6);
        assert!((sol.x[1] - 1.0).abs() < 1e-6);
        assert!(sol.x[2].abs() < 1e-6);
    }

    #[test]
    fn lp_relaxation_fractional_mip_rounds() {
        // max x ; 2x ≤ 1, binary x → LP gives 0.5, MIP must give 0.
        let lp = LinearProgram {
            objective: vec![1.0],
            constraints: vec![Constraint::le(vec![2.0], 1.0)],
        };
        let sol = Mip::new(lp, vec![0]).solve().unwrap();
        assert_eq!(sol.status, MipStatus::Optimal);
        assert!(sol.value.abs() < 1e-9);
    }

    #[test]
    fn mixed_continuous_and_binary() {
        // max 3b + y ; y ≤ 2 ; b + y ≤ 2.5 ; b binary → b=1, y=1.5 → 4.5.
        let lp = LinearProgram {
            objective: vec![3.0, 1.0],
            constraints: vec![
                Constraint::le(vec![0.0, 1.0], 2.0),
                Constraint::le(vec![1.0, 1.0], 2.5),
            ],
        };
        let sol = Mip::new(lp, vec![0]).solve().unwrap();
        assert_eq!(sol.status, MipStatus::Optimal);
        assert!((sol.value - 4.5).abs() < 1e-6);
    }

    #[test]
    fn infeasible_integer() {
        // 0.4 ≤ x ≤ 0.6 has no binary solution.
        let lp = LinearProgram {
            objective: vec![1.0],
            constraints: vec![
                Constraint::ge(vec![1.0], 0.4),
                Constraint::le(vec![1.0], 0.6),
            ],
        };
        let sol = Mip::new(lp, vec![0]).solve().unwrap();
        assert_eq!(sol.status, MipStatus::Infeasible);
    }

    #[test]
    fn matches_exhaustive_enumeration_random() {
        use crate::testing::{check, prop_assert, prop_close};
        check("B&B equals brute force on random knapsacks", 30, |g| {
            let n = g.usize_in(2..7);
            let obj: Vec<f64> = (0..n).map(|_| g.f64_in(-2.0..5.0)).collect();
            let w: Vec<f64> = (0..n).map(|_| g.f64_in(0.1..3.0)).collect();
            let cap = g.f64_in(1.0..5.0);
            let lp = LinearProgram {
                objective: obj.clone(),
                constraints: vec![Constraint::le(w.clone(), cap)],
            };
            let sol = Mip::new(lp, (0..n).collect()).solve().map_err(|e| e.to_string())?;
            // Brute force.
            let mut best = f64::NEG_INFINITY;
            for mask in 0..(1u32 << n) {
                let picked: Vec<f64> = (0..n)
                    .map(|i| if mask & (1 << i) != 0 { 1.0 } else { 0.0 })
                    .collect();
                let weight: f64 = w.iter().zip(&picked).map(|(a, b)| a * b).sum();
                if weight <= cap + 1e-9 {
                    let v: f64 = obj.iter().zip(&picked).map(|(a, b)| a * b).sum();
                    best = best.max(v);
                }
            }
            prop_assert(sol.status == MipStatus::Optimal, "not optimal")?;
            prop_close(sol.value, best, 1e-6, "objective")
        });
    }

    #[test]
    fn node_limit_returns_incumbent() {
        let n = 12;
        let obj: Vec<f64> = (0..n).map(|i| 1.0 + (i as f64) * 0.1).collect();
        let w: Vec<f64> = (0..n).map(|i| 1.0 + (i as f64 % 3.0)).collect();
        let lp = LinearProgram {
            objective: obj,
            constraints: vec![Constraint::le(w, 7.5)],
        };
        let mut mip = Mip::new(lp, (0..n).collect());
        mip.max_nodes = 3;
        let sol = mip.solve().unwrap();
        // With 3 nodes it may or may not find an incumbent, but it must
        // not claim optimality if the budget stopped the search.
        if sol.status == MipStatus::NodeLimit {
            assert!(sol.nodes <= 3);
        }
    }
}
