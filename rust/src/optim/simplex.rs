//! Two-phase dense tableau simplex.
//!
//! Maximizes `cᵀx` subject to general rows (`≤`, `≥`, `=`) and `x ≥ 0`.
//! Upper bounds are expressed by the caller as explicit `≤` rows (the MIP
//! layer does this for its `[0,1]` variables). Bland's rule guards against
//! cycling; problem sizes here are ~10²×10², where a dense tableau is the
//! right tool.

use anyhow::{bail, Result};

/// Row comparison operator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    Le,
    Ge,
    Eq,
}

/// One constraint row: `coeffs · x (op) rhs`.
#[derive(Debug, Clone)]
pub struct Constraint {
    pub coeffs: Vec<f64>,
    pub op: Op,
    pub rhs: f64,
}

impl Constraint {
    pub fn le(coeffs: Vec<f64>, rhs: f64) -> Self {
        Self { coeffs, op: Op::Le, rhs }
    }
    pub fn ge(coeffs: Vec<f64>, rhs: f64) -> Self {
        Self { coeffs, op: Op::Ge, rhs }
    }
    pub fn eq(coeffs: Vec<f64>, rhs: f64) -> Self {
        Self { coeffs, op: Op::Eq, rhs }
    }
}

/// Maximization LP in "natural" form.
#[derive(Debug, Clone)]
pub struct LinearProgram {
    /// Objective coefficients (maximize).
    pub objective: Vec<f64>,
    pub constraints: Vec<Constraint>,
}

/// Solver outcome.
#[derive(Debug, Clone, PartialEq)]
pub enum LpStatus {
    Optimal,
    Infeasible,
    Unbounded,
}

/// Primal solution.
#[derive(Debug, Clone)]
pub struct LpSolution {
    pub status: LpStatus,
    pub x: Vec<f64>,
    pub value: f64,
}

const EPS: f64 = 1e-9;
const MAX_ITERS: usize = 20_000;

struct Tableau {
    /// rows × cols, last column is rhs.
    t: Vec<Vec<f64>>,
    /// Basis variable per row.
    basis: Vec<usize>,
    n_rows: usize,
    n_cols: usize, // structural + slack + artificial (excludes rhs)
}

impl Tableau {
    fn pivot(&mut self, row: usize, col: usize) {
        let inv = 1.0 / self.t[row][col];
        for j in 0..=self.n_cols {
            self.t[row][j] *= inv;
        }
        for r in 0..self.n_rows {
            if r == row {
                continue;
            }
            let f = self.t[r][col];
            if f.abs() < EPS {
                continue;
            }
            for j in 0..=self.n_cols {
                self.t[r][j] -= f * self.t[row][j];
            }
        }
        self.basis[row] = col;
    }

    /// One simplex phase: maximize `obj` (length n_cols) given current basis.
    /// Returns Ok(true) if optimal, Ok(false) if unbounded.
    fn run(&mut self, obj: &[f64]) -> Result<bool> {
        // Dantzig's rule for speed; after a degeneracy-scaled number of
        // iterations, switch to Bland's rule, which provably cannot cycle
        // (Beale's example cycles under pure Dantzig).
        let bland_after = 4 * (self.n_rows + self.n_cols).max(16);
        for iter in 0..MAX_ITERS {
            let bland = iter >= bland_after;
            // Reduced costs rc_j = c_j - Σ_rows c_B[r]·t[r][j].
            let cb: Vec<f64> = self.basis.iter().map(|&b| obj[b]).collect();
            let mut entering = None;
            let mut best = EPS;
            for j in 0..self.n_cols {
                let mut rc = obj[j];
                for r in 0..self.n_rows {
                    if cb[r] != 0.0 {
                        rc -= cb[r] * self.t[r][j];
                    }
                }
                if bland {
                    // Bland: first improving column.
                    if rc > EPS {
                        entering = Some(j);
                        break;
                    }
                } else if rc > best {
                    best = rc;
                    entering = Some(j);
                }
            }
            let Some(col) = entering else {
                return Ok(true); // optimal
            };
            // Ratio test.
            let mut leave = None;
            let mut best_ratio = f64::INFINITY;
            for r in 0..self.n_rows {
                if self.t[r][col] > EPS {
                    let ratio = self.t[r][self.n_cols] / self.t[r][col];
                    if ratio < best_ratio - EPS
                        || (ratio < best_ratio + EPS
                            && leave.map_or(true, |lr: usize| self.basis[r] < self.basis[lr]))
                    {
                        best_ratio = ratio;
                        leave = Some(r);
                    }
                }
            }
            let Some(row) = leave else {
                return Ok(false); // unbounded
            };
            self.pivot(row, col);
        }
        bail!("simplex iteration limit hit");
    }

    fn objective_value(&self, obj: &[f64]) -> f64 {
        self.basis
            .iter()
            .enumerate()
            .map(|(r, &b)| obj[b] * self.t[r][self.n_cols])
            .sum()
    }
}

impl LinearProgram {
    /// Number of structural variables.
    pub fn n_vars(&self) -> usize {
        self.objective.len()
    }

    /// Solve with two-phase simplex.
    pub fn solve(&self) -> Result<LpSolution> {
        let n = self.n_vars();
        let m = self.constraints.len();
        for (i, c) in self.constraints.iter().enumerate() {
            if c.coeffs.len() != n {
                bail!("constraint {i} has {} coeffs, want {n}", c.coeffs.len());
            }
        }

        // Normalize rows to nonnegative rhs.
        let rows: Vec<Constraint> = self
            .constraints
            .iter()
            .map(|c| {
                if c.rhs < 0.0 {
                    Constraint {
                        coeffs: c.coeffs.iter().map(|v| -v).collect(),
                        op: match c.op {
                            Op::Le => Op::Ge,
                            Op::Ge => Op::Le,
                            Op::Eq => Op::Eq,
                        },
                        rhs: -c.rhs,
                    }
                } else {
                    c.clone()
                }
            })
            .collect();

        // Column layout: [structural | slacks/surplus | artificials].
        let n_slack = rows.iter().filter(|c| c.op != Op::Eq).count();
        let n_art = rows.iter().filter(|c| c.op != Op::Le).count();
        let n_cols = n + n_slack + n_art;

        let mut t = vec![vec![0.0; n_cols + 1]; m];
        let mut basis = vec![0usize; m];
        let mut s_off = n;
        let mut a_off = n + n_slack;

        for (r, c) in rows.iter().enumerate() {
            t[r][..n].copy_from_slice(&c.coeffs);
            t[r][n_cols] = c.rhs;
            match c.op {
                Op::Le => {
                    t[r][s_off] = 1.0;
                    basis[r] = s_off;
                    s_off += 1;
                }
                Op::Ge => {
                    t[r][s_off] = -1.0;
                    s_off += 1;
                    t[r][a_off] = 1.0;
                    basis[r] = a_off;
                    a_off += 1;
                }
                Op::Eq => {
                    t[r][a_off] = 1.0;
                    basis[r] = a_off;
                    a_off += 1;
                }
            }
        }

        let mut tab = Tableau {
            t,
            basis,
            n_rows: m,
            n_cols,
        };

        // Phase 1: maximize -Σ artificials.
        if n_art > 0 {
            let mut obj1 = vec![0.0; n_cols];
            for j in n + n_slack..n_cols {
                obj1[j] = -1.0;
            }
            if !tab.run(&obj1)? {
                bail!("phase-1 unbounded (cannot happen)");
            }
            if tab.objective_value(&obj1) < -1e-7 {
                return Ok(LpSolution {
                    status: LpStatus::Infeasible,
                    x: vec![0.0; n],
                    value: 0.0,
                });
            }
            // Drive any residual artificial out of the basis when possible.
            for r in 0..m {
                if tab.basis[r] >= n + n_slack {
                    if let Some(col) = (0..n + n_slack).find(|&j| tab.t[r][j].abs() > 1e-7) {
                        tab.pivot(r, col);
                    }
                }
            }
        }

        // Phase 2: original objective; artificials pinned at zero cost and
        // excluded from entering by a large negative cost.
        let mut obj2 = vec![0.0; n_cols];
        obj2[..n].copy_from_slice(&self.objective);
        for j in n + n_slack..n_cols {
            obj2[j] = -1e12;
        }
        let optimal = tab.run(&obj2)?;
        if !optimal {
            return Ok(LpSolution {
                status: LpStatus::Unbounded,
                x: vec![0.0; n],
                value: f64::INFINITY,
            });
        }

        let mut x = vec![0.0; n];
        for (r, &b) in tab.basis.iter().enumerate() {
            if b < n {
                x[b] = tab.t[r][n_cols];
            }
        }
        let value = self
            .objective
            .iter()
            .zip(&x)
            .map(|(c, v)| c * v)
            .sum();
        Ok(LpSolution {
            status: LpStatus::Optimal,
            x,
            value,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn solve(obj: Vec<f64>, cons: Vec<Constraint>) -> LpSolution {
        LinearProgram {
            objective: obj,
            constraints: cons,
        }
        .solve()
        .unwrap()
    }

    #[test]
    fn textbook_le_problem() {
        // max 3x + 5y; x ≤ 4; 2y ≤ 12; 3x + 2y ≤ 18 → (2, 6), value 36.
        let sol = solve(
            vec![3.0, 5.0],
            vec![
                Constraint::le(vec![1.0, 0.0], 4.0),
                Constraint::le(vec![0.0, 2.0], 12.0),
                Constraint::le(vec![3.0, 2.0], 18.0),
            ],
        );
        assert_eq!(sol.status, LpStatus::Optimal);
        assert!((sol.value - 36.0).abs() < 1e-7);
        assert!((sol.x[0] - 2.0).abs() < 1e-7);
        assert!((sol.x[1] - 6.0).abs() < 1e-7);
    }

    #[test]
    fn equality_constraints() {
        // max x + y; x + y = 5; x ≤ 3 → value 5.
        let sol = solve(
            vec![1.0, 1.0],
            vec![
                Constraint::eq(vec![1.0, 1.0], 5.0),
                Constraint::le(vec![1.0, 0.0], 3.0),
            ],
        );
        assert_eq!(sol.status, LpStatus::Optimal);
        assert!((sol.value - 5.0).abs() < 1e-7);
    }

    #[test]
    fn ge_constraints_two_phase() {
        // max -x - y; x + y ≥ 4; x ≤ 10; y ≤ 10 → value -4.
        let sol = solve(
            vec![-1.0, -1.0],
            vec![
                Constraint::ge(vec![1.0, 1.0], 4.0),
                Constraint::le(vec![1.0, 0.0], 10.0),
                Constraint::le(vec![0.0, 1.0], 10.0),
            ],
        );
        assert_eq!(sol.status, LpStatus::Optimal);
        assert!((sol.value + 4.0).abs() < 1e-7, "value={}", sol.value);
    }

    #[test]
    fn infeasible_detected() {
        // x ≤ 1 and x ≥ 3 cannot both hold.
        let sol = solve(
            vec![1.0],
            vec![
                Constraint::le(vec![1.0], 1.0),
                Constraint::ge(vec![1.0], 3.0),
            ],
        );
        assert_eq!(sol.status, LpStatus::Infeasible);
    }

    #[test]
    fn unbounded_detected() {
        // max x with only x ≥ 1.
        let sol = solve(vec![1.0], vec![Constraint::ge(vec![1.0], 1.0)]);
        assert_eq!(sol.status, LpStatus::Unbounded);
    }

    #[test]
    fn negative_rhs_normalization() {
        // max -x; -x ≥ -5 (i.e. x ≤ 5); x ≥ 2 → x = 2, value -2.
        let sol = solve(
            vec![-1.0],
            vec![
                Constraint::ge(vec![-1.0], -5.0),
                Constraint::ge(vec![1.0], 2.0),
            ],
        );
        assert_eq!(sol.status, LpStatus::Optimal);
        assert!((sol.value + 2.0).abs() < 1e-7);
    }

    #[test]
    fn degenerate_does_not_cycle() {
        // Classic degeneracy: multiple rows tie in the ratio test.
        let sol = solve(
            vec![0.75, -150.0, 0.02, -6.0],
            vec![
                Constraint::le(vec![0.25, -60.0, -0.04, 9.0], 0.0),
                Constraint::le(vec![0.5, -90.0, -0.02, 3.0], 0.0),
                Constraint::le(vec![0.0, 0.0, 1.0, 0.0], 1.0),
            ],
        );
        assert_eq!(sol.status, LpStatus::Optimal);
        assert!((sol.value - 0.05).abs() < 1e-6, "value={}", sol.value);
    }

    #[test]
    fn random_lp_feasibility_of_reported_solutions() {
        use crate::testing::{check, prop_assert};
        check("simplex solutions are feasible", 60, |g| {
            let n = g.usize_in(1..6);
            let m = g.usize_in(1..6);
            let obj: Vec<f64> = (0..n).map(|_| g.f64_in(-2.0..2.0)).collect();
            let mut cons = Vec::new();
            for _ in 0..m {
                let coeffs: Vec<f64> = (0..n).map(|_| g.f64_in(-1.0..2.0)).collect();
                cons.push(Constraint::le(coeffs, g.f64_in(0.5..5.0)));
            }
            // Box to keep things bounded.
            for i in 0..n {
                let mut e = vec![0.0; n];
                e[i] = 1.0;
                cons.push(Constraint::le(e, 3.0));
            }
            let lp = LinearProgram {
                objective: obj,
                constraints: cons.clone(),
            };
            let sol = lp.solve().map_err(|e| e.to_string())?;
            prop_assert(sol.status == LpStatus::Optimal, "not optimal")?;
            for (i, c) in cons.iter().enumerate() {
                let lhs: f64 = c.coeffs.iter().zip(&sol.x).map(|(a, b)| a * b).sum();
                prop_assert(lhs <= c.rhs + 1e-6, &format!("row {i} violated"))?;
            }
            prop_assert(sol.x.iter().all(|&v| v >= -1e-9), "negative variable")
        });
    }
}
