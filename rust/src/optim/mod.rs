//! Optimization substrate for the paper's power-control pipeline (§III-B).
//!
//! The paper minimizes a ratio of two convex quadratics over the box
//! `[0,1]^K` (problem **P2**) with Dinkelbach's parametric scheme
//! (Algorithm 2); each parametric subproblem **P3** is a (generally
//! nonconcave) quadratic maximization that the paper reduces to a 0-1
//! linear MIP via eigendecomposition + piecewise-linear approximation
//! (**P4**/eq. (39)), solved there by IBM CPLEX.
//!
//! CPLEX is proprietary, so this module IS the solver stack:
//!
//! * [`simplex`]    — two-phase dense tableau simplex (`≤`/`≥`/`=` rows).
//! * [`mip`]        — 0-1 branch-and-bound over the LP relaxation.
//! * [`quadratic`]  — box-constrained quadratic maximization: the faithful
//!   PLA→MIP path (small K) and projected coordinate descent (any K).
//! * [`dinkelbach`] — the outer fractional-programming loop.

pub mod dinkelbach;
pub mod mip;
pub mod quadratic;
pub mod simplex;

pub use dinkelbach::{maximize_ratio, DinkelbachReport};
pub use mip::{Mip, MipStatus};
pub use quadratic::{BoxQp, QpSolver};
pub use simplex::{Constraint, LinearProgram, LpStatus, Op};
