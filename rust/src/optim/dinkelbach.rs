//! Dinkelbach's parametric scheme for the quadratic fractional program
//! **P2** (Algorithm 2 of the paper).
//!
//! The paper *minimizes* `h₁(β)/h₂(β)` over the box, which is equivalent
//! to maximizing `h₂/h₁`; Dinkelbach iterates
//!
//! ```text
//!   β* ← argmax_β  F(β; λ) = h₂(β) − λ·h₁(β)
//!   λ  ← h₂(β*) / h₁(β*)
//! ```
//!
//! until `F(β*; λ) < ε`. The parametric subproblem is a (generally
//! nonconcave) box QP solved by either solver in [`super::quadratic`].
//! λ is monotonically non-decreasing and converges superlinearly to the
//! maximal ratio (Dinkelbach 1967; Gotoh & Konno 2001 for the quadratic
//! case the paper cites).

use anyhow::{bail, Result};

use super::quadratic::{BoxQp, QpSolver};
use crate::util::Rng;

/// One quadratic form `βᵀAβ + bᵀβ + c`.
#[derive(Debug, Clone)]
pub struct Quadratic {
    pub a: crate::linalg::Matrix,
    pub b: Vec<f64>,
    pub c: f64,
}

impl Quadratic {
    pub fn eval(&self, x: &[f64]) -> f64 {
        self.a.quad_form(x) + self.b.iter().zip(x).map(|(p, q)| p * q).sum::<f64>() + self.c
    }
}

/// Result of the Dinkelbach loop.
#[derive(Debug, Clone)]
pub struct DinkelbachReport {
    /// The maximizing β (per-client trade-off parameters).
    pub beta: Vec<f64>,
    /// Final ratio `h₂/h₁` (the maximized objective).
    pub ratio: f64,
    /// λ trace — non-decreasing by construction (property-tested).
    pub lambdas: Vec<f64>,
    /// Number of outer iterations.
    pub iters: usize,
}

/// Generic Dinkelbach loop: `h1_eval`/`h2_eval` evaluate the two
/// quadratics; `argmax(λ)` maximizes `F(β;λ) = h₂ − λh₁` over the box and
/// returns `(β*, F*)`. Specialized callers (the rank-one power-control
/// path, §Perf) plug in O(K)-per-sweep subproblem solvers.
pub fn maximize_ratio_generic(
    n: usize,
    h1_eval: impl Fn(&[f64]) -> f64,
    h2_eval: impl Fn(&[f64]) -> f64,
    mut argmax: impl FnMut(f64) -> Result<(Vec<f64>, f64)>,
    eps: f64,
    max_iters: usize,
) -> Result<DinkelbachReport> {
    // Initial λ from a feasible point (β = ½·1).
    let beta0 = vec![0.5; n];
    let h1v = h1_eval(&beta0);
    if h1v <= 0.0 {
        bail!("h1 not positive at the initial point (h1 = {h1v})");
    }
    let mut lambda = h2_eval(&beta0) / h1v;
    let mut lambdas = vec![lambda];
    let mut beta = beta0;

    for it in 1..=max_iters {
        let (b_star, f_star) = argmax(lambda)?;
        let h1s = h1_eval(&b_star);
        if h1s <= 0.0 {
            bail!("h1 non-positive at Dinkelbach iterate (h1 = {h1s})");
        }
        let new_lambda = h2_eval(&b_star) / h1s;
        // Keep the best iterate (inner solver is heuristic for PCD).
        if new_lambda >= lambda {
            beta = b_star;
        }
        let done = f_star < eps;
        lambda = lambda.max(new_lambda);
        lambdas.push(lambda);
        if done {
            return Ok(DinkelbachReport {
                beta,
                ratio: lambda,
                lambdas,
                iters: it,
            });
        }
    }
    // Converged by iteration budget; return the best seen.
    let iters = lambdas.len() - 1;
    Ok(DinkelbachReport {
        beta,
        ratio: lambda,
        lambdas,
        iters,
    })
}

/// Maximize `h₂(β)/h₁(β)` over `β ∈ [0,1]^K` with a dense subproblem
/// solver.
///
/// `h₁` must be strictly positive on the box (it is the paper's
/// denominator-after-inversion — term (d)+(e) of the bound, a sum of a PSD
/// quadratic and a positive constant).
pub fn maximize_ratio(
    h1: &Quadratic,
    h2: &Quadratic,
    solver: QpSolver,
    eps: f64,
    max_iters: usize,
    rng: &mut Rng,
) -> Result<DinkelbachReport> {
    let n = h1.b.len();
    if h2.b.len() != n {
        bail!("h1/h2 dimension mismatch");
    }
    maximize_ratio_generic(
        n,
        |x| h1.eval(x),
        |x| h2.eval(x),
        |lambda| {
            // F(β; λ) = h₂ − λh₁ as a single BoxQp.
            let qp = BoxQp {
                a: h2.a.add_scaled(&h1.a, -lambda),
                b: h2
                    .b
                    .iter()
                    .zip(&h1.b)
                    .map(|(q, g)| q - lambda * g)
                    .collect(),
                c: h2.c - lambda * h1.c,
            };
            qp.maximize(solver, rng)
        },
        eps,
        max_iters,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::testing::{check, prop_assert, prop_close};

    fn quad(diag: &[f64], b: Vec<f64>, c: f64) -> Quadratic {
        Quadratic {
            a: Matrix::diag(diag),
            b,
            c,
        }
    }

    #[test]
    fn scalar_ratio_known_optimum() {
        // max (x² + 1) / (x² − x + 1) on [0,1]: at x = 1 ratio = 2;
        // check interior too: ratio'(x) = 0 at x where derivative sign
        // flips; brute force confirms max at x = 1 → 2.0.
        let h2 = quad(&[1.0], vec![0.0], 1.0);
        let h1 = quad(&[1.0], vec![-1.0], 1.0);
        let mut rng = Rng::new(2);
        let rep = maximize_ratio(&h1, &h2, QpSolver::default(), 1e-10, 50, &mut rng).unwrap();
        // Brute-force the true max.
        let mut best = 0.0f64;
        for i in 0..=10_000 {
            let x = i as f64 / 10_000.0;
            best = best.max((x * x + 1.0) / (x * x - x + 1.0));
        }
        assert!((rep.ratio - best).abs() < 1e-6, "got {} want {best}", rep.ratio);
    }

    #[test]
    fn lambda_trace_monotone_nondecreasing() {
        check("Dinkelbach λ monotone", 25, |g| {
            let n = g.usize_in(1..6);
            let d1: Vec<f64> = (0..n).map(|_| g.f64_in(0.1..2.0)).collect();
            let d2: Vec<f64> = (0..n).map(|_| g.f64_in(-1.0..2.0)).collect();
            let b1: Vec<f64> = (0..n).map(|_| g.f64_in(-0.2..0.2)).collect();
            let b2: Vec<f64> = (0..n).map(|_| g.f64_in(-1.0..1.0)).collect();
            let h1 = quad(&d1, b1, 2.0 + n as f64); // positive on the box
            let h2 = quad(&d2, b2, 3.0);
            let mut rng = Rng::new(3);
            let rep = maximize_ratio(&h1, &h2, QpSolver::default(), 1e-9, 40, &mut rng)
                .map_err(|e| e.to_string())?;
            for w in rep.lambdas.windows(2) {
                prop_assert(w[1] >= w[0] - 1e-12, "λ decreased")?;
            }
            Ok(())
        });
    }

    #[test]
    fn ratio_matches_grid_search_2d() {
        check("Dinkelbach vs grid search", 10, |g| {
            let d1: Vec<f64> = (0..2).map(|_| g.f64_in(0.1..1.5)).collect();
            let d2: Vec<f64> = (0..2).map(|_| g.f64_in(-1.5..1.5)).collect();
            let h1 = quad(&d1, vec![g.f64_in(-0.3..0.3), g.f64_in(-0.3..0.3)], 1.5);
            let h2 = quad(&d2, vec![g.f64_in(-1.0..1.0), g.f64_in(-1.0..1.0)], 2.0);
            let mut rng = Rng::new(5);
            let rep = maximize_ratio(&h1, &h2, QpSolver::default(), 1e-10, 60, &mut rng)
                .map_err(|e| e.to_string())?;
            let mut best = f64::NEG_INFINITY;
            let steps = 60;
            for i in 0..=steps {
                for j in 0..=steps {
                    let x = [i as f64 / steps as f64, j as f64 / steps as f64];
                    best = best.max(h2.eval(&x) / h1.eval(&x));
                }
            }
            prop_close(rep.ratio, best, 5e-3, "ratio vs grid")
        });
    }

    #[test]
    fn rejects_nonpositive_denominator() {
        let h1 = quad(&[1.0], vec![0.0], -10.0);
        let h2 = quad(&[1.0], vec![0.0], 1.0);
        let mut rng = Rng::new(7);
        assert!(maximize_ratio(&h1, &h2, QpSolver::default(), 1e-9, 10, &mut rng).is_err());
    }

    #[test]
    fn pla_mip_inner_solver_agrees_with_pcd() {
        let h1 = quad(&[0.8, 1.2], vec![0.1, -0.1], 2.0);
        let h2 = quad(&[1.0, -0.5], vec![0.5, 0.8], 1.0);
        let mut rng = Rng::new(11);
        let pcd = maximize_ratio(&h1, &h2, QpSolver::default(), 1e-9, 40, &mut rng).unwrap();
        let mip = maximize_ratio(
            &h1,
            &h2,
            QpSolver::PlaMip {
                segments: 8,
                max_nodes: 4000,
            },
            1e-9,
            40,
            &mut rng,
        )
        .unwrap();
        assert!(
            (pcd.ratio - mip.ratio).abs() < 1e-2 * (1.0 + pcd.ratio.abs()),
            "pcd {} vs mip {}",
            pcd.ratio,
            mip.ratio
        );
    }
}
