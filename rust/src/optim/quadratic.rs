//! Box-constrained quadratic maximization: `max xᵀAx + bᵀx + c` over
//! `x ∈ [0,1]^n` — the Dinkelbach subproblem **P3** of the paper.
//!
//! Two solvers, matching DESIGN.md §4.2:
//!
//! * [`QpSolver::PlaMip`] — the paper's faithful path: diagonalize the
//!   quadratic (Jacobi), rotate to separable coordinates `z` (eq. (28)–
//!   (30)), piecewise-linearly approximate each scalar quadratic with ϱ
//!   segments (eq. (34)–(38)), and solve the resulting 0-1 linear MIP
//!   (eq. (39)) with branch-and-bound. Binaries are introduced only for
//!   coordinates whose quadratic is *convex* in the max direction — for
//!   concave coordinates the LP relaxation already lands on adjacent
//!   breakpoints, exactly the paper's `h × ϱ` binary count. The PLA
//!   solution is then polished with one coordinate-descent pass.
//! * [`QpSolver::Pcd`] — projected coordinate descent with *exact*
//!   per-coordinate maximization (each coordinate restriction is a scalar
//!   quadratic over `[0,1]`), multi-started from box corners and random
//!   interior points. Monotone, scales to K = 100, and agrees with the
//!   MIP to <1% objective on sizes where both run (bench `power_opt`).

use anyhow::Result;

use crate::linalg::{jacobi_eigen, Matrix};
use crate::optim::mip::{Mip, MipStatus};
use crate::optim::simplex::{Constraint, LinearProgram};
use crate::util::Rng;

/// `max xᵀAx + bᵀx + c` over the unit box (A symmetric).
#[derive(Debug, Clone)]
pub struct BoxQp {
    pub a: Matrix,
    pub b: Vec<f64>,
    pub c: f64,
}

/// Which P3 solver to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QpSolver {
    /// Paper-faithful PLA → 0-1 MIP (`segments`, `max_nodes`).
    PlaMip { segments: usize, max_nodes: usize },
    /// Projected coordinate descent (`starts`, `sweeps`).
    Pcd { starts: usize, sweeps: usize },
}

impl Default for QpSolver {
    fn default() -> Self {
        QpSolver::Pcd {
            starts: 8,
            sweeps: 60,
        }
    }
}

impl BoxQp {
    pub fn n(&self) -> usize {
        self.b.len()
    }

    /// Objective value at `x`.
    pub fn eval(&self, x: &[f64]) -> f64 {
        self.a.quad_form(x) + self.b.iter().zip(x).map(|(a, b)| a * b).sum::<f64>() + self.c
    }

    /// Maximize with the chosen solver; returns `(argmax, value)`.
    pub fn maximize(&self, solver: QpSolver, rng: &mut Rng) -> Result<(Vec<f64>, f64)> {
        match solver {
            QpSolver::Pcd { starts, sweeps } => Ok(self.maximize_pcd(starts, sweeps, rng)),
            QpSolver::PlaMip {
                segments,
                max_nodes,
            } => self.maximize_pla_mip(segments, max_nodes),
        }
    }

    // ------------------------------------------------------------------
    // Projected coordinate descent.
    // ------------------------------------------------------------------

    /// Exact maximization of the coordinate-k restriction over [0,1].
    fn best_coordinate(&self, x: &[f64], k: usize) -> f64 {
        let akk = self.a[(k, k)];
        // f(t) = akk t² + lin·t + const, lin = b_k + 2 Σ_{j≠k} a_kj x_j.
        let mut lin = self.b[k];
        for j in 0..self.n() {
            if j != k {
                lin += 2.0 * self.a[(k, j)] * x[j];
            }
        }
        if akk < -1e-12 {
            // Concave: interior vertex, clamped.
            (-lin / (2.0 * akk)).clamp(0.0, 1.0)
        } else {
            // Convex/linear: an endpoint.
            let f0 = 0.0;
            let f1 = akk + lin;
            if f1 > f0 {
                1.0
            } else {
                0.0
            }
        }
    }

    fn pcd_from(&self, mut x: Vec<f64>, sweeps: usize) -> (Vec<f64>, f64) {
        let n = self.n();
        for _ in 0..sweeps {
            let mut moved = 0.0f64;
            for k in 0..n {
                let nk = self.best_coordinate(&x, k);
                moved = moved.max((nk - x[k]).abs());
                x[k] = nk;
            }
            if moved < 1e-10 {
                break;
            }
        }
        let v = self.eval(&x);
        (x, v)
    }

    fn maximize_pcd(&self, starts: usize, sweeps: usize, rng: &mut Rng) -> (Vec<f64>, f64) {
        let n = self.n();
        let mut best: Option<(Vec<f64>, f64)> = None;
        let consider = |cand: (Vec<f64>, f64), best: &mut Option<(Vec<f64>, f64)>| {
            if best.as_ref().map_or(true, |(_, bv)| cand.1 > *bv) {
                *best = Some(cand);
            }
        };
        // Deterministic starts: all-zero, all-one, 0.5.
        for v in [0.0, 1.0, 0.5] {
            consider(self.pcd_from(vec![v; n], sweeps), &mut best);
        }
        // Random starts.
        for _ in 0..starts {
            let x: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
            consider(self.pcd_from(x, sweeps), &mut best);
        }
        best.unwrap()
    }

    // ------------------------------------------------------------------
    // Paper-faithful PLA → 0-1 MIP.
    // ------------------------------------------------------------------

    fn maximize_pla_mip(&self, segments: usize, max_nodes: usize) -> Result<(Vec<f64>, f64)> {
        let n = self.n();
        assert!(segments >= 1);
        // Diagonalize: A = V·diag(nᵢ)·Vᵀ; z = Vᵀx (orthogonal rotation),
        // objective = Σᵢ nᵢ zᵢ² + rᵢ zᵢ + c with r = Vᵀb, box x = Vz ∈ [0,1]ⁿ.
        let (eig, v) = jacobi_eigen(&self.a, 100);
        let r = v.t().matvec(&self.b);

        // Per-coordinate z ranges over the box (eq. (32)–(33)).
        let mut z_lo = vec![0.0f64; n];
        let mut z_hi = vec![0.0f64; n];
        for i in 0..n {
            for j in 0..n {
                let m = v[(j, i)]; // z_i = Σ_j V_ji x_j
                if m > 0.0 {
                    z_hi[i] += m;
                } else {
                    z_lo[i] += m;
                }
            }
        }

        // Variable layout: z offsets in γ space.
        // Per coordinate i: (segments+1) γ weights; binaries for convex
        // coordinates only (nᵢ > 0, the nonconcave part of the max).
        let pts = segments + 1;
        let n_gamma = n * pts;
        let convex: Vec<bool> = eig.iter().map(|&e| e > 1e-12).collect();
        let bin_offset: Vec<Option<usize>> = {
            let mut off = n_gamma;
            convex
                .iter()
                .map(|&cv| {
                    if cv {
                        let o = off;
                        off += segments;
                        Some(o)
                    } else {
                        None
                    }
                })
                .collect()
        };
        let n_total = n_gamma
            + convex.iter().filter(|&&c| c).count() * segments;

        // Breakpoints and their objective values.
        let mut zb = vec![vec![0.0f64; pts]; n];
        let mut fb = vec![vec![0.0f64; pts]; n];
        for i in 0..n {
            for j in 0..pts {
                let z = z_lo[i] + (z_hi[i] - z_lo[i]) * j as f64 / segments as f64;
                zb[i][j] = z;
                fb[i][j] = eig[i] * z * z + r[i] * z;
            }
        }

        // Objective over γ (binaries cost 0).
        let mut obj = vec![0.0f64; n_total];
        for i in 0..n {
            for j in 0..pts {
                obj[i * pts + j] = fb[i][j];
            }
        }

        let mut cons: Vec<Constraint> = Vec::new();
        // Σ_j γ_ij = 1 per coordinate (eq. (36)).
        for i in 0..n {
            let mut row = vec![0.0; n_total];
            for j in 0..pts {
                row[i * pts + j] = 1.0;
            }
            cons.push(Constraint::eq(row, 1.0));
        }
        // Box feasibility: x = Vz ∈ [0,1]ⁿ with z_i = Σ_j zb_ij γ_ij.
        // x_k = Σ_i V_ki z_i = Σ_i Σ_j V_ki·zb_ij·γ_ij.
        for k in 0..n {
            let mut row = vec![0.0; n_total];
            for i in 0..n {
                for j in 0..pts {
                    row[i * pts + j] += v[(k, i)] * zb[i][j];
                }
            }
            cons.push(Constraint::le(row.clone(), 1.0));
            cons.push(Constraint::ge(row, 0.0));
        }
        // SOS2 adjacency via binaries for convex coordinates (eq. (38)):
        // γ_i1 ≤ c_i1; γ_ij ≤ c_i,j-1 + c_ij; γ_i,p ≤ c_i,seg; Σ_j c_ij = 1.
        let mut binaries = Vec::new();
        for i in 0..n {
            let Some(boff) = bin_offset[i] else { continue };
            for s in 0..segments {
                binaries.push(boff + s);
            }
            for j in 0..pts {
                let mut row = vec![0.0; n_total];
                row[i * pts + j] = 1.0;
                if j > 0 {
                    row[boff + j - 1] -= 1.0;
                }
                if j < segments {
                    row[boff + j] -= 1.0;
                }
                cons.push(Constraint::le(row, 0.0));
            }
            let mut row = vec![0.0; n_total];
            for s in 0..segments {
                row[boff + s] = 1.0;
            }
            cons.push(Constraint::eq(row, 1.0));
        }

        let lp = LinearProgram {
            objective: obj,
            constraints: cons,
        };
        let mut mip = Mip::new(lp, binaries);
        mip.max_nodes = max_nodes;
        let sol = mip.solve()?;
        if sol.status == MipStatus::Infeasible {
            anyhow::bail!("PLA MIP infeasible (should not happen on a box)");
        }

        // Recover x = Vz and clamp tiny violations from the approximation.
        let mut z = vec![0.0f64; n];
        for i in 0..n {
            for j in 0..pts {
                z[i] += zb[i][j] * sol.x[i * pts + j];
            }
        }
        let mut x: Vec<f64> = v.matvec(&z).iter().map(|&t| t.clamp(0.0, 1.0)).collect();
        // Polish: PLA is an approximation — one exact coordinate-descent
        // pass from the MIP point removes the discretization error.
        let (px, pv) = self.pcd_from(std::mem::take(&mut x), 30);
        Ok((px, pv))
    }
}

/// Specialized box QP for the PAOTA power-control structure:
///
/// ```text
///   f(x) = s·(uᵀx + t)² + Σᵢ (dᵢ xᵢ² + bᵢ xᵢ) + c
/// ```
///
/// i.e. a rank-one quadratic plus a diagonal — exactly `h₂ − λh₁` of
/// problem P2 (`h₂ = (Σp)²` is rank-one in β, `h₁`'s quadratic is
/// diagonal). Coordinate descent here is **O(1) per coordinate** (the
/// rank-one inner product is maintained incrementally), so a full sweep
/// is O(K) instead of the dense solver's O(K²) — the §Perf optimization
/// for the per-round power solve at K = 100.
#[derive(Debug, Clone)]
pub struct RankOneQp {
    /// Rank-one coefficient s (may be any sign).
    pub s: f64,
    /// Rank-one direction u.
    pub u: Vec<f64>,
    /// Rank-one offset t.
    pub t: f64,
    /// Diagonal quadratic coefficients.
    pub diag: Vec<f64>,
    /// Linear coefficients.
    pub b: Vec<f64>,
    /// Constant.
    pub c: f64,
}

impl RankOneQp {
    pub fn n(&self) -> usize {
        self.u.len()
    }

    /// Objective value at `x`.
    pub fn eval(&self, x: &[f64]) -> f64 {
        let inner: f64 = self.u.iter().zip(x).map(|(a, b)| a * b).sum::<f64>() + self.t;
        let diag: f64 = x
            .iter()
            .enumerate()
            .map(|(i, &xi)| self.diag[i] * xi * xi + self.b[i] * xi)
            .sum();
        self.s * inner * inner + diag + self.c
    }

    /// One coordinate-descent pass from `x`, maintaining the rank-one
    /// inner product incrementally. Returns (x*, value).
    fn pcd_from(&self, mut x: Vec<f64>, sweeps: usize) -> (Vec<f64>, f64) {
        let n = self.n();
        let mut inner: f64 =
            self.u.iter().zip(&x).map(|(a, b)| a * b).sum::<f64>() + self.t;
        for _ in 0..sweeps {
            let mut moved = 0.0f64;
            for k in 0..n {
                // Restriction to coordinate k:
                //   f(xk) = (s·u_k² + diag_k)·xk² + (2s·u_k·rest + b_k)·xk + …
                // where rest = inner − u_k·x_k.
                let rest = inner - self.u[k] * x[k];
                let quad = self.s * self.u[k] * self.u[k] + self.diag[k];
                let lin = 2.0 * self.s * self.u[k] * rest + self.b[k];
                let nk = if quad < -1e-12 {
                    (-lin / (2.0 * quad)).clamp(0.0, 1.0)
                } else if quad + lin > 0.0 {
                    1.0
                } else {
                    0.0
                };
                moved = moved.max((nk - x[k]).abs());
                inner += self.u[k] * (nk - x[k]);
                x[k] = nk;
            }
            if moved < 1e-10 {
                break;
            }
        }
        let v = self.eval(&x);
        (x, v)
    }

    /// Multi-start maximization (same start schedule as the dense PCD).
    pub fn maximize_pcd(&self, starts: usize, sweeps: usize, rng: &mut Rng) -> (Vec<f64>, f64) {
        let n = self.n();
        let mut best: Option<(Vec<f64>, f64)> = None;
        let consider = |cand: (Vec<f64>, f64), best: &mut Option<(Vec<f64>, f64)>| {
            if best.as_ref().map_or(true, |(_, bv)| cand.1 > *bv) {
                *best = Some(cand);
            }
        };
        for v in [0.0, 1.0, 0.5] {
            consider(self.pcd_from(vec![v; n], sweeps), &mut best);
        }
        for _ in 0..starts {
            let x: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
            consider(self.pcd_from(x, sweeps), &mut best);
        }
        best.unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, prop_assert, prop_close};

    fn rng() -> Rng {
        Rng::new(1234)
    }

    fn neg_definite(n: usize, scale: f64) -> Matrix {
        let mut a = Matrix::zeros(n, n);
        for i in 0..n {
            a[(i, i)] = -scale * (1.0 + i as f64);
        }
        a
    }

    #[test]
    fn concave_interior_optimum_pcd() {
        // max -x² - y² + x + 0.5y → x = 0.5, y = 0.25.
        let qp = BoxQp {
            a: neg_definite(2, 1.0).add_scaled(&Matrix::zeros(2, 2), 0.0),
            b: vec![1.0, 0.5],
            c: 0.0,
        };
        // a = diag(-1, -2): optimum x = 0.5, y = 0.125.
        let (x, v) = qp.maximize(QpSolver::default(), &mut rng()).unwrap();
        assert!((x[0] - 0.5).abs() < 1e-8, "x={x:?}");
        assert!((x[1] - 0.125).abs() < 1e-8, "x={x:?}");
        assert!((v - (0.25 + 0.03125)).abs() < 1e-8);
    }

    #[test]
    fn convex_pushes_to_corner() {
        // max x² + y² over box → a corner with value 2.
        let qp = BoxQp {
            a: Matrix::eye(2),
            b: vec![0.0, 0.0],
            c: 0.0,
        };
        let (x, v) = qp.maximize(QpSolver::default(), &mut rng()).unwrap();
        assert!((v - 2.0).abs() < 1e-9, "v={v} x={x:?}");
    }

    #[test]
    fn pla_mip_matches_pcd_on_concave() {
        check("PLA-MIP ≈ PCD on concave quadratics", 10, |g| {
            let n = g.usize_in(1..4);
            let mut a = Matrix::zeros(n, n);
            for i in 0..n {
                a[(i, i)] = -g.f64_in(0.5..3.0);
            }
            // Mild off-diagonal coupling, keeping diagonal dominance
            // (hence concavity).
            for i in 0..n {
                for j in 0..i {
                    let v = g.f64_in(-0.1..0.1);
                    a[(i, j)] = v;
                    a[(j, i)] = v;
                }
            }
            let b: Vec<f64> = (0..n).map(|_| g.f64_in(-2.0..2.0)).collect();
            let qp = BoxQp { a, b, c: 0.0 };
            let mut r = Rng::new(7);
            let (_, v_pcd) = qp
                .maximize(QpSolver::Pcd { starts: 8, sweeps: 80 }, &mut r)
                .unwrap();
            let (_, v_mip) = qp
                .maximize(
                    QpSolver::PlaMip {
                        segments: 6,
                        max_nodes: 2000,
                    },
                    &mut r,
                )
                .unwrap();
            prop_close(v_mip, v_pcd, 2e-2, "objective agreement")
        });
    }

    #[test]
    fn pla_mip_handles_indefinite() {
        // Indefinite: one convex, one concave direction.
        let mut a = Matrix::zeros(2, 2);
        a[(0, 0)] = 1.5;
        a[(1, 1)] = -2.0;
        let qp = BoxQp {
            a,
            b: vec![-0.2, 1.0],
            c: 0.0,
        };
        let mut r = rng();
        let (x_mip, v_mip) = qp
            .maximize(
                QpSolver::PlaMip {
                    segments: 8,
                    max_nodes: 4000,
                },
                &mut r,
            )
            .unwrap();
        let (_, v_pcd) = qp
            .maximize(QpSolver::Pcd { starts: 16, sweeps: 80 }, &mut r)
            .unwrap();
        assert!(x_mip.iter().all(|&t| (-1e-9..=1.0 + 1e-9).contains(&t)));
        assert!(
            (v_mip - v_pcd).abs() <= 1e-2 * (1.0 + v_pcd.abs()),
            "mip {v_mip} vs pcd {v_pcd}"
        );
    }

    #[test]
    fn pcd_never_leaves_box_and_is_monotone_vs_start() {
        check("PCD feasible + improves", 40, |g| {
            let n = g.usize_in(1..8);
            let mut a = Matrix::zeros(n, n);
            for i in 0..n {
                for j in 0..=i {
                    let v = g.f64_in(-1.0..1.0);
                    a[(i, j)] = v;
                    a[(j, i)] = v;
                }
            }
            let b: Vec<f64> = (0..n).map(|_| g.f64_in(-1.0..1.0)).collect();
            let qp = BoxQp { a, b, c: 0.0 };
            let x0 = vec![0.5; n];
            let v0 = qp.eval(&x0);
            let (x, v) = qp.pcd_from(x0, 50);
            prop_assert(
                x.iter().all(|&t| (-1e-12..=1.0 + 1e-12).contains(&t)),
                "left the box",
            )?;
            prop_assert(v >= v0 - 1e-9, "descent in a maximizer")
        });
    }

    #[test]
    fn rank_one_matches_dense_solver() {
        check("RankOneQp ≡ dense BoxQp", 40, |g| {
            let n = g.usize_in(1..12);
            let s = g.f64_in(-2.0..2.0);
            let u: Vec<f64> = (0..n).map(|_| g.f64_in(-1.5..1.5)).collect();
            let t = g.f64_in(-1.0..1.0);
            let diag: Vec<f64> = (0..n).map(|_| g.f64_in(-2.0..0.5)).collect();
            let b: Vec<f64> = (0..n).map(|_| g.f64_in(-1.0..1.0)).collect();
            let r1 = RankOneQp {
                s,
                u: u.clone(),
                t,
                diag: diag.clone(),
                b: b.clone(),
                c: 0.3,
            };
            // Dense equivalent: A = s·uuᵀ + diag(diag); b' = b + 2stu; c' = st² + c.
            let mut a = Matrix::outer(&u, &u);
            for i in 0..n {
                for j in 0..n {
                    a[(i, j)] *= s;
                }
                a[(i, i)] += diag[i];
            }
            let dense = BoxQp {
                a,
                b: (0..n).map(|i| b[i] + 2.0 * s * t * u[i]).collect(),
                c: s * t * t + 0.3,
            };
            // Same objective at random points.
            for _ in 0..5 {
                let x: Vec<f64> = (0..n).map(|_| g.f64_in(0.0..1.0)).collect();
                prop_close(r1.eval(&x), dense.eval(&x), 1e-9, "eval equality")?;
            }
            // Same maximization result (multi-start PCD both sides).
            let mut ra = Rng::new(5);
            let mut rb = Rng::new(5);
            let (_, v1) = r1.maximize_pcd(8, 60, &mut ra);
            let (_, v2) = dense.maximize_pcd(8, 60, &mut rb);
            prop_close(v1, v2, 1e-6, "maximize equality")
        });
    }

    #[test]
    fn eval_matches_manual() {
        let mut a = Matrix::zeros(2, 2);
        a[(0, 0)] = 2.0;
        a[(0, 1)] = 1.0;
        a[(1, 0)] = 1.0;
        a[(1, 1)] = -1.0;
        let qp = BoxQp {
            a,
            b: vec![3.0, -1.0],
            c: 0.5,
        };
        // x = (1, 0.5): xᵀAx = 2 + 2·0.5 - 0.25 = 2.75; bᵀx = 2.5; +0.5.
        assert!((qp.eval(&[1.0, 0.5]) - 5.75).abs() < 1e-12);
    }
}
