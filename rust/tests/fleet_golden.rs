//! Fleet-scale golden pinning for the scale-out refactor (indexed event
//! queue, refcounted round models, SoA client state, cohort sampling).
//!
//! The contract has three legs, all bitwise and all artifact-free (the
//! pure-Rust native kernel), so CI exercises them on every push:
//!
//! 1. **Worker invariance** — every builtin policy, grouped AirComp and a
//!    roaming multi-cell run produce bit-identical telemetry and final
//!    weights at `workers = 1` and `workers = 2`. Per-run RNG streams
//!    derive only from the seed, so the pool is a pure wall-clock lever;
//!    any fleet-refactor regression that lets scheduling order leak into
//!    numerics fails here.
//! 2. **Full-cohort degeneracy** — `[fleet]` left at its defaults, or set
//!    to explicitly cover the fleet (`cohort_frac = 1.0`,
//!    `cohort_size = K`), is bitwise the pre-fleet run: cohort sampling
//!    consumes zero RNG draws when nobody is excluded.
//! 3. **Sampled cohorts** — a strict sub-fleet cohort is seed-
//!    deterministic (two identical configs agree bitwise) and never
//!    reports more participants than the cohort admits.
//!
//! Together with `golden_seed` (whose reference loops are independent
//! ports of the seed trainers and were untouched by the refactor), leg 2
//! proves the K=100 paper runs unchanged end to end.

use paota::config::{Algorithm, Config};
use paota::fl::topology::multi_cell;
use paota::fl::{self, RunResult, TrainContext};
use paota::runtime::Engine;

/// K = 100 fleet on the native kernel at a geometry small enough for
/// debug-mode CI (d_in = 64, 20–40 samples per client).
fn fleet_cfg(algo: &str) -> Config {
    let mut c = Config::default();
    c.algorithm = Algorithm::parse(algo).unwrap();
    c.rounds = 4;
    c.eval_every = 2;
    c.artifacts_dir = "native".into();
    c.synth.side = 8;
    c.partition.clients = 100;
    c.partition.sizes = vec![20, 40];
    c.partition.test_size = 32;
    c
}

fn run(cfg: &Config) -> RunResult {
    let engine = Engine::cpu().unwrap();
    let ctx = TrainContext::build(&engine, cfg).unwrap();
    fl::run_with_context(&ctx, cfg).unwrap()
}

fn assert_run_bitwise(tag: &str, got: &RunResult, want: &RunResult) {
    assert_eq!(got.records.len(), want.records.len(), "{tag}: record count");
    for (a, b) in got.records.iter().zip(&want.records) {
        let t = format!("{tag} round {}", b.round);
        assert_eq!(a.round, b.round, "{t}");
        assert_eq!(a.participants, b.participants, "{t}: participants");
        assert_eq!(a.sim_time.to_bits(), b.sim_time.to_bits(), "{t}: sim_time");
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "{t}: train_loss");
        assert_eq!(
            a.mean_staleness.to_bits(),
            b.mean_staleness.to_bits(),
            "{t}: staleness"
        );
        assert_eq!(a.mean_power.to_bits(), b.mean_power.to_bits(), "{t}: power");
    }
    assert_eq!(got.final_weights.len(), want.final_weights.len(), "{tag}");
    let same = got
        .final_weights
        .iter()
        .zip(&want.final_weights)
        .all(|(x, y)| x.to_bits() == y.to_bits());
    assert!(same, "{tag}: final weights drifted");
}

#[test]
fn builtin_policies_are_bitwise_invariant_to_worker_count() {
    for algo in ["paota", "local_sgd", "cotaf", "centralized", "fedasync"] {
        let mut one = fleet_cfg(algo);
        one.perf.workers = 1;
        let mut two = fleet_cfg(algo);
        two.perf.workers = 2;
        assert_run_bitwise(algo, &run(&two), &run(&one));
    }
}

#[test]
fn grouped_aircomp_is_bitwise_invariant_to_worker_count() {
    let mut one = fleet_cfg("air_fedga");
    one.topology.groups = 4;
    one.perf.workers = 1;
    let mut two = one.clone();
    two.perf.workers = 2;
    assert_run_bitwise("air_fedga", &run(&two), &run(&one));
}

#[test]
fn roaming_multi_cell_run_is_bitwise_invariant_to_worker_count() {
    let mut one = fleet_cfg("paota");
    one.partition.clients = 24; // multi-cell trains every cell: keep small
    one.topology.cells = 3;
    one.topology.mixing_every = 2;
    one.mobility.kind = paota::fl::mobility::MobilityKind::Markov;
    one.mobility.dwell_mean = 1.5;
    one.perf.workers = 1;
    let mut two = one.clone();
    two.perf.workers = 2;

    let engine = Engine::cpu().unwrap();
    let ctx1 = TrainContext::build(&engine, &one).unwrap();
    let want = multi_cell::run(&ctx1, &one).unwrap();
    let ctx2 = TrainContext::build(&engine, &two).unwrap();
    let got = multi_cell::run(&ctx2, &two).unwrap();

    assert_run_bitwise("markov merged", &got.merged, &want.merged);
    for (i, (a, b)) in got.cells.iter().zip(&want.cells).enumerate() {
        assert_run_bitwise(&format!("markov cell {i}"), a, b);
    }
}

#[test]
fn explicit_full_cohort_is_bitwise_the_default_run() {
    let base = fleet_cfg("paota");
    let want = run(&base);

    let mut frac = base.clone();
    frac.fleet.cohort_frac = 1.0; // the default, stated explicitly
    assert_run_bitwise("cohort_frac=1.0", &run(&frac), &want);

    let mut size = base.clone();
    size.fleet.cohort_size = size.partition.clients; // covers the fleet
    assert_run_bitwise("cohort_size=K", &run(&size), &want);
}

#[test]
fn sampled_cohort_is_deterministic_and_bounds_participants() {
    let mut cfg = fleet_cfg("paota");
    cfg.fleet.cohort_size = 25;
    cfg.validate().unwrap();

    let a = run(&cfg);
    let b = run(&cfg);
    assert_run_bitwise("cohort_size=25 replay", &b, &a);

    let total: usize = a.records.iter().map(|r| r.participants).sum();
    assert!(total > 0, "a 25-client cohort never uploaded in 4 rounds");
    for r in &a.records {
        assert!(
            r.participants <= 25,
            "round {}: {} participants from a 25-client cohort",
            r.round,
            r.participants
        );
    }

    // A different cohort knob spelling the same size picks the same
    // cohort (the FLEET stream depends only on seed and cohort size).
    let mut frac = fleet_cfg("paota");
    frac.fleet.cohort_frac = 0.25;
    assert_run_bitwise("cohort_frac=0.25", &run(&frac), &a);
}
