//! Wire-service integration tests (`fl::serve`), all on the native
//! kernel so they run artifact-free in CI:
//!
//! 1. **Loopback golden** — a lockstep (`serve_period_ms = 0`) serve run
//!    driven by the real loadgen over 127.0.0.1 is *bitwise identical* —
//!    record stream and final weights — to the in-process `fl::run` on
//!    the same config. The wire moves raw LE f32 bits, the round manager
//!    reassembles submissions into dispatch order, and local training is
//!    a pure function of `(w, xs, ys, lr)`, so the equality holds under
//!    arbitrary session interleaving.
//! 2. **Protocol semantics on the wire** — a hand-rolled client session
//!    exercises duplicate rejection, out-of-round rejection and `Busy`
//!    backpressure under a full (`serve_queue_depth = 1`) aggregation
//!    buffer in wall-clock period mode.
//! 3. **Startup validation** — non-periodic algorithms are refused at
//!    bind time.
//! 4. **Chaos golden** (PR 9) — the same lockstep loopback run with
//!    fault injection *and* recovery on is still bitwise identical to
//!    the library loop: faults live only on the wire, reclaimed jobs
//!    re-dispatch with their original `(pos, staleness, payload)`, and
//!    retraining is pure, so every recovered loss reproduces the same
//!    update.
//! 5. **Chaos liveness** — with recovery off and heavy unrecoverable
//!    loss, period-mode rounds still close on the wall clock with
//!    whoever arrived; losses surface in the stats instead of wedging
//!    the round manager.
//! 6. **Protocol fuzz** — truncated, bit-flipped, and hostile-length
//!    variants of valid frames never panic the frame reader; every
//!    corruption lands as a clean error or EOF.

use std::net::TcpStream;

use paota::config::{Algorithm, Config};
use paota::fl::serve::proto::{self, FrameRead, Msg, RejectCode};
use paota::fl::serve::{run_loadgen, Server};
use paota::fl::{self, RunResult, TrainContext};
use paota::obs::admin::http_get;

/// Small native-kernel fleet (debug-mode CI friendly).
fn serve_cfg() -> Config {
    let mut c = Config::default();
    c.algorithm = Algorithm::parse("paota").unwrap();
    c.rounds = 3;
    c.eval_every = 2;
    c.artifacts_dir = "native".into();
    c.synth.side = 6;
    c.partition.clients = 10;
    c.partition.sizes = vec![12, 20];
    c.partition.test_size = 16;
    c.serve.bind = "127.0.0.1:0".into();
    c
}

fn assert_run_bitwise(tag: &str, got: &RunResult, want: &RunResult) {
    assert_eq!(got.records.len(), want.records.len(), "{tag}: record count");
    for (a, b) in got.records.iter().zip(&want.records) {
        let t = format!("{tag} round {}", b.round);
        assert_eq!(a.round, b.round, "{t}");
        assert_eq!(a.participants, b.participants, "{t}: participants");
        assert_eq!(a.sim_time.to_bits(), b.sim_time.to_bits(), "{t}: sim_time");
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "{t}: train_loss");
        assert_eq!(
            a.mean_staleness.to_bits(),
            b.mean_staleness.to_bits(),
            "{t}: staleness"
        );
        assert_eq!(a.mean_power.to_bits(), b.mean_power.to_bits(), "{t}: power");
        assert_eq!(
            a.probe_loss.map(f32::to_bits),
            b.probe_loss.map(f32::to_bits),
            "{t}: probe_loss"
        );
        assert_eq!(
            a.eval.map(|e| (e.loss.to_bits(), e.accuracy.to_bits())),
            b.eval.map(|e| (e.loss.to_bits(), e.accuracy.to_bits())),
            "{t}: eval"
        );
    }
    assert_eq!(got.final_weights.len(), want.final_weights.len(), "{tag}: dim");
    let same = got
        .final_weights
        .iter()
        .zip(&want.final_weights)
        .all(|(x, y)| x.to_bits() == y.to_bits());
    assert!(same, "{tag}: final weights drifted");
}

/// The golden tie-down: loopback serve + loadgen, lockstep schedule,
/// bitwise equal to the library loop.
#[test]
fn loopback_serve_is_bitwise_identical_to_library_run() {
    let mut cfg = serve_cfg();
    cfg.serve.period_ms = 0; // lockstep: deterministic serial schedule
    cfg.serve.sessions = 3;

    let library = fl::run(&cfg).unwrap();

    let ctx = TrainContext::new(&cfg).unwrap();
    let server = Server::bind(&ctx, &cfg).unwrap();
    let addr = server.local_addr().to_string();

    let (outcome, report) = std::thread::scope(|s| {
        let lg_cfg = &cfg;
        let lg = s.spawn(move || run_loadgen(lg_cfg, &addr));
        let outcome = server.run().unwrap();
        (outcome, lg.join().unwrap().unwrap())
    });

    assert_run_bitwise("loopback", &outcome.result, &library);

    // Wire accounting: every dispatched job came back exactly once.
    assert_eq!(report.lost, 0, "loadgen lost updates: {report:?}");
    assert_eq!(report.acks, outcome.stats.accepted, "{report:?}");
    assert_eq!(outcome.stats.dispatched, outcome.stats.accepted, "{report:?}");
    assert_eq!(outcome.stats.duplicates, 0);
    assert_eq!(outcome.stats.out_of_round, 0);
    assert!(outcome.sessions >= 1 && outcome.sessions <= 3, "{}", outcome.sessions);
}

/// Observation neutrality + scrape consistency: the loopback run with
/// the obs layer fully on (admin listener, private registry, shared
/// trace journal) stays bitwise identical to the library loop, the
/// scraped counters agree *exactly* with the loadgen's own tallies, and
/// `repro trace summarize` reproduces the loadgen's submit percentiles
/// byte for byte.
#[test]
fn observed_loopback_matches_library_and_scrape_matches_loadgen() {
    let mut cfg = serve_cfg();
    cfg.serve.period_ms = 0;
    cfg.serve.sessions = 2;

    // Reference run *before* obs is switched on, so the journal holds
    // only the observed run's events.
    let library = fl::run(&cfg).unwrap();

    let trace_path = std::env::temp_dir()
        .join(format!("paota_serve_obs_{}.jsonl", std::process::id()))
        .to_string_lossy()
        .into_owned();
    std::fs::remove_file(&trace_path).ok();
    cfg.obs.trace_path = trace_path.clone();
    cfg.obs.sample_every = 1;
    cfg.obs.admin_bind = "127.0.0.1:0".into();

    let ctx = TrainContext::new(&cfg).unwrap();
    let server = Server::bind(&ctx, &cfg).unwrap();
    let addr = server.local_addr().to_string();
    let admin = server.admin_addr().expect("admin listener requested");

    // The admin listener is live from bind time.
    assert_eq!(http_get(admin, "/healthz").unwrap(), "ok\n");

    let (outcome, report) = std::thread::scope(|s| {
        let lg_cfg = &cfg;
        let lg = s.spawn(move || run_loadgen(lg_cfg, &addr));
        let outcome = server.run().unwrap();
        (outcome, lg.join().unwrap().unwrap())
    });

    assert_run_bitwise("observed loopback", &outcome.result, &library);
    assert_eq!(report.lost, 0, "{report:?}");

    // Exact-match accounting: every counter is bumped where its reply
    // frame is written, and every frame lands at exactly one session, so
    // the server's private registry and the loadgen tallies agree.
    let get = |name: &str| outcome.metrics.counter(name).get();
    assert_eq!(get("paota_serve_acks_total"), report.acks as u64, "{report:?}");
    assert_eq!(get("paota_serve_duplicates_total"), report.duplicates as u64);
    assert_eq!(get("paota_serve_out_of_round_total"), report.out_of_round as u64);
    assert_eq!(get("paota_serve_busy_total"), report.busy as u64, "{report:?}");
    assert_eq!(get("paota_serve_dispatched_total"), report.jobs as u64, "{report:?}");

    // The scrape endpoints (still alive in the outcome) serve the same
    // numbers over HTTP.
    let text = http_get(admin, "/metrics").unwrap();
    assert!(text.contains("# TYPE paota_serve_acks_total counter"), "{text}");
    assert!(
        text.contains(&format!("paota_serve_acks_total {}", report.acks)),
        "{text}"
    );
    assert!(
        text.contains(&format!("paota_serve_dispatched_total {}", report.jobs)),
        "{text}"
    );
    let json = http_get(admin, "/metrics.json").unwrap();
    assert!(json.contains("\"paota_serve_acks_total\""), "{json}");

    // The journal replays into the loadgen's own percentile line: same
    // samples (shortest round-trip f64 formatting), same nearest-rank
    // helpers, same `{:.2}` formatting.
    let summary = paota::obs::trace::summarize(&trace_path).unwrap();
    assert!(
        summary.contains(&format!("wire_submit {}", report.jobs)),
        "{summary}"
    );
    let want = format!(
        "# submit_ms p50={:.2} p90={:.2} p99={:.2}",
        report.submit_p50_ms, report.submit_p90_ms, report.submit_p99_ms
    );
    assert!(summary.contains(&want), "summary missing {want:?}\n{summary}");
    std::fs::remove_file(&trace_path).ok();
}

fn send(stream: &mut TcpStream, msg: &Msg) {
    proto::write_msg(stream, msg).unwrap();
}

fn recv(stream: &mut TcpStream) -> Msg {
    match proto::read_msg(stream).unwrap() {
        FrameRead::Msg(m) => m,
        other => panic!("expected a message, got {other:?}"),
    }
}

/// Fetch until a job arrives (the server answers `NoJob {done: false}`
/// between rounds).
fn fetch_job(stream: &mut TcpStream) -> (u64, u64, u64, Vec<f32>) {
    loop {
        send(stream, &Msg::FetchJob);
        match recv(stream) {
            Msg::Job {
                client,
                round,
                staleness,
                w,
                ..
            } => return (client, round, staleness, w),
            Msg::NoJob { done: false } => std::thread::sleep(std::time::Duration::from_millis(2)),
            other => panic!("unexpected fetch reply {other:?}"),
        }
    }
}

/// Duplicate / out-of-round / Busy semantics observed on the wire, in
/// wall-clock period mode with a depth-1 aggregation buffer.
#[test]
fn wire_rejects_duplicates_out_of_round_and_backpressures_when_full() {
    let mut cfg = serve_cfg();
    cfg.rounds = 2;
    // ΔT above latency_hi (15 s): every client arrives inside round 0, so
    // PAOTA (which schedules every ready client and weights via β)
    // deterministically dispatches all 6 jobs at the round-0 open.
    cfg.delta_t = 20.0;
    cfg.partition.clients = 6;
    // Period mode: the buffer drains only at the round close, so a
    // depth-1 buffer must answer Busy to the second accept attempt.
    cfg.serve.period_ms = 3000;
    cfg.serve.queue_depth = 1;

    let ctx = TrainContext::new(&cfg).unwrap();
    let server = Server::bind(&ctx, &cfg).unwrap();
    let addr = server.local_addr();

    let outcome = std::thread::scope(|s| {
        let client = s.spawn(move || {
            let mut stream = TcpStream::connect(addr).unwrap();
            send(&mut stream, &Msg::Hello { token: 7, resume: 0 });
            let Msg::Assign { session, dim, .. } = recv(&mut stream) else {
                panic!("expected Assign");
            };
            assert_eq!(session, 7);

            // Pull the whole round-0 dispatch.
            let mut jobs = Vec::new();
            for _ in 0..6 {
                jobs.push(fetch_job(&mut stream));
            }
            assert!(jobs.iter().all(|j| j.1 == 0), "{jobs:?}");
            assert!(jobs.iter().all(|j| j.3.len() == dim as usize));

            let submit = |stream: &mut TcpStream, j: &(u64, u64, u64, Vec<f32>), round: u64| {
                send(
                    stream,
                    &Msg::Submit {
                        client: j.0,
                        round,
                        staleness: j.2,
                        loss: 1.0,
                        // Echoing the base model back is a valid (if
                        // useless) local-training result — the test is
                        // about wire semantics, not learning.
                        weights: j.3.clone(),
                    },
                );
                recv(stream)
            };

            // First submission fills the depth-1 buffer.
            assert!(matches!(submit(&mut stream, &jobs[0], 0), Msg::Ack { .. }));
            // Second: buffer full → explicit backpressure.
            assert!(matches!(submit(&mut stream, &jobs[1], 0), Msg::Busy));
            // Same client, same round again → duplicate rejection.
            assert!(matches!(
                submit(&mut stream, &jobs[0], 0),
                Msg::Reject {
                    code: RejectCode::Duplicate,
                    ..
                }
            ));
            // A round never dispatched → out-of-round rejection.
            assert!(matches!(
                submit(&mut stream, &jobs[1], 99),
                Msg::Reject {
                    code: RejectCode::OutOfRound,
                    ..
                }
            ));
            send(&mut stream, &Msg::Bye);
            // Remaining jobs are deliberately abandoned: period mode
            // closes rounds on the wall clock, so the server finishes
            // without them.
        });
        let outcome = server.run().unwrap();
        client.join().unwrap();
        outcome
    });

    let s = outcome.stats;
    assert_eq!(s.dispatched, 6, "{s:?}");
    assert!(s.accepted >= 1, "{s:?}");
    assert!(s.busy >= 1, "{s:?}");
    assert!(s.duplicates >= 1, "{s:?}");
    assert!(s.out_of_round >= 1, "{s:?}");
    // Both rounds closed despite the abandoned jobs.
    assert_eq!(outcome.result.records.len(), 2);
}

/// The chaos golden tie-down: lockstep loopback with every fault kind
/// injected at a nonzero rate *and* recovery on is bitwise identical to
/// the library loop, and no update is lost — every injected failure is
/// healed by resubmit, reconnect-and-resume, or server-side reclaim.
#[test]
fn chaotic_loopback_with_recovery_matches_the_library_run() {
    let mut cfg = serve_cfg();
    cfg.serve.period_ms = 0; // lockstep: deterministic serial schedule
    cfg.serve.sessions = 3;

    // Reference run before chaos is switched on: the fault plan must
    // not leak into the training schedule.
    let library = fl::run(&cfg).unwrap();

    cfg.chaos.drop = 0.03;
    cfg.chaos.delay = 0.03;
    cfg.chaos.delay_ms = 5;
    cfg.chaos.truncate = 0.02;
    cfg.chaos.corrupt = 0.02;
    cfg.chaos.disconnect = 0.02;
    cfg.chaos.recovery = true;
    cfg.chaos.session_deadline_ms = 400;
    cfg.chaos.retry_base_ms = 5;
    cfg.chaos.retry_max_ms = 100;
    cfg.validate().unwrap();

    let ctx = TrainContext::new(&cfg).unwrap();
    let server = Server::bind(&ctx, &cfg).unwrap();
    let addr = server.local_addr().to_string();

    let (outcome, report) = std::thread::scope(|s| {
        let lg_cfg = &cfg;
        let lg = s.spawn(move || run_loadgen(lg_cfg, &addr));
        let outcome = server.run().unwrap();
        (outcome, lg.join().unwrap().unwrap())
    });

    assert_run_bitwise("chaotic loopback", &outcome.result, &library);
    assert_eq!(outcome.result.records.len(), cfg.rounds, "rounds must close");
    // Recovery heals every loss: no job ends without a terminal reply.
    // (Unlike the healthy-wire golden, dispatched may exceed accepted —
    // reclaimed jobs are dispatched again — and duplicates are legal
    // when a resubmit races its own recovered copy.)
    assert_eq!(report.lost, 0, "chaos with recovery lost updates: {report:?}");
    // A dropped Ack frame is tallied server-side but times out
    // client-side (the resubmit lands as Duplicate), so client acks can
    // only undercount server accepts.
    assert!(report.acks <= outcome.stats.accepted, "{report:?}");
}

/// Liveness under unrecoverable loss: with recovery off and heavy drop/
/// corrupt/disconnect rates, period-mode rounds still close on the wall
/// clock with whoever arrived — chaos degrades throughput, not
/// liveness — and the losses are visible in the stats.
#[test]
fn unrecoverable_chaos_still_closes_every_period_mode_round() {
    let mut cfg = serve_cfg();
    cfg.rounds = 3;
    cfg.serve.period_ms = 300;
    cfg.serve.sessions = 2;
    cfg.chaos.drop = 0.2;
    cfg.chaos.corrupt = 0.1;
    cfg.chaos.disconnect = 0.1;
    cfg.chaos.recovery = false;
    cfg.chaos.session_deadline_ms = 200;
    cfg.chaos.retry_base_ms = 5;
    cfg.chaos.retry_max_ms = 50;
    cfg.validate().unwrap();

    let ctx = TrainContext::new(&cfg).unwrap();
    let server = Server::bind(&ctx, &cfg).unwrap();
    let addr = server.local_addr().to_string();

    let (outcome, report) = std::thread::scope(|s| {
        let lg_cfg = &cfg;
        let lg = s.spawn(move || run_loadgen(lg_cfg, &addr));
        let outcome = server.run().unwrap();
        (outcome, lg.join().unwrap().unwrap())
    });

    // The liveness gate: every round closed despite unrecovered losses.
    assert_eq!(outcome.result.records.len(), 3, "rounds wedged: {report:?}");
    // The chaos was real and surfaced: faults were injected somewhere
    // (client tally or server counters).
    let server_faults: u64 = paota::fl::serve::FaultKind::ALL
        .iter()
        .map(|k| {
            outcome
                .metrics
                .counter(&format!("paota_faults_{}_total", k.name()))
                .get()
        })
        .sum();
    assert!(
        report.faults as u64 + server_faults > 0,
        "no faults injected: {report:?}"
    );
}

/// Protocol fuzz: truncations, single-bit flips, and hostile length
/// prefixes applied to valid frames must never panic the reader — and a
/// corrupt length claim must fail before any allocation its size.
#[test]
fn proto_reader_survives_truncation_and_bit_flips() {
    use paota::util::Rng;

    let mut rng = Rng::for_entity(0xF00D, 0x9, 0);
    let msgs = vec![
        Msg::Hello { token: 7, resume: 3 },
        Msg::FetchJob,
        Msg::NoJob { done: false },
        Msg::Busy,
        Msg::Bye,
        Msg::Submit {
            client: 3,
            round: 1,
            staleness: 2,
            loss: 0.5,
            weights: vec![0.25; 33],
        },
    ];
    for i in 0..200 {
        let mut frame = Vec::new();
        proto::write_msg(&mut frame, &msgs[rng.index(msgs.len())]).unwrap();
        match i % 3 {
            0 => {
                // Truncate anywhere, including inside the length prefix.
                let cut = rng.index(frame.len());
                frame.truncate(cut);
            }
            1 => {
                // Flip one bit anywhere.
                let byte = rng.index(frame.len());
                frame[byte] ^= 1 << rng.index(8);
            }
            _ => {
                // Hostile length claim, from zero to "allocate 4 GiB".
                let claims = [0u32, 1, 3, 0x0FFF_FFFF, 0x1000_0001, u32::MAX];
                let claim = claims[rng.index(claims.len())];
                frame[..4].copy_from_slice(&claim.to_le_bytes());
            }
        }
        // Any of: a (luckily still valid) message, clean EOF, or a
        // clean error. Panics and oversized allocations are the bugs.
        let _ = proto::read_msg(&mut &frame[..]);
    }
}

/// Synchronous/continuous policies cannot sit behind the ΔT-slotted
/// wire loop; the server refuses them at bind time.
#[test]
fn serve_refuses_non_periodic_algorithms() {
    let mut cfg = serve_cfg();
    cfg.algorithm = Algorithm::parse("local_sgd").unwrap();
    let ctx = TrainContext::new(&cfg).unwrap();
    let err = match Server::bind(&ctx, &cfg) {
        Err(e) => e,
        Ok(_) => panic!("local_sgd should not be servable"),
    };
    assert!(err.to_string().contains("periodic"), "{err}");
}
