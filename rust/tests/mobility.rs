//! Integration over the `fl::mobility` subsystem on the pure-Rust native
//! kernel: the static-degeneracy (bitwise) contract, fleet conservation
//! under every roaming model × handover policy, forward-handover
//! staleness monotonicity, worker-count invariance, and the mobility
//! ablation campaign — all artifact-free so CI exercises them on every
//! push.

use paota::config::{Algorithm, Config};
use paota::experiments;
use paota::fl::coordinator::streams;
use paota::fl::mobility::{self, HandoverPolicy, MobilityKind};
use paota::fl::topology::{multi_cell, MixingKind};
use paota::fl::{Coordinator, RunResult, TrainContext};
use paota::runtime::Engine;

/// Small 3-cell native-kernel config: fast in debug CI, enough churn at
/// dwell_mean 1.5 that every handover policy is exercised.
fn tiny_cfg() -> Config {
    let mut c = Config::default();
    c.rounds = 5;
    c.eval_every = 2;
    c.artifacts_dir = "native".into();
    c.synth.side = 8; // d_in = 64
    c.partition.clients = 12;
    c.partition.sizes = vec![40, 80];
    c.partition.test_size = 32;
    c.topology.cells = 3;
    c.topology.mixing = MixingKind::Cloud;
    c.topology.mixing_every = 2;
    c.mobility.dwell_mean = 1.5;
    c
}

fn build_ctx(cfg: &Config) -> (Engine, TrainContext) {
    let engine = Engine::cpu().unwrap();
    let ctx = TrainContext::build(&engine, cfg).unwrap();
    (engine, ctx)
}

fn assert_run_bitwise(tag: &str, got: &RunResult, want: &RunResult) {
    assert_eq!(got.records.len(), want.records.len(), "{tag}: record count");
    for (a, b) in got.records.iter().zip(&want.records) {
        let t = format!("{tag} round {}", b.round);
        assert_eq!(a.round, b.round, "{t}");
        assert_eq!(a.participants, b.participants, "{t}");
        assert_eq!(a.sim_time.to_bits(), b.sim_time.to_bits(), "{t}");
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "{t}");
        assert_eq!(a.mean_staleness.to_bits(), b.mean_staleness.to_bits(), "{t}");
        assert_eq!(a.mean_power.to_bits(), b.mean_power.to_bits(), "{t}");
    }
    let same = got
        .final_weights
        .iter()
        .zip(&want.final_weights)
        .all(|(x, y)| x.to_bits() == y.to_bits());
    assert!(same, "{tag}: final weights drifted");
}

#[test]
fn static_mobility_is_bitwise_the_frozen_multi_cell_run() {
    // The degeneracy contract: with `mobility = static` the handover
    // machinery runs (the model is consulted every slot) but finds zero
    // movers, so the run must be BITWISE the frozen-assignment multi-cell
    // run — whatever the handover policy or cadence knobs say.
    let base = tiny_cfg();
    assert_eq!(base.mobility.kind, MobilityKind::Static);
    let (_engine, ctx) = build_ctx(&base);
    let frozen = multi_cell::run(&ctx, &base).unwrap();
    assert_eq!(frozen.mobility.handovers, 0);
    assert_eq!(frozen.mobility.delivered, 0);
    assert!(frozen.mobility.per_round_moves.iter().all(|&m| m == 0));

    for policy in [HandoverPolicy::Deliver, HandoverPolicy::Forward, HandoverPolicy::Drop] {
        for every in [1usize, 3] {
            let mut cfg = base.clone();
            cfg.mobility.handover = policy;
            cfg.mobility.handover_every = every;
            let got = multi_cell::run(&ctx, &cfg).unwrap();
            let tag = format!("static/{}/every={every}", policy.name());
            assert_run_bitwise(&format!("{tag} merged"), &got.merged, &frozen.merged);
            for (i, (a, b)) in got.cells.iter().zip(&frozen.cells).enumerate() {
                assert_run_bitwise(&format!("{tag} cell {i}"), a, b);
            }
        }
    }
}

#[test]
fn every_client_attached_to_exactly_one_cell_at_every_step() {
    // The conservation property, across models × handover policies ×
    // seeds: the runner snapshots per-cell member counts after every
    // slot's sweep; each row must partition the 12-client fleet.
    for kind in [MobilityKind::Markov, MobilityKind::Waypoint] {
        for policy in [HandoverPolicy::Deliver, HandoverPolicy::Forward, HandoverPolicy::Drop] {
            let mut cfg = tiny_cfg();
            cfg.seed = 42 + policy.name().len() as u64; // vary seeds a bit
            cfg.mobility.kind = kind;
            cfg.mobility.handover = policy;
            let (_engine, ctx) = build_ctx(&cfg);
            let out = multi_cell::run(&ctx, &cfg).unwrap();
            let tag = format!("{}/{}", kind.name(), policy.name());
            assert_eq!(out.mobility.per_round_members.len(), cfg.rounds, "{tag}");
            for (r, members) in out.mobility.per_round_members.iter().enumerate() {
                assert_eq!(members.len(), cfg.topology.cells, "{tag} round {r}");
                assert_eq!(
                    members.iter().sum::<usize>(),
                    cfg.partition.clients,
                    "{tag} round {r}: fleet not conserved ({members:?})"
                );
            }
            // Applied churn bookkeeping is internally consistent.
            assert_eq!(
                out.mobility.per_round_moves.iter().sum::<usize>(),
                out.mobility.handovers,
                "{tag}"
            );
            assert_eq!(
                out.mobility.arrivals.iter().sum::<usize>(),
                out.mobility.handovers,
                "{tag}"
            );
            assert_eq!(
                out.mobility.departures.iter().sum::<usize>(),
                out.mobility.handovers,
                "{tag}"
            );
            assert_eq!(
                out.mobility.per_client.iter().sum::<usize>(),
                out.mobility.handovers,
                "{tag}"
            );
            assert_eq!(out.merged.records.len(), cfg.rounds, "{tag}");
        }
    }
}

#[test]
fn roaming_is_deterministic_and_changes_the_trajectory() {
    let mut cfg = tiny_cfg();
    cfg.mobility.kind = MobilityKind::Markov;
    cfg.mobility.handover = HandoverPolicy::Forward;
    let (_engine, ctx) = build_ctx(&cfg);
    let a = multi_cell::run(&ctx, &cfg).unwrap();
    let b = multi_cell::run(&ctx, &cfg).unwrap();
    assert_run_bitwise("markov/forward repeat", &a.merged, &b.merged);
    assert_eq!(a.mobility.handovers, b.mobility.handovers);
    assert!(a.mobility.handovers > 0, "dwell_mean 1.5 over 5 slots moved nobody");

    let frozen = {
        let mut c = cfg.clone();
        c.mobility.kind = MobilityKind::Static;
        multi_cell::run(&ctx, &c).unwrap()
    };
    assert_ne!(
        a.merged.final_weights, frozen.merged.final_weights,
        "roaming changed nothing"
    );
}

#[test]
fn handover_policies_treat_in_flight_work_differently() {
    // Same trajectory (same seed/model), three in-flight semantics —
    // the cloud models must diverge.
    let mut base = tiny_cfg();
    base.mobility.kind = MobilityKind::Markov;
    base.mobility.dwell_mean = 1.0; // maximal churn
    let (_engine, ctx) = build_ctx(&base);
    let mut finals = Vec::new();
    for policy in [HandoverPolicy::Deliver, HandoverPolicy::Forward, HandoverPolicy::Drop] {
        let mut cfg = base.clone();
        cfg.mobility.handover = policy;
        let out = multi_cell::run(&ctx, &cfg).unwrap();
        if policy == HandoverPolicy::Deliver {
            assert_eq!(
                out.mobility.delivered, out.mobility.handovers,
                "every applied deliver move must have delivered its upload first"
            );
        } else {
            assert_eq!(out.mobility.delivered, 0, "{}", policy.name());
        }
        finals.push((policy.name(), out.merged.final_weights));
    }
    for i in 0..finals.len() {
        for j in i + 1..finals.len() {
            assert_ne!(
                finals[i].1, finals[j].1,
                "{} and {} produced identical models under heavy churn",
                finals[i].0, finals[j].0
            );
        }
    }
}

#[test]
fn forward_handover_staleness_is_monotone_across_the_hop() {
    // Unit-level contract behind "staleness accrues across the hop": a
    // forwarded client keeps its base_round (and base weights), so its
    // staleness `round − base_round` can only grow while rounds advance.
    let mut cfg = tiny_cfg();
    cfg.topology.cells = 1; // plain coordinators, driven by hand
    let (_engine, ctx) = build_ctx(&cfg);
    let mut cell_a = Coordinator::new(&ctx, &cfg, streams::BATCH);
    let mut other = cfg.clone();
    other.seed ^= 0x9e37_79b9;
    let mut cell_b = Coordinator::new(&ctx, &other, streams::BATCH);
    cell_a.begin_periodic();
    cell_b.begin_periodic();

    let client = 3usize;
    let base_at_hop = cell_a.client_base_round(client);
    let d = cell_a.detach_client(client);
    assert_eq!(d.slot.base_round, base_at_hop);
    let was_ready = d.was_ready;
    let queued = d.queued_finish.is_some();
    assert!(was_ready || queued, "a spawned client is either training or ready");

    // Forward: the new cell sees the same base_round — staleness at any
    // later round r' is r' − base ≥ r − base for r' ≥ r.
    cell_b.admit_client(client, d);
    assert_eq!(cell_b.client_base_round(client), base_at_hop);
    for later in [base_at_hop + 1, base_at_hop + 4] {
        assert!(later.saturating_sub(cell_b.client_base_round(client)) >= later - base_at_hop);
    }

    // Drop/deliver tail: a fresh admit resets the base to the admit
    // round, discarding the accrued staleness (the carried flag is the
    // device's Gilbert–Elliott residence state).
    cell_b.admit_fresh(client, 2, false);
    assert_eq!(cell_b.client_base_round(client), 3);
}

#[test]
fn residence_coupled_channels_change_the_physical_layer() {
    // Spreading the per-cell noise floors must change the run (clients
    // now transmit through their resident cell's channel)…
    let base = tiny_cfg();
    let (_engine, ctx) = build_ctx(&base);
    let flat_noise = multi_cell::run(&ctx, &base).unwrap();
    let mut spread = base.clone();
    spread.mobility.cell_noise_spread_db = 100.0;
    let spread_out = multi_cell::run(&ctx, &spread).unwrap();
    assert_ne!(
        flat_noise.merged.final_weights, spread_out.merged.final_weights,
        "cell_noise_spread_db had no effect"
    );
    // …and a 0 dB spread is the bitwise identity (covered more broadly by
    // the static-degeneracy test; asserted directly here).
    let mut zero = base.clone();
    zero.mobility.cell_noise_spread_db = 0.0;
    let z = multi_cell::run(&ctx, &zero).unwrap();
    assert_run_bitwise("zero spread", &z.merged, &flat_noise.merged);
}

#[test]
fn parallel_workers_do_not_move_a_bit_under_roaming() {
    // The handover sweep runs between the (possibly concurrent) cell
    // steps; workers must stay bitwise invisible under churn.
    let mut serial = tiny_cfg();
    serial.mobility.kind = MobilityKind::Markov;
    serial.mobility.handover = HandoverPolicy::Forward;
    serial.perf.workers = 1;
    let mut par = serial.clone();
    par.perf.workers = 4;
    let ctx_s = TrainContext::new(&serial).unwrap();
    let ctx_p = TrainContext::new(&par).unwrap();
    let a = multi_cell::run(&ctx_s, &serial).unwrap();
    let b = multi_cell::run(&ctx_p, &par).unwrap();
    assert_eq!(a.mobility.handovers, b.mobility.handovers);
    assert_run_bitwise("workers=4 vs 1 merged", &b.merged, &a.merged);
    for (i, (x, y)) in b.cells.iter().zip(&a.cells).enumerate() {
        assert_run_bitwise(&format!("workers=4 vs 1 cell {i}"), x, y);
    }
}

#[test]
fn run_dispatch_routes_roaming_configs_like_any_multi_cell_run() {
    // `fl::run_with_context` must accept a roaming config unchanged and
    // return the merged stream.
    let mut cfg = tiny_cfg();
    cfg.mobility.kind = MobilityKind::Waypoint;
    cfg.mobility.handover = HandoverPolicy::Drop;
    cfg.algorithm = Algorithm::parse("paota").unwrap();
    let (_engine, ctx) = build_ctx(&cfg);
    let via_dispatch = paota::fl::run_with_context(&ctx, &cfg).unwrap();
    let direct = multi_cell::run(&ctx, &cfg).unwrap();
    assert_run_bitwise("dispatch vs direct", &via_dispatch, &direct.merged);
}

#[test]
fn mobility_ablation_emits_accuracy_and_churn_csvs() {
    let mut cfg = tiny_cfg();
    cfg.rounds = 3;
    cfg.topology = Default::default(); // the ablation sets its own tree
    cfg.mobility = Default::default();
    let dir = std::env::temp_dir().join("paota_mobility_ablation_test");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    experiments::ablation("mobility", &cfg, &dir).unwrap();
    let acc = std::fs::read_to_string(dir.join("ablation_mobility.csv")).unwrap();
    let churn = std::fs::read_to_string(dir.join("ablation_mobility_churn.csv")).unwrap();
    for series in [
        "static",
        "markov_deliver",
        "markov_forward",
        "markov_drop",
        "waypoint_deliver",
        "waypoint_forward",
        "waypoint_drop",
        "markov_deliver_snr6",
    ] {
        assert!(acc.contains(series), "missing series {series} in:\n{acc}");
        assert!(churn.contains(series), "missing churn series {series} in:\n{churn}");
    }
    // Churn schema: series,round,moves,members_per_cell with the member
    // counts slash-joined and conserving the fleet; the static series
    // never moves anyone.
    let lines: Vec<&str> = churn.lines().collect();
    assert_eq!(lines[0], "series,round,moves,members_per_cell");
    for line in &lines[1..] {
        let cols: Vec<&str> = line.split(',').collect();
        assert_eq!(cols.len(), 4, "{line}");
        let members: usize = cols[3].split('/').map(|m| m.parse::<usize>().unwrap()).sum();
        assert_eq!(members, cfg.partition.clients, "{line}");
        if cols[0] == "static" {
            assert_eq!(cols[2], "0", "{line}");
        }
    }
}

#[test]
fn trace_matches_applied_churn_for_immediate_policies() {
    // `forward`/`drop` apply every intended move the slot it is decided,
    // so the runner's applied churn must equal the model-level trace.
    let mut cfg = tiny_cfg();
    cfg.mobility.kind = MobilityKind::Markov;
    cfg.mobility.handover = HandoverPolicy::Forward;
    let t = mobility::trace(&cfg).unwrap();
    let (_engine, ctx) = build_ctx(&cfg);
    let out = multi_cell::run(&ctx, &cfg).unwrap();
    assert_eq!(out.mobility.handovers, t.total_moves);
    assert_eq!(out.mobility.per_round_moves, t.per_round_moves);
    assert_eq!(out.mobility.per_round_members, t.per_round_members);
    // `deliver` defers: applied churn never exceeds intent.
    let mut del = cfg.clone();
    del.mobility.handover = HandoverPolicy::Deliver;
    let d = multi_cell::run(&ctx, &del).unwrap();
    assert!(d.mobility.handovers <= t.total_moves);
}
