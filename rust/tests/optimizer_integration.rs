//! Integration of the full power-control pipeline (§III-B): P2 assembly →
//! Dinkelbach → P3 solvers, on realistic paper-scale inputs. No artifacts
//! required (pure Rust).

use paota::config::SolverKind;
use paota::optim::dinkelbach::maximize_ratio;
use paota::optim::QpSolver;
use paota::power::{
    build_p2, solve_power_control, staleness_factor, BoundConstants, ClientFactors,
    PowerSolverConfig,
};
use paota::util::Rng;

fn paper_consts() -> BoundConstants {
    BoundConstants {
        l_smooth: 10.0,
        epsilon2: 1.0,
        k_total: 100,
        dim: 8070,
        noise_power: 7.96e-14,
        omega: 3.0,
    }
}

fn realistic_factors(n: usize, seed: u64) -> Vec<ClientFactors> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| ClientFactors {
            stale_rounds: rng.index(4),
            cosine: rng.uniform(-1.0, 1.0),
            p_cap: rng.uniform(0.05, 0.6), // channel-inversion scale
        })
        .collect()
}

#[test]
fn paper_scale_solve_is_fast_and_feasible() {
    // 60 active clients — the typical PAOTA round at ΔT = 8.
    let factors = realistic_factors(60, 1);
    let consts = paper_consts();
    let cfg = PowerSolverConfig::default();
    let mut rng = Rng::new(2);
    let t0 = std::time::Instant::now();
    let alloc = solve_power_control(&factors, &consts, &cfg, &mut rng).unwrap();
    let elapsed = t0.elapsed();
    assert!(
        elapsed < std::time::Duration::from_millis(500),
        "power solve too slow: {elapsed:?}"
    );
    assert_eq!(alloc.powers.len(), 60);
    for (f, &p) in factors.iter().zip(&alloc.powers) {
        assert!(p >= -1e-9 && p <= f.p_cap + 1e-9);
    }
    assert!(alloc.ratio.is_finite() && alloc.ratio > 0.0);
}

#[test]
fn dinkelbach_ratio_beats_naive_allocations() {
    // The optimized β must achieve a ratio at least as good as β = 0,
    // β = 1, and 20 random β draws evaluated on the same P2.
    let factors = realistic_factors(10, 3);
    let consts = paper_consts();
    let (h1, h2, _, _) = build_p2(&factors, &consts);
    let mut rng = Rng::new(4);
    let rep = maximize_ratio(&h1, &h2, QpSolver::default(), 1e-8, 30, &mut rng).unwrap();

    let eval_ratio = |beta: &[f64]| h2.eval(beta) / h1.eval(beta);
    assert!(rep.ratio >= eval_ratio(&vec![0.0; 10]) - 1e-6);
    assert!(rep.ratio >= eval_ratio(&vec![1.0; 10]) - 1e-6);
    for _ in 0..20 {
        let beta: Vec<f64> = (0..10).map(|_| rng.f64()).collect();
        assert!(rep.ratio >= eval_ratio(&beta) - 1e-6);
    }
}

#[test]
fn mip_pipeline_matches_pcd_on_small_instances() {
    for seed in [5, 6, 7] {
        let factors = realistic_factors(4, seed);
        let consts = paper_consts();
        let mut rng = Rng::new(8);
        let pcd = solve_power_control(
            &factors,
            &consts,
            &PowerSolverConfig::default(),
            &mut rng,
        )
        .unwrap();
        let mip_cfg = PowerSolverConfig {
            solver: SolverKind::PlaMip,
            pla_segments: 8,
            ..PowerSolverConfig::default()
        };
        let mip = solve_power_control(&factors, &consts, &mip_cfg, &mut rng).unwrap();
        let rel = (mip.ratio - pcd.ratio).abs() / pcd.ratio.max(1e-12);
        assert!(
            rel < 0.01,
            "seed {seed}: MIP ratio {} vs PCD {} ({}% off)",
            mip.ratio,
            pcd.ratio,
            rel * 100.0
        );
    }
}

#[test]
fn mip_guard_falls_back_to_pcd_above_limit() {
    // With mip_max_k below the active-set size the MIP config must still
    // solve (via PCD) in reasonable time.
    let factors = realistic_factors(40, 9);
    let consts = paper_consts();
    let cfg = PowerSolverConfig {
        solver: SolverKind::PlaMip,
        mip_max_k: 12,
        ..PowerSolverConfig::default()
    };
    let mut rng = Rng::new(10);
    let t0 = std::time::Instant::now();
    let alloc = solve_power_control(&factors, &consts, &cfg, &mut rng).unwrap();
    assert!(t0.elapsed() < std::time::Duration::from_secs(1));
    assert_eq!(alloc.powers.len(), 40);
}

#[test]
fn staleness_discount_dominates_when_beta_forced_to_one() {
    // With β = 1 forced, powers must be exactly cap·ρ(s).
    let factors: Vec<ClientFactors> = (0..6)
        .map(|s| ClientFactors {
            stale_rounds: s,
            cosine: 0.3,
            p_cap: 10.0,
        })
        .collect();
    let cfg = PowerSolverConfig {
        force_beta: Some(1.0),
        ..PowerSolverConfig::default()
    };
    let mut rng = Rng::new(11);
    let alloc = solve_power_control(&factors, &paper_consts(), &cfg, &mut rng).unwrap();
    for (s, &p) in alloc.powers.iter().enumerate() {
        let want = 10.0 * staleness_factor(s, 3.0);
        assert!((p - want).abs() < 1e-12, "s={s}: {p} != {want}");
    }
    // Strictly decreasing in staleness.
    for w in alloc.powers.windows(2) {
        assert!(w[1] < w[0]);
    }
}

#[test]
fn similarity_dominates_when_beta_forced_to_zero() {
    // With β = 0 forced, powers must be exactly cap·(cos+1)/2.
    let cosines = [-1.0, -0.5, 0.0, 0.5, 1.0];
    let factors: Vec<ClientFactors> = cosines
        .iter()
        .map(|&c| ClientFactors {
            stale_rounds: 2,
            cosine: c,
            p_cap: 8.0,
        })
        .collect();
    let cfg = PowerSolverConfig {
        force_beta: Some(0.0),
        ..PowerSolverConfig::default()
    };
    let mut rng = Rng::new(12);
    let alloc = solve_power_control(&factors, &paper_consts(), &cfg, &mut rng).unwrap();
    for (&c, &p) in cosines.iter().zip(&alloc.powers) {
        let want = 8.0 * (c + 1.0) / 2.0;
        assert!((p - want).abs() < 1e-12);
    }
    assert_eq!(alloc.powers[0], 0.0); // fully opposed client is silenced
}

#[test]
fn dinkelbach_iterations_bounded_and_monotone_at_scale() {
    let factors = realistic_factors(80, 13);
    let consts = paper_consts();
    let (h1, h2, _, _) = build_p2(&factors, &consts);
    let mut rng = Rng::new(14);
    let rep = maximize_ratio(&h1, &h2, QpSolver::default(), 1e-8, 30, &mut rng).unwrap();
    assert!(rep.iters <= 30);
    for w in rep.lambdas.windows(2) {
        assert!(w[1] >= w[0] - 1e-12, "λ regressed: {:?}", rep.lambdas);
    }
}
