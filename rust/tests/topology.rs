//! Integration over the `fl::topology` subsystem on the pure-Rust native
//! kernel: grouped AirComp (`air_fedga`), multi-cell hierarchies, the
//! topology/replicates ablation campaigns, and the grid helper — all
//! artifact-free so CI exercises them on every push.

use paota::config::{Algorithm, Config};
use paota::experiments;
use paota::fl::mobility::{HandoverPolicy, MobilityKind};
use paota::fl::topology::{multi_cell, GroupPowerMode, MixingKind, NoMixing, PartitionerKind};
use paota::fl::{self, TrainContext};
use paota::runtime::Engine;

/// Small native-kernel config: fast in debug CI, heterogeneous enough
/// that groups fire on different slots and cells see stragglers.
fn tiny_cfg() -> Config {
    let mut c = Config::default();
    c.rounds = 4;
    c.eval_every = 2;
    c.artifacts_dir = "native".into();
    c.synth.side = 8; // d_in = 64
    c.partition.clients = 12;
    c.partition.sizes = vec![40, 80];
    c.partition.test_size = 48;
    c
}

fn build_ctx(cfg: &Config) -> (Engine, TrainContext) {
    let engine = Engine::cpu().unwrap();
    let ctx = TrainContext::build(&engine, cfg).unwrap();
    (engine, ctx)
}

#[test]
fn air_fedga_is_deterministic_and_diverges_from_flat_paota() {
    let mut cfg = tiny_cfg();
    cfg.algorithm = Algorithm::parse("air_fedga").unwrap();
    cfg.topology.groups = 3;
    cfg.topology.partitioner = PartitionerKind::Latency;

    let r1 = fl::run(&cfg).unwrap();
    let r2 = fl::run(&cfg).unwrap();
    assert_eq!(r1.final_weights, r2.final_weights, "air_fedga not seed-deterministic");
    assert_eq!(r1.records.len(), cfg.rounds);
    assert_eq!(r1.algorithm.name(), "air_fedga");
    for r in &r1.records {
        assert!(r.participants <= cfg.partition.clients);
    }

    let mut flat = cfg.clone();
    flat.algorithm = Algorithm::parse("paota").unwrap();
    let paota = fl::run(&flat).unwrap();
    assert_ne!(
        r1.final_weights, paota.final_weights,
        "grouped aggregation collapsed to flat paota"
    );
}

#[test]
fn air_fedga_group_readiness_gates_selection() {
    use paota::fl::coordinator::streams;
    use paota::fl::RngStreams;

    // 12 clients round-robin over 4 groups: group g = {g, g+4, g+8}.
    let mut cfg = tiny_cfg();
    cfg.algorithm = Algorithm::parse("air_fedga").unwrap();
    cfg.topology.groups = 4;
    cfg.topology.partitioner = PartitionerKind::RoundRobin;
    cfg.topology.group_ready_frac = 1.0;
    let (_engine, ctx) = build_ctx(&cfg);

    let mut strict = fl::build_policy(&ctx, &cfg).unwrap();
    let mut rngs = RngStreams::new(cfg.seed, streams::BATCH);
    // Group 0 is fully ready, group 1 only partially: with the
    // whole-group quorum only group 0 fires, its members kept in
    // offered order; 1 and 5 wait for client 9.
    let chosen = strict.select_participants(&[0, 4, 8, 1, 5], &mut rngs);
    assert_eq!(chosen, vec![0, 4, 8]);
    // No group is complete → nobody uploads this slot.
    assert!(strict.select_participants(&[1, 5, 2], &mut rngs).is_empty());

    // Quorum 1 (frac 0.25 of 3 members): every group with any ready
    // member fires.
    let mut eager_cfg = cfg.clone();
    eager_cfg.topology.group_ready_frac = 0.25;
    let mut eager = fl::build_policy(&ctx, &eager_cfg).unwrap();
    let chosen = eager.select_participants(&[0, 4, 8, 1, 5], &mut rngs);
    assert_eq!(chosen, vec![0, 4, 8, 1, 5]);

    // End-to-end the quorum changes the trajectory (both deterministic).
    let rs = fl::run(&cfg).unwrap();
    let re = fl::run(&eager_cfg).unwrap();
    assert_ne!(rs.final_weights, re.final_weights, "quorum had no effect");
}

#[test]
fn multi_cell_merges_telemetry_and_counts_every_cell() {
    let mut cfg = tiny_cfg();
    cfg.topology.cells = 2;
    cfg.topology.mixing = MixingKind::Cloud;
    cfg.topology.mixing_every = 2;
    let (_engine, ctx) = build_ctx(&cfg);

    let out = multi_cell::run(&ctx, &cfg).unwrap();
    assert_eq!(out.cells.len(), 2);
    assert_eq!(out.merged.records.len(), cfg.rounds);
    for (r, rec) in out.merged.records.iter().enumerate() {
        let cell_sum: usize = out.cells.iter().map(|c| c.records[r].participants).sum();
        assert_eq!(rec.participants, cell_sum, "round {r}");
        assert_eq!(rec.sim_time, (r as f64 + 1.0) * cfg.delta_t, "round {r}");
        // Merged eval follows the shared cadence.
        assert_eq!(rec.eval.is_some(), r % cfg.eval_every == 0 || r + 1 == cfg.rounds);
    }
    // The dispatch in fl::run_with_context returns the same merged run.
    let via_dispatch = fl::run_with_context(&ctx, &cfg).unwrap();
    assert_eq!(via_dispatch.final_weights, out.merged.final_weights);
    assert_eq!(via_dispatch.records.len(), out.merged.records.len());
}

#[test]
fn inter_cell_mixing_changes_the_outcome() {
    let mut cfg = tiny_cfg();
    cfg.topology.cells = 2;
    cfg.topology.mixing = MixingKind::Cloud;
    cfg.topology.mixing_every = 1;
    let (_engine, ctx) = build_ctx(&cfg);

    let mixed = multi_cell::run(&ctx, &cfg).unwrap();
    let isolated = multi_cell::MultiCellRunner::new(&ctx, &cfg)
        .with_mixing(Box::new(NoMixing))
        .run()
        .unwrap();
    assert_ne!(
        mixed.merged.final_weights, isolated.merged.final_weights,
        "cloud mixing had no effect on the cloud model"
    );
    // With cloud mixing every slot, the cells end on the same model.
    assert_eq!(
        mixed.cells[0].final_weights, mixed.cells[1].final_weights,
        "cloud FedAvg left the cells apart"
    );
    assert_ne!(
        isolated.cells[0].final_weights, isolated.cells[1].final_weights,
        "isolated cells converged identically — cell filtering broken?"
    );
}

#[test]
fn air_fedga_nests_inside_cells_and_survives_churn() {
    // The composed topology layers: cells > 1 with the grouped policy is
    // now valid — each cell builds its GroupMap over its own member
    // slice (and rebuilds it after handover churn).
    let mut cfg = tiny_cfg();
    cfg.algorithm = Algorithm::parse("air_fedga").unwrap();
    cfg.topology.cells = 2;
    cfg.topology.groups = 3;
    cfg.topology.group_ready_frac = 0.5;
    cfg.validate().unwrap(); // the PR-3 restriction is lifted
    let (_engine, ctx) = build_ctx(&cfg);

    let a = multi_cell::run(&ctx, &cfg).unwrap();
    let b = multi_cell::run(&ctx, &cfg).unwrap();
    assert_eq!(a.merged.final_weights, b.merged.final_weights, "nested run not deterministic");
    assert_eq!(a.merged.records.len(), cfg.rounds);
    assert_eq!(a.cells.len(), 2);

    // The nested tree is not the flat grouped run (cells actually split
    // the fleet) and not the flat-policy multi-cell run (groups actually
    // fire per cell).
    let mut flat_grouped = cfg.clone();
    flat_grouped.topology.cells = 1;
    let fg = fl::run_with_context(&ctx, &flat_grouped).unwrap();
    assert_ne!(a.merged.final_weights, fg.final_weights);
    let mut flat_policy = cfg.clone();
    flat_policy.algorithm = Algorithm::parse("paota").unwrap();
    let fp = multi_cell::run(&ctx, &flat_policy).unwrap();
    assert_ne!(a.merged.final_weights, fp.merged.final_weights);

    // With roaming on top, the per-cell maps rebuild after churn and the
    // run stays deterministic and conserving.
    let mut roam = cfg.clone();
    roam.mobility.kind = MobilityKind::Markov;
    roam.mobility.dwell_mean = 1.0;
    roam.mobility.handover = HandoverPolicy::Forward;
    let r1 = multi_cell::run(&ctx, &roam).unwrap();
    let r2 = multi_cell::run(&ctx, &roam).unwrap();
    assert_eq!(r1.merged.final_weights, r2.merged.final_weights);
    assert!(r1.mobility.handovers > 0, "no churn at dwell_mean 1");
    for members in &r1.mobility.per_round_members {
        assert_eq!(members.iter().sum::<usize>(), cfg.partition.clients);
    }
}

#[test]
fn group_power_modes_are_distinct_and_deterministic() {
    // The group-aware power control: the per-group Dinkelbach program
    // (default) vs the legacy staleness-discounted p_max.
    for mode in [GroupPowerMode::Dinkelbach, GroupPowerMode::Discounted] {
        assert_eq!(GroupPowerMode::parse(mode.name()).unwrap(), mode);
    }
    assert!(GroupPowerMode::parse("nope").is_err());

    let mut din = tiny_cfg();
    din.algorithm = Algorithm::parse("air_fedga").unwrap();
    din.topology.groups = 3;
    din.topology.group_ready_frac = 0.5;
    assert_eq!(din.topology.group_power, GroupPowerMode::Dinkelbach);
    // Similarity-only β pins the optimized powers to p_max·θ — a
    // different allocation than the discounted p_max·ρ whenever θ ≠ ρ
    // (θ = 0.5 on the first round's zero reference direction).
    din.force_beta = Some(0.0);
    let mut disc = din.clone();
    disc.topology.group_power = GroupPowerMode::Discounted;

    let d1 = fl::run(&din).unwrap();
    let d2 = fl::run(&din).unwrap();
    assert_eq!(d1.final_weights, d2.final_weights, "dinkelbach mode not deterministic");
    let l1 = fl::run(&disc).unwrap();
    assert_ne!(
        d1.final_weights, l1.final_weights,
        "group power mode had no effect on the trajectory"
    );
    // The per-group program respects the power cap in telemetry.
    for rec in &d1.records {
        assert!(rec.mean_power <= din.p_max + 1e-9, "round {}", rec.round);
    }
}

#[test]
fn multi_cell_rejects_non_periodic_policies() {
    let mut cfg = tiny_cfg();
    cfg.topology.cells = 2;
    cfg.algorithm = Algorithm::parse("local_sgd").unwrap();
    let (_engine, ctx) = build_ctx(&cfg);
    let err = multi_cell::run(&ctx, &cfg).unwrap_err().to_string();
    assert!(err.contains("periodic"), "{err}");
}

#[test]
fn topology_ablation_emits_all_series_from_one_campaign() {
    let cfg = tiny_cfg();
    let dir = std::env::temp_dir().join("paota_topology_ablation_test");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    experiments::ablation("topology", &cfg, &dir).unwrap();
    let text = std::fs::read_to_string(dir.join("ablation_topology.csv")).unwrap();
    for series in [
        "paota_flat",
        "air_fedga_rr_g4",
        "air_fedga_latency_g4",
        "air_fedga_channel_g4",
        "hier_2cell_cloud",
        "hier_3cell_gossip",
        "paota_flat_lognormal",
        "air_fedga_latency_g4_ge",
    ] {
        assert!(text.contains(series), "missing series {series} in:\n{text}");
    }
}

#[test]
fn replicates_ablation_emits_mean_std_error_bars() {
    let mut cfg = tiny_cfg();
    cfg.rounds = 2;
    cfg.eval_every = 1;
    let dir = std::env::temp_dir().join("paota_replicates_ablation_test");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    experiments::ablation("replicates", &cfg, &dir).unwrap();
    let text = std::fs::read_to_string(dir.join("ablation_replicates.csv")).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines[0], "series,round,time_s,mean,std,n");
    // Three algorithms, seed segments stripped, n = 3 replicates each.
    for series in ["PAOTA", "Local SGD", "COTAF"] {
        let row = lines
            .iter()
            .find(|l| l.starts_with(&format!("{series},")))
            .unwrap_or_else(|| panic!("no {series} rows in:\n{text}"));
        assert!(row.ends_with(",3"), "expected 3 replicates: {row}");
    }
}
