//! Golden-seed equivalence: each refactored [`AggregationPolicy`] must
//! reproduce the seed trainers' `RoundRecord` stream on a small fixed
//! config — same round count, participants, sim_time and staleness, and
//! losses/weights within f32 tolerance.
//!
//! The references below are direct ports of the seed's five hand-rolled
//! round loops (eval/probe/logging stripped — those draw no randomness),
//! so any drift in the coordinator's RNG-stream discipline, slot
//! scheduling, or aggregation plumbing fails loudly. The FedAsync
//! reference carries the *intended* trailing-flush semantics (the seed
//! dropped the last partial window's accumulated staleness; the
//! coordinator fixes that, and so does the reference).
//!
//! The suite **never self-skips**: with the AOT artifacts present it runs
//! at paper scale on the PJRT backend; without them it runs on the
//! pure-Rust reference kernel (`artifacts_dir = native`) at a reduced
//! geometry — the equivalence contract (coordinator vs seed round loops
//! over the *same* `ctx.rt`) is backend-agnostic, so artifact-free CI
//! exercises it on every push.

use paota::channel::Mac;
use paota::config::{Algorithm, Config, LatencyKind, PowerCapMode};
use paota::fl::{self, TrainContext};
use paota::power::{
    solve_power_control, staleness_factor, BoundConstants, ClientFactors, PowerSolverConfig,
};
use paota::runtime::{Engine, ModelRuntime};
use paota::sim::events::EventQueue;
use paota::sim::VirtualClock;
use paota::util::{vecmath, Rng};

fn have_artifacts() -> bool {
    ModelRuntime::default_dir().join("manifest.txt").exists()
}

fn quick_cfg(algo: &str) -> Config {
    let mut c = Config::default();
    c.algorithm = Algorithm::parse(algo).unwrap();
    c.rounds = 4;
    c.eval_every = 2;
    if !have_artifacts() {
        // Artifact-free environment: the native reference kernel at a
        // geometry small enough for debug-mode CI.
        eprintln!("golden_seed: no AOT artifacts — using the native reference kernel");
        c.artifacts_dir = "native".into();
        c.synth.side = 10; // d_in = 100
        c.partition.clients = 24;
        c.partition.sizes = vec![60, 120];
        c.partition.test_size = 80;
    }
    c
}

/// The telemetry fields the equivalence contract covers (eval/probe are
/// deterministic functions of the weights and draw no randomness, so the
/// references skip them).
struct RefRecord {
    round: usize,
    sim_time: f64,
    train_loss: f32,
    participants: usize,
    mean_staleness: f64,
    mean_power: f64,
}

struct RefRun {
    records: Vec<RefRecord>,
    final_weights: Vec<f32>,
}

fn close_f32(a: f32, b: f32, what: &str) {
    if a.is_nan() && b.is_nan() {
        return;
    }
    assert!(
        (a - b).abs() <= 1e-6 * (1.0 + b.abs()),
        "{what}: {a} vs {b}"
    );
}

fn assert_equivalent(got: &fl::RunResult, want: &RefRun) {
    assert_eq!(got.records.len(), want.records.len(), "record count");
    for (g, w) in got.records.iter().zip(&want.records) {
        let tag = format!("round {}", w.round);
        assert_eq!(g.round, w.round, "{tag}: round index");
        assert_eq!(g.participants, w.participants, "{tag}: participants");
        assert!(
            (g.sim_time - w.sim_time).abs() < 1e-9,
            "{tag}: sim_time {} vs {}",
            g.sim_time,
            w.sim_time
        );
        assert!(
            (g.mean_staleness - w.mean_staleness).abs() < 1e-9,
            "{tag}: staleness {} vs {}",
            g.mean_staleness,
            w.mean_staleness
        );
        close_f32(g.train_loss, w.train_loss, &format!("{tag}: train_loss"));
        assert!(
            (g.mean_power - w.mean_power).abs() <= 1e-9 * (1.0 + w.mean_power.abs()),
            "{tag}: mean_power {} vs {}",
            g.mean_power,
            w.mean_power
        );
    }
    assert_eq!(got.final_weights.len(), want.final_weights.len());
    for (i, (a, b)) in got
        .final_weights
        .iter()
        .zip(&want.final_weights)
        .enumerate()
    {
        close_f32(*a, *b, &format!("final_weights[{i}]"));
    }
}

fn run_case(cfg: &Config, reference: fn(&TrainContext, &Config) -> RefRun) {
    let engine = Engine::cpu().unwrap();
    let ctx = TrainContext::build(&engine, cfg).unwrap();
    let got = fl::run_with_context(&ctx, cfg).unwrap();
    let want = reference(&ctx, cfg);
    assert_equivalent(&got, &want);
}

// ---------------------------------------------------------------------
// Reference round loops (seed ports).
// ---------------------------------------------------------------------

fn ref_paota(ctx: &TrainContext, cfg: &Config) -> RefRun {
    struct Slot {
        base_round: usize,
        base_weights: Vec<f32>,
        finish_time: f64,
    }
    let dim = ctx.dim();
    let k = ctx.clients();
    let latency = cfg.latency();
    let mac = Mac::new(cfg.channel);
    let consts = BoundConstants {
        l_smooth: cfg.l_smooth,
        epsilon2: cfg.epsilon2,
        k_total: k,
        dim,
        noise_power: cfg.channel.noise_power(),
        omega: cfg.omega,
    };
    let solver_cfg = PowerSolverConfig {
        solver: cfg.solver,
        mip_max_k: cfg.mip_max_k,
        pla_segments: cfg.pla_segments,
        mip_max_nodes: cfg.mip_max_nodes,
        dinkelbach_eps: cfg.dinkelbach_eps,
        dinkelbach_iters: cfg.dinkelbach_iters,
        force_beta: cfg.force_beta,
    };

    let mut lat_rng = Rng::with_stream(cfg.seed, 0x1a7);
    let mut batch_rng = Rng::with_stream(cfg.seed, 0xba7c);
    let mut chan_rng = Rng::with_stream(cfg.seed, 0xc4a2);
    let mut opt_rng = Rng::with_stream(cfg.seed, 0x0b7);

    let mut w_g = ctx.init_weights();
    let mut last_delta = vec![0.0f32; dim];
    let mut slots: Vec<Slot> = (0..k)
        .map(|_| Slot {
            base_round: 0,
            base_weights: w_g.clone(),
            finish_time: latency.draw(&mut lat_rng),
        })
        .collect();

    let mut stack = vec![0.0f32; k * dim];
    let mut coef = vec![0.0f32; k];
    let mut scratch = vec![0.0f32; dim];
    let mut records = Vec::with_capacity(cfg.rounds);

    for round in 0..cfg.rounds {
        let slot_end = (round as f64 + 1.0) * cfg.delta_t;
        let ready: Vec<usize> = (0..k).filter(|&i| slots[i].finish_time <= slot_end).collect();

        let mut train_loss_sum = 0.0f64;
        let mut staleness_sum = 0.0f64;
        let mut updates: Vec<(usize, Vec<f32>, usize, f64)> = Vec::with_capacity(ready.len());

        let jobs: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = ready
            .iter()
            .map(|&i| {
                let (xs, ys) = ctx.partition.client(i).sample_batches(
                    ctx.rt.manifest().local_steps,
                    ctx.rt.manifest().batch,
                    &mut batch_rng,
                );
                (slots[i].base_weights.clone(), xs, ys)
            })
            .collect();
        let outs = ctx.train_many(jobs, cfg.lr).unwrap();
        for (&i, out) in ready.iter().zip(outs) {
            let staleness = round.saturating_sub(slots[i].base_round);
            train_loss_sum += out.loss as f64;
            staleness_sum += staleness as f64;
            vecmath::sub(&out.weights, &slots[i].base_weights, &mut scratch);
            let cosine = vecmath::cosine(&scratch, &last_delta);
            updates.push((i, out.weights, staleness, cosine));
        }

        let mut mean_power = 0.0;
        if !updates.is_empty() {
            let gains = mac.draw_fading_gains(&mut chan_rng, updates.len());
            let factors: Vec<ClientFactors> = updates
                .iter()
                .zip(&gains)
                .map(|((_, w_k, stale, cosine), &g2)| ClientFactors {
                    stale_rounds: *stale,
                    cosine: *cosine,
                    p_cap: match cfg.power_cap_mode {
                        PowerCapMode::Paper => cfg.p_max,
                        PowerCapMode::Inversion => {
                            mac.effective_power_cap(cfg.p_max, g2, vecmath::norm(w_k))
                        }
                    },
                })
                .collect();
            let alloc = solve_power_control(&factors, &consts, &solver_cfg, &mut opt_rng).unwrap();

            coef.iter_mut().for_each(|c| *c = 0.0);
            stack.iter_mut().for_each(|v| *v = 0.0);
            let mut sigma_sum = 0.0f64;
            for (slot_idx, (i, w_k, _, _)) in updates.iter().enumerate() {
                coef[*i] = alloc.powers[slot_idx] as f32;
                sigma_sum += alloc.powers[slot_idx];
                stack[i * dim..(i + 1) * dim].copy_from_slice(w_k);
            }
            mean_power = sigma_sum / updates.len() as f64;
            if sigma_sum > 0.0 {
                let noise = mac.channel_noise(&mut chan_rng, dim);
                let new_w = ctx.rt.aggregate(&stack, &coef, &noise).unwrap();
                vecmath::sub(&new_w, &w_g, &mut last_delta);
                w_g = new_w;
            }
            for (i, _, _, _) in &updates {
                slots[*i] = Slot {
                    base_round: round + 1,
                    base_weights: w_g.clone(),
                    finish_time: slot_end + latency.draw(&mut lat_rng),
                };
            }
        }

        let n_up = updates.len();
        records.push(RefRecord {
            round,
            sim_time: slot_end,
            train_loss: if n_up > 0 {
                (train_loss_sum / n_up as f64) as f32
            } else {
                f32::NAN
            },
            participants: n_up,
            mean_staleness: if n_up > 0 {
                staleness_sum / n_up as f64
            } else {
                0.0
            },
            mean_power,
        });
    }
    RefRun {
        records,
        final_weights: w_g,
    }
}

fn ref_local_sgd(ctx: &TrainContext, cfg: &Config) -> RefRun {
    let dim = ctx.dim();
    let k = ctx.clients();
    let m = ctx.rt.manifest().clone();
    let participants = ctx.sync_participants(cfg);
    let latency = cfg.latency();

    let mut lat_rng = Rng::with_stream(cfg.seed, 0x1a7);
    let mut batch_rng = Rng::with_stream(cfg.seed, 0xba7c);
    let mut pick_rng = Rng::with_stream(cfg.seed, 0x91c4);

    let mut w_g = ctx.init_weights();
    let mut clock = VirtualClock::new();
    let mut stack = vec![0.0f32; k * dim];
    let mut coef = vec![0.0f32; k];
    let noise = vec![0.0f32; dim];
    let mut records = Vec::with_capacity(cfg.rounds);

    for round in 0..cfg.rounds {
        let chosen = pick_rng.choose_indices(k, participants);
        let mut round_time = 0.0f64;
        let mut train_loss_sum = 0.0f64;
        coef.iter_mut().for_each(|c| *c = 0.0);
        stack.iter_mut().for_each(|v| *v = 0.0);

        let jobs: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = chosen
            .iter()
            .map(|&i| {
                round_time = round_time.max(latency.draw(&mut lat_rng));
                let (xs, ys) =
                    ctx.partition.client(i).sample_batches(m.local_steps, m.batch, &mut batch_rng);
                (w_g.clone(), xs, ys)
            })
            .collect();
        for (&i, out) in chosen.iter().zip(ctx.train_many(jobs, cfg.lr).unwrap()) {
            train_loss_sum += out.loss as f64;
            stack[i * dim..(i + 1) * dim].copy_from_slice(&out.weights);
            coef[i] = ctx.partition.client(i).data.len() as f32;
        }
        clock.advance(round_time);
        w_g = ctx.rt.aggregate(&stack, &coef, &noise).unwrap();

        records.push(RefRecord {
            round,
            sim_time: clock.now(),
            train_loss: (train_loss_sum / participants as f64) as f32,
            participants,
            mean_staleness: 0.0,
            mean_power: 0.0,
        });
    }
    RefRun {
        records,
        final_weights: w_g,
    }
}

fn ref_cotaf(ctx: &TrainContext, cfg: &Config) -> RefRun {
    let dim = ctx.dim();
    let k = ctx.clients();
    let m = ctx.rt.manifest().clone();
    let participants = ctx.sync_participants(cfg);
    let latency = cfg.latency();
    let mac = Mac::new(cfg.channel);

    let mut lat_rng = Rng::with_stream(cfg.seed, 0x1a7);
    let mut batch_rng = Rng::with_stream(cfg.seed, 0xba7c);
    let mut pick_rng = Rng::with_stream(cfg.seed, 0x91c4);
    let mut chan_rng = Rng::with_stream(cfg.seed, 0xc4a2);

    let mut w_g = ctx.init_weights();
    let mut clock = VirtualClock::new();
    let mut stack = vec![0.0f32; k * dim];
    let mut coef = vec![0.0f32; k];
    let mut delta = vec![0.0f32; dim];
    let mut records = Vec::with_capacity(cfg.rounds);

    for round in 0..cfg.rounds {
        let chosen = pick_rng.choose_indices(k, participants);
        let mut round_time = 0.0f64;
        let mut train_loss_sum = 0.0f64;
        let mut max_delta_norm2 = 0.0f64;
        coef.iter_mut().for_each(|c| *c = 0.0);
        stack.iter_mut().for_each(|v| *v = 0.0);

        let jobs: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = chosen
            .iter()
            .map(|&i| {
                round_time = round_time.max(latency.draw(&mut lat_rng));
                let (xs, ys) =
                    ctx.partition.client(i).sample_batches(m.local_steps, m.batch, &mut batch_rng);
                (w_g.clone(), xs, ys)
            })
            .collect();
        for (&i, out) in chosen.iter().zip(ctx.train_many(jobs, cfg.lr).unwrap()) {
            train_loss_sum += out.loss as f64;
            vecmath::sub(&out.weights, &w_g, &mut delta);
            let n2 = vecmath::dot(&delta, &delta);
            max_delta_norm2 = max_delta_norm2.max(n2);
            stack[i * dim..(i + 1) * dim].copy_from_slice(&delta);
            coef[i] = 1.0;
        }
        clock.advance(round_time);

        let alpha_t = if max_delta_norm2 > 1e-20 {
            cfg.p_max / max_delta_norm2
        } else {
            f64::INFINITY
        };
        let noise_std = if alpha_t.is_finite() {
            (mac.config().noise_power().sqrt() / alpha_t.sqrt()) as f32
        } else {
            0.0
        };
        let mut noise = vec![0.0f32; dim];
        chan_rng.fill_normal(&mut noise, noise_std);
        let mean_update = ctx.rt.aggregate(&stack, &coef, &noise).unwrap();
        vecmath::axpy(1.0, &mean_update, &mut w_g);

        records.push(RefRecord {
            round,
            sim_time: clock.now(),
            train_loss: (train_loss_sum / participants as f64) as f32,
            participants,
            mean_staleness: 0.0,
            mean_power: cfg.p_max,
        });
    }
    RefRun {
        records,
        final_weights: w_g,
    }
}

fn ref_centralized(ctx: &TrainContext, cfg: &Config) -> RefRun {
    let m = ctx.rt.manifest().clone();
    let pooled = ctx.partition.pooled();
    let mut batch_rng = Rng::with_stream(cfg.seed, 0xce27);

    let mut w = ctx.init_weights();
    let mut clock = VirtualClock::new();
    let mean_latency = (cfg.latency_lo + cfg.latency_hi) / 2.0;
    let mut records = Vec::with_capacity(cfg.rounds);

    for round in 0..cfg.rounds {
        let mut xs = Vec::with_capacity(m.local_steps * m.batch * pooled.dim);
        let mut ys = vec![0.0f32; m.local_steps * m.batch * pooled.classes];
        for row in 0..(m.local_steps * m.batch) {
            let i = batch_rng.index(pooled.len());
            xs.extend_from_slice(pooled.row(i));
            ys[row * pooled.classes + pooled.y[i] as usize] = 1.0;
        }
        let out = ctx.rt.local_train(&w, &xs, &ys, cfg.lr).unwrap();
        w = out.weights;
        clock.advance(mean_latency);

        records.push(RefRecord {
            round,
            sim_time: clock.now(),
            train_loss: out.loss,
            participants: 1,
            mean_staleness: 0.0,
            mean_power: 0.0,
        });
    }
    RefRun {
        records,
        final_weights: w,
    }
}

fn ref_fedasync(ctx: &TrainContext, cfg: &Config) -> RefRun {
    // EventQueue keys its removal index by payload, so the payload needs
    // Eq + Hash even though this reference port never removes.
    #[derive(Clone, Copy, PartialEq, Eq, Hash)]
    struct Finished {
        client: usize,
        base_window: usize,
    }
    let dim = ctx.dim();
    let k = ctx.clients();
    let m = ctx.rt.manifest().clone();
    let latency = cfg.latency();
    let horizon = cfg.rounds as f64 * cfg.delta_t;
    let gamma0 = cfg.fedasync_gamma;

    let mut lat_rng = Rng::with_stream(cfg.seed, 0x1a7);
    let mut batch_rng = Rng::with_stream(cfg.seed, 0xba7c);

    let mut w_g = ctx.init_weights();
    let mut bases: Vec<Vec<f32>> = (0..k).map(|_| w_g.clone()).collect();

    let mut q = EventQueue::new();
    for client in 0..k {
        q.push(
            latency.draw(&mut lat_rng),
            Finished {
                client,
                base_window: 0,
            },
        );
    }

    let mut records = Vec::with_capacity(cfg.rounds);
    let mut window = 0usize;
    let mut win_updates = 0usize;
    let mut win_loss = 0.0f64;
    let mut win_stale = 0.0f64;
    let mut mixed = vec![0.0f32; dim];

    let flush = |records: &mut Vec<RefRecord>, window: usize, n: usize, loss: f64, stale: f64| {
        records.push(RefRecord {
            round: window,
            sim_time: (window as f64 + 1.0) * cfg.delta_t,
            train_loss: if n > 0 { (loss / n as f64) as f32 } else { f32::NAN },
            participants: n,
            mean_staleness: if n > 0 { stale / n as f64 } else { 0.0 },
            mean_power: 0.0,
        });
    };

    while let Some((t, ev)) = q.pop() {
        if t > horizon {
            break;
        }
        while (window as f64 + 1.0) * cfg.delta_t < t {
            flush(&mut records, window, win_updates, win_loss, win_stale);
            window += 1;
            win_updates = 0;
            win_loss = 0.0;
            win_stale = 0.0;
        }

        let (xs, ys) = ctx
            .partition
            .client(ev.client)
            .sample_batches(m.local_steps, m.batch, &mut batch_rng);
        let out = ctx
            .rt
            .local_train(&bases[ev.client], &xs, &ys, cfg.lr)
            .unwrap();

        let stale = window.saturating_sub(ev.base_window);
        let gamma = gamma0 * staleness_factor(stale, cfg.omega);

        mixed.copy_from_slice(&w_g);
        vecmath::scale(&mut mixed, (1.0 - gamma) as f32);
        vecmath::axpy(gamma as f32, &out.weights, &mut mixed);
        std::mem::swap(&mut w_g, &mut mixed);

        win_updates += 1;
        win_loss += out.loss as f64;
        win_stale += stale as f64;

        bases[ev.client] = w_g.clone();
        q.push(
            t + latency.draw(&mut lat_rng),
            Finished {
                client: ev.client,
                base_window: window,
            },
        );
    }

    // Intended trailing-flush semantics: the first partial window keeps
    // its accumulated staleness (the seed hardcoded 0.0 here).
    while records.len() < cfg.rounds {
        let window = records.len();
        flush(&mut records, window, win_updates, win_loss, win_stale);
        win_updates = 0;
        win_loss = 0.0;
        win_stale = 0.0;
    }

    RefRun {
        records,
        final_weights: w_g,
    }
}

// ---------------------------------------------------------------------
// The equivalence tests.
// ---------------------------------------------------------------------

#[test]
fn paota_matches_seed_trainer() {
    run_case(&quick_cfg("paota"), ref_paota);
}

#[test]
fn local_sgd_matches_seed_trainer() {
    run_case(&quick_cfg("local_sgd"), ref_local_sgd);
}

#[test]
fn cotaf_matches_seed_trainer() {
    run_case(&quick_cfg("cotaf"), ref_cotaf);
}

#[test]
fn centralized_matches_seed_trainer() {
    run_case(&quick_cfg("centralized"), ref_centralized);
}

#[test]
fn fedasync_matches_seed_trainer() {
    // rounds = 5 leaves a tail beyond the last arrival so the trailing
    // window flush (the fixed-staleness path) is exercised too.
    let mut cfg = quick_cfg("fedasync");
    cfg.rounds = 5;
    run_case(&cfg, ref_fedasync);
}

#[test]
fn one_cell_one_group_hierarchy_is_bitwise_flat_paota() {
    // The topology degeneracy contract: a hierarchical run with cells = 1
    // and groups = 1 (the config defaults) must be BITWISE identical to
    // the flat paota run at the same seed — same weights bit patterns,
    // same record stream. Cell 0 runs on the base seed and an all-member
    // cell filter is the identity, so any drift here means the step-wise
    // coordinator API or the cell plumbing changed the RNG/flow.
    let cfg = quick_cfg("paota");
    assert_eq!(cfg.topology.cells, 1);
    assert_eq!(cfg.topology.groups, 1);
    let engine = Engine::cpu().unwrap();
    let ctx = TrainContext::build(&engine, &cfg).unwrap();
    let flat = fl::run_with_context(&ctx, &cfg).unwrap();
    let hier = fl::topology::multi_cell::run(&ctx, &cfg).unwrap();
    assert_eq!(hier.cells.len(), 1);
    for (tag, run) in [("cell0", &hier.cells[0]), ("merged", &hier.merged)] {
        assert_eq!(run.final_weights, flat.final_weights, "{tag}: weights drifted");
        assert_eq!(run.records.len(), flat.records.len(), "{tag}");
        for (a, b) in run.records.iter().zip(&flat.records) {
            let t = format!("{tag} round {}", b.round);
            assert_eq!(a.round, b.round, "{t}");
            assert_eq!(a.participants, b.participants, "{t}");
            assert_eq!(a.sim_time, b.sim_time, "{t}");
            assert!(
                a.train_loss == b.train_loss
                    || (a.train_loss.is_nan() && b.train_loss.is_nan()),
                "{t}: {} vs {}",
                a.train_loss,
                b.train_loss
            );
            assert_eq!(a.mean_staleness, b.mean_staleness, "{t}");
            assert_eq!(a.mean_power, b.mean_power, "{t}");
            assert_eq!(a.probe_loss, b.probe_loss, "{t}");
            match (a.eval, b.eval) {
                (None, None) => {}
                (Some(x), Some(y)) => {
                    assert_eq!(x.accuracy, y.accuracy, "{t}");
                    assert_eq!(x.loss, y.loss, "{t}");
                }
                _ => panic!("{t}: eval cadence drifted"),
            }
        }
    }
}

// ---------------------------------------------------------------------
// Parallel ≡ serial: the perf layer must be bitwise invisible. Runs on
// the native backend everywhere (no artifacts needed). CI re-runs this
// group with PAOTA_WORKERS=2 (`cargo test --test golden_seed parallel`).
// ---------------------------------------------------------------------

/// A small native-backend config regardless of whether AOT artifacts are
/// present (the parallel suite wants the thread-safe backend).
fn native_cfg(algo: &str) -> Config {
    let mut c = Config::default();
    c.algorithm = Algorithm::parse(algo).unwrap();
    c.rounds = 4;
    c.eval_every = 2;
    c.artifacts_dir = "native".into();
    c.synth.side = 8; // d_in = 64
    c.partition.clients = 12;
    c.partition.sizes = vec![40, 80];
    c.partition.test_size = 32;
    c
}

fn assert_records_bitwise(tag: &str, got: &fl::RunResult, want: &fl::RunResult) {
    assert_eq!(got.final_weights.len(), want.final_weights.len(), "{tag}");
    let same = got
        .final_weights
        .iter()
        .zip(&want.final_weights)
        .all(|(a, b)| a.to_bits() == b.to_bits());
    assert!(same, "{tag}: final weights drifted");
    assert_eq!(got.records.len(), want.records.len(), "{tag}");
    for (a, b) in got.records.iter().zip(&want.records) {
        let t = format!("{tag} round {}", b.round);
        assert_eq!(a.round, b.round, "{t}");
        assert_eq!(a.participants, b.participants, "{t}");
        assert_eq!(a.sim_time.to_bits(), b.sim_time.to_bits(), "{t}");
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "{t}");
        assert_eq!(a.mean_staleness.to_bits(), b.mean_staleness.to_bits(), "{t}");
        assert_eq!(a.mean_power.to_bits(), b.mean_power.to_bits(), "{t}");
        assert_eq!(a.probe_loss.map(f32::to_bits), b.probe_loss.map(f32::to_bits), "{t}");
        match (a.eval, b.eval) {
            (None, None) => {}
            (Some(x), Some(y)) => {
                assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "{t}");
                assert_eq!(x.accuracy.to_bits(), y.accuracy.to_bits(), "{t}");
            }
            _ => panic!("{t}: eval cadence drifted"),
        }
    }
}

#[test]
fn parallel_native_train_many_is_bitwise_serial() {
    // The same job batch through a 1-worker (in-line) context and a
    // multi-worker pool context must produce identical bits in order.
    let mut serial = native_cfg("paota");
    serial.perf.workers = 1;
    let mut par = serial.clone();
    par.perf.workers = 4;
    let ctx1 = TrainContext::new(&serial).unwrap();
    let ctx4 = TrainContext::new(&par).unwrap();
    assert!(ctx1.pool.is_none());
    assert!(ctx4.pool.is_some());

    let m = ctx1.rt.manifest().clone();
    let mut rng = Rng::new(9);
    let w0 = ctx1.init_weights();
    let jobs: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = (0..9)
        .map(|i| {
            let (xs, ys) = ctx1.partition.client(i % ctx1.clients()).sample_batches(
                m.local_steps,
                m.batch,
                &mut rng,
            );
            (w0.clone(), xs, ys)
        })
        .collect();
    let a = ctx1.train_many(jobs.clone(), 0.1).unwrap();
    let b = ctx4.train_many(jobs, 0.1).unwrap();
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.loss.to_bits(), y.loss.to_bits());
        let same = x
            .weights
            .iter()
            .zip(&y.weights)
            .all(|(p, q)| p.to_bits() == q.to_bits());
        assert!(same, "pooled training drifted from sequential");
    }
}

#[test]
fn parallel_native_full_run_matches_serial_bitwise() {
    // Whole-run equivalence: workers = 1 vs workers = 4 configs differ
    // only in the perf section, so records and weights must be bitwise
    // identical for every policy timing class.
    for algo in ["paota", "local_sgd", "fedasync"] {
        let mut serial = native_cfg(algo);
        serial.perf.workers = 1;
        let mut par = serial.clone();
        par.perf.workers = 4;
        let ctx1 = TrainContext::new(&serial).unwrap();
        let ctx4 = TrainContext::new(&par).unwrap();
        let a = fl::run_with_context(&ctx1, &serial).unwrap();
        let b = fl::run_with_context(&ctx4, &par).unwrap();
        assert_records_bitwise(&format!("{algo} workers=4 vs 1"), &b, &a);
    }
}

#[test]
fn parallel_campaign_csv_bytes_match_serial() {
    use paota::experiments::{Campaign, CurvesCsv, RecordsCsv};

    let base = native_cfg("paota");
    let run_campaign = |jobs: usize, dir: &std::path::Path| {
        let _ = std::fs::remove_dir_all(dir);
        let mut b = base.clone();
        b.perf.campaign_jobs = jobs;
        Campaign::new("bytes", b)
            .scenario("PAOTA", |_| {})
            .scenario("Local SGD", |c| {
                c.algorithm = Algorithm::parse("local_sgd").unwrap()
            })
            .scenario("seed 7", |c| c.seed = 7)
            .scenario("seed 8", |c| c.seed = 8)
            .observe(CurvesCsv::accuracy(dir.join("curves.csv")))
            .observe(RecordsCsv::new(dir.to_path_buf(), "bytes"))
            .run()
            .unwrap();
    };
    let d1 = std::env::temp_dir().join("paota_par_campaign_serial");
    let d2 = std::env::temp_dir().join("paota_par_campaign_jobs3");
    run_campaign(1, &d1);
    run_campaign(3, &d2);
    for file in ["curves.csv", "bytes_paota.csv", "bytes_local_sgd.csv"] {
        let a = std::fs::read(d1.join(file)).unwrap();
        let b = std::fs::read(d2.join(file)).unwrap();
        assert_eq!(a, b, "{file}: parallel campaign changed the output bytes");
    }
}

#[test]
fn parallel_multi_cell_cells_match_serial_stepping() {
    // Cells inside one slot step concurrently when workers > 1; the
    // hierarchy's per-cell and merged streams must not move by a bit.
    let mut cfg = native_cfg("paota");
    cfg.rounds = 5;
    cfg.topology.cells = 3;
    cfg.topology.mixing_every = 2;
    let mut serial = cfg.clone();
    serial.perf.workers = 1;
    let mut par = cfg.clone();
    par.perf.workers = 4;
    let ctx_s = TrainContext::new(&serial).unwrap();
    let ctx_p = TrainContext::new(&par).unwrap();
    let a = fl::topology::multi_cell::run(&ctx_s, &serial).unwrap();
    let b = fl::topology::multi_cell::run(&ctx_p, &par).unwrap();
    assert_eq!(a.cells.len(), b.cells.len());
    for (i, (x, y)) in b.cells.iter().zip(&a.cells).enumerate() {
        assert_records_bitwise(&format!("cell {i}"), x, y);
    }
    assert_records_bitwise("merged", &b.merged, &a.merged);
}

// ---------------------------------------------------------------------
// Observation neutrality: the obs layer (metrics registry + trace
// journal) reads simulation state but never touches an RNG stream or
// the virtual clock, so enabling it must not move a record by a bit.
// ---------------------------------------------------------------------

/// Per-test journal path (parallel `cargo test` safe).
fn obs_tmp(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("paota_obs_neutral_{tag}_{}.jsonl", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

#[test]
fn observed_run_is_bitwise_identical_to_unobserved() {
    for algo in ["paota", "fedasync", "local_sgd"] {
        let plain = native_cfg(algo);
        let mut observed = plain.clone();
        observed.obs.trace_path = obs_tmp(algo);
        observed.obs.sample_every = 1;
        std::fs::remove_file(&observed.obs.trace_path).ok();

        let a = fl::run(&plain).unwrap();
        let b = fl::run(&observed).unwrap();
        assert_records_bitwise(&format!("{algo}: observed vs plain"), &b, &a);

        // The journal really recorded the run: every record stream entry
        // went through `close_round`, which emits one `round_close`.
        let raw = std::fs::read_to_string(&observed.obs.trace_path).unwrap();
        let closes = raw
            .lines()
            .filter(|l| l.contains("\"kind\":\"round_close\""))
            .count();
        assert_eq!(closes, a.records.len(), "{algo}: journal round_close count\n{raw}");
        std::fs::remove_file(&observed.obs.trace_path).ok();
    }
}

#[test]
fn observed_mobile_multi_cell_is_bitwise_identical() {
    let mut plain = native_cfg("paota");
    plain.rounds = 5;
    plain.topology.cells = 3;
    plain.topology.mixing_every = 2;
    plain.mobility.kind = paota::fl::mobility::MobilityKind::Markov;
    plain.mobility.dwell_mean = 1.5;
    let mut observed = plain.clone();
    observed.obs.trace_path = obs_tmp("multi_cell");
    observed.obs.sample_every = 1;
    std::fs::remove_file(&observed.obs.trace_path).ok();

    let ctx_a = TrainContext::new(&plain).unwrap();
    let ctx_b = TrainContext::new(&observed).unwrap();
    let a = fl::topology::multi_cell::run(&ctx_a, &plain).unwrap();
    let b = fl::topology::multi_cell::run(&ctx_b, &observed).unwrap();
    assert_eq!(a.cells.len(), b.cells.len());
    for (i, (x, y)) in b.cells.iter().zip(&a.cells).enumerate() {
        assert_records_bitwise(&format!("observed cell {i}"), x, y);
    }
    assert_records_bitwise("observed merged", &b.merged, &a.merged);

    // `handover` journal events mirror the mobility tally one-for-one
    // (each `record_move` emits exactly one event at sample_every = 1).
    let raw = std::fs::read_to_string(&observed.obs.trace_path).unwrap();
    let hand = raw
        .lines()
        .filter(|l| l.contains("\"kind\":\"handover\""))
        .count();
    assert_eq!(hand, b.mobility.handovers, "journal handover count\n{raw}");
    assert!(hand > 0, "dwell_mean 1.5 over 5 slots moved nobody");
    std::fs::remove_file(&observed.obs.trace_path).ok();
}

#[test]
fn parallel_campaign_replays_observers_in_declaration_order() {
    use paota::experiments::{Campaign, RunObserver, RunResult, Scenario, ScenarioResult};
    use std::cell::RefCell;
    use std::rc::Rc;

    // Property: for any scenario count and job count, the observer hook
    // sequence is exactly the serial one — start(s0), end(s0), start(s1),
    // end(s1), …, campaign_end — regardless of completion order.
    struct OrderProbe {
        log: Rc<RefCell<Vec<String>>>,
    }
    impl RunObserver for OrderProbe {
        fn on_scenario_start(&mut self, scenario: &Scenario) -> anyhow::Result<()> {
            self.log.borrow_mut().push(format!("start:{}", scenario.name));
            Ok(())
        }
        fn on_scenario_end(&mut self, scenario: &Scenario, _run: &RunResult) -> anyhow::Result<()> {
            self.log.borrow_mut().push(format!("end:{}", scenario.name));
            Ok(())
        }
        fn on_campaign_end(&mut self, results: &[ScenarioResult]) -> anyhow::Result<()> {
            self.log.borrow_mut().push(format!("campaign_end:{}", results.len()));
            Ok(())
        }
    }

    for &count in &[1usize, 2, 5, 8] {
        for &jobs in &[1usize, 2, 3] {
            let mut base = native_cfg("paota");
            base.rounds = 2;
            base.eval_every = 2;
            base.perf.campaign_jobs = jobs;
            let names: Vec<String> = (0..count).map(|i| format!("s{i}")).collect();
            let log = Rc::new(RefCell::new(Vec::new()));
            let mut campaign = Campaign::new("order", base.clone());
            for (i, name) in names.iter().enumerate() {
                // Varying seeds vary each run's wall-clock, shuffling the
                // parallel completion order.
                let seed = 100 + ((i as u64 * 37) % 11);
                campaign = campaign.scenario(name.clone(), move |c| c.seed = seed);
            }
            campaign = campaign.observe(OrderProbe { log: Rc::clone(&log) });
            campaign.run().unwrap();

            let mut want: Vec<String> = Vec::new();
            for name in &names {
                want.push(format!("start:{name}"));
                want.push(format!("end:{name}"));
            }
            want.push(format!("campaign_end:{count}"));
            assert_eq!(*log.borrow(), want, "count={count} jobs={jobs}");
        }
    }
}

#[test]
fn fedasync_coalesced_ties_match_sequential_reference() {
    // Homogeneous latency makes ALL K clients finish at identical
    // timestamps: the coordinator coalesces each tie into one batched
    // `train_many` call, the reference serves them strictly one by one —
    // the streams must still agree bit-for-bit (within f32 tolerance).
    let mut cfg = quick_cfg("fedasync");
    cfg.latency_kind = LatencyKind::Homogeneous;
    cfg.latency_lo = 6.0;
    cfg.latency_hi = 6.0;
    run_case(&cfg, ref_fedasync);
}
